"""Property-based tests (hypothesis) for the MoE dispatch invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.configs import get_arch, reduced
from repro.models import moe as MOE


def _setup(E, K, T, seed, skew):
    cfg = reduced(get_arch("olmoe-1b-7b"), n_experts=E, experts_per_token=K,
                  d_model=32, moe_d_ff=32)
    p = MOE.init_moe(jax.random.PRNGKey(seed), cfg)
    if skew:
        p["router"] = p["router"].at[:, 0].add(float(skew))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (T, cfg.d_model))
    return cfg, p, x


@settings(max_examples=12, deadline=None)
@given(E=st.sampled_from([4, 8, 16]), K=st.sampled_from([1, 2, 4]),
       T=st.integers(16, 96), seed=st.integers(0, 50),
       skew=st.floats(0, 4))
def test_dispatch_accounting_invariant(E, K, T, seed, skew):
    """kept + dropped == T*K entries, capacity is never exceeded, and
    stealing never increases drops."""
    cfg, p, x = _setup(E, K, T, seed, skew)
    cap = jnp.ones((E,))
    for steal in (False, True):
        y, aux = MOE.moe_local(cfg, p, x, cap, steal=steal, capacity_factor=1.0)
        assert float(aux["entries"]) == T * K
        assert 0 <= float(aux["dropped"]) <= T * K
        assert bool(jnp.isfinite(y).all())
    _, a_ns = MOE.moe_local(cfg, p, x, cap, steal=False, capacity_factor=1.0)
    _, a_st = MOE.moe_local(cfg, p, x, cap, steal=True, capacity_factor=1.0)
    assert float(a_st["dropped"]) <= float(a_ns["dropped"]) + 1e-6


@settings(max_examples=10, deadline=None)
@given(E=st.sampled_from([4, 8]), T=st.integers(16, 64),
       seed=st.integers(0, 20))
def test_generous_capacity_matches_dropless(E, T, seed):
    """with capacity >> demand and no stealing, output equals the dropless
    top-k mixture exactly."""
    cfg, p, x = _setup(E, 2, T, seed, 0.0)
    y, aux = MOE.moe_local(cfg, p, x, jnp.ones((E,)) * 100, steal=False,
                           capacity_factor=50.0)
    assert float(aux["dropped"]) == 0
    probs = jax.nn.softmax((x @ p["router"]).astype(jnp.float32), -1)
    w, e = jax.lax.top_k(probs, 2)
    w = w / w.sum(-1, keepdims=True)
    y_ref = jnp.zeros_like(x)
    for j in range(2):
        h = jax.nn.silu(jnp.einsum("td,tdf->tf", x, p["wg"][e[:, j]])) * \
            jnp.einsum("td,tdf->tf", x, p["wi"][e[:, j]])
        y_ref = y_ref + w[:, j, None] * jnp.einsum("tf,tfd->td", h, p["wo"][e[:, j]])
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=5e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100), eps=st.floats(0.1, 0.6))
def test_cap_scale_fixed_point_on_balanced_load(seed, eps):
    """uniform router load is a fixed point of the iCh capacity update."""
    counts = jnp.full((16,), 100.0)
    cap = jnp.ones((16,))
    new = MOE.ich_update_cap_scale(counts, cap, eps=eps)
    np.testing.assert_allclose(np.asarray(new), np.asarray(cap), atol=1e-6)
