"""First test coverage for the serving engine (`repro/serve/engine.py`):
chunked-prefill equivalence, iCh divisor adaptation, `generate` contracts,
deadline-based graceful degradation (DESIGN.md §2.9), and the ssm
family's incremental prefill (state-threaded chunks, scan-block aligned,
bit-identical to one-shot).

Runs on a reduced decoder config (repro.configs.reduced) so the whole
module is CPU-cheap; the model params are built once per module.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models import model as M
from repro.serve.engine import Engine, EngineConfig
from repro.serve.metrics import ServeMetrics
from repro.serve.queue import Request, RequestState

ECFG = dict(max_seq=64, min_chunk=4)


@pytest.fixture(scope="module")
def tiny_model():
    cfg = reduced(get_arch("qwen2-1.5b"))
    params = M.init_params(cfg, jax.random.PRNGKey(0), max_seq=64)
    return cfg, params


@pytest.fixture()
def engine(tiny_model):
    cfg, params = tiny_model
    return Engine(cfg, params, EngineConfig(**ECFG))


def prompts_for(cfg, B=2, S=24, seed=2):
    return np.asarray(jax.random.randint(jax.random.PRNGKey(seed),
                                         (B, S), 0, cfg.vocab_size))


# ------------------------------------------------ chunked prefill

class TestChunkedPrefill:
    def test_matches_one_shot_bit_identical(self, tiny_model, engine):
        """Chunked prefill's final logits must equal a one-shot prefill of
        the same prompt bit-for-bit: the last chunk runs the FULL prompt
        through the same jitted prefill, so chunking affects scheduling
        (and the iCh divisor), never the math."""
        cfg, params = tiny_model
        toks = prompts_for(cfg)
        logits, _, log = engine.prefill_chunked(toks)
        one_shot = Engine(cfg, params, EngineConfig(**ECFG))
        ref, _ = one_shot._prefill(params, {"tokens": np.asarray(toks)})
        np.testing.assert_array_equal(np.asarray(logits), np.asarray(ref))
        assert len(log) > 1  # S=24 with d_0=4, min_chunk=4 -> chunked

    def test_chunk_log_covers_prompt_exactly(self, tiny_model, engine):
        cfg, _ = tiny_model
        B, S = 2, 24
        _, _, log = engine.prefill_chunked(prompts_for(cfg, B, S))
        assert sum(rec["chunk"] for rec in log) == S
        assert all(rec["chunk"] >= 1 for rec in log)
        assert all(set(rec) == {"chunk", "dt", "d"} for rec in log)

    def test_outputs_independent_of_chunk_count(self, tiny_model):
        """Incremental prefill feeds each chunk into the growing cache
        (O(chunk) work per chunk, engine no longer re-runs the prefix);
        the final logits must be bit-identical however the prompt is cut.
        Divisors 1/3/8 produce genuinely different chunk sequences."""
        cfg, params = tiny_model
        toks = prompts_for(cfg, B=2, S=24)
        logits, counts = [], []
        for d0 in (1.0, 3.0, 8.0):
            eng = Engine(cfg, params,
                         EngineConfig(max_seq=64, min_chunk=2,
                                      init_divisor=d0))
            lg, _, log = eng.prefill_chunked(toks)
            logits.append(np.asarray(lg))
            counts.append(len(log))
        assert len(set(counts)) > 1  # the splits really differed
        for lg in logits[1:]:
            np.testing.assert_array_equal(lg, logits[0])


# ------------------------------------------------ iCh divisor adaptation

def bare_engine(**overrides):
    """An Engine shell with only the state `_adapt`/`_next_chunk` touch —
    no model build needed to pin the divisor dynamics."""
    eng = Engine.__new__(Engine)
    eng.ecfg = EngineConfig(**{**ECFG, **overrides})
    eng.d = eng.ecfg.init_divisor
    eng.ks = []
    return eng


class TestAdapt:
    def steady(self, eng, rounds=6):
        for _ in range(rounds):
            eng._adapt(100, 1.0)

    def test_steady_throughput_keeps_divisor(self):
        eng = bare_engine()
        self.steady(eng)
        assert eng.d == eng.ecfg.init_divisor

    def test_fast_chunk_doubles_divisor(self):
        """Fast chunk (throughput above the mu + eps*mu band) -> HIGH ->
        d doubles -> next chunk shrinks, leaving slots for decode."""
        eng = bare_engine()
        self.steady(eng)
        eng._adapt(100, 0.01)
        assert eng.d == 2 * eng.ecfg.init_divisor

    def test_slow_chunk_halves_divisor(self):
        """Slow chunk (cache pressure, long context) -> LOW -> d halves ->
        next chunk grows to amortize dispatch."""
        eng = bare_engine()
        self.steady(eng)
        eng._adapt(100, 100.0)
        assert eng.d == eng.ecfg.init_divisor / 2

    def test_divisor_clamped_to_bounds(self):
        eng = bare_engine()
        for k in range(12):  # ever-faster chunks
            eng._adapt(100, 1.0 / 10 ** k)
        assert eng.d <= 64.0
        eng = bare_engine()
        for k in range(12):  # ever-slower chunks
            eng._adapt(100, 1.0 * 10 ** k)
        assert eng.d >= 1.0

    def test_next_chunk_contracts(self):
        eng = bare_engine()
        eng.d = 4.0
        assert eng._next_chunk(100) == 25
        assert eng._next_chunk(3) == 3      # never exceeds remaining
        eng.d = 64.0
        assert eng._next_chunk(100) == 4    # min_chunk floor


# ------------------------------------------------ generate

class TestGenerate:
    def test_output_shape_and_stats_contract(self, tiny_model, engine):
        cfg, _ = tiny_model
        B, S, n_new = 2, 24, 5
        out, stats = engine.generate(prompts_for(cfg, B, S), n_new=n_new)
        assert out.shape == (B, n_new)
        assert np.issubdtype(out.dtype, np.integer)
        assert (out >= 0).all() and (out < cfg.vocab_size).all()
        assert set(stats) == {"chunks", "d_final", "degraded", "n_shed",
                              "deadline_s"}
        assert stats["degraded"] is False and stats["n_shed"] == 0
        assert stats["deadline_s"] is None
        assert sum(rec["chunk"] for rec in stats["chunks"]) == S
        assert stats["d_final"] == engine.d

    def test_greedy_generate_deterministic(self, tiny_model):
        cfg, params = tiny_model
        toks = prompts_for(cfg)
        outs = [Engine(cfg, params, EngineConfig(**ECFG))
                .generate(toks, n_new=4)[0] for _ in range(2)]
        np.testing.assert_array_equal(outs[0], outs[1])

    def test_deadline_sheds_decode_steps(self, tiny_model, engine):
        """deadline_s=0 is already spent after prefill: the engine sheds
        all remaining decode steps, returns the partial output (at least
        the prefill argmax token) and flags the degradation."""
        cfg, _ = tiny_model
        n_new = 6
        out, stats = engine.generate(prompts_for(cfg), n_new=n_new,
                                     deadline_s=0.0)
        assert stats["degraded"] is True
        assert 1 <= out.shape[1] < n_new
        assert out.shape[1] + stats["n_shed"] == n_new
        assert stats["deadline_s"] == 0.0

    def test_generous_deadline_not_degraded(self, tiny_model, engine):
        cfg, _ = tiny_model
        out, stats = engine.generate(prompts_for(cfg), n_new=3,
                                     deadline_s=600.0)
        assert stats["degraded"] is False and stats["n_shed"] == 0
        assert out.shape[1] == 3

    def test_degraded_prefix_matches_full_run(self, tiny_model):
        """Degradation sheds FUTURE work only: the tokens a degraded run
        does emit are the same tokens the unconstrained run emits."""
        cfg, params = tiny_model
        toks = prompts_for(cfg)
        full, _ = Engine(cfg, params, EngineConfig(**ECFG)) \
            .generate(toks, n_new=6)
        part, stats = Engine(cfg, params, EngineConfig(**ECFG)) \
            .generate(toks, n_new=6, deadline_s=0.0)
        assert stats["degraded"] is True
        np.testing.assert_array_equal(part, full[:, :part.shape[1]])


# ------------------------------------------------ ssm incremental prefill

@pytest.fixture(scope="module")
def ssm_model():
    cfg = reduced(get_arch("xlstm-350m"), block_pattern=("X", "S"),
                  ssm_chunk=4)
    params = M.init_params(cfg, jax.random.PRNGKey(1), max_seq=64)
    return cfg, params


@pytest.fixture()
def ssm_engine(ssm_model):
    cfg, params = ssm_model
    return Engine(cfg, params, EngineConfig(**ECFG))


def assert_trees_equal(a, b):
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(
        np.asarray(x), np.asarray(y)), a, b)


class TestSSMIncrementalPrefill:
    """The ssm family extends chunk to chunk through its O(1) recurrent
    block states (mLSTM matrix, sLSTM h/c) instead of re-running the
    prefix: O(chunk) per chunk, bit-identical to a one-shot prefill as
    long as chunk boundaries align to the scan-block quantum Q."""

    def test_family_supported(self, ssm_model):
        cfg, _ = ssm_model
        assert M.extend_cache_specs_ok(cfg)

    def test_hybrid_still_falls_back(self):
        assert not M.extend_cache_specs_ok(reduced(get_arch("zamba2-1.2b")))

    def test_matches_one_shot_bit_identical(self, ssm_model, ssm_engine):
        """Logits AND final recurrent states must equal a one-shot
        prefill bit-for-bit, including a final PARTIAL chunk (S=22 is not
        a multiple of Q=4, so the last chunk pads exactly like the
        one-shot scan pads its tail block)."""
        cfg, params = ssm_model
        toks = prompts_for(cfg, B=2, S=22)
        logits, cache, log = ssm_engine.prefill_chunked(toks)
        ref, ref_cache = ssm_engine._prefill(params,
                                             {"tokens": np.asarray(toks)})
        np.testing.assert_array_equal(np.asarray(logits), np.asarray(ref))
        assert_trees_equal(cache, ref_cache)
        assert len(log) > 1            # really chunked
        assert ssm_engine.n_prefill_fallbacks == 0

    def test_chunks_align_to_scan_quantum(self, ssm_model, ssm_engine):
        cfg, _ = ssm_model
        _, _, log = ssm_engine.prefill_chunked(prompts_for(cfg, B=1, S=22))
        chunks = [rec["chunk"] for rec in log]
        assert sum(chunks) == 22
        assert all(c % 4 == 0 for c in chunks[:-1])  # only the tail is partial

    def test_outputs_independent_of_chunk_count(self, ssm_model):
        cfg, params = ssm_model
        toks = prompts_for(cfg, B=2, S=24)
        logits, counts = [], []
        for d0 in (1.0, 3.0, 8.0):
            eng = Engine(cfg, params,
                         EngineConfig(max_seq=64, min_chunk=2,
                                      init_divisor=d0))
            lg, _, log = eng.prefill_chunked(toks)
            logits.append(np.asarray(lg))
            counts.append(len(log))
        assert len(set(counts)) > 1  # the splits really differed
        for lg in logits[1:]:
            np.testing.assert_array_equal(lg, logits[0])

    def test_generate_deterministic_across_divisors(self, ssm_model):
        """Identical prefill states mean identical decode streams no
        matter how the prompt was chunked."""
        cfg, params = ssm_model
        toks = prompts_for(cfg, B=2, S=20)
        outs = [Engine(cfg, params,
                       EngineConfig(max_seq=64, min_chunk=4,
                                    init_divisor=d0))
                .generate(toks, n_new=4)[0] for d0 in (1.0, 8.0)]
        np.testing.assert_array_equal(outs[0], outs[1])

    def test_request_chunk_step_quantizes_and_matches(self, ssm_model,
                                                      ssm_engine):
        """The batcher primitive rounds the policy's chunk up to a
        multiple of Q and the completed prefill's first token equals the
        one-shot argmax."""
        cfg, params = ssm_model
        toks = prompts_for(cfg, B=1, S=10)
        st = RequestState(request=Request(req_id=0, tokens=toks, n_new=1))
        ssm_engine.prefill_chunk_step(st, 5)    # -> rounded up to 8
        assert st.prefill_done == 8
        ssm_engine.prefill_chunk_step(st, 1)    # -> final partial chunk (2)
        assert st.prefill_done == 10
        ref, _ = ssm_engine._prefill(params, {"tokens": np.asarray(toks)})
        assert st.out_tokens == [int(np.argmax(np.asarray(ref)[0]))]


class TestPrefillFallbackVisibility:
    def test_fallback_chunks_counted(self):
        """hybrid (zamba2) still re-runs the prefix per chunk — every
        such chunk must land in the loud counter."""
        cfg = reduced(get_arch("zamba2-1.2b"))
        params = M.init_params(cfg, jax.random.PRNGKey(2), max_seq=64)
        eng = Engine(cfg, params, EngineConfig(**ECFG))
        _, _, log = eng.prefill_chunked(prompts_for(cfg, B=1, S=12))
        assert eng.n_prefill_fallbacks == len(log) > 1

    def test_metrics_counter_wired(self):
        m = ServeMetrics()
        assert m.n_prefill_fallback == 0
        assert "n_prefill_fallback" in m.summary()
        m.n_prefill_fallback = 3
        assert ServeMetrics.from_state(m.state_dict()) \
            .n_prefill_fallback == 3
