"""Property-based tests (hypothesis) for the vectorized schedule
construction: the array programs in core/tiling.py must match the
`_reference_*` loop oracles exactly, and every constructed schedule must
still replay chunk-for-chunk through the discrete-event simulator, for
arbitrary sizes / rows_per_tile / width."""
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import policies as P
from repro.core.simulator import simulate
from repro.core.tiling import (
    _reference_build_schedule, _reference_coverage_counts,
    _reference_pack_csr, _reference_split_items,
    build_schedule, coverage_counts, pack_csr, split_items,
)

# sizes lists mix zeros, band-sized items, and heavy outliers so splitting,
# padding, and the zero-item slot rule all get exercised
_SIZES = st.lists(st.one_of(st.just(0), st.integers(0, 40),
                            st.integers(200, 3000)),
                  min_size=1, max_size=120)


@settings(max_examples=40, deadline=None)
@given(sizes=_SIZES, R=st.integers(1, 17),
       W=st.one_of(st.none(), st.integers(1, 600)))
def test_vectorized_matches_reference(sizes, R, W):
    sizes = np.asarray(sizes, np.int64)
    vec = build_schedule(sizes, rows_per_tile=R, width=W)
    ref = _reference_build_schedule(sizes, rows_per_tile=R, width=W)
    assert vec.width == ref.width and vec.n_items == ref.n_items
    np.testing.assert_array_equal(vec.item_id, ref.item_id)
    np.testing.assert_array_equal(vec.seg_start, ref.seg_start)
    np.testing.assert_array_equal(vec.seg_len, ref.seg_len)
    item, start, length = split_items(sizes, vec.width)
    assert (list(zip(item.tolist(), start.tolist(), length.tolist()))
            == _reference_split_items(sizes, vec.width))
    np.testing.assert_array_equal(coverage_counts(vec, sizes),
                                  _reference_coverage_counts(vec, sizes))
    counts = coverage_counts(vec, sizes)
    assert counts.shape == (int(sizes.sum()),) and (counts == 1).all()


@settings(max_examples=25, deadline=None)
@given(sizes=_SIZES, R=st.integers(1, 17),
       W=st.one_of(st.none(), st.integers(1, 600)), seed=st.integers(0, 99))
def test_vectorized_pack_csr_matches_reference(sizes, R, W, seed):
    sizes = np.asarray(sizes, np.int64)
    sched = build_schedule(sizes, rows_per_tile=R, width=W)
    rng = np.random.default_rng(seed)
    indptr = np.concatenate([[0], np.cumsum(sizes)])
    nnz = int(indptr[-1])
    indices = rng.integers(0, sizes.size, nnz).astype(np.int32)
    data = rng.standard_normal(nnz).astype(np.float32)
    for a, b in zip(pack_csr(indptr, indices, data, sched),
                    _reference_pack_csr(indptr, indices, data, sched)):
        np.testing.assert_array_equal(a, b)


@settings(max_examples=25, deadline=None)
@given(sizes=_SIZES, R=st.integers(1, 17),
       W=st.one_of(st.none(), st.integers(1, 600)), p=st.integers(1, 8))
def test_schedule_replays_in_simulator(sizes, R, W, p):
    """slot_ranges() of any vectorized-constructed schedule is a valid
    pretiled central-queue chunking: the simulator dispatches exactly the
    per-tile work tile_cost predicts, tile for tile."""
    sizes = np.asarray(sizes, np.int64)
    costs = 1.0 + sizes.astype(np.float64)
    sched = build_schedule(sizes, rows_per_tile=R, width=W)
    ranges = sched.slot_ranges()
    assert ranges[0, 0] == 0 and ranges[-1, 1] == int(sizes.sum())
    np.testing.assert_array_equal(ranges[1:, 0], ranges[:-1, 1])
    if int(sizes.sum()) == 0:  # no work units: nothing for the sim to run
        assert (sched.tile_cost(costs, sizes) == 0).all()
        return
    res = simulate(sched.unit_costs(costs, sizes), p, P.pretiled(ranges),
                   record_chunks=True)
    sim_work = np.array([w for (_, _, _, w) in res.chunk_log])
    np.testing.assert_allclose(sim_work, sched.tile_cost(costs, sizes),
                               atol=1e-9)
    assert res.chunks == sched.n_tiles
