"""Unit tests for the shared segmented-reduction epilogue
(core/segmented.py), independent of any particular ich_* kernel: a minimal
pallas_call harness scatters per-slot values through real build_schedule
item-id schedules and must match the per-slot scalar-RMW oracle the kernels
used before the windowed epilogue replaced it."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.segmented import segmented_apply, slot_window
from repro.core.tiling import build_schedule


def _apply_kernel(rowid_ref, vals_ref, out_ref, *, combine):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    segmented_apply(out_ref, rowid_ref[t], vals_ref[0], combine=combine)


def _run(rowid, vals, n_out, combine, dtype):
    T, R = rowid.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(T,),
        in_specs=[pl.BlockSpec((1, R), lambda t, rowid: (t, 0))],
        out_specs=pl.BlockSpec((n_out,), lambda t, rowid: (0,)),
    )
    return pl.pallas_call(
        functools.partial(_apply_kernel, combine=combine),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_out,), dtype),
        interpret=True,
    )(jnp.asarray(rowid), jnp.asarray(vals))


def _oracle(rowid, vals, n_out, combine, dtype):
    out = np.zeros(n_out, dtype)
    for t in range(rowid.shape[0]):
        for j in range(rowid.shape[1]):
            r = int(rowid[t, j])
            if r < 0:
                continue
            if combine == "add":
                out[r] += vals[t, j]
            elif combine == "max":
                out[r] = max(out[r], vals[t, j])
            else:
                out[r] = vals[t, j]
    return out


def _schedule_and_values(n, R, W, seed, split_aware):
    rng = np.random.default_rng(seed)
    sizes = np.minimum(rng.zipf(1.6, n), 10 * max(W or 8, 8)).astype(np.int64)
    sizes[rng.random(n) < 0.1] = 0
    sched = build_schedule(sizes, rows_per_tile=R, width=W)
    if split_aware:
        # "store" semantics need duplicate slots to agree (the K-Means
        # idempotence contract): value is a function of the item alone
        per_item = rng.integers(0, 7, n).astype(np.float32)
        vals = np.where(sched.item_id >= 0,
                        per_item[np.clip(sched.item_id, 0, n - 1)], 0.0)
    else:
        vals = rng.standard_normal(sched.item_id.shape).astype(np.float32)
    return sched, vals.astype(np.float32)


@pytest.mark.parametrize("n,R,W,seed", [
    (64, 8, None, 0), (100, 4, 16, 1), (37, 16, 8, 2), (200, 8, None, 3),
    (5, 8, 4, 4),  # n_out < R: window shrinks to n_out
])
@pytest.mark.parametrize("combine", ["add", "max"])
def test_segmented_apply_matches_scalar_rmw(n, R, W, seed, combine):
    # values include negatives: "max" must leave uncovered window rows
    # untouched and must not floor covered rows at a fake 0 neutral
    sched, vals = _schedule_and_values(n, R, W, seed, split_aware=False)
    out = _run(sched.item_id, vals, n, combine, jnp.float32)
    np.testing.assert_allclose(
        np.asarray(out), _oracle(sched.item_id, vals, n, combine, np.float32),
        atol=1e-5)


def test_segmented_add_keeps_float64_accuracy():
    # regression: the one-hot matmul must accumulate in the value dtype
    # (promoted to >= f32), not force-truncate f64 partials to f32
    with jax.experimental.enable_x64():
        sched, vals = _schedule_and_values(64, 8, None, 11, split_aware=False)
        vals = vals.astype(np.float64) + 1e-9
        out = _run(sched.item_id, vals, 64, "add", jnp.float64)
        oracle = _oracle(sched.item_id, vals, 64, "add", np.float64)
        assert np.asarray(out).dtype == np.float64
        np.testing.assert_allclose(np.asarray(out), oracle, atol=1e-12,
                                   rtol=0)


@pytest.mark.parametrize("n,R,W,seed", [
    (64, 8, None, 0), (100, 4, 8, 1), (37, 16, 4, 2), (5, 8, 4, 3),
])
def test_segmented_store_matches_idempotent_writes(n, R, W, seed):
    sched, vals = _schedule_and_values(n, R, W, seed, split_aware=True)
    out = _run(sched.item_id, vals, n, "store", jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(out), _oracle(sched.item_id, vals, n, "store", np.float32))


def test_slot_window_covers_every_tile_of_any_schedule():
    """The window invariant behind the whole layer: greedy in-order packing
    keeps each tile's item ids inside one length-R window."""
    rng = np.random.default_rng(7)
    for _ in range(30):
        n = int(rng.integers(1, 400))
        R = int(rng.choice([1, 2, 4, 8, 16]))
        sizes = np.minimum(rng.zipf(1.5, n), 5000).astype(np.int64)
        sizes[rng.random(n) < 0.2] = 0
        sched = build_schedule(sizes, rows_per_tile=R)
        for t in range(sched.n_tiles):
            rows = sched.item_id[t]
            valid = rows[rows >= 0]
            if valid.size:
                assert valid.max() - valid.min() < R
            base, onehot = jax.jit(
                slot_window, static_argnums=1)(jnp.asarray(rows), n)
            # every valid slot is inside the window and one-hot is exact
            onehot = np.asarray(onehot)
            base = int(base)
            for j, r in enumerate(rows):
                if r >= 0:
                    assert onehot[j].sum() == 1
                    assert base + int(np.argmax(onehot[j])) == r
                else:
                    assert onehot[j].sum() == 0


def test_segmented_apply_rejects_unknown_combine():
    class _FakeRef:
        shape = (8,)

    with pytest.raises(ValueError, match="combine"):
        segmented_apply(_FakeRef(), jnp.zeros(8, jnp.int32),
                        jnp.zeros(8), combine="mul")
