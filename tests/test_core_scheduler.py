"""Unit + property tests for the iCh scheduler core (paper §3)."""
import threading

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    HIGH, LOW, NORMAL, SimParams, Welford, adapt_d, classify, dynamic,
    guided, ich, ich_band, ich_chunk, ich_initial_d, parallel_for,
    paper_policy_grid, simulate, static, steal_merge, stealing, taskloop,
    binlpt,
)
from repro.core import workloads as WL

PARAMS = SimParams()


# ---------------------------------------------------------------- welford
def test_welford_matches_numpy():
    rng = np.random.default_rng(0)
    xs = rng.exponential(10.0, size=500)
    w = Welford()
    w.update_many(xs)
    assert np.isclose(w.mean, xs.mean())
    assert np.isclose(w.variance, xs.var())


def test_ich_band_and_classification():
    ks = np.array([10.0, 10.0, 10.0, 50.0])
    mu, delta = ich_band(ks, 0.25)
    assert np.isclose(mu, 20.0) and np.isclose(delta, 5.0)
    assert classify(10.0, mu, delta) == LOW
    assert classify(20.0, mu, delta) == NORMAL
    assert classify(50.0, mu, delta) == HIGH


def test_adapt_d_direction_is_inverted_on_purpose():
    # paper §3.2: LOW (slow) -> bigger chunk (smaller d); HIGH -> smaller chunk
    assert adapt_d(8.0, LOW) == 4.0
    assert adapt_d(8.0, HIGH) == 16.0
    assert adapt_d(8.0, NORMAL) == 8.0
    assert adapt_d(1.0, LOW) == 1.0  # clamped
    assert adapt_d(4096.0, HIGH) == 4096.0  # clamped


def test_steal_merge_averages():
    k, d = steal_merge(10.0, 4.0, 30.0, 8.0)
    assert k == 20.0 and d == 6.0


def test_ich_chunk_law():
    p = 4
    assert ich_initial_d(p) == 4.0
    assert ich_chunk(16, 4.0) == 4  # n/p^2 with |q|=n/p
    assert ich_chunk(3, 8.0) == 1  # never below 1
    assert ich_chunk(0, 8.0) == 0


# ---------------------------------------------------------------- simulator
@pytest.mark.parametrize("pol", [
    dynamic(1), dynamic(3), guided(1), taskloop(8), binlpt(64),
    stealing(2), stealing(64), ich(0.25), ich(0.5), static(),
])
def test_simulator_executes_every_iteration_exactly_once(pol):
    costs = WL.synth_exp(2000, increasing=False, seed=3)
    r = simulate(costs, 8, pol, PARAMS, record_assignment=True)
    assert (r.assignment >= 0).all()
    assert r.makespan > 0


@pytest.mark.parametrize("pol", [dynamic(2), guided(1), stealing(2), ich(0.25)])
def test_simulator_makespan_lower_bound(pol):
    """makespan >= total_work / (p * fastest speed) and >= max single cost."""
    costs = WL.synth_exp(3000, increasing=True, seed=1)
    p = 8
    r = simulate(costs, p, pol, PARAMS)
    fastest = 1.0 + 5 * PARAMS.speed_jitter
    assert r.makespan >= costs.sum() / (p * fastest)
    assert r.makespan >= costs.max() / fastest


def test_single_worker_reduces_to_serial():
    costs = np.ones(100) * 5.0
    r = simulate(costs, 1, guided(1), PARAMS)
    # serial work/speed + one dispatch; speed jitter is a few percent
    assert r.makespan == pytest.approx(500.0, rel=0.25)
    assert r.steals == 0


def test_central_queue_contention_limits_throughput():
    """dynamic(1) on tiny iterations must saturate at the lock rate --
    the mechanism behind the paper's K-Means plateau (§6.1)."""
    costs = np.full(20000, 2.0)  # iteration cost ~ dispatch overhead
    r1 = simulate(costs, 1, dynamic(1), PARAMS)
    r28 = simulate(costs, 28, dynamic(1), PARAMS)
    speedup = r1.makespan / r28.makespan
    assert speedup < 5.0  # heavily serialized
    rs = simulate(costs, 28, stealing(64), PARAMS)
    assert r1.makespan / rs.makespan > 15.0  # distributed queues scale


def test_ich_adapts_d_and_steals_on_imbalance():
    costs = WL.synth_exp(4000, increasing=False, seed=0)
    r = simulate(costs, 8, ich(0.25), PARAMS)
    assert r.steals > 0
    assert r.ds is not None and (r.ds != ich_initial_d(8)).any()
    # NOTE: sum(k_i) != n under iCh -- the paper's steal rule AVERAGES the
    # thief's and victim's k (Listing 1), so k is an estimate after steals.
    assert (r.ks > 0).all()
    rs = simulate(costs, 8, stealing(2), PARAMS)
    assert rs.ks.sum() == len(costs)  # plain stealing: k is an exact count


def test_guided_fails_on_exp_decreasing_but_ich_does_not():
    """Paper Fig. 4 (Exp-Decreasing): guided collapses, iCh stays close to
    the best method."""
    costs = WL.synth_exp(20000, increasing=False, seed=0)
    p = 28
    t = {m: min(simulate(costs, p, pol, PARAMS).makespan
                for pol in paper_policy_grid(p) if pol.name == m)
         for m in ("guided", "dynamic", "stealing", "ich")}
    assert t["guided"] > 2.0 * t["dynamic"]
    best = min(t.values())
    assert t["ich"] <= 1.15 * best


# ------------------------------------------------------------- hypothesis
@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=400),
    p=st.integers(min_value=1, max_value=16),
    pol_idx=st.integers(min_value=0, max_value=4),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_property_all_policies_schedule_everything(n, p, pol_idx, seed):
    rng = np.random.default_rng(seed)
    costs = rng.exponential(10.0, size=n) + 0.1
    pol = [dynamic(2), guided(1), taskloop(p), stealing(3), ich(0.33)][pol_idx]
    r = simulate(costs, p, pol, PARAMS, record_assignment=True)
    assert (r.assignment >= 0).all()
    assert (r.assignment < p).all()
    fastest = 1.0 + 5 * PARAMS.speed_jitter
    assert r.makespan >= costs.sum() / (p * fastest) - 1e-9


@settings(max_examples=15, deadline=None)
@given(eps=st.floats(min_value=0.05, max_value=0.9),
       seed=st.integers(min_value=0, max_value=100))
def test_property_ich_d_stays_bounded(eps, seed):
    rng = np.random.default_rng(seed)
    costs = rng.exponential(50.0, size=500) + 1.0
    r = simulate(costs, 8, ich(eps), PARAMS)
    assert (r.ds >= 1.0).all() and (r.ds <= 4096.0).all()


# ---------------------------------------------------------------- executor
@pytest.mark.parametrize("pol", [dynamic(3), guided(1), taskloop(4),
                                 stealing(2), ich(0.25)])
def test_threaded_executor_exactly_once(pol):
    n = 3000
    hits = np.zeros(n, dtype=np.int64)
    lock = threading.Lock()

    def body(i):
        with lock:
            hits[i] += 1

    parallel_for(n, body, 6, pol)
    assert (hits == 1).all()


def test_threaded_executor_steals_under_imbalance():
    # worker 0's range is artificially slow -> others must steal
    n = 800
    hits = np.zeros(n, dtype=np.int64)
    lock = threading.Lock()

    def body(i):
        if i < n // 8:
            x = 0.0
            for k in range(2000):
                x += k * 0.5
        with lock:
            hits[i] += 1

    st_ = parallel_for(n, body, 8, ich(0.25))
    assert (hits == 1).all()
    assert st_.chunks > 8
