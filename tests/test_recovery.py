"""Checkpoint/restart recovery for the sharded kernel layer (DESIGN.md
§2.11): superstep-boundary `CheckpointLog`, chain-widened
`Schedule.reshard_survivors`, bit-identical kill-k-of-p recovery for all
three workloads, the recovery-vs-steal inflation cross-check, and the
seeded recovery matrix CI runs (RECOVERY_SEEDS kill-points).

Checkpoint logs for the matrix cases are written to results/recovery/ so
a CI failure uploads the exact interrupted-run state that broke.
"""
import json
import os
from pathlib import Path

import numpy as np
import pytest
from conftest import random_csr as _random_csr

from repro.core import tiling as T
from repro.robust import CheckpointLog, Death, FaultPlan, plan_recovery
from repro.sched.api import LoopScheduler

RESULTS = Path(__file__).resolve().parent.parent / "results" / "recovery"

# the CI recovery matrix: each seed picks a (p, k, ragged kill point);
# override RECOVERY_SEEDS=0,1,... to widen or pin the sweep
RECOVERY_SEEDS = [int(s) for s in os.environ.get(
    "RECOVERY_SEEDS", ",".join(map(str, range(12)))).split(",") if s != ""]


def _schedule(n=160, *, p=4, seed=0):
    indptr, indices, data = _random_csr(n, seed=seed)
    s = LoopScheduler(p=p, cache_size=0).schedule(np.diff(indptr))
    return s, (indptr, indices, data)


def _spmv_runner(s, csr, B):
    import jax.numpy as jnp
    from repro.kernels.ich_spmv.ich_spmv import ich_spmv_sharded

    indptr, indices, data = csr
    n = len(indptr) - 1
    vp, cp = T.pack_csr(indptr, indices, data, s.tiles, pad_tiles_to=B)
    x = np.random.default_rng(9).standard_normal(n).astype(np.float32)

    def run(sh):
        return np.asarray(ich_spmv_sharded(
            jnp.asarray(vp), jnp.asarray(cp),
            jnp.asarray(sh.shard_item_id(s.tiles)),
            jnp.asarray(sh.kernel_block_ids()), jnp.asarray(x), n, sh.p,
            B, interpret=True))

    return run


def _checkpoint(p, steps):
    """A log where worker w completed its first steps[w] grid steps."""
    log = CheckpointLog()
    for w in range(p):
        log.mark_through(w, steps[w])
    return log


# ------------------------------------------------- checkpoint log basics

class TestCheckpointLog:
    def test_json_roundtrip(self):
        log = _checkpoint(3, [2, 0, 1])
        log.mark(2, 5)
        back = CheckpointLog.from_json(log.to_json())
        assert back.entries == log.entries
        assert json.loads(back.to_json()) == json.loads(log.to_json())

    def test_rejects_negative_entries(self):
        with pytest.raises(ValueError):
            CheckpointLog().mark(-1, 0)
        with pytest.raises(ValueError):
            CheckpointLog().mark(0, -2)

    def test_completed_blocks_ignores_out_of_range_and_padding(self):
        s, _ = _schedule(80, p=3)
        shards = s.shard(superstep=4)
        log = CheckpointLog()
        log.mark(0, 0)
        log.mark(99, 0)                    # unknown worker: ignored
        log.mark(0, shards.n_steps + 7)    # past the grid: ignored
        for st in range(shards.n_steps):
            log.mark(1, st)                # includes padding steps
        done = log.completed_blocks(shards)
        expect = {int(shards.block_perm[0, 0])}
        expect |= {int(b) for b in shards.block_perm[1] if b >= 0}
        assert set(done.tolist()) == expect


# ------------------------------------------------------ plan structure

class TestRecoveryPlanStructure:
    def test_dead_out_of_range_and_all_dead_rejected(self):
        s, _ = _schedule(60, p=2)
        with pytest.raises(ValueError, match="out of range"):
            s.reshard_survivors(dead=[5], superstep=4)
        with pytest.raises(ValueError, match="all 2 workers dead"):
            s.reshard_survivors(dead=[0, 1], superstep=4)

    @pytest.mark.parametrize("p,k", [(2, 1), (4, 1), (4, 2)])
    def test_partition_is_chain_closed(self, p, k):
        """keep/redo partition the blocks; every redo chain is included
        whole; every keep block's chain is fully checkpointed."""
        s, _ = _schedule(200, p=p, seed=p)
        B = 4
        shards = s.shard(superstep=B)
        log = _checkpoint(p, [(w * 7 + 3) % (shards.n_steps + 1)
                              for w in range(p)])
        plan = s.reshard_survivors(dead=range(k), checkpoint=log,
                                   superstep=B)
        n_blocks = -(-s.n_tiles // B)
        both = np.concatenate([plan.keep_blocks, plan.redo_blocks])
        np.testing.assert_array_equal(np.sort(both), np.arange(n_blocks))
        chain = T.block_chains(s.item_id, B)
        redo_chains = set(chain[plan.redo_blocks].tolist())
        keep_chains = set(chain[plan.keep_blocks].tolist())
        assert not (redo_chains & keep_chains)
        # every block of every redo chain is in redo (whole chains)
        for c in redo_chains:
            assert set(np.flatnonzero(chain == c)) <= \
                set(plan.redo_blocks.tolist())
        # keep blocks all proven complete
        done = set(log.completed_blocks(shards).tolist())
        assert set(plan.keep_blocks.tolist()) <= done
        # survivor layout uses p-k rows and covers exactly the redo blocks
        assert plan.p_rec == p - k
        rec_blocks = plan.shards.block_perm[plan.shards.block_perm >= 0]
        np.testing.assert_array_equal(np.sort(rec_blocks),
                                      plan.redo_blocks)
        # redo_items is exactly the union of redo blocks' item ids
        idx = (plan.redo_blocks[:, None] * B + np.arange(B)).reshape(-1)
        idx = idx[idx < s.n_tiles]
        ids = s.item_id[idx]
        expect = np.zeros(s.n_items, bool)
        expect[ids[ids >= 0]] = True
        np.testing.assert_array_equal(plan.redo_items, expect)

    def test_empty_checkpoint_is_full_restart(self):
        s, _ = _schedule(100, p=4)
        plan = s.reshard_survivors(dead=[2], superstep=4)
        assert plan.keep_blocks.size == 0
        assert plan.redo_items.all()
        assert float(plan.makespan_model(s.tile_cost())["t_done"]) == 0.0


# ----------------------------------------- bit-identical kill-k recovery

KILL_CASES = [(2, (1,)), (4, (1,)), (4, (0, 2))]


@pytest.mark.parametrize("p,dead", KILL_CASES)
def test_spmv_recovery_bit_identical(p, dead):
    """Interrupted sharded SpMV + survivor re-execution == fault-free run,
    bitwise, across ragged per-worker checkpoint positions."""
    B = 4
    s, csr = _schedule(170, p=p, seed=11 + p)
    shards = s.shard(superstep=B)
    run = _spmv_runner(s, csr, B)
    y_full = run(shards)
    for shift in range(3):
        steps = [(w + shift) % (shards.n_steps + 1) for w in range(p)]
        plan = s.reshard_survivors(dead=dead,
                                   checkpoint=_checkpoint(p, steps),
                                   superstep=B)
        y = plan.combine(run(plan.done_shards), run(plan.shards))
        np.testing.assert_array_equal(y, y_full)


@pytest.mark.parametrize("p,dead", KILL_CASES)
def test_bfs_recovery_bit_identical(p, dead):
    import jax.numpy as jnp
    from repro.kernels.ich_bfs.ich_bfs import ich_bfs_step_sharded

    B = 4
    s, (indptr, indices, _) = _schedule(150, p=p, seed=23 + p)
    n = len(indptr) - 1
    shards = s.shard(superstep=B)
    ones = np.ones(int(indptr[-1]), np.float32)
    mp, cp = T.pack_csr(indptr, indices, ones, s.tiles, pad_tiles_to=B)
    rng = np.random.default_rng(23 + p)
    frontier = (rng.random(n) < 0.1).astype(np.float32)
    visited = frontier.copy()

    def run(sh):
        return np.asarray(ich_bfs_step_sharded(
            jnp.asarray(mp), jnp.asarray(cp),
            jnp.asarray(sh.shard_item_id(s.tiles)),
            jnp.asarray(sh.kernel_block_ids()), jnp.asarray(frontier),
            jnp.asarray(visited), n, sh.p, B, interpret=True))

    nxt_full = run(shards)
    steps = [shards.n_steps // 2] * p
    plan = s.reshard_survivors(dead=dead, checkpoint=_checkpoint(p, steps),
                               superstep=B)
    nxt = plan.combine(run(plan.done_shards), run(plan.shards))
    np.testing.assert_array_equal(nxt, nxt_full)


@pytest.mark.parametrize("p,dead", KILL_CASES)
def test_kmeans_recovery_bit_identical(p, dead):
    import jax.numpy as jnp
    from repro.kernels.ich_kmeans.ich_kmeans import ich_kmeans_assign_sharded

    B = 4
    rng = np.random.default_rng(31 + p)
    n = 140
    costs = rng.uniform(1.0, 9.0, n)
    s = LoopScheduler(p=p, cache_size=0).schedule(costs)
    shards = s.shard(superstep=B)
    pts = rng.standard_normal((n, 5)).astype(np.float32)
    cent = rng.standard_normal((6, 5)).astype(np.float32)

    def run(sh):
        return np.asarray(ich_kmeans_assign_sharded(
            jnp.asarray(pts), jnp.asarray(cent),
            jnp.asarray(sh.shard_item_id(s.tiles)), sh.p, B,
            interpret=True))

    a_full = run(shards)
    steps = [1 + (w % max(shards.n_steps - 1, 1)) for w in range(p)]
    plan = s.reshard_survivors(dead=dead, checkpoint=_checkpoint(p, steps),
                               superstep=B)
    a = plan.combine(run(plan.done_shards), run(plan.shards))
    np.testing.assert_array_equal(a, a_full)


def test_combine_shape_validation():
    s, _ = _schedule(60, p=2)
    plan = s.reshard_survivors(dead=[0], superstep=4)
    n = s.n_items
    with pytest.raises(ValueError, match="shapes"):
        plan.combine(np.zeros(n), np.zeros(n + 1))
    with pytest.raises(ValueError, match="does not match"):
        plan.combine(np.zeros(n + 3), np.zeros(n + 3))


# --------------------------------------- recovery vs steal-only inflation

def test_reshard_inflation_not_worse_than_steal_reclaim():
    """The §2.11 claim the bench asserts per release: finishing an
    interrupted run by RE-LOWERING the incomplete chains onto survivors
    (barrier-time model: completed prefix + re-execution) costs no more
    than PR 7's dynamic steal-path reclaim of the same early deaths,
    which pays per-chunk steal/dispatch overheads for every reclaimed
    item."""
    from repro.core.policies import ich
    from repro.core.simulator import simulate

    p, seed = 4, 100
    rng = np.random.default_rng(seed)
    n = 400
    sizes = rng.integers(8, 13, n)
    s = LoopScheduler(p=p, cache_size=0).schedule(sizes)
    shards = s.shard()
    tc = s.tile_cost()
    clean_static = float(shards.worker_cost(tc).max())
    clean_steal = simulate(s.costs, p, ich())
    for k in (1, 2, 3):
        faulty = simulate(s.costs, p, ich(),
                          faults=FaultPlan(
                              seed=seed,
                              deaths=tuple((w, 1) for w in range(k))))
        steal_inflation = faulty.makespan / clean_steal.makespan
        log = _checkpoint(p, [1] * p)      # same early-death kill point
        plan = s.reshard_survivors(dead=range(k), checkpoint=log)
        mm = plan.makespan_model(tc)
        reshard_inflation = mm["makespan"] / clean_static
        assert reshard_inflation <= steal_inflation, (
            f"k={k}: reshard inflation {reshard_inflation:.3f} exceeds "
            f"steal-only inflation {steal_inflation:.3f}")


# -------------------------------------------------- seeded recovery matrix

@pytest.mark.parametrize("seed", RECOVERY_SEEDS)
def test_recovery_matrix(seed):
    """One seeded kill scenario per RECOVERY_SEEDS entry: seed-derived
    (p, k, ragged checkpoint), SpMV recovery asserted bit-identical, and
    the scenario's checkpoint + plan summary written to results/recovery/
    for the CI failure artifact."""
    rng = np.random.default_rng(seed)
    p = int(rng.choice([2, 4]))
    k = 1 if p == 2 else int(rng.integers(1, 3))
    dead = tuple(sorted(rng.choice(p, size=k, replace=False).tolist()))
    B = 4
    s, csr = _schedule(120, p=p, seed=seed)
    shards = s.shard(superstep=B)
    steps = rng.integers(0, shards.n_steps + 1, p).tolist()
    log = _checkpoint(p, steps)
    plan = s.reshard_survivors(dead=dead, checkpoint=log, superstep=B)

    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / f"sharded_seed{seed}.json").write_text(json.dumps({
        "seed": seed, "p": p, "dead": list(dead), "steps": steps,
        "checkpoint": json.loads(log.to_json()),
        "keep_blocks": plan.keep_blocks.tolist(),
        "redo_blocks": plan.redo_blocks.tolist(),
        "makespan_model": plan.makespan_model(s.tile_cost()),
    }, indent=2) + "\n")

    run = _spmv_runner(s, csr, B)
    y = plan.combine(run(plan.done_shards), run(plan.shards))
    np.testing.assert_array_equal(y, run(shards))
    # the plan is a pure function of its inputs: replanning is identical
    again = s.reshard_survivors(dead=dead, checkpoint=log, superstep=B)
    np.testing.assert_array_equal(again.redo_blocks, plan.redo_blocks)
    np.testing.assert_array_equal(again.keep_blocks, plan.keep_blocks)
