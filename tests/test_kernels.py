"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.flash_attention.ops import flash_attention_op
from repro.kernels.ich_bfs.ops import IChBfs
from repro.kernels.ich_bfs.ref import bfs_levels_ref, bfs_step_ref
from repro.kernels.ich_kmeans.ops import IChKMeans
from repro.kernels.ich_kmeans.ref import kmeans_assign_ref
from repro.kernels.ich_spmv.ich_spmv import ich_spmv, pack_tiles
from repro.kernels.ich_spmv.ref import spmv_ref, tiles_ref
from repro.kernels.ich_spmv.ops import IChSpmv
from repro.kernels.mamba_scan.mamba_scan import mamba_scan
from repro.kernels.mamba_scan.ref import ssd_ref

RNG = np.random.default_rng(0)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


# ------------------------------------------------------------ flash attention
@pytest.mark.parametrize("B,S,Hq,Hkv,dh", [
    (1, 64, 2, 2, 64),
    (2, 128, 4, 2, 64),
    (1, 256, 8, 8, 128),
    (2, 192, 6, 3, 64),
    (1, 512, 4, 1, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, S, Hq, Hkv, dh, dtype):
    q = jnp.array(RNG.standard_normal((B, S, Hq, dh)), dtype)
    k = jnp.array(RNG.standard_normal((B, S, Hkv, dh)), dtype)
    v = jnp.array(RNG.standard_normal((B, S, Hkv, dh)), dtype)
    out = flash_attention(q, k, v, causal=True, q_block=64, kv_block=64,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


def test_flash_attention_noncausal():
    q = jnp.array(RNG.standard_normal((2, 128, 4, 64)), jnp.float32)
    k = jnp.array(RNG.standard_normal((2, 128, 4, 64)), jnp.float32)
    v = jnp.array(RNG.standard_normal((2, 128, 4, 64)), jnp.float32)
    out = flash_attention(q, k, v, causal=False, q_block=64, kv_block=64,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_flash_attention_op_pads_ragged_seq():
    q = jnp.array(RNG.standard_normal((1, 100, 4, 64)), jnp.float32)
    k = jnp.array(RNG.standard_normal((1, 100, 2, 64)), jnp.float32)
    v = jnp.array(RNG.standard_normal((1, 100, 2, 64)), jnp.float32)
    out = flash_attention_op(q, k, v, q_block=32, kv_block=32, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, ref, atol=3e-5, rtol=3e-5)


# ------------------------------------------------------------------ ich_spmv
def _random_csr(n, zipf_a, seed=0, max_nnz=300):
    rng = np.random.default_rng(seed)
    row_nnz = np.minimum(rng.zipf(zipf_a, n), max_nnz)
    indptr = np.concatenate([[0], np.cumsum(row_nnz)]).astype(np.int64)
    nnz = int(indptr[-1])
    indices = rng.integers(0, n, nnz).astype(np.int32)
    data = rng.standard_normal(nnz).astype(np.float32)
    return indptr, indices, data


@pytest.mark.parametrize("n,zipf_a,R", [(100, 1.6, 4), (256, 1.9, 8),
                                        (333, 2.5, 8), (64, 1.3, 16)])
def test_ich_spmv_sweep(n, zipf_a, R):
    indptr, indices, data = _random_csr(n, zipf_a, seed=n)
    x = np.random.default_rng(1).standard_normal(n).astype(np.float32)
    vals, cols, rowid, W = pack_tiles(indptr, indices, data, rows_per_tile=R)
    y_ref = spmv_ref(indptr, indices, data, x)
    # packing oracle (isolates schedule-construction bugs)
    np.testing.assert_allclose(tiles_ref(vals, cols, rowid, x, n), y_ref,
                               atol=1e-4, rtol=1e-4)
    y = ich_spmv(jnp.asarray(vals), jnp.asarray(cols), jnp.asarray(rowid),
                 jnp.asarray(x), n, interpret=True)
    np.testing.assert_allclose(y, y_ref, atol=1e-4, rtol=1e-4)


def test_ich_spmv_ops_wrapper():
    indptr, indices, data = _random_csr(128, 1.8, seed=7)
    op = IChSpmv(indptr, indices, data)
    x = jnp.array(np.random.default_rng(2).standard_normal(128), jnp.float32)
    np.testing.assert_allclose(op(x, interpret=True),
                               spmv_ref(indptr, indices, data, x),
                               atol=1e-4, rtol=1e-4)


def test_ich_spmv_empty_rows():
    indptr = np.array([0, 0, 3, 3, 5], np.int64)  # rows 0 and 2 empty
    indices = np.array([0, 1, 2, 1, 3], np.int32)
    data = np.ones(5, np.float32)
    x = jnp.arange(4, dtype=jnp.float32) + 1.0
    vals, cols, rowid, _ = pack_tiles(indptr, indices, data, rows_per_tile=4)
    y = ich_spmv(jnp.asarray(vals), jnp.asarray(cols), jnp.asarray(rowid),
                 x, 4, interpret=True)
    np.testing.assert_allclose(y, spmv_ref(indptr, indices, data, x), atol=1e-6)


# ------------------------------------------------------------------- ich_bfs
def _random_graph(n, kind, seed):
    rng = np.random.default_rng(seed)
    if kind == "uniform":
        deg = rng.integers(1, 21, n)
    else:  # scale-free, P(k) ~ k^-2.3 as in workloads.bfs_levels
        deg = np.minimum(rng.zipf(2.3, n), n // 4)
    indptr = np.concatenate([[0], np.cumsum(deg)]).astype(np.int64)
    indices = rng.integers(0, n, int(indptr[-1])).astype(np.int32)
    return indptr, indices


@pytest.mark.parametrize("n,kind,R", [(100, "uniform", 4),
                                      (256, "scale_free", 8),
                                      (200, "uniform", 8),
                                      (150, "scale_free", 16)])
def test_ich_bfs_levels_sweep(n, kind, R):
    indptr, indices = _random_graph(n, kind, seed=n)
    g = IChBfs(indptr, indices, rows_per_tile=R)
    np.testing.assert_array_equal(g.levels(0, interpret=True),
                                  bfs_levels_ref(indptr, indices, 0))


def test_ich_bfs_single_step_matches_ref():
    indptr, indices = _random_graph(128, "uniform", seed=3)
    g = IChBfs(indptr, indices)
    rng = np.random.default_rng(4)
    frontier = (rng.random(128) < 0.1).astype(np.float32)
    visited = np.maximum(frontier, (rng.random(128) < 0.3)).astype(np.float32)
    out = g.step(frontier, visited, interpret=True)
    np.testing.assert_allclose(out, bfs_step_ref(indptr, indices, frontier,
                                                 visited), atol=1e-5)


def test_ich_bfs_isolated_source():
    # source with no in-neighbors anywhere pointing out: frontier dies after
    # expansion; unreached vertices stay at -1
    indptr = np.array([0, 0, 1, 2], np.int64)   # v0 no in-nbrs; v1<-0; v2<-1
    indices = np.array([0, 1], np.int32)
    g = IChBfs(indptr, indices, rows_per_tile=4)
    np.testing.assert_array_equal(g.levels(0, interpret=True),
                                  np.array([0, 1, 2], np.int32))
    np.testing.assert_array_equal(g.levels(2, interpret=True),
                                  np.array([-1, -1, 0], np.int32))


# ---------------------------------------------------------------- ich_kmeans
@pytest.mark.parametrize("n,D,K,R", [(100, 4, 3, 4), (256, 8, 16, 8),
                                     (333, 2, 5, 8), (64, 16, 2, 16)])
def test_ich_kmeans_assign_sweep(n, D, K, R):
    rng = np.random.default_rng(n)
    pts = rng.standard_normal((n, D)).astype(np.float32)
    cent = rng.standard_normal((K, D)).astype(np.float32)
    costs = rng.uniform(6.0, 10.0, n)
    costs[rng.choice(n, max(n // 50, 1), replace=False)] += \
        rng.exponential(120.0, max(n // 50, 1))
    km = IChKMeans(costs, rows_per_tile=R)
    out = np.asarray(km(pts, cent, interpret=True))
    np.testing.assert_allclose(out, kmeans_assign_ref(pts, cent), atol=1e-5)


def test_ich_kmeans_heavy_point_split_is_idempotent():
    # a point far heavier than max_w occupies many slots; its assignment is
    # recomputed per slot and must still be written exactly once per value
    costs = np.full(32, 7.0)
    costs[5] = 10_000.0
    km = IChKMeans(costs, width=8)
    assert (km.schedule.item_id == 5).sum() > 1  # genuinely split
    rng = np.random.default_rng(9)
    pts = rng.standard_normal((32, 3)).astype(np.float32)
    cent = rng.standard_normal((4, 3)).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(km(pts, cent, interpret=True)),
                                  kmeans_assign_ref(pts, cent))


# ---------------------------------------------------------------- mamba_scan
@pytest.mark.parametrize("B,S,H,N,Pd,chunk", [
    (1, 128, 2, 16, 32, 64),
    (2, 256, 3, 16, 32, 64),
    (1, 256, 1, 64, 64, 128),
    (2, 128, 4, 8, 16, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mamba_scan_sweep(B, S, H, N, Pd, chunk, dtype):
    q = jnp.array(RNG.standard_normal((B, S, H, N)), dtype)
    k = jnp.array(RNG.standard_normal((B, S, H, N)), dtype)
    v = jnp.array(RNG.standard_normal((B, S, H, Pd)), dtype)
    la = jnp.array(-np.abs(RNG.standard_normal((B, S, H))) * 0.2, jnp.float32)
    y, s = mamba_scan(q, k, v, la, chunk=chunk, interpret=True)
    y_ref, s_ref = ssd_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                           v.astype(jnp.float32), la, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               atol=_tol(dtype) * 10, rtol=_tol(dtype) * 10)
    np.testing.assert_allclose(s, s_ref, atol=_tol(dtype) * 10,
                               rtol=_tol(dtype) * 10)


def test_mamba_scan_matches_sequential():
    """End-to-end: kernel vs the plain sequential recurrence."""
    B, S, H, N, Pd = 1, 64, 2, 8, 16
    q = np.asarray(RNG.standard_normal((B, S, H, N)), np.float32)
    k = np.asarray(RNG.standard_normal((B, S, H, N)), np.float32)
    v = np.asarray(RNG.standard_normal((B, S, H, Pd)), np.float32)
    la = -np.abs(RNG.standard_normal((B, S, H))).astype(np.float32) * 0.3
    St = np.zeros((B, H, Pd, N))
    ys = []
    for t in range(S):
        a = np.exp(la[:, t])[:, :, None, None]
        St = St * a + np.einsum("bhn,bhp->bhpn", k[:, t], v[:, t])
        ys.append(np.einsum("bhn,bhpn->bhp", q[:, t], St))
    y_ref = np.stack(ys, 1)
    y, s = mamba_scan(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                      jnp.asarray(la), chunk=32, interpret=True)
    np.testing.assert_allclose(y, y_ref, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(s, St.swapaxes(-1, -2), atol=1e-4, rtol=1e-4)
