"""Dispatch-conformance suite for iCh-scheduled MoE expert dispatch
(DESIGN.md §2.8) — the acceptance gate for running the model ON the
scheduler.

Covered contracts:

* token conservation: every (token, choice) entry is kept exactly once or
  dropped; the plan's expert-major CSR is a gap-free permutation of the
  kept entries;
* dispatch bit-identity: the host-side planner (`sched.moe.plan_dispatch`)
  reproduces the in-graph sort-based path (`models/moe.py:
  dispatch_decisions`) decision-for-decision at equal capacity, and the
  scheduled kernel's outputs match `moe_local`'s end to end;
* steal-target optimality: every stolen entry lands on its token's
  max-slack alternative, and only on an expert that actually had slack;
* simulator-vs-kernel cross-checks for p in {1, 2, 4}: the sharded MoE
  kernel's per-expert cost sums equal the schedule's per-item totals
  EXACTLY, its per-worker superstep sums equal the shard partition's
  worker costs exactly, and the zero-overhead sharded replay's makespan
  is the same number;
* hypothesis properties mirroring tests/test_adaptive_properties.py:
  permutation-of-tokens invariance of per-expert loads, overflow landing
  underloaded-or-dropped deterministically, and refined `cap_scale` as a
  monotone fixed point on structural (integer-count) workloads;
* the regression pin for the previously xfail'd decode-vs-prefill gap:
  shared-capacity dispatch depends on the token pool size, dropless
  (serving) dispatch does not (tests/test_arch_smoke.py asserts the
  full-model consequence).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro import sched
from repro.configs import get_arch, reduced
from repro.core.simulator import SimParams
from repro.kernels.ich_moe.ref import moe_dispatch_ref
from repro.models import moe as MOE
from repro.sched import get as sched_get
from repro.sched.moe import (cap_scale_from_costs, expert_capacity,
                             plan_dispatch, refine_cap_scale)

_ZERO = SimParams(dispatch_overhead=0.0, local_dispatch_overhead=0.0,
                  speed_jitter=0.0)


def _router(T, E, K, seed=0, skew=1.2):
    """Zipf-skewed synthetic router: distinct top-K expert ids per token
    (gumbel-perturbed popularity) + renormalized combine weights."""
    rng = np.random.default_rng(seed)
    pop = np.arange(1, E + 1, dtype=np.float64) ** -float(skew)
    logits = rng.gumbel(size=(T, E)) + 3.0 * np.log(pop)[None]
    e_topk = np.argsort(-logits, axis=1)[:, :K].astype(np.int32)
    w = rng.random((T, K)).astype(np.float32) + 0.1
    w /= w.sum(1, keepdims=True)
    return e_topk, w


def _ffn(E, D, F, seed=0):
    rng = np.random.default_rng(seed)
    wi = (rng.standard_normal((E, D, F)) * D ** -0.5).astype(np.float32)
    wg = (rng.standard_normal((E, D, F)) * D ** -0.5).astype(np.float32)
    wo = (rng.standard_normal((E, F, D)) * F ** -0.5).astype(np.float32)
    return wi, wg, wo


# ------------------------------------------------------ token conservation
@pytest.mark.parametrize("steal", [False, True])
@pytest.mark.parametrize("seed", [0, 3, 11])
def test_plan_token_conservation_and_csr_layout(seed, steal):
    T, E, K = 200, 16, 2
    e_topk, w = _router(T, E, K, seed=seed)
    plan = plan_dispatch(e_topk, w, cap_scale=np.ones(E), steal=steal)
    assert int(plan.counts.sum()) + plan.dropped == T * K
    assert plan.stolen + int((plan.expert.reshape(T, K)
                              == e_topk).all(axis=None)) >= 0
    np.testing.assert_array_equal(
        plan.counts, np.bincount(plan.expert[plan.keep], minlength=E))
    np.testing.assert_array_equal(
        plan.router_counts, np.bincount(e_topk.reshape(-1), minlength=E))
    # capacity is never exceeded
    assert (plan.counts <= plan.cap.astype(np.int64)).all()
    # CSR: gap-free permutation of the kept entries, segment sizes = loads
    indptr, tok, wcsr = plan.csr()
    np.testing.assert_array_equal(np.diff(indptr), plan.counts)
    at = indptr[plan.expert[plan.keep]] + plan.pos[plan.keep]
    assert np.unique(at).size == at.size  # no slot collisions, no gaps
    assert tok.min() >= 0 and tok.max() < T if tok.size else True
    np.testing.assert_allclose(wcsr.sum(), plan.weight[plan.keep].sum(),
                               rtol=1e-6)
    # without stealing, kept loads are exactly min(demand, capacity)
    if not steal:
        np.testing.assert_array_equal(
            plan.counts, np.minimum(plan.router_counts,
                                    plan.cap.astype(np.int64)))


# -------------------------------------- bit-identity vs the in-graph path
@pytest.mark.parametrize("steal", [False, True])
@pytest.mark.parametrize("seed", [0, 7, 42])
def test_plan_matches_ingraph_decisions_bitwise(seed, steal):
    """The numpy planner and the jnp dispatch pass agree on every entry:
    final expert, dispatch slot, survival, steal count."""
    T, E, K = 160, 8, 2
    e_topk, _ = _router(T, E, K, seed=seed)
    plan = plan_dispatch(e_topk, cap_scale=np.ones(E), steal=steal)
    ef, tf, pos, keep, stolen = MOE.dispatch_decisions(
        jnp.asarray(e_topk), jnp.asarray(plan.cap), steal=steal)
    np.testing.assert_array_equal(np.asarray(ef), plan.expert)
    np.testing.assert_array_equal(np.asarray(tf), plan.token)
    np.testing.assert_array_equal(np.asarray(pos), plan.pos)
    np.testing.assert_array_equal(np.asarray(keep), plan.keep)
    assert int(stolen) == plan.stolen


def test_scheduled_dispatch_matches_moe_local_end_to_end():
    """At equal capacity the scheduled kernel reproduces the sort-based
    layer's output: same router, same capacities, same combine weights —
    the model-on-scheduler bridge, end to end."""
    cfg = reduced(get_arch("olmoe-1b-7b"), n_experts=8, experts_per_token=2,
                  d_model=32, moe_d_ff=32)
    E, K = cfg.n_experts, cfg.experts_per_token
    T = 96
    p = MOE.init_moe(jax.random.PRNGKey(0), cfg)
    p["router"] = p["router"].at[:, 0].add(2.0)  # skew the load
    x = jax.random.normal(jax.random.PRNGKey(1), (T, cfg.d_model),
                          dtype=jnp.float32)
    cap_scale = jnp.ones((E,))
    y_model, aux = MOE.moe_local(cfg, p, x, cap_scale, capacity_factor=1.0)

    # host-side mirror of the router + capacity arithmetic
    probs = jax.nn.softmax((x @ p["router"]).astype(jnp.float32), -1)
    w_topk, e_topk = jax.lax.top_k(probs, K)
    w_topk = w_topk / jnp.maximum(w_topk.sum(-1, keepdims=True), 1e-9)
    c_base = MOE.capacity(cfg, T, 1.0)
    c_max = max(c_base, int(round(getattr(cfg, "moe_cmax_factor", 2.0)
                                  * c_base)))
    cap_e = np.clip(np.round(c_base * np.asarray(cap_scale)), 4,
                    c_max).astype(np.int32)
    plan = plan_dispatch(np.asarray(e_topk), np.asarray(w_topk), cap=cap_e)
    assert plan.dropped == int(aux["dropped"])
    assert plan.stolen == int(aux["stolen"])

    op = sched.LoopScheduler(p=2).build("moe-dispatch", plan)
    y_sched = op(x, p["wi"].astype(jnp.float32),
                 p["wg"].astype(jnp.float32), p["wo"].astype(jnp.float32),
                 interpret=True)
    np.testing.assert_allclose(np.asarray(y_sched), np.asarray(y_model),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_array_equal(op.expert_load(),
                                  plan.counts.astype(np.float64))


# ------------------------------------------------- steal-target optimality
@pytest.mark.parametrize("seed", [0, 5, 19])
def test_steal_targets_are_max_slack_alternatives(seed):
    """Every stolen entry (a) lands on an expert that had positive slack,
    (b) lands on one of its token's own top-K alternatives, and (c) picks
    the FIRST max-slack alternative — the exact argmax the in-graph path
    computes."""
    T, E, K = 300, 16, 4
    e_topk, w = _router(T, E, K, seed=seed, skew=1.6)
    plan = plan_dispatch(e_topk, w, cap_scale=np.ones(E), steal=True)
    orig = e_topk.reshape(-1).astype(np.int32)
    stolen = plan.keep & (plan.expert != orig)
    assert plan.stolen >= int(stolen.sum())  # rerouted-to-same never counts
    if not stolen.any():
        pytest.skip(f"seed {seed} produced no steals at this skew")
    slack = np.maximum(plan.cap.astype(np.int64) - plan.router_counts, 0)
    dests = plan.expert[stolen]
    assert (slack[dests] > 0).all()  # always an underloaded expert
    toks = plan.token[stolen]
    choice_rows = e_topk[toks]  # (n_stolen, K)
    assert (dests[:, None] == choice_rows).any(axis=1).all()
    expected = choice_rows[np.arange(toks.size),
                           np.argmax(slack[choice_rows].astype(np.float32),
                                     axis=1)]
    np.testing.assert_array_equal(dests, expected)


# --------------------------- simulator vs kernel per-expert work (p grid)
@pytest.mark.parametrize("p", [1, 2, 4])
def test_kernel_costs_match_schedule_and_simulator_exactly(p):
    """PR 5's routing proof extended to the MoE kernel at every p: the
    emitted per-expert cost sums equal the plan's kept token counts
    EXACTLY, the per-worker superstep sums equal the shard partition's
    worker costs exactly, and the zero-overhead sharded replay agrees on
    the makespan."""
    T, E, K, D, F = 256, 16, 2, 16, 24
    e_topk, w = _router(T, E, K, seed=p)
    plan = plan_dispatch(e_topk, w, cap_scale=np.ones(E))
    op = sched.LoopScheduler(p=p, cache_size=0).build("moe-dispatch", plan)
    wi, wg, wo = _ffn(E, D, F, seed=p)
    x = np.random.default_rng(p).standard_normal((T, D)).astype(np.float32)
    y = op(jnp.asarray(x), jnp.asarray(wi), jnp.asarray(wg),
           jnp.asarray(wo), interpret=True)

    indptr, tok, wcsr = plan.csr()
    np.testing.assert_allclose(np.asarray(y),
                               moe_dispatch_ref(indptr, tok, wcsr, x,
                                                wi, wg, wo),
                               atol=1e-4, rtol=1e-4)
    # per-expert totals: bit-exact integer token counts in float32
    emitted_e = np.asarray(op.last_expert_costs)
    assert emitted_e.shape == (op.p, E)
    np.testing.assert_array_equal(emitted_e.sum(axis=0),
                                  plan.counts.astype(np.float32))
    np.testing.assert_array_equal(emitted_e.sum(axis=0),
                                  op.schedule.costs.astype(np.float32))
    # per-worker superstep stream: the §2.7 invariant
    emitted_w = np.asarray(op.last_costs)
    shards = op.schedule.shard()
    assert emitted_w.shape == shards.block_perm.shape
    wc = shards.worker_cost(op.schedule.tile_cost())
    np.testing.assert_array_equal(emitted_w.sum(axis=1),
                                  wc.astype(np.float32))
    # simulator cross-check: zero-overhead sharded replay's makespan is
    # the partition's max per-worker cost — the same number the kernel
    # emitted
    rep = op.schedule.replay_sharded(params=_ZERO)
    assert rep.makespan == pytest.approx(float(wc.max()))
    assert rep.makespan == pytest.approx(float(emitted_w.sum(axis=1).max()))


def test_op_observe_refine_roundtrip_keeps_dispatch_semantics():
    """Closing the loop re-partitions but never re-routes: the op rebuilt
    on the refined schedule dispatches the same plan (exact same
    per-expert loads, outputs equal to tolerance — fold order may differ
    because tokens are shared across workers)."""
    T, E, K, D, F = 200, 16, 2, 16, 24
    e_topk, w = _router(T, E, K, seed=2)
    plan = plan_dispatch(e_topk, w, cap_scale=np.ones(E))
    scheduler = sched.LoopScheduler(p=4, cache_size=0)
    op = scheduler.build("moe-dispatch", plan)
    wi, wg, wo = _ffn(E, D, F, seed=2)
    x = np.random.default_rng(2).standard_normal((T, D)).astype(np.float32)
    y0 = np.asarray(op(jnp.asarray(x), jnp.asarray(wi), jnp.asarray(wg),
                       jnp.asarray(wo), interpret=True))
    refined_s = op.observe().refine()
    assert refined_s.generation == 1
    np.testing.assert_array_equal(refined_s.sizes, plan.counts)  # structural
    op2 = sched_get("moe-dispatch").build(refined_s, plan)
    y1 = np.asarray(op2(jnp.asarray(x), jnp.asarray(wi), jnp.asarray(wg),
                        jnp.asarray(wo), interpret=True))
    np.testing.assert_allclose(y1, y0, atol=1e-5, rtol=1e-5)
    np.testing.assert_array_equal(op2.expert_load(), op.expert_load())


def test_registry_and_provider_validation():
    assert "moe-dispatch" in sched.registered()
    with pytest.raises(TypeError, match="integer"):
        sched.ExpertLoadCosts(np.ones(4, np.float64))
    with pytest.raises(ValueError, match="non-negative"):
        sched.ExpertLoadCosts(np.array([3, -1], np.int64))
    with pytest.raises(ValueError, match="1-D"):
        sched.ExpertLoadCosts(np.ones((2, 2), np.int64))


# ----------------------------------------------------- hypothesis properties
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 5000), E=st.sampled_from([4, 8, 16]),
       K=st.sampled_from([1, 2, 4]), T=st.integers(16, 200))
def test_per_expert_loads_are_permutation_invariant(seed, E, K, T):
    """Reordering the token pool never changes per-expert loads: without
    stealing the loads are exactly min(demand, capacity) — a function of
    the demand histogram alone — and the steal round's demand/slack
    inputs are permutation-invariant too (WHICH entries overflow is
    order-dependent by design: positions are the dispatch order)."""
    e_topk, w = _router(T, E, K, seed=seed)
    perm = np.random.default_rng(seed + 1).permutation(T)
    a = plan_dispatch(e_topk, w, cap_scale=np.ones(E), steal=False)
    b = plan_dispatch(e_topk[perm], w[perm], cap_scale=np.ones(E),
                      steal=False)
    np.testing.assert_array_equal(a.counts, b.counts)
    np.testing.assert_array_equal(a.router_counts, b.router_counts)
    assert a.dropped == b.dropped
    np.testing.assert_array_equal(
        a.counts, np.minimum(a.router_counts, a.cap.astype(np.int64)))
    # stealing fills from an order-invariant slack pool: kept totals can
    # only improve on the no-steal dispatch, for every ordering
    sa = plan_dispatch(e_topk, w, cap_scale=np.ones(E), steal=True)
    sb = plan_dispatch(e_topk[perm], w[perm], cap_scale=np.ones(E),
                       steal=True)
    assert sa.dropped <= a.dropped and sb.dropped <= b.dropped
    np.testing.assert_array_equal(sa.router_counts, sb.router_counts)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 5000), E=st.sampled_from([8, 16]),
       K=st.sampled_from([2, 4]), T=st.integers(32, 200),
       skew=st.floats(0.5, 2.0))
def test_overflow_lands_underloaded_or_drops_deterministically(seed, E, K,
                                                               T, skew):
    """Every entry that overflows its router choice either lands on an
    alternative that had positive slack or is dropped — and the whole
    resolution is a deterministic function of the inputs (bit-identical
    on re-planning)."""
    e_topk, w = _router(T, E, K, seed=seed, skew=skew)
    plan = plan_dispatch(e_topk, w, cap_scale=np.ones(E), steal=True)
    orig = e_topk.reshape(-1).astype(np.int32)
    stolen = plan.keep & (plan.expert != orig)
    slack = np.maximum(plan.cap.astype(np.int64) - plan.router_counts, 0)
    assert (slack[plan.expert[stolen]] > 0).all()
    # dropped entries still point at a router choice of their own token
    dropped = ~plan.keep
    assert (plan.expert[dropped][:, None]
            == e_topk[plan.token[dropped]]).any(axis=1).all()
    replan = plan_dispatch(e_topk, w, cap_scale=np.ones(E), steal=True)
    np.testing.assert_array_equal(replan.expert, plan.expert)
    np.testing.assert_array_equal(replan.keep, plan.keep)
    np.testing.assert_array_equal(replan.pos, plan.pos)


@pytest.mark.parametrize("seed", [1, 5, 9])
def test_refined_cap_scale_is_monotone_fixed_point(seed):
    """On a structural (integer-count) workload, the closed capacity loop
    mirrors tests/test_adaptive_properties.py's refine-round invariant:
    the sharded makespan on true per-expert costs is non-increasing
    across observe/refine rounds and hits a fixed point once the loads
    are learned; cap_scale orders experts like the measured loads
    (monotone) and stops moving at the fixed point (bit-identical across
    further rounds)."""
    rng = np.random.default_rng(seed)
    E = 256
    counts = np.minimum(rng.zipf(1.6, E), 400).astype(np.int64)
    # heterogeneous per-expert throughput: true cost != token count
    true = counts.astype(np.float64) * rng.uniform(0.5, 2.0, E) + 0.01
    s = sched.LoopScheduler(p=8, cache_size=0).schedule(
        sched.ExpertLoadCosts(counts))
    ms, scales = [], []
    for _ in range(4):
        ms.append(s.replay_refined(true, sharded=True, params=_ZERO)
                  .makespan)
        s, cs = refine_cap_scale(s, true)
        np.testing.assert_array_equal(s.sizes, counts)  # structural
        scales.append(cs)
    assert all(b <= a + 1e-9 for a, b in zip(ms, ms[1:])), ms
    assert ms[2] == pytest.approx(ms[1], rel=1e-12)  # fixed point
    # cap_scale is monotone in measured load (clip preserves order)
    order = np.argsort(true)
    assert (np.diff(scales[0][order]) >= -1e-12).all()
    # and a fixed point: identical once the Welford means equal the loads
    np.testing.assert_array_equal(scales[1], scales[2])
    np.testing.assert_array_equal(scales[2], scales[3])
    # budget rule: never exceeds E, clips to the materializable range
    for cs in scales:
        assert cs.sum() <= E + 1e-9
        assert (cs >= 0.25 - 1e-12).all() and (cs <= 2.0 + 1e-12).all()


def test_cap_scale_from_costs_degenerate_inputs():
    np.testing.assert_array_equal(cap_scale_from_costs(np.zeros(4)),
                                  np.ones(4))
    uniform = cap_scale_from_costs(np.full(6, 7.0))
    np.testing.assert_allclose(uniform, np.ones(6))


# -------------------------------------- decode-vs-prefill regression pin
def test_shared_capacity_depends_on_pool_size_but_dropless_does_not():
    """The mechanism behind the previously xfail'd
    test_decode_matches_prefill[olmoe-1b-7b]: under shared capacity the
    SAME prefix tokens dispatch differently depending on how many tokens
    compete (pool T vs T+1 — exactly prefill-of-S vs fresh
    prefill-of-S+1), while dropless per-request dispatch is pool-size
    independent — which is why serving now uses it
    (models/model.py prefill/decode_step)."""
    T, E, K = 12, 4, 2
    # every token's first choice is expert 0: demand 12 > capacity
    e_topk = np.stack([np.zeros(T + 1, np.int32),
                       1 + (np.arange(T + 1, dtype=np.int32) % (E - 1))],
                      axis=1)
    cap_s = np.full(E, expert_capacity(T, E, K, 1.0), np.int32)      # 6
    cap_s1 = np.full(E, expert_capacity(T + 1, E, K, 1.0), np.int32)  # 7
    assert cap_s[0] != cap_s1[0]
    plan_s = plan_dispatch(e_topk[:T], cap=cap_s, steal=False)
    plan_s1 = plan_dispatch(e_topk, cap=cap_s1, steal=False)
    shared = slice(0, T * K)  # the prefix tokens' entries in both plans
    assert (plan_s.keep != plan_s1.keep[shared]).any(), \
        "pool-size competition must change a shared token's dispatch"
    # dropless: capacity = the whole pool; nothing dropped, assignments
    # of the shared tokens identical across pool sizes
    drop_s = plan_dispatch(e_topk[:T], cap=np.full(E, T, np.int32),
                           steal=False)
    drop_s1 = plan_dispatch(e_topk, cap=np.full(E, T + 1, np.int32),
                            steal=False)
    assert drop_s.keep.all() and drop_s1.keep.all()
    np.testing.assert_array_equal(drop_s.expert, drop_s1.expert[shared])


def test_moe_local_dropless_flag_keeps_everything():
    """dropless=True through the in-graph layer: zero drops, zero steals,
    and the output equals the generous-capacity dispatch exactly."""
    cfg = reduced(get_arch("olmoe-1b-7b"), n_experts=8, experts_per_token=2,
                  d_model=32, moe_d_ff=32)
    p = MOE.init_moe(jax.random.PRNGKey(3), cfg)
    p["router"] = p["router"].at[:, 0].add(3.0)  # heavy skew
    x = jax.random.normal(jax.random.PRNGKey(4), (48, cfg.d_model))
    cap = jnp.ones((cfg.n_experts,))
    y_d, aux_d = MOE.moe_local(cfg, p, x, cap, dropless=True)
    assert float(aux_d["dropped"]) == 0 and float(aux_d["stolen"]) == 0
    y_g, aux_g = MOE.moe_local(cfg, p, x, cap * 100, steal=False,
                               capacity_factor=50.0)
    assert float(aux_g["dropped"]) == 0
    np.testing.assert_allclose(np.asarray(y_d), np.asarray(y_g), atol=1e-5)
    # and the capacity-constrained path under the same skew DOES drop —
    # the two serving/training modes are genuinely different
    _, aux_c = MOE.moe_local(cfg, p, x, cap, capacity_factor=1.0)
    assert float(aux_c["dropped"]) > 0
