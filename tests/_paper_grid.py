"""Shared machinery for the paper-conformance suite (tests/test_paper_claims
.py): the Table-2 policy grid evaluated per workload family, with the
paper's eq.-9 speedup definition and its top-3 / gap-to-best claims.

Self-contained over `repro.core` (mirrors benchmarks/common.py rather than
importing it, so the tests run under any pytest invocation, not only
`python -m pytest` from the repo root).

Families come in two scales:

* ``smoke`` — small n, runs inside tier-1 on every push. One deliberate
  adaptation keeps the reduced scale faithful to paper conditions rather
  than to reduction artifacts (see test_paper_claims.py for the full
  rationale): scale-free BFS runs at p=8.
* ``paper`` — paper-scale n, behind the `paper` marker + PAPER_SUITE=1
  (the non-blocking CI job): the same families at full size.

Both scales assert ALL ten Table-1 SpMV matrices, extreme-hub entries
included: `workloads.matrix_row_nnz` caps a synthesized hub row's share
of total work and the mass of any contiguous hub run (splitting hubs
across extra rows/runs, total-nnz-preserving), so reduced-n sampling no
longer plants indivisible multi-thread-share items that exist in no real
matrix (see HUB_DEG_CAP / HUB_RUN_SHARE there).
"""
from __future__ import annotations

import numpy as np

from repro.core import policies as P
from repro.core import workloads as WL
from repro.core.simulator import SimParams, simulate

PARAMS = SimParams()
METHODS = ("guided", "dynamic", "taskloop", "binlpt", "stealing", "ich")

# Methods within 5% relative speedup count as tied when ranking. The
# paper's own headline resolution is "within 5.4% of the best method";
# at reduced simulation scale, orderings inside that band flip with the
# RNG seed and say nothing about the methods (road_usa's binlpt-vs-iCh
# 4.6% margin is the canonical example).
TIE_TOL = 0.05


def app_time(loops, p, pol, estimates=None, params=PARAMS):
    """Sum of per-loop makespans under one policy (fork-join barriers)."""
    total = 0.0
    for i, costs in enumerate(loops):
        est = estimates[i] if estimates is not None else None
        total += simulate(np.asarray(costs, np.float64), p, pol, params,
                          estimate=est).makespan
    return total


def best_time(loops, p, method, estimates=None, params=PARAMS):
    """T(app, method, p): best over the method's Table-2 parameter grid."""
    return min(app_time(loops, p, pol, estimates, params)
               for pol in P.paper_policy_grid(p) if pol.name == method)


def speedup_table(loops, p, estimates=None, params=PARAMS):
    """{method: speedup at p}, eq. 9: T(guided, 1) / T(method, p)."""
    t1 = best_time(loops, 1, "guided", estimates, params)
    return {m: t1 / best_time(loops, p, m, estimates, params)
            for m in METHODS}


def rank_of_ich(table: dict, tol: float = TIE_TOL) -> int:
    """1-based rank of iCh among the methods (ties within tol)."""
    ich = table["ich"]
    return 1 + sum(1 for m, v in table.items()
                   if m != "ich" and v > ich * (1 + tol))


def gap_to_best(table: dict) -> float:
    """(best - ich) / best — the paper reports 5.4% on average."""
    best = max(table.values())
    return (best - table["ich"]) / best


def static_speedup(loops, p, estimates=None, params=PARAMS):
    """Eq.-9 speedup of the static uniform-chunk baseline at p — the
    fixed-capacity analogue the moe-dispatch assertions compare against
    (a static expert->worker partition ignores router skew exactly the
    way uniform chunking ignores iteration skew)."""
    t1 = best_time(loops, 1, "guided", estimates, params)
    return t1 / app_time(loops, p, P.static(), estimates, params)


# ---------------------------------------------------------------------------
# Workload families (paper §5.1). Each entry: name -> (loops, estimates, p).
# `estimates` is what workload-aware methods (binlpt) are handed — the
# static degree estimate for BFS, the stale round-0 costs for K-Means.
# ---------------------------------------------------------------------------

# All ten evaluated Table-1 matrices are asserted. The extreme-hub
# entries (FullChip, wikipedia, arabic-2005, uk-2005, wb-edu) used to
# synthesize one contiguous hub block holding tens of percent of ALL
# work at small n — an artifact of stat-matching a 5M-row matrix into
# 1e4 rows — and were reported-but-not-asserted; the per-item and
# per-run share caps in `workloads.matrix_row_nnz` removed the artifact.
MODERATE_SPMV = ("circuit5M_dc", "delaunay_n23", "road_usa", "kmer_P1a",
                 "nlpkkt240")
HUB_SPMV = ("FullChip", "wikipedia", "arabic-2005", "uk-2005", "wb-edu")
ALL_SPMV = MODERATE_SPMV + HUB_SPMV

SMOKE = {"synth": 4_000, "bfs": 3_000, "kmeans": 3_000, "spmv": 4_000,
         "kmeans_rounds": 3, "moe_experts": 512}
PAPER = {"synth": 50_000, "bfs": 20_000, "kmeans": 30_000, "spmv": 50_000,
         "kmeans_rounds": 6, "moe_experts": 4_096}

# Router-skew grid for the moe-dispatch family: zipf exponents spanning
# mild to heavy expert-popularity skew (CV of per-expert load roughly
# 0.5x to 3x the mean at these scales).
MOE_ALPHAS = (0.6, 1.0, 1.4)


def moe_expert_loads(n_experts: int, tokens_per_expert: int = 64,
                     alpha: float = 1.0, seed: int = 0,
                     capacity_factor: float = 1.25) -> np.ndarray:
    """Per-expert KEPT token counts for one MoE dispatch step — the
    loop-cost array of DESIGN.md §2.8 (experts are the irregular items).

    Expert popularity follows a shuffled zipf law with exponent `alpha`;
    T = n_experts * tokens_per_expert tokens route multinomially and the
    per-expert capacity cut clips the result, exactly like
    `repro.sched.moe.plan_dispatch` produces `plan.counts` — what the
    scheduler actually partitions. Modeling PRE-cut router demand instead
    would plant tens of percent of all work on one indivisible item at
    reduced scale, the same reduction artifact as the extreme-hub SpMV
    matrices (reported, not asserted)."""
    from repro.sched.moe import expert_capacity

    rng = np.random.default_rng(seed)
    pop = np.arange(1, n_experts + 1, dtype=np.float64) ** -float(alpha)
    rng.shuffle(pop)
    pop /= pop.sum()
    counts = rng.multinomial(n_experts * tokens_per_expert, pop)
    cap = expert_capacity(n_experts * tokens_per_expert, n_experts, 1,
                          capacity_factor)
    return np.minimum(np.maximum(counts, 1), cap).astype(np.float64)


def _spec(name: str) -> WL.MatrixSpec:
    return next(s for s in WL.TABLE1 if s.name == name)


def families(scale: dict, spmv_names=ALL_SPMV) -> dict:
    """name -> (loops, estimates, p) for every asserted workload family."""
    fams = {}
    n = scale["synth"]
    fams["synth/linear"] = ([WL.synth_linear(n)], None, 28)
    fams["synth/exp_inc"] = ([WL.synth_exp(n, True)], None, 28)
    fams["synth/exp_dec"] = ([WL.synth_exp(n, False)], None, 28)
    lv, est = WL.bfs_levels("uniform", scale["bfs"])
    fams["bfs/uniform"] = (lv, [est] * len(lv), 28)
    # Reduced-scale adaptation: the clipped-zipf generator at small n puts
    # a paper-impossible fraction of all edges on a handful of vertices
    # (single iterations no stealing can split), so the paper's 28-thread
    # point is evaluated at p=8 where work-per-thread matches paper ratios.
    lv, est = WL.bfs_levels("scale_free", scale["bfs"])
    fams["bfs/scale_free"] = (lv, [est] * len(lv), 8)
    loops, est0 = WL.kmeans_rounds(scale["kmeans"], scale["kmeans_rounds"])
    fams["kmeans"] = (loops, [est0] * len(loops), 28)
    for name in spmv_names:
        fams[f"spmv/{name}"] = ([WL.spmv_costs(_spec(name), scale["spmv"])],
                                None, 28)
    # MoE expert dispatch (DESIGN.md §2.8): per-expert token loads are the
    # loop costs; p=8 workers shard the experts. Evaluated at several
    # router-skew levels so the claim covers mild and heavy imbalance.
    E = scale["moe_experts"]
    for alpha in MOE_ALPHAS:
        fams[f"moe-dispatch/zipf{alpha:g}"] = (
            [moe_expert_loads(E, alpha=alpha, seed=int(alpha * 10))],
            None, 8)
    return fams


def evaluate(fams: dict) -> dict:
    """name -> {"table": {method: speedup}, "rank": int, "gap": float}."""
    out = {}
    for name, (loops, ests, p) in fams.items():
        table = speedup_table(loops, p, ests)
        out[name] = {"table": table, "p": p, "rank": rank_of_ich(table),
                     "gap": gap_to_best(table)}
    return out


def digest_rows(results: dict, asserted: set) -> list[str]:
    """CSV rows (family,p,method,speedup / family,p,rank,gap,asserted)."""
    rows = []
    for name, r in sorted(results.items()):
        for m, v in r["table"].items():
            rows.append(f"{name},{r['p']},{m},{v:.4f}")
        rows.append(f"{name},{r['p']},rank,{r['rank']},"
                    f"gap,{r['gap']:.4f},asserted,{name in asserted}")
    return rows
