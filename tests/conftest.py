"""Shared test helpers (importable as `from conftest import ...` — pytest
puts this directory on sys.path, same mechanism as _hypothesis_compat)."""
import numpy as np


def random_csr(n, zipf_a=1.8, seed=0, max_nnz=60):
    """A zipf-heavy CSR matrix with ~10% empty rows (the hard case): the
    canonical irregular workload used across the scheduler suites."""
    rng = np.random.default_rng(seed)
    row_nnz = np.minimum(rng.zipf(zipf_a, n), max_nnz).astype(np.int64)
    row_nnz[rng.random(n) < 0.1] = 0
    indptr = np.concatenate([[0], np.cumsum(row_nnz)]).astype(np.int64)
    nnz = int(indptr[-1])
    indices = rng.integers(0, n, nnz).astype(np.int32)
    data = rng.standard_normal(nnz).astype(np.float32)
    return indptr, indices, data
