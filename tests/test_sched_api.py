"""Tests for the unified `repro.sched` API: facade, cost providers,
registry, schedule cache, cross-backend round-trips, and the legacy-ops
deprecation shims."""
import threading
import warnings

import numpy as np
import pytest
from conftest import random_csr as _random_csr

from repro import sched
from repro.core import policies as P
from repro.core import tiling as T
from repro.sched.api import LoopScheduler, Schedule
from repro.sched.costs import (DegreeCosts, ExplicitCosts, NnzCosts,
                               as_cost_provider, quantize_costs)
from repro.sched.registry import register, unregister


# ------------------------------------------------------------ cost providers
def test_explicit_costs_int_keeps_zeros_float_quantizes():
    ints = ExplicitCosts(np.array([0, 3, 1], np.int64))
    np.testing.assert_array_equal(ints.sizes(), [0, 3, 1])
    floats = ExplicitCosts(np.array([0.2, 3.7, 1.0]))
    np.testing.assert_array_equal(floats.sizes(), [1, 4, 1])  # ceil, >= 1
    np.testing.assert_array_equal(floats.costs(), [0.2, 3.7, 1.0])
    np.testing.assert_array_equal(
        floats.sizes(), quantize_costs(np.array([0.2, 3.7, 1.0])))


def test_cost_provider_fingerprints():
    a = np.array([1, 2, 3], np.int64)
    assert ExplicitCosts(a).fingerprint() == ExplicitCosts(a.copy()).fingerprint()
    assert ExplicitCosts(a).fingerprint() != \
        ExplicitCosts(np.array([1, 2, 4], np.int64)).fingerprint()
    indptr = np.array([0, 2, 5], np.int64)
    # same content, different provider kinds -> different cache identity
    assert NnzCosts(indptr).fingerprint() != DegreeCosts(indptr).fingerprint()
    np.testing.assert_array_equal(NnzCosts(indptr).sizes(), [2, 3])


def test_as_cost_provider_passthrough_and_wrap():
    p = ExplicitCosts(np.arange(1, 4))
    assert as_cost_provider(p) is p
    assert isinstance(as_cost_provider(np.arange(1, 4)), ExplicitCosts)


# ------------------------------------------------------------------- facade
def test_schedule_matches_direct_tiling():
    sizes = np.minimum(np.random.default_rng(0).zipf(1.8, 400), 100)
    s = LoopScheduler().schedule(sizes.astype(np.int64))
    direct = T.build_schedule(sizes.astype(np.int64),
                              rows_per_tile=sched.ROWS_PER_TILE,
                              eps=sched.ICH_EPS)
    np.testing.assert_array_equal(s.item_id, direct.item_id)
    np.testing.assert_array_equal(s.lower().seg_start, direct.seg_start)
    np.testing.assert_array_equal(s.lower().seg_len, direct.seg_len)
    assert s.width == direct.width


def test_cache_hit_returns_same_object_and_skips_construction():
    sizes = np.arange(1, 200, dtype=np.int64)
    scheduler = LoopScheduler(cache_size=4)
    s1 = scheduler.schedule(sizes)
    s2 = scheduler.schedule(sizes)
    assert s2 is s1
    assert scheduler.cache_stats.hits == 1
    assert scheduler.cache_stats.misses == 1
    # different policy / p / construction params are different entries
    scheduler.schedule(sizes, policy=P.ich(0.5))
    scheduler.schedule(sizes, p=2)
    scheduler.schedule(sizes, rows_per_tile=16)
    assert scheduler.cache_stats.misses == 4


def test_cache_keys_worker_partition_params_distinct_p_no_collision():
    """p and superstep are worker-PARTITION parameters now (the Schedule
    lowers to a p-worker shard layout), so distinct values must be
    distinct cache entries — a p=2 schedule's memoized shards must never
    be served to a p=4 caller."""
    sizes = np.arange(1, 300, dtype=np.int64)
    scheduler = LoopScheduler(cache_size=8)
    s2 = scheduler.schedule(sizes, p=2)
    s4 = scheduler.schedule(sizes, p=4)
    assert s2 is not s4
    assert scheduler.cache_stats.misses == 2
    assert scheduler.cache_stats.hits == 0
    # each lowers to its own worker count by default
    assert s2.shard().p == 2 and s4.shard().p == 4
    assert s2.shard().worker.shape == s4.shard().worker.shape
    # repeat calls hit their own entries
    assert scheduler.schedule(sizes, p=2) is s2
    assert scheduler.schedule(sizes, p=4) is s4
    assert scheduler.cache_stats.hits == 2
    # superstep is part of the key too (it shapes the padded layout)
    s2b = scheduler.schedule(sizes, p=2, superstep=2)
    assert s2b is not s2 and s2b.shard().superstep == 2
    assert scheduler.cache_stats.misses == 3


def test_cache_distinguishes_policies_with_lossy_labels():
    # taskloop(4) and taskloop(16) share label() == "taskloop"; the cache
    # keys on the full Policy dataclass so they must NOT alias
    sizes = np.arange(1, 100, dtype=np.int64)
    scheduler = LoopScheduler()
    s4 = scheduler.schedule(sizes, policy=P.taskloop(4))
    s16 = scheduler.schedule(sizes, policy=P.taskloop(16))
    assert s4 is not s16
    assert s16.policy.num_tasks == 16
    assert scheduler.cache_stats.misses == 2
    # same for pretiled policies with equal chunk counts, distinct ranges
    pa = scheduler.schedule(sizes, policy=P.pretiled([(0, 50), (50, 99)]))
    pb = scheduler.schedule(sizes, policy=P.pretiled([(0, 10), (10, 99)]))
    assert pa is not pb and pa.policy.label() == pb.policy.label()


def test_schedule_inherits_scheduler_sim_params():
    from repro.core.simulator import SimParams

    params = SimParams(speed_jitter=0.0, seed=7)
    scheduler = LoopScheduler(p=4, sim_params=params)
    s = scheduler.schedule(np.arange(1, 120, dtype=np.int64))
    assert s.sim_params is params
    # zero jitter => exactly-even worker speeds; replay under the instance
    # params must differ from an explicit default-params run on this seed
    r = s.simulate(policy=P.dynamic(2))
    r_default = s.simulate(policy=P.dynamic(2), params=SimParams())
    assert r.makespan != r_default.makespan


def test_explicit_costs_copy_insulates_cached_schedule():
    sizes = np.arange(1, 80, dtype=np.int64)
    scheduler = LoopScheduler()
    s = scheduler.schedule(sizes)
    total = int(s.sizes.sum())
    sizes[:] = 1  # caller reuses its buffer
    assert int(s.sizes.sum()) == total  # cached Schedule is unaffected


def test_cache_lru_eviction():
    scheduler = LoopScheduler(cache_size=2)
    a = scheduler.schedule(np.arange(1, 50, dtype=np.int64))
    scheduler.schedule(np.arange(1, 60, dtype=np.int64))
    scheduler.schedule(np.arange(1, 70, dtype=np.int64))  # evicts `a`
    assert scheduler.cache_stats.evictions == 1
    a2 = scheduler.schedule(np.arange(1, 50, dtype=np.int64))
    assert a2 is not a  # rebuilt after eviction, equal content
    np.testing.assert_array_equal(a2.item_id, a.item_id)


def test_simulate_and_parallel_for_passthroughs():
    scheduler = LoopScheduler(p=4)
    costs = np.random.default_rng(1).exponential(10.0, 500) + 0.1
    r = scheduler.simulate(costs)
    assert r.policy == P.ich().label() and r.makespan > 0
    hits = np.zeros(300, np.int64)
    lock = threading.Lock()

    def body(i):
        with lock:
            hits[i] += 1

    scheduler.parallel_for(300, body)
    assert (hits == 1).all()


# ------------------------------------------------- cross-backend round-trip
@pytest.mark.parametrize("workload,n", [("spmv", 220), ("bfs", 180),
                                        ("kmeans", 150)])
def test_roundtrip_simulator_executor_tiles_agree(workload, n):
    """schedule -> simulate(replay) -> parallel_for -> lowering must all
    dispatch identical per-tile iteration (work-unit) sets."""
    rng = np.random.default_rng(n)
    if workload == "kmeans":
        costs = rng.uniform(4.0, 9.0, n)
        costs[rng.choice(n, 3, replace=False)] += rng.exponential(80.0, 3)
        inputs = (costs,)
    else:
        indptr, indices, data = _random_csr(n, seed=n)
        inputs = (indptr, indices, data) if workload == "spmv" \
            else (indptr, indices)
    scheduler = LoopScheduler(p=4)
    entry = sched.get(workload)
    provider = entry.costs(*inputs)
    s = scheduler.schedule(provider)
    ranges = s.unit_ranges()
    n_units = int(s.sizes.sum())
    assert ranges[-1, 1] == n_units

    # (a) simulator replay dispatches exactly the tile chunks, in order,
    # with exactly the predicted per-tile work
    rep = s.replay(record_chunks=True)
    log = np.array([(b, e) for (b, e, _, _) in rep.chunk_log])
    np.testing.assert_array_equal(log, ranges)
    work = np.array([w for (*_, w) in rep.chunk_log])
    np.testing.assert_allclose(work, s.tile_cost(), atol=1e-9)

    # (b) threaded executor covers every work unit exactly once in exactly
    # n_tiles chunks (the same pretiled ranges)
    hits = np.zeros(n_units, np.int64)
    lock = threading.Lock()

    def body(u):
        with lock:
            hits[u] += 1

    st = s.parallel_for_units(body)
    assert (hits == 1).all()
    assert st.chunks == s.n_tiles

    # (c) the lowered tiles name the same per-tile item sets as the unit
    # ranges do (via the unit -> item map); padding slots excluded
    unit_item = s.unit_to_item()
    for t in range(s.n_tiles):
        b, e = ranges[t]
        items_from_units = set(unit_item[b:e].tolist())
        ids = s.item_id[t]
        lens = s.lower().seg_len[t]
        items_from_tiles = set(ids[(ids >= 0) & (lens > 0)].tolist())
        assert items_from_tiles == items_from_units


def test_roundtrip_kernel_outputs_match_refs():
    from repro.kernels.ich_bfs.ref import bfs_levels_ref
    from repro.kernels.ich_kmeans.ref import kmeans_assign_ref
    from repro.kernels.ich_spmv.ref import spmv_ref

    rng = np.random.default_rng(5)
    scheduler = LoopScheduler()
    n = 128
    indptr, indices, data = _random_csr(n, seed=5)
    x = rng.standard_normal(n).astype(np.float32)
    spmv = scheduler.build("spmv", indptr, indices, data)
    np.testing.assert_allclose(np.asarray(spmv(x, interpret=True)),
                               spmv_ref(indptr, indices, data, x),
                               atol=1e-4, rtol=1e-4)
    bfs = scheduler.build("bfs", indptr, indices)
    np.testing.assert_array_equal(bfs.levels(0, interpret=True),
                                  bfs_levels_ref(indptr, indices, 0))
    pts = rng.standard_normal((64, 4)).astype(np.float32)
    cent = rng.standard_normal((5, 4)).astype(np.float32)
    km = scheduler.build("kmeans", rng.uniform(1.0, 20.0, 64))
    np.testing.assert_allclose(np.asarray(km(pts, cent, interpret=True)),
                               kmeans_assign_ref(pts, cent), atol=1e-5)


# ----------------------------------------------------------------- registry
def test_registry_builtins_present():
    names = sched.registered()
    assert {"spmv", "bfs", "kmeans"} <= set(names)
    spec = sched.get("spmv")
    assert spec.name == "spmv" and callable(spec.costs) and callable(spec.build)


def test_registry_register_and_duplicate_rejection():
    try:
        spec = register("test_wl", costs=lambda a: ExplicitCosts(a),
                        build=lambda s, a: (s, a), doc="test")
        assert sched.get("test_wl") is spec
        with pytest.raises(ValueError, match="already registered"):
            register("test_wl", costs=spec.costs, build=spec.build)
        register("test_wl", costs=spec.costs, build=spec.build,
                 overwrite=True)  # explicit replacement is allowed
        # facade .build() drives the custom entry end-to-end
        out_s, out_a = LoopScheduler().build(
            "test_wl", np.arange(1, 40, dtype=np.int64))
        assert isinstance(out_s, Schedule) and out_a.shape == (39,)
    finally:
        unregister("test_wl")
    with pytest.raises(KeyError, match="unknown workload"):
        sched.get("test_wl")


def test_schedule_equality_is_identity():
    sizes = np.arange(1, 40, dtype=np.int64)
    scheduler = LoopScheduler(cache_size=0)
    a, b = scheduler.schedule(sizes), scheduler.schedule(sizes)
    # dataclass field-eq over ndarrays would raise; identity semantics don't
    assert a == a and a != b
    assert a in [a, b] and len({id(a), id(b)}) == 2


def test_unregister_builtin_refused():
    with pytest.raises(ValueError, match="cannot unregister built-in"):
        unregister("spmv")
    assert "spmv" in sched.registered()


def test_kmeans_shim_does_not_grow_default_cache():
    from repro.kernels.ich_kmeans.ops import IChKMeans
    from repro.sched import default_scheduler

    cache = default_scheduler().cache
    before = len(cache) if cache is not None else 0
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        IChKMeans(np.random.default_rng(3).uniform(1.0, 9.0, 64))
    after = len(cache) if cache is not None else 0
    assert after == before  # one-shot per-round schedules are not retained


def test_cache_size_zero_disables_caching():
    scheduler = LoopScheduler(cache_size=0)
    sizes = np.arange(1, 60, dtype=np.int64)
    a = scheduler.schedule(sizes)
    b = scheduler.schedule(sizes)
    assert a is not b  # every call constructs fresh
    np.testing.assert_array_equal(a.item_id, b.item_id)
    assert scheduler.cache_stats.hits == 0
    assert scheduler.cache_stats.misses == 0


def test_register_builtin_name_collides_even_before_any_lookup():
    # register() must load the built-ins first, so claiming "spmv" in a
    # fresh process fails AT the offending call instead of poisoning every
    # later registry lookup
    import os
    import subprocess
    import sys
    code = (
        "from repro.sched.registry import register\n"
        "try:\n"
        "    register('spmv', costs=lambda *a: None, build=lambda *a: None)\n"
        "except ValueError as e:\n"
        "    assert 'already registered' in str(e), e\n"
        "else:\n"
        "    raise SystemExit('collision with built-in spmv not detected')\n"
        "from repro.sched import registered\n"
        "assert {'spmv', 'bfs', 'kmeans'} <= set(registered())\n")
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True)
    assert res.returncode == 0, res.stderr


def test_unknown_workload_raises():
    with pytest.raises(KeyError, match="unknown workload"):
        LoopScheduler().build("no_such_workload")


# -------------------------------------------------------- deprecation shims
def test_shims_warn_and_match_new_api_bit_for_bit():
    from repro.kernels.ich_bfs.ops import IChBfs
    from repro.kernels.ich_kmeans.ops import IChKMeans
    from repro.kernels.ich_spmv.ops import IChSpmv

    rng = np.random.default_rng(9)
    n = 96
    indptr, indices, data = _random_csr(n, seed=9)
    x = rng.standard_normal(n).astype(np.float32)
    scheduler = LoopScheduler()

    with pytest.warns(DeprecationWarning, match="IChSpmv is deprecated"):
        spmv_old = IChSpmv(indptr, indices, data)
    spmv_new = scheduler.build("spmv", indptr, indices, data)
    np.testing.assert_array_equal(np.asarray(spmv_old(x, interpret=True)),
                                  np.asarray(spmv_new(x, interpret=True)))

    with pytest.warns(DeprecationWarning, match="IChBfs is deprecated"):
        bfs_old = IChBfs(indptr, indices)
    bfs_new = scheduler.build("bfs", indptr, indices)
    np.testing.assert_array_equal(bfs_old.levels(0, interpret=True),
                                  bfs_new.levels(0, interpret=True))

    costs = rng.uniform(1.0, 30.0, n)
    pts = rng.standard_normal((n, 3)).astype(np.float32)
    cent = rng.standard_normal((4, 3)).astype(np.float32)
    with pytest.warns(DeprecationWarning, match="IChKMeans is deprecated"):
        km_old = IChKMeans(costs)
    km_new = scheduler.build("kmeans", costs)
    np.testing.assert_array_equal(km_old.schedule.item_id,
                                  km_new.schedule.item_id)
    np.testing.assert_array_equal(np.asarray(km_old(pts, cent, interpret=True)),
                                  np.asarray(km_new(pts, cent, interpret=True)))


def test_shims_share_default_scheduler_cache():
    from repro.kernels.ich_spmv.ops import IChSpmv
    from repro.sched import default_scheduler

    indptr, indices, data = _random_csr(70, seed=11)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        a = IChSpmv(indptr, indices, data)
        before = default_scheduler().cache_stats.hits
        b = IChSpmv(indptr, indices, data)
    assert b.schedule is a.schedule  # second shim was a cache hit
    assert default_scheduler().cache_stats.hits == before + 1


# ------------------------------------------------------------- data dispatch
def test_shard_dispatcher_exactly_once_and_weighted_memoized():
    from repro.sched.data_sched import ShardDispatcher

    scheduler = LoopScheduler()
    d = ShardDispatcher(n_hosts=4, scheduler=scheduler)
    n = 500
    hits = np.zeros(n, np.int64)
    lock = threading.Lock()

    def read(i):
        with lock:
            hits[i] += 1

    st = d.dispatch(n, read)
    assert (hits == 1).all() and st.chunks > 0

    costs = np.random.default_rng(2).exponential(5.0, n) + 0.5
    hits[:] = 0
    d.dispatch_weighted(costs, read)
    assert (hits == 1).all()
    before = scheduler.cache_stats.hits
    hits[:] = 0
    d.dispatch_weighted(costs, read)  # chunk list memoized in the LRU
    assert (hits == 1).all()
    assert scheduler.cache_stats.hits == before + 1


# ----------------------------------------------------------- unified epsilon
def test_ich_eps_unified_across_layers():
    import inspect

    from repro.kernels.ich_spmv.ich_spmv import pack_tiles
    from repro.models import moe as MOE

    assert sched.ICH_EPS == 0.33
    assert P.ich().eps == sched.ICH_EPS
    assert P.Policy("x", P.DISTRIBUTED).eps == sched.ICH_EPS
    for fn, name in [(T.ich_tile_width, "eps"), (T.build_schedule, "eps"),
                     (pack_tiles, "eps"), (MOE.ich_update_cap_scale, "eps")]:
        assert inspect.signature(fn).parameters[name].default == sched.ICH_EPS
