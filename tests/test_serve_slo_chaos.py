"""SLO chaos for the serving loop: a PR 7 `FaultPlan` stall hits the
continuous batcher as worker 0, its duration lands on one step's serving
clock, and every request the stall pushes past its deadline must DEGRADE
(shed remaining decode, keep the emitted prefix, PR 7 contract fields) —
never raise, never silently blow the SLO (DESIGN.md §2.9 / §2.10).

Simulated backend + simulated clock: the whole scenario replays
bit-identically from (FaultPlan seed, arrival seed, cost seed)."""
import numpy as np
import pytest

from repro.robust.faults import FaultPlan, Stall
from repro.serve.batcher import (ContinuousBatcher, SimBackend, SimClock,
                                 StepCostModel, make_request_factory)
from repro.serve.loadgen import LengthDist, OpenPoissonLoadGen
from repro.serve.policies import FCFSStatic, IChAdaptive
from repro.serve.queue import AdmissionQueue


def run_trace(policy, *, faults=None, deadline_s=0.25, n=8, seed=21):
    gen = OpenPoissonLoadGen(
        200.0, prompt_lens=LengthDist("fixed", 128, 128),
        output_lens=LengthDist("fixed", 6, 6),
        deadline_s=deadline_s, seed=seed)
    b = ContinuousBatcher(
        policy,
        queue=AdmissionQueue(max_running=4),
        backend=SimBackend(StepCostModel(seed=1)),
        clock=SimClock(), faults=faults)
    m = b.run(gen.arrivals(n), make_request=make_request_factory(
        gen, vocab_size=512))
    return b, m


STALL_PLAN = FaultPlan(seed=5, stalls=(Stall(0, after_chunks=3,
                                             duration=0.5),))


class TestStallDegradesNotBlows:
    def test_baseline_meets_slo_without_faults(self):
        """The deadline is calibrated to pass cleanly fault-free, so any
        degradation in the stall run is attributable to the stall."""
        b, m = run_trace(FCFSStatic(chunk=64))
        assert m.n_degraded == 0
        assert m.n_completed == 8

    def test_stall_degrades_affected_requests(self):
        """A 0.5 s stall against a 0.25 s SLO: requests in flight at the
        stall step blow their budget and MUST come back degraded with the
        prefix kept — the run itself completes every request."""
        b, m = run_trace(FCFSStatic(chunk=64), faults=STALL_PLAN)
        assert m.n_degraded > 0
        assert m.n_completed == 8            # nothing lost, nothing raised
        assert b.queue.n_outstanding == 0
        for st in b.queue.done:
            if st.degraded:
                assert st.n_shed > 0
                assert len(st.out_tokens) + st.n_shed == st.request.n_new
                # emitted prefix survives (shed FUTURE work only)
                assert st.out_tokens == [
                    (st.request.req_id * 7919 + j) % 251
                    for j in range(len(st.out_tokens))]
                assert st.stats()["degraded"] is True
            else:
                assert st.n_shed == 0

    def test_undisturbed_requests_keep_their_outputs(self):
        """Requests that complete before the stall (or start after its
        effect drains) match the fault-free run token-for-token."""
        clean, _ = run_trace(FCFSStatic(chunk=64))
        chaos, _ = run_trace(FCFSStatic(chunk=64), faults=STALL_PLAN)
        clean_out = {st.request.req_id: st.out_tokens
                     for st in clean.queue.done}
        for st in chaos.queue.done:
            full = clean_out[st.request.req_id]
            assert st.out_tokens == full[:len(st.out_tokens)]

    def test_chaos_replays_bit_identically(self):
        runs = [run_trace(IChAdaptive(), faults=STALL_PLAN)[1].summary()
                for _ in range(2)]
        assert runs[0] == runs[1]

    def test_stall_consumed_once(self):
        """The plan's stall fires at exactly one step boundary; the
        serving clock shows one stall-sized jump, not a per-step tax."""
        clean, mc = run_trace(FCFSStatic(chunk=64))
        chaos, mf = run_trace(FCFSStatic(chunk=64), faults=STALL_PLAN)
        extra = mf.t_elapsed - mc.t_elapsed
        assert extra == pytest.approx(0.5, rel=0.3)

    def test_adaptive_policy_survives_chaos_too(self):
        b, m = run_trace(IChAdaptive(), faults=STALL_PLAN)
        assert m.n_completed == 8
        assert b.queue.n_outstanding == 0
        assert m.n_degraded > 0
