"""Serving crash resume + the hardened backend boundary (DESIGN.md §2.11):
the append-only deterministic journal, kill-and-resume bit-identity across
seeded kill points (the CI recovery matrix), `snapshot()/restore()`, the
`EngineBackend` retry budget / circuit breaker, and KV rebuild on the real
engine.

Journals from the matrix cases are written to results/recovery/ so a CI
failure uploads the exact interrupted-run state that broke.
"""
import dataclasses
import json
import os
import time
from pathlib import Path

import numpy as np
import pytest

from repro.robust import (FaultPlan, InjectedFault, JournalDivergence,
                          ServeJournal, resume_from_journal)
from repro.serve.batcher import (CircuitBreaker, ContinuousBatcher,
                                 EngineBackend, SimBackend, SimClock,
                                 StepCostModel, make_request_factory)
from repro.serve.loadgen import OpenPoissonLoadGen
from repro.serve.policies import FCFSStatic, IChAdaptive, RoundRobin
from repro.serve.queue import AdmissionQueue, Request

RESULTS = Path(__file__).resolve().parent.parent / "results" / "recovery"

# seeded kill points for the CI recovery matrix (>= 12 by default);
# override RECOVERY_SEEDS=0,1,... to widen or pin the sweep
RECOVERY_SEEDS = [int(s) for s in os.environ.get(
    "RECOVERY_SEEDS", ",".join(map(str, range(12)))).split(",") if s != ""]


def _workload(seed, n=14):
    gen = OpenPoissonLoadGen(rate=40.0, deadline_s=2.0, seed=seed)
    return gen.arrivals(n), make_request_factory(gen, vocab_size=256)


def _batcher(seed, *, policy=None, journal=None, faults=None):
    return ContinuousBatcher(
        policy if policy is not None else IChAdaptive(),
        queue=AdmissionQueue(max_pending=8, max_running=4),
        backend=SimBackend(StepCostModel(seed=seed)),
        clock=SimClock(), faults=faults, journal=journal)


def _run_killed(seed, kill_events, *, policy=None, faults=None):
    """Drive a journaled run and abandon it once the journal holds
    `kill_events` events — the crash, mid-run, at a step boundary."""
    arrivals, mk = _workload(seed)
    j = ServeJournal()
    b = _batcher(seed, policy=policy, journal=j, faults=faults)
    pending = sorted(arrivals, key=lambda a: (a.t, a.req_id))
    i = 0
    b._t_start = b.clock.now()
    b._j({"ev": "run", "t_start": b._t_start})
    while len(j.events) < kill_events:
        now = b.clock.now()
        while i < len(pending) and pending[i].t + b._t_start <= now:
            a = dataclasses.replace(pending[i], t=pending[i].t + b._t_start)
            b.submit(mk(a))
            i += 1
        if not b.step():
            if i >= len(pending):
                break
            gap = pending[i].t + b._t_start - now
            b._j({"ev": "gap", "dt": gap})
            b.clock.advance(gap)
    return j


# ----------------------------------------------------- journal mechanics

class TestServeJournal:
    def test_jsonl_roundtrip_is_exact(self):
        arrivals, mk = _workload(0)
        j = ServeJournal()
        _batcher(0, journal=j).run(arrivals, make_request=mk)
        assert len(j.events) > 20
        back = ServeJournal.from_jsonl(j.to_jsonl())
        assert back.events == j.events
        assert back.header == j.header

    def test_torn_final_line_dropped(self):
        text = ('{"ev":"header","version":1}\n'
                '{"ev":"run","t_start":0.0}\n'
                '{"ev":"step","i":0,"dt":0.0')       # crash mid-write
        j = ServeJournal.from_jsonl(text)
        assert [e["ev"] for e in j.events] == ["header", "run"]

    def test_malformed_interior_line_raises(self):
        text = '{"ev":"header"}\nnot json\n{"ev":"run","t_start":0.0}\n'
        with pytest.raises(json.JSONDecodeError):
            ServeJournal.from_jsonl(text)

    def test_file_mirror_flushes_every_event(self, tmp_path):
        path = tmp_path / "serve.jsonl"
        j = ServeJournal(path=str(path))
        arrivals, mk = _workload(1)
        _batcher(1, journal=j).run(arrivals, make_request=mk)
        loaded = ServeJournal.load(path)
        assert loaded.events == j.events

    def test_numpy_scalars_canonicalized(self):
        j = ServeJournal()
        j.append({"ev": "x", "v": np.int64(3), "f": np.float64(0.5)})
        assert j.events[0] == {"ev": "x", "v": 3, "f": 0.5}
        assert json.loads(j.to_jsonl()) == j.events[0]


# ------------------------------------------------ kill-and-resume matrix

@pytest.mark.parametrize("seed", RECOVERY_SEEDS)
def test_kill_and_resume_bit_identical(seed):
    """The acceptance criterion: kill the batcher at a seed-derived event
    count, resume from the journal, finish the trace — final journal,
    queue state, and metrics summary are bit-identical to the
    uninterrupted run. The interrupted journal is written to
    results/recovery/ for the CI failure artifact."""
    arrivals, mk = _workload(seed)
    j_full = ServeJournal()
    b_full = _batcher(seed, journal=j_full)
    m_full = b_full.run(arrivals, make_request=mk)
    n_ev = len(j_full.events)
    assert n_ev > 10
    kill = 2 + (seed * 37) % (n_ev - 4)

    RESULTS.mkdir(parents=True, exist_ok=True)
    j_kill = _run_killed(seed, kill)
    (RESULTS / f"serve_seed{seed}.jsonl").write_text(j_kill.to_jsonl())

    # resume from the persisted form (what a real crash leaves behind)
    j_loaded = ServeJournal.from_jsonl(j_kill.to_jsonl())
    rb = resume_from_journal(
        j_loaded, policy=IChAdaptive(),
        queue=AdmissionQueue(max_pending=8, max_running=4),
        backend=SimBackend(StepCostModel(seed=seed)))
    m_res = rb.run(arrivals, make_request=mk)
    assert rb.journal.events == j_full.events
    assert rb.queue.state_dict() == b_full.queue.state_dict()
    assert m_res.summary() == m_full.summary()
    # per-request outputs and stats survive the crash exactly
    for a, c in zip(b_full.queue.done, rb.queue.done):
        assert a.out_tokens == c.out_tokens
        assert a.stats() == c.stats()


def test_resume_with_fault_plan_stalls(seed=5):
    """Journaled stall events replay: a FaultPlan's batcher-loop stalls
    (worker 0) are consumed at the same steps on resume, and the resumed
    run still matches the uninterrupted faulty run."""
    plan = FaultPlan(seed=seed, stalls=((0, 3, 1.5), (0, 9, 0.7)))
    arrivals, mk = _workload(seed)
    j_full = ServeJournal()
    b_full = _batcher(seed, journal=j_full, faults=plan)
    m_full = b_full.run(arrivals, make_request=mk)
    assert any(e["ev"] == "stall" for e in j_full.events)

    j_kill = _run_killed(seed, len(j_full.events) // 2, faults=plan)
    rb = resume_from_journal(
        j_kill, policy=IChAdaptive(),
        queue=AdmissionQueue(max_pending=8, max_running=4),
        backend=SimBackend(StepCostModel(seed=seed)), faults=plan)
    m_res = rb.run(arrivals, make_request=mk)
    assert rb.journal.events == j_full.events
    assert m_res.summary() == m_full.summary()


class TestResumeRefusals:
    def _journal(self, seed=3):
        arrivals, mk = _workload(seed)
        j = ServeJournal()
        _batcher(seed, journal=j).run(arrivals, make_request=mk)
        return j

    def test_wrong_policy_refused(self):
        j = self._journal()
        with pytest.raises(JournalDivergence, match="policy"):
            resume_from_journal(
                j, policy=FCFSStatic(),
                queue=AdmissionQueue(max_pending=8, max_running=4),
                backend=SimBackend(StepCostModel(seed=3)))

    def test_wrong_cost_model_refused(self):
        j = self._journal()
        with pytest.raises(JournalDivergence, match="cost_model"):
            resume_from_journal(
                j, policy=IChAdaptive(),
                queue=AdmissionQueue(max_pending=8, max_running=4),
                backend=SimBackend(StepCostModel(seed=99)))

    def test_wrong_fault_plan_fingerprint_refused(self):
        plan = FaultPlan(seed=2, stalls=((0, 4, 1.0),))
        arrivals, mk = _workload(2)
        j = ServeJournal()
        _batcher(2, journal=j, faults=plan).run(arrivals, make_request=mk)
        other = FaultPlan(seed=2, stalls=((0, 4, 2.0),))
        assert other.fingerprint() != plan.fingerprint()
        with pytest.raises(JournalDivergence, match="faults"):
            resume_from_journal(
                j, policy=IChAdaptive(),
                queue=AdmissionQueue(max_pending=8, max_running=4),
                backend=SimBackend(StepCostModel(seed=2)), faults=other)

    def test_strict_false_overrides_header_check(self):
        j = self._journal()
        rb = resume_from_journal(
            j, policy=IChAdaptive(),
            queue=AdmissionQueue(max_pending=8, max_running=4),
            backend=SimBackend(StepCostModel(seed=3)), strict=False)
        assert rb.step_idx > 0

    def test_headerless_journal_refused(self):
        with pytest.raises(JournalDivergence, match="no header"):
            resume_from_journal(ServeJournal(), policy=IChAdaptive())


# --------------------------------------------------- snapshot / restore

def test_snapshot_restore_resumes_identically():
    """Direct state restore (no replay) with a stateless policy: the
    restored batcher finishes the trace to the same queue state and
    metrics as the uninterrupted run."""
    seed = 4
    arrivals, mk = _workload(seed)
    b_full = _batcher(seed, policy=FCFSStatic())
    m_full = b_full.run(arrivals, make_request=mk)

    b = _batcher(seed, policy=FCFSStatic())
    pending = sorted(arrivals, key=lambda a: (a.t, a.req_id))
    i = 0
    b._t_start = b.clock.now()
    for _ in range(23):
        now = b.clock.now()
        while i < len(pending) and pending[i].t + b._t_start <= now:
            a = dataclasses.replace(pending[i], t=pending[i].t + b._t_start)
            b.submit(mk(a))
            i += 1
        if not b.step():
            if i >= len(pending):
                break
            b.clock.advance(pending[i].t + b._t_start - now)
    snap = json.loads(json.dumps(b.snapshot()))   # through serialization
    rb = ContinuousBatcher.restore(
        snap, policy=FCFSStatic(),
        backend=SimBackend(StepCostModel(seed=seed)))
    m_res = rb.run(arrivals, make_request=mk)
    assert rb.queue.state_dict() == b_full.queue.state_dict()
    assert m_res.summary() == m_full.summary()


def test_snapshot_restore_version_check():
    b = _batcher(0, policy=FCFSStatic())
    snap = b.snapshot()
    snap["version"] = 99
    with pytest.raises(ValueError, match="version"):
        ContinuousBatcher.restore(snap, policy=FCFSStatic())


# --------------------------------------------- hardened backend boundary

class FakeEngine:
    """Pure-Python engine twin: tokens are the SimBackend's deterministic
    function of (req_id, position), faults are injected by a predicate on
    (op, call index) so flaky scenarios replay exactly."""

    def __init__(self, fail=None):
        self.calls = 0
        self.fail = fail if fail is not None else (lambda op, call: False)

    def _op(self, op):
        self.calls += 1
        if self.fail(op, self.calls):
            raise InjectedFault(f"injected {op} fault at call {self.calls}")

    def prefill_chunk_step(self, st, chunk):
        self._op("prefill")
        c = min(int(chunk), st.remaining_prefill)
        st.prefill_done += c
        if st.remaining_prefill == 0:
            st.out_tokens.append((st.request.req_id * 7919) % 251)

    def decode_one(self, st):
        self._op("decode")
        st.out_tokens.append(
            (st.request.req_id * 7919 + len(st.out_tokens)) % 251)


def _requests(n=3, n_new=5, deadline_s=None):
    return [Request(req_id=i, tokens=np.arange(1, 7, dtype=np.int32),
                    n_new=n_new, deadline_s=deadline_s, t_arrival=0.0)
            for i in range(n)]


class TestCircuitBreaker:
    def test_state_machine(self):
        br = CircuitBreaker(threshold=2, cooldown_steps=3)
        assert br.allow(0) and br.state == br.CLOSED
        br.record_failure(0)
        assert br.state == br.CLOSED
        br.record_failure(1)
        assert br.state == br.OPEN and br.n_trips == 1
        assert not br.allow(2) and not br.allow(3)
        assert br.allow(4) and br.state == br.HALF_OPEN   # cooldown done
        br.record_failure(4)                              # probe failed
        assert br.state == br.OPEN and br.n_trips == 2
        assert br.allow(8) and br.state == br.HALF_OPEN
        br.record_success()                               # probe succeeded
        assert br.state == br.CLOSED and br.failures == 0

    def test_success_resets_consecutive_count(self):
        br = CircuitBreaker(threshold=3, cooldown_steps=2)
        br.record_failure(0)
        br.record_failure(1)
        br.record_success()
        br.record_failure(2)
        br.record_failure(3)
        assert br.state == br.CLOSED   # never 3 consecutive

    def test_state_dict_roundtrip(self):
        br = CircuitBreaker(threshold=2, cooldown_steps=5)
        br.record_failure(0)
        br.record_failure(1)
        back = CircuitBreaker.from_state(br.state_dict())
        assert back.state_dict() == br.state_dict()

    def test_validation(self):
        with pytest.raises(ValueError):
            CircuitBreaker(threshold=0)
        with pytest.raises(ValueError):
            CircuitBreaker(cooldown_steps=0)


class TestEngineBackendHardening:
    def test_transient_faults_absorbed_by_retry_budget(self):
        """Every 5th engine call fails once; with a retry budget the run
        completes with full outputs, zero absorbed faults, and the retry
        backoff goes through the injected sleep_fn (zero wall-clock)."""
        sleeps = []
        eng = FakeEngine(fail=lambda op, call: call % 5 == 0)
        be = EngineBackend(eng, retries=2, retry_backoff_s=0.05,
                           sleep_fn=sleeps.append)
        b = ContinuousBatcher(RoundRobin(chunk=4, min_chunk=2),
                              queue=AdmissionQueue(max_running=4),
                              backend=be, clock=SimClock())
        sts = [b.submit(r) for r in _requests()]
        t0 = time.monotonic()
        while b.step():
            pass
        assert time.monotonic() - t0 < 1.0      # no real backoff sleeps
        assert sleeps and all(s > 0 for s in sleeps)
        assert be.n_retries == len(sleeps)
        assert be.n_faults == 0
        for st in sts:
            assert st.out_tokens == [(st.request.req_id * 7919 + j) % 251
                                     for j in range(5)]
        assert b.metrics.n_backend_retries == be.n_retries

    def test_dead_engine_degrades_instead_of_crashing(self):
        """An engine that dies permanently mid-run: the retry budget is
        exhausted, faults are absorbed, the breaker opens and charges
        `open_step_s` to the simulated clock, and every stuck request
        exits through the deadline path DEGRADED — the batcher loop never
        sees an exception."""
        eng = FakeEngine(fail=lambda op, call: call > 10)
        be = EngineBackend(
            eng, retries=1,
            breaker=CircuitBreaker(threshold=2, cooldown_steps=4),
            open_step_s=0.05)
        b = ContinuousBatcher(RoundRobin(chunk=4, min_chunk=2),
                              queue=AdmissionQueue(max_running=4),
                              backend=be, clock=SimClock())
        sts = [b.submit(r) for r in _requests(n=3, n_new=8,
                                              deadline_s=1.0)]
        steps = 0
        while b.step():
            steps += 1
            assert steps < 500, "batcher failed to drain via deadlines"
        assert be.n_faults > 0
        assert be.breaker.state == be.breaker.OPEN
        assert b.metrics.n_breaker_trips >= 1
        assert b.metrics.n_backend_faults == be.n_faults
        degraded = [st for st in sts if st.degraded]
        assert degraded and all(st.n_shed > 0 for st in degraded)
        assert b.queue.running == []             # everyone finalized

    def test_breaker_half_open_probe_recovers(self):
        """The engine fails for a window then recovers: the breaker trips,
        the half-open probe succeeds once the window passes, and every
        request still completes its FULL output (nothing degraded —
        failed ops made no progress, so no tokens were lost)."""
        eng = FakeEngine(fail=lambda op, call: 4 <= call <= 9)
        be = EngineBackend(
            eng, breaker=CircuitBreaker(threshold=2, cooldown_steps=3),
            open_step_s=0.01)
        b = ContinuousBatcher(RoundRobin(chunk=4, min_chunk=2),
                              queue=AdmissionQueue(max_running=4),
                              backend=be, clock=SimClock())
        sts = [b.submit(r) for r in _requests(n=2, n_new=4)]
        while b.step():
            pass
        assert be.breaker.n_trips >= 1
        assert be.breaker.state == be.breaker.CLOSED
        for st in sts:
            assert not st.degraded
            assert st.out_tokens == [(st.request.req_id * 7919 + j) % 251
                                     for j in range(4)]

    def test_real_bugs_still_propagate(self):
        class Boom(Exception):
            pass

        class BuggyEngine(FakeEngine):
            def decode_one(self, st):
                raise Boom("not a fault")

        be = EngineBackend(BuggyEngine(), retries=3)
        b = ContinuousBatcher(RoundRobin(chunk=4, min_chunk=2),
                              queue=AdmissionQueue(max_running=2),
                              backend=be, clock=SimClock())
        b.submit(_requests(n=1)[0])
        with pytest.raises(Boom):
            while b.step():
                pass

    def test_rebuild_state_verifies_token_replay(self):
        eng = FakeEngine()
        be = EngineBackend(eng)
        b = ContinuousBatcher(RoundRobin(chunk=4, min_chunk=2),
                              queue=AdmissionQueue(max_running=2),
                              backend=be, clock=SimClock())
        st = b.submit(_requests(n=1, n_new=6)[0])
        for _ in range(5):
            b.step()
        assert st.out_tokens                      # mid-decode
        good = list(st.out_tokens)
        be.rebuild_state(st)                      # replays cleanly
        assert st.out_tokens == good
        st.out_tokens[-1] = (st.out_tokens[-1] + 1) % 251
        with pytest.raises(ValueError, match="diverge"):
            be.rebuild_state(st)


# -------------------------------------- wall-clock journal resume (fake)

def test_wall_clock_journal_resumes_tokens_exactly():
    """A wall-clock backend's measured step durations are journaled and
    replayed via the dt override; tokens and queue contents resume
    exactly even though 't' stamps are measurements."""
    def build(journal=None):
        be = EngineBackend(FakeEngine())
        return ContinuousBatcher(RoundRobin(chunk=4, min_chunk=2),
                                 queue=AdmissionQueue(max_running=4),
                                 backend=be, journal=journal)

    j = ServeJournal()
    b = build(journal=j)
    reqs = _requests(n=3, n_new=5)
    sts = [b.submit(r) for r in reqs]
    b._t_start = b.clock.now()
    for _ in range(6):                            # crash mid-run
        b.step()
    rb = resume_from_journal(j, policy=RoundRobin(chunk=4, min_chunk=2),
                             queue=AdmissionQueue(max_running=4),
                             backend=EngineBackend(FakeEngine()))
    # resumed streams picked up exactly where the crashed run stood
    for orig, res in zip(sts, rb.queue.running + rb.queue.done):
        assert res.out_tokens == orig.out_tokens
        assert res.prefill_done == orig.prefill_done
    while rb.step():
        pass
    for st in rb.queue.done:
        assert st.out_tokens == [(st.request.req_id * 7919 + j) % 251
                                 for j in range(5)]


# ------------------------------------------- KV rebuild on the real engine

def test_engine_backend_rebuild_kv_bit_identical():
    """`EngineBackend.rebuild_state` on the real reduced model: a
    snapshot/restore mid-decode re-derives the KV cache by replaying the
    journaled chunk sizes, and the resumed run's remaining tokens equal
    the uninterrupted run's bit-for-bit."""
    jax = pytest.importorskip("jax")
    from repro.configs import get_arch, reduced
    from repro.models import model as M
    from repro.serve.engine import Engine, EngineConfig

    cfg = reduced(get_arch("qwen2-1.5b"))
    params = M.init_params(cfg, jax.random.PRNGKey(0), max_seq=64)
    rng = np.random.default_rng(7)
    toks = [rng.integers(0, cfg.vocab_size, (1, s), dtype=np.int64)
            for s in (22, 15)]

    def build():
        eng = Engine(cfg, params, EngineConfig(max_seq=64, min_chunk=4))
        return ContinuousBatcher(RoundRobin(chunk=8, min_chunk=4),
                                 queue=AdmissionQueue(max_running=4),
                                 backend=EngineBackend(eng),
                                 clock=SimClock())

    b_full = build()
    sts_full = [b_full.submit(Request(req_id=i, tokens=toks[i], n_new=6,
                                      t_arrival=0.0)) for i in range(2)]
    while b_full.step():
        pass

    b = build()
    sts = [b.submit(Request(req_id=i, tokens=toks[i], n_new=6,
                            t_arrival=0.0)) for i in range(2)]
    for _ in range(7):                       # past prefill, mid-decode
        b.step()
    assert any(st.out_tokens for st in sts)
    snap = json.loads(json.dumps(b.snapshot()))  # KV deliberately absent
    eng2 = Engine(cfg, params, EngineConfig(max_seq=64, min_chunk=4))
    rb = ContinuousBatcher.restore(snap, policy=RoundRobin(chunk=8,
                                                           min_chunk=4),
                                   backend=EngineBackend(eng2))
    while rb.step():
        pass
    assert [st.out_tokens for st in rb.queue.done] == \
        [st.out_tokens for st in b_full.queue.done]
