"""Tests for the shared iCh schedule-construction layer (core/tiling.py)."""
import numpy as np
import pytest

from repro.core import policies as P
from repro.core.simulator import simulate
from repro.core.tiling import (
    _reference_build_schedule, _reference_coverage_counts,
    _reference_pack_csr, _reference_split_items,
    build_schedule, coverage_counts, ich_tile_width, pack_csr, split_items,
)


def _random_sizes(n, zipf_a, seed, max_size=300):
    rng = np.random.default_rng(seed)
    sizes = np.minimum(rng.zipf(zipf_a, n), max_size).astype(np.int64)
    sizes[rng.random(n) < 0.1] = 0  # sprinkle empty items
    return sizes


# ------------------------------------------------------------------ coverage
@pytest.mark.parametrize("n,zipf_a,R,seed", [
    (100, 1.6, 4, 0), (256, 1.9, 8, 1), (333, 2.5, 8, 2), (64, 1.3, 16, 3),
])
def test_every_iteration_covered_exactly_once(n, zipf_a, R, seed):
    sizes = _random_sizes(n, zipf_a, seed)
    sched = build_schedule(sizes, rows_per_tile=R)
    counts = coverage_counts(sched, sizes)
    assert counts.shape == (int(sizes.sum()),)
    assert (counts == 1).all()
    # every item owns at least one slot (even empty ones)
    present = np.unique(sched.item_id[sched.item_id >= 0])
    np.testing.assert_array_equal(present, np.arange(n))
    assert int(sched.tile_work().sum()) == int(sizes.sum())


def test_empty_sizes_array_builds_zero_tile_schedule():
    # since the empty-schedule sweep, zero items is a valid degenerate
    # input — the full contract lives in tests/test_empty_schedule.py
    sched = build_schedule(np.array([], dtype=np.int64))
    assert sched.n_tiles == 0 and sched.n_items == 0


def test_int32_overflow_guard_raises_instead_of_corrupting():
    # the vectorized path runs int32 internally; out-of-range items must be
    # rejected loudly, not silently wrapped to empty schedules
    with pytest.raises(ValueError, match="fit int32"):
        build_schedule(np.array([2 ** 31 + 5, 3], dtype=np.int64), width=8)


@pytest.mark.parametrize("bad", [0, -1, -16])
def test_nonpositive_explicit_width_raises(bad):
    # regression: width=0 used to fall through `if width` to the band
    # heuristic instead of being rejected
    with pytest.raises(ValueError, match="width must be positive"):
        build_schedule(np.array([3, 4, 5]), width=bad)
    with pytest.raises(ValueError, match="width must be positive"):
        _reference_build_schedule(np.array([3, 4, 5]), width=bad)
    with pytest.raises(ValueError, match="width must be positive"):
        split_items(np.array([3, 4, 5]), bad)
    with pytest.raises(ValueError, match="width must be positive"):
        _reference_split_items(np.array([3, 4, 5]), bad)


def test_empty_rows_get_one_slot_each():
    sizes = np.zeros(10, np.int64)
    sched = build_schedule(sizes, rows_per_tile=4)
    assert sched.n_tiles == 3  # ceil(10 / 4)
    assert (sched.seg_len == 0).all()
    assert (sched.tile_work() == 0).all()
    assert sorted(sched.item_id[sched.item_id >= 0]) == list(range(10))


def test_single_row_wider_than_max_w_splits():
    sizes = np.array([10_000], np.int64)
    sched = build_schedule(sizes, rows_per_tile=8)
    assert sched.width == 512  # clamped at max_w
    n_segs = -(-10_000 // 512)
    assert (sched.item_id >= 0).sum() == n_segs
    assert (coverage_counts(sched, sizes) == 1).all()
    # all segments belong to item 0 and tile back-to-back
    starts = np.sort(sched.seg_start[sched.item_id >= 0])
    np.testing.assert_array_equal(starts, np.arange(n_segs) * 512)


def test_explicit_width_override():
    sizes = _random_sizes(200, 1.8, 5)
    sched = build_schedule(sizes, width=16)
    assert sched.width == 16
    assert (sched.seg_len <= 16).all()
    assert (coverage_counts(sched, sizes) == 1).all()


def test_width_band_monotone_and_clamped():
    # W = pow2(mu*(1+eps)): uniform-32 rows fit one segment (64 >= 42.6);
    # small-row inputs clamp to min_w; always a power of two in [8, 512]
    assert ich_tile_width(np.full(1000, 32)) == 64
    assert ich_tile_width(np.full(1000, 2)) == 8
    w_hvy = ich_tile_width(
        np.minimum(np.random.default_rng(0).zipf(1.5, 1000), 5000))
    assert w_hvy in {8, 16, 32, 64, 128, 256, 512}
    # monotone in eps (wider band -> wider tiles)
    rows = np.random.default_rng(1).integers(1, 100, 500)
    assert ich_tile_width(rows, eps=0.5) >= ich_tile_width(rows, eps=0.25)


def test_split_items_orders_segments_by_item():
    item, start, length = split_items(np.array([5, 0, 12]), width=8)
    segs = list(zip(item.tolist(), start.tolist(), length.tolist()))
    assert segs == [(0, 0, 5), (1, 0, 0), (2, 0, 8), (2, 8, 4)]
    assert segs == _reference_split_items(np.array([5, 0, 12]), width=8)


# ------------------------------------------- vectorized vs reference oracles
@pytest.mark.parametrize("n,zipf_a,R,W,seed", [
    (1, 1.5, 8, None, 0), (97, 1.4, 4, None, 1), (256, 2.1, 8, 16, 2),
    (333, 1.7, 16, 1, 3), (64, 1.3, 3, 7, 4), (500, 1.9, 8, None, 5),
])
def test_vectorized_construction_matches_reference(n, zipf_a, R, W, seed):
    sizes = _random_sizes(n, zipf_a, seed)
    vec = build_schedule(sizes, rows_per_tile=R, width=W)
    ref = _reference_build_schedule(sizes, rows_per_tile=R, width=W)
    assert vec.width == ref.width and vec.n_items == ref.n_items
    np.testing.assert_array_equal(vec.item_id, ref.item_id)
    np.testing.assert_array_equal(vec.seg_start, ref.seg_start)
    np.testing.assert_array_equal(vec.seg_len, ref.seg_len)
    item, start, length = split_items(sizes, vec.width)
    assert (list(zip(item.tolist(), start.tolist(), length.tolist()))
            == _reference_split_items(sizes, vec.width))
    rng = np.random.default_rng(seed + 100)
    indptr = np.concatenate([[0], np.cumsum(sizes)])
    nnz = int(indptr[-1])
    indices = rng.integers(0, n, nnz).astype(np.int32)
    data = rng.standard_normal(nnz).astype(np.float32)
    for a, b in zip(pack_csr(indptr, indices, data, vec),
                    _reference_pack_csr(indptr, indices, data, vec)):
        np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(coverage_counts(vec, sizes),
                                  _reference_coverage_counts(vec, sizes))


# -------------------------------------------------------------- CSR packing
def test_pack_csr_matches_flat_payload():
    rng = np.random.default_rng(7)
    sizes = _random_sizes(120, 1.7, 7)
    indptr = np.concatenate([[0], np.cumsum(sizes)])
    nnz = int(indptr[-1])
    indices = rng.integers(0, 120, nnz).astype(np.int32)
    data = rng.standard_normal(nnz).astype(np.float32)
    sched = build_schedule(sizes, rows_per_tile=8)
    vals, cols = pack_csr(indptr, indices, data, sched)
    # scatter the tiles back into flat CSR order and compare
    flat_v = np.zeros(nnz, np.float32)
    flat_c = np.zeros(nnz, np.int32)
    for t in range(sched.n_tiles):
        for j in range(sched.rows_per_tile):
            it, s, ln = (int(sched.item_id[t, j]), int(sched.seg_start[t, j]),
                         int(sched.seg_len[t, j]))
            if it >= 0 and ln > 0:
                b = int(indptr[it]) + s
                flat_v[b:b + ln] = vals[t, j, :ln]
                flat_c[b:b + ln] = cols[t, j, :ln]
    np.testing.assert_array_equal(flat_v, data)
    np.testing.assert_array_equal(flat_c, indices)
    # padding slots are zero (kernels reduce over W unmasked)
    mask = np.zeros_like(vals, bool)
    for t in range(sched.n_tiles):
        for j in range(sched.rows_per_tile):
            mask[t, j, :int(sched.seg_len[t, j])] = True
    assert (vals[~mask] == 0).all() and (cols[~mask] == 0).all()


# ------------------------------------------------- simulator cross-check
def test_schedule_replays_in_simulator_chunk_for_chunk():
    """The constructed schedule, handed to the discrete-event simulator as an
    explicit pretiled policy over the same cost array, must be dispatched
    with exactly the per-tile work the schedule predicts."""
    sizes = _random_sizes(300, 1.8, 11)
    costs = 1.0 + sizes.astype(np.float64)  # per-item cost model
    sched = build_schedule(sizes, rows_per_tile=8)
    ranges = sched.slot_ranges()
    # tiles cover the flattened work-unit space contiguously, in order
    assert ranges[0, 0] == 0 and ranges[-1, 1] == int(sizes.sum())
    np.testing.assert_array_equal(ranges[1:, 0], ranges[:-1, 1])
    res = simulate(sched.unit_costs(costs, sizes), 4, P.pretiled(ranges),
                   record_chunks=True)
    sim_work = np.array([w for (_, _, _, w) in res.chunk_log])
    np.testing.assert_allclose(sim_work, sched.tile_cost(costs, sizes),
                               atol=1e-9)
    assert res.chunks == sched.n_tiles
