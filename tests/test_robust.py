"""Fault injection & recovery across the simulator and the threaded
executor (DESIGN.md §2.9, repro.robust).

The acceptance contract this suite pins: with k of p workers killed
mid-run under a seeded `FaultPlan`, both layers still complete — SpMV
output bit-identical to the sequential reference, every iteration executed
exactly once, and the same plan replayed twice yields identical
chunk/steal/fault traces.
"""
import dataclasses
import json
import os
import threading
import time

import numpy as np
import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from conftest import random_csr

from repro.core import executor as E
from repro.core import policies as P
from repro.core import simulator as S
from repro.robust import (Death, FaultError, FaultPlan, InjectedFault,
                          Stall, simulate_faulty)
from repro.sched import LoopScheduler


def zipf_costs(n=400, seed=0):
    rng = np.random.default_rng(seed)
    return rng.zipf(1.8, n).clip(1, 60).astype(np.float64)


# --------------------------------------------------------------- FaultPlan

class TestFaultPlan:
    def test_bare_tuples_coerced(self):
        plan = FaultPlan(deaths=((1, 2),), stalls=((0, 1, 0.5),))
        assert plan.deaths == (Death(1, 2),)
        assert plan.stalls == (Stall(0, 1, 0.5),)

    def test_validation(self):
        with pytest.raises(ValueError):
            FaultPlan(flaky_frac=1.5)
        with pytest.raises(ValueError):
            FaultPlan(flaky_failures=0)
        with pytest.raises(ValueError):
            FaultPlan(cost_noise=-1.0)
        with pytest.raises(ValueError):
            Death(worker=-1)
        with pytest.raises(ValueError):
            Stall(worker=0, duration=-1.0)

    def test_worker_out_of_range_rejected_everywhere(self):
        plan = FaultPlan(deaths=((5, 0),))
        with pytest.raises(ValueError, match="worker 5"):
            plan.validate_workers(2)
        with pytest.raises(ValueError, match="worker 5"):
            S.simulate(zipf_costs(50), 2, P.ich(), faults=plan)
        with pytest.raises(ValueError, match="worker 5"):
            E.parallel_for(50, lambda i: None, 2, P.ich(), faults=plan)

    def test_derived_streams_are_seed_deterministic(self):
        a = FaultPlan(seed=9, flaky_frac=0.2, cost_noise=0.3)
        b = FaultPlan(seed=9, flaky_frac=0.2, cost_noise=0.3)
        costs = zipf_costs(200)
        np.testing.assert_array_equal(a.flaky_items(200), b.flaky_items(200))
        np.testing.assert_array_equal(a.corrupt_costs(costs),
                                      b.corrupt_costs(costs))
        c = FaultPlan(seed=10, flaky_frac=0.2, cost_noise=0.3)
        assert not np.array_equal(a.corrupt_costs(costs),
                                  c.corrupt_costs(costs))

    def test_corrupt_costs_identity_without_noise(self):
        costs = zipf_costs(64)
        out = FaultPlan(seed=1).corrupt_costs(costs)
        np.testing.assert_array_equal(out, costs)
        assert out is not costs  # always a copy

    def test_wrap_body_passthrough_when_no_body_faults(self):
        body = lambda i: None  # noqa: E731
        assert FaultPlan(deaths=((0, 0),)).wrap_body(body, 10) is body
        assert FaultPlan(poison=(3,)).wrap_body(body, 10) is not body


# -------------------------------------------------------- simulator faults

class TestSimulatorFaults:
    def test_single_death_completes_with_full_coverage(self):
        costs = zipf_costs()
        plan = FaultPlan(seed=3, deaths=((2, 2),))
        res = S.simulate(costs, 4, P.ich(), faults=plan,
                         record_assignment=True)
        assert res.deaths == 1
        assert res.reclaims >= 1
        assert (res.assignment >= 0).all()  # every item dispatched
        assert res.assignment.size == costs.size
        kinds = [ev[0] for ev in res.fault_log]
        assert "death" in kinds and "reclaim" in kinds

    def test_fault_replay_is_deterministic(self):
        costs = zipf_costs(seed=5)
        plan = FaultPlan(seed=7, deaths=((1, 3),), stalls=((0, 2, 25.0),))
        runs = [S.simulate(costs, 4, P.ich(), faults=plan,
                           record_chunks=True) for _ in range(2)]
        assert runs[0].makespan == runs[1].makespan
        assert runs[0].chunk_log == runs[1].chunk_log
        assert runs[0].fault_log == runs[1].fault_log

    def test_stall_inflates_makespan(self):
        costs = np.full(200, 5.0)
        plan = FaultPlan(stalls=((0, 1, 500.0),))
        rep = simulate_faulty(costs, 4, P.ich(), plan)
        assert rep.faulty.stall_events == 1
        assert rep.inflation > 1.0

    def test_central_policy_death_survivors_drain(self):
        costs = zipf_costs()
        plan = FaultPlan(deaths=((0, 1),))
        res = S.simulate(costs, 4, P.dynamic(8), faults=plan,
                         record_assignment=True)
        assert res.deaths == 1
        assert (res.assignment >= 0).all()
        assert not (res.assignment == 0).any() or \
            (res.assignment == 0).sum() <= 8  # at most its one chunk

    def test_all_workers_dead_raises(self):
        plan = FaultPlan(deaths=tuple((w, 1) for w in range(4)))
        with pytest.raises(FaultError):
            S.simulate(zipf_costs(), 4, P.ich(), faults=plan)
        with pytest.raises(FaultError):
            S.simulate(zipf_costs(), 4, P.dynamic(4), faults=plan)

    def test_static_assignment_policies_reject_faults(self):
        costs = zipf_costs(64)
        tiles = [(i * 8, (i + 1) * 8) for i in range(8)]
        workers = np.arange(8) % 4
        plan = FaultPlan(deaths=((0, 0),))
        with pytest.raises(ValueError, match="statically"):
            S.simulate(costs, 4, P.assigned(tiles, workers), faults=plan)
        with pytest.raises(ValueError, match="statically"):
            S.simulate(costs, 4, P.binlpt(32), faults=plan)

    def test_bounded_factor_vs_faultfree_smaller_machine(self):
        """Headline invariant: killing k of p workers early costs at most
        a small constant factor over running fault-free on p-k workers
        (measured spread across seeds is ~[0.88, 1.25])."""
        for seed in range(3):
            costs = zipf_costs(seed=seed)
            for k in (1, 2):
                plan = FaultPlan(seed=seed,
                                 deaths=tuple((w, 1) for w in range(k)))
                faulty = S.simulate(costs, 4, P.ich(), faults=plan)
                clean = S.simulate(costs, 4 - k, P.ich())
                assert faulty.makespan <= 1.5 * clean.makespan

    def test_simulate_faulty_report(self):
        costs = zipf_costs()
        plan = FaultPlan(seed=3, deaths=((1, 2),))
        rep = simulate_faulty(costs, 4, P.ich(), plan)
        assert rep.clean.deaths == 0 and rep.faulty.deaths == 1
        assert rep.plan is plan
        assert rep.inflation == pytest.approx(
            rep.faulty.makespan / rep.clean.makespan)


# ----------------------------------------------- executor: supervision bug

class TestExecutorSupervision:
    """Satellite 1: `_run_threads` used to swallow worker exceptions — a
    raising body returned partial results as if complete."""

    @pytest.mark.parametrize("policy", [P.dynamic(8), P.guided(4),
                                        P.stealing(4), P.ich()],
                             ids=["dynamic", "guided", "stealing", "ich"])
    def test_worker_exception_reraised_in_caller(self, policy):
        def boom(i):
            if i == 37:
                raise ZeroDivisionError("worker blew up")
        with pytest.raises(ZeroDivisionError, match="worker blew up"):
            E.parallel_for(200, boom, 4, policy, seed=1)

    def test_exception_aborts_siblings_promptly(self):
        """Survivors drain out via the abort event instead of spinning
        against the failed worker's nonempty deque (the old hang mode)."""
        ran = []
        lock = threading.Lock()

        def boom(i):
            if i == 0:
                raise RuntimeError("early")
            with lock:
                ran.append(i)
        with pytest.raises(RuntimeError):
            E.parallel_for(5000, boom, 4, P.ich(), seed=2)
        assert len(ran) < 5000

    def test_first_error_by_worker_id_wins(self):
        def boom(i):
            raise ValueError(f"item {i}")
        with pytest.raises(ValueError):
            E.parallel_for(100, boom, 4, P.dynamic(1), seed=0,
                           deterministic=True)


# ------------------------------------------------- executor: fault plans

def spmv_fixture(n=300, seed=0):
    """CSR SpMV closure over a shared output — the bit-identity workload:
    y[i] depends only on row i, so ANY exactly-once execution order must
    reproduce the sequential reference bit-for-bit."""
    indptr, indices, data = random_csr(n, seed=seed)
    x = np.random.default_rng(seed + 1).standard_normal(n).astype(np.float32)
    y_ref = np.zeros(n, np.float32)
    for i in range(n):
        y_ref[i] = data[indptr[i]:indptr[i + 1]] @ x[indices[indptr[i]:indptr[i + 1]]]
    y = np.zeros(n, np.float32)
    hits = np.zeros(n, np.int64)
    lock = threading.Lock()

    def body(i):
        v = data[indptr[i]:indptr[i + 1]] @ x[indices[indptr[i]:indptr[i + 1]]]
        with lock:
            y[i] = v
            hits[i] += 1
    return body, y, y_ref, hits


class TestExecutorFaultRecovery:
    def test_one_of_four_killed_bit_identical_spmv(self):
        """THE acceptance criterion: 1 of p=4 workers killed mid-run,
        threaded executor completes with SpMV output bit-identical to the
        sequential reference and every row computed exactly once."""
        body, y, y_ref, hits = spmv_fixture()
        plan = FaultPlan(seed=7, deaths=((2, 1),))
        stats = E.parallel_for(300, body, 4, P.ich(), seed=3, faults=plan)
        np.testing.assert_array_equal(y, y_ref)  # bit-identical
        assert (hits == 1).all()                 # exactly once
        assert stats.fault_log is not None

    def test_death_fires_and_reclaims_under_load(self):
        """With a body that takes real time, all four threads participate
        and the planned death actually triggers + its deque is drained."""
        import time
        n = 200
        hits = np.zeros(n, np.int64)
        lock = threading.Lock()

        def body(i):
            time.sleep(0.0003)
            with lock:
                hits[i] += 1
        plan = FaultPlan(seed=7, deaths=((2, 1),))
        stats = E.parallel_for(n, body, 4, P.ich(), seed=3, faults=plan)
        assert (hits == 1).all()
        assert stats.deaths == 1
        assert stats.reclaims >= 1

    def test_deterministic_chaos_replay_identical_traces(self):
        """Same plan replayed twice -> identical chunk/steal/fault traces
        (acceptance criterion, deterministic driver)."""
        plan = FaultPlan(seed=7, deaths=((2, 3),), stalls=((0, 2, 0.1),))
        runs = []
        for _ in range(2):
            st_ = E.parallel_for(400, lambda i: None, 4, P.ich(), seed=3,
                                 faults=plan, record_chunks=True,
                                 deterministic=True)
            runs.append(st_)
        strip = [[(b, e, w) for (b, e, w, _) in r.chunk_log] for r in runs]
        assert strip[0] == strip[1]
        assert runs[0].steal_log == runs[1].steal_log
        assert runs[0].fault_log == runs[1].fault_log
        assert runs[0].deaths == runs[1].deaths == 1

    def test_flaky_items_recovered_by_retry_budget(self):
        n = 300
        hits = np.zeros(n, np.int64)
        lock = threading.Lock()

        def body(i):
            with lock:
                hits[i] += 1
        plan = FaultPlan(seed=11, flaky_frac=0.1, flaky_failures=2)
        stats = E.parallel_for(n, body, 4, P.ich(), seed=3, faults=plan,
                               retries=2)
        assert (hits == 1).all()  # retries never duplicate a completed item
        assert stats.retries > 0
        assert stats.faults_recovered > 0
        assert stats.faults_observed >= stats.retries

    def test_flaky_without_retry_budget_raises(self):
        plan = FaultPlan(seed=11, flaky_frac=0.1)
        with pytest.raises(InjectedFault):
            E.parallel_for(300, lambda i: None, 4, P.ich(), faults=plan)

    def test_poison_propagates_through_retries(self):
        plan = FaultPlan(poison=(150,))
        with pytest.raises(InjectedFault, match="poisoned item 150"):
            E.parallel_for(300, lambda i: None, 4, P.ich(), faults=plan,
                           retries=5)

    def test_all_workers_dead_raises(self):
        plan = FaultPlan(deaths=tuple((w, 1) for w in range(4)))
        for det in (False, True):
            with pytest.raises(FaultError):
                E.parallel_for(400, lambda i: None, 4, P.ich(), seed=3,
                               faults=plan, deterministic=det)
        with pytest.raises(FaultError):
            E.parallel_for(400, lambda i: None, 4, P.dynamic(8),
                           faults=plan, deterministic=True)

    def test_central_policy_death_survivors_drain(self):
        body, y, y_ref, hits = spmv_fixture(seed=4)
        plan = FaultPlan(deaths=((0, 1),))
        stats = E.parallel_for(300, body, 4, P.dynamic(16), seed=3,
                               faults=plan, deterministic=True)
        np.testing.assert_array_equal(y, y_ref)
        assert (hits == 1).all()
        assert stats.deaths == 1

    def test_watchdog_reclaims_stalled_worker(self):
        """A worker that stalls past the heartbeat budget is declared dead
        by the watchdog; survivors drain its deque and the run completes
        exactly-once."""
        import time
        n = 200
        hits = np.zeros(n, np.int64)
        lock = threading.Lock()

        def body(i):
            time.sleep(0.0003)
            with lock:
                hits[i] += 1
        plan = FaultPlan(seed=5, stalls=((1, 0, 0.6),))
        stats = E.parallel_for(n, body, 4, P.ich(), seed=3, faults=plan,
                               watchdog_s=0.15)
        assert (hits == 1).all()
        assert stats.stall_events == 1
        assert stats.deaths == 1  # the watchdog kill
        assert any(ev[0] == "watchdog_kill" for ev in stats.fault_log)


# ------------------------------------------------------- Schedule facade

class TestScheduleFaultApi:
    def test_replay_faulty_deterministic_and_counted(self):
        sch = LoopScheduler(p=4, cache_size=0)
        s = sch.schedule(zipf_costs())
        plan = FaultPlan(seed=3, deaths=((1, 2),))
        a = s.replay_faulty(plan)
        b = s.replay_faulty(plan)
        assert a.faulty.deaths == 1 and a.faulty.reclaims >= 1
        assert a.faulty.makespan == b.faulty.makespan
        assert a.faulty.fault_log == b.faulty.fault_log
        assert a.clean.makespan == b.clean.makespan

    def test_parallel_for_faults_passthrough(self):
        sch = LoopScheduler(p=4, cache_size=0)
        s = sch.schedule(zipf_costs(200))
        hits = np.zeros(s.n_items, np.int64)
        lock = threading.Lock()

        def body(i):
            with lock:
                hits[i] += 1
        stats = s.parallel_for(body, faults=FaultPlan(seed=1,
                                                      deaths=((0, 1),)),
                               deterministic=True)
        assert (hits == 1).all()
        assert stats.deaths == 1

    def test_parallel_for_units_faults_passthrough(self):
        sch = LoopScheduler(p=4, cache_size=0)
        s = sch.schedule(zipf_costs(100))
        n_units = int(s.sizes.sum())
        hits = np.zeros(n_units, np.int64)
        lock = threading.Lock()

        def body(u):
            with lock:
                hits[u] += 1
        stats = s.parallel_for_units(body, faults=FaultPlan(
            seed=1, deaths=((2, 0),)), deterministic=True)
        assert (hits == 1).all()
        assert stats.deaths == 1


# ------------------------------------------------------ CI chaos smoke

# CI's chaos step widens this via CHAOS_SEEDS=0,1,2,... (ci.yml); a plain
# pytest run exercises one seed so the test stays cheap locally.
CHAOS_SEEDS = [int(s) for s in
               os.environ.get("CHAOS_SEEDS", "0").split(",")]


@pytest.mark.parametrize("seed", CHAOS_SEEDS)
def test_chaos_smoke_matrix(seed):
    """One full chaos scenario per seed — a death, a stall, and flaky
    items together — through BOTH layers: the executor must finish
    exactly-once with bit-identical SpMV output, the simulator must
    dispatch every item and replay deterministically."""
    plan = FaultPlan(seed=seed, deaths=((seed % 4, 1 + seed % 3),),
                     stalls=(((seed + 1) % 4, seed % 2, 10.0),),
                     flaky_frac=0.05)
    body, y, y_ref, hits = spmv_fixture(seed=seed)
    stats = E.parallel_for(300, body, 4, P.ich(), seed=seed, faults=plan,
                           retries=2, deterministic=True)
    np.testing.assert_array_equal(y, y_ref)
    assert (hits == 1).all()
    assert stats.deaths == 1 and stats.stall_events == 1

    costs = zipf_costs(seed=seed)
    sim_plan = FaultPlan(seed=seed, deaths=((seed % 4, 1 + seed % 3),),
                         stalls=(((seed + 1) % 4, seed % 2, 10.0),))
    a = S.simulate(costs, 4, P.ich(), faults=sim_plan,
                   record_assignment=True)
    b = S.simulate(costs, 4, P.ich(), faults=sim_plan)
    assert (a.assignment >= 0).all()
    assert a.makespan == b.makespan and a.fault_log == b.fault_log


# ------------------------------------------------ hypothesis properties

@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
class TestRecoveryProperties:
    """Satellite 3: recovery invariants over random workloads + plans."""

    @settings(max_examples=30, deadline=None)
    @given(n=st.integers(8, 300), p=st.integers(2, 6),
           victim=st.integers(0, 5), after=st.integers(0, 4),
           seed=st.integers(0, 2**16))
    def test_single_death_exactly_once(self, n, p, victim, after, seed):
        victim %= p
        plan = FaultPlan(seed=seed, deaths=((victim, after),))
        hits = np.zeros(n, np.int64)
        stats = E.parallel_for(n, lambda i: hits.__setitem__(
            i, hits[i] + 1), p, P.ich(), seed=seed, faults=plan,
            deterministic=True)
        assert (hits == 1).all()
        assert stats.chunks > 0

    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(8, 200), p=st.integers(2, 6),
           victim=st.integers(0, 5), after=st.integers(0, 4),
           seed=st.integers(0, 2**16))
    def test_simulator_fault_replay_deterministic(self, n, p, victim,
                                                  after, seed):
        victim %= p
        rng = np.random.default_rng(seed)
        costs = rng.uniform(0.5, 20.0, n)
        plan = FaultPlan(seed=seed, deaths=((victim, after),))
        a = S.simulate(costs, p, P.ich(), faults=plan,
                       record_assignment=True)
        b = S.simulate(costs, p, P.ich(), faults=plan,
                       record_assignment=True)
        assert a.makespan == b.makespan
        assert a.fault_log == b.fault_log
        np.testing.assert_array_equal(a.assignment, b.assignment)
        assert (a.assignment >= 0).all()


# ------------------------------------- fault-plan serialization (PR 9)

class TestFaultPlanSerialization:
    def test_roundtrip_and_fingerprint(self):
        plan = FaultPlan(seed=3, deaths=((1, 2),), stalls=((0, 4, 0.5),),
                         flaky_frac=0.1, flaky_failures=2, poison=(7,),
                         cost_noise=0.2)
        assert FaultPlan.from_json(plan.to_json()) == plan
        assert FaultPlan.from_json(json.loads(plan.to_json())) == plan
        assert plan.fingerprint() == FaultPlan.from_json(
            plan.to_json()).fingerprint()

    def test_fingerprint_sensitive_to_every_field(self):
        base = FaultPlan(seed=3, deaths=((1, 2),), stalls=((0, 4, 0.5),),
                         flaky_frac=0.1, flaky_failures=2, poison=(7,),
                         cost_noise=0.2)
        variants = [
            dataclasses.replace(base, seed=4),
            dataclasses.replace(base, deaths=((1, 3),)),
            dataclasses.replace(base, stalls=((0, 4, 0.6),)),
            dataclasses.replace(base, flaky_frac=0.2),
            dataclasses.replace(base, flaky_failures=3),
            dataclasses.replace(base, poison=(8,)),
            dataclasses.replace(base, cost_noise=0.3),
        ]
        fps = {v.fingerprint() for v in variants}
        assert len(fps) == len(variants)
        assert base.fingerprint() not in fps

    def test_invalid_serialized_plan_rejected(self):
        blob = FaultPlan(flaky_frac=0.1).to_json()
        bad = json.loads(blob)
        bad["flaky_frac"] = 1.5
        with pytest.raises(ValueError):
            FaultPlan.from_json(bad)


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
class TestFaultPlanJsonProperties:
    """Satellite (PR 9): to_json/from_json is the identity over the full
    plan space, and the fingerprint is a function of plan VALUE only."""

    plans = st.builds(
        FaultPlan,
        seed=st.integers(0, 2**31 - 1),
        deaths=st.lists(st.tuples(st.integers(0, 7), st.integers(0, 50)),
                        max_size=4).map(tuple),
        stalls=st.lists(st.tuples(st.integers(0, 7), st.integers(0, 50),
                                  st.floats(0.0, 10.0)),
                        max_size=4).map(tuple),
        flaky_frac=st.floats(0.0, 1.0),
        flaky_failures=st.integers(1, 5),
        poison=st.lists(st.integers(0, 1000), max_size=4).map(tuple),
        cost_noise=st.floats(0.0, 3.0),
    ) if HAVE_HYPOTHESIS else None

    @settings(max_examples=60, deadline=None)
    @given(plan=plans)
    def test_json_roundtrip_identity(self, plan):
        back = FaultPlan.from_json(plan.to_json())
        assert back == plan
        assert back.to_json() == plan.to_json()
        assert back.fingerprint() == plan.fingerprint()

    @settings(max_examples=30, deadline=None)
    @given(plan=plans, seed2=st.integers(0, 2**31 - 1))
    def test_fingerprint_is_value_identity(self, plan, seed2):
        same = FaultPlan.from_json(json.loads(plan.to_json()))
        assert same.fingerprint() == plan.fingerprint()
        other = dataclasses.replace(plan, seed=seed2)
        assert (other.fingerprint() == plan.fingerprint()) == \
            (other == plan)


# --------------------------------------- injectable backoff sleep (PR 9)

class TestSleepFnHook:
    def test_retry_backoff_routed_through_sleep_fn(self):
        """A flaky run with a real backoff costs zero wall-clock when
        `sleep_fn` is injected, and the recorded delays follow the
        bounded-exponential contract."""
        n = 300
        hits = np.zeros(n, np.int64)
        lock = threading.Lock()

        def body(i):
            with lock:
                hits[i] += 1
        sleeps = []
        plan = FaultPlan(seed=11, flaky_frac=0.1, flaky_failures=2)
        t0 = time.monotonic()
        stats = E.parallel_for(n, body, 4, P.ich(), seed=3, faults=plan,
                               retries=2, retry_backoff_s=0.5,
                               sleep_fn=sleeps.append)
        assert time.monotonic() - t0 < 2.0   # nobody actually slept
        assert (hits == 1).all()
        assert stats.retries > 0
        assert len(sleeps) == stats.retries
        assert all(0.0 < s <= E.RETRY_BACKOFF_CAP_S for s in sleeps)

    def test_injected_stalls_routed_through_sleep_fn(self):
        sleeps = []
        plan = FaultPlan(stalls=((0, 2, 5.0),))
        t0 = time.monotonic()
        E.parallel_for(200, lambda i: None, 2, P.ich(), seed=0,
                       faults=plan, sleep_fn=sleeps.append)
        assert time.monotonic() - t0 < 2.0
        assert 5.0 in sleeps
