"""Paper-conformance suite: the headline claims of "An Adaptive
Self-Scheduling Loop Scheduler" asserted against the discrete-event
simulator over the paper's workload families (§5.1, Table 2, Figs. 4-6).

The claims (abstract / §6):

* iCh is ALWAYS one of the top-3 loop-scheduling methods, on every
  application family;
* on average across applications iCh lands within ~5.4% of the best
  (tuned) method.

Two scales run here:

* the SMOKE grid — reduced n, part of tier-1 on every push. One
  reduced-scale adaptation (documented in tests/_paper_grid.py) keeps the
  smoke grid faithful to paper *conditions* instead of reduction
  *artifacts*: scale-free BFS is evaluated at p=8 because the
  clipped-zipf generator at 3k vertices concentrates a paper-impossible
  share of all edges on a few single iterations, which no stealing-based
  method can split (the paper's graphs have 1M+ vertices).
* the FULL grid — paper-scale n behind the `paper` marker and
  PAPER_SUITE=1 (a non-blocking CI job): same assertions at full size,
  written to the CSV digest (results/paper_conformance.csv).

Both grids assert all ten Table-1 SpMV matrices, extreme-hub entries
included. Those five used to be reported-but-not-asserted because naive
stat-matching of a ~1e6 max/min-degree ratio into 1e4 rows planted one
contiguous hub block holding ~30-45% of all work — single items and
runs worth multiple thread-shares that exist in no real matrix. The
per-item (HUB_DEG_CAP) and per-run (HUB_RUN_SHARE) caps in
`workloads.matrix_row_nnz` split synthesized hubs across rows and runs,
preserving total nnz mass, so the families are asserted like any other.

The average-gap tolerance is 10% (paper: 5.4% measured on a real 28-thread
Xeon; the simulator's overhead model is calibrated, not fitted, so we
allow roughly double).
"""
import os
from pathlib import Path

import pytest

import _paper_grid as G

AVG_GAP_TOL = 0.10
TOP = 3

_smoke_results = {}


def _results(scale):
    # one evaluation per session, shared across the per-family asserts
    key = id(scale)
    if key not in _smoke_results:
        _smoke_results[key] = G.evaluate(G.families(scale))
    return _smoke_results[key]


# --------------------------------------------------------------- smoke grid
@pytest.mark.parametrize("family", sorted(G.families(G.SMOKE)))
def test_ich_top3_on_every_family_smoke(family):
    r = _results(G.SMOKE)[family]
    assert r["rank"] <= TOP, (
        f"iCh ranked {r['rank']} on {family} at p={r['p']} "
        f"(claim: always top-3); table={r['table']}")


def test_ich_average_gap_to_best_smoke():
    results = _results(G.SMOKE)
    gaps = {name: r["gap"] for name, r in results.items()}
    avg = sum(gaps.values()) / len(gaps)
    assert avg <= AVG_GAP_TOL, (
        f"average gap to best {avg:.1%} exceeds {AVG_GAP_TOL:.0%} "
        f"(paper: 5.4%); per-family: { {k: f'{v:.1%}' for k, v in gaps.items()} }")


def test_ich_beats_static_and_dynamic_on_moe_dispatch_smoke():
    """DESIGN.md §2.8: scheduled expert dispatch must pay off against the
    two baselines a MoE layer would otherwise use — a static
    expert->worker partition (fixed capacity layout, blind to router
    skew) and plain dynamic self-scheduling — at every router-skew level
    in the grid. This is the in-model claim of the dispatch bridge: the
    tests/test_moe_sched.py suite proves the kernel dispatches the plan
    faithfully; this asserts the plan is worth dispatching."""
    fams = G.families(G.SMOKE)
    results = _results(G.SMOKE)
    for alpha in G.MOE_ALPHAS:
        name = f"moe-dispatch/zipf{alpha:g}"
        loops, ests, p = fams[name]
        static = G.static_speedup(loops, p, ests)
        table = results[name]["table"]
        assert table["ich"] > static, (
            f"iCh {table['ich']:.3f} must beat static capacity "
            f"{static:.3f} on {name}")
        assert table["ich"] >= table["dynamic"] * (1 - G.TIE_TOL), (
            f"iCh {table['ich']:.3f} must beat or tie dynamic "
            f"{table['dynamic']:.3f} on {name}")


def test_ich_beats_or_ties_other_methods_where_paper_says_so_smoke():
    """§6: iCh outperforms the other methods on BFS and K-Means — at our
    scale, assert it is at worst a statistical tie (top-2) there."""
    results = _results(G.SMOKE)
    for family in ("bfs/uniform", "kmeans"):
        r = results[family]
        assert r["rank"] <= 2, (
            f"paper claims iCh wins {family}; got rank {r['rank']} "
            f"({r['table']})")


# ---------------------------------------------------------------- full grid
needs_paper = pytest.mark.skipif(
    not os.environ.get("PAPER_SUITE"),
    reason="full paper-scale conformance grid; set PAPER_SUITE=1")


@pytest.mark.paper
@needs_paper
def test_paper_claims_full_grid_and_digest():
    results = G.evaluate(G.families(G.PAPER))
    # every family — extreme-hub SpMV included — is asserted
    asserted = set(results)
    out = Path(__file__).resolve().parent.parent / "results"
    out.mkdir(exist_ok=True)
    rows = G.digest_rows(results, asserted)
    (out / "paper_conformance.csv").write_text(
        "family,p,method_or_metric,value,...\n" + "\n".join(rows) + "\n")
    failures = []
    for name in asserted:
        if results[name]["rank"] > TOP:
            failures.append(f"{name}: rank {results[name]['rank']}")
    avg = sum(results[n]["gap"] for n in asserted) / len(asserted)
    if avg > AVG_GAP_TOL:
        failures.append(f"avg gap {avg:.1%} > {AVG_GAP_TOL:.0%}")
    assert not failures, "; ".join(failures)
