"""Empty-workload schedules (ISSUE 10 satellite): a zero-item sizes
array yields a valid 0-tile `TileSchedule` that replays, executes,
shards, packs, and lowers as a no-op instead of raising.

A registered workload can legitimately hit this: an exhausted BFS
frontier, a moe-dispatch step with zero admitted tokens, a drained
serving queue. Every layer the facade exposes must degenerate cleanly.
"""
import numpy as np
import pytest

import repro.core.tiling as T
import repro.sched as S

EMPTY_I = np.array([], dtype=np.int64)
EMPTY_F = np.array([], dtype=np.float64)


class TestBuild:
    def test_build_schedule_empty_is_zero_tiles(self):
        ts = T.build_schedule(EMPTY_I)
        assert ts.n_tiles == 0 and ts.n_items == 0
        assert ts.item_id.shape == (0, ts.rows_per_tile)
        assert ts.seg_start.shape == ts.item_id.shape
        assert ts.seg_len.shape == ts.item_id.shape
        assert ts.width >= 1

    def test_reference_oracle_agrees(self):
        ts = T.build_schedule(EMPTY_I)
        ref = T._reference_build_schedule(EMPTY_I)
        assert ts.width == ref.width and ts.n_tiles == ref.n_tiles
        np.testing.assert_array_equal(ts.item_id, ref.item_id)

    def test_explicit_width_respected(self):
        assert T.build_schedule(EMPTY_I, width=32).width == 32

    def test_ich_tile_width_empty_is_band_floor(self):
        w = T.ich_tile_width(EMPTY_I)
        assert w == T.ich_tile_width(np.array([1]))  # mu<=1 clamps alike

    def test_pack_csr_empty(self):
        ts = T.build_schedule(EMPTY_I)
        vals, cols = T.pack_csr(np.zeros(1, np.int64), EMPTY_I.astype(np.int32),
                                EMPTY_F.astype(np.float32), ts)
        assert vals.shape == (0, ts.rows_per_tile, ts.width)
        assert cols.shape == vals.shape


class TestFacadeRoundTrip:
    @pytest.fixture()
    def empty_schedule(self):
        return S.LoopScheduler(p=4).schedule(EMPTY_F)

    def test_simulator_replay_is_noop(self, empty_schedule):
        r = empty_schedule.replay()
        assert r.makespan == 0.0 and r.chunks == 0

    def test_sharded_replay_is_noop(self, empty_schedule):
        r = empty_schedule.replay_sharded(p=4)
        assert r.makespan == 0.0
        np.testing.assert_array_equal(r.worker_busy, np.zeros(4))

    def test_executor_dispatches_nothing(self, empty_schedule):
        hits = []
        empty_schedule.parallel_for(lambda lo, hi: hits.append((lo, hi)), p=2)
        assert hits == []

    def test_shard_layout_all_padding(self, empty_schedule):
        sh = empty_schedule.shard(p=4)
        assert sh.worker.shape == (0,)
        assert (sh.block_perm == -1).all()
        # prefetch streams stay well-shaped for the kernels
        assert (sh.kernel_block_ids() == 0).all()
        assert (sh.shard_item_id(empty_schedule.tiles) == -1).all()

    def test_refine_round_trip(self, empty_schedule):
        nxt = empty_schedule.refine()
        assert nxt.generation == empty_schedule.generation + 1
        assert nxt.n_tiles == 0


class TestOpsLowerAsNoop:
    def test_spmv(self):
        sched = S.LoopScheduler(p=4)
        op = sched.build("spmv", np.zeros(1, np.int64),
                         np.zeros(0, np.int32), np.zeros(0, np.float32))
        y = np.asarray(op(np.ones(5, np.float32)))
        assert y.shape == (0,)
        # observe/refine still round-trips on the all-zero cost stream
        assert op.observe().refine().n_tiles == 0

    def test_bfs_step(self):
        sched = S.LoopScheduler(p=2)
        op = sched.build("bfs", np.zeros(1, np.int64), np.zeros(0, np.int32))
        nxt = np.asarray(op.step(np.zeros(0, np.float32),
                                 np.zeros(0, np.float32)))
        assert nxt.shape == (0,)

    def test_kmeans(self):
        sched = S.LoopScheduler(p=2)
        op = sched.build("kmeans", np.zeros(0, np.float64))
        a = np.asarray(op(np.zeros((0, 3), np.float32),
                          np.zeros((2, 3), np.float32)))
        assert a.shape == (0,) and a.dtype == np.int32

    def test_moe_zero_admitted_tokens(self):
        from repro.sched.moe import plan_dispatch
        plan = plan_dispatch(np.zeros((0, 2), np.int64),
                             np.zeros((0, 2), np.float32))
        sched = S.LoopScheduler(p=4)
        op = sched.build("moe-dispatch", plan)
        E = plan.n_experts
        y = np.asarray(op(np.zeros((0, 8), np.float32),
                          np.zeros((E, 8, 16), np.float32),
                          np.zeros((E, 8, 16), np.float32),
                          np.zeros((E, 16, 8), np.float32)))
        assert y.shape == (0, 8)
        np.testing.assert_array_equal(op.expert_load(), np.zeros(E))
