"""Import hypothesis if available; otherwise degrade property-based tests to
skips instead of erroring the whole module at collection.

`requirements.txt` / `pyproject.toml[test]` declare hypothesis, so dev
installs and CI get the real thing; hermetic containers without it still run
every plain pytest test in the suite.
"""
try:  # pragma: no cover - exercised one way or the other per environment
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _Strategies:
        """Stand-in for `hypothesis.strategies`: strategy objects are only
        inspected by @given, and our @given stub skips the test first."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies()

    def settings(*args, **kwargs):
        return lambda f: f

    def given(*args, **kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")
