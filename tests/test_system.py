"""End-to-end system tests: trainer (checkpoint/restart/failure), serving
engine (iCh chunked prefill), MoE balancer, optimizer, gradient compression,
data pipeline, cost model, and HLO collective parsing."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_arch, reduced
from repro.core import welford as W
from repro.data.pipeline import IChDataDispatcher, synthetic_tokens
from repro.launch import hlo_stats
from repro.launch.costmodel import MeshShape, cell_cost
from repro.models import model as M
from repro.models import moe as MOE
from repro.optim import adamw
from repro.optim import grad_compress as GC
from repro.serve.engine import Engine, EngineConfig
from repro.train import checkpoint as CKPT
from repro.train import train_step as TS
from repro.train.trainer import InjectedFailure, RunConfig, train


# ------------------------------------------------------------------ trainer
def test_trainer_checkpoint_restart_and_loss_decreases(tmp_path):
    cfg = reduced(get_arch("olmo-1b"))
    run = RunConfig(steps=14, batch=4, seq=32, ckpt_dir=str(tmp_path),
                    ckpt_every=5, failure_at=7, log_every=100)
    with pytest.raises(InjectedFailure):
        train(cfg, run, verbose=False)
    assert CKPT.list_steps(str(tmp_path)) == [5]
    state, losses = train(cfg, dataclasses.replace(run, failure_at=None),
                          verbose=False)
    assert len(losses) == 9  # resumed from step 5
    full_run = RunConfig(steps=14, batch=4, seq=32,
                         ckpt_dir=str(tmp_path / "fresh"), log_every=100)
    _, fresh_losses = train(cfg, full_run, verbose=False)
    assert fresh_losses[-1] < fresh_losses[0]  # learning happens


def test_trainer_moe_cap_scales_update(tmp_path):
    cfg = reduced(get_arch("olmoe-1b-7b"))
    run = RunConfig(steps=3, batch=4, seq=32, ckpt_dir=str(tmp_path),
                    ckpt_every=100, log_every=100)
    state, _ = train(cfg, run, verbose=False)
    assert state["cap_scales"].shape == (cfg.n_layers, cfg.n_experts)
    assert bool(jnp.isfinite(state["cap_scales"]).all())


def test_checkpoint_is_mesh_agnostic(tmp_path):
    cfg = reduced(get_arch("olmo-1b"))
    tcfg = TS.TrainConfig()
    state = TS.init_train_state(cfg, jax.random.PRNGKey(0), 32, tcfg)
    CKPT.save_state(state, str(tmp_path), 7)
    like = TS.init_train_state(cfg, jax.random.PRNGKey(1), 32, tcfg)
    loaded, step = CKPT.load_state(like, str(tmp_path))
    assert step == 7
    for a, b in zip(jax.tree.leaves(loaded), jax.tree.leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_bf16_master_training_state():
    cfg = reduced(get_arch("olmo-1b"))
    tcfg = TS.TrainConfig(bf16_params=True)
    state = TS.init_train_state(cfg, jax.random.PRNGKey(0), 32, tcfg)
    assert all(l.dtype == jnp.bfloat16 for l in jax.tree.leaves(state["params"]))
    assert all(l.dtype == jnp.float32
               for l in jax.tree.leaves(state["opt"]["master"]))
    step = TS.make_train_step(cfg, tcfg)
    batch = {k: jnp.asarray(v) for k, v in
             synthetic_tokens(4, 32, cfg.padded_vocab, 0).items()}
    state2, metrics = jax.jit(step)(state, batch)
    assert jnp.isfinite(metrics["loss"])
    # params stayed bf16 and actually moved
    assert all(l.dtype == jnp.bfloat16 for l in jax.tree.leaves(state2["params"]))


# ------------------------------------------------------------------ serving
def test_engine_generates_and_adapts():
    cfg = reduced(get_arch("olmo-1b"))
    params = M.init_params(cfg, jax.random.PRNGKey(0), max_seq=256)
    eng = Engine(cfg, params, EngineConfig(max_seq=160, min_chunk=8,
                                           init_divisor=4.0))
    prompts = np.random.default_rng(0).integers(1, 400, (2, 64)).astype(np.int32)
    out, stats = eng.generate(prompts, n_new=4)
    assert out.shape == (2, 4)
    assert len(stats["chunks"]) >= 2  # chunked prefill happened
    # every chunk respects min_chunk except the final remainder
    assert all(e["chunk"] >= 8 for e in stats["chunks"][:-1])
    assert sum(e["chunk"] for e in stats["chunks"]) == 64


# ---------------------------------------------------------------- MoE / iCh
def test_moe_steal_reduces_drops_under_skew():
    cfg = reduced(get_arch("olmoe-1b-7b"))
    p = MOE.init_moe(jax.random.PRNGKey(0), cfg)
    p["router"] = p["router"].at[:, 0].add(3.0)
    x = jax.random.normal(jax.random.PRNGKey(1), (256, cfg.d_model))
    cap = jnp.ones((cfg.n_experts,))
    _, a_ns = MOE.moe_local(cfg, p, x, cap, steal=False, capacity_factor=1.0)
    _, a_st = MOE.moe_local(cfg, p, x, cap, steal=True, capacity_factor=1.0)
    assert float(a_st["dropped"]) <= float(a_ns["dropped"])


def test_ich_cap_scale_conserves_budget_and_bounds():
    counts = jnp.asarray(np.random.default_rng(0).exponential(100, 64))
    cap = jnp.ones((64,))
    for _ in range(10):
        cap = MOE.ich_update_cap_scale(counts, cap)
    assert float(cap.sum()) <= 64.0 + 1e-3
    assert float(cap.min()) >= 0.25 and float(cap.max()) <= 2.0


# ------------------------------------------------------------------- optim
def test_adamw_converges_on_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0])}
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                            total_steps=200)
    state = adamw.init_state(params)
    for _ in range(150):
        g = {"w": 2 * params["w"]}
        params, state, _ = adamw.apply_updates(params, g, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_grad_compression_error_feedback_is_unbiased():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(2000) * 0.01)
    err = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    for _ in range(50):
        cg, err = GC.compress_with_feedback(g, err)
        acc = acc + cg
    # accumulated compressed grads track accumulated true grads
    np.testing.assert_allclose(acc / 50, g, atol=2e-4)


# ------------------------------------------------------------------- data
def test_ich_data_dispatcher_exactly_once():
    hits = np.zeros(500, np.int64)
    import threading
    lock = threading.Lock()

    def read(i):
        with lock:
            hits[i] += 1

    stats = IChDataDispatcher(n_hosts=4).ingest(500, read)
    assert (hits == 1).all()
    assert stats.chunks > 4


# --------------------------------------------------------------- cost model
def test_costmodel_terms_positive_and_levers_act():
    cfg = get_arch("olmoe-1b-7b")
    shape = SHAPES["train_4k"]
    base = cell_cost(cfg, shape, MeshShape())
    assert all(v > 0 for v in base.terms().values())
    opt = cell_cost(dataclasses.replace(cfg, moe_cmax_factor=1.25), shape,
                    MeshShape(), bf16_gather=True, causal_skip=True)
    assert opt.flops < base.flops
    assert opt.wire_bytes < base.wire_bytes
    # decode serve-opt removes the FSDP gathers
    d = SHAPES["decode_32k"]
    db = cell_cost(get_arch("phi3-medium-14b"), d, MeshShape())
    do = cell_cost(get_arch("phi3-medium-14b"), d, MeshShape(), decode_fsdp=False)
    assert do.wire_bytes < db.wire_bytes / 100


def test_hlo_collective_parser():
    txt = """
  %ag = bf16[16,1024]{1,0} all-gather(%p0), replica_groups={{0,1,2,3}}, dimensions={0}
  %ar = f32[512]{0} all-reduce(%p1), replica_groups=[4,2]<=[8]
  %rs = f32[128]{0} reduce-scatter(%p2), replica_groups={{0,1}}, dimensions={0}
"""
    st = hlo_stats.parse_collectives(txt)
    assert st.by_kind["all-gather"][0] == 1
    assert st.by_kind["all-gather"][1] == 16 * 1024 * 2
    assert st.by_kind["all-gather"][2] == 16 * 1024 * 2 / 4  # operand
    assert st.by_kind["all-reduce"][1] == 512 * 4
    assert st.by_kind["reduce-scatter"][2] == 128 * 4 * 2


# ------------------------------------------------------------- welford/iCh
def test_welford_band_monotone_in_eps():
    ks = np.asarray([5.0, 10.0, 20.0, 40.0])
    _, d1 = W.ich_band(ks, 0.25)
    _, d2 = W.ich_band(ks, 0.50)
    assert d2 > d1


# ---------------------------------------------------------------- dry-run
def test_dryrun_cell_subprocess(tmp_path):
    """One real dry-run cell end-to-end in a fresh process (the 512-device
    XLA flag must be set before jax import, so this cannot run in-process)."""
    import json
    import pathlib
    import subprocess
    import sys

    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "olmo-1b",
         "--shape", "decode_32k", "--out", str(tmp_path)],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"},
        cwd=str(pathlib.Path(__file__).resolve().parents[1]))
    assert r.returncode == 0, r.stdout + r.stderr
    rec = json.loads((tmp_path / "olmo-1b_decode_32k_16x16.json").read_text())
    assert rec["status"] == "OK"
    assert rec["cost"]["flops"] > 0
    assert rec["memory"]["temp_bytes"] > 0
