"""Tests for the worker-sharded kernel execution layer (DESIGN.md §2.6):
cost-balanced block-granular tile partitioning, the (p, S_B) zero-copy
shard layout, superstep-padded CSR packing, the simulator cross-check
(`policies.assigned` / `Schedule.replay_sharded`), and bit-identity of the
2D sharded kernels against the sequential reference grids for all three
workloads."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st
from conftest import random_csr as _random_csr

from repro.core import policies as P
from repro.core import tiling as T
from repro.core.simulator import SimParams
from repro.sched.api import LoopScheduler

_NO_OVERHEAD = SimParams(dispatch_overhead=0.0, local_dispatch_overhead=0.0,
                         speed_jitter=0.0)

_SIZES = st.lists(st.one_of(st.just(0), st.integers(0, 40),
                            st.integers(200, 3000)),
                  min_size=1, max_size=120)


# ------------------------------------------------------------ partitioning
@settings(max_examples=30, deadline=None)
@given(sizes=_SIZES, R=st.integers(1, 17), p=st.integers(1, 9),
       B=st.integers(1, 8))
def test_partition_item_closed_and_layout_valid(sizes, R, p, B):
    """Every tile is assigned exactly one worker, the assignment is
    constant within each superstep block, no item's tiles span two
    workers, and the (p, S_B) block layout lists each worker's blocks
    exactly once in ascending order."""
    sizes = np.asarray(sizes, np.int64)
    sched = T.build_schedule(sizes, rows_per_tile=R)
    costs = 1.0 + sizes.astype(np.float64)
    tc = sched.tile_cost(costs, sizes)
    worker = T.partition_tiles(tc, sched.item_id, p, block=B)
    assert worker.shape == (sched.n_tiles,)
    assert worker.min() >= 0 and worker.max() < p
    # constant within each B-tile block
    np.testing.assert_array_equal(
        worker, np.repeat(worker[::B], B)[:sched.n_tiles])
    # item-closed: the tiles holding any one item sit on one worker
    for item in range(len(sizes)):
        tiles = np.nonzero((sched.item_id == item).any(axis=1))[0]
        assert len(np.unique(worker[tiles])) == 1
    shards = T.make_shards(worker, p, superstep=B)
    assert shards.p == p and shards.superstep == B
    assert shards.tiles_per_worker == shards.n_steps * B
    n_blocks = -(-sched.n_tiles // B)
    bp = shards.block_perm
    np.testing.assert_array_equal(np.sort(bp[bp >= 0]), np.arange(n_blocks))
    perm = shards.perm
    np.testing.assert_array_equal(np.sort(perm[perm >= 0]),
                                  np.arange(sched.n_tiles))
    assert shards.n_tiles_padded % B == 0
    assert shards.n_tiles_padded >= sched.n_tiles
    for w in range(p):
        row = perm[w][perm[w] >= 0]
        assert (np.diff(row) > 0).all()  # ascending global tile order
        np.testing.assert_array_equal(row, np.nonzero(worker == w)[0])


@settings(max_examples=25, deadline=None)
@given(sizes=_SIZES, R=st.integers(1, 17), p=st.integers(1, 9))
def test_lpt_partition_matches_simulator_per_worker_work(sizes, R, p):
    """The LPT partition's per-worker cost — and its max, the predicted
    sharded makespan — must match a zero-overhead simulator replay that
    dispatches every tile on its assigned worker."""
    sizes = np.asarray(sizes, np.int64)
    if int(sizes.sum()) == 0:
        return  # no work units: nothing for the simulator to dispatch
    costs = 1.0 + sizes.astype(np.float64)
    scheduler = LoopScheduler(p=p, cache_size=0)
    s = scheduler.schedule(np.asarray(costs), rows_per_tile=R)
    shards = s.shard()
    wc = shards.worker_cost(s.tile_cost())
    assert wc.shape == (p,)
    np.testing.assert_allclose(wc.sum(), s.tile_cost().sum(), atol=1e-9)
    rep = s.replay_sharded(params=_NO_OVERHEAD)
    # every tile dispatched on its assigned worker with its predicted work
    assert rep.chunks == s.n_tiles
    sim_wc = np.zeros(p)
    for (b, e, w, work) in rep.chunk_log:
        assert shards.worker[np.searchsorted(
            s.unit_ranges()[:, 1], b, side="right")] == w
        sim_wc[w] += work
    np.testing.assert_allclose(sim_wc, wc, atol=1e-9)
    np.testing.assert_allclose(rep.makespan, wc.max(), atol=1e-9)


@pytest.mark.parametrize("n,p,R", [(60, 1, 8), (250, 3, 8), (400, 8, 4)])
def test_replay_sharded_per_worker_work_deterministic(n, p, R):
    """Deterministic twin of the hypothesis cross-check above: per-worker
    dispatched work equals the partition's worker_cost and the
    zero-overhead makespan equals its max."""
    rng = np.random.default_rng(n + p)
    costs = rng.uniform(0.5, 5.0, n)
    costs[rng.choice(n, 5, replace=False)] += rng.exponential(60.0, 5)
    s = LoopScheduler(p=p, cache_size=0).schedule(costs, rows_per_tile=R)
    shards = s.shard()
    wc = shards.worker_cost(s.tile_cost())
    rep = s.replay_sharded(params=_NO_OVERHEAD)
    assert rep.chunks == s.n_tiles
    sim_wc = np.zeros(p)
    for (b, e, w, work) in rep.chunk_log:
        sim_wc[w] += work
    np.testing.assert_allclose(sim_wc, wc, atol=1e-9)
    np.testing.assert_allclose(rep.makespan, wc.max(), atol=1e-9)
    # and the assignment covers exactly the tile ranges per worker
    ranges = s.unit_ranges()
    log = np.array([(b, e) for (b, e, _, _) in rep.chunk_log])
    np.testing.assert_array_equal(log, ranges)


def test_partition_lpt_balances_heavy_tail():
    """A zipf-heavy 2000-item workload must spread within a few percent of
    perfectly even across 8 workers (block-chains are fine-grained
    there)."""
    rng = np.random.default_rng(3)
    sizes = np.minimum(rng.zipf(1.8, 2000), 500).astype(np.int64)
    sizes[rng.random(2000) < 0.1] = 0
    sched = T.build_schedule(sizes, rows_per_tile=8)
    costs = 1.0 + sizes.astype(np.float64)
    tc = sched.tile_cost(costs, sizes)
    shards = T.shard_schedule(sched, tc, 8)
    wc = shards.worker_cost(tc)
    assert wc.max() <= 1.15 * wc.mean()


def test_make_shards_rejects_block_misaligned_worker_map():
    # superstep blocks must be whole: a worker map that flips mid-block
    # was partitioned at the wrong granularity
    with pytest.raises(ValueError, match="not constant within superstep"):
        T.make_shards(np.array([0, 1, 0, 1], np.int32), 2, superstep=2)
    # out-of-range worker ids (map built for a different p) fail loudly
    with pytest.raises(ValueError, match=r"lie in \[0, 2\)"):
        T.make_shards(np.array([0, 5], np.int32), 2, superstep=1)


def test_assigned_policy_validates_inputs():
    with pytest.raises(ValueError, match="worker assignments"):
        P.assigned([(0, 5), (5, 9)], [0])
    with pytest.raises(ValueError, match="must be >= 0"):
        P.assigned([(0, 5), (5, 9)], [0, -1])
    from repro.core.simulator import simulate
    with pytest.raises(ValueError, match=r"outside \[0, 2\)"):
        simulate(np.ones(9), 2, P.assigned([(0, 5), (5, 9)], [0, 4]))


# ----------------------------------------------------- superstep-padded pack
@settings(max_examples=20, deadline=None)
@given(sizes=_SIZES, R=st.integers(1, 17),
       W=st.one_of(st.none(), st.integers(1, 600)), B=st.integers(1, 8),
       seed=st.integers(0, 99))
def test_pack_csr_pad_tiles_matches_reference(sizes, R, W, B, seed):
    """pack_csr(pad_tiles_to=B) — the payload the sharded kernels fetch
    blocks from — must equal the loop reference oracle on the real tiles
    and be all-zero on the pad tiles."""
    sizes = np.asarray(sizes, np.int64)
    sched = T.build_schedule(sizes, rows_per_tile=R, width=W)
    rng = np.random.default_rng(seed)
    indptr = np.concatenate([[0], np.cumsum(sizes)])
    nnz = int(indptr[-1])
    indices = rng.integers(0, sizes.size, nnz).astype(np.int32)
    data = rng.standard_normal(nnz).astype(np.float32)
    vp, cp = T.pack_csr(indptr, indices, data, sched, pad_tiles_to=B)
    Tn = sched.n_tiles
    T_pad = -(-Tn // B) * B
    assert vp.shape == (T_pad, R, sched.width)
    vr, cr = T._reference_pack_csr(indptr, indices, data, sched)
    np.testing.assert_array_equal(vp[:Tn], vr)
    np.testing.assert_array_equal(cp[:Tn], cr)
    assert (vp[Tn:] == 0).all() and (cp[Tn:] == 0).all()


def test_pack_csr_gather_fallback_matches_reference():
    """Nonzero indptr[0] (CSR slice views) breaks the sequential-stream
    precondition; pack_csr must detect it and still match the oracle."""
    rng = np.random.default_rng(11)
    sizes = np.minimum(rng.zipf(1.7, 150), 300).astype(np.int64)
    sched = T.build_schedule(sizes, rows_per_tile=8)
    indptr = np.concatenate([[0], np.cumsum(sizes)]) + 7
    nnz = int(indptr[-1])
    indices = rng.integers(0, 150, nnz).astype(np.int32)
    data = rng.standard_normal(nnz).astype(np.float32)
    vr, cr = T._reference_pack_csr(indptr, indices, data, sched)
    for B in (1, 8):
        v, c = T.pack_csr(indptr, indices, data, sched, pad_tiles_to=B)
        Tn = sched.n_tiles
        np.testing.assert_array_equal(v[:Tn], vr)
        np.testing.assert_array_equal(c[:Tn], cr)
        assert (v[Tn:] == 0).all() and (c[Tn:] == 0).all()


# ------------------------------------------- sharded kernel bit-identity
def _shard_args(s, B):
    shards = s.shard(superstep=B)
    return (shards, shards.shard_item_id(s.tiles),
            shards.kernel_block_ids())


@pytest.mark.parametrize("p", [1, 2, 4])
def test_sharded_spmv_bit_identical_to_sequential_grid(p):
    import jax.numpy as jnp
    from repro.kernels.ich_spmv.ich_spmv import ich_spmv, ich_spmv_sharded

    rng = np.random.default_rng(p)
    n = 180
    indptr, indices, data = _random_csr(n, seed=p)
    x = rng.standard_normal(n).astype(np.float32)
    scheduler = LoopScheduler(p=p, cache_size=0)
    s = scheduler.schedule(np.diff(indptr))
    vals, cols = T.pack_csr(indptr, indices, data, s.tiles)
    y_seq = np.asarray(ich_spmv(jnp.asarray(vals), jnp.asarray(cols),
                                jnp.asarray(s.item_id), jnp.asarray(x), n,
                                interpret=True))
    for B in (1, 4, 8):
        shards, rid, blk = _shard_args(s, B)
        vp, cp = T.pack_csr(indptr, indices, data, s.tiles, pad_tiles_to=B)
        y_sh = np.asarray(ich_spmv_sharded(
            jnp.asarray(vp), jnp.asarray(cp), jnp.asarray(rid),
            jnp.asarray(blk), jnp.asarray(x), n, p, B, interpret=True))
        np.testing.assert_array_equal(y_sh, y_seq)  # bitwise, fp add order


@pytest.mark.parametrize("p", [1, 2, 4])
def test_sharded_bfs_bit_identical_to_sequential_grid(p):
    import jax.numpy as jnp
    from repro.kernels.ich_bfs.ich_bfs import (ich_bfs_step,
                                               ich_bfs_step_sharded)

    rng = np.random.default_rng(20 + p)
    n = 160
    indptr, indices, _ = _random_csr(n, seed=20 + p)
    scheduler = LoopScheduler(p=p, cache_size=0)
    s = scheduler.schedule(np.diff(indptr))
    ones = np.ones(int(indptr[-1]), np.float32)
    mask, cols = T.pack_csr(indptr, indices, ones, s.tiles)
    frontier = (rng.random(n) < 0.08).astype(np.float32)
    visited = frontier.copy()
    nxt_seq = np.asarray(ich_bfs_step(
        jnp.asarray(mask), jnp.asarray(cols), jnp.asarray(s.item_id),
        jnp.asarray(frontier), jnp.asarray(visited), n, interpret=True))
    for B in (1, 4, 8):
        shards, rid, blk = _shard_args(s, B)
        mp, cp = T.pack_csr(indptr, indices, ones, s.tiles, pad_tiles_to=B)
        nxt_sh = np.asarray(ich_bfs_step_sharded(
            jnp.asarray(mp), jnp.asarray(cp), jnp.asarray(rid),
            jnp.asarray(blk), jnp.asarray(frontier), jnp.asarray(visited),
            n, p, B, interpret=True))
        np.testing.assert_array_equal(nxt_sh, nxt_seq)


@pytest.mark.parametrize("p", [1, 2, 4])
def test_sharded_kmeans_bit_identical_to_sequential_grid(p):
    import jax.numpy as jnp
    from repro.kernels.ich_kmeans.ich_kmeans import (
        ich_kmeans_assign, ich_kmeans_assign_sharded)

    rng = np.random.default_rng(40 + p)
    n = 150
    costs = rng.uniform(1.0, 9.0, n)
    costs[rng.choice(n, 4, replace=False)] += rng.exponential(70.0, 4)
    scheduler = LoopScheduler(p=p, cache_size=0)
    s = scheduler.schedule(costs)
    pts = rng.standard_normal((n, 6)).astype(np.float32)
    cent = rng.standard_normal((7, 6)).astype(np.float32)
    a_seq = np.asarray(ich_kmeans_assign(
        jnp.asarray(pts), jnp.asarray(cent), jnp.asarray(s.item_id),
        interpret=True))
    for B in (1, 4, 8):
        shards = s.shard(superstep=B)
        rid = shards.shard_item_id(s.tiles)
        a_sh = np.asarray(ich_kmeans_assign_sharded(
            jnp.asarray(pts), jnp.asarray(cent), jnp.asarray(rid), p, B,
            interpret=True))
        np.testing.assert_array_equal(a_sh, a_seq)


def test_registry_ops_run_sharded_and_match_refs():
    """The registry ops (the production path) execute the sharded kernels
    at the schedule's p and still match the numpy oracles."""
    from repro.kernels.ich_bfs.ref import bfs_levels_ref
    from repro.kernels.ich_spmv.ref import spmv_ref

    rng = np.random.default_rng(8)
    n = 140
    indptr, indices, data = _random_csr(n, seed=8)
    scheduler = LoopScheduler(p=4, cache_size=0)
    spmv = scheduler.build("spmv", indptr, indices, data)
    assert spmv.p == 4
    assert spmv.vals.shape[0] % spmv.superstep == 0  # whole supersteps
    x = rng.standard_normal(n).astype(np.float32)
    np.testing.assert_allclose(np.asarray(spmv(x, interpret=True)),
                               spmv_ref(indptr, indices, data, x),
                               atol=1e-4, rtol=1e-4)
    bfs = scheduler.build("bfs", indptr, indices)
    np.testing.assert_array_equal(bfs.levels(0, interpret=True),
                                  bfs_levels_ref(indptr, indices, 0))


# --------------------------------------------------- degenerate lowerings
def _bit_identity_spmv(s, indptr, indices, data, p, B):
    """Sequential-grid vs sharded-grid SpMV on schedule `s` at (p, B)."""
    import jax.numpy as jnp
    from repro.kernels.ich_spmv.ich_spmv import ich_spmv, ich_spmv_sharded

    n = len(indptr) - 1
    rng = np.random.default_rng(p * 31 + B)
    x = rng.standard_normal(n).astype(np.float32)
    vals, cols = T.pack_csr(indptr, indices, data, s.tiles)
    y_seq = np.asarray(ich_spmv(jnp.asarray(vals), jnp.asarray(cols),
                                jnp.asarray(s.item_id), jnp.asarray(x), n,
                                interpret=True))
    shards = s.shard(p=p, superstep=B)
    vp, cp = T.pack_csr(indptr, indices, data, s.tiles, pad_tiles_to=B)
    y_sh = np.asarray(ich_spmv_sharded(
        jnp.asarray(vp), jnp.asarray(cp),
        jnp.asarray(shards.shard_item_id(s.tiles)),
        jnp.asarray(shards.kernel_block_ids()), jnp.asarray(x), n, p, B,
        interpret=True))
    np.testing.assert_array_equal(y_sh, y_seq)
    return shards


@pytest.mark.parametrize("case", ["p_exceeds_blocks", "superstep_exceeds_T",
                                  "p_one"])
def test_shard_degenerate_lowerings_bit_identical(case):
    """The degenerate shard shapes — more workers than superstep blocks
    (idle workers), a superstep larger than the whole tile axis (one
    block, p-1 idle workers), and p=1 (everything on one worker) — must
    all produce valid layouts, agree with the simulator's static replay,
    and stay bit-identical to the sequential grid."""
    n = 40
    indptr, indices, data = _random_csr(n, seed=13)
    s = LoopScheduler(cache_size=0).schedule(np.diff(indptr),
                                             rows_per_tile=4)
    Tn = s.n_tiles
    p, B = {"p_exceeds_blocks": (max(Tn, 3) + 2, 2),
            "superstep_exceeds_T": (3, Tn + 5),
            "p_one": (1, 4)}[case]
    shards = _bit_identity_spmv(s, indptr, indices, data, p, B)
    assert shards.p == p and shards.superstep == B
    n_blocks = -(-Tn // B)
    # every block placed exactly once; idle workers hold only -1 padding
    bp = shards.block_perm
    np.testing.assert_array_equal(np.sort(bp[bp >= 0]), np.arange(n_blocks))
    idle = ~(bp >= 0).any(axis=1)
    assert idle.sum() == max(0, p - len(np.unique(shards.worker)))
    # simulator static replay: per-worker dispatched work == partition cost
    wc = shards.worker_cost(s.tile_cost())
    assert wc.shape == (p,)
    assert (wc[idle] == 0).all()
    rep = s.replay_sharded(p=p, superstep=B, params=_NO_OVERHEAD)
    sim_wc = np.zeros(p)
    for (b, e, w, work) in rep.chunk_log:
        sim_wc[w] += work
    np.testing.assert_allclose(sim_wc, wc, atol=1e-9)
    np.testing.assert_allclose(rep.makespan, wc.max(), atol=1e-9)
    if case == "p_one":
        # p=1 static assignment degenerates to the sequential tile order
        np.testing.assert_array_equal(shards.worker, np.zeros(Tn, np.int32))
        np.testing.assert_array_equal(
            np.array([(b, e) for (b, e, _, _) in rep.chunk_log]),
            s.unit_ranges())


def test_shard_memoized_per_p_and_superstep():
    scheduler = LoopScheduler(p=2, cache_size=0)
    s = scheduler.schedule(np.arange(1, 200, dtype=np.int64))
    a = s.shard()
    assert s.shard() is a  # memoized on the Schedule
    b = s.shard(p=4)
    assert b is not a and b.p == 4
    c = s.shard(superstep=2)
    assert c is not a and c.superstep == 2
    assert s.shard() is a  # defaults still hit the original entry
