"""Tests for the measured-cost feedback loop (DESIGN.md §2.7): the
vectorized Welford recurrence, CostRefiner attribution, the
observe() -> refine() round on the Schedule facade, cache-generation
invalidation, the executor's per-chunk instrumentation and deterministic
replay, and the sharded kernels' per-worker cost output."""
import threading

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st
from conftest import random_csr as _random_csr

from repro.core import policies as P
from repro.core.executor import parallel_for
from repro.core.simulator import SimParams
from repro.core.welford import Welford, WelfordVec
from repro.sched import LoopScheduler, NnzCosts
from repro.sched import get as sched_get
from repro.sched.api import Schedule

_ZERO = SimParams(dispatch_overhead=0.0, local_dispatch_overhead=0.0,
                  speed_jitter=0.0)

# one observe/refine round must never cost more than this factor of the
# unrefined makespan on the self-balancing central replay (empirically the
# worst over wide sweeps is ~1.25; 1.5 catches systematic attribution bugs
# without flaking on adversarial hypothesis cases)
ROUND_TOL = 1.5

_SIZES = st.lists(st.one_of(st.just(0), st.integers(0, 40),
                            st.integers(200, 3000)),
                  min_size=1, max_size=120)


# ----------------------------------------------------------- WelfordVec
@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 999), n=st.integers(1, 20),
       rounds=st.integers(1, 6))
def test_welford_vec_matches_scalar_oracle(seed, n, rounds):
    """Lane i of WelfordVec after folding its observed samples must equal
    a scalar Welford fed the same samples, including masked-out rounds."""
    rng = np.random.default_rng(seed)
    vec = WelfordVec.zeros(n)
    oracles = [Welford() for _ in range(n)]
    for _ in range(rounds):
        xs = rng.exponential(10.0, n)
        mask = rng.random(n) < 0.7
        vec.update(xs, mask)
        for i in range(n):
            if mask[i]:
                oracles[i].update(xs[i])
    for i in range(n):
        assert vec.count[i] == oracles[i].count
        np.testing.assert_allclose(vec.mean[i], oracles[i].mean, atol=1e-12)
        np.testing.assert_allclose(vec.variance[i], oracles[i].variance,
                                   atol=1e-9)


# ----------------------------------------------- observe/refine properties
def _jittered(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(20, 300))
    est = rng.uniform(0.5, 10.0, n)
    if rng.random() < 0.4:
        heavy = rng.choice(n, max(1, n // 30), replace=False)
        est[heavy] += rng.exponential(100.0, heavy.size)
    true = est * rng.uniform(0.25, 4.0, n)
    return est, true


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), p=st.integers(1, 8),
       R=st.integers(1, 17), level=st.sampled_from(["item", "tile"]))
def test_one_refine_round_never_blows_up_central_makespan(seed, p, R, level):
    """One observe/refine round on a jittered workload keeps the central
    pretiled replay makespan within ROUND_TOL of the unrefined one."""
    est, true = _jittered(seed)
    s = LoopScheduler(p=p, cache_size=0).schedule(est, rows_per_tile=R)
    m0 = s.replay_refined(true, params=_ZERO).makespan
    if level == "item":
        s1 = s.observe(true, level="item").refine()
    else:
        rep = s.replay_refined(true, params=_ZERO, record_chunks=True)
        tile_true = np.array([wk for (*_, wk) in rep.chunk_log])
        s1 = s.observe(tile_true, level="tile").refine()
    assert s1.generation == 1
    m1 = s1.replay_refined(true, params=_ZERO).makespan
    assert m1 <= m0 * ROUND_TOL + 1e-9


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), p=st.integers(1, 8),
       R=st.integers(1, 17))
def test_exact_cost_refinement_converges_to_true_schedule(seed, p, R):
    """Refinement from EXACT per-item observations reproduces scheduling
    on the true costs: the refined schedule's tiles and replayed makespan
    equal a schedule constructed from the true costs directly."""
    est, true = _jittered(seed)
    scheduler = LoopScheduler(p=p, cache_size=0)
    s1 = scheduler.schedule(est, rows_per_tile=R) \
        .observe(true, level="item").refine()
    s_true = scheduler.schedule(true, rows_per_tile=R)
    np.testing.assert_array_equal(s1.costs, s_true.costs)
    np.testing.assert_array_equal(s1.sizes, s_true.sizes)
    np.testing.assert_array_equal(s1.item_id, s_true.item_id)
    m1 = s1.replay_refined(true, sharded=True, params=_ZERO).makespan
    mt = s_true.replay_refined(true, sharded=True, params=_ZERO).makespan
    assert m1 == mt


@pytest.mark.parametrize("seed,p,R", [(0, 4, 8), (7, 2, 8), (23, 8, 4)])
def test_exact_cost_refinement_converges_deterministic(seed, p, R):
    """Deterministic twin of the hypothesis convergence property (runs in
    environments without hypothesis)."""
    est, true = _jittered(seed)
    scheduler = LoopScheduler(p=p, cache_size=0)
    s1 = scheduler.schedule(est, rows_per_tile=R) \
        .observe(true, level="item").refine()
    s_true = scheduler.schedule(true, rows_per_tile=R)
    np.testing.assert_array_equal(s1.item_id, s_true.item_id)
    assert s1.replay_refined(true, sharded=True, params=_ZERO).makespan \
        == s_true.replay_refined(true, sharded=True, params=_ZERO).makespan
    m0 = scheduler.schedule(est, rows_per_tile=R) \
        .replay_refined(true, params=_ZERO).makespan
    m1 = s1.replay_refined(true, params=_ZERO).makespan
    assert m1 <= m0 * ROUND_TOL + 1e-9


@pytest.mark.parametrize("seed", [1, 2, 11])
def test_refine_rounds_monotone_on_structural_workload(seed):
    """With structural sizes (NnzCosts: tiling fixed, only the worker
    partition re-weights) the sharded makespan on true costs is
    monotonically non-increasing across observe/refine rounds and reaches
    a fixed point once the tile costs are learned exactly — the bench
    refine-loop invariant (benchmarks/bench_schedule_build.py)."""
    rng = np.random.default_rng(seed)
    n = 3000
    sizes = np.minimum(rng.zipf(1.8, n), 800).astype(np.int64)
    sizes[rng.random(n) < 0.1] = 0
    indptr = np.concatenate([[0], np.cumsum(sizes)])
    true = (1.0 + sizes) * rng.uniform(0.3, 3.0, n)
    s = LoopScheduler(p=8).schedule(NnzCosts(indptr))
    ms = []
    for r in range(4):
        rep = s.replay_refined(true, sharded=True, params=_ZERO,
                               record_chunks=True)
        ms.append(rep.makespan)
        tile_true = np.array([wk for (*_, wk) in rep.chunk_log])
        s = s.observe(tile_true, level="tile").refine()
        np.testing.assert_array_equal(s.sizes, np.diff(indptr))  # structural
    assert all(b <= a + 1e-9 for a, b in zip(ms, ms[1:])), ms
    assert ms[1] < ms[0]          # the first round visibly improves
    assert ms[2] == pytest.approx(ms[1], rel=1e-12)  # then a fixed point


# ------------------------------------------------ cache generation keying
def test_refine_reenters_cache_under_fresh_generation():
    sizes = np.arange(1, 200, dtype=np.int64)
    scheduler = LoopScheduler(p=4, cache_size=8)
    s0 = scheduler.schedule(sizes)
    sh0 = s0.shard()
    rep = s0.replay(record_chunks=True)
    s1 = s0.observe(rep).refine()
    assert s1 is not s0 and s1.generation == 1
    assert scheduler.cache_stats.misses == 2  # gen-1 entry is a new build
    # the refined schedule's lowering is its own, never the stale one
    assert s1.shard() is not sh0
    # an identical second refine from the same refiner state is a HIT on
    # the generation-1 entry (same refined content, same generation)
    hits = scheduler.cache_stats.hits
    assert s0.refine() is s1
    assert scheduler.cache_stats.hits == hits + 1
    # chaining advances the generation again
    s2 = s1.observe(s1.replay(record_chunks=True)).refine()
    assert s2.generation == 2 and s2 is not s1


def test_refine_without_scheduler_rebuilds_directly():
    """Hand-assembled Schedules (no facade) still refine."""
    import repro.core.tiling as T

    sizes = np.arange(1, 60, dtype=np.int64)
    costs = sizes.astype(np.float64)
    tiles = T.build_schedule(sizes)
    s = Schedule(sizes=sizes, costs=costs, policy=P.ich(), p=2, tiles=tiles)
    s1 = s.observe(costs * 2.0, level="item").refine()
    assert s1.generation == 1
    np.testing.assert_allclose(s1.costs, costs * 2.0)


# ------------------------------------------- executor instrumentation
def test_deterministic_replay_identical_steal_trace():
    """`parallel_for` with a distributed policy, a fixed seed, and
    deterministic=True must produce identical chunk and steal traces
    across two runs — the accounting guard for the per-chunk
    instrumentation."""
    n = 700
    for policy in (P.ich(), P.stealing(4)):
        logs = []
        for _ in range(2):
            hits = np.zeros(n, np.int64)
            stats = parallel_for(n, lambda i: hits.__setitem__(
                i, hits[i] + 1), 4, policy, seed=9, record_chunks=True,
                deterministic=True)
            assert (hits == 1).all()
            logs.append(([(b, e, w) for (b, e, w, _) in stats.chunk_log],
                         stats.steal_log, stats.chunks, stats.steals))
        assert logs[0] == logs[1]
        chunk_trace, steal_trace, chunks, steals = logs[0]
        assert chunks == len(chunk_trace)
        assert steals == len(steal_trace)
        # the trace covers every iteration exactly once
        seen = np.zeros(n, np.int64)
        for b, e, _ in chunk_trace:
            seen[b:e] += 1
        assert (seen == 1).all()


def test_chunk_timing_recorded_on_both_executor_paths():
    n = 400
    for policy, distributed in ((P.dynamic(16), False), (P.guided(1), False),
                                (P.ich(), True), (P.stealing(8), True)):
        hits = np.zeros(n, np.int64)
        lock = threading.Lock()

        def body(i):
            with lock:
                hits[i] += 1

        stats = parallel_for(n, body, 3, policy, seed=2, record_chunks=True)
        assert (hits == 1).all()
        assert stats.chunk_log is not None
        assert len(stats.chunk_log) == stats.chunks
        seen = np.zeros(n, np.int64)
        for b, e, w, dt in stats.chunk_log:
            assert 0 <= w < 3 and dt >= 0.0
            seen[b:e] += 1
        assert (seen == 1).all()
        assert (stats.steal_log is not None) == distributed


def test_record_chunks_off_keeps_logs_none():
    stats = parallel_for(50, lambda i: None, 2, P.ich(), seed=0)
    assert stats.chunk_log is None and stats.steal_log is None


def test_schedule_observe_from_executor_wall_clock():
    """parallel_for_units chunk timings feed the refiner through the
    normalizing ExecStats path: estimate mass is preserved while relative
    per-item costs move toward the measurements."""
    rng = np.random.default_rng(4)
    costs = rng.uniform(1.0, 9.0, 120)
    s = LoopScheduler(p=2, cache_size=0).schedule(costs)
    stats = s.parallel_for_units(lambda u: None, seed=1)
    with pytest.raises(ValueError, match="no chunk_log"):
        s.observe(stats)
    stats = s.parallel_for_units(lambda u: None, seed=1, record_chunks=True)
    s.observe(stats)
    r = s.refiner
    assert (r.stats.count > 0).any()
    refined = r.refined_costs()
    # wall-clock normalization keeps the total estimate mass (ratio ~1)
    assert refined.sum() == pytest.approx(float(s.costs.sum()), rel=0.2)


def test_observe_simresult_ambiguous_space_requires_flag():
    """sizes [3, 0, 0]: a replay's unit-space ranges must not be silently
    read as item ranges (zero-work items would gain cost)."""
    s = LoopScheduler(p=2, cache_size=0).schedule(
        np.array([3, 0, 0], np.int64))
    rep = s.replay(record_chunks=True)
    with pytest.raises(ValueError, match="non-uniform sizes"):
        s.observe(rep)
    s1 = s.observe(rep, space="units").refine()
    # all measured work stays on item 0; zero-size items stay at zero
    np.testing.assert_allclose(s1.costs, [3.0, 0.0, 0.0])
    with pytest.raises(ValueError, match="items space has 3"):
        # a simulate() run over a different n can't claim item space
        bad = s.simulate(record_chunks=True, policy=P.dynamic(1))
        bad.n = 5
        s.observe(bad, space="items")


def test_observe_execstats_ambiguous_space_requires_flag():
    """sizes [2, 0, 1]: n_items == n_units == 3 but the spaces distribute
    differently — auto inference must refuse, an explicit space works."""
    s = LoopScheduler(p=2, cache_size=0).schedule(
        np.array([2, 0, 1], np.int64))
    stats = s.parallel_for_units(lambda u: None, record_chunks=True)
    with pytest.raises(ValueError, match="non-uniform sizes"):
        s.observe(stats)
    s.observe(stats, space="units")
    assert (s.refiner.stats.count > 0).any()
    with pytest.raises(ValueError, match="'units'"):
        s.observe(stats, space="bogus")


def test_observe_validations():
    s = LoopScheduler(cache_size=0).schedule(np.arange(1, 50,
                                                       dtype=np.int64))
    with pytest.raises(ValueError, match="matches neither"):
        s.observe(np.ones(s.n_items + s.n_tiles + 1))
    with pytest.raises(ValueError, match="no chunk_log"):
        s.observe(s.replay(record_chunks=False))
    with pytest.raises(ValueError, match="unknown observation level"):
        s.observe(np.ones(s.n_items), level="bogus")
    with pytest.raises(ValueError, match="cannot identify a lowering"):
        s.observe(np.ones((13, 17)))


def test_worker_step_observation_names_its_lowering():
    """A (p, S_B) shape alone cannot identify a shard lowering — distinct
    supersteps can share a block grid (12 tiles, p=3: superstep 2 and 3
    both lower to (3, 2)). observe() therefore attributes through the
    DEFAULT lowering unless the caller passes `shards=`, and a
    non-default lowering routed explicitly must update the refiner."""
    sizes = np.full(12 * 8, 4, np.int64)  # uniform -> exactly 12 tiles
    s = LoopScheduler(p=3, cache_size=0).schedule(sizes, width=4)
    assert s.n_tiles == 12
    sh2, sh3 = s.shard(superstep=2), s.shard(superstep=3)
    assert sh2.block_perm.shape == sh3.block_perm.shape == (3, 2)
    measured = np.abs(np.random.default_rng(0).standard_normal((3, 2))) + 1
    before = s.refiner.rounds
    s.observe(measured, shards=sh3)
    assert s.refiner.rounds == before + 1
    # shape mismatch against the NAMED lowering still fails loudly
    with pytest.raises(ValueError, match="cannot identify a lowering"):
        s.observe(np.ones((3, 5)), shards=sh3)


# -------------------------------------------- kernel cost-output routing
def test_sharded_kernel_costs_sum_to_schedule_totals_exactly():
    """The ops' emitted per-worker, per-superstep cost streams must sum to
    the schedule's tile-cost totals: bit-exact for SpMV/BFS (integer nnz
    costs stay exact in float32) and to float tolerance for K-Means."""
    rng = np.random.default_rng(8)
    n = 140
    indptr, indices, data = _random_csr(n, seed=8)
    scheduler = LoopScheduler(p=4, cache_size=0)

    spmv = scheduler.build("spmv", indptr, indices, data)
    spmv(rng.standard_normal(n).astype(np.float32), interpret=True)
    emitted = np.asarray(spmv.last_costs)
    shards = spmv.schedule.shard()
    assert emitted.shape == shards.block_perm.shape
    np.testing.assert_array_equal(
        emitted.sum(axis=1),
        shards.worker_cost(spmv.schedule.tile_cost()).astype(np.float32))

    bfs = scheduler.build("bfs", indptr, indices)
    bfs.step(np.ones(n, np.float32), np.zeros(n, np.float32),
             interpret=True)
    emitted = np.asarray(bfs.last_costs)
    shards = bfs.schedule.shard()
    np.testing.assert_array_equal(
        emitted.sum(axis=1),
        shards.worker_cost(bfs.schedule.tile_cost()).astype(np.float32))

    km = scheduler.build("kmeans", rng.uniform(1.0, 20.0, 64))
    km(rng.standard_normal((64, 4)).astype(np.float32),
       rng.standard_normal((5, 4)).astype(np.float32), interpret=True)
    emitted = np.asarray(km.last_costs)
    shards = km.schedule.shard()
    np.testing.assert_allclose(emitted.sum(axis=1),
                               shards.worker_cost(km.schedule.tile_cost()),
                               rtol=1e-5)


def test_op_observe_refine_roundtrip_keeps_outputs_identical():
    """Closing the loop through the kernels must not change payload
    semantics: ops rebuilt on the refined schedule produce outputs equal
    to the unrefined ops' for the same inputs (bit-identical for SpMV —
    structural sizes keep the tiling, and the sharded grids are
    fold-order-exact for any partition — and exactly equal for BFS levels
    and K-Means assignments)."""
    rng = np.random.default_rng(3)
    n = 120
    indptr, indices, data = _random_csr(n, seed=3)
    scheduler = LoopScheduler(p=4, cache_size=0)

    spmv = scheduler.build("spmv", indptr, indices, data)
    x = rng.standard_normal(n).astype(np.float32)
    y0 = np.asarray(spmv(x, interpret=True))
    refined_s = spmv.observe().refine()
    assert refined_s.generation == 1
    spmv2 = sched_get("spmv").build(refined_s, indptr, indices, data)
    np.testing.assert_array_equal(np.asarray(spmv2(x, interpret=True)), y0)

    bfs = scheduler.build("bfs", indptr, indices)
    lv0 = bfs.levels(0, interpret=True)
    bfs2 = sched_get("bfs").build(bfs.observe().refine(), indptr, indices)
    np.testing.assert_array_equal(bfs2.levels(0, interpret=True), lv0)

    costs = rng.uniform(1.0, 20.0, 64)
    km = scheduler.build("kmeans", costs)
    pts = rng.standard_normal((64, 4)).astype(np.float32)
    cent = rng.standard_normal((5, 4)).astype(np.float32)
    a0 = np.asarray(km(pts, cent, interpret=True))
    km2 = sched_get("kmeans").build(km.observe().refine(), costs)
    np.testing.assert_array_equal(np.asarray(km2(pts, cent,
                                                 interpret=True)), a0)


def test_op_observe_requires_an_invocation():
    indptr, indices, data = _random_csr(60, seed=1)
    op = LoopScheduler(cache_size=0).build("spmv", indptr, indices, data)
    with pytest.raises(ValueError, match="no kernel invocation"):
        op.observe()
