"""Continuous-batching serving tests (DESIGN.md §2.10): admission/shed
determinism, per-request state isolation (interleaved == serial,
bit-identical, on the real engine), deadline shedding under load with the
PR 7 degraded/n_shed contract per request, and the log-bucketed histogram
against a numpy-sort oracle.

Everything except the engine isolation test runs on the simulated backend
(SimBackend + SimClock): bit-deterministic, no jax."""
import dataclasses
import math

import numpy as np
import pytest

from repro.serve.batcher import (ContinuousBatcher, SimBackend, SimClock,
                                 StepCostModel, make_request_factory)
from repro.serve.loadgen import Arrival, LengthDist, OpenPoissonLoadGen
from repro.serve.metrics import LatencyHistogram, ServeMetrics
from repro.serve.policies import (FCFSStatic, IChAdaptive, RoundRobin,
                                  StepPlan, default_policies)
from repro.serve.queue import DONE, AdmissionQueue, Request


def sim_tokens(req_id, n):
    """The SimBackend's deterministic output stream for one request."""
    return [(req_id * 7919 + j) % 251 for j in range(n)]


def run_sim(policy, arrivals, gen, *, max_pending=64, max_running=8,
            cost_seed=0):
    b = ContinuousBatcher(
        policy,
        queue=AdmissionQueue(max_pending=max_pending,
                             max_running=max_running),
        backend=SimBackend(StepCostModel(seed=cost_seed)),
        clock=SimClock())
    m = b.run(arrivals, make_request=make_request_factory(
        gen, vocab_size=512))
    return b, m


# ---------------------------------------------------- admission determinism

class TestAdmissionDeterminism:
    def trace(self, seed=3, n=40, rate=2000.0):
        gen = OpenPoissonLoadGen(
            rate, prompt_lens=LengthDist("zipf", 16, 512, alpha=1.5),
            output_lens=LengthDist("fixed", 4, 4), seed=seed)
        return gen, gen.arrivals(n)

    def shed_ids(self, seed):
        gen, arrivals = self.trace(seed)
        b, m = run_sim(FCFSStatic(chunk=32), arrivals, gen,
                       max_pending=4, max_running=2)
        return [r.req_id for r in b.queue.shed], m

    def test_overload_sheds_and_replays_identically(self):
        """A burst beyond the bounded queue sheds deterministically: the
        same seeded trace drops the same request ids every run."""
        ids1, m1 = self.shed_ids(seed=3)
        ids2, m2 = self.shed_ids(seed=3)
        assert ids1, "trace must overload the 4-slot queue"
        assert ids1 == ids2
        assert m1.n_shed_admission == m2.n_shed_admission == len(ids1)
        assert m1.n_arrived == m2.n_arrived == 40
        assert m1.n_admitted + m1.n_shed_admission == m1.n_arrived

    def test_different_seed_different_trace(self):
        ids1, _ = self.shed_ids(seed=3)
        ids2, _ = self.shed_ids(seed=4)
        # shed decisions follow the arrival trace; a different seed gives
        # a different trace (same COUNT would be a coincidence, same ids
        # at the same arrival stamps would mean the seed is ignored)
        gen1, a1 = self.trace(seed=3)
        gen2, a2 = self.trace(seed=4)
        assert [a.t for a in a1] != [a.t for a in a2]

    def test_accepted_requests_all_complete(self):
        gen, arrivals = self.trace(seed=5, n=20)
        b, m = run_sim(RoundRobin(chunk=32), arrivals, gen,
                       max_pending=64, max_running=4)
        assert m.n_shed_admission == 0
        assert m.n_completed == 20
        assert b.queue.n_outstanding == 0
        for st in b.queue.done:
            assert st.status == DONE
            assert st.out_tokens == sim_tokens(st.request.req_id,
                                               st.request.n_new)

    def test_full_run_metrics_replay_bit_identical(self):
        gen, arrivals = self.trace(seed=7, n=30)
        sums = []
        for _ in range(2):
            _, m = run_sim(IChAdaptive(), arrivals, gen, max_running=4)
            sums.append(m.summary())
        assert sums[0] == sums[1]


# ------------------------------------------------- per-request iCh isolation

class TestPerRequestState:
    def test_divisor_adapts_per_request_not_globally(self):
        """One request's slow chunks must not move another's divisor: the
        iCh band lives on RequestState (the engine-singleton band is gone
        from the batched path)."""
        q = AdmissionQueue(max_running=4)
        a = q.submit(Request(req_id=0, tokens=np.zeros((1, 512)), n_new=1))
        c = q.submit(Request(req_id=1, tokens=np.zeros((1, 512)), n_new=1))
        q.admit(0.0)
        pol = IChAdaptive()
        # steady band for request 0, then one very slow chunk
        for dt in [1.0] * 6 + [100.0]:
            pol.observe(StepPlan(decode=[], prefill=a, prefill_chunk=32), dt)
            a.prefill_done = min(a.prefill_done + 32, 500)
        assert a.d == 2.0          # slow chunk -> LOW -> d halves from 4
        assert c.d == 4.0          # untouched request keeps d_0
        assert c.ks == [] and len(a.ks) == 7

    def test_interleaved_bit_identical_to_serial_on_real_engine(self):
        """Two requests interleaved through the continuous batcher emit
        exactly the tokens each emits when run alone: each RequestState
        owns its KV cache, so batching is a pure scheduling choice."""
        jax = pytest.importorskip("jax")
        from repro.configs import get_arch, reduced
        from repro.models import model as M
        from repro.serve.batcher import EngineBackend
        from repro.serve.engine import Engine, EngineConfig

        cfg = reduced(get_arch("qwen2-1.5b"))
        params = M.init_params(cfg, jax.random.PRNGKey(0), max_seq=64)
        rng = np.random.default_rng(1)
        toks = [rng.integers(0, cfg.vocab_size, (1, s), dtype=np.int64)
                for s in (24, 17)]

        serial = []
        eng = Engine(cfg, params, EngineConfig(max_seq=64, min_chunk=4))
        for t in toks:
            out, _ = eng.generate(t, n_new=6)
            serial.append(out[0].tolist())

        eng2 = Engine(cfg, params, EngineConfig(max_seq=64, min_chunk=4))
        b = ContinuousBatcher(
            RoundRobin(chunk=8, min_chunk=4),
            queue=AdmissionQueue(max_running=4),
            backend=EngineBackend(eng2), clock=SimClock())
        sts = [b.submit(Request(req_id=i, tokens=toks[i], n_new=6,
                                t_arrival=0.0)) for i in range(2)]
        while b.step():
            pass
        assert [st.out_tokens for st in sts] == serial
        # interleaving actually happened: both were running concurrently
        assert all(st.status == DONE for st in sts)
        assert len(sts[0].chunk_log) > 1 and len(sts[1].chunk_log) > 1


# --------------------------------------------------------- deadline shedding

class TestDeadlineShedding:
    def overloaded(self, deadline_s, n=12):
        gen = OpenPoissonLoadGen(
            500.0, prompt_lens=LengthDist("fixed", 256, 256),
            output_lens=LengthDist("fixed", 8, 8),
            deadline_s=deadline_s, seed=11)
        arrivals = gen.arrivals(n)
        return run_sim(FCFSStatic(chunk=64), arrivals, gen,
                       max_running=2) + (n,)

    def test_tight_deadline_degrades_not_raises(self):
        """Under overload a tight SLO sheds decode steps per request: the
        run completes (no exception), late requests finalize DEGRADED with
        the PR 7 contract fields, and the delivered tokens are a prefix of
        the unconstrained stream."""
        b, m, n = self.overloaded(deadline_s=0.05)
        assert m.n_degraded > 0
        assert m.n_completed == n                 # everything finalized
        assert b.queue.n_outstanding == 0
        for st in b.queue.done:
            stats = st.stats()
            assert stats["degraded"] == st.degraded
            if st.degraded:
                assert st.n_shed > 0
                assert len(st.out_tokens) + st.n_shed == st.request.n_new
                # shed FUTURE work only: emitted prefix is unchanged
                assert st.out_tokens == sim_tokens(
                    st.request.req_id, len(st.out_tokens))
            else:
                assert st.n_shed == 0
                assert len(st.out_tokens) == st.request.n_new
        assert m.n_tokens_shed == sum(st.n_shed for st in b.queue.done)

    def test_generous_deadline_never_degrades(self):
        b, m, n = self.overloaded(deadline_s=1e6)
        assert m.n_degraded == 0
        assert all(not st.degraded and st.n_shed == 0
                   for st in b.queue.done)

    def test_degradation_is_per_request(self):
        """Early arrivals meet the SLO while late ones shed: degradation
        must track each request's own deadline, not a global switch."""
        b, m, n = self.overloaded(deadline_s=0.08)
        flags = {st.request.req_id: st.degraded for st in b.queue.done}
        assert True in flags.values() and False in flags.values()


# ------------------------------------------------------------ histogram oracle

class TestHistogramOracle:
    def oracle(self, xs, q):
        xs = np.sort(np.asarray(xs))
        return float(xs[max(1, math.ceil(q / 100.0 * len(xs))) - 1])

    @pytest.mark.parametrize("dist", ["lognormal", "uniform", "bimodal"])
    def test_percentiles_within_resolution(self, dist):
        rng = np.random.default_rng(7)
        if dist == "lognormal":
            xs = rng.lognormal(-3.0, 1.0, 5000)
        elif dist == "uniform":
            xs = rng.uniform(1e-4, 2.0, 5000)
        else:
            xs = np.concatenate([rng.normal(0.01, 1e-3, 2500),
                                 rng.normal(1.0, 0.1, 2500)])
        xs = np.clip(xs, 1e-6, None)
        h = LatencyHistogram(resolution=0.02)
        h.record_many(xs)
        for q in (50, 90, 99, 99.9):
            exact = self.oracle(xs, q)
            got = h.percentile(q)
            assert got == pytest.approx(exact, rel=0.021), (dist, q)

    def test_extremes_exact(self):
        xs = [0.003, 0.5, 0.020, 7.0]
        h = LatencyHistogram()
        h.record_many(xs)
        assert h.percentile(0) == min(xs)
        assert h.percentile(100) == max(xs)
        assert h.count == 4 and h.mean == pytest.approx(np.mean(xs))

    def test_single_sample_answers_itself(self):
        h = LatencyHistogram()
        h.record(0.125)
        for q in (0, 50, 99, 100):
            assert h.percentile(q) == 0.125

    def test_merge_equals_combined_stream(self):
        rng = np.random.default_rng(9)
        a, b = rng.lognormal(-2, 0.5, 400), rng.lognormal(-1, 0.5, 600)
        ha, hb, hc = (LatencyHistogram() for _ in range(3))
        ha.record_many(a)
        hb.record_many(b)
        hc.record_many(np.concatenate([a, b]))
        ha.merge(hb)
        assert ha.count == hc.count
        assert ha.total == pytest.approx(hc.total)
        for q in (50, 90, 99):
            assert ha.percentile(q) == hc.percentile(q)

    def test_merge_rejects_layout_mismatch(self):
        with pytest.raises(ValueError):
            LatencyHistogram(resolution=0.02).merge(
                LatencyHistogram(resolution=0.05))

    def test_rejects_bad_samples(self):
        h = LatencyHistogram()
        with pytest.raises(ValueError):
            h.record(-1.0)
        with pytest.raises(ValueError):
            h.record(float("nan"))


# -------------------------------------------------------------- policy sanity

class TestPolicies:
    def test_default_policy_set(self):
        pols = default_policies()
        assert [p.name for p in pols] == ["fcfs-static", "round-robin",
                                          "ich-adaptive"]

    def test_choose_is_deterministic(self):
        """Same queue state -> same plan, for every policy (bench sweeps
        depend on it)."""
        for make in (lambda: FCFSStatic(), lambda: RoundRobin(),
                     lambda: IChAdaptive()):
            plans = []
            for _ in range(2):
                q = AdmissionQueue(max_running=4)
                for i in range(3):
                    q.submit(Request(req_id=i,
                                     tokens=np.zeros((1, 64 + 16 * i)),
                                     n_new=2))
                q.admit(0.0)
                p = make().choose(q, now=0.0)
                plans.append((p.prefill.request.req_id, p.prefill_chunk,
                              len(p.decode)))
            assert plans[0] == plans[1]

    def test_ich_adaptive_prefers_shortest_remaining(self):
        q = AdmissionQueue(max_running=4)
        q.submit(Request(req_id=0, tokens=np.zeros((1, 1024)), n_new=2))
        q.submit(Request(req_id=1, tokens=np.zeros((1, 48)), n_new=2))
        q.admit(0.0)
        plan = IChAdaptive().choose(q, now=0.0)
        assert plan.prefill.request.req_id == 1  # drain the near-done one


# ----------------------------------- histogram merge ranges + state (PR 9)

class TestHistogramMergeRanges:
    """Satellite (PR 9): `merge` against the combined-stream oracle when
    the two inputs occupy DISJOINT bucket ranges (percentile mass jumps
    the gap) and heavily OVERLAPPING ones, plus merge of serialized
    state."""

    def _oracle_equal(self, a, b):
        ha, hb, hc = (LatencyHistogram() for _ in range(3))
        ha.record_many(a)
        hb.record_many(b)
        hc.record_many(np.concatenate([a, b]))
        ha.merge(hb)
        assert ha.count == hc.count
        assert ha.total == pytest.approx(hc.total)
        assert ha.percentile(0) == hc.percentile(0)
        assert ha.percentile(100) == hc.percentile(100)
        for q in (10, 50, 90, 99, 99.9):
            assert ha.percentile(q) == hc.percentile(q), q

    def test_disjoint_ranges(self):
        rng = np.random.default_rng(21)
        fast = rng.uniform(1e-4, 5e-4, 700)     # sub-millisecond band
        slow = rng.uniform(2.0, 30.0, 300)      # seconds band, no overlap
        self._oracle_equal(fast, slow)
        self._oracle_equal(slow, fast)          # merge is symmetric here

    def test_overlapping_ranges(self):
        rng = np.random.default_rng(22)
        self._oracle_equal(rng.lognormal(-2.5, 0.8, 900),
                           rng.lognormal(-2.0, 0.8, 1100))

    def test_merge_into_empty_and_of_empty(self):
        rng = np.random.default_rng(23)
        xs = rng.uniform(0.01, 1.0, 200)
        h = LatencyHistogram()
        full = LatencyHistogram()
        full.record_many(xs)
        h.merge(full)                            # empty <- full
        full.merge(LatencyHistogram())           # full <- empty
        for q in (0, 50, 99, 100):
            assert h.percentile(q) == full.percentile(q)
        assert h.count == full.count == 200

    def test_merge_after_state_roundtrip(self):
        rng = np.random.default_rng(24)
        a, b = rng.uniform(1e-3, 0.1, 300), rng.uniform(5.0, 50.0, 300)
        ha, hb = LatencyHistogram(), LatencyHistogram()
        ha.record_many(a)
        hb.record_many(b)
        direct = LatencyHistogram()
        direct.record_many(np.concatenate([a, b]))
        back = LatencyHistogram.from_state(ha.state_dict())
        back.merge(LatencyHistogram.from_state(hb.state_dict()))
        for q in (0, 50, 90, 99, 100):
            assert back.percentile(q) == direct.percentile(q)


# ------------------------------------- deadline carry-through (PR 9)

class TestDeadlineCarryThrough:
    """Satellite (PR 9): the loadgen's `deadline_s` reaches every
    `Arrival`, survives `make_request_factory`, and lands on each
    `Request` the batcher enforces; absent a deadline, nothing is
    stamped."""

    def test_deadline_stamped_on_all_arrivals_and_requests(self):
        gen = OpenPoissonLoadGen(rate=30.0, deadline_s=0.75, seed=5)
        arrivals = gen.arrivals(20)
        assert len(arrivals) == 20
        assert all(a.deadline_s == 0.75 for a in arrivals)
        mk = make_request_factory(gen, vocab_size=128)
        reqs = [mk(a) for a in arrivals]
        assert all(r.deadline_s == 0.75 for r in reqs)
        assert [r.t_arrival for r in reqs] == [a.t for a in arrivals]

    def test_no_deadline_means_none_everywhere(self):
        gen = OpenPoissonLoadGen(rate=30.0, seed=5)
        arrivals = gen.arrivals(10)
        mk = make_request_factory(gen, vocab_size=128)
        assert all(a.deadline_s is None for a in arrivals)
        assert all(mk(a).deadline_s is None for a in arrivals)

    def test_deadline_enforced_end_to_end(self):
        """The stamped deadline is the one the batcher degrades on: same
        trace, tight vs generous deadline, only the tight one sheds."""
        def run(deadline_s):
            gen = OpenPoissonLoadGen(
                rate=200.0, deadline_s=deadline_s,
                output_lens=LengthDist("fixed", 16, 16), seed=11)
            _, m = run_sim(FCFSStatic(), gen.arrivals(12), gen,
                           max_running=2)
            return m
        assert run(0.05).n_degraded > 0
        assert run(1e6).n_degraded == 0
