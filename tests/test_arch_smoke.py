"""Per-architecture smoke tests (deliverable (f)): reduced same-family
configs, one forward/train step on CPU, output shapes + no NaNs, and
prefill/decode consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, get_arch, reduced
from repro.models import model as M

ARCH_NAMES = list(ARCHS)


def _batch(cfg, B=2, S=16):
    b = {"tokens": jnp.ones((B, S), jnp.int32),
         "labels": jnp.ones((B, S), jnp.int32)}
    if cfg.family == "encdec":
        b["frames"] = jnp.zeros((B, cfg.encoder_seq, cfg.d_model))
    if cfg.family == "vlm":
        b["patches"] = jnp.zeros((B, cfg.num_patches, cfg.d_model))
    return b


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_reduced_train_step_no_nans(name):
    cfg = reduced(get_arch(name))
    params = M.init_params(cfg, jax.random.PRNGKey(0), max_seq=64)
    caps = jnp.ones((M.n_moe_layers(cfg), max(cfg.n_experts, 1))) if cfg.moe else None
    batch = _batch(cfg)
    loss, metrics = M.loss_fn(cfg, params, batch, caps, dtype=jnp.float32)
    assert jnp.isfinite(loss)
    grads = jax.grad(lambda p: M.loss_fn(cfg, p, batch, caps,
                                         dtype=jnp.float32)[0])(params)
    assert all(bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grads))


@pytest.mark.parametrize("name", [
    "olmo-1b", "qwen2-1.5b",
    # olmoe exercises decode-aware capacity accounting: serving dispatches
    # MoE layers DROPLESS (per-request capacity, models/moe.py), so the
    # token at position S gets the same experts whether it arrives in a
    # fresh S+1-token prefill or as a single decode step. Under the old
    # shared-capacity dispatch the two pools competed differently and this
    # case was xfail'd; the regression pin for the mechanism lives in
    # tests/test_moe_sched.py.
    "olmoe-1b-7b",
    "zamba2-1.2b", "xlstm-350m", "whisper-small"])
def test_decode_matches_prefill(name):
    """decode at position S must equal a fresh prefill of S+1 tokens."""
    cfg = reduced(get_arch(name))
    params = M.init_params(cfg, jax.random.PRNGKey(1), max_seq=64)
    caps = jnp.ones((M.n_moe_layers(cfg), max(cfg.n_experts, 1))) if cfg.moe else None
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S + 1), 0, cfg.vocab_size)
    b_s = dict(_batch(cfg, B, S), tokens=toks[:, :S])
    b_s1 = dict(_batch(cfg, B, S + 1), tokens=toks)
    for b in (b_s, b_s1):
        b.pop("labels")
    logits_s1, _ = M.prefill(cfg, params, b_s1, caps, dtype=jnp.float32)
    _, cache = M.prefill(cfg, params, b_s, caps, dtype=jnp.float32)

    # pad attention caches to 64 slots
    def pad(c):
        if cfg.family in ("hybrid", "ssm"):
            out = []
            for kind, st in zip(cfg.block_pattern, c):
                if kind == "A":
                    out.append({k: jnp.pad(v, ((0, 0), (0, 64 - v.shape[1]),
                                               (0, 0), (0, 0)))
                                for k, v in st.items()})
                else:
                    out.append(st)
            return out
        if cfg.family == "encdec":
            return {"self": [{k: jnp.pad(v, ((0, 0), (0, 0),
                                             (0, 64 - v.shape[2]),
                                             (0, 0), (0, 0)))
                              for k, v in c["self"][0].items()}],
                    "cross": c["cross"]}
        return [{k: jnp.pad(v, ((0, 0), (0, 0), (0, 64 - v.shape[2]), (0, 0),
                                (0, 0))) for k, v in seg.items()} for seg in c]

    pos = S + (cfg.num_patches if cfg.family == "vlm" else 0)
    logits_d, _ = M.decode_step(cfg, params, toks[:, S:S + 1], pad(cache),
                                pos, caps, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(logits_d, np.float32),
                               np.asarray(logits_s1, np.float32),
                               atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_param_pspec_tree_matches_param_tree(name):
    cfg = reduced(get_arch(name))
    params = jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0),
                                                  max_seq=32))
    pspecs = M.param_pspecs(cfg, tp=2, max_seq=32)
    # same treedef => in_shardings always line up
    assert (jax.tree.structure(params)
            == jax.tree.structure(pspecs, is_leaf=lambda x: isinstance(
                x, jax.sharding.PartitionSpec)))


def test_full_configs_match_assignment():
    """Exact assigned hyperparameters (brief ARCHITECTURES block)."""
    c = get_arch("glm4-9b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (40, 4096, 32, 2, 13696, 151552)
    c = get_arch("olmoe-1b-7b")
    assert (c.n_experts, c.experts_per_token, c.moe_d_ff) == (64, 8, 1024)
    c = get_arch("deepseek-moe-16b")
    assert (c.n_experts, c.experts_per_token, c.n_shared_experts) == (64, 6, 2)
    c = get_arch("zamba2-1.2b")
    assert c.ssm_state == 64 and c.block_pattern.count("A") == 6
    c = get_arch("phi3-medium-14b")
    assert (c.n_heads, c.n_kv_heads, c.d_ff) == (40, 10, 17920)
    c = get_arch("whisper-small")
    assert c.encoder_layers == 12 and c.vocab_size == 51865
    c = get_arch("qwen2-1.5b")
    assert c.qkv_bias and c.n_kv_heads == 2


def test_long_500k_support_matrix():
    long = SHAPES["long_500k"]
    runs = {n for n, c in ARCHS.items() if c.supports(long)}
    assert runs == {"zamba2-1.2b", "xlstm-350m"}


def test_param_count_analytic_vs_actual():
    for name in ("olmo-1b", "qwen2-1.5b", "olmoe-1b-7b"):
        cfg = reduced(get_arch(name))
        shapes = jax.eval_shape(lambda c=cfg: M.init_params(
            c, jax.random.PRNGKey(0), max_seq=32))
        actual = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
        analytic = cfg.param_count()
        assert abs(actual - analytic) / actual < 0.12, (name, actual, analytic)
