"""Conformance suite for the jitted schedule pipeline (core/tiling_jax.py).

The bar is ELEMENT-IDENTICAL outputs to the numpy construction path
(core/tiling.py) — integer streams exact by construction, float cost
arithmetic exact because the jax path replicates numpy's f64 association
order (`_pairwise_rowsum`, segment sums). Three layers of evidence:

* hypothesis property tests over arbitrary sizes/R/W/dtypes (skipped
  where hypothesis is absent — the deterministic tests below keep the
  bar in hermetic containers);
* deterministic-twin zipf seeds (the test_tiling.py generator) through
  the FULL lowering pipeline at several (p, superstep) points, plus
  paper-grid workload families;
* `LoopScheduler(backend="jax")` cache-generation tests: device-backed
  entries must invalidate under a new refine generation exactly like
  host-backed ones — a refined schedule can never be served a stale
  device lowering.
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import tiling as T
from repro.core import tiling_jax as TJ
from repro.sched.api import LoopScheduler

jnp = pytest.importorskip("jax.numpy")


def _random_sizes(n, zipf_a, seed, max_size=300):
    rng = np.random.default_rng(seed)
    return np.minimum(rng.zipf(zipf_a, n), max_size).astype(np.int64)


def _random_costs(sizes, seed):
    rng = np.random.default_rng(seed + 1000)
    return (1.0 + sizes) * rng.uniform(0.5, 2.0, sizes.size)


def _numpy_lowering(sizes, costs, *, p, superstep, rows_per_tile=8):
    """The host pipeline's arrays, in the exact layout DeviceLowering
    mirrors (shard_item_id / kernel_block_ids / padded slot cost)."""
    sched = T.build_schedule(sizes, rows_per_tile=rows_per_tile)
    tile_cost = sched.tile_cost(costs, sizes)
    shards = T.shard_schedule(sched, tile_cost, p, superstep=superstep)
    slot = np.zeros((shards.n_tiles_padded, sched.rows_per_tile), np.float32)
    slot[:sched.n_tiles] = sched.slot_cost(costs, sizes)
    return sched, tile_cost, shards, slot


def assert_lowering_matches(low, sizes, costs, *, p, superstep):
    sched, tile_cost, shards, slot = _numpy_lowering(
        sizes, costs, p=p, superstep=superstep)
    host = low.schedule.to_host()
    assert host.width == sched.width and host.n_items == sched.n_items
    np.testing.assert_array_equal(host.item_id, sched.item_id)
    np.testing.assert_array_equal(host.seg_start, sched.seg_start)
    np.testing.assert_array_equal(host.seg_len, sched.seg_len)
    # float costs: bit-identical, not merely close
    np.testing.assert_array_equal(np.asarray(low.tile_cost), tile_cost)
    np.testing.assert_array_equal(np.asarray(low.worker), shards.worker)
    np.testing.assert_array_equal(np.asarray(low.block_perm),
                                  shards.block_perm)
    np.testing.assert_array_equal(np.asarray(low.rowid),
                                  shards.shard_item_id(sched))
    np.testing.assert_array_equal(np.asarray(low.blkid),
                                  shards.kernel_block_ids())
    np.testing.assert_array_equal(np.asarray(low.slot_cost), slot)


# --------------------------------------------------------------- hypothesis
# sizes mix zeros, band-sized items, and heavy outliers so splitting,
# padding, and the zero-item slot rule all get exercised (the
# test_tiling_properties.py strategy)
_SIZES = st.lists(st.one_of(st.just(0), st.integers(0, 40),
                            st.integers(200, 3000)),
                  min_size=1, max_size=120)


@settings(max_examples=30, deadline=None)
@given(sizes=_SIZES, R=st.integers(1, 17),
       W=st.one_of(st.none(), st.integers(1, 600)),
       dtype=st.sampled_from([np.int32, np.int64]))
def test_build_matches_numpy(sizes, R, W, dtype):
    sizes = np.asarray(sizes, dtype)
    ref = T.build_schedule(sizes, rows_per_tile=R, width=W)
    dev = TJ.build_schedule_jax(sizes, rows_per_tile=R, width=W).to_host()
    assert dev.width == ref.width and dev.n_items == ref.n_items
    np.testing.assert_array_equal(dev.item_id, ref.item_id)
    np.testing.assert_array_equal(dev.seg_start, ref.seg_start)
    np.testing.assert_array_equal(dev.seg_len, ref.seg_len)
    item, start, length = T.split_items(sizes, ref.width)
    jitem, jstart, jlen = TJ.split_items_jax(sizes, ref.width)
    np.testing.assert_array_equal(np.asarray(jitem), item)
    np.testing.assert_array_equal(np.asarray(jstart), start)
    np.testing.assert_array_equal(np.asarray(jlen), length)
    assert int(TJ.ich_tile_width_jax(sizes)) == T.ich_tile_width(sizes)


@settings(max_examples=20, deadline=None)
@given(sizes=_SIZES, R=st.integers(1, 17), seed=st.integers(0, 99),
       pad=st.integers(1, 5),
       dtype=st.sampled_from([np.float32, np.float64, np.int32]))
def test_pack_matches_numpy(sizes, R, seed, pad, dtype):
    sizes = np.asarray(sizes, np.int64)
    sched = T.build_schedule(sizes, rows_per_tile=R)
    rng = np.random.default_rng(seed)
    indptr = np.concatenate([[0], np.cumsum(sizes)])
    nnz = int(indptr[-1])
    indices = rng.integers(0, sizes.size, nnz).astype(np.int32)
    data = (rng.integers(1, 100, nnz).astype(dtype)
            if np.issubdtype(dtype, np.integer)
            else rng.standard_normal(nnz).astype(dtype))
    ref_v, ref_c = T.pack_csr(indptr, indices, data, sched,
                              pad_tiles_to=pad)
    dev = TJ.build_schedule_jax(sizes, rows_per_tile=R)
    jv, jc = TJ.pack_csr_jax(indptr, indices, data, dev, pad_tiles_to=pad)
    assert np.asarray(jv).dtype == ref_v.dtype
    np.testing.assert_array_equal(np.asarray(jv), ref_v)
    np.testing.assert_array_equal(np.asarray(jc), ref_c)


@settings(max_examples=20, deadline=None)
@given(sizes=_SIZES, p=st.integers(1, 8), B=st.integers(1, 4),
       seed=st.integers(0, 99))
def test_partition_and_lowering_match_numpy(sizes, p, B, seed):
    sizes = np.asarray(sizes, np.int64)
    costs = _random_costs(sizes, seed)
    sched = T.build_schedule(sizes)
    tile_cost = sched.tile_cost(costs, sizes)
    ref = T.partition_tiles(tile_cost, sched.item_id, p, block=B)
    dev = TJ.partition_tiles_jax(tile_cost, sched.item_id, p, block=B)
    np.testing.assert_array_equal(np.asarray(dev), ref)
    low = TJ.lower_schedule_jax(sizes, costs, p=p, superstep=B)
    assert_lowering_matches(low, sizes, costs, p=p, superstep=B)


# ------------------------------------------------- deterministic twin seeds
@pytest.mark.parametrize("n,zipf_a,seed", [
    (500, 1.3, 0), (500, 2.0, 1), (2000, 1.3, 2), (2000, 1.6, 3),
    (97, 1.5, 4), (4096, 2.2, 5),
])
@pytest.mark.parametrize("p", [1, 3, 4, 8])
def test_pipeline_matches_numpy_twin_seeds(n, zipf_a, seed, p):
    sizes = _random_sizes(n, zipf_a, seed)
    costs = _random_costs(sizes, seed)
    low = TJ.lower_schedule_jax(sizes, costs, p=p)
    assert_lowering_matches(low, sizes, costs, p=p, superstep=low.superstep)


@pytest.mark.parametrize("dtype", [np.int32, np.int64])
@pytest.mark.parametrize("cdtype", [np.float32, np.float64])
def test_pipeline_matches_numpy_across_dtypes(dtype, cdtype):
    sizes = _random_sizes(1200, 1.5, 7).astype(dtype)
    costs = _random_costs(sizes.astype(np.int64), 7).astype(cdtype)
    low = TJ.lower_schedule_jax(sizes, costs, p=4)
    assert_lowering_matches(low, sizes, costs, p=4, superstep=low.superstep)


def test_pipeline_no_sync_path_identical():
    """Passing n_steps= (the refine-loop steady state, no device->host
    sync) must produce the identical lowering."""
    sizes = _random_sizes(1500, 1.4, 11)
    costs = _random_costs(sizes, 11)
    low = TJ.lower_schedule_jax(sizes, costs, p=4)
    low2 = TJ.lower_schedule_jax(sizes, costs, p=4, n_steps=low.n_steps)
    assert low2.n_steps == low.n_steps
    np.testing.assert_array_equal(np.asarray(low2.block_perm),
                                  np.asarray(low.block_perm))
    np.testing.assert_array_equal(np.asarray(low2.rowid),
                                  np.asarray(low.rowid))


def test_pipeline_matches_numpy_paper_grid():
    """The lowering equality over real paper-grid cost families (SpMV
    Table-1 matrices, BFS frontier degrees)."""
    from repro.core import workloads as WL

    cases = []
    for name in ("FullChip", "road_usa", "arabic-2005"):
        spec = next(s for s in WL.TABLE1 if s.name == name)
        nnz = WL.matrix_row_nnz(spec, 4000).astype(np.int64)
        cases.append((np.maximum(nnz, 1), 1.0 + nnz))
    levels, _ = WL.bfs_levels("scale_free", 3000)
    deg = np.maximum(np.asarray(levels[0], np.int64), 1)
    cases.append((deg, deg.astype(np.float64)))
    for sizes, costs in cases:
        low = TJ.lower_schedule_jax(sizes, costs, p=8)
        assert_lowering_matches(low, sizes, costs, p=8,
                                superstep=low.superstep)


def test_empty_sizes_zero_tile_lowering():
    low = TJ.lower_schedule_jax(np.zeros(0, np.int64), np.zeros(0), p=4)
    assert low.schedule.n_tiles == 0
    assert (np.asarray(low.block_perm) == -1).all()
    assert (np.asarray(low.rowid) == -1).all()
    host = low.schedule.to_host()
    assert host.n_tiles == 0 and host.n_items == 0


# ------------------------------------------ backend seam cache generations
class TestDeviceCacheGenerations:
    """`LoopScheduler(backend='jax')`: device-backed cache entries must
    invalidate under a new refine generation exactly like host-backed
    ones (sched/cache.py's no-stale-lowering rule)."""

    def _sched(self, backend):
        ls = LoopScheduler(p=4, backend=backend)
        sizes = _random_sizes(600, 1.5, 3)
        from repro.sched.costs import ExplicitCosts
        return ls, ExplicitCosts(_random_costs(sizes, 3))

    def test_backend_tiles_element_identical(self):
        ls_np, prov = self._sched("numpy")
        ls_jx = LoopScheduler(p=4, backend="jax")
        a, b = ls_np.schedule(prov), ls_jx.schedule(prov)
        np.testing.assert_array_equal(a.item_id, b.item_id)
        np.testing.assert_array_equal(a.tiles.seg_len, b.tiles.seg_len)
        assert a.width == b.width

    def test_backend_part_of_cache_key(self):
        ls, prov = self._sched("jax")
        s1 = ls.schedule(prov)
        ls.backend = "numpy"
        s2 = ls.schedule(prov)
        assert s1 is not s2 and s1.backend == "jax" and s2.backend == "numpy"

    def test_device_lowering_memoized_per_key(self):
        ls, prov = self._sched("jax")
        s = ls.schedule(prov)
        low = s.device_lowering()
        assert s.device_lowering() is low
        assert s.device_lowering(p=2) is not low
        assert s.device_lowering(p=2).p == 2
        assert_lowering_matches(low, s.sizes, s.costs, p=s.p,
                                superstep=s.superstep)

    @pytest.mark.parametrize("backend", ["numpy", "jax"])
    def test_refine_generation_invalidates_lowerings(self, backend):
        """After observe+refine the new generation must build fresh
        lowerings while the old schedule's memo stays untouched — for
        the device path exactly as for the host path."""
        ls, prov = self._sched(backend)
        s0 = ls.schedule(prov)
        host0 = s0.shard()
        dev0 = s0.device_lowering() if backend == "jax" else None
        rng = np.random.default_rng(42)
        measured = s0.costs * rng.uniform(0.25, 4.0, s0.n_items)
        s1 = s0.observe(measured, level="item").refine()
        assert s1 is not s0 and s1.generation == s0.generation + 1
        # fresh memo dicts, empty until first use
        assert s1._shards is not s0._shards and not s1._shards
        assert s1._device is not s0._device and not s1._device
        host1 = s1.shard()
        assert host1 is not host0
        # old entries survive unchanged (no aliasing, no eviction)
        assert s0.shard() is host0
        if backend == "jax":
            dev1 = s1.device_lowering()
            assert dev1 is not dev0
            assert s0.device_lowering() is dev0
            # the refined lowering reflects the refined costs, and stays
            # element-identical to ITS OWN generation's host pipeline
            assert_lowering_matches(dev1, s1.sizes, s1.costs, p=s1.p,
                                    superstep=s1.superstep)
            assert not np.array_equal(np.asarray(dev1.tile_cost),
                                      np.asarray(dev0.tile_cost))

    def test_same_generation_is_cache_hit(self):
        """Re-presenting the same provider at the same generation returns
        the SAME schedule object with its device memo intact; the refined
        generation keys separately (a cache miss, never an overwrite)."""
        ls, prov = self._sched("jax")
        s0 = ls.schedule(prov)
        low = s0.device_lowering()
        assert ls.schedule(prov) is s0
        assert ls.schedule(prov).device_lowering() is low
        s1 = s0.observe(s0.costs * 2.0, level="item").refine()
        assert ls.schedule(prov) is s0  # gen 0 entry undisturbed
        assert s1._scheduler is ls and s1.generation == 1
