"""End-to-end training driver: data pipeline (iCh dispatcher) -> train_step
(AdamW, remat, MoE iCh balancer) -> async checkpoints -> auto-resume.

  PYTHONPATH=src python examples/train_lm.py --steps 60            # tiny, CPU
  PYTHONPATH=src python examples/train_lm.py --arch olmoe-1b-7b \
      --preset 100m --steps 300                                    # real HW

Crash-recovery demo: run with --failure-at 30, rerun the same command, and
the trainer resumes from the published checkpoint.
"""
import argparse
import dataclasses

from repro.configs import get_arch, reduced
from repro.train.trainer import RunConfig, train, InjectedFailure


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--preset", default="tiny", choices=["tiny", "100m", "full"])
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt_example")
    ap.add_argument("--failure-at", type=int, default=None)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.preset == "tiny":
        cfg = reduced(cfg)
    elif args.preset == "100m":
        cfg = dataclasses.replace(
            reduced(cfg), n_layers=8, d_model=768, n_heads=12,
            n_kv_heads=12 if cfg.n_kv_heads == cfg.n_heads else 4,
            d_ff=3072, vocab_size=32000)
    run = RunConfig(steps=args.steps, batch=args.batch, seq=args.seq,
                    ckpt_dir=args.ckpt_dir, failure_at=args.failure_at)
    try:
        state, losses = train(cfg, run)
        print(f"done: loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    except InjectedFailure as e:
        print(f"crashed as requested: {e}; rerun to resume")


if __name__ == "__main__":
    main()
