"""Batched serving with iCh-adaptive chunked prefill.

  PYTHONPATH=src python examples/serve_lm.py --arch qwen2-1.5b

Watch the chunk log: the engine classifies each prefill chunk's measured
token throughput against the running mean band (paper eqs. 1-8) and adapts
the chunk divisor d — the serving-side realization of iCh.
"""
import argparse

import jax
import numpy as np

from repro.configs import get_arch, reduced
from repro.models import model as M
from repro.serve.engine import Engine, EngineConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=192)
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = reduced(get_arch(args.arch))
    params = M.init_params(cfg, jax.random.PRNGKey(0), max_seq=512)
    eng = Engine(cfg, params, EngineConfig(max_seq=args.prompt_len + args.new_tokens + 8))
    prompts = np.random.default_rng(0).integers(
        1, cfg.vocab_size - 1, (args.batch, args.prompt_len)).astype(np.int32)
    out, stats = eng.generate(prompts, n_new=args.new_tokens)
    print("generated ids:\n", out)
    print("prefill chunk log (iCh adaptation):")
    for e in stats["chunks"]:
        print(f"  chunk={e['chunk']:4d} dt={e['dt']*1e3:7.1f}ms d={e['d']:.2f}")
    print("final divisor d:", stats["d_final"])


if __name__ == "__main__":
    main()
