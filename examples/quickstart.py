"""Quickstart: the paper's scheduler family on an irregular loop.

Runs the iCh scheduler (and every baseline) on the paper's synthetic
exponential workload, prints the speedup table and iCh's adaptive state —
then shows the same algorithm balancing MoE experts.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import paper_policy_grid, simulate, SimParams
from repro.core import workloads as WL


def main():
    costs = WL.synth_exp(30_000, increasing=False)
    params = SimParams()
    p = 28
    t1 = simulate(costs, 1, [g for g in paper_policy_grid(1) if g.name == "guided"][0], params).makespan
    print(f"workload: synth Exp-Decreasing, n={len(costs)}, p={p}")
    print(f"{'policy':16s} {'speedup':>8s} {'steals':>7s} {'chunks':>7s}")
    best = {}
    for pol in paper_policy_grid(p):
        r = simulate(costs, p, pol, params)
        sp = t1 / r.makespan
        best[pol.name] = max(best.get(pol.name, 0.0), sp)
        print(f"{pol.label():16s} {sp:8.2f} {r.steals:7d} {r.chunks:7d}")
    print("\nbest per method:", {k: round(v, 2) for k, v in best.items()})
    r = simulate(costs, p, [g for g in paper_policy_grid(p) if g.name == "ich"][0],
                 params)
    print("iCh final d_i (chunk divisors):", np.round(r.ds, 2))
    print("iCh k_i (per-worker progress estimates):", np.round(r.ks, 1))


if __name__ == "__main__":
    main()
