"""Quickstart: the unified `repro.sched` scheduler API.

One facade, four backends. A `LoopScheduler` turns a per-item cost array
into a `Schedule` that (a) replays through the discrete-event simulator,
(b) drives the real threaded executor, and (c) lowers to the tile layout
the Pallas kernels consume — and its workload registry builds the kernels
themselves. Repeated requests hit the LRU schedule cache.

  PYTHONPATH=src python examples/quickstart.py

Runs entirely on CPU (kernels in interpret mode); CI executes it
end-to-end.
"""
import numpy as np

from repro import sched
from repro.core import workloads as WL


def policy_table(scheduler: sched.LoopScheduler, costs: np.ndarray, p: int):
    """The paper's Table-2 sweep through the facade's simulator backend."""
    t1 = scheduler.simulate(costs, policy=sched.guided(1), p=1).makespan
    print(f"workload: synth Exp-Decreasing, n={len(costs)}, p={p}")
    print(f"{'policy':16s} {'speedup':>8s} {'steals':>7s} {'chunks':>7s}")
    best = {}
    for pol in sched.paper_policy_grid(p):
        r = scheduler.simulate(costs, policy=pol, p=p)
        sp = t1 / r.makespan
        best[pol.name] = max(best.get(pol.name, 0.0), sp)
        print(f"{pol.label():16s} {sp:8.2f} {r.steals:7d} {r.chunks:7d}")
    print("best per method:", {k: round(v, 2) for k, v in best.items()})
    r = scheduler.simulate(costs, policy=sched.ich(), p=p)
    print("iCh final d_i (chunk divisors):", np.round(r.ds, 2))
    print("iCh k_i (per-worker progress estimates):", np.round(r.ks, 1))


def one_schedule_three_backends(scheduler: sched.LoopScheduler):
    """The same Schedule object across simulator, executor, and lowering."""
    rng = np.random.default_rng(0)
    sizes = np.minimum(rng.zipf(1.8, 2000), 500).astype(np.int64)
    s = scheduler.schedule(sizes)                       # construct (cached)
    print(f"\nschedule: {s.n_items} items -> {s.n_tiles} tiles of "
          f"{s.rows_per_tile} x W={s.width}")

    # (a) simulator: replay the constructed tiles chunk-for-chunk
    rep = s.replay()
    sim_work = np.array([w for (_, _, _, w) in rep.chunk_log])
    assert np.abs(sim_work - s.tile_cost()).max() < 1e-6
    print(f"simulator replay: {rep.chunks} chunks == {s.n_tiles} tiles, "
          f"per-tile work matches prediction")

    # (b) threaded executor: every work unit exactly once, same tile chunks
    import threading
    hits = np.zeros(int(sizes.sum()), np.int64)
    lock = threading.Lock()

    def body(u):
        with lock:
            hits[u] += 1

    st = s.parallel_for_units(body, p=4)
    assert (hits == 1).all() and st.chunks == s.n_tiles
    print(f"executor: {st.chunks} chunks on 4 threads, "
          "every unit executed exactly once")

    # (c) lowering: the Pallas-facing tile layout
    tiles = s.lower()
    print(f"lowered TileSchedule: item_id {tiles.item_id.shape}, "
          f"width {tiles.width}")

    # LRU cache: an identical request skips construction entirely
    again = scheduler.schedule(sizes)
    assert again is s
    print(f"schedule cache: {scheduler.cache_stats}")


def registry_kernels(scheduler: sched.LoopScheduler):
    """Registered workloads: kernels built from raw inputs, no ops classes."""
    print("\nregistered workloads:", sched.registered())
    rng = np.random.default_rng(1)
    n = 256
    row_nnz = np.minimum(rng.zipf(1.8, n), 60).astype(np.int64)
    indptr = np.concatenate([[0], np.cumsum(row_nnz)])
    indices = rng.integers(0, n, int(indptr[-1])).astype(np.int32)
    data = rng.standard_normal(int(indptr[-1])).astype(np.float32)
    x = rng.standard_normal(n).astype(np.float32)

    from repro.kernels.ich_spmv.ref import spmv_ref
    spmv = scheduler.build("spmv", indptr, indices, data)
    y = np.asarray(spmv(x, interpret=True))
    np.testing.assert_allclose(y, spmv_ref(indptr, indices, data, x),
                               atol=1e-4, rtol=1e-4)
    print(f"spmv kernel (interpret): y[:4] = {np.round(y[:4], 3)} "
          f"(matches reference)")

    bfs = scheduler.build("bfs", indptr, indices)
    levels = bfs.levels(0, interpret=True)
    print(f"bfs kernel (interpret): reached "
          f"{int((levels >= 0).sum())}/{n} vertices from source 0")


def measured_cost_feedback(scheduler: sched.LoopScheduler):
    """Close the loop (DESIGN.md §2.7): observe measured costs, refine,
    re-lower, and watch the sharded makespan on the TRUE costs drop."""
    from repro.core.simulator import SimParams

    rng = np.random.default_rng(7)
    n = 4000
    sizes = np.minimum(rng.zipf(1.8, n), 800).astype(np.int64)
    indptr = np.concatenate([[0], np.cumsum(sizes)])
    # the a-priori estimate (cost ~ nnz) misses a hidden per-item jitter
    true = (1.0 + sizes) * rng.uniform(0.3, 3.0, n)
    zero = SimParams(dispatch_overhead=0.0, local_dispatch_overhead=0.0,
                     speed_jitter=0.0)
    s = scheduler.schedule(sched.NnzCosts(indptr), p=8)
    print("\nmeasured-cost feedback (sharded makespan on true costs):")
    for r in range(3):
        rep = s.replay_refined(true, sharded=True, params=zero,
                               record_chunks=True)
        print(f"  generation {s.generation}: makespan {rep.makespan:,.0f} "
              f"(perfect balance {rep.busy / 8:,.0f})")
        tile_true = np.array([wk for (*_, wk) in rep.chunk_log])
        s_next = s.observe(tile_true, level="tile").refine()
        assert s_next.replay_refined(true, sharded=True,
                                     params=zero).makespan \
            <= rep.makespan + 1e-9
        s = s_next


def serving():
    """Continuous-batching serving (DESIGN.md §2.10): submit requests on
    an open Poisson clock, serve them with the ich-adaptive dispatch
    policy on the simulated backend, and read the tail latencies plus
    each request's adapted chunk divisor."""
    from repro import serve

    gen = serve.OpenPoissonLoadGen(
        rate=20.0,
        prompt_lens=serve.LengthDist("zipf", 64, 2048, alpha=1.1),
        output_lens=serve.LengthDist("fixed", 8, 8), seed=3)
    b = serve.ContinuousBatcher(serve.IChAdaptive(),
                                queue=serve.AdmissionQueue(max_running=4))
    m = b.run(gen.arrivals(4),
              make_request=serve.make_request_factory(gen, vocab_size=512))
    assert m.n_completed == 4 and m.n_degraded == 0
    print("\nserving (4 requests, open Poisson clock, ich-adaptive):")
    print(f"  TTFT p50 {m.ttft.percentile(50) * 1e3:.1f} ms, "
          f"p99 {m.ttft.percentile(99) * 1e3:.1f} ms; "
          f"e2e p99 {m.e2e.percentile(99) * 1e3:.1f} ms; "
          f"goodput {m.goodput():.0f} tok/s")
    for st in sorted(b.queue.done, key=lambda s: s.request.req_id):
        print(f"  req {st.request.req_id}: prompt {st.prompt_len:4d} tok "
              f"in {len(st.chunk_log)} chunks, adapted d={st.d:g} "
              f"(d_0=4), ttft {st.stats()['ttft'] * 1e3:.1f} ms")


def main():
    scheduler = sched.LoopScheduler(p=28)
    costs = WL.synth_exp(30_000, increasing=False)
    policy_table(scheduler, costs, p=28)
    one_schedule_three_backends(scheduler)
    registry_kernels(scheduler)
    measured_cost_feedback(scheduler)
    serving()
    print("\nOK")


if __name__ == "__main__":
    main()
