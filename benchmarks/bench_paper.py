"""Paper-figure benchmarks (Figs. 4-7): one function per figure.

Each returns (csv_rows, summary_dict); run.py aggregates, writes CSVs under
results/paper/, and validates the paper's headline claims.
"""
from __future__ import annotations

import numpy as np

from repro.core import policies as P
from repro.core import workloads as WL

from . import common as C  # simulation goes through C.SCHED (repro.sched)


def bench_synth(n: int = 50_000, threads=C.THREADS):
    """Fig. 4: synth with linear / exp-increasing / exp-decreasing."""
    rows, summary = [], {}
    for label, costs in [
        ("Linear", WL.synth_linear(n)),
        ("Exp-Increasing", WL.synth_exp(n, True)),
        ("Exp-Decreasing", WL.synth_exp(n, False)),
    ]:
        table = C.speedup_table([costs], threads=threads)
        rows += C.csv_rows(f"synth/{label}", table)
        summary[f"synth/{label}"] = table
    return rows, summary


def bench_bfs(n: int = 50_000, threads=C.THREADS):
    """Fig. 5a: BFS on uniform and scale-free graphs (per-level loops)."""
    rows, summary = [], {}
    for label, kind in [("Uniform", "uniform"), ("Scale-Free", "scale_free")]:
        levels, est = WL.bfs_levels(kind, n)
        table = C.speedup_table(levels, estimates=[est] * len(levels),
                                threads=threads)
        rows += C.csv_rows(f"bfs/{label}", table)
        summary[f"bfs/{label}"] = table
    return rows, summary


def bench_kmeans(n: int = 50_000, rounds: int = 8, threads=C.THREADS):
    """Fig. 5b: K-Means — per-round workload drift; binlpt sees the stale
    round-0 estimate (history-based methods can't learn here, §6.1)."""
    loops, est0 = WL.kmeans_rounds(n, rounds)
    estimates = [est0] * len(loops)
    table = C.speedup_table(loops, estimates=estimates, threads=threads)
    return C.csv_rows("kmeans", table), {"kmeans": table}


def bench_lavamd(threads=C.THREADS):
    """Fig. 6a: LavaMD — 512 heavy near-uniform iterations."""
    costs = WL.lavamd_costs()
    table = C.speedup_table([costs], threads=threads)
    return C.csv_rows("lavamd", table), {"lavamd": table}


def bench_spmv(n: int = 100_000, threads=(28,), full_threads=(1, 28)):
    """Fig. 6b: SpMV over the 15 Table-1 matrices; geometric-mean speedup
    with min/max whiskers per method."""
    rows = []
    per_matrix = {m: {} for m in C.METHODS}
    for spec in WL.TABLE1:
        costs = WL.spmv_costs(spec, n)
        t1 = C.best_time([costs], 1, "guided")
        for m in C.METHODS:
            sp = t1 / C.best_time([costs], 28, m)
            per_matrix[m][spec.name] = sp
            rows.append(f"spmv/{spec.name},{m},28,{sp:.3f}")
        stats = WL.achieved_stats(costs - 1.0)
        rows.append(f"spmv_stats/{spec.name},mean,{stats[0]:.2f},var,{stats[2]:.1f}")
    geo = {m: float(np.exp(np.mean(np.log(list(v.values())))))
           for m, v in per_matrix.items()}
    whisk = {m: (min(v.values()), max(v.values())) for m, v in per_matrix.items()}
    for m in C.METHODS:
        rows.append(f"spmv/geomean,{m},28,{geo[m]:.3f}")
        rows.append(f"spmv/whisker,{m},28,{whisk[m][0]:.3f}|{whisk[m][1]:.3f}")
    return rows, {"spmv_geo": geo, "spmv_whisker": whisk,
                  "spmv_per_matrix": per_matrix}


def bench_sensitivity(threads=(8, 14, 28)):
    """Fig. 7: eps_sensitivity (eq. 10) and worst_stealing (eq. 11)."""
    apps = {
        "Synth (Lin)": [WL.synth_linear(50_000)],
        "Synth (Exp-Inc)": [WL.synth_exp(50_000, True)],
        "Synth (Exp-Dec)": [WL.synth_exp(50_000, False)],
        "BF (Uniform)": WL.bfs_levels("uniform", 50_000)[0],
        "BF (Scale-free)": WL.bfs_levels("scale_free", 50_000)[0],
        "Kmeans": WL.kmeans_rounds(50_000, 6)[0],
        "LavaMD": [WL.lavamd_costs()],
        "spmv (arabic)": [WL.spmv_costs(WL.TABLE1[8], 100_000)],
    }
    rows, summary = [], {}
    for app, loops in apps.items():
        for p in threads:
            ich_times = {e: C.app_time(loops, p, P.ich(e))
                         for e in (0.25, 0.33, 0.50)}
            st_best = min(C.app_time(loops, p, P.stealing(c))
                          for c in (1, 2, 3, 64))
            eps_sens = max(ich_times.values()) / min(ich_times.values())
            worst_st = max(ich_times.values()) / st_best
            rows.append(f"sensitivity/{app},{p},{eps_sens:.3f},{worst_st:.3f}")
            summary[(app, p)] = (eps_sens, worst_st)
    return rows, summary


def bench_moe_balance(steps: int = 30, T: int = 8192, E: int = 64, k: int = 8,
                      seed: int = 0):
    """Beyond-paper: iCh-MoE balancer (adaptive capacity + token stealing)
    vs fixed capacity on a drifting, skewed router load."""
    import jax
    import jax.numpy as jnp
    from repro.models import moe as MOE
    from repro.configs import get_arch, reduced

    cfg = reduced(get_arch("olmoe-1b-7b"), n_experts=E, experts_per_token=k,
                  d_model=64, moe_d_ff=64)
    p = MOE.init_moe(jax.random.PRNGKey(seed), cfg)
    rng = jax.random.PRNGKey(seed + 1)
    cap = jnp.ones((E,))
    rows = []
    totals = {"fixed": 0.0, "steal": 0.0, "ich": 0.0}
    # capacity-MISALLOCATION regime: a drifting quarter of the experts is
    # favored; total demand ~= total capacity, so reallocation (not global
    # headroom) is what recovers drops. At extreme skew (demand > the
    # 2*C_base buffer bound) no capacity policy helps — boundary noted in
    # EXPERIMENTS.md.
    fn = jax.jit(lambda p_, x_, cap_, steal: MOE.moe_local(
        cfg, p_, x_, cap_, steal=steal, capacity_factor=1.0)[1]["dropped"],
        static_argnames="steal")
    fn_counts = jax.jit(lambda p_, x_, cap_: MOE.moe_local(
        cfg, p_, x_, cap_, steal=True, capacity_factor=1.0)[1])
    n_hot = max(1, E // 4)
    for t in range(steps):
        rng, k1, k2 = jax.random.split(rng, 3)
        x = jax.random.normal(k1, (T, cfg.d_model))
        hot = (jnp.arange(E) // n_hot == ((t // 5) % (E // n_hot)))
        p_t = dict(p, router=p["router"] + 1.5 * hot.astype(jnp.float32)[None, :])
        d_fixed = float(fn(p_t, x, jnp.ones((E,)), False))
        d_steal = float(fn(p_t, x, jnp.ones((E,)), True))
        aux = fn_counts(p_t, x, cap)
        d_ich = float(aux["dropped"])
        cap = MOE.ich_update_cap_scale(aux["counts"], cap, eps=0.33)
        totals["fixed"] += d_fixed
        totals["steal"] += d_steal
        totals["ich"] += d_ich
        rows.append(f"moe_balance,{t},{d_fixed:.0f},{d_steal:.0f},{d_ich:.0f}")
    denom = steps * T * k
    summary = {m: totals[m] / denom for m in totals}
    rows.append(f"moe_balance/drop_rate,fixed,{summary['fixed']:.4f}")
    rows.append(f"moe_balance/drop_rate,steal,{summary['steal']:.4f}")
    rows.append(f"moe_balance/drop_rate,ich+steal,{summary['ich']:.4f}")
    return rows, summary
