"""Schedule-construction performance benchmark (the repo's perf trajectory).

Measures, across item counts (default 10k / 100k / 1M):

  * `build_schedule` wall time — vectorized array program vs the
    `_reference_*` loop oracle (the seed implementation);
  * `pack_csr` wall time PER LAYOUT: the flat (T, R, W) layout and the
    worker-sharded (p*S, R, W) layout the 2D kernels consume (partition +
    shard layout time reported separately). Outputs are asserted identical
    to the loop oracle on BOTH layouts before any timing is reported, so
    the speedup numbers can't drift away from correctness;
  * the `repro.sched` schedule cache: a repeated `LoopScheduler.schedule()`
    call with identical inputs must be an LRU hit that returns the
    previously built `Schedule` object and skips construction entirely
    (asserted on the cache counters and on object identity); warm-path
    cost is the fingerprint hash;
  * interpret-mode step cost of the three ich_* kernels at the smallest
    size (interpret mode is Python-per-grid-step, so larger sizes measure
    the interpreter, not the kernel), on the sequential (T,) reference
    grid AND the worker-sharded superstepped 2D grid at p in {1, 4} —
    sharded outputs are asserted bit-identical to the sequential grid, so
    this section doubles as the CI sharded-kernel smoke;
  * MoE expert dispatch on the scheduler (DESIGN.md §2.8) at the smallest
    size: the sort-based dispatch resolution alone vs the full scheduled
    build (plan + schedule + shard + pack), and the closed capacity loop —
    the sharded-replay TRUE-cost imbalance is asserted non-increasing
    across three `refine_cap_scale` rounds;
  * fault-injection degradation (DESIGN.md §2.9) at the smallest size:
    makespan inflation of the iCh simulator run vs number of killed
    workers (seeded `FaultPlan` deaths, queues reclaimed by survivors) —
    asserted monotone in the kill count, bounded by 1.5x the fault-free
    run on the surviving worker count, and bit-identical across replays;
  * the measured-cost refine loop (DESIGN.md §2.7) at the smallest size:
    a jittered workload is scheduled from a-priori estimates, per-tile
    true costs are observed from a sharded replay, and
    `Schedule.observe(...).refine()` re-lowers — the simulated sharded
    makespan on the TRUE costs is asserted monotonically non-increasing
    across the rounds and reported against the perfect-balance bound;
  * the COMPILED trajectory (DESIGN.md §2.12) at the smallest size: the
    jitted on-device schedule pipeline (`core/tiling_jax.py` — build ->
    cost -> partition -> shard layout as one XLA executable) asserted
    element-identical to the numpy construction and timed cold
    (trace+compile) and warm, the jitted device `pack_csr` twin asserted
    equal to the host pack, and the sharded SpMV kernel step at p in
    {1, 4} consuming the device pipeline's own prefetch streams,
    asserted bit-identical to the sequential grid. On a real TPU the
    kernel compiles (interpret=False); on CPU the Pallas TPU lowering is
    unavailable, so the step falls back to jit-wrapped interpret mode
    and the record carries `interpret_fallback: true` — an honestly
    labeled stand-in, not a compiled number. `--compiled-smoke` runs
    ONLY this section and merges it into an existing BENCH_schedule.json
    (the CI compiled-smoke step); `--no-compiled` skips it.

Writes `BENCH_schedule.json` at the repo root so future PRs have a recorded
trajectory to regress against, and prints one CSV line per measurement.
Run standalone:

  PYTHONPATH=src python -m benchmarks.bench_schedule_build
  PYTHONPATH=src python -m benchmarks.bench_schedule_build --sizes 10000

or through the driver: PYTHONPATH=src python -m benchmarks.run --bench schedule
"""
from __future__ import annotations

import argparse
import json
import platform
import time
from pathlib import Path

import numpy as np

from repro.core import tiling as T
from repro.sched.defaults import SUPERSTEP

ROOT = Path(__file__).resolve().parent.parent
DEFAULT_SIZES = (10_000, 100_000, 1_000_000)
ROWS_PER_TILE = 8
SHARD_P = 8  # worker count for the sharded-layout pack measurements


def workload(n: int, seed: int = 1) -> np.ndarray:
    """Heavy-tailed per-item work: zipf(1.8) capped at 2000, 10% zero items
    (the empty-CSR-row / isolated-vertex case)."""
    rng = np.random.default_rng(seed)
    sizes = np.minimum(rng.zipf(1.8, n), 2000).astype(np.int64)
    sizes[rng.random(n) < 0.1] = 0
    return sizes


def _best(fn, repeats: int):
    best, out = float("inf"), None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def _csr(sizes: np.ndarray, seed: int = 2):
    rng = np.random.default_rng(seed)
    indptr = np.concatenate([[0], np.cumsum(sizes)])
    nnz = int(indptr[-1])
    indices = rng.integers(0, sizes.size, nnz).astype(np.int32)
    data = rng.standard_normal(nnz).astype(np.float32)
    return indptr, indices, data


def bench_build(n: int, repeats: int) -> dict:
    """Vectorized vs reference construction at n items, plus pack_csr per
    layout (outputs asserted equal before any timing is reported)."""
    sizes = workload(n)
    ref_repeats = repeats if n <= 100_000 else 1  # ref at 1M is seconds/run
    t_vec, sched = _best(lambda: T.build_schedule(
        sizes, rows_per_tile=ROWS_PER_TILE), repeats)
    t_ref, ref = _best(lambda: T._reference_build_schedule(
        sizes, rows_per_tile=ROWS_PER_TILE), ref_repeats)
    np.testing.assert_array_equal(sched.item_id, ref.item_id)
    np.testing.assert_array_equal(sched.seg_start, ref.seg_start)
    np.testing.assert_array_equal(sched.seg_len, ref.seg_len)

    indptr, indices, data = _csr(sizes)
    costs = 1.0 + sizes.astype(np.float64)
    t_shard, shards = _best(lambda: T.shard_schedule(
        sched, sched.tile_cost(costs, sizes), SHARD_P), repeats)

    t_pvec, packed = _best(
        lambda: T.pack_csr(indptr, indices, data, sched), repeats)
    # the sharded layout is zero-copy (kernels fetch blocks straight from
    # the flat payload): its pack = the superstep-padded flat pack plus
    # the prefetch-stream build (block ids + sharded item ids)
    B = shards.superstep

    def pack_sharded():
        vp, cp = T.pack_csr(indptr, indices, data, sched, pad_tiles_to=B)
        return vp, cp, shards.kernel_block_ids(), shards.shard_item_id(sched)

    t_psh, (pv, pc, blkid, rowid_sh) = _best(pack_sharded, repeats)
    t_pref, packed_ref = _best(
        lambda: T._reference_pack_csr(indptr, indices, data, sched), 1)
    # vec == reference on the flat layout...
    np.testing.assert_array_equal(packed[0], packed_ref[0])
    np.testing.assert_array_equal(packed[1], packed_ref[1])
    # ...and on the sharded layout: the padded payload matches reference on
    # real tiles (zeros beyond), and the block/item prefetch streams name
    # every tile exactly once
    Tn = sched.n_tiles
    np.testing.assert_array_equal(pv[:Tn], packed_ref[0])
    np.testing.assert_array_equal(pc[:Tn], packed_ref[1])
    assert (pv[Tn:] == 0).all() and (pc[Tn:] == 0).all()
    perm = shards.perm
    np.testing.assert_array_equal(np.sort(perm[perm >= 0]), np.arange(Tn))
    assert blkid.shape == (SHARD_P * shards.n_steps,)
    assert rowid_sh.shape == (SHARD_P * shards.tiles_per_worker,
                              ROWS_PER_TILE)
    return {
        "n_items": n,
        "nnz": int(sizes.sum()),
        "width": sched.width,
        "n_tiles": sched.n_tiles,
        "build_vec_s": t_vec,
        "build_ref_s": t_ref,
        "build_speedup": t_ref / t_vec,
        "pack": {
            "ref_s": t_pref,
            "flat": {"vec_s": t_pvec, "speedup": t_pref / t_pvec},
            "sharded": {"vec_s": t_psh, "speedup": t_pref / t_psh,
                        "p": SHARD_P, "superstep": B,
                        "partition_s": t_shard,
                        "tiles_per_worker": shards.tiles_per_worker},
        },
    }


def bench_cache(n: int, repeats: int) -> dict:
    """Schedule-cache behavior at n items (the serving path's reuse story).

    The second `schedule()` call with identical inputs MUST be a cache hit
    that skips construction entirely: asserted on the LRU counters (one
    miss total) and on object identity (the very same `Schedule` comes
    back). The warm path pays only the cost-fingerprint hash.
    """
    from repro.sched import LoopScheduler

    sizes = workload(n)
    sched = LoopScheduler()
    t0 = time.perf_counter()
    first = sched.schedule(sizes)
    t_cold = time.perf_counter() - t0
    assert sched.cache_stats.misses == 1 and sched.cache_stats.hits == 0
    t_warm, again = _best(lambda: sched.schedule(sizes), repeats)
    assert again is first, "cache hit must return the cached Schedule object"
    assert sched.cache_stats.misses == 1, \
        "cache hit must not re-run schedule construction"
    assert sched.cache_stats.hits == repeats
    return {
        "n_items": n,
        "cold_s": t_cold,
        "warm_hit_s": t_warm,
        "hit_speedup": t_cold / max(t_warm, 1e-12),
        "hits": sched.cache_stats.hits,
        "misses": sched.cache_stats.misses,
    }


def bench_refine_loop(n: int, p: int = 8, rounds: int = None,
                      jitter_seed: int = 5) -> dict:
    """The closed feedback loop, demonstrated end to end (DESIGN.md §2.7).

    A zipf workload's payload structure (row sizes) is known exactly, but
    its TRUE per-item costs carry a hidden multiplicative jitter the
    a-priori estimate (cost ~ size) misses — the paper's DVFS/cache-miss
    heterogeneity (§3.2) at item granularity. Each round replays the
    current schedule's worker-sharded lowering on the true costs, observes
    the exact per-tile measured costs from the replay's chunk log, and
    `observe(...).refine()` re-lowers under the refreshed estimates. The
    simulated sharded makespan (zero overhead/jitter: the partition's max
    per-worker true cost) must be monotonically non-increasing across the
    rounds — asserted here, so CI catches any refinement regression — and
    converges onto the perfect-balance bound (busy/p).
    """
    from repro.core.simulator import SimParams
    from repro.sched import LoopScheduler, NnzCosts
    from repro.sched.defaults import REFINE_ROUNDS

    rounds = REFINE_ROUNDS if rounds is None else int(rounds)
    rng = np.random.default_rng(jitter_seed)
    sizes = workload(n)
    indptr = np.concatenate([[0], np.cumsum(sizes)])
    true = (1.0 + sizes) * rng.uniform(0.3, 3.0, n)
    zero = SimParams(dispatch_overhead=0.0, local_dispatch_overhead=0.0,
                     speed_jitter=0.0)
    s = LoopScheduler(p=p).schedule(NnzCosts(indptr))
    makespans, balance = [], None
    t0 = time.perf_counter()
    for r in range(rounds + 1):
        rep = s.replay_refined(true, sharded=True, params=zero,
                               record_chunks=True)
        makespans.append(rep.makespan)
        balance = rep.busy / p  # perfect-balance lower bound on this work
        if r == rounds:
            break
        tile_true = np.array([wk for (*_, wk) in rep.chunk_log])
        s = s.observe(tile_true, level="tile").refine()
    elapsed = time.perf_counter() - t0
    for a, b in zip(makespans, makespans[1:]):
        assert b <= a + 1e-9, (
            f"refine round increased sharded makespan: {makespans}")
    assert s.generation == rounds
    return {
        "n_items": n, "p": p, "rounds": rounds,
        "makespans": makespans,
        "balance_bound": balance,
        "improvement": 1.0 - makespans[-1] / makespans[0],
        "imbalance_final": makespans[-1] / balance,
        "loop_s": elapsed,
    }


def bench_moe_dispatch(n_tokens: int, repeats: int, n_experts: int = 512,
                       k: int = 2, p: int = 8, rounds: int = 3,
                       seed: int = 7) -> dict:
    """MoE expert dispatch on the scheduler (DESIGN.md §2.8).

    Two measurements over a zipf-skewed router at n_tokens:

    * build cost — the sort-based dispatch resolution alone
      (`plan_dispatch`, what the in-graph path also computes) vs the FULL
      scheduled build: plan + iCh schedule over the per-expert loads +
      worker-shard partition + packed (T, R, W) payload. The difference
      is the price of running the model on the scheduler.
    * the closed capacity loop — per-expert TRUE costs carry hidden
      multiplicative heterogeneity the token-count estimate misses;
      each round folds them in through `refine_cap_scale`
      (observe/refine + next cap_scale) and the sharded-replay TRUE-cost
      imbalance (makespan over the perfect-balance bound) is asserted
      non-increasing across the rounds, so CI catches any regression of
      the §2.8 feedback path.
    """
    from repro.core.simulator import SimParams
    from repro.sched import ExpertLoadCosts, LoopScheduler
    from repro.sched.moe import plan_dispatch, refine_cap_scale

    rng = np.random.default_rng(seed)
    # moderate zipf popularity: every expert sees traffic, hot experts see
    # several times the mean (heavier skew starves most experts and the
    # capacity cut flattens what's left — nothing to schedule)
    pop = np.arange(1, n_experts + 1, dtype=np.float64) ** -1.0
    logits = rng.gumbel(size=(n_tokens, n_experts)) + np.log(pop)[None]
    e_topk = np.argsort(-logits, axis=1)[:, :k].astype(np.int32)
    w = (rng.random((n_tokens, k)) + 0.1).astype(np.float32)
    w /= w.sum(1, keepdims=True)

    # cap_scale pins E: heavy skew can leave high-id experts unrouted
    ones = np.ones(n_experts)
    t_plan, plan = _best(lambda: plan_dispatch(e_topk, w, cap_scale=ones),
                         repeats)
    # time real rebuilds (cache off); 2-row tiles because the shard
    # partition's unit is the superstep BLOCK — 8-row tiles over 512
    # capped experts yield exactly p blocks, leaving the partition no
    # freedom to act on refined costs
    scheduler = LoopScheduler(p=p, cache_size=0, rows_per_tile=2)

    def scheduled_build():
        pl = plan_dispatch(e_topk, w, cap_scale=ones)
        s = scheduler.schedule(ExpertLoadCosts(pl.counts))
        sh = s.shard()
        indptr, tok, wcsr = pl.csr()
        T.pack_csr(indptr, tok, wcsr, s.tiles, pad_tiles_to=sh.superstep)
        return s

    t_sched, s = _best(scheduled_build, repeats)

    zero = SimParams(dispatch_overhead=0.0, local_dispatch_overhead=0.0,
                     speed_jitter=0.0)
    true = (plan.counts.astype(np.float64)
            * rng.uniform(0.5, 2.0, n_experts) + 0.01)
    imb_true, imb_pred, cap_scale = [], [], None
    for r in range(rounds + 1):
        rep = s.replay_refined(true, sharded=True, params=zero)
        imb_true.append(rep.makespan / (rep.busy / p))
        imb_pred.append(s.imbalance())
        if r == rounds:
            break
        s, cap_scale = refine_cap_scale(s, true)
    for a, b in zip(imb_true, imb_true[1:]):
        assert b <= a + 1e-9, (
            f"refine round increased dispatch imbalance: {imb_true}")
    assert s.generation == rounds
    return {
        "n_tokens": n_tokens, "n_experts": n_experts, "k": k, "p": p,
        "kept": int(plan.counts.sum()), "stolen": plan.stolen,
        "dropped": plan.dropped,
        "plan_s": t_plan,
        "scheduled_build_s": t_sched,
        "schedule_overhead": t_sched / t_plan,
        "rounds": rounds,
        "imbalance_true": imb_true,
        "imbalance_predicted": imb_pred,
        "cap_scale_min": float(cap_scale.min()),
        "cap_scale_max": float(cap_scale.max()),
    }


def bench_degradation(n: int, p: int = 4, seed: int = 100) -> dict:
    """Graceful degradation under injected worker deaths (DESIGN.md §2.9):
    makespan inflation vs number of killed workers, asserted monotone.

    Near-uniform per-item costs and EARLY deaths (after each victim's
    first chunk), so the lost capacity dominates the measurement — on
    heavy-tailed workloads steal-path luck can mask a single death (a
    different chunk/steal pattern occasionally beats the fault-free run).
    Asserted, so CI catches any reclaim regression:

      * inflation(k) > 1 and strictly increasing in k for k = 1..p-1
        (each additional dead worker costs more);
      * bounded factor: the k-death run stays within 1.5x of a fault-free
        run on the p-k survivors (recovery never costs more than simply
        having started with the smaller machine, modulo steal luck);
      * every plan replays bit-identically (same makespan + fault trace).
    """
    from repro.core.policies import ich
    from repro.core.simulator import simulate
    from repro.robust import FaultPlan

    rng = np.random.default_rng(seed)
    costs = rng.uniform(8.0, 12.0, n)
    clean = simulate(costs, p, ich())
    rows = []
    prev = 1.0
    for k in range(1, p):
        plan = FaultPlan(seed=seed,
                         deaths=tuple((w, 1) for w in range(k)))
        faulty = simulate(costs, p, ich(), faults=plan)
        again = simulate(costs, p, ich(), faults=plan)
        assert faulty.makespan == again.makespan, \
            f"chaos replay diverged at k={k}"
        assert faulty.fault_log == again.fault_log
        inflation = faulty.makespan / clean.makespan
        assert inflation > prev, (
            f"inflation must increase monotonically in killed workers: "
            f"k={k} gave {inflation:.4f} after {prev:.4f}")
        survivors = simulate(costs, p - k, ich())
        assert faulty.makespan <= 1.5 * survivors.makespan, (
            f"k={k}: faulty makespan {faulty.makespan:.1f} exceeds 1.5x "
            f"the fault-free p-{k} run {survivors.makespan:.1f}")
        rows.append({
            "killed": k,
            "makespan": faulty.makespan,
            "inflation": inflation,
            "vs_survivor_machine": faulty.makespan / survivors.makespan,
            "deaths": faulty.deaths,
            "reclaims": faulty.reclaims,
        })
        prev = inflation
    return {
        "n_items": n, "p": p, "policy": "ich",
        "workload": f"uniform(8, 12), seed {seed}, deaths after 1 chunk",
        "clean_makespan": clean.makespan,
        "rows": rows,
    }


def bench_recovery(n: int, p: int = 4, seed: int = 100) -> dict:
    """Checkpoint-based reshard recovery vs steal-only reclaim
    (DESIGN.md §2.11): kill k of p workers early, then finish the run
    two ways from the SAME amount of completed work — PR 7's dynamic
    steal-path reclaim (pays per-chunk steal/dispatch overheads for
    every reclaimed item) vs re-lowering the incomplete chains onto the
    p-k survivors from the checkpoint at the last superstep barrier
    before the first death (barrier-time model: completed prefix +
    re-execution, no per-chunk overheads). Asserted per row: reshard
    inflation must not exceed steal inflation beyond the superstep
    QUANTIZATION allowance — the checkpoint rounds each worker's credit
    down to a completed block, losing at most one block of progress per
    worker — so CI catches any reshard regression."""
    from repro.core.policies import ich
    from repro.core.simulator import simulate
    from repro.robust import CheckpointLog, FaultPlan
    from repro.sched import LoopScheduler

    rng = np.random.default_rng(seed)
    sizes = rng.integers(8, 13, n)
    s = LoopScheduler(p=p, cache_size=0).schedule(sizes)
    shards = s.shard()
    tc = s.tile_cost()
    B = s.superstep
    clean_static = float(shards.worker_cost(tc).max())
    clean_steal = simulate(s.costs, p, ich())
    # per-worker cumulative cost at each superstep barrier
    perm = shards.perm
    step_cost = np.zeros((shards.p, shards.n_steps))
    for w in range(shards.p):
        for t in range(shards.n_steps):
            tiles = perm[w, t * B:(t + 1) * B]
            step_cost[w, t] = tc[tiles[tiles >= 0]].sum()
    cum = np.cumsum(step_cost, axis=1)
    quantum = float(step_cost.max()) / clean_static  # one block of credit
    rows = []
    for k in range(1, p):
        plan_f = FaultPlan(seed=seed,
                           deaths=tuple((w, 1) for w in range(k)))
        faulty = simulate(s.costs, p, ich(), faults=plan_f,
                          record_assignment=True)
        steal_inflation = faulty.makespan / clean_steal.makespan
        # the last consistent barrier before the first death: every dead
        # worker had completed exactly its first chunk
        t_c = min(float(s.costs[faulty.assignment == w].sum())
                  for w in range(k))
        log = CheckpointLog()
        for w in range(p):
            log.mark_through(w, int(np.searchsorted(cum[w], t_c,
                                                    side="right")))
        plan = s.reshard_survivors(dead=range(k), checkpoint=log)
        again = s.reshard_survivors(
            dead=range(k),
            checkpoint=CheckpointLog.from_json(log.to_json()))
        assert np.array_equal(plan.redo_blocks, again.redo_blocks), \
            f"recovery replan diverged at k={k}"
        mm = plan.makespan_model(tc)
        inflation = mm["makespan"] / clean_static
        assert inflation <= steal_inflation + quantum, (
            f"k={k}: reshard inflation {inflation:.4f} exceeds the "
            f"steal-only reclaim inflation {steal_inflation:.4f} beyond "
            f"the one-block quantization allowance {quantum:.4f}")
        rows.append({
            "killed": k,
            "blocks_redone": int(plan.redo_blocks.size),
            "blocks_kept": int(plan.keep_blocks.size),
            "t_done": mm["t_done"],
            "t_redo": mm["t_redo"],
            "makespan": mm["makespan"],
            "inflation": inflation,
            "steal_inflation": steal_inflation,
        })
    return {
        "n_items": n, "p": p,
        "workload": f"integers(8, 13), seed {seed}, deaths after 1 chunk, "
                    f"checkpoint at the barrier before the first death",
        "clean_static_makespan": clean_static,
        "clean_steal_makespan": clean_steal.makespan,
        "quantization_allowance": quantum,
        "rows": rows,
    }


def _timed(fn, repeats: int = 3):
    import jax
    out = jax.block_until_ready(fn())  # trace + compile
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best, out


def bench_kernel_step(n: int, shard_ps=(1, 4)) -> dict:
    """Steady-state interpret-mode cost of one full schedule sweep for each
    ich_* kernel (first call = trace/compile, second call timed): the
    sequential (T,) reference grid vs the worker-sharded superstepped 2D
    grid at p in `shard_ps`. Sharded outputs are asserted bit-identical to
    the sequential grid — this is the CI sharded-kernel smoke."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.ich_bfs.ich_bfs import (ich_bfs_step,
                                               ich_bfs_step_sharded)
    from repro.kernels.ich_kmeans.ich_kmeans import (
        ich_kmeans_assign, ich_kmeans_assign_sharded)
    from repro.kernels.ich_spmv.ich_spmv import ich_spmv, ich_spmv_sharded
    from repro.sched import LoopScheduler

    rng = np.random.default_rng(3)
    sizes = workload(n)
    indptr, indices, data = _csr(sizes)
    scheduler = LoopScheduler(rows_per_tile=ROWS_PER_TILE)
    s = scheduler.schedule(np.diff(indptr))
    n_tiles, B = s.n_tiles, SUPERSTEP
    out = {"n_items": n, "n_tiles": n_tiles, "superstep": B}

    def record(name, seq_fn, sharded_fn, k_tiles):
        """Time the sequential grid, then each sharded p; assert bitwise
        equality; return {seq: {...}, sharded: {p: {...}}}. `k_tiles` is
        the tile count of the schedule THIS kernel runs (kmeans builds its
        own schedule, which need not match spmv/bfs's)."""
        dt, ref_out = _timed(seq_fn)
        rec = {"seq": {"total_s": dt, "per_tile_us": 1e6 * dt / k_tiles}}
        rec["sharded"] = {}
        for p, fn in sharded_fn.items():
            dt_p, out_p = _timed(fn)
            np.testing.assert_array_equal(
                np.asarray(out_p), np.asarray(ref_out),
                err_msg=f"{name} sharded p={p} != sequential grid")
            rec["sharded"][str(p)] = {
                "total_s": dt_p, "per_tile_us": 1e6 * dt_p / k_tiles,
                "per_tile_speedup": dt / dt_p}
        return rec

    # --- spmv ---------------------------------------------------------
    x = jnp.asarray(rng.standard_normal(sizes.size).astype(np.float32))
    vals, cols = T.pack_csr(indptr, indices, data, s.tiles)
    va, ca, ra = jnp.asarray(vals), jnp.asarray(cols), jnp.asarray(s.item_id)
    vp, cp = T.pack_csr(indptr, indices, data, s.tiles, pad_tiles_to=B)
    vpa, cpa = jnp.asarray(vp), jnp.asarray(cp)
    seq = jax.jit(lambda: ich_spmv(va, ca, ra, x, sizes.size,
                                   interpret=True))
    sharded = {}
    for p in shard_ps:
        sh = s.shard(p=p)
        args = (jnp.asarray(sh.shard_item_id(s.tiles)),
                jnp.asarray(sh.kernel_block_ids()))
        sharded[p] = jax.jit(lambda a=args, p=p: ich_spmv_sharded(
            vpa, cpa, *a, x, sizes.size, p, B, interpret=True))
    out["ich_spmv"] = record("ich_spmv", seq, sharded, s.n_tiles)

    # --- bfs ----------------------------------------------------------
    frontier = jnp.asarray((rng.random(sizes.size) < 0.05)
                           .astype(np.float32))
    ones = np.ones(len(indices), np.float32)
    mask, mcols = T.pack_csr(indptr, indices, ones, s.tiles)
    ma, mc = jnp.asarray(mask), jnp.asarray(mcols)
    mp, mcp = T.pack_csr(indptr, indices, ones, s.tiles, pad_tiles_to=B)
    mpa, mcpa = jnp.asarray(mp), jnp.asarray(mcp)
    seq = jax.jit(lambda: ich_bfs_step(ma, mc, ra, frontier, frontier,
                                       sizes.size, interpret=True))
    sharded = {}
    for p in shard_ps:
        sh = s.shard(p=p)
        args = (jnp.asarray(sh.shard_item_id(s.tiles)),
                jnp.asarray(sh.kernel_block_ids()))
        sharded[p] = jax.jit(lambda a=args, p=p: ich_bfs_step_sharded(
            mpa, mcpa, *a, frontier, frontier, sizes.size, p, B,
            interpret=True))
    out["ich_bfs"] = record("ich_bfs", seq, sharded, s.n_tiles)

    # --- kmeans -------------------------------------------------------
    km_s = scheduler.schedule(np.maximum(sizes.astype(np.float64), 1.0))
    pts = jnp.asarray(rng.standard_normal((sizes.size, 8))
                      .astype(np.float32))
    cent = jnp.asarray(rng.standard_normal((16, 8)).astype(np.float32))
    kra = jnp.asarray(km_s.item_id)
    seq = jax.jit(lambda: ich_kmeans_assign(pts, cent, kra, interpret=True))
    sharded = {}
    for p in shard_ps:
        sh = km_s.shard(p=p)
        rid = jnp.asarray(sh.shard_item_id(km_s.tiles))
        sharded[p] = jax.jit(lambda r=rid, p=p: ich_kmeans_assign_sharded(
            pts, cent, r, p, B, interpret=True))
    out["ich_kmeans"] = record("ich_kmeans", seq, sharded, km_s.n_tiles)
    return out


def bench_compiled(n: int, repeats: int, shard_ps=(1, 4)) -> dict:
    """The compiled-mode trajectory (ISSUE 10 / DESIGN.md §2.12).

    Three measurements, each gated on an exactness assertion so the
    recorded numbers can never drift away from correctness:

    * the jitted on-device pipeline (`tiling_jax.lower_schedule_jax`:
      build -> cost -> partition -> shard layout) vs the numpy
      construction chain at each p — every output (tiles, f64 tile
      costs, LPT worker map, (p, S_B) layout, prefetch streams) asserted
      ELEMENT-IDENTICAL before timing; cold includes trace+compile, warm
      is the steady-state re-dispatch;
    * the jitted device `pack_csr` twin vs the host pack (superstep-
      padded layout), asserted equal;
    * one sharded SpMV sweep at each p consuming the device pipeline's
      own rowid/blkid streams, asserted bit-identical to the sequential
      reference grid. Compiled (interpret=False) when a TPU backend is
      present; otherwise jit-wrapped interpret mode, recorded with
      `interpret_fallback: true`.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import tiling_jax as TJ
    from repro.kernels.ich_spmv.ich_spmv import ich_spmv, ich_spmv_sharded

    sizes = workload(n)
    indptr, indices, data = _csr(sizes)
    costs = 1.0 + sizes.astype(np.float64)
    B = SUPERSTEP
    backend = jax.default_backend()
    interp = backend != "tpu"
    out = {"n_items": n, "backend": backend, "interpret_fallback": interp,
           "superstep": B}

    # --- jitted pipeline vs numpy construction ------------------------
    def np_pipeline(p):
        sched = T.build_schedule(sizes, rows_per_tile=ROWS_PER_TILE)
        tc = sched.tile_cost(costs, sizes)
        shards = T.shard_schedule(sched, tc, p)
        return (sched, tc, shards, shards.shard_item_id(sched),
                shards.kernel_block_ids())

    lowerings, rows = {}, {}
    for p in shard_ps:
        t_np, (sched, tc, shards, rowid, blkid) = _best(
            lambda p=p: np_pipeline(p), repeats)
        t0 = time.perf_counter()
        low = TJ.lower_schedule_jax(sizes, costs, p=p,
                                    rows_per_tile=ROWS_PER_TILE)
        jax.block_until_ready(low.block_perm)
        t_cold = time.perf_counter() - t0

        def jax_pipeline(p=p):
            lw = TJ.lower_schedule_jax(sizes, costs, p=p,
                                       rows_per_tile=ROWS_PER_TILE)
            jax.block_until_ready(lw.block_perm)
            return lw

        t_warm, low = _best(jax_pipeline, repeats)
        np.testing.assert_array_equal(np.asarray(low.schedule.item_id),
                                      sched.item_id)
        np.testing.assert_array_equal(np.asarray(low.schedule.seg_start),
                                      sched.seg_start)
        np.testing.assert_array_equal(np.asarray(low.schedule.seg_len),
                                      sched.seg_len)
        np.testing.assert_array_equal(np.asarray(low.tile_cost), tc)
        np.testing.assert_array_equal(np.asarray(low.worker), shards.worker)
        np.testing.assert_array_equal(np.asarray(low.block_perm),
                                      shards.block_perm)
        np.testing.assert_array_equal(np.asarray(low.rowid), rowid)
        np.testing.assert_array_equal(np.asarray(low.blkid), blkid)
        lowerings[p] = (sched, low)
        rows[str(p)] = {"numpy_s": t_np, "jax_cold_s": t_cold,
                        "jax_warm_s": t_warm,
                        "warm_speedup": t_np / max(t_warm, 1e-12)}
    out["pipeline"] = {
        "asserted": "element-identical to numpy build/cost/partition/shard",
        "p": rows}

    # --- jitted device pack vs host pack ------------------------------
    sched, low = lowerings[shard_ps[0]]
    vp_np, cp_np = T.pack_csr(indptr, indices, data, sched, pad_tiles_to=B)

    def jax_pack():
        vp, cp = TJ.pack_csr_jax(indptr, indices, data, low.schedule,
                                 pad_tiles_to=B)
        jax.block_until_ready(vp)
        return vp, cp

    t0 = time.perf_counter()
    vp, cp = jax_pack()
    t_pcold = time.perf_counter() - t0
    t_pwarm, (vp, cp) = _best(jax_pack, repeats)
    t_pnp, _ = _best(lambda: T.pack_csr(indptr, indices, data, sched,
                                        pad_tiles_to=B), repeats)
    np.testing.assert_array_equal(np.asarray(vp), vp_np)
    np.testing.assert_array_equal(np.asarray(cp), cp_np)
    out["pack"] = {"asserted": "equal to host pack_csr (padded layout)",
                   "numpy_s": t_pnp, "jax_cold_s": t_pcold,
                   "jax_warm_s": t_pwarm}

    # --- sharded kernel step on the device pipeline's streams ---------
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal(sizes.size).astype(np.float32))
    vals, cols = T.pack_csr(indptr, indices, data, sched)
    seq = jax.jit(lambda: ich_spmv(jnp.asarray(vals), jnp.asarray(cols),
                                   jnp.asarray(sched.item_id), x,
                                   sizes.size, interpret=interp))
    dt_seq, ref_out = _timed(seq)
    krows = {}
    for p in shard_ps:
        _, low = lowerings[p]
        vpp, cpp = TJ.pack_csr_jax(indptr, indices, data, low.schedule,
                                   pad_tiles_to=B)
        fn = jax.jit(lambda v=vpp, c=cpp, lw=low, p=p: ich_spmv_sharded(
            v, c, lw.rowid, lw.blkid, x, sizes.size, p, B,
            interpret=interp))
        dt, out_p = _timed(fn)
        np.testing.assert_array_equal(
            np.asarray(out_p), np.asarray(ref_out),
            err_msg=f"compiled sharded p={p} != sequential grid")
        krows[str(p)] = {"total_s": dt,
                         "per_tile_us": 1e6 * dt / sched.n_tiles,
                         "vs_seq": dt_seq / dt}
    out["kernel_step"] = {
        "kernel": "ich_spmv_sharded",
        "mode": "jit(interpret=True) fallback" if interp else "compiled",
        "n_tiles": sched.n_tiles,
        "seq": {"total_s": dt_seq,
                "per_tile_us": 1e6 * dt_seq / sched.n_tiles},
        "sharded": krows}
    return out


def _print_compiled(cm: dict) -> None:
    for p, r in cm["pipeline"]["p"].items():
        print(f"compiled_pipeline,n={cm['n_items']},p={p},"
              f"numpy_s={r['numpy_s']:.5f},jax_cold_s={r['jax_cold_s']:.3f},"
              f"jax_warm_s={r['jax_warm_s']:.5f},"
              f"warm_speedup={r['warm_speedup']:.2f}")
    pk = cm["pack"]
    print(f"compiled_pack,numpy_s={pk['numpy_s']:.5f},"
          f"jax_warm_s={pk['jax_warm_s']:.5f}")
    ks = cm["kernel_step"]
    line = (f"compiled_kernel,{ks['kernel']},mode={ks['mode']},"
            f"seq_per_tile_us={ks['seq']['per_tile_us']:.1f}")
    for p, rec in ks["sharded"].items():
        line += f",p{p}_per_tile_us={rec['per_tile_us']:.1f}"
    print(line)


def main(sizes=DEFAULT_SIZES, repeats: int = 7, out_path: Path | None = None,
         kernel_step: bool = True, compiled: bool = True,
         compiled_only: bool = False) -> dict:
    if repeats < 1:
        raise ValueError(f"repeats must be >= 1, got {repeats}")
    sizes = sorted(int(s) for s in sizes)
    out_path = Path(out_path) if out_path else ROOT / "BENCH_schedule.json"
    if compiled_only:
        # the CI compiled-smoke step: run ONLY the compiled section and
        # merge it into the existing report so the uploaded
        # BENCH_schedule.json carries both trajectories
        report = (json.loads(out_path.read_text()) if out_path.exists()
                  else {"benchmark": "schedule_build"})
        cm = bench_compiled(sizes[0], repeats)
        report["compiled"] = cm
        _print_compiled(cm)
        out_path.write_text(json.dumps(report, indent=2) + "\n")
        print(f"# wrote {out_path}")
        return report
    report = {
        "benchmark": "schedule_build",
        "workload": "zipf(a=1.8) capped at 2000, 10% zero items, seed 1",
        "rows_per_tile": ROWS_PER_TILE,
        "repeats": repeats,
        "env": {"python": platform.python_version(),
                "numpy": np.__version__,
                "machine": platform.machine()},
        "builds": [],
    }
    print("n_items,width,n_tiles,build_vec_s,build_ref_s,build_speedup,"
          "pack_ref_s,pack_flat_s,pack_flat_speedup,pack_sharded_s,"
          "pack_sharded_speedup")
    for n in sizes:
        row = bench_build(n, repeats)
        report["builds"].append(row)
        pk = row["pack"]
        print(f"{row['n_items']},{row['width']},{row['n_tiles']},"
              f"{row['build_vec_s']:.5f},{row['build_ref_s']:.5f},"
              f"{row['build_speedup']:.1f},{pk['ref_s']:.5f},"
              f"{pk['flat']['vec_s']:.5f},{pk['flat']['speedup']:.1f},"
              f"{pk['sharded']['vec_s']:.5f},"
              f"{pk['sharded']['speedup']:.1f}")
    report["schedule_cache"] = []
    for n in sizes:
        row = bench_cache(n, repeats)
        report["schedule_cache"].append(row)
        print(f"cache,n={row['n_items']},cold_s={row['cold_s']:.5f},"
              f"warm_hit_s={row['warm_hit_s']:.6f},"
              f"hit_speedup={row['hit_speedup']:.1f}")
    rf = bench_refine_loop(sizes[0])
    report["refine_loop"] = rf
    print(f"refine_loop,n={rf['n_items']},p={rf['p']},"
          + ",".join(f"round{i}_makespan={m:.1f}"
                     for i, m in enumerate(rf["makespans"]))
          + f",improvement={100 * rf['improvement']:.1f}%"
          + f",imbalance_final={rf['imbalance_final']:.4f}")
    md = bench_moe_dispatch(sizes[0], repeats)
    report["moe_dispatch"] = md
    print(f"moe_dispatch,T={md['n_tokens']},E={md['n_experts']},"
          f"p={md['p']},plan_s={md['plan_s']:.5f},"
          f"scheduled_build_s={md['scheduled_build_s']:.5f},"
          f"schedule_overhead={md['schedule_overhead']:.2f}x,"
          + ",".join(f"round{i}_imbalance={v:.4f}"
                     for i, v in enumerate(md["imbalance_true"])))
    dg = bench_degradation(sizes[0])
    report["degradation"] = dg
    print(f"degradation,n={dg['n_items']},p={dg['p']},"
          f"clean_makespan={dg['clean_makespan']:.1f},"
          + ",".join(f"k{r['killed']}_inflation={r['inflation']:.3f}"
                     for r in dg["rows"]))
    rc = bench_recovery(sizes[0])
    report["recovery"] = rc
    print(f"recovery,n={rc['n_items']},p={rc['p']},"
          f"clean_static_makespan={rc['clean_static_makespan']:.1f},"
          + ",".join(f"k{r['killed']}_inflation={r['inflation']:.3f}"
                     f"(steal={r['steal_inflation']:.3f})"
                     for r in rc["rows"]))
    if kernel_step:
        ks = bench_kernel_step(sizes[0])
        report["kernel_step_interpret"] = ks
        for k in ("ich_spmv", "ich_bfs", "ich_kmeans"):
            line = (f"kernel_step,{k},n={ks['n_items']},"
                    f"seq_per_tile_us={ks[k]['seq']['per_tile_us']:.1f}")
            for p, rec in ks[k]["sharded"].items():
                line += (f",p{p}_per_tile_us={rec['per_tile_us']:.1f}"
                         f",p{p}_speedup={rec['per_tile_speedup']:.1f}")
            print(line)
    if compiled:
        cm = bench_compiled(sizes[0], repeats)
        report["compiled"] = cm
        _print_compiled(cm)
    out_path.write_text(json.dumps(report, indent=2) + "\n")
    print(f"# wrote {out_path}")
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--sizes", default=",".join(map(str, DEFAULT_SIZES)),
                    help="comma-separated item counts")
    ap.add_argument("--repeats", type=int, default=7,
                    help="best-of repeats for the vectorized path")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default: repo-root "
                         "BENCH_schedule.json)")
    ap.add_argument("--no-kernel-step", action="store_true",
                    help="skip the interpret-mode kernel step measurement")
    ap.add_argument("--no-compiled", action="store_true",
                    help="skip the compiled-mode section")
    ap.add_argument("--compiled-smoke", action="store_true",
                    help="run ONLY the compiled-mode section and merge it "
                         "into an existing BENCH_schedule.json")
    args = ap.parse_args()
    main(sizes=[int(s) for s in args.sizes.split(",")],
         repeats=args.repeats, out_path=args.out,
         kernel_step=not args.no_kernel_step,
         compiled=not args.no_compiled,
         compiled_only=args.compiled_smoke)
