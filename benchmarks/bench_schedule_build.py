"""Schedule-construction performance benchmark (the repo's perf trajectory).

Measures, across item counts (default 10k / 100k / 1M):

  * `build_schedule` wall time — vectorized array program vs the
    `_reference_*` loop oracle (the seed implementation) — plus the same
    comparison for `pack_csr`; outputs are asserted identical, so the
    speedup numbers can't drift away from correctness;
  * the `repro.sched` schedule cache: a repeated `LoopScheduler.schedule()`
    call with identical inputs must be an LRU hit that returns the
    previously built `Schedule` object and skips construction entirely
    (asserted on the cache counters and on object identity); warm-path
    cost is the fingerprint hash;
  * interpret-mode step cost of the three ich_* Pallas kernels at the
    smallest size (interpret mode is Python-per-grid-step, so larger sizes
    measure the interpreter, not the kernel).

Writes `BENCH_schedule.json` at the repo root so future PRs have a recorded
trajectory to regress against, and prints one CSV line per measurement.
Run standalone:

  PYTHONPATH=src python -m benchmarks.bench_schedule_build
  PYTHONPATH=src python -m benchmarks.bench_schedule_build --sizes 10000

or through the driver: PYTHONPATH=src python -m benchmarks.run --bench schedule
"""
from __future__ import annotations

import argparse
import json
import platform
import time
from pathlib import Path

import numpy as np

from repro.core import tiling as T

ROOT = Path(__file__).resolve().parent.parent
DEFAULT_SIZES = (10_000, 100_000, 1_000_000)
ROWS_PER_TILE = 8


def workload(n: int, seed: int = 1) -> np.ndarray:
    """Heavy-tailed per-item work: zipf(1.8) capped at 2000, 10% zero items
    (the empty-CSR-row / isolated-vertex case)."""
    rng = np.random.default_rng(seed)
    sizes = np.minimum(rng.zipf(1.8, n), 2000).astype(np.int64)
    sizes[rng.random(n) < 0.1] = 0
    return sizes


def _best(fn, repeats: int):
    best, out = float("inf"), None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def _csr(sizes: np.ndarray, seed: int = 2):
    rng = np.random.default_rng(seed)
    indptr = np.concatenate([[0], np.cumsum(sizes)])
    nnz = int(indptr[-1])
    indices = rng.integers(0, sizes.size, nnz).astype(np.int32)
    data = rng.standard_normal(nnz).astype(np.float32)
    return indptr, indices, data


def bench_build(n: int, repeats: int) -> dict:
    """Vectorized vs reference construction at n items (outputs asserted
    equal before any timing is reported)."""
    sizes = workload(n)
    ref_repeats = repeats if n <= 100_000 else 1  # ref at 1M is seconds/run
    t_vec, sched = _best(lambda: T.build_schedule(
        sizes, rows_per_tile=ROWS_PER_TILE), repeats)
    t_ref, ref = _best(lambda: T._reference_build_schedule(
        sizes, rows_per_tile=ROWS_PER_TILE), ref_repeats)
    np.testing.assert_array_equal(sched.item_id, ref.item_id)
    np.testing.assert_array_equal(sched.seg_start, ref.seg_start)
    np.testing.assert_array_equal(sched.seg_len, ref.seg_len)

    indptr, indices, data = _csr(sizes)
    t_pvec, packed = _best(
        lambda: T.pack_csr(indptr, indices, data, sched), repeats)
    t_pref, packed_ref = _best(
        lambda: T._reference_pack_csr(indptr, indices, data, sched), 1)
    np.testing.assert_array_equal(packed[0], packed_ref[0])
    np.testing.assert_array_equal(packed[1], packed_ref[1])
    return {
        "n_items": n,
        "nnz": int(sizes.sum()),
        "width": sched.width,
        "n_tiles": sched.n_tiles,
        "build_vec_s": t_vec,
        "build_ref_s": t_ref,
        "build_speedup": t_ref / t_vec,
        "pack_vec_s": t_pvec,
        "pack_ref_s": t_pref,
        "pack_speedup": t_pref / t_pvec,
    }


def bench_cache(n: int, repeats: int) -> dict:
    """Schedule-cache behavior at n items (the serving path's reuse story).

    The second `schedule()` call with identical inputs MUST be a cache hit
    that skips construction entirely: asserted on the LRU counters (one
    miss total) and on object identity (the very same `Schedule` comes
    back). The warm path pays only the cost-fingerprint hash.
    """
    from repro.sched import LoopScheduler

    sizes = workload(n)
    sched = LoopScheduler()
    t0 = time.perf_counter()
    first = sched.schedule(sizes)
    t_cold = time.perf_counter() - t0
    assert sched.cache_stats.misses == 1 and sched.cache_stats.hits == 0
    t_warm, again = _best(lambda: sched.schedule(sizes), repeats)
    assert again is first, "cache hit must return the cached Schedule object"
    assert sched.cache_stats.misses == 1, \
        "cache hit must not re-run schedule construction"
    assert sched.cache_stats.hits == repeats
    return {
        "n_items": n,
        "cold_s": t_cold,
        "warm_hit_s": t_warm,
        "hit_speedup": t_cold / max(t_warm, 1e-12),
        "hits": sched.cache_stats.hits,
        "misses": sched.cache_stats.misses,
    }


def bench_kernel_step(n: int) -> dict:
    """Steady-state interpret-mode cost of one full schedule sweep for each
    ich_* kernel (first call = trace/compile, second call timed). Ops are
    built through the `repro.sched` registry (the facade path)."""
    import jax

    from repro.sched import LoopScheduler

    sched = LoopScheduler(rows_per_tile=ROWS_PER_TILE)
    rng = np.random.default_rng(3)
    sizes = workload(n)
    indptr, indices, data = _csr(sizes)
    out = {"n_items": n}

    spmv = sched.build("spmv", indptr, indices, data)
    x = rng.standard_normal(sizes.size).astype(np.float32)
    jax.block_until_ready(spmv(x, interpret=True))  # trace + compile
    t0 = time.perf_counter()
    jax.block_until_ready(spmv(x, interpret=True))
    dt = time.perf_counter() - t0
    n_tiles = spmv.rowid.shape[0]
    out["ich_spmv"] = {"total_s": dt, "n_tiles": int(n_tiles),
                       "per_tile_us": 1e6 * dt / n_tiles}

    bfs = sched.build("bfs", indptr, indices)
    frontier = (rng.random(sizes.size) < 0.05).astype(np.float32)
    visited = frontier.copy()
    jax.block_until_ready(bfs.step(frontier, visited, interpret=True))
    t0 = time.perf_counter()
    jax.block_until_ready(bfs.step(frontier, visited, interpret=True))
    dt = time.perf_counter() - t0
    out["ich_bfs"] = {"total_s": dt, "n_tiles": bfs.schedule.n_tiles,
                      "per_tile_us": 1e6 * dt / bfs.schedule.n_tiles}

    km = sched.build("kmeans", np.maximum(sizes.astype(np.float64), 1.0))
    pts = rng.standard_normal((sizes.size, 8)).astype(np.float32)
    cent = rng.standard_normal((16, 8)).astype(np.float32)
    jax.block_until_ready(km(pts, cent, interpret=True))
    t0 = time.perf_counter()
    jax.block_until_ready(km(pts, cent, interpret=True))
    dt = time.perf_counter() - t0
    out["ich_kmeans"] = {"total_s": dt, "n_tiles": km.schedule.n_tiles,
                         "per_tile_us": 1e6 * dt / km.schedule.n_tiles}
    return out


def main(sizes=DEFAULT_SIZES, repeats: int = 7, out_path: Path | None = None,
         kernel_step: bool = True) -> dict:
    if repeats < 1:
        raise ValueError(f"repeats must be >= 1, got {repeats}")
    sizes = sorted(int(s) for s in sizes)
    report = {
        "benchmark": "schedule_build",
        "workload": "zipf(a=1.8) capped at 2000, 10% zero items, seed 1",
        "rows_per_tile": ROWS_PER_TILE,
        "repeats": repeats,
        "env": {"python": platform.python_version(),
                "numpy": np.__version__,
                "machine": platform.machine()},
        "builds": [],
    }
    print("n_items,width,n_tiles,build_vec_s,build_ref_s,build_speedup,"
          "pack_vec_s,pack_ref_s,pack_speedup")
    for n in sizes:
        row = bench_build(n, repeats)
        report["builds"].append(row)
        print(f"{row['n_items']},{row['width']},{row['n_tiles']},"
              f"{row['build_vec_s']:.5f},{row['build_ref_s']:.5f},"
              f"{row['build_speedup']:.1f},{row['pack_vec_s']:.5f},"
              f"{row['pack_ref_s']:.5f},{row['pack_speedup']:.1f}")
    report["schedule_cache"] = []
    for n in sizes:
        row = bench_cache(n, repeats)
        report["schedule_cache"].append(row)
        print(f"cache,n={row['n_items']},cold_s={row['cold_s']:.5f},"
              f"warm_hit_s={row['warm_hit_s']:.6f},"
              f"hit_speedup={row['hit_speedup']:.1f}")
    if kernel_step:
        ks = bench_kernel_step(sizes[0])
        report["kernel_step_interpret"] = ks
        for k in ("ich_spmv", "ich_bfs", "ich_kmeans"):
            print(f"kernel_step,{k},n={ks['n_items']},"
                  f"total_s={ks[k]['total_s']:.3f},"
                  f"per_tile_us={ks[k]['per_tile_us']:.1f}")
    out_path = Path(out_path) if out_path else ROOT / "BENCH_schedule.json"
    out_path.write_text(json.dumps(report, indent=2) + "\n")
    print(f"# wrote {out_path}")
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--sizes", default=",".join(map(str, DEFAULT_SIZES)),
                    help="comma-separated item counts")
    ap.add_argument("--repeats", type=int, default=7,
                    help="best-of repeats for the vectorized path")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default: repo-root "
                         "BENCH_schedule.json)")
    ap.add_argument("--no-kernel-step", action="store_true",
                    help="skip the interpret-mode kernel step measurement")
    args = ap.parse_args()
    main(sizes=[int(s) for s in args.sizes.split(",")],
         repeats=args.repeats, out_path=args.out,
         kernel_step=not args.no_kernel_step)
