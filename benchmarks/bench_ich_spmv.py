"""Kernel-schedule benchmark: iCh-banded tile width vs fixed widths on the
Table-1 matrices. Metric = slot efficiency (useful nnz / padded R*W slots):
the TPU analogue of the paper's chunk-size tuning problem — too-wide tiles
waste MXU slots on padding, too-narrow tiles split rows into many segments
(per-tile dispatch overhead). Run standalone:

  PYTHONPATH=src python -m benchmarks.bench_ich_spmv
"""
import numpy as np

from repro.core import workloads as WL
from repro.kernels.ich_spmv.ich_spmv import ich_tile_width, pack_tiles


def main(n=20000):
    print("matrix,ich_W,ich_eff,ich_tiles,best_fixed_W,best_fixed_eff,naive_max_eff")
    rows = []
    for spec in WL.TABLE1:
        nnz_rows = WL.matrix_row_nnz(spec, n).astype(np.int64)
        indptr = np.concatenate([[0], np.cumsum(nnz_rows)])
        nnz = int(indptr[-1])
        indices = np.zeros(nnz, np.int32)
        data = np.ones(nnz, np.float32)

        TILE_OVERHEAD = 64  # slot-equivalents per tile (grid-step dispatch)

        def eff(width):
            vals, cols, rowid, W = pack_tiles(indptr, indices, data,
                                              rows_per_tile=8, width=width)
            slots = vals.shape[0] * vals.shape[1] * vals.shape[2]
            cost = slots + TILE_OVERHEAD * vals.shape[0]
            return nnz / cost, vals.shape[0], W

        wi = ich_tile_width(nnz_rows)
        e_ich, t_ich, _ = eff(wi)
        fixed = {w: eff(w)[0] for w in (8, 16, 32, 64, 128, 256, 512)}
        wb = max(fixed, key=fixed.get)
        # naive: width = max row nnz (no row splitting needed)
        e_naive, _, _ = eff(int(min(max(nnz_rows), 512)))
        print(f"{spec.name},{wi},{e_ich:.3f},{t_ich},{wb},{fixed[wb]:.3f},{e_naive:.3f}")
        rows.append((e_ich, fixed[wb], e_naive))
    a = np.asarray(rows)
    print(f"MEAN,,{a[:,0].mean():.3f},,,{a[:,1].mean():.3f},{a[:,2].mean():.3f}")


if __name__ == "__main__":
    main()
