"""Serving benchmark: tail latency vs offered load per dispatch policy.

Sweeps the continuous batcher (serve/batcher.py) over offered-load levels
x dispatch policies on the SIMULATED backend — a seeded `StepCostModel`
prices each step and a `SimClock` advances by it, so the whole sweep is
bit-deterministic (CI-safe, zero machine noise) while still exercising the
real queue/policy/batcher code paths. Arrivals are open-loop Poisson
(arrivals never wait for completions: overload shows up as backlog and
tail latency, not reduced load) with heavy-tailed zipf prompt lengths, so
a monster prompt really does land in front of short ones.

Policies compared (>= 3, the ISSUE contract):

  * ``fcfs-static``  — arrival order, fixed chunk (head-of-line baseline);
  * ``round-robin``  — fixed chunk rotating across prefill streams;
  * ``ich-adaptive`` — per-request iCh chunk divisors + refined-cost
    SRPT-with-aging target selection through the `sched` facade.

Headline assertion (reproduced in the CI smoke): at the HIGHEST offered
load, ich-adaptive's p99 end-to-end latency must not exceed fcfs-static's,
for every sweep seed. Writes `BENCH_serve.json` at the repo root so future
PRs have a recorded serving trajectory to regress against.

Run standalone:

  PYTHONPATH=src python -m benchmarks.bench_serve
  PYTHONPATH=src python -m benchmarks.bench_serve --fast

or through the driver: PYTHONPATH=src python -m benchmarks.run --bench serve
"""
from __future__ import annotations

import argparse
import json
import platform
from pathlib import Path

from repro.serve.batcher import (ContinuousBatcher, SimBackend, SimClock,
                                 StepCostModel, make_request_factory)
from repro.serve.loadgen import LengthDist, OpenPoissonLoadGen
from repro.serve.policies import FCFSStatic, IChAdaptive, RoundRobin
from repro.serve.queue import AdmissionQueue

ROOT = Path(__file__).resolve().parent.parent

RATES = (10.0, 30.0, 60.0)       # offered load, requests/s (low/mid/high)
SEEDS = (0, 1, 2, 3, 4)          # arrival-trace seeds
N_ARRIVALS = 80
N_NEW = 8                        # decode budget per request
MAX_RUNNING = 8                  # continuous-batch width
COST_SEED = 2                    # StepCostModel jitter stream
SLO_DEADLINE_S = 2.0             # the SLO section's per-request budget


def make_policies(chunk: int = 64) -> list:
    return [FCFSStatic(chunk=chunk), RoundRobin(chunk=chunk),
            IChAdaptive()]


def load_gen(rate: float, seed: int, deadline_s=None) -> OpenPoissonLoadGen:
    """Heavy-tailed prompts (zipf alpha=1.4 over [16, 2048], the
    tests/_paper_grid.py family shape): most prompts are short, a few are
    monsters — the regime where chunk-size and target-selection policy
    decide the tail."""
    return OpenPoissonLoadGen(
        rate,
        prompt_lens=LengthDist("zipf", 16, 2048, alpha=1.4),
        output_lens=LengthDist("fixed", N_NEW, N_NEW),
        deadline_s=deadline_s, seed=seed)


def run_one(policy, rate: float, seed: int, deadline_s=None) -> dict:
    gen = load_gen(rate, seed, deadline_s)
    b = ContinuousBatcher(
        policy,
        queue=AdmissionQueue(max_pending=4 * N_ARRIVALS,
                             max_running=MAX_RUNNING),
        backend=SimBackend(StepCostModel(seed=COST_SEED)),
        clock=SimClock())
    m = b.run(gen.arrivals(N_ARRIVALS),
              make_request=make_request_factory(gen, vocab_size=512))
    s = m.summary()
    return {
        "policy": policy.name, "rate": rate, "seed": seed,
        "deadline_s": deadline_s,
        "ttft_p50": s["ttft"]["p50"], "ttft_p99": s["ttft"]["p99"],
        "e2e_p50": s["e2e"]["p50"], "e2e_p99": s["e2e"]["p99"],
        "per_token_p99": s["per_token"]["p99"],
        "goodput_tok_s": s["goodput_tok_s"],
        "n_completed": s["n_completed"], "n_degraded": s["n_degraded"],
        "n_shed_admission": s["n_shed_admission"],
        "n_tokens_shed": s["n_tokens_shed"],
        "elapsed_s": s["elapsed_s"],
    }


def main(*, rates=RATES, seeds=SEEDS, out_path=None) -> dict:
    rates = tuple(sorted(rates))
    report = {
        "host": platform.node(), "python": platform.python_version(),
        "config": {"rates": list(rates), "seeds": list(seeds),
                   "n_arrivals": N_ARRIVALS, "n_new": N_NEW,
                   "max_running": MAX_RUNNING, "cost_seed": COST_SEED,
                   "prompt_lens": "zipf(16, 2048, alpha=1.4)"},
        "sweep": [], "slo": [],
    }

    # ---- tail latency vs offered load (no deadlines: pure queueing) ----
    for rate in rates:
        for seed in seeds:
            for pol in make_policies():
                row = run_one(pol, rate, seed)
                report["sweep"].append(row)
                print(f"serve,{row['policy']},rate={rate:g},seed={seed},"
                      f"ttft_p99={row['ttft_p99']:.3f},"
                      f"e2e_p99={row['e2e_p99']:.3f},"
                      f"goodput={row['goodput_tok_s']:.1f}")

    # ---- headline claim: adaptive beats the static baseline's tail at
    #      the highest offered load, on every seed ----
    top = rates[-1]
    failures = []
    for seed in seeds:
        by_pol = {r["policy"]: r for r in report["sweep"]
                  if r["rate"] == top and r["seed"] == seed}
        ich, fcfs = by_pol["ich-adaptive"], by_pol["fcfs-static"]
        margin = 1.0 - ich["e2e_p99"] / fcfs["e2e_p99"]
        print(f"claim,rate={top:g},seed={seed},"
              f"ich_p99={ich['e2e_p99']:.3f},fcfs_p99={fcfs['e2e_p99']:.3f},"
              f"margin={100 * margin:.1f}%")
        if ich["e2e_p99"] > fcfs["e2e_p99"]:
            failures.append((seed, ich["e2e_p99"], fcfs["e2e_p99"]))
    report["claim"] = {
        "rate": top,
        "ok": not failures,
        "text": "ich-adaptive p99 e2e <= fcfs-static p99 e2e at top load",
    }

    # ---- SLO section: same top load with a deadline, goodput + shed ----
    for pol in make_policies():
        row = run_one(pol, top, seeds[0], deadline_s=SLO_DEADLINE_S)
        report["slo"].append(row)
        print(f"slo,{row['policy']},rate={top:g},"
              f"deadline={SLO_DEADLINE_S:g}s,"
              f"goodput={row['goodput_tok_s']:.1f},"
              f"n_degraded={row['n_degraded']},"
              f"n_tokens_shed={row['n_tokens_shed']}")

    out_path = Path(out_path) if out_path else ROOT / "BENCH_serve.json"
    out_path.write_text(json.dumps(report, indent=2) + "\n")
    print(f"# wrote {out_path}")

    if failures:
        raise SystemExit(
            "serving claim FAILED: ich-adaptive p99 e2e > fcfs-static at "
            f"rate={top}: " + ", ".join(
                f"seed={s} ({a:.3f} > {b:.3f})" for s, a, b in failures))
    print(f"# claim OK at rate={top:g}: ich-adaptive p99 e2e <= "
          f"fcfs-static on all {len(seeds)} seeds")
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fast", action="store_true",
                    help="single-seed smoke (claim still asserted)")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default: repo-root "
                         "BENCH_serve.json)")
    args = ap.parse_args()
    main(seeds=(SEEDS[0],) if args.fast else SEEDS, out_path=args.out)
