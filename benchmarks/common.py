"""Shared helpers for the paper-figure benchmarks.

All benchmarks use T(app, schedule, p) = best makespan across the Table 2
parameter grid (paper §6.1) and speedup = T(app, guided, 1) / T(app, s, p)
(eq. 9). Nested-loop apps (BFS levels, K-Means rounds) sum per-loop
makespans (fork-join barrier between loops), with fresh scheduler state per
loop, and grid parameters chosen once per app (as a user would).

Simulation routes through the `repro.sched.LoopScheduler` facade (its
direct simulator pass-through — policy sweeps need no tile construction).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import policies as P
from repro.core.simulator import SimParams
from repro.sched import LoopScheduler

THREADS = (1, 2, 4, 8, 14, 28)
METHODS = ("guided", "dynamic", "taskloop", "binlpt", "stealing", "ich")
PARAMS = SimParams()
SCHED = LoopScheduler(sim_params=PARAMS)


def method_grid(name: str, p: int) -> list[P.Policy]:
    return [pol for pol in P.paper_policy_grid(p) if pol.name == name]


def app_time(loops: list[np.ndarray], p: int, pol: P.Policy,
             estimates: list[np.ndarray] = None,
             params: SimParams = PARAMS) -> float:
    """Sum of makespans over the app's parallel loops under one policy."""
    total = 0.0
    for i, costs in enumerate(loops):
        est = estimates[i] if estimates is not None else None
        total += SCHED.simulate(costs, policy=pol, p=p, params=params,
                                estimate=est).makespan
    return total


def best_time(loops, p: int, method: str, estimates=None,
              params: SimParams = PARAMS) -> float:
    return min(app_time(loops, p, pol, estimates, params)
               for pol in method_grid(method, p))


def speedup_table(loops, estimates=None, threads=THREADS,
                  methods=METHODS, params: SimParams = PARAMS):
    """-> {method: {p: speedup}} with the paper's eq. 9 definition."""
    t1 = best_time(loops, 1, "guided", estimates, params)
    out = {}
    for m in methods:
        out[m] = {p: t1 / best_time(loops, p, m, estimates, params)
                  for p in threads}
    return out


def rank_of_ich(table: dict, p: int = 28, tol: float = 0.02) -> int:
    """1-based rank of iCh at thread count p (paper: top-3). Methods within
    `tol` relative speedup are treated as ties (the paper's bar charts have
    comparable noise; sub-2%% orderings are not meaningful)."""
    ich = table["ich"][p]
    better = sum(1 for m in table if m != "ich" and table[m][p] > ich * (1 + tol))
    return better + 1


def gap_to_best(table: dict, p: int = 28) -> float:
    """(best - ich)/best at p (paper: avg ~5.4%)."""
    best = max(table[m][p] for m in table)
    return (best - table["ich"][p]) / best


def csv_rows(app: str, table: dict) -> list[str]:
    rows = []
    for m, sp in table.items():
        for p, v in sp.items():
            rows.append(f"{app},{m},{p},{v:.3f}")
    return rows


def write_csv(path: str, header: str, rows: list[str]):
    import pathlib
    f = pathlib.Path(path)
    f.parent.mkdir(parents=True, exist_ok=True)
    f.write_text(header + "\n" + "\n".join(rows) + "\n")
