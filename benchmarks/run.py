"""Benchmark driver: one function per paper table/figure.

Prints ``name,metric,value`` CSV lines, writes per-figure CSVs under
results/paper/, and validates the paper's headline claims:
  * iCh is top-3 at 28 threads on every application (paper §6.1);
  * iCh's average gap to the best method is small (paper: ~5.4%);
  * iCh beats plain stealing on BFS and K-Means (paper: +9.6%..54%).

Usage: PYTHONPATH=src python -m benchmarks.run [--fast] [--only NAME]
       PYTHONPATH=src python -m benchmarks.run --bench schedule [--fast]
       PYTHONPATH=src python -m benchmarks.run --bench serve [--fast]

`--bench paper` (default) reproduces the paper figures; `--bench schedule`
runs the schedule-construction perf benchmark (bench_schedule_build) and
refreshes BENCH_schedule.json at the repo root; `--bench serve` runs the
serving tail-latency sweep (bench_serve: offered load x dispatch policy,
simulated clock) and refreshes BENCH_serve.json.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from . import bench_paper as B
from . import common as C


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller n (quick smoke; claims still checked)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--bench", default="paper",
                    choices=["paper", "schedule", "serve"],
                    help="paper = figure reproduction; schedule = "
                         "schedule-construction perf (BENCH_schedule.json); "
                         "serve = serving tail-latency sweep "
                         "(BENCH_serve.json)")
    args = ap.parse_args()
    if args.bench == "schedule":
        from . import bench_schedule_build as BS
        BS.main(sizes=(10_000,) if args.fast else BS.DEFAULT_SIZES)
        return
    if args.bench == "serve":
        from . import bench_serve as BV
        BV.main(seeds=(BV.SEEDS[0],) if args.fast else BV.SEEDS)
        return
    n = 20_000 if args.fast else 50_000
    n_spmv = 40_000 if args.fast else 100_000

    t_start = time.time()
    tables = {}
    all_rows = []

    benches = {
        "synth": lambda: B.bench_synth(n),
        "bfs": lambda: B.bench_bfs(n),
        "kmeans": lambda: B.bench_kmeans(n),
        "lavamd": lambda: B.bench_lavamd(),
        "spmv": lambda: B.bench_spmv(n_spmv),
        "sensitivity": lambda: B.bench_sensitivity(),
        "moe_balance": lambda: B.bench_moe_balance(),
    }
    for name, fn in benches.items():
        if args.only and name != args.only:
            continue
        t0 = time.time()
        rows, summary = fn()
        dt = time.time() - t0
        all_rows += rows
        C.write_csv(f"results/paper/{name}.csv", "app,method,p,value", rows)
        print(f"# {name}: {dt:.1f}s")
        if name in ("synth", "bfs", "kmeans", "lavamd"):
            tables.update(summary)
        elif name == "spmv":
            tables["spmv_geo"] = summary["spmv_geo"]
        for r in rows:
            print(r)

    # ---- paper-claim validation (the reproduction scorecard) ----
    speedup_apps = {k: v for k, v in tables.items() if k != "spmv_geo"}
    print("\n# === paper-claim validation (28 threads) ===")
    ranks, gaps = {}, {}
    for app, table in speedup_apps.items():
        r = C.rank_of_ich(table)
        g = C.gap_to_best(table)
        ranks[app], gaps[app] = r, g
        best_m = max(table, key=lambda m: table[m][28])
        print(f"claim,{app},ich_rank,{r},gap_to_best,{100*g:.1f}%,best={best_m}")
    if "spmv_geo" in tables:
        geo = tables["spmv_geo"]
        order = sorted(geo, key=geo.get, reverse=True)
        r = order.index("ich") + 1
        g = (geo[order[0]] - geo["ich"]) / geo[order[0]]
        ranks["spmv"], gaps["spmv"] = r, g
        print(f"claim,spmv(geomean),ich_rank,{r},gap_to_best,{100*g:.1f}%,best={order[0]}")
    if ranks:
        print(f"claim,ALL,ich_always_top3,{max(ranks.values()) <= 3}")
        print(f"claim,ALL,avg_gap_to_best,{100*float(np.mean(list(gaps.values()))):.1f}%"
              f" (paper: ~5.4%)")
        for app in ("bfs/Uniform", "bfs/Scale-Free", "kmeans"):
            if app in speedup_apps:
                t = speedup_apps[app]
                print(f"claim,{app},ich_vs_stealing,"
                      f"{100*(t['ich'][28]/t['stealing'][28]-1):+.1f}% (paper: +9.6%/+54%/+12.3%)")
    print(f"# total {time.time()-t_start:.1f}s")


if __name__ == "__main__":
    main()
