"""Schedule-construction benchmark for the iCh kernel family (BFS, K-Means,
SpMV) + the schedule/simulator cross-check.

For each paper application we build the iCh tile schedule from its per-item
work array and report slot efficiency (useful work units / padded R*W slots)
and the predicted per-tile load imbalance. We then CROSS-CHECK the
construction against the discrete-event simulator: the schedule's tiles,
replayed as an explicit pretiled central-queue policy over the flattened
work-unit cost array, must be dispatched chunk-for-chunk with exactly the
work `TileSchedule.tile_cost` predicts. This ties the kernel layer to the
simulator layer — the same cost array drives both. Run standalone:

  PYTHONPATH=src python -m benchmarks.bench_ich_kernels
"""
import numpy as np

from repro.core import policies as P
from repro.core import workloads as WL
from repro.core.simulator import simulate
from repro.core.tiling import TileSchedule, build_schedule
from repro.kernels.ich_kmeans.ops import quantize_costs


def crosscheck(schedule: TileSchedule, costs, sizes, p: int = 8) -> float:
    """Replay the schedule in the simulator; return max |tile - chunk| work
    mismatch (must be ~0)."""
    unit_costs = schedule.unit_costs(costs, sizes)
    ranges = schedule.slot_ranges()
    res = simulate(unit_costs, p, P.pretiled(ranges), record_chunks=True)
    sim_work = np.array([w for (_, _, _, w) in res.chunk_log])
    predicted = schedule.tile_cost(costs, sizes)
    assert len(sim_work) == schedule.n_tiles
    return float(np.abs(sim_work - predicted).max())


def report(app: str, schedule: TileSchedule, costs, sizes):
    work = schedule.tile_work()
    slots = schedule.n_tiles * schedule.rows_per_tile * schedule.width
    eff = work.sum() / slots
    imb = work.max() / max(work.mean(), 1e-12)
    err = crosscheck(schedule, costs, sizes)
    ok = "OK" if err < 1e-6 else f"FAIL({err:.2e})"
    print(f"{app},{schedule.width},{schedule.n_tiles},{eff:.3f},{imb:.3f},{ok}")
    return err


def main(n: int = 20_000) -> float:
    print("app,W,tiles,slot_eff,tile_imbalance,sim_crosscheck")
    worst = 0.0

    # BFS: per-vertex cost = degree (uniform + scale-free graphs, §5.1)
    rng = np.random.default_rng(0)
    for kind, deg in (("bfs/uniform", rng.integers(1, 21, n)),
                      ("bfs/scale_free",
                       np.minimum(rng.zipf(2.3, n), n // 10))):
        sizes = deg.astype(np.int64)
        sched = build_schedule(sizes)
        worst = max(worst, report(kind, sched, sizes.astype(float), sizes))

    # K-Means: heavy-tailed per-point predicted cost, reshuffled per round
    rounds, _ = WL.kmeans_rounds(n=n, rounds=3)
    for r, costs in enumerate(rounds):
        sizes = quantize_costs(costs)
        sched = build_schedule(sizes)
        worst = max(worst, report(f"kmeans/round{r}", sched, costs, sizes))

    # SpMV: Table-1 stat-matched row-nnz arrays (subset for speed)
    for spec in WL.TABLE1[:5]:
        sizes = WL.matrix_row_nnz(spec, n).astype(np.int64)
        sched = build_schedule(sizes)
        worst = max(worst, report(f"spmv/{spec.name}", sched,
                                  sizes.astype(float), sizes))

    print(f"MAX_CROSSCHECK_ERR,{worst:.3e}")
    return worst


if __name__ == "__main__":
    main()
