"""Schedule-construction benchmark for the iCh kernel family (BFS, K-Means,
SpMV) + the schedule/simulator cross-check, on the unified `repro.sched` API.

For each paper application we build the schedule through the
`LoopScheduler` facade from its per-item cost description and report slot
efficiency (useful work units / padded R*W slots) and the predicted
per-tile load imbalance. We then CROSS-CHECK the construction against the
discrete-event simulator via `Schedule.replay()`: the schedule's tiles,
re-dispatched as explicit central-queue chunks over the flattened
work-unit cost array, must be handed out chunk-for-chunk with exactly the
work `Schedule.tile_cost()` predicts. This ties the kernel layer to the
simulator layer — the same `Schedule` object drives both. Run standalone:

  PYTHONPATH=src python -m benchmarks.bench_ich_kernels
"""
import numpy as np

from repro.core import workloads as WL
from repro.sched import ExplicitCosts, LoopScheduler
from repro.sched.api import Schedule

SCHED = LoopScheduler(p=8)


def crosscheck(s: Schedule) -> float:
    """Replay the schedule in the simulator; return max |tile - chunk| work
    mismatch (must be ~0)."""
    res = s.replay(record_chunks=True)
    sim_work = np.array([w for (_, _, _, w) in res.chunk_log])
    assert len(sim_work) == s.n_tiles
    return float(np.abs(sim_work - s.tile_cost()).max())


def report(app: str, s: Schedule):
    work = s.tile_work()
    slots = s.n_tiles * s.rows_per_tile * s.width
    eff = work.sum() / slots
    imb = work.max() / max(work.mean(), 1e-12)
    err = crosscheck(s)
    ok = "OK" if err < 1e-6 else f"FAIL({err:.2e})"
    print(f"{app},{s.width},{s.n_tiles},{eff:.3f},{imb:.3f},{ok}")
    return err


def main(n: int = 20_000) -> float:
    print("app,W,tiles,slot_eff,tile_imbalance,sim_crosscheck")
    worst = 0.0

    # BFS: per-vertex cost = degree (uniform + scale-free graphs, §5.1)
    rng = np.random.default_rng(0)
    for kind, deg in (("bfs/uniform", rng.integers(1, 21, n)),
                      ("bfs/scale_free",
                       np.minimum(rng.zipf(2.3, n), n // 10))):
        s = SCHED.schedule(deg.astype(np.int64))
        worst = max(worst, report(kind, s))

    # K-Means: heavy-tailed per-point predicted cost, reshuffled per round
    # (float costs quantize to >= 1 work unit on the provider's path)
    rounds, _ = WL.kmeans_rounds(n=n, rounds=3)
    for r, costs in enumerate(rounds):
        s = SCHED.schedule(ExplicitCosts(np.asarray(costs, np.float64)))
        worst = max(worst, report(f"kmeans/round{r}", s))

    # SpMV: Table-1 stat-matched row-nnz arrays (subset for speed)
    for spec in WL.TABLE1[:5]:
        sizes = WL.matrix_row_nnz(spec, n).astype(np.int64)
        s = SCHED.schedule(sizes)
        worst = max(worst, report(f"spmv/{spec.name}", s))

    print(f"MAX_CROSSCHECK_ERR,{worst:.3e}")
    stats = SCHED.cache_stats
    print(f"SCHEDULE_CACHE,misses,{stats.misses},hits,{stats.hits}")
    return worst


if __name__ == "__main__":
    main()
