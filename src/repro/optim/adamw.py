"""AdamW with fp32 master state, global-norm clipping, and a linear-warmup
cosine schedule — implemented directly in JAX (no external deps), sharded
with the same PartitionSpecs as the parameters (ZeRO-style: optimizer state
inherits the 2-D param sharding, so m/v add ~2x param bytes / (tp*fsdp))."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def init_state(params):
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return {"m": zeros, "v": jax.tree.map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def opt_pspecs(param_pspecs):
    from jax.sharding import PartitionSpec as P
    return {"m": param_pspecs, "v": jax.tree.map(lambda x: x, param_pspecs),
            "step": P()}


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def apply_updates(params, grads, state, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        step_ = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            step_ = step_ + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {
        "grad_norm": gnorm, "lr": lr}
