"""Gradient compression for the cross-pod data-parallel all-reduce.

int8 block-quantization with error feedback (EF-SGD style): the quantization
residual is carried in the train state and added back next step, so the
compression is unbiased in the long run. Intended for the DCN (cross-pod)
hop where bandwidth is ~10x scarcer than ICI; within-pod reduction stays
full-precision. Toggle via TrainConfig.grad_compress.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to_block(x):
    n = x.size
    pad = (-n) % BLOCK
    return jnp.pad(x.reshape(-1), (0, pad)), n


def quantize(g: jnp.ndarray):
    """g (any shape) -> (int8 blocks, fp32 scales per block)."""
    flat, n = _pad_to_block(g.astype(jnp.float32))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-12)), -127, 127)
    return q.astype(jnp.int8), scale, n


def dequantize(q, scale, n, shape):
    return (q.astype(jnp.float32) * scale).reshape(-1)[:n].reshape(shape)


def compress_with_feedback(g, err):
    """Returns (g_compressed, new_err). err is the carried residual."""
    target = g.astype(jnp.float32) + err
    q, s, n = quantize(target)
    deq = dequantize(q, s, n, g.shape)
    return deq.astype(g.dtype), (target - deq)


def tree_compress(grads, err_tree):
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err_tree)
    outs = [compress_with_feedback(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]))


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
