"""LRU schedule cache: the serving path reuses schedules across requests.

Schedule construction is O(n) and vectorized (`core/tiling.py`), but at
serving rates even milliseconds per request add up — and most requests
re-present a cost distribution the scheduler has already seen (the same
CSR matrix, the same graph, the same batch shape). The cache keys on
``(cost_fingerprint, policy, p, construction params, superstep,
backend)`` — the full frozen `Policy` dataclass, not its lossy
``label()``, and the worker PARTITION parameters `p`/`superstep`: a
cached `Schedule` memoizes its worker-shard lowering (`Schedule.shard`)
and the kernel ops pack payloads into that layout, so entries built for
different worker counts must never alias (tests/test_sched_api.py proves
distinct `p` values don't collide). The construction BACKEND ("numpy" or
"jax", `core/tiling_jax.py`) keys for the same reason: a jax-backed
entry additionally memoizes on-device lowerings
(`Schedule.device_lowering`), and those device buffers obey the same
no-aliasing rule as the host shards — see the generation paragraph
below.
A repeat `LoopScheduler.schedule()` call returns the previously built
`Schedule` object without touching construction at all
(`benchmarks/bench_schedule_build.py` records the hit path in
`BENCH_schedule.json`).

Generation invalidation (measured-cost feedback, DESIGN.md §2.7): the key
also carries the refinement GENERATION. `Schedule.refine()` re-enters
this cache with generation g+1 and a `RefinedCosts` fingerprint over the
refreshed (sizes, costs) content, so a refined schedule — and everything
hanging off it: memoized shard layouts, packed kernel payloads, DEVICE
lowerings (`Schedule.device_lowering`'s jax buffers) — is always a fresh
entry; a stale generation-g lowering can never be served for
generation-g+1 costs, even if an unrelated entry hashed equal on the
non-generation fields. Old generations age out through normal LRU
eviction rather than eager invalidation: in a serving loop the previous
generation often still has in-flight consumers, and evicting it early
would only force rebuilds (`tests/test_adaptive_properties.py` pins the
no-aliasing rule).

Thread-safe; eviction is least-recently-used. Construction runs outside
the cache lock (it serializes internally on the tiling workspace), so a
slow build never blocks concurrent hits. Two threads racing on the same
missing key may both build; the first insert wins and both get a usable
schedule — acceptable for a cache whose values are immutable.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Any, Callable, Hashable


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class ScheduleCache:
    """LRU map from schedule keys to built `Schedule` objects."""

    def __init__(self, maxsize: int = 128):
        if maxsize < 1:
            raise ValueError(f"cache maxsize must be >= 1, got {maxsize}")
        self.maxsize = int(maxsize)
        self._data: OrderedDict[Hashable, Any] = OrderedDict()
        self._lock = threading.Lock()
        self.stats = CacheStats()

    def __len__(self) -> int:
        return len(self._data)

    def get_or_build(self, key: Hashable, build: Callable[[], Any]) -> Any:
        """Return the cached value for `key`, building it on a miss."""
        with self._lock:
            if key in self._data:
                self._data.move_to_end(key)
                self.stats.hits += 1
                return self._data[key]
            self.stats.misses += 1
        value = build()
        with self._lock:
            if key not in self._data:  # lost races keep the first insert
                self._data[key] = value
                if len(self._data) > self.maxsize:
                    self._data.popitem(last=False)
                    self.stats.evictions += 1
            return self._data[key]

    def clear(self) -> None:
        with self._lock:
            self._data.clear()
            self.stats = CacheStats()
