"""The `LoopScheduler` facade and the `Schedule` it hands out.

One object per constructed schedule, three consumers (DESIGN.md §3):

* ``Schedule.simulate()`` / ``Schedule.replay()`` — the discrete-event
  simulator (`core/simulator.py`): `simulate` runs the schedule's policy
  over the per-item cost array; `replay` re-dispatches the constructed
  tiles chunk-for-chunk (`policies.pretiled` over flattened work units),
  which is the simulator-side ground truth for what the Pallas kernels
  will execute.
* ``Schedule.parallel_for()`` / ``Schedule.parallel_for_units()`` — the
  real threaded executor (`core/executor.py`): per-item under the policy,
  or per-work-unit under the exact tile chunking.
* ``Schedule.lower()`` — the `TileSchedule` the Pallas kernels consume
  (`core/tiling.py`; scalar-prefetched `item_id`, packed payload layout).

`LoopScheduler` is the construction front-end: cost provider in, cached
`Schedule` out, plus `build(name, *inputs)` to instantiate a registered
workload's kernel op, and direct pass-throughs to the simulator/executor
for policy studies that need no tiles.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Optional

import numpy as np

from repro.core import executor as E
from repro.core import policies as P
from repro.core import simulator as S
from repro.core import tiling as T
from repro.robust import faults as F
from repro.robust import recovery as R

from .adaptive import CostRefiner
from .cache import CacheStats, ScheduleCache
from .costs import CostProvider, RefinedCosts, as_cost_provider
from .defaults import (ICH_EPS, MAX_WIDTH, MIN_WIDTH, ROWS_PER_TILE,
                       SUPERSTEP)


@dataclasses.dataclass(frozen=True, eq=False)
class Schedule:
    """An immutable constructed schedule: per-item costs + policy + tiles.

    Identity semantics (eq=False): schedules compare by object identity,
    matching the cache's `is` contract — generated field equality would
    try to bool() ndarray comparisons and raise.

    `tiles` is the (T, R) iCh tile layout; `sizes`/`costs` are the per-item
    work units / float costs it was built from; `policy`/`p` are the
    runtime-side defaults its simulator/executor methods use. `p` and
    `superstep` are also the kernel-lowering defaults: `shard()` partitions
    the tiles across `p` accelerator workers in supersteps of `superstep`
    tiles (DESIGN.md §2.6).
    """

    sizes: np.ndarray        # (n,) int64 work units per item
    costs: np.ndarray        # (n,) float64 per-item costs
    policy: P.Policy
    p: int
    tiles: T.TileSchedule
    # simulator time model inherited from the constructing LoopScheduler
    sim_params: S.SimParams = dataclasses.field(default_factory=S.SimParams)
    superstep: int = SUPERSTEP
    # memoized worker shard layouts keyed (p, superstep); benign build race
    _shards: dict = dataclasses.field(default_factory=dict, repr=False)
    # which construction pipeline built (and re-builds) this schedule:
    # "numpy" = core/tiling.py, "jax" = the jitted core/tiling_jax.py twin
    # (element-identical tiles; device lowerings via `device_lowering()`)
    backend: str = "numpy"
    # memoized DEVICE lowerings keyed (p, superstep) — the on-device twin
    # of `_shards` (core/tiling_jax.DeviceLowering); same benign build race
    _device: dict = dataclasses.field(default_factory=dict, repr=False)
    # ---- measured-cost feedback state (DESIGN.md §2.7) ----
    # refinement generation: 0 = built from a-priori estimates, g+1 = built
    # by the g-th schedule's refine(); part of the schedule-cache key, so a
    # refined schedule can never be served a stale lowering
    generation: int = 0
    # True when sizes describe a payload layout (CSR nnz / degrees) that
    # refine() must keep; False when they are quantized cost estimates
    structural_sizes: bool = True
    # construction parameters refine() rebuilds with (None width = re-band)
    width_arg: Optional[int] = None
    band_eps: float = ICH_EPS
    # lazily-created CostRefiner lives here (frozen dataclass; same benign
    # setdefault race as _shards)
    _feedback: dict = dataclasses.field(default_factory=dict, repr=False)
    # the constructing facade — refine() re-enters its cache; None for
    # hand-assembled Schedules (refine then rebuilds directly)
    _scheduler: Optional["LoopScheduler"] = dataclasses.field(
        default=None, repr=False)

    # ------------------------------------------------------------- lowering
    def lower(self) -> T.TileSchedule:
        """The static tile schedule a Pallas kernel consumes."""
        return self.tiles

    def shard(self, *, p: Optional[int] = None,
              superstep: Optional[int] = None) -> T.WorkerShards:
        """The worker-sharded lowering of the tiles (DESIGN.md §2.6): a
        cost-balanced, item-closed LPT partition of the tiles across `p`
        accelerator workers, padded to supersteps of `superstep` tiles —
        the layout the 2D `ich_*_sharded` kernels consume. Memoized per
        (p, superstep) on this Schedule."""
        key = (int(p if p is not None else self.p),
               int(superstep if superstep is not None else self.superstep))
        hit = self._shards.get(key)
        if hit is None:
            # benign build race: the first insert wins and both callers
            # get the winning layout
            hit = self._shards.setdefault(key, T.shard_schedule(
                self.tiles, self.tile_cost(), key[0], superstep=key[1]))
        return hit

    def device_lowering(self, *, p: Optional[int] = None,
                        superstep: Optional[int] = None):
        """The jitted on-device lowering of this schedule
        (`core/tiling_jax.DeviceLowering`): build -> cost -> partition ->
        shard layout run as one compiled pipeline, element-identical to
        the host `shard()` arrays (tests/test_tiling_jax.py) but resident
        as jax device buffers the sharded kernels can consume without a
        host round-trip. Memoized per (p, superstep) like `shard()`.

        Generation safety: `refine()` always returns a NEW Schedule under
        a fresh cache generation with an EMPTY device memo, so a cached
        device lowering can never alias a stale generation's buffers —
        the same no-aliasing rule the host shard layouts obey
        (sched/cache.py). Width is pinned to this schedule's resolved
        tile width, so the device pipeline reproduces these exact tiles
        rather than re-deriving the band."""
        from repro.core import tiling_jax as TJ
        key = (int(p if p is not None else self.p),
               int(superstep if superstep is not None else self.superstep))
        hit = self._device.get(key)
        if hit is None:
            hit = self._device.setdefault(key, TJ.lower_schedule_jax(
                self.sizes, self.costs, p=key[0], superstep=key[1],
                rows_per_tile=self.rows_per_tile, width=self.width,
                eps=self.band_eps))
        return hit

    @property
    def n_items(self) -> int:
        return int(self.sizes.size)

    @property
    def n_tiles(self) -> int:
        return self.tiles.n_tiles

    @property
    def rows_per_tile(self) -> int:
        return self.tiles.rows_per_tile

    @property
    def width(self) -> int:
        return self.tiles.width

    @property
    def item_id(self) -> np.ndarray:
        """(T, R) scalar-prefetch schedule (-1 = padding slot)."""
        return self.tiles.item_id

    # ------------------------------------------- work-unit space utilities
    def unit_ranges(self) -> np.ndarray:
        """(T, 2) [begin, end) tile chunks in flattened work-unit space."""
        return self.tiles.slot_ranges()

    def unit_costs(self) -> np.ndarray:
        """Per-work-unit cost array that `unit_ranges` indexes into."""
        return self.tiles.unit_costs(self.costs, self.sizes)

    def unit_to_item(self) -> np.ndarray:
        """Flattened-unit -> item map (item i owns sizes[i] units)."""
        return np.repeat(np.arange(self.n_items, dtype=np.int64), self.sizes)

    def tile_work(self) -> np.ndarray:
        """Work units packed into each tile, shape (T,)."""
        return self.tiles.tile_work()

    def tile_cost(self) -> np.ndarray:
        """Predicted per-tile cost; what `replay` must reproduce."""
        return self.tiles.tile_cost(self.costs, self.sizes)

    def slot_cost(self) -> np.ndarray:
        """Per-slot (T, R) cost decomposition; rows sum to `tile_cost`.
        This is the stream the sharded kernels account their per-worker
        cost output against (`sched/kernels.py`)."""
        return self.tiles.slot_cost(self.costs, self.sizes)

    def imbalance(self, *, p: Optional[int] = None,
                  superstep: Optional[int] = None) -> float:
        """max/mean per-worker cost of the sharded lowering (1.0 =
        perfectly balanced). The load-balance figure the refine loop
        drives down: observe() + refine() re-partitions from measured
        costs, so a schedule built from stale estimates converges toward
        imbalance 1.0 over rounds (benchmarks/bench_schedule_build.py,
        tests/test_moe_sched.py)."""
        shards = self.shard(p=p, superstep=superstep)
        wc = shards.worker_cost(self.tile_cost())
        mean = float(wc.mean())
        return float(wc.max()) / mean if mean > 0 else 1.0

    # ------------------------------------------------------- (a) simulator
    def simulate(self, *, p: Optional[int] = None,
                 policy: Optional[P.Policy] = None,
                 params: Optional[S.SimParams] = None,
                 **kw) -> S.SimResult:
        """Discrete-event run of `policy` (default: the schedule's) over the
        per-item cost array."""
        return S.simulate(self.costs, p or self.p, policy or self.policy,
                          params if params is not None else self.sim_params,
                          **kw)

    def replay(self, *, p: Optional[int] = None,
               params: Optional[S.SimParams] = None,
               record_chunks: bool = True) -> S.SimResult:
        """Replay the constructed tiles through the simulator: each tile is
        dispatched as one explicit central-queue chunk over the flattened
        work units. `chunk_log` ranges equal `unit_ranges()` row-for-row
        and per-chunk work equals `tile_cost()` (the kernel/simulator
        cross-check in benchmarks/bench_ich_kernels.py)."""
        return S.simulate(self.unit_costs(), p or self.p,
                          P.pretiled(self.unit_ranges()),
                          params if params is not None else self.sim_params,
                          record_chunks=record_chunks)

    def replay_sharded(self, *, p: Optional[int] = None,
                       superstep: Optional[int] = None,
                       params: Optional[S.SimParams] = None,
                       record_chunks: bool = True) -> S.SimResult:
        """Replay the WORKER-SHARDED lowering through the simulator: each
        tile is dispatched on exactly the worker `shard()` assigned it
        (`policies.assigned`, static assignment — no queue, no stealing).
        Per-worker dispatched work must equal `shard().worker_cost(
        tile_cost())` worker-for-worker, and under zero overhead/jitter the
        makespan is the partition's max per-worker cost — the simulator
        cross-check for the sharded kernel execution layer
        (tests/test_sharding.py)."""
        shards = self.shard(p=p, superstep=superstep)
        return S.simulate(self.unit_costs(), shards.p,
                          P.assigned(self.unit_ranges(), shards.worker),
                          params if params is not None else self.sim_params,
                          record_chunks=record_chunks)

    # ------------------------------- measured-cost feedback (DESIGN.md §2.7)
    @property
    def refiner(self) -> CostRefiner:
        """This schedule's cost refiner (created on first use). Carries the
        per-item Welford statistics across observe() rounds and — through
        refine() — across schedule generations."""
        r = self._feedback.get("refiner")
        if r is None:
            r = self._feedback.setdefault(
                "refiner", CostRefiner.for_costs(self.sizes, self.costs))
        return r

    def observe(self, measured, *, level: str = "auto",
                space: str = "auto", normalize: Optional[bool] = None,
                shards: Optional[T.WorkerShards] = None) -> "Schedule":
        """Fold one execution round's measured costs into the refiner.

        Accepts what each execution layer emits:

        * a `SimResult` with `chunk_log` (from `replay`/`replay_sharded`/
          `simulate(record_chunks=True)`) — per-chunk dispatched work, in
          item space (simulate) or flattened work-unit space (replays);
          inferred from the simulated n, with the same `space=` escape
          hatch as ExecStats when the two coincide;
        * an `ExecStats` with `chunk_log` (from `parallel_for(record_chunks
          =True)` / `parallel_for_units`) — per-chunk wall seconds,
          normalized onto the estimate scale by default (wall clocks and
          abstract cost units share no unit). Chunk ranges live in ITEM
          space (`parallel_for`) or flattened WORK-UNIT space
          (`parallel_for_units`); this is inferred from where the ranges
          end, and when n_items == n_units with non-uniform sizes makes
          the two indistinguishable, `space="items"`/`"units"` must say
          which executor produced the stats;
        * a (p, S_B) array — the sharded kernels' per-worker, per-superstep
          cost output (`sched/kernels.py` ops' `.observe()`). Attributed
          through the schedule's DEFAULT shard lowering unless `shards`
          names the lowering the measurement came from — shapes alone
          cannot identify a lowering (distinct supersteps can share a
          (p, S_B) grid), so a non-default lowering must be passed
          explicitly;
        * a 1-D array — per-item (`level="item"`) or per-tile
          (`level="tile"`) measurements; "auto" infers from the length and
          raises when n_items == n_tiles makes it ambiguous.

        Returns self, so a round reads
        ``schedule.observe(measured).refine()``.
        """
        r = self.refiner
        if isinstance(measured, S.SimResult):
            if not measured.chunk_log:
                raise ValueError(
                    "SimResult carries no chunk_log; run the simulator "
                    "with record_chunks=True to observe it")
            ranges = [(b, e) for (b, e, _, _) in measured.chunk_log]
            work = np.array([wk for (_, _, _, wk) in measured.chunk_log])
            n_units = int(self.sizes.sum())
            if space not in ("auto", "items", "units"):
                raise ValueError(f"space must be 'auto', 'items' or "
                                 f"'units', got {space!r}")
            # simulate() runs over per-item costs, replay()/replay_sharded()
            # over flattened work units; same ambiguity rule as ExecStats
            # below when the two coincide with non-uniform sizes
            if space != "auto":
                unit_space = space == "units"
                expect = n_units if unit_space else self.n_items
                if measured.n != expect:
                    raise ValueError(
                        f"SimResult ran over n={measured.n} iterations but "
                        f"the {space} space has {expect} entries")
            elif measured.n == self.n_items == n_units \
                    and not (self.sizes == 1).all():
                raise ValueError(
                    "n_items == work units with non-uniform sizes: pass "
                    "space='items' (a simulate() run) or space='units' "
                    "(a replay)")
            elif measured.n == self.n_items:
                unit_space = False
            elif measured.n == n_units:
                unit_space = True
            else:
                raise ValueError(
                    f"SimResult over n={measured.n} iterations matches "
                    f"neither items ({self.n_items}) nor work units "
                    f"({n_units}) of this schedule")
            if unit_space:
                r.observe_unit_ranges(ranges, work)
            else:
                r.observe_item_ranges(ranges, work)
            return self
        if isinstance(measured, E.ExecStats):
            if not measured.chunk_log:
                raise ValueError(
                    "ExecStats carries no chunk_log; run parallel_for with "
                    "record_chunks=True to observe it")
            ranges = np.array([(b, e) for (b, e, _, _) in measured.chunk_log],
                              np.int64)
            secs = np.array([dt for (_, _, _, dt) in measured.chunk_log])
            n_units = int(self.sizes.sum())
            end = int(ranges[:, 1].max(initial=0))
            if space not in ("auto", "items", "units"):
                raise ValueError(f"space must be 'auto', 'items' or "
                                 f"'units', got {space!r}")
            # parallel_for chunks cover [0, n_items), parallel_for_units
            # [0, n_units); when the two coincide AND sizes are non-
            # uniform, the spaces distribute differently and the caller
            # must say which executor produced the stats
            if space != "auto":
                unit_space = space == "units"
                expect = n_units if unit_space else self.n_items
                if end != expect:
                    raise ValueError(
                        f"ExecStats chunks end at {end} but the "
                        f"{space} space has {expect} entries")
            elif end == self.n_items == n_units \
                    and not (self.sizes == 1).all():
                raise ValueError(
                    "n_items == work units with non-uniform sizes: pass "
                    "space='items' (parallel_for stats) or space='units' "
                    "(parallel_for_units stats)")
            elif end == self.n_items:
                unit_space = False
            elif end == n_units:
                unit_space = True
            else:
                raise ValueError(
                    f"ExecStats chunks end at {end}, matching neither "
                    f"items ({self.n_items}) nor work units ({n_units})")
            if normalize is None:
                normalize = True  # wall seconds -> estimate scale
            if normalize and secs.sum() > 0:
                if unit_space:
                    unit_est = self.unit_costs()
                    covered = sum(float(unit_est[b:e].sum())
                                  for b, e in ranges)
                else:
                    covered = sum(float(r.est[b:e].sum()) for b, e in ranges)
                if covered > 0:
                    secs = secs * (covered / secs.sum())
            if unit_space:
                r.observe_unit_ranges(ranges, secs)
            else:
                r.observe_item_ranges(ranges, secs)
            return self
        arr = np.asarray(measured, np.float64)
        if arr.ndim == 2:
            sh = shards if shards is not None else self.shard()
            if sh.block_perm.shape != arr.shape:
                raise ValueError(
                    f"worker-step observation {arr.shape} does not match "
                    f"the {'given' if shards is not None else 'default'} "
                    f"shard lowering's (p, S_B) grid "
                    f"{sh.block_perm.shape}; pass shards=<the lowering the "
                    "measurement came from> (shapes alone cannot identify "
                    "a lowering)")
            r.observe_worker_steps(self.tiles, sh, arr)
            return self
        if arr.ndim != 1:
            raise ValueError(f"cannot interpret a {arr.ndim}-D observation")
        if level == "auto":
            if arr.size == self.n_items == self.n_tiles:
                raise ValueError(
                    "n_items == n_tiles: pass level='item' or level='tile'")
            level = ("item" if arr.size == self.n_items else
                     "tile" if arr.size == self.n_tiles else None)
            if level is None:
                raise ValueError(
                    f"observation of length {arr.size} matches neither "
                    f"items ({self.n_items}) nor tiles ({self.n_tiles})")
        if level == "item":
            r.observe_items(arr)
        elif level == "tile":
            r.observe_tiles(self.tiles, arr)
        else:
            raise ValueError(f"unknown observation level {level!r}")
        return self

    def refine(self, *, blend: Optional[float] = None) -> "Schedule":
        """Re-construct from the refiner's current refined costs: re-tile
        (unless sizes are structural), re-partition, and re-shard, under a
        fresh cache GENERATION so no stale lowering (tiles, shard layouts,
        packed payloads) is ever reused. The refiner — with all its
        accumulated per-item statistics — transfers to the new schedule, so
        rounds keep compounding: ``s = s.observe(m).refine()``.
        """
        r = self.refiner
        if blend is not None:
            r.blend = float(blend)
        refined = r.refresh_estimates()
        provider = RefinedCosts(self.sizes, refined,
                                generation=self.generation + 1,
                                structural=self.structural_sizes)
        if self._scheduler is not None:
            new = self._scheduler.schedule(
                provider, policy=self.policy, p=self.p,
                rows_per_tile=self.rows_per_tile, width=self.width_arg,
                eps=self.band_eps, superstep=self.superstep,
                _generation=self.generation + 1)
        else:  # hand-assembled schedule: rebuild directly, no cache
            if self.backend == "jax":
                from repro.core import tiling_jax as TJ
                tiles = TJ.build_schedule_jax(
                    provider.sizes(), rows_per_tile=self.rows_per_tile,
                    width=self.width_arg, eps=self.band_eps).to_host()
            else:
                tiles = T.build_schedule(provider.sizes(),
                                         rows_per_tile=self.rows_per_tile,
                                         width=self.width_arg,
                                         eps=self.band_eps)
            new = dataclasses.replace(
                self, sizes=provider.sizes(), costs=provider.costs(),
                tiles=tiles, generation=self.generation + 1,
                _shards={}, _feedback={}, _device={})
        new._feedback["refiner"] = r.successor(new.sizes)
        return new

    def replay_refined(self, true_costs, *, sharded: bool = False,
                       p: Optional[int] = None,
                       superstep: Optional[int] = None,
                       params: Optional[S.SimParams] = None,
                       record_chunks: bool = False) -> S.SimResult:
        """Deterministically answer "what does THIS schedule cost on that
        workload": replay the constructed chunks with per-item costs
        `true_costs` (measured or ground truth) instead of the estimates
        the schedule was built from — `simulator.replay_refined` over the
        tile ranges, through the central pretiled queue, or as the static
        sharded assignment when `sharded=True`. The observe/refine loop
        must drive this makespan down (tests/test_adaptive_properties.py,
        benchmarks/bench_schedule_build.py)."""
        true_costs = np.asarray(true_costs, np.float64)
        if true_costs.shape != (self.n_items,):
            raise ValueError(f"true costs must have shape "
                             f"({self.n_items},), got {true_costs.shape}")
        unit = self.tiles.unit_costs(true_costs, self.sizes)
        prm = params if params is not None else self.sim_params
        if sharded:
            shards = self.shard(p=p, superstep=superstep)
            return S.replay_refined(unit, self.unit_ranges(), shards.p,
                                    workers=shards.worker, params=prm,
                                    record_chunks=record_chunks)
        return S.replay_refined(unit, self.unit_ranges(), p or self.p,
                                params=prm, record_chunks=record_chunks)

    # --------------------------- fault replay & chaos runs (DESIGN.md §2.9)
    def replay_faulty(self, plan: F.FaultPlan, *,
                      p: Optional[int] = None,
                      policy: Optional[P.Policy] = None,
                      params: Optional[S.SimParams] = None,
                      record_chunks: bool = False,
                      record_assignment: bool = False) -> F.FaultReport:
        """Simulate this schedule's policy over its cost array twice —
        fault-free and under the seeded `FaultPlan` — and report both runs
        plus the makespan inflation the chaos scenario costs it. Dead
        workers' queued work is reclaimed by survivors through the steal
        machinery, so the faulty run still dispatches every item exactly
        once (or raises `repro.robust.FaultError` when no live worker
        remains). Deterministic: the same plan replays bit-identically."""
        return F.simulate_faulty(
            self.costs, p or self.p, policy or self.policy, plan,
            params=params if params is not None else self.sim_params,
            record_chunks=record_chunks,
            record_assignment=record_assignment)

    def reshard_survivors(self, *, dead,
                          checkpoint: Optional[R.CheckpointLog] = None,
                          p: Optional[int] = None,
                          superstep: Optional[int] = None) -> R.RecoveryPlan:
        """Recovery re-lowering for an interrupted sharded run (DESIGN.md
        §2.11): given the workers lost and a `CheckpointLog` of blocks
        completed at superstep barriers, re-partition every incomplete
        item-closed chain onto the p-k survivors with the same
        `partition_tiles` LPT the original lowering used. The returned
        `RecoveryPlan` carries the survivor layout (`.shards`), the
        completed-prefix layout (`.done_shards`), and `.combine()` — both
        layouts drive the standard sharded kernels over the original flat
        payload, and the combined output is bit-identical to the
        fault-free run. Without a checkpoint the plan is a worst-case
        full re-execution on the survivors."""
        shards = self.shard(p=p, superstep=superstep)
        return R.plan_recovery(self.tiles, self.tile_cost(), shards,
                               dead=dead, checkpoint=checkpoint)

    # -------------------------------------------------------- (b) executor
    def parallel_for(self, body: Callable[[int], None], *,
                     p: Optional[int] = None,
                     policy: Optional[P.Policy] = None,
                     seed: int = 0, record_chunks: bool = False,
                     deterministic: bool = False,
                     faults: Optional[F.FaultPlan] = None,
                     retries: int = 0, retry_backoff_s: float = 0.0,
                     watchdog_s: Optional[float] = None,
                     sleep_fn: Optional[Callable[[float], None]] = None
                     ) -> E.ExecStats:
        """Run `body(i)` for every item on real threads under `policy`
        (default: the schedule's). `record_chunks=True` fills the per-chunk
        wall-time log `observe()` consumes (DESIGN.md §2.7). `faults`,
        `retries`/`retry_backoff_s`, `watchdog_s`, and `sleep_fn` pass
        through to the supervised executor (DESIGN.md §2.9): injected
        chaos, per-item retry budget, heartbeat-based dead-worker
        detection, and the virtual-sleep hook for zero-wall-clock
        retry/stall suites."""
        return E.parallel_for(self.n_items, body, p or self.p,
                              policy or self.policy, seed=seed,
                              record_chunks=record_chunks,
                              deterministic=deterministic, faults=faults,
                              retries=retries,
                              retry_backoff_s=retry_backoff_s,
                              watchdog_s=watchdog_s, sleep_fn=sleep_fn)

    def parallel_for_units(self, body: Callable[[int], None], *,
                           p: Optional[int] = None,
                           seed: int = 0, record_chunks: bool = False,
                           deterministic: bool = False,
                           faults: Optional[F.FaultPlan] = None,
                           retries: int = 0, retry_backoff_s: float = 0.0,
                           sleep_fn: Optional[Callable[[float], None]] = None
                           ) -> E.ExecStats:
        """Run `body(u)` for every flattened work unit on real threads,
        dispatched in exactly the constructed tile chunks (one central-queue
        chunk per tile — the threaded twin of `replay`). With
        `record_chunks=True` the returned stats carry one wall-time record
        per tile, ready for `observe()`. `faults`/`retries` pass through to
        the supervised executor (central path: no watchdog — there are no
        per-worker deques to reclaim; survivors drain the shared queue)."""
        n_units = int(self.sizes.sum())
        return E.parallel_for(n_units, body, p or self.p,
                              P.pretiled(self.unit_ranges()), seed=seed,
                              record_chunks=record_chunks,
                              deterministic=deterministic, faults=faults,
                              retries=retries,
                              retry_backoff_s=retry_backoff_s,
                              sleep_fn=sleep_fn)


class LoopScheduler:
    """Facade over policies, simulator, executor, and Pallas lowering.

    Construction parameters set here are the instance defaults; every
    method takes per-call overrides. Schedules are cached (LRU) on
    ``(cost fingerprint, full policy, p, construction params)`` — the FULL
    frozen `Policy`, not its label, which is lossy — see `sched/cache.py`.

    Memory: each cached `Schedule` pins O(n) per-item arrays plus its
    tiles (~tens of MB at a million items), so `cache_size` bounds
    retained memory at roughly `cache_size * max_schedule_bytes`. Size it
    to the working set of DISTINCT cost distributions you re-present
    (matrices, graphs, batch shapes); for one-shot schedules (a fresh
    cost array every request, never re-seen) pass `cache_size=0` to
    disable caching entirely.
    """

    def __init__(self, *, p: int = 8, policy: Optional[P.Policy] = None,
                 rows_per_tile: int = ROWS_PER_TILE,
                 min_w: int = MIN_WIDTH, max_w: int = MAX_WIDTH,
                 superstep: int = SUPERSTEP,
                 cache_size: int = 32,
                 sim_params: Optional[S.SimParams] = None,
                 backend: str = "numpy"):
        if backend not in ("numpy", "jax"):
            raise ValueError(
                f"backend must be 'numpy' or 'jax', got {backend!r}")
        self.backend = backend
        self.p = int(p)
        self.policy = policy if policy is not None else P.ich(ICH_EPS)
        self.rows_per_tile = int(rows_per_tile)
        self.min_w = int(min_w)
        self.max_w = int(max_w)
        self.superstep = int(superstep)
        self.sim_params = sim_params if sim_params is not None else S.SimParams()
        self.cache = ScheduleCache(cache_size) if cache_size > 0 else None

    # ------------------------------------------------- schedule construction
    def schedule(self, costs, *, policy: Optional[P.Policy] = None,
                 p: Optional[int] = None,
                 rows_per_tile: Optional[int] = None,
                 width: Optional[int] = None,
                 eps: Optional[float] = None,
                 superstep: Optional[int] = None,
                 _generation: int = 0) -> Schedule:
        """Construct (or fetch from cache) the schedule for `costs`.

        `costs` is a `CostProvider` or a bare per-item array
        (`as_cost_provider`). The tile width comes from the paper's band at
        `eps` (default: the policy's epsilon for adaptive policies, else
        the unified `ICH_EPS`) unless `width` pins it explicitly.

        The cache key includes the worker-partition parameters `p` and
        `superstep`: the returned `Schedule` lowers to a p-worker shard
        layout (and carries policy/p as its simulator/executor defaults),
        so entries differing only in those must be distinct objects — a
        p=2 schedule's memoized shards and packed kernels must never be
        served to a p=4 caller (tests/test_sched_api.py proves distinct
        p values don't collide). It also includes the refinement
        GENERATION (`_generation`, set by `Schedule.refine`): a refined
        schedule's lowerings are always freshly keyed, never a stale
        entry's (sched/cache.py).
        """
        provider = as_cost_provider(costs)
        pol = policy if policy is not None else self.policy
        pp = int(p if p is not None else self.p)
        rpt = int(rows_per_tile if rows_per_tile is not None
                  else self.rows_per_tile)
        band_eps = float(eps if eps is not None
                         else (pol.eps if pol.adaptive else ICH_EPS))
        sstep = int(superstep if superstep is not None else self.superstep)
        gen = int(_generation)
        # absent a declaration, sizes count as structural: keeping them
        # across refinement is always payload-safe (see sched/costs.py)
        structural = bool(getattr(provider, "sizes_are_structural", True))
        # the policy keys as the full (frozen, hashable) dataclass, not just
        # label(): labels are lossy — taskloop's drops num_tasks, pretiled's
        # drops the actual ranges — and would alias distinct policies onto
        # one cache entry
        # the backend is part of the key: a "jax" entry memoizes DEVICE
        # lowerings (device_lowering) a "numpy"-facade caller never asked
        # to pin, and the two construction pipelines must stay separately
        # attributable even though their tiles are element-identical
        key = (provider.fingerprint(), pol, pp, rpt, width,
               band_eps, self.min_w, self.max_w, sstep, gen, self.backend)

        def build() -> Schedule:
            sizes = provider.sizes()
            if self.backend == "jax":
                from repro.core import tiling_jax as TJ
                tiles = TJ.build_schedule_jax(
                    sizes, rows_per_tile=rpt, width=width, eps=band_eps,
                    min_w=self.min_w, max_w=self.max_w).to_host()
            else:
                tiles = T.build_schedule(sizes, rows_per_tile=rpt,
                                         width=width, eps=band_eps,
                                         min_w=self.min_w, max_w=self.max_w)
            return Schedule(sizes=sizes, costs=provider.costs(), policy=pol,
                            p=pp, tiles=tiles, sim_params=self.sim_params,
                            superstep=sstep, generation=gen,
                            structural_sizes=structural, width_arg=width,
                            band_eps=band_eps, backend=self.backend,
                            _scheduler=self)

        if self.cache is None:
            return build()
        return self.cache.get_or_build(key, build)

    # ----------------------------------------------------- workload registry
    def build(self, workload: str, *inputs,
              policy: Optional[P.Policy] = None, p: Optional[int] = None,
              rows_per_tile: Optional[int] = None,
              width: Optional[int] = None, eps: Optional[float] = None,
              superstep: Optional[int] = None):
        """Instantiate a registered workload's kernel op from raw inputs.

        Looks up `workload` in the registry (`sched.register` /
        `sched.get`), derives its cost provider from `inputs`, routes the
        schedule through the cache, and hands both to the entry's builder.
        """
        from . import registry
        entry = registry.get(workload)
        provider = entry.costs(*inputs)
        s = self.schedule(provider, policy=policy, p=p,
                          rows_per_tile=rows_per_tile, width=width, eps=eps,
                          superstep=superstep)
        return entry.build(s, *inputs)

    # --------------------------------------------- direct backend shortcuts
    def simulate(self, costs, *, policy: Optional[P.Policy] = None,
                 p: Optional[int] = None,
                 params: Optional[S.SimParams] = None,
                 **kw) -> S.SimResult:
        """Simulator pass-through for policy studies that need no tiles
        (the paper-figure benchmarks); `costs` is per-ITEM here."""
        return S.simulate(np.asarray(costs, np.float64),
                          p or self.p, policy or self.policy,
                          params if params is not None else self.sim_params,
                          **kw)

    def parallel_for(self, n: int, body: Callable[[int], None], *,
                     policy: Optional[P.Policy] = None,
                     p: Optional[int] = None, seed: int = 0) -> E.ExecStats:
        """Threaded-executor pass-through: `body(i)` for i in [0, n)."""
        return E.parallel_for(n, body, p or self.p, policy or self.policy,
                              seed=seed)

    @property
    def cache_stats(self) -> CacheStats:
        return self.cache.stats if self.cache is not None else CacheStats()


_DEFAULT: Optional[LoopScheduler] = None
_DEFAULT_LOCK = threading.Lock()


def default_scheduler() -> LoopScheduler:
    """Process-wide facade instance (one shared schedule cache) — what the
    deprecation shims and the serving path use."""
    global _DEFAULT
    with _DEFAULT_LOCK:
        if _DEFAULT is None:
            _DEFAULT = LoopScheduler()
        return _DEFAULT
