"""MoE expert dispatch as a scheduling problem (DESIGN.md §2.8).

The paper's loop-scheduling problem reappears verbatim in MoE routing:
tokens are loop iterations, experts are workers, per-expert *capacity* is
the chunk size, and overflow rerouting is the steal — except that on an
accelerator the steal must happen at SCHEDULE time, not run time. This
module is the host-side half of that mapping:

* `plan_dispatch` mirrors the in-graph sort-based dispatch of
  `models/moe.py` (`dispatch_decisions`) decision-for-decision in numpy —
  stable argsort positions, `pos < cap` capacity cut, one steal round to
  each dropped token's max-slack alternative — and returns a
  `DispatchPlan`. The two paths are BIT-IDENTICAL at equal capacity
  (tests/test_moe_sched.py), which is what lets the model run on the
  scheduler without changing a single routing decision.
* `DispatchPlan.csr()` lays the kept entries out as an expert-major CSR
  (indptr over experts, token ids + combine weights as payload), i.e.
  exactly the shape `LoopScheduler.schedule` consumes through
  `ExpertLoadCosts` and the packed segmented kernels execute
  (`sched/kernels.py:MoeDispatchOp`, `kernels/ich_moe/`).
* `cap_scale_from_costs` / `refine_cap_scale` close the adaptive loop:
  measured per-expert load folds into the schedule's `CostRefiner`
  (`Schedule.observe` / `refine`) and the refined estimates become the
  next step's `cap_scale` — the d_i array of the in-graph balancer
  (`models/moe.py:ich_update_cap_scale`), derived from compounding
  Welford statistics instead of one multiplicative step.

Everything here is numpy-only: planning runs on the host between steps,
never inside a traced computation.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .defaults import (MOE_CAP_SCALE_MAX, MOE_CAP_SCALE_MIN,
                       MOE_CAPACITY_FACTOR, MOE_CMAX_FACTOR, MOE_MIN_CAPACITY)

__all__ = ["DispatchPlan", "expert_capacity", "plan_dispatch",
           "cap_scale_from_costs", "refine_cap_scale"]


def expert_capacity(n_tokens: int, n_experts: int, experts_per_token: int,
                    factor: float = MOE_CAPACITY_FACTOR) -> int:
    """Base per-expert capacity for a token pool: ceil(K*T*factor/E),
    floored at MOE_MIN_CAPACITY. The chunk-size analogue."""
    return max(MOE_MIN_CAPACITY,
               int(-(-experts_per_token * n_tokens * factor // n_experts)))


def _dispatch_positions(experts_flat: np.ndarray, n_experts: int):
    """Positions of each (token, choice) entry within its expert segment —
    the numpy mirror of `models/moe.py:_dispatch_positions` (stable
    argsort + searchsorted segment starts, positions scattered back)."""
    order = np.argsort(experts_flat, kind="stable")
    es = experts_flat[order]
    seg_start = np.searchsorted(es, np.arange(n_experts))
    pos_sorted = np.arange(es.shape[0], dtype=np.int64) - seg_start[es]
    pos = np.zeros_like(pos_sorted)
    pos[order] = pos_sorted
    return pos


@dataclasses.dataclass(frozen=True)
class DispatchPlan:
    """A resolved token->expert dispatch: which (token, choice) entries run
    where after the capacity cut and the schedule-time steal round.

    Entry arrays are flat over the (T, K) router choices in token-major
    order (entry t*K + k is token t's k-th choice). `expert`/`pos` are the
    FINAL assignment — a stolen entry points at its steal target, not its
    router choice."""

    n_tokens: int
    n_experts: int
    experts_per_token: int
    expert: np.ndarray      # (T*K,) int32 final expert per entry
    token: np.ndarray       # (T*K,) int32 token id per entry
    weight: np.ndarray      # (T*K,) float32 combine weight per entry
    pos: np.ndarray         # (T*K,) int64 slot within the expert segment
    keep: np.ndarray        # (T*K,) bool — entry survives dispatch
    cap: np.ndarray         # (E,) int32 per-expert capacity used
    counts: np.ndarray      # (E,) int64 kept token load per expert
    router_counts: np.ndarray  # (E,) int64 pre-cut router demand
    stolen: int             # entries rerouted by the steal round
    dropped: int            # entries dropped after the steal round

    def csr(self):
        """Kept entries as an expert-major CSR: (indptr (E+1,), token ids,
        combine weights), tokens of one expert ordered by dispatch slot.

        Kept slots per expert are contiguous [0, counts[e]) — first-round
        keeps occupy [0, used_e) and stolen entries are ranked from
        used_e up — so scattering by `indptr[expert] + pos` is a
        permutation of the kept entries, no gaps."""
        indptr = np.zeros(self.n_experts + 1, np.int64)
        np.cumsum(self.counts, out=indptr[1:])
        tok = np.zeros(int(indptr[-1]), np.int32)
        w = np.zeros(int(indptr[-1]), np.float32)
        k = self.keep
        at = indptr[self.expert[k]] + self.pos[k]
        tok[at] = self.token[k]
        w[at] = self.weight[k]
        return indptr, tok, w


def plan_dispatch(e_topk: np.ndarray, weights: np.ndarray = None, *,
                  cap=None, cap_scale=None,
                  capacity_factor: float = MOE_CAPACITY_FACTOR,
                  cmax_factor: float = MOE_CMAX_FACTOR,
                  steal: bool = True) -> DispatchPlan:
    """Resolve a dispatch plan from router choices — the scheduler-side
    mirror of the in-graph path.

    e_topk (T, K): the router's top-K expert ids per token, with implied
    expert count E = max id + 1 unless `cap` fixes it. weights (T, K):
    combine weights (defaults to 1/K). Capacity comes either from `cap`
    ((E,) int, used verbatim) or from `cap_scale` ((E,) float, the d_i
    array) through the same clip-to-[MOE_MIN_CAPACITY, C_max] rule the
    model uses; `cap_scale=None` means scale 1 everywhere.

    Decision semantics (bit-identical to `models/moe.py`): entries take
    stable-sort positions inside their expert segment and survive while
    `pos < cap[expert]`; with `steal`, each overflowing entry is rerouted
    to its token's max-slack alternative (first max on ties — the exact
    argmax the in-graph path computes) and ranked after the expert's
    first-round keeps, surviving under the same capacity rule.
    """
    e_topk = np.asarray(e_topk)
    if e_topk.ndim != 2:
        raise ValueError(f"e_topk must be (T, K), got {e_topk.shape}")
    T, K = e_topk.shape
    if weights is None:
        weights = np.full((T, K), 1.0 / K, np.float32)
    weights = np.asarray(weights, np.float32)
    if weights.shape != (T, K):
        raise ValueError(f"weights {weights.shape} != e_topk {(T, K)}")

    if cap is not None:
        cap_e = np.asarray(cap, np.int32)
        E = cap_e.shape[0]
    else:
        E = int(e_topk.max()) + 1 if e_topk.size else 1
        if cap_scale is None:
            cap_scale = np.ones(E, np.float64)
        cap_scale = np.asarray(cap_scale, np.float64)
        E = cap_scale.shape[0]
        c_base = expert_capacity(T, E, K, capacity_factor)
        c_max = max(c_base, int(round(cmax_factor * c_base)))
        cap_e = np.clip(np.round(c_base * cap_scale),
                        MOE_MIN_CAPACITY, c_max).astype(np.int32)
    if (e_topk < 0).any() or (e_topk >= E).any():
        raise ValueError(f"expert ids out of range [0, {E})")

    ef = e_topk.reshape(-1).astype(np.int64)
    tf = np.repeat(np.arange(T, dtype=np.int32), K)
    wf = weights.reshape(-1)
    router_counts = np.bincount(ef, minlength=E).astype(np.int64)

    pos = _dispatch_positions(ef, E)
    keep = pos < cap_e[ef]

    if steal:
        # float32 slack to match the in-graph argmax bit-for-bit (counts
        # and capacities are exact integers well under 2^24 in float32)
        slack = np.maximum(cap_e.astype(np.float32)
                           - router_counts.astype(np.float32), 0.0)
        alt_slack = slack[e_topk]                                    # (T,K)
        fallback = e_topk[np.arange(T), np.argmax(alt_slack, axis=-1)]
        ef2 = np.where(keep, ef, fallback[tf])
        used = np.bincount(ef[keep], minlength=E).astype(np.int64)
        # rank stolen entries only: kept entries park on sentinel E+1
        pos2 = _dispatch_positions(np.where(keep, E + 1, ef2), E + 2)
        pos2 = pos2 + used[ef2]
        keep2 = (~keep) & (pos2 < cap_e[ef2])
        ef = np.where(keep2, ef2, ef)
        pos = np.where(keep2, pos2, pos)
        stolen = int(keep2.sum())
        keep = keep | keep2
    else:
        stolen = 0

    counts = np.bincount(ef[keep], minlength=E).astype(np.int64)
    return DispatchPlan(
        n_tokens=T, n_experts=E, experts_per_token=K,
        expert=ef.astype(np.int32), token=tf, weight=wf, pos=pos,
        keep=keep, cap=cap_e, counts=counts, router_counts=router_counts,
        stolen=stolen, dropped=int((~keep).sum()))


# ---------------------------------------------------------------------------
# Closing the loop: measured expert load -> next step's cap_scale
# ---------------------------------------------------------------------------

def cap_scale_from_costs(costs: np.ndarray, *,
                         lo: float = MOE_CAP_SCALE_MIN,
                         hi: float = MOE_CAP_SCALE_MAX) -> np.ndarray:
    """Per-expert capacity scale from (refined) per-expert costs: the
    cost-to-mean ratio clipped to the materializable range, renormalized
    only when the total EXCEEDS the budget (sum == E) — the same clip and
    budget rule as the in-graph `ich_update_cap_scale`, but derived from
    absolute load estimates instead of a multiplicative step."""
    costs = np.asarray(costs, np.float64)
    mu = costs.mean() if costs.size else 0.0
    if mu <= 0:
        return np.ones_like(costs)
    scale = np.clip(costs / mu, lo, hi)
    over = scale.sum() / scale.size
    return scale / over if over > 1.0 else scale


def refine_cap_scale(schedule, measured: np.ndarray, *,
                     blend: float = None,
                     lo: float = MOE_CAP_SCALE_MIN,
                     hi: float = MOE_CAP_SCALE_MAX):
    """One closed-loop round: fold measured per-expert load (what the
    sharded MoE kernel's per-expert cost output sums to) into the
    schedule's `CostRefiner`, re-lower, and derive the next step's
    cap_scale from the refined estimates.

    Returns `(refined_schedule, cap_scale)`. Repeated rounds on a
    structural (integer-count) workload reach a fixed point: once the
    Welford means equal the true loads, both the schedule and the scale
    stop moving (tests/test_moe_sched.py)."""
    refined = schedule.observe(np.asarray(measured, np.float64),
                               level="item").refine(blend=blend)
    return refined, cap_scale_from_costs(refined.costs, lo=lo, hi=hi)
