"""`repro.sched` — the single public entry point for loop scheduling.

The paper's point is that ONE adaptive scheduler serves every irregular
workload without per-application tuning; this package is that claim as an
API (DESIGN.md §3). One facade spans all four backends:

    from repro import sched

    scheduler = sched.LoopScheduler(p=28)
    s = scheduler.schedule(costs)          # -> Schedule (cached, LRU)
    s.simulate()                           # (a) discrete-event simulator
    s.parallel_for(body)                   # (b) real threaded executor
    s.lower()                              # (c) TileSchedule for Pallas
    spmv = scheduler.build("spmv", indptr, indices, data)   # (d) kernels
    y = spmv(x)

New applications plug in through the registry instead of a new ops class:

    sched.register("myapp", costs=..., build=...)
    op = scheduler.build("myapp", *inputs)

Exports are lazy (PEP 562) for two reasons: `repro.core` imports
`repro.sched.defaults` for the unified iCh epsilon, so this init must not
eagerly import core back; and the numpy-only surface (facade, simulator,
executor) must stay importable without paying for jax.
"""
from .defaults import (ICH_EPS, MAX_WIDTH, MIN_WIDTH, ROWS_PER_TILE,
                       SUPERSTEP)

_LAZY = {
    # facade + schedule object (sched/api.py)
    "LoopScheduler": "api",
    "Schedule": "api",
    "default_scheduler": "api",
    # measured-cost feedback (sched/adaptive.py)
    "CostRefiner": "adaptive",
    # cost providers (sched/costs.py)
    "CostProvider": "costs",
    "DegreeCosts": "costs",
    "ExpertLoadCosts": "costs",
    "ExplicitCosts": "costs",
    "NnzCosts": "costs",
    "RefinedCosts": "costs",
    "RemainingTokensCosts": "costs",
    "as_cost_provider": "costs",
    # MoE dispatch planning (sched/moe.py, DESIGN.md §2.8)
    "DispatchPlan": "moe",
    "cap_scale_from_costs": "moe",
    "expert_capacity": "moe",
    "plan_dispatch": "moe",
    "refine_cap_scale": "moe",
    # schedule cache (sched/cache.py)
    "CacheStats": "cache",
    "ScheduleCache": "cache",
    # workload/kernel registry (sched/registry.py)
    "WorkloadSpec": "registry",
    "get": "registry",
    "register": "registry",
    "registered": "registry",
    # shard dispatch (sched/data_sched.py)
    "ShardDispatcher": "data_sched",
    # policy family + simulator knobs, re-exported so facade users need only
    # this package (the objects live in repro.core and stay usable from there)
    "Policy": "_core",
    "assigned": "_core",
    "binlpt": "_core",
    "dynamic": "_core",
    "guided": "_core",
    "ich": "_core",
    "paper_policy_grid": "_core",
    "pretiled": "_core",
    "static": "_core",
    "stealing": "_core",
    "taskloop": "_core",
    "SimParams": "_core",
    "SimResult": "_core",
    "TileSchedule": "_core",
    "WorkerShards": "_core",
}

__all__ = ["ICH_EPS", "MAX_WIDTH", "MIN_WIDTH", "ROWS_PER_TILE", "SUPERSTEP",
           *sorted(_LAZY)]


def __getattr__(name):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    if mod == "_core":
        from repro.core import policies, simulator, tiling
        for m in (policies, simulator, tiling):
            if hasattr(m, name):
                return getattr(m, name)
        raise AttributeError(name)  # pragma: no cover - _LAZY names exist
    import importlib
    return getattr(importlib.import_module(f".{mod}", __name__), name)


def __dir__():
    return sorted(__all__)
