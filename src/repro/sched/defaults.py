"""Shared scheduler defaults — the single source of truth for tuned values.

This module is imported by BOTH sides of the stack (`repro.core` below the
facade, kernels/serving above it), so it must stay dependency-free: no
numpy, no jax, no intra-repo imports. That is what lets `core/policies.py`
import the constant without a circular import through the `repro.sched`
package init.
"""

# The paper evaluates iCh at eps in {25%, 33%, 50%} (Table 2) and finds the
# method insensitive within the band (eq. 10, Fig. 7); 33% is the midpoint
# the TPU schedule-construction layer was tuned with (DESIGN.md §2: the band
# edge mu*(1+eps) picks the tile width) and is what every kernel op shipped
# with. It is now the one default everywhere — the runtime policy
# (`core/policies.py:ich`), schedule construction (`core/tiling.py`), the
# kernel wrappers, the MoE balancer, and the serving engine all import it.
ICH_EPS = 0.33

# Segment slots per tile (R) for constructed schedules: 8 keeps the one-hot
# epilogue matmul (R, R) tiny while giving splitting enough slots to spread
# a heavy item (DESIGN.md §2.5).
ROWS_PER_TILE = 8

# Tile-width clamp for `ich_tile_width` (work units per segment slot).
MIN_WIDTH = 8
MAX_WIDTH = 512

# Tiles per kernel superstep (B): each grid step of a worker-sharded ich_*
# kernel processes B tiles at once (a (B*R, W) payload block), amortizing
# the per-step dispatch/prefetch overhead over B tiles (DESIGN.md §2.6).
SUPERSTEP = 8

# Measured-cost feedback (DESIGN.md §2.7). REFINE_BLEND is the weight of
# the observed running mean against the a-priori estimate once an item has
# been observed at least once: 1.0 trusts measurements fully (the paper's
# posture — iCh's whole premise is that the runtime signal beats the
# estimate), lower values damp noisy single observations. Items never
# observed always keep their prior.
REFINE_BLEND = 1.0

# Rounds the refine-loop demo/benchmark runs (observe -> refine cycles on
# the jittered workload in benchmarks/bench_schedule_build.py).
REFINE_ROUNDS = 3

# MoE expert dispatch (DESIGN.md §2.8): per-expert capacity is the chunk-
# size analogue, so its knobs live with the scheduler defaults and are
# imported by BOTH the in-graph layer (models/moe.py) and the host-side
# dispatch planner (sched/moe.py) — one source of truth keeps the two
# paths bit-identical at equal capacity.
MOE_CAPACITY_FACTOR = 1.25   # C_base = ceil(K * T * factor / E)
MOE_CMAX_FACTOR = 2.0        # compiled expert buffer = factor * C_base
MOE_MIN_CAPACITY = 4         # capacity floor (tiny decode pools)
# cap_scale (the d_i array) is clipped to the materializable range: the
# compiled buffer is C_max = MOE_CMAX_FACTOR * C_base, so scale can never
# usefully exceed it, and 0.25 keeps cold experts warm enough to recover.
MOE_CAP_SCALE_MIN = 0.25
MOE_CAP_SCALE_MAX = 2.0
