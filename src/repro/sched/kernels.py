"""Registry-backed kernel ops for the three paper applications.

Each op binds a constructed `Schedule` to a workload's payloads once
(pack), then applies the Pallas kernel many times. These are the
implementations behind `scheduler.build("spmv" | "bfs" | "kmeans", ...)`;
the legacy `IChSpmv` / `IChBfs` / `IChKMeans` classes under
`repro/kernels/ich_*/ops.py` are deprecation shims over this module.

Ops execute on the worker-sharded 2D kernels (DESIGN.md §2.6): the
schedule's tiles are cost-partitioned across `schedule.p` accelerator
workers at superstep-block granularity (`Schedule.shard()`), payloads
stay in the FLAT (T_pad, R, W) pack (padded to whole supersteps), and
each grid step fetches one worker's next block of `schedule.superstep`
tiles via the prefetched block-index stream — lowering to the shard
layout moves no payload bytes. Outputs are bit-identical to the
sequential (T,)-grid kernels (tests/test_sharding.py), which remain
available in the kernel modules as the cross-check path.

Measured-cost feedback (DESIGN.md §2.7): every op passes the schedule's
per-slot cost stream into the sharded kernel, which emits a per-worker,
per-superstep cost output alongside its payload result. The op stashes the
latest stream as `last_costs`; calling `op.observe()` folds it back into
the schedule's `CostRefiner`, after which `op.schedule.refine()` re-lowers
under a fresh cache generation. Per-worker sums of the emitted stream
equal the schedule's tile-cost totals exactly — the routing proof in
tests/test_adaptive_properties.py.

jax is imported inside the op constructors: deriving costs and constructing
schedules is numpy-only, and the registry must be listable without paying
the jax import.
"""
from __future__ import annotations

import functools

import numpy as np

from repro.core.tiling import pack_csr

from .api import Schedule
from .costs import (DegreeCosts, ExpertLoadCosts, ExplicitCosts, NnzCosts,
                    RemainingTokensCosts)
from .registry import register


def _flat_slot_cost(schedule: Schedule, n_tiles_padded: int) -> np.ndarray:
    """The (T_pad, R) float32 per-slot scheduled-cost stream the sharded
    SpMV/BFS kernels fetch blockwise (pad tiles carry zeros)."""
    sc = np.zeros((n_tiles_padded, schedule.rows_per_tile), np.float32)
    sc[:schedule.n_tiles] = schedule.slot_cost()
    return sc


def _sharded_slot_cost(schedule: Schedule, shards) -> np.ndarray:
    """The (p*S, R) per-slot cost stream in SHARD layout for kernels with
    no flat-payload indirection (K-Means); padding rows are zero."""
    flat = shards.perm.reshape(-1)
    if schedule.n_tiles == 0:  # 0-tile schedule: all rows are padding
        return np.zeros((flat.size, schedule.rows_per_tile), np.float32)
    sc = schedule.slot_cost()
    out = np.where((flat >= 0)[:, None], sc[np.clip(flat, 0, None)], 0.0)
    return np.ascontiguousarray(out, np.float32)


class _ObservableOp:
    """Shared feedback plumbing: stash the kernel's latest cost stream and
    route it into the schedule's refiner on demand."""

    schedule: Schedule
    last_costs = None  # (p, S_B) device array from the latest invocation

    def _empty_costs(self):
        """Zero (p, S_B) cost stream for a 0-tile schedule: an empty
        workload lowers as a no-op — no kernel launch, no payload fetch —
        but the op still reports a well-shaped (all-zero) cost stream."""
        import jax.numpy as jnp
        return jnp.zeros(self.shards.block_perm.shape, jnp.float32)

    def observe(self) -> Schedule:
        """Fold the latest invocation's per-worker, per-superstep cost
        stream into `schedule.refiner`; chain with
        ``op.observe().refine()`` to re-lower from it. The op names its
        own shard lowering explicitly — a (p, S_B) shape alone cannot
        identify one."""
        if self.last_costs is None:
            raise ValueError("no kernel invocation to observe yet; run the "
                             "op first")
        return self.schedule.observe(np.asarray(self.last_costs),
                                     shards=self.shards)


def _default_interpret(interpret):
    if interpret is None:
        import jax
        return jax.default_backend() != "tpu"
    return interpret


class SpmvOp(_ObservableOp):
    """iCh-scheduled segmented CSR SpMV: pack once, apply many times."""

    def __init__(self, schedule: Schedule, indptr, indices, data):
        import jax.numpy as jnp
        self.schedule = schedule
        self.n_rows = len(indptr) - 1
        shards = self.shards = schedule.shard()
        vals, cols = pack_csr(np.asarray(indptr), np.asarray(indices),
                              np.asarray(data), schedule.tiles,
                              pad_tiles_to=shards.superstep)
        self.width = schedule.width
        self.p = shards.p
        self.superstep = shards.superstep
        self.vals = jnp.asarray(vals)
        self.cols = jnp.asarray(cols)
        self.rowid = jnp.asarray(shards.shard_item_id(schedule.tiles))
        self.blkid = jnp.asarray(shards.kernel_block_ids())
        self.slot_cost = jnp.asarray(
            _flat_slot_cost(schedule, shards.n_tiles_padded))
        self.last_costs = None
        self._jitted = {}  # interpret mode -> jitted spmv (compile once)

    def __call__(self, x, interpret: bool | None = None):
        import jax
        import jax.numpy as jnp
        from repro.kernels.ich_spmv.ich_spmv import ich_spmv_sharded
        if self.schedule.n_tiles == 0:
            self.last_costs = self._empty_costs()
            return jnp.zeros((self.n_rows,), jnp.float32)
        interpret = _default_interpret(interpret)
        if interpret not in self._jitted:
            self._jitted[interpret] = jax.jit(functools.partial(
                ich_spmv_sharded, n_rows=self.n_rows, p=self.p,
                superstep=self.superstep, interpret=interpret))
        y, self.last_costs = self._jitted[interpret](
            self.vals, self.cols, self.rowid, self.blkid, x,
            slot_cost=self.slot_cost)
        return y


class BfsOp(_ObservableOp):
    """iCh-scheduled BFS: pack the graph once, expand frontiers many times."""

    def __init__(self, schedule: Schedule, indptr, indices):
        import jax.numpy as jnp
        self.schedule = schedule
        self.n = len(indptr) - 1
        shards = self.shards = schedule.shard()
        mask, cols = pack_csr(np.asarray(indptr), np.asarray(indices),
                              np.ones(len(indices), np.float32),
                              schedule.tiles,
                              pad_tiles_to=shards.superstep)
        self.p = shards.p
        self.superstep = shards.superstep
        self.mask = jnp.asarray(mask)
        self.cols = jnp.asarray(cols)
        self.rowid = jnp.asarray(shards.shard_item_id(schedule.tiles))
        self.blkid = jnp.asarray(shards.kernel_block_ids())
        self.slot_cost = jnp.asarray(
            _flat_slot_cost(schedule, shards.n_tiles_padded))
        self.last_costs = None
        self._jitted = {}  # interpret mode -> jitted step (compile once)

    def step(self, frontier, visited, interpret: bool | None = None):
        """One frontier expansion; indicator in, indicator out."""
        import jax
        import jax.numpy as jnp
        from repro.kernels.ich_bfs.ich_bfs import ich_bfs_step_sharded
        if self.schedule.n_tiles == 0:
            self.last_costs = self._empty_costs()
            return jnp.zeros((self.n,), jnp.float32)
        interpret = _default_interpret(interpret)
        if interpret not in self._jitted:
            self._jitted[interpret] = jax.jit(functools.partial(
                ich_bfs_step_sharded, n_vertices=self.n, p=self.p,
                superstep=self.superstep, interpret=interpret))
        nxt, self.last_costs = self._jitted[interpret](
            self.mask, self.cols, self.rowid, self.blkid,
            jnp.asarray(frontier, jnp.float32),
            jnp.asarray(visited, jnp.float32), slot_cost=self.slot_cost)
        return nxt

    def levels(self, source: int = 0,
               interpret: bool | None = None) -> np.ndarray:
        """Full traversal: level per vertex (-1 = unreached)."""
        level = np.full(self.n, -1, np.int32)
        level[source] = 0
        frontier = np.zeros(self.n, np.float32)
        frontier[source] = 1.0
        visited = frontier.copy()
        depth = 0
        while frontier.any():
            nxt = np.asarray(self.step(frontier, visited, interpret))
            depth += 1
            level[nxt > 0] = depth
            visited = np.maximum(visited, nxt)
            frontier = nxt
        return level


class KMeansOp(_ObservableOp):
    """iCh-scheduled K-Means assignment over a predicted per-point cost."""

    def __init__(self, schedule: Schedule, costs):
        import jax.numpy as jnp
        self.schedule = schedule
        self.sizes = schedule.sizes
        self.n = schedule.n_items
        shards = self.shards = schedule.shard()
        self.p = shards.p
        self.superstep = shards.superstep
        self.rowid = jnp.asarray(shards.shard_item_id(schedule.tiles))
        self.slot_cost = jnp.asarray(_sharded_slot_cost(schedule, shards))
        self.last_costs = None
        self._jitted = {}  # interpret mode -> jitted assign (compile once)

    def __call__(self, points, centroids, interpret: bool | None = None):
        import jax
        import jax.numpy as jnp
        from repro.kernels.ich_kmeans.ich_kmeans import \
            ich_kmeans_assign_sharded
        if self.schedule.n_tiles == 0:
            self.last_costs = self._empty_costs()
            return jnp.zeros((self.n,), jnp.int32)
        interpret = _default_interpret(interpret)
        if interpret not in self._jitted:
            self._jitted[interpret] = jax.jit(functools.partial(
                ich_kmeans_assign_sharded, p=self.p,
                superstep=self.superstep, interpret=interpret))
        assign, self.last_costs = self._jitted[interpret](
            jnp.asarray(points, jnp.float32),
            jnp.asarray(centroids, jnp.float32), self.rowid,
            slot_cost=self.slot_cost)
        return assign


class MoeDispatchOp(_ObservableOp):
    """iCh-scheduled MoE expert application: pack a dispatch plan once,
    apply the expert FFN stack many times (DESIGN.md §2.8).

    The plan's expert-major CSR (token ids + combine weights per expert)
    packs through the same `pack_csr` path as SpMV — expert = item, a hot
    expert's tokens split across tiles like a heavy row — and executes on
    the worker-sharded `ich_moe_sharded` kernel. Besides the (p, S_B)
    superstep cost stream every op emits, this kernel also returns
    (p, E) per-worker PER-EXPERT cost totals (`last_expert_costs`);
    `expert_load()` worker-sums them into the measured per-expert load
    that `repro.sched.moe.refine_cap_scale` folds into the next step's
    capacity scale."""

    def __init__(self, schedule: Schedule, plan):
        import jax.numpy as jnp
        self.schedule = schedule
        self.plan = plan
        self.n_tokens = plan.n_tokens
        self.n_experts = plan.n_experts
        shards = self.shards = schedule.shard()
        indptr, tok, w = plan.csr()
        vals, cols = pack_csr(indptr, tok, w, schedule.tiles,
                              pad_tiles_to=shards.superstep)
        self.p = shards.p
        self.superstep = shards.superstep
        self.vals = jnp.asarray(vals)
        self.cols = jnp.asarray(cols)
        self.rowid = jnp.asarray(shards.shard_item_id(schedule.tiles))
        self.blkid = jnp.asarray(shards.kernel_block_ids())
        self.slot_cost = jnp.asarray(
            _flat_slot_cost(schedule, shards.n_tiles_padded))
        self.last_costs = None
        self.last_expert_costs = None  # (p, E) from the latest invocation
        self._jitted = {}  # interpret mode -> jitted apply (compile once)

    def __call__(self, x, wi, wg, wo, interpret: bool | None = None):
        """Apply the planned dispatch: x (n_tokens, D) token activations,
        wi/wg (E, D, F), wo (E, F, D). Returns y (n_tokens, D)."""
        import jax
        import jax.numpy as jnp
        from repro.kernels.ich_moe.ich_moe import ich_moe_sharded
        # n_tokens == 0 also short-circuits: a zero-admission plan still
        # carries one tile per (zero-count) expert, but the kernel's token
        # gather has no source rows to read
        if self.schedule.n_tiles == 0 or self.n_tokens == 0:
            self.last_costs = self._empty_costs()
            self.last_expert_costs = jnp.zeros(
                (self.p, self.n_experts), jnp.float32)
            return jnp.zeros((self.n_tokens, x.shape[-1]), x.dtype)
        interpret = _default_interpret(interpret)
        if interpret not in self._jitted:
            self._jitted[interpret] = jax.jit(functools.partial(
                ich_moe_sharded, p=self.p, superstep=self.superstep,
                interpret=interpret))
        y, self.last_costs, self.last_expert_costs = self._jitted[interpret](
            self.vals, self.cols, self.rowid, self.blkid, x, wi, wg, wo,
            slot_cost=self.slot_cost)
        return y

    def expert_load(self) -> np.ndarray:
        """Measured per-expert cost totals of the latest invocation
        (worker-summed (E,) float64) — equals the plan's kept token
        counts exactly; the signal `refine_cap_scale` consumes."""
        if self.last_expert_costs is None:
            raise ValueError("no kernel invocation to read yet; run the "
                             "op first")
        return np.asarray(self.last_expert_costs, np.float64).sum(axis=0)


register(
    "spmv",
    costs=lambda indptr, indices, data: NnzCosts(indptr),
    build=SpmvOp,
    doc="Segmented CSR SpMV; inputs (indptr, indices, data); cost = row nnz.")
register(
    "bfs",
    costs=lambda indptr, indices: DegreeCosts(indptr),
    build=BfsOp,
    doc="Pull-direction BFS; inputs (indptr, indices); cost = in-degree.")
register(
    "kmeans",
    # float64 coercion keeps the provider on its quantizing path (ceil, >= 1
    # unit per point) for integer inputs too — every point must be computed
    costs=lambda costs: ExplicitCosts(np.asarray(costs, np.float64)),
    build=KMeansOp,
    doc="K-Means assignment; input (predicted per-point costs).")
register(
    "moe-dispatch",
    costs=lambda plan: ExpertLoadCosts(plan.counts),
    build=MoeDispatchOp,
    doc="MoE expert FFN over a dispatch plan (sched/moe.py); input "
        "(DispatchPlan); cost = per-expert kept token load.")
register(
    "serve-prefill",
    costs=lambda remaining: RemainingTokensCosts(
        np.asarray(remaining, np.int64)),
    # there is no kernel here: the "op" IS the schedule — the continuous
    # batcher (serve/batcher.py) consumes its cost estimates and tile
    # order to pick the next prefill target, and routes measured step
    # wall-clock back through Schedule.observe/refine (DESIGN.md §2.10)
    build=lambda schedule, remaining: schedule,
    doc="Continuous-batching prefill scheduling; input (per-request "
        "remaining prompt token counts); cost = remaining tokens.")
