"""Per-host input-shard dispatch with stealing — the data-path consumer of
the threaded executor.

This is the module `core/executor.py` runs for real in production: the
global batch is a loop over example shards, each ingest host owns a
contiguous shard range (distributed deques), chunk sizes adapt with iCh's
band classification, and idle hosts steal shard ranges from stragglers
(slow disks / hot nodes). `data/pipeline.py` wraps this dispatcher in its
double-buffered synthetic pipeline.

When per-shard costs are known (byte counts, historical read times), the
dispatcher routes them through the `LoopScheduler` facade so the schedule
is constructed once and reused across steps via the shared LRU cache —
the same pack-once/apply-many pattern the kernels use.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from repro.core import executor as E
from repro.core import policies as P

from .api import LoopScheduler, default_scheduler
from .defaults import ICH_EPS


@dataclasses.dataclass
class DispatchStats:
    chunks: int = 0
    steals: int = 0

    @classmethod
    def from_exec(cls, stats: E.ExecStats) -> "DispatchStats":
        return cls(chunks=stats.chunks, steals=stats.steals)


class ShardDispatcher:
    """Dispatch ingest work items across `n_hosts` worker threads under the
    iCh policy (adaptive chunk + stealing)."""

    def __init__(self, n_hosts: int = 4, eps: float = ICH_EPS,
                 scheduler: Optional[LoopScheduler] = None):
        self.n_hosts = int(n_hosts)
        self.policy = P.ich(eps)
        self._scheduler = scheduler

    @property
    def scheduler(self) -> LoopScheduler:
        return self._scheduler or default_scheduler()

    def dispatch(self, n_shards: int,
                 read_fn: Callable[[int], None]) -> DispatchStats:
        """read_fn(i) ingests shard i (exactly once, any host)."""
        stats = self.scheduler.parallel_for(
            n_shards, read_fn, p=self.n_hosts, policy=self.policy)
        return DispatchStats.from_exec(stats)

    def dispatch_weighted(self, shard_costs: np.ndarray,
                          read_fn: Callable[[int], None]) -> DispatchStats:
        """Cost-aware dispatch: shards with known per-shard costs (byte
        counts, historical read times) are cut into equal-work contiguous
        chunks (the BinLPT law) offered heaviest-first, so no host starts
        on a light chunk while a heavy one waits. The chunk list is
        memoized in the facade's LRU cache — a repeated cost array across
        steps skips chunking entirely; `read_fn` runs exactly once per
        shard either way."""
        costs = np.asarray(shard_costs, np.float64)
        from .costs import _digest

        def chunk():
            return tuple(P.pretile(P.binlpt(4 * self.n_hosts), costs,
                                   self.n_hosts))

        cache = self.scheduler.cache
        if cache is None:
            chunks = chunk()
        else:
            chunks = cache.get_or_build(
                ("data_sched", _digest(costs), self.n_hosts), chunk)
        stats = self.scheduler.parallel_for(
            len(costs), read_fn, p=self.n_hosts, policy=P.pretiled(chunks))
        return DispatchStats.from_exec(stats)
