"""Cost providers: how a workload tells the scheduler what its items cost.

Every scheduling decision in this repo starts from a per-item cost array —
nnz per CSR row, in-degree per vertex, predicted cost per K-Means point.
`CostProvider` is the small protocol the `LoopScheduler` facade consumes:

* ``sizes()``  -> integer work units per item (drives tile construction;
  zero is allowed — a zero-size item still gets an output slot);
* ``costs()``  -> float per-item costs (drives the simulator's time model);
* ``fingerprint()`` -> stable content hash, the schedule-cache key part.

Three concrete providers cover the paper's applications: `NnzCosts` (CSR
matrix row lengths), `DegreeCosts` (graph adjacency-list lengths), and
`ExplicitCosts` (any per-item array; float arrays are quantized to work
units the same way the K-Means wrapper always did). `as_cost_provider`
lets facade callers pass a bare ndarray anywhere a provider is expected.
"""
from __future__ import annotations

import hashlib
from typing import Protocol, runtime_checkable

import numpy as np


@runtime_checkable
class CostProvider(Protocol):
    """Per-item work description consumed by `LoopScheduler.schedule`."""

    def sizes(self) -> np.ndarray:
        """Integer work units per item, shape (n,). May contain zeros."""
        ...

    def costs(self) -> np.ndarray:
        """Float per-item costs for the simulator's time model, shape (n,)."""
        ...

    def fingerprint(self) -> str:
        """Stable content hash; equal inputs must produce equal values."""
        ...

    # NOTE: providers may additionally expose `sizes_are_structural`
    # (bool). True means sizes() describes a payload layout (CSR row nnz,
    # adjacency degrees) that measured-cost refinement must NOT re-derive
    # from refreshed costs; False means sizes are merely quantized cost
    # estimates and refinement may re-tile from scratch. Absent, the
    # facade assumes True (the conservative choice: a kept size array is
    # always payload-safe). See `sched/adaptive.py` / `Schedule.refine`.


def _digest(*arrays: np.ndarray) -> str:
    h = hashlib.blake2b(digest_size=16)
    for a in arrays:
        a = np.ascontiguousarray(a)
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()


def quantize_costs(costs: np.ndarray) -> np.ndarray:
    """Predicted float costs -> integer work units (>= 1 per item)."""
    return np.maximum(np.ceil(np.asarray(costs, np.float64)), 1.0).astype(
        np.int64)


class ExplicitCosts:
    """A bare per-item cost array.

    Integer arrays are taken as work units verbatim (zeros allowed — the
    empty-CSR-row case); float arrays are the simulator-facing costs and
    are quantized to `>= 1` work units for tile construction, exactly like
    the K-Means wrapper's predicted-cost path.

    Only the fingerprint is computed eagerly; `sizes()`/`costs()`
    materialize on first use, so a schedule-cache HIT pays the hash and
    nothing else. Materialized arrays are copies — a cached `Schedule`
    never aliases a caller-mutable buffer. Do not mutate the input array
    between construction and the first `sizes()`/`costs()` call (the
    fingerprint describes the content at construction time).
    """

    def __init__(self, values: np.ndarray):
        values = np.asarray(values)
        if values.ndim != 1:
            raise ValueError(f"per-item costs must be 1-D, got {values.shape}")
        if not (np.issubdtype(values.dtype, np.integer)
                or np.issubdtype(values.dtype, np.floating)):
            raise TypeError(f"cost array must be numeric, got {values.dtype}")
        self._values = values
        self._sizes = None
        self._costs = None
        self._structural = np.issubdtype(values.dtype, np.integer)
        self._fp = f"explicit:{_digest(values)}"

    def _materialize(self) -> None:
        values = self._values
        # astype copies (default copy=True) even for matching dtypes: the
        # results outlive this call inside LRU-cached Schedule objects and
        # must not alias caller-mutable buffers
        if np.issubdtype(values.dtype, np.integer):
            self._sizes = values.astype(np.int64)
            self._costs = values.astype(np.float64)
        else:
            self._costs = values.astype(np.float64)
            self._sizes = quantize_costs(self._costs)
        self._values = None  # drop the caller-buffer reference

    def sizes(self) -> np.ndarray:
        if self._sizes is None:
            self._materialize()
        return self._sizes

    def costs(self) -> np.ndarray:
        if self._costs is None:
            self._materialize()
        return self._costs

    def fingerprint(self) -> str:
        return self._fp

    @property
    def sizes_are_structural(self) -> bool:
        """Integer inputs ARE the work units (keep them across refinement);
        float inputs only quantize to units (refinement may re-derive)."""
        return bool(self._structural)


class NnzCosts:
    """Per-row nonzero counts of a CSR matrix: cost[i] = indptr[i+1] -
    indptr[i]. The paper's SpMV workload (cost ~ row nnz).

    Fingerprint eager, `sizes()` lazy — same cache-hit economics and
    no-mutation window as `ExplicitCosts`."""

    _kind = "nnz"

    def __init__(self, indptr: np.ndarray):
        indptr = np.asarray(indptr)
        if indptr.ndim != 1 or indptr.size < 1:
            raise ValueError(f"indptr must be 1-D non-empty, got {indptr.shape}")
        self._indptr = indptr
        self._sizes = None
        self._fp = f"{self._kind}:{_digest(indptr)}"

    def sizes(self) -> np.ndarray:
        if self._sizes is None:
            # np.diff allocates fresh memory: no caller-buffer aliasing
            self._sizes = np.diff(self._indptr).astype(np.int64, copy=False)
            self._indptr = None
        return self._sizes

    def costs(self) -> np.ndarray:
        return self.sizes().astype(np.float64)

    def fingerprint(self) -> str:
        return self._fp

    @property
    def sizes_are_structural(self) -> bool:
        """Row lengths ARE the CSR payload layout; refinement keeps them."""
        return True


class DegreeCosts(NnzCosts):
    """Per-vertex degree of a CSR graph (row u = u's neighbor list): the
    paper's BFS per-vertex cost. Structurally `NnzCosts`; kept distinct so
    registry entries and fingerprints name the workload they describe."""

    _kind = "degree"


class ExpertLoadCosts:
    """Per-expert kept token counts from an MoE router — the expert-
    dispatch analogue of `NnzCosts` (DESIGN.md §2.8): item = expert,
    work units = tokens dispatched to it, and the counts ARE the
    expert-major CSR payload layout of the dispatch plan, so sizes are
    structural (refinement re-weights the partition but never re-derives
    the token layout from measured costs).

    Zero-load experts are allowed (a cold expert still owns an output
    slot). Fingerprint eager, arrays copied on first use — same cache-hit
    economics and no-aliasing guarantees as the other providers."""

    _kind = "expert-load"

    def __init__(self, counts: np.ndarray):
        counts = np.asarray(counts)
        if counts.ndim != 1 or counts.size < 1:
            raise ValueError(
                f"expert loads must be 1-D non-empty, got {counts.shape}")
        if not np.issubdtype(counts.dtype, np.integer):
            raise TypeError(
                f"expert loads are token counts, expected an integer "
                f"array, got {counts.dtype}")
        if (counts < 0).any():
            raise ValueError("expert loads must be non-negative")
        self._counts = counts
        self._sizes = None
        self._fp = f"{self._kind}:{_digest(counts)}"

    def sizes(self) -> np.ndarray:
        if self._sizes is None:
            self._sizes = self._counts.astype(np.int64)  # astype copies
            self._counts = None
        return self._sizes

    def costs(self) -> np.ndarray:
        return self.sizes().astype(np.float64)

    def fingerprint(self) -> str:
        return self._fp

    @property
    def sizes_are_structural(self) -> bool:
        """Token counts ARE the dispatch-buffer layout; refinement keeps
        them."""
        return True


class RemainingTokensCosts:
    """Per-request REMAINING prompt tokens — the serving engine's cost
    provider (DESIGN.md §2.10): item = an in-flight request's prefill
    stream, work units = prompt tokens not yet prefilled. The continuous
    batcher re-presents this every engine step as chunks complete, and the
    measured step wall-clock flows back through `Schedule.observe/refine`
    so the per-request cost estimates track the machine, not the token
    count alone.

    Zero-remaining requests are allowed (a request that finished prefill
    but still holds a batch slot). Token counts ARE the chunk layout the
    batcher slices, so sizes are structural. Fingerprint eager, arrays
    copied on first use — same cache-hit economics and no-aliasing
    guarantees as the other providers."""

    _kind = "remaining-tokens"

    def __init__(self, remaining: np.ndarray):
        remaining = np.asarray(remaining)
        if remaining.ndim != 1 or remaining.size < 1:
            raise ValueError(
                f"remaining tokens must be 1-D non-empty, got "
                f"{remaining.shape}")
        if not np.issubdtype(remaining.dtype, np.integer):
            raise TypeError(
                f"remaining tokens are counts, expected an integer array, "
                f"got {remaining.dtype}")
        if (remaining < 0).any():
            raise ValueError("remaining token counts must be non-negative")
        self._remaining = remaining
        self._sizes = None
        self._fp = f"{self._kind}:{_digest(remaining)}"

    def sizes(self) -> np.ndarray:
        if self._sizes is None:
            self._sizes = self._remaining.astype(np.int64)  # astype copies
            self._remaining = None
        return self._sizes

    def costs(self) -> np.ndarray:
        return self.sizes().astype(np.float64)

    def fingerprint(self) -> str:
        return self._fp

    @property
    def sizes_are_structural(self) -> bool:
        """Token counts ARE the prefill chunk layout; refinement keeps
        them."""
        return True


class RefinedCosts:
    """Measured-cost refinement output: refreshed per-item costs, with the
    work-unit sizes either KEPT from the parent schedule (structural —
    payload layouts must not drift) or re-derived by quantization
    (estimate-only sizes). Carries the refinement `generation` in its
    fingerprint so a refined schedule can never alias a stale cache entry
    (`Schedule.refine`, sched/cache.py).
    """

    def __init__(self, sizes: np.ndarray, costs: np.ndarray, *,
                 generation: int, structural: bool):
        costs = np.asarray(costs, np.float64)
        if costs.ndim != 1:
            raise ValueError(f"per-item costs must be 1-D, got {costs.shape}")
        self._costs = costs.copy()
        self._structural = bool(structural)
        self._gen = int(generation)
        if self._structural:
            sizes = np.asarray(sizes, np.int64)
            if sizes.shape != costs.shape:
                raise ValueError(f"sizes {sizes.shape} != costs {costs.shape}")
            self._sizes = sizes.copy()
        else:
            self._sizes = quantize_costs(self._costs)
        self._fp = (f"refined:g{self._gen}:"
                    f"{_digest(self._sizes, self._costs)}")

    def sizes(self) -> np.ndarray:
        return self._sizes

    def costs(self) -> np.ndarray:
        return self._costs

    def fingerprint(self) -> str:
        return self._fp

    @property
    def sizes_are_structural(self) -> bool:
        return self._structural

    @property
    def generation(self) -> int:
        return self._gen


def as_cost_provider(costs) -> CostProvider:
    """Coerce facade inputs: a provider passes through, an array wraps."""
    if isinstance(costs, CostProvider):
        return costs
    return ExplicitCosts(costs)
