"""Measured-cost feedback: fold observed execution costs into refreshed
per-item estimates (DESIGN.md §2.7).

The paper's iCh adapts chunk size *during* a loop from the running
mean/deviation band of observed per-worker progress (§3.2, eqs. 4-8). On an
accelerator the schedule is constructed ahead of time, so the same signal
closes the loop at the next-coarser granularity: ACROSS invocations. Every
execution layer emits what it actually measured —

* the discrete-event simulator: per-chunk dispatched work
  (``SimResult.chunk_log``, chunk == tile for a replayed schedule);
* the threaded executor: per-chunk wall seconds
  (``ExecStats.chunk_log``, both central and distributed paths);
* the worker-sharded Pallas kernels: a per-worker, per-superstep cost
  output ref (`sched/kernels.py` routes it back here);

— and `CostRefiner` folds those observations through the vectorized
Welford recurrence (`core/welford.WelfordVec`, the paper's eqs. 6-7 kept
exact because host-side refinement CAN afford it) into per-item running
statistics. `refined_costs()` then blends the running means with the
a-priori estimates, and `Schedule.refine()` re-tiles / re-partitions /
re-shards from the result under a fresh cache generation
(`sched/api.py`).

Observations arrive at whatever granularity the layer could measure —
per item, per tile, per contiguous item- or unit-range, per worker
superstep block. Coarse observations are distributed DOWN to items
proportionally to the current estimates (the only unbiased split absent
finer information; uniform when the estimate mass is zero), and an item
only partially covered by the observed chunks has its sample extrapolated
by the observed fraction of its estimated mass, so partial traces don't
bias items low. Each ``observe_*`` call is one execution round: one
Welford sample per covered item.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.tiling import TileSchedule, WorkerShards
from repro.core.welford import WelfordVec

from .defaults import REFINE_BLEND


def _proportional_split(measured: np.ndarray,
                        weights: np.ndarray,
                        owner: np.ndarray,
                        n_groups: int) -> np.ndarray:
    """Distribute `measured[g]` over the members of each group g in
    proportion to `weights` (uniform within a group whose weight mass is
    zero but which still has members). `owner[k]` names member k's group
    (-1 = unowned, dropped). Returns the per-member share array."""
    measured = np.asarray(measured, np.float64)
    weights = np.asarray(weights, np.float64)
    owned = owner >= 0
    safe_owner = np.where(owned, owner, 0)
    wsum = np.bincount(safe_owner[owned], weights=weights[owned],
                       minlength=n_groups)
    csum = np.bincount(safe_owner[owned], minlength=n_groups)
    # zero-mass groups fall back to an even split over their members
    frac = np.where(wsum[safe_owner] > 0,
                    np.divide(weights, wsum[safe_owner],
                              out=np.zeros_like(weights),
                              where=wsum[safe_owner] > 0),
                    np.divide(1.0, csum[safe_owner],
                              out=np.zeros_like(weights),
                              where=csum[safe_owner] > 0))
    return np.where(owned, measured[safe_owner] * frac, 0.0)


@dataclasses.dataclass
class CostRefiner:
    """Per-item running cost statistics fed by measured execution traces.

    `sizes`/`prior` are the work units and a-priori cost estimates the
    schedule under refinement was built from; `est` is the attribution
    estimate used to split coarse observations (it starts as the prior and
    is refreshed to the latest refined costs by `Schedule.refine`, so each
    round attributes with the best information available). Thread-safety:
    callers serialize observe calls (the facade's Schedule does).
    """

    sizes: np.ndarray            # (n,) int64 work units per item
    prior: np.ndarray            # (n,) float64 a-priori estimates
    est: np.ndarray              # (n,) float64 current attribution estimate
    stats: WelfordVec            # per-item running (count, mean, M2)
    blend: float = REFINE_BLEND
    rounds: int = 0              # completed observation rounds

    @classmethod
    def for_costs(cls, sizes: np.ndarray, costs: np.ndarray,
                  blend: float = REFINE_BLEND) -> "CostRefiner":
        sizes = np.asarray(sizes, np.int64)
        prior = np.asarray(costs, np.float64).copy()
        return cls(sizes=sizes, prior=prior, est=prior.copy(),
                   stats=WelfordVec.zeros(prior.size), blend=float(blend))

    @property
    def n_items(self) -> int:
        return int(self.prior.size)

    # ------------------------------------------------------------ folding
    def _fold(self, per_item: np.ndarray, covered: np.ndarray) -> None:
        """One Welford sample for every covered item, extrapolating items
        whose estimated mass was only partially covered this round."""
        self.stats.update(np.maximum(per_item, 0.0), covered)
        self.rounds += 1

    def _covered_sample(self, per_item: np.ndarray,
                        est_covered: np.ndarray) -> tuple[np.ndarray,
                                                          np.ndarray]:
        """Scale partially-covered items up by the observed fraction of
        their estimated mass; an item counts as covered when any of its
        estimate mass (or, for zero-estimate items, any of its work) was
        inside the observed chunks."""
        # bincount over an EMPTY observation returns int64 regardless of
        # its weights dtype; keep the arithmetic in float64 either way
        per_item = np.asarray(per_item, np.float64)
        est_covered = np.asarray(est_covered, np.float64)
        covered = est_covered > 0
        frac = np.divide(est_covered, self.est,
                         out=np.ones_like(est_covered),
                         where=self.est > 0)
        frac = np.clip(frac, 1e-12, 1.0)
        sample = np.divide(per_item, frac, out=per_item.copy(),
                           where=covered)
        return sample, covered

    # ------------------------------------------------------- entry points
    def observe_items(self, measured: np.ndarray,
                      mask: Optional[np.ndarray] = None) -> None:
        """Finest granularity: one measured cost per item (mask = items
        actually observed this round)."""
        measured = np.asarray(measured, np.float64)
        if measured.shape != (self.n_items,):
            raise ValueError(f"per-item observation must have shape "
                             f"({self.n_items},), got {measured.shape}")
        covered = (np.ones(self.n_items, bool) if mask is None
                   else np.asarray(mask, bool))
        self._fold(measured.copy(), covered)

    def observe_tiles(self, tiles: TileSchedule, measured: np.ndarray,
                      tile_mask: Optional[np.ndarray] = None) -> None:
        """Per-tile measured costs (what a replayed simulator run or the
        kernel cost stream reduce to): distributed to items through the
        tile's slot-cost decomposition under the current estimates."""
        measured = np.asarray(measured, np.float64)
        T, R = tiles.n_tiles, tiles.rows_per_tile
        if measured.shape != (T,):
            raise ValueError(f"per-tile observation must have shape ({T},),"
                             f" got {measured.shape}")
        slot_est = tiles.slot_cost(self.est, self.sizes).reshape(-1)
        seg = tiles.seg_len.reshape(-1).astype(np.float64)
        item = tiles.item_id.reshape(-1)
        tile_of_slot = np.repeat(np.arange(T, dtype=np.int64), R)
        owner = np.where(item >= 0, tile_of_slot, -1)
        # slots of unobserved tiles drop out of both the split and coverage
        if tile_mask is not None:
            keep = np.repeat(np.asarray(tile_mask, bool), R)
            owner = np.where(keep, owner, -1)
        # split by estimated slot cost; a tile whose estimate mass is zero
        # splits by work units instead, so zero-estimate items still
        # receive their share of that tile's measurement
        tile_mass = np.bincount(tile_of_slot, weights=slot_est, minlength=T)
        weights = np.where(tile_mass[tile_of_slot] > 0, slot_est, seg)
        slot_share = _proportional_split(measured, weights, owner, T)
        valid = owner >= 0
        per_item = np.bincount(item[valid], weights=slot_share[valid],
                               minlength=self.n_items)
        est_covered = np.bincount(item[valid], weights=slot_est[valid],
                                  minlength=self.n_items)
        # an all-zero-estimate item is covered if any of its units was seen
        unit_cov = np.bincount(item[valid], weights=seg[valid],
                               minlength=self.n_items)
        sample, covered = self._covered_sample(per_item, est_covered)
        covered |= (unit_cov > 0) & (self.est <= 0)
        self._fold(sample, covered)

    def observe_item_ranges(self, ranges, measured: np.ndarray) -> None:
        """Chunk records over ITEM index space (the threaded executor's
        `parallel_for` chunk_log): each chunk's measurement splits over the
        items it ran, proportional to current estimates."""
        ranges = np.asarray(ranges, np.int64).reshape(-1, 2)
        measured = np.asarray(measured, np.float64)
        owner = np.full(self.n_items, -1, np.int64)
        for c, (b, e) in enumerate(ranges):
            owner[b:e] = c
        per_item = _proportional_split(measured, self.est, owner,
                                       len(ranges))
        est_covered = np.where(owner >= 0, self.est, 0.0)
        sample, covered = self._covered_sample(per_item, est_covered)
        covered |= (owner >= 0) & (self.est <= 0)
        self._fold(sample, covered)

    def observe_unit_ranges(self, ranges, measured: np.ndarray) -> None:
        """Chunk records over flattened WORK-UNIT space (simulator replay /
        `parallel_for_units` logs): split each chunk over its units by the
        current per-unit estimate, then fold units into their items."""
        ranges = np.asarray(ranges, np.int64).reshape(-1, 2)
        measured = np.asarray(measured, np.float64)
        n_units = int(self.sizes.sum())
        unit_item = np.repeat(np.arange(self.n_items, dtype=np.int64),
                              self.sizes)
        unit_est = np.repeat(
            np.divide(self.est, self.sizes, out=np.zeros_like(self.est),
                      where=self.sizes > 0), self.sizes)
        owner = np.full(n_units, -1, np.int64)
        for c, (b, e) in enumerate(ranges):
            owner[b:e] = c
        per_unit = _proportional_split(measured, unit_est, owner,
                                       len(ranges))
        seen = owner >= 0
        per_item = np.bincount(unit_item[seen], weights=per_unit[seen],
                               minlength=self.n_items)
        est_covered = np.bincount(unit_item[seen], weights=unit_est[seen],
                                  minlength=self.n_items)
        unit_cov = np.bincount(unit_item[seen], minlength=self.n_items)
        sample, covered = self._covered_sample(per_item, est_covered)
        covered |= (unit_cov > 0) & (self.est <= 0)
        self._fold(sample, covered)

    def observe_worker_steps(self, tiles: TileSchedule,
                             shards: WorkerShards,
                             measured: np.ndarray) -> None:
        """The sharded kernels' cost output: measured[w, s] is what worker
        w's s-th superstep block cost. Block costs split over the block's
        tiles by estimated tile cost, then tiles fold into items."""
        measured = np.asarray(measured, np.float64)
        if measured.shape != shards.block_perm.shape:
            raise ValueError(
                f"worker-step observation must have shape "
                f"{shards.block_perm.shape} (p, S_B), got {measured.shape}")
        T = tiles.n_tiles
        B = shards.superstep
        tile_est = tiles.tile_cost(self.est, self.sizes)
        # tile -> block (only real blocks; padding steps have perm -1)
        block = np.arange(T) // B
        flat_blocks = shards.block_perm.reshape(-1)
        step_cost = measured.reshape(-1)
        n_blocks = -(-T // B)
        block_cost = np.zeros(n_blocks)
        real = flat_blocks >= 0
        block_cost[flat_blocks[real]] = step_cost[real]
        tile_share = _proportional_split(block_cost, tile_est, block,
                                         n_blocks)
        self.observe_tiles(tiles, tile_share)

    # ------------------------------------------------------------- output
    def refined_costs(self) -> np.ndarray:
        """Blend of running observed means and priors: an item observed at
        least once moves to `blend * mean + (1-blend) * prior`; an item
        never observed keeps its prior untouched."""
        seen = self.stats.count > 0
        out = self.prior.copy()
        out[seen] = (self.blend * self.stats.mean[seen]
                     + (1.0 - self.blend) * self.prior[seen])
        return np.maximum(out, 0.0)

    def successor(self, sizes: np.ndarray) -> "CostRefiner":
        """The refiner handed to the NEXT schedule generation: same running
        statistics (they keep compounding across refine() rounds — the
        WelfordVec is shared, not copied), same priors, fresh attribution
        estimate, sizes as the new generation derived them."""
        return dataclasses.replace(
            self, sizes=np.asarray(sizes, np.int64),
            est=self.refined_costs())

    def refresh_estimates(self) -> np.ndarray:
        """Move the attribution estimate to the current refined costs (the
        refine step calls this so the NEXT round's coarse observations
        split with the freshest information). Returns the refined array."""
        refined = self.refined_costs()
        self.est = refined.copy()
        return refined
