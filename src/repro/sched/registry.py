"""Workload/kernel registry: new applications plug in without a new ops class.

A workload is two functions (DESIGN.md §3):

* ``costs(*inputs) -> CostProvider`` — derive the per-item cost description
  from the workload's raw inputs (numpy-only; runs before any jax import);
* ``build(schedule, *inputs) -> op`` — given the constructed `Schedule`
  and the same raw inputs, return the callable kernel op (this side may
  import jax/Pallas).

Example — registering a custom workload:

    sched.register(
        "histogram",
        costs=lambda values, bins: sched.ExplicitCosts(counts_per_bin),
        build=lambda schedule, values, bins: MyHistogramOp(schedule, ...),
    )
    op = sched.default_scheduler().build("histogram", values, bins)

The three paper applications (``spmv``, ``bfs``, ``kmeans``) are registered
by `sched/kernels.py`, loaded lazily on first lookup so the numpy-only
facade surface never imports jax.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable

from .costs import CostProvider


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """A registered workload: name + cost derivation + kernel-op builder."""

    name: str
    costs: Callable[..., CostProvider]
    build: Callable[..., Any]
    doc: str = ""


_REGISTRY: dict[str, WorkloadSpec] = {}
_LOCK = threading.Lock()
_BUILTINS_LOADED = False
_BUILTINS_LOADING = False


def _load_builtins() -> None:
    # NOT guarded by _LOCK: the kernels module registers its entries at
    # import time, and register() takes _LOCK itself (non-reentrant) —
    # idempotence/races are handled by the import system's own module lock.
    # The _LOADING sentinel keeps the register() calls issued DURING the
    # kernels import from re-entering the import.
    global _BUILTINS_LOADED, _BUILTINS_LOADING
    if _BUILTINS_LOADED or _BUILTINS_LOADING:
        return
    _BUILTINS_LOADING = True
    try:
        from . import kernels  # noqa: F401  (registers spmv/bfs/kmeans)
        _BUILTINS_LOADED = True
    finally:
        _BUILTINS_LOADING = False


def register(name: str, *, costs: Callable[..., CostProvider],
             build: Callable[..., Any], doc: str = "",
             overwrite: bool = False) -> WorkloadSpec:
    """Register a workload under `name`; returns the spec.

    Re-registering an existing name raises unless `overwrite=True` — a
    silent replacement of e.g. "spmv" would change what every caller gets.
    """
    if not name or not isinstance(name, str):
        raise ValueError(f"workload name must be a non-empty string: {name!r}")
    # load built-ins first so an early user registration of "spmv"/"bfs"/
    # "kmeans" collides HERE (clear error at the offending call) instead of
    # blowing up the built-in import inside every later get()
    _load_builtins()
    spec = WorkloadSpec(name=name, costs=costs, build=build, doc=doc)
    with _LOCK:
        if name in _REGISTRY and not overwrite:
            raise ValueError(
                f"workload {name!r} is already registered; pass "
                "overwrite=True to replace it")
        _REGISTRY[name] = spec
    return spec


def get(name: str) -> WorkloadSpec:
    """Look up a registered workload (loads the built-ins on first use)."""
    _load_builtins()
    with _LOCK:
        spec = _REGISTRY.get(name)
    if spec is None:
        raise KeyError(
            f"unknown workload {name!r}; registered: {registered()}")
    return spec


def registered() -> tuple[str, ...]:
    """Names of all registered workloads, sorted."""
    _load_builtins()
    with _LOCK:
        return tuple(sorted(_REGISTRY))


_BUILTIN_NAMES = frozenset({"spmv", "bfs", "kmeans"})


def unregister(name: str) -> None:
    """Remove a workload (primarily for tests tearing down custom entries).

    Built-in names are refused: the kernels module only registers them on
    its first import, so removal would be irreversible for the process.
    Replace a built-in with ``register(..., overwrite=True)`` instead.
    """
    if name in _BUILTIN_NAMES:
        raise ValueError(f"cannot unregister built-in workload {name!r}; "
                         "use register(..., overwrite=True) to replace it")
    with _LOCK:
        _REGISTRY.pop(name, None)
