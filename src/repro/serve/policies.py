"""Dispatch policies for the continuous batcher, compared queue_flex-style.

*A Comparative Study of OpenMP Scheduling Algorithm Selection Strategies*
(PAPERS.md) argues the gap to the best scheduler is closed by comparing
policies per workload; here the "workload" is an offered-load level and the
policies decide, each engine step, (a) WHICH pending prefill advances and
(b) by HOW MANY tokens — while every running decode stream gets one token.
The common `DispatchPolicy` protocol lets benchmarks/bench_serve.py sweep
them against the same seeded arrival trace (the EREW/CREW comparison shape
of the queue_flex exemplar):

* ``fcfs-static`` — requests prefill one at a time in arrival order with a
  FIXED chunk: the head-of-line baseline (a long prompt monopolizes the
  prefill slot, and the chunk never adapts to the machine).
* ``round-robin`` — the fixed chunk rotates across all requests needing
  prefill: fair, but finishes nobody early, so TTFT of EVERY request drifts
  toward the worst case under load.
* ``ich-adaptive`` — the paper's scheduler applied to serving: per-request
  cost = remaining prompt tokens through the `sched` facade
  (`RemainingTokensCosts` + the ``serve-prefill`` registry entry), refined
  across steps from measured step wall-clock via `Schedule.observe/refine`;
  the next prefill target is the cheapest refined stream (finish the
  near-done request first — the stealing intuition: never let a nearly
  empty queue idle behind a heavy one), and the chunk size is the
  per-request iCh divisor ``d`` adapted against the measured throughput
  band exactly like `Engine._adapt` (paper eqs. 1-8).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Protocol, runtime_checkable

import numpy as np

from ..core import welford as W
from ..sched.defaults import ICH_EPS
from .queue import AdmissionQueue, RequestState


@dataclasses.dataclass
class StepPlan:
    """What one engine step will execute: every decoding request advances
    one token; at most one prefill stream advances `prefill_chunk`."""

    decode: list            # list[RequestState]
    prefill: Optional[RequestState] = None
    prefill_chunk: int = 0

    @property
    def n_decode(self) -> int:
        return len(self.decode)

    @property
    def work_tokens(self) -> int:
        return self.n_decode + self.prefill_chunk


@runtime_checkable
class DispatchPolicy(Protocol):
    """The protocol bench_serve sweeps. `choose` must be a pure function of
    queue state (same queue -> same plan: determinism is asserted);
    `observe` feeds the measured step wall-clock back for adaptation."""

    name: str

    def choose(self, queue: AdmissionQueue, now: float = 0.0) -> StepPlan: ...

    def observe(self, plan: StepPlan, dt: float) -> None: ...


def _clamp_chunk(chunk: int, remaining: int, min_chunk: int) -> int:
    return min(max(chunk, min_chunk), remaining)


class FCFSStatic:
    """First-come-first-served prefill with a fixed chunk size."""

    def __init__(self, chunk: int = 64, min_chunk: int = 8):
        self.name = "fcfs-static"
        self.chunk = int(chunk)
        self.min_chunk = int(min_chunk)

    def choose(self, queue: AdmissionQueue, now: float = 0.0) -> StepPlan:
        plan = StepPlan(decode=queue.decoding())
        pre = queue.prefilling()
        if pre:
            st = min(pre, key=lambda s: s.request.req_id)  # arrival order
            plan.prefill = st
            plan.prefill_chunk = _clamp_chunk(
                self.chunk, st.remaining_prefill, self.min_chunk)
        return plan

    def observe(self, plan: StepPlan, dt: float) -> None:
        pass  # static: nothing adapts


class RoundRobin:
    """Fixed chunk, rotating fairly across prefill-needing requests."""

    def __init__(self, chunk: int = 64, min_chunk: int = 8):
        self.name = "round-robin"
        self.chunk = int(chunk)
        self.min_chunk = int(min_chunk)
        self._next = 0

    def choose(self, queue: AdmissionQueue, now: float = 0.0) -> StepPlan:
        plan = StepPlan(decode=queue.decoding())
        pre = sorted(queue.prefilling(), key=lambda s: s.request.req_id)
        if pre:
            st = pre[self._next % len(pre)]
            self._next += 1
            plan.prefill = st
            plan.prefill_chunk = _clamp_chunk(
                self.chunk, st.remaining_prefill, self.min_chunk)
        return plan

    def observe(self, plan: StepPlan, dt: float) -> None:
        pass


class IChAdaptive:
    """iCh-scheduled dispatch through the `sched` facade.

    Target selection: a `Schedule` is constructed over the current
    prefill backlog's remaining-token counts (the ``serve-prefill``
    registry entry / `RemainingTokensCosts`), its per-item cost estimates
    are refined from measured step wall-clock (`Schedule.observe/refine`
    — each step's seconds are attributed to the items it advanced), and
    the next target is the stream with the LEAST refined remaining cost
    (shortest-refined-work-first: drain nearly-done prompts so their
    decode streams start, instead of queueing them behind a monster
    prompt).

    Chunk sizing: the per-request divisor ``d`` (paper §3.2) lives on
    `RequestState`; each observed chunk's token throughput is classified
    against the running band mu +- eps*mu and d halves (slow: grow the
    chunk, amortize dispatch) or doubles (fast: shrink it, leave room for
    interleaved decode).
    """

    def __init__(self, *, eps: float = ICH_EPS, min_chunk: int = 32,
                 d_min: float = 1.0, d_max: float = 64.0, aging: float = 1.0,
                 scheduler=None, refine_every: int = 4):
        self.name = "ich-adaptive"
        self.eps = float(eps)
        self.min_chunk = int(min_chunk)
        self.d_min, self.d_max = float(d_min), float(d_max)
        # SRPT-with-aging: each second a stream waits discounts one
        # `aging`-weighted second of its estimated remaining work, so a
        # monster prompt is deferred, never starved (pure SRPT would hold
        # it to the very end and its e2e would swallow the whole makespan)
        self.aging = float(aging)
        self._scheduler = scheduler  # LoopScheduler (lazy default)
        self.refine_every = int(refine_every)
        self._schedule = None        # current serve-prefill Schedule
        self._sched_ids: list = []   # req ids, aligned with schedule items
        self._observed = 0
        self._last_plan_items: list = []
        # running seconds-per-token baseline: measured chunk slowness is
        # fed to the refiner RELATIVE to this, keeping the measurement on
        # the same token-count scale as the provider's prior costs
        self._spt_sum = 0.0
        self._spt_tokens = 0

    # ---------------------------------------------------- facade plumbing
    @property
    def scheduler(self):
        if self._scheduler is None:
            from repro import sched
            # one-shot cost arrays every step: construction is cheap at
            # per-queue sizes and caching them would only evict real
            # workloads, so this facade instance runs cache-off
            self._scheduler = sched.LoopScheduler(p=1, cache_size=0)
        return self._scheduler

    def _refresh_schedule(self, pre: list) -> None:
        """(Re)build the serve-prefill schedule over the current backlog,
        carrying forward refined per-request cost estimates."""
        ids = [st.request.req_id for st in pre]
        remaining = np.array([st.remaining_prefill for st in pre], np.int64)
        sch = self.scheduler.build("serve-prefill", remaining)
        # transplant refined per-token cost for requests surviving from the
        # previous backlog: slowness learned there still applies. The carry
        # goes into BOTH prior and est — `refined_costs` falls back to the
        # prior for never-observed items, so est alone would be wiped by
        # the first refresh.
        if self._schedule is not None and self._sched_ids:
            prev = {rid: float(c) / max(float(s), 1.0)
                    for rid, c, s in zip(self._sched_ids,
                                         self._schedule.refiner
                                             .refresh_estimates(),
                                         self._schedule.sizes)}
            per_tok = np.array([prev.get(rid, 1.0) for rid in ids])
            carried = np.maximum(remaining, 1) * per_tok
            r = sch.refiner
            r.prior[:] = carried
            r.est[:] = carried
        self._schedule = sch
        self._sched_ids = ids

    # ------------------------------------------------------------- choose
    def choose(self, queue: AdmissionQueue, now: float = 0.0) -> StepPlan:
        plan = StepPlan(decode=queue.decoding())
        pre = sorted(queue.prefilling(), key=lambda s: s.request.req_id)
        self._last_plan_items = []
        if not pre:
            return plan
        ids = [st.request.req_id for st in pre]
        if ids != self._sched_ids or self._schedule is None:
            self._refresh_schedule(pre)
        est = self._schedule.refiner.refresh_estimates()
        # shortest-refined-work-first with aging: refined token estimates
        # convert to seconds at the running seconds-per-token baseline,
        # minus the time the stream has already waited; req_id breaks
        # ties -> deterministic
        spt = (self._spt_sum / self._spt_tokens if self._spt_tokens
               else 1e-4)
        order = sorted(
            range(len(pre)),
            key=lambda i: (est[i] * spt
                           - self.aging * (now - pre[i].t_admit), ids[i]))
        st = pre[order[0]]
        chunk = int(np.ceil(st.remaining_prefill / st.d))
        chunk = _clamp_chunk(chunk, st.remaining_prefill, self.min_chunk)
        if st.remaining_prefill - chunk < self.min_chunk:
            # fold the tail: a sub-min_chunk remainder would cost a whole
            # extra step of fixed overhead for a sliver of work
            chunk = st.remaining_prefill
        plan.prefill = st
        plan.prefill_chunk = chunk
        self._last_plan_items = [order[0]]
        return plan

    # ------------------------------------------------------------ observe
    def observe(self, plan: StepPlan, dt: float) -> None:
        if plan.prefill is None:
            return
        st, chunk = plan.prefill, plan.prefill_chunk
        # (a) per-request iCh band: classify measured chunk throughput and
        #     adapt the divisor exactly like Engine._adapt
        thr = chunk / max(dt, 1e-9)
        st.ks.append(thr)
        mu, delta = W.ich_band(np.asarray(st.ks[-16:]), self.eps)
        st.d = W.adapt_d(st.d, W.classify(thr, mu, delta),
                         d_min=self.d_min, d_max=self.d_max)
        # (b) facade feedback: attribute this step's wall seconds to the
        #     advanced item's unit range. The sample is expressed on the
        #     provider's token-count scale as covered_tokens * relative
        #     slowness (chunk seconds-per-token over the running global
        #     baseline) — normalizing a single chunk to its OWN estimate
        #     mass would make the sample equal the estimate and learn
        #     nothing.
        if self._schedule is None or not self._last_plan_items:
            return
        self._spt_sum += max(dt, 0.0)
        self._spt_tokens += chunk
        i = self._last_plan_items[0]
        sizes = self._schedule.sizes
        begin = int(sizes[:i].sum())
        covered = min(chunk, int(sizes[i]))
        if covered <= 0 or self._spt_sum <= 0:
            return
        mean_spt = self._spt_sum / max(self._spt_tokens, 1)
        rel = (max(dt, 1e-9) / max(chunk, 1)) / mean_spt
        self._schedule.refiner.observe_unit_ranges(
            [(begin, begin + covered)], np.array([covered * rel]))
        self._observed += 1
        if self._observed % self.refine_every == 0:
            try:
                self._schedule = self._schedule.refine()
            except Exception:
                self._schedule = None  # rebuild lazily on next choose()


def default_policies(chunk: int = 64) -> list:
    """The bench's standard comparison set (>= 3 policies)."""
    return [FCFSStatic(chunk=chunk), RoundRobin(chunk=chunk), IChAdaptive()]
