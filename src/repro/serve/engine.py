"""Batched serving engine with iCh-adaptive chunked prefill.

Serving is the one place in the framework where the paper's *runtime*
feedback loop survives intact: dispatch is host-driven, so real step
latencies are observable. Prefill is processed in CHUNKS (Sarathi-style) so
decode batches are not head-of-line blocked by long prompts; the chunk size
is the iCh chunk: after each chunk the engine classifies its measured token
throughput against the running mean band (mu +- eps*mu, paper eqs. 1-8) and
adapts the divisor d exactly like adapt_d — slow chunks (cache pressure,
long context) grow the chunk to amortize dispatch, fast chunks shrink it to
leave room for interleaved decode ("stealable" slots).

Chunked prefill is INCREMENTAL for stacked-segment families (dense / vlm /
moe): each chunk feeds only its own tokens through `models.prefill_extend`
against the growing KV cache — O(chunk * context) per chunk instead of
re-running the whole prefix — and stays bit-identical to a one-shot
prefill because the cache is sized to the exact prompt length (see
`empty_extend_cache`). The ssm family is incremental too — O(chunk) per
chunk through its O(1) recurrent block states — with chunk boundaries
quantized to the one-shot scan-block length Q = min(cfg.ssm_chunk, S) so
every chunk replays exactly the scan steps a one-shot prefill would run
(bit-identity, `_ssm_q`). Families whose state still doesn't extend
(encdec / hybrid) fall back to re-running the prefix — QUADRATIC in the
prompt, so every fallback chunk is counted loudly in
`Engine.n_prefill_fallbacks` and surfaces as
`ServeMetrics.n_prefill_fallback`.

Two usage surfaces:

* `generate(prompts, ...)` — the single-request path with the engine-level
  iCh band (`self.d` / `self.ks`) and the PR 7 deadline contract;
* `start_request` / `prefill_chunk_step` / `decode_one` — the per-request
  primitives the continuous batcher (serve/batcher.py) drives, operating
  on `RequestState` so each request carries its OWN iCh band and cache
  (two interleaved requests can no longer pollute each other's divisor).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import welford as W
from ..sched.defaults import ICH_EPS
from ..models import model as M
from .queue import RequestState


@dataclasses.dataclass
class EngineConfig:
    max_seq: int = 512
    eps: float = ICH_EPS       # iCh band (unified default)
    init_divisor: float = 4.0  # d_0: first chunk = prompt_len / d_0
    min_chunk: int = 16


class Engine:
    def __init__(self, cfg, params, ecfg: Optional[EngineConfig] = None):
        # default constructed per instance: a shared EngineConfig default
        # would alias mutable config across engines
        self.cfg, self.params = cfg, params
        self.ecfg = ecfg if ecfg is not None else EngineConfig()
        caps = jnp.ones((M.n_moe_layers(cfg), max(cfg.n_experts, 1))) \
            if cfg.moe else None
        self._prefill = jax.jit(
            lambda p, b: M.prefill(cfg, p, b, caps, dtype=jnp.float32))
        self._decode = jax.jit(
            lambda p, t, c, pos: M.decode_step(cfg, p, t, c, pos, caps,
                                               dtype=jnp.float32))
        if M.extend_cache_specs_ok(cfg):
            # q (the ssm scan-block length) is static: it shapes the
            # chunked scan; None for attention families
            self._extend = jax.jit(
                lambda p, t, c, done, q=None: M.prefill_extend(
                    cfg, p, t, c, done, caps, dtype=jnp.float32,
                    ssm_chunk=q),
                static_argnums=(4,))
        else:
            self._extend = None
        # every prefix-rerun fallback chunk (encdec/hybrid) is counted:
        # the O(n^2) path must be visible, never silent
        self.n_prefill_fallbacks = 0
        # iCh state: divisor d + completed-token counters per "worker"
        # (here: per prefill stream) — the single-request surface; the
        # batcher path keeps this state per request on RequestState
        self.d = self.ecfg.init_divisor
        self.ks: list[float] = []

    # ---------------- iCh chunked prefill ----------------
    def _ssm_q(self, prompt_len: int):
        """Scan-block quantum for ssm prompts, else None. The one-shot
        prefill scans in Q = min(cfg.ssm_chunk, S) blocks; incremental
        chunk boundaries must land on multiples of Q to replay the same
        scan steps (bit-identity — see `models.prefill_extend`)."""
        if self.cfg.family != "ssm":
            return None
        return min(getattr(self.cfg, "ssm_chunk", 256), int(prompt_len))

    def _next_chunk(self, remaining: int, q: int = None) -> int:
        c = max(self.ecfg.min_chunk, int(np.ceil(remaining / self.d)))
        if q:
            c = -(-c // q) * q  # round up to the ssm scan-block quantum
        return min(c, remaining)

    def _adapt(self, tokens_done: int, dt: float):
        thr = tokens_done / max(dt, 1e-6)
        self.ks.append(thr)
        mu, delta = W.ich_band(np.asarray(self.ks[-16:]), self.ecfg.eps)
        cls = W.classify(thr, mu, delta)
        self.d = W.adapt_d(self.d, cls, d_min=1.0, d_max=64.0)

    def prefill_chunked(self, tokens: np.ndarray):
        """tokens (B, S_prompt). Returns (last logits, cache, chunk log)."""
        B, S = tokens.shape
        log = []
        done = 0
        logits = None
        incremental = self._extend is not None
        q = self._ssm_q(S) if incremental else None
        cache = (M.empty_extend_cache(self.cfg, B, S, dtype=jnp.float32)
                 if incremental else None)
        while done < S:
            c = self._next_chunk(S - done, q)
            t0 = time.perf_counter()
            if incremental:
                # feed ONLY the chunk to the growing cache: O(chunk) work
                logits, cache = self._extend(
                    self.params, jnp.asarray(tokens[:, done: done + c]),
                    cache, done, q)
            else:
                # encoder/hybrid families: re-run the prefix — O(n^2),
                # counted so the fallback can never hide in the logs
                self.n_prefill_fallbacks += 1
                chunk = jnp.asarray(tokens[:, : done + c])
                logits, cache = self._prefill(self.params, {"tokens": chunk})
            dt = time.perf_counter() - t0
            self._adapt(c * B, dt)
            log.append({"chunk": c, "dt": dt, "d": self.d})
            done += c
        return logits, cache, log

    # ---------------- per-request primitives (batcher surface) ----------------
    def start_request(self, st: RequestState) -> None:
        """Allocate the request's incremental prefill cache (cache sized to
        the exact prompt, the bit-identity requirement)."""
        if self._extend is None:
            raise NotImplementedError(
                f"continuous batching needs prefill_extend; family "
                f"{self.cfg.family!r} caches don't extend incrementally")
        st.cache = M.empty_extend_cache(self.cfg, 1, st.prompt_len,
                                        dtype=jnp.float32)

    def prefill_chunk_step(self, st: RequestState, chunk: int) -> None:
        """Advance one request's prefill by `chunk` tokens. Mechanical: the
        caller (batcher + policy) owns timing, chunk logs, and divisor
        adaptation. On completion, pads the cache to max_seq and emits the
        request's first token (the prefill argmax)."""
        if st.cache is None:
            self.start_request(st)
        done = st.prefill_done
        chunk = min(chunk, st.remaining_prefill)
        if chunk <= 0:
            return
        q = self._ssm_q(st.prompt_len)
        if q:
            # ssm scan-block alignment (`_ssm_q`): round the policy's
            # chunk up to a multiple of Q, capped at the prompt end
            chunk = min(-(-chunk // q) * q, st.remaining_prefill)
        toks = jnp.asarray(st.request.tokens[:, done: done + chunk])
        logits, st.cache = self._extend(self.params, toks, st.cache, done, q)
        st.prefill_done = done + chunk
        st.last_logits = logits
        if st.remaining_prefill == 0:
            st.cache = self._pad_cache(st.cache, st.prompt_len)
            st.out_tokens.append(
                int(jnp.argmax(logits[0], -1)))

    def decode_one(self, st: RequestState) -> None:
        """One greedy decode token for a stream that finished prefill."""
        if not st.out_tokens:
            raise ValueError("decode_one before prefill produced a token")
        pos = st.prompt_len + len(st.out_tokens) - 1
        tok = jnp.asarray([[st.out_tokens[-1]]], jnp.int32)
        logits, st.cache = self._decode(self.params, tok, st.cache, pos)
        st.out_tokens.append(int(jnp.argmax(logits[0], -1)))
        st.last_logits = logits

    # ---------------- decode ----------------
    def generate(self, prompts: np.ndarray, n_new: int = 16,
                 greedy: bool = True,
                 deadline_s: Optional[float] = None):
        """prompts (B, S). Returns (B, n_done) generated ids + stats.

        `deadline_s` is the per-request latency budget, measured from entry
        (so chunked prefill spends from the same budget). When the clock
        runs out mid-decode the engine degrades gracefully instead of
        blowing the SLO: remaining decode steps are shed and the partial
        output is returned with `stats["degraded"] = True` and the shed
        count in `stats["n_shed"]` (DESIGN.md §2.9). At least one token —
        the prefill argmax — is always produced; without a deadline
        `n_done == n_new` and the stats contract is unchanged apart from
        the constant `degraded=False` / `n_shed=0` fields."""
        t_start = time.perf_counter()
        B, S = prompts.shape
        logits, cache, chunk_log = self.prefill_chunked(prompts)
        cache = self._pad_cache(cache, S)
        out = []
        degraded = False
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        for i in range(n_new):
            out.append(np.asarray(tok)[:, 0])
            if (deadline_s is not None and i + 1 < n_new
                    and time.perf_counter() - t_start > deadline_s):
                degraded = True
                break
            logits, cache = self._decode(self.params, tok, cache, S + i)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        stats = {"chunks": chunk_log, "d_final": self.d,
                 "degraded": degraded, "n_shed": n_new - len(out),
                 "deadline_s": deadline_s}
        return np.stack(out, 1), stats

    def _pad_cache(self, cache, s_now: int):
        """Grow prefill caches to max_seq for in-place decode updates."""
        target = self.ecfg.max_seq
        cfg = self.cfg

        def pad_kv(t, axis):
            pad = target - t.shape[axis]
            if pad <= 0:
                return t
            widths = [(0, 0)] * t.ndim
            widths[axis] = (0, pad)
            return jnp.pad(t, widths)

        if cfg.family in ("hybrid", "ssm"):
            out = []
            for kind, st in zip(cfg.block_pattern, cache):
                if kind == "A":
                    w = min(target, cfg.attn_window) if cfg.attn_window else target
                    out.append({k: pad_kv(v, 1)[:, :w] for k, v in st.items()})
                else:
                    out.append(st)
            return out
        if cfg.family == "encdec":
            return {"self": [{k: pad_kv(v, 2) for k, v in cache["self"][0].items()}],
                    "cross": cache["cross"]}
        return [{k: pad_kv(v, 2) for k, v in seg.items()} for seg in cache]
