"""Dependency-free log-bucketed latency histograms + serving counters.

The tail-latency reporting layer of the serving engine (DESIGN.md §2.10),
modeled on HdrHistogram: values are recorded into geometrically-spaced
buckets, so percentile queries (p50/p90/p99/p999) cost O(buckets) memory
regardless of how many samples stream through an offered-load sweep, and
every quantile answer is within one bucket's relative resolution of the
exact order statistic (asserted against a numpy-sort oracle in
tests/test_serve_batch.py).

Pure Python on purpose — no numpy, no jax — so the metrics layer imports
anywhere (the load generator, the CI smoke, a log post-processor) without
paying for the numeric stack.
"""
from __future__ import annotations

import math
from typing import Iterable, Optional


class LatencyHistogram:
    """Log-bucketed histogram over positive values.

    `resolution` is the relative bucket width (0.05 = 5%): any percentile
    query is within a factor of (1 + resolution) of the exact sample
    quantile. Values below `min_value` clamp into the first bucket; values
    above `max_value` clamp into the last (min/max are still tracked
    exactly, and p0/p100 report them exactly).
    """

    __slots__ = ("min_value", "max_value", "resolution", "_log_g",
                 "_n_buckets", "_counts", "count", "total",
                 "_min_seen", "_max_seen")

    def __init__(self, min_value: float = 1e-6, max_value: float = 1e5,
                 resolution: float = 0.05):
        if not (0 < min_value < max_value):
            raise ValueError(
                f"need 0 < min_value < max_value, got {min_value}, {max_value}")
        if not (0 < resolution < 1):
            raise ValueError(f"resolution must be in (0, 1), got {resolution}")
        self.min_value = float(min_value)
        self.max_value = float(max_value)
        self.resolution = float(resolution)
        self._log_g = math.log1p(resolution)
        self._n_buckets = 1 + int(
            math.log(max_value / min_value) / self._log_g)
        self._counts = [0] * self._n_buckets
        self.count = 0
        self.total = 0.0
        self._min_seen: Optional[float] = None
        self._max_seen: Optional[float] = None

    # -------------------------------------------------------------- record
    def _bucket(self, v: float) -> int:
        if v <= self.min_value:
            return 0
        i = int(math.log(v / self.min_value) / self._log_g)
        return min(i, self._n_buckets - 1)

    def record(self, v: float) -> None:
        v = float(v)
        if not math.isfinite(v) or v < 0:
            raise ValueError(f"latency samples must be finite and >= 0: {v}")
        self._counts[self._bucket(v)] += 1
        self.count += 1
        self.total += v
        if self._min_seen is None or v < self._min_seen:
            self._min_seen = v
        if self._max_seen is None or v > self._max_seen:
            self._max_seen = v

    def record_many(self, vs: Iterable[float]) -> None:
        for v in vs:
            self.record(v)

    # -------------------------------------------------------------- queries
    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """The q-th percentile (q in [0, 100]); 0.0 when empty.

        Quantile convention matches `numpy.percentile(..., method="lower"
        )`-style order statistics: the value at rank ceil(q/100 * count),
        reported as the geometric midpoint of its bucket (within one
        bucket's resolution of exact)."""
        if not (0 <= q <= 100):
            raise ValueError(f"percentile must be in [0, 100], got {q}")
        if self.count == 0:
            return 0.0
        if q == 0:
            return self._min_seen
        if q == 100:
            return self._max_seen
        rank = max(1, math.ceil(q / 100.0 * self.count))
        seen = 0
        for i, c in enumerate(self._counts):
            seen += c
            if seen >= rank:
                lo = self.min_value * math.exp(i * self._log_g)
                hi = lo * (1.0 + self.resolution)
                # clamp into the exactly-tracked range so a one-sample
                # histogram answers that sample, not its bucket midpoint
                mid = math.sqrt(lo * hi)
                return min(max(mid, self._min_seen), self._max_seen)
        return self._max_seen  # pragma: no cover - rank <= count

    def percentiles(self, qs=(50, 90, 99, 99.9)) -> dict:
        def label(q):
            s = f"{float(q):g}"  # 50 -> "50", 99.9 -> "99.9"
            return f"p{s.replace('.', '')}" if "." in s else f"p{s}"
        return {label(q): self.percentile(q) for q in qs}

    def merge(self, other: "LatencyHistogram") -> "LatencyHistogram":
        """Fold `other` into self (bucket layouts must match)."""
        if (other.min_value, other.max_value, other.resolution) != \
                (self.min_value, self.max_value, self.resolution):
            raise ValueError("cannot merge histograms with different "
                             "bucket layouts")
        for i, c in enumerate(other._counts):
            self._counts[i] += c
        self.count += other.count
        self.total += other.total
        for v in (other._min_seen, other._max_seen):
            if v is not None:
                if self._min_seen is None or v < self._min_seen:
                    self._min_seen = v
                if self._max_seen is None or v > self._max_seen:
                    self._max_seen = v
        return self

    def summary(self) -> dict:
        s = {"count": self.count, "mean": self.mean}
        s.update(self.percentiles((50, 90, 99, 99.9)))
        return s

    # ------------------------------------------------- snapshot (DESIGN §2.11)
    def state_dict(self) -> dict:
        """JSON-serializable full state; `from_state` restores a histogram
        that answers every query identically (crash-resume snapshots)."""
        return {"min_value": self.min_value, "max_value": self.max_value,
                "resolution": self.resolution, "counts": list(self._counts),
                "count": self.count, "total": self.total,
                "min_seen": self._min_seen, "max_seen": self._max_seen}

    @classmethod
    def from_state(cls, d: dict) -> "LatencyHistogram":
        h = cls(min_value=d["min_value"], max_value=d["max_value"],
                resolution=d["resolution"])
        counts = list(d["counts"])
        if len(counts) != h._n_buckets:
            raise ValueError(f"state has {len(counts)} buckets, layout "
                             f"needs {h._n_buckets}")
        h._counts = counts
        h.count = int(d["count"])
        h.total = float(d["total"])
        h._min_seen = d["min_seen"]
        h._max_seen = d["max_seen"]
        return h

    def __repr__(self):
        if self.count == 0:
            return "LatencyHistogram(empty)"
        p = self.percentiles((50, 99))
        return (f"LatencyHistogram(n={self.count}, mean={self.mean:.4g}, "
                f"p50={p['p50']:.4g}, p99={p['p99']:.4g})")


class ServeMetrics:
    """One serving run's latency histograms + goodput/shed counters.

    Three latency dimensions per request (all in clock seconds):

    * **TTFT** — arrival to first token (the prefill argmax), the
      queueing + chunked-prefill tail;
    * **per-token** — gap between consecutive decode tokens (how much a
      decode stream stutters when steps carry other requests' prefill
      chunks);
    * **e2e** — arrival to completion, COMPLETED requests only (degraded
      completions are counted separately so shedding cannot flatter the
      tail).
    """

    def __init__(self, resolution: float = 0.02):
        self.ttft = LatencyHistogram(resolution=resolution)
        self.per_token = LatencyHistogram(resolution=resolution)
        self.e2e = LatencyHistogram(resolution=resolution)
        self.n_arrived = 0
        self.n_admitted = 0
        self.n_shed_admission = 0     # rejected at the bounded queue
        self.n_completed = 0          # full n_new tokens delivered
        self.n_degraded = 0           # deadline hit: partial output returned
        self.n_tokens_out = 0         # goodput numerator
        self.n_tokens_shed = 0        # decode steps shed by degradation
        self.n_prefill_fallback = 0   # O(n^2) prefix-rerun prefill chunks
        self.t_elapsed = 0.0          # serving-clock seconds (set by run())
        # ---- hardened backend boundary (DESIGN.md §2.11) ----
        self.n_backend_faults = 0     # terminal per-op FaultErrors absorbed
        self.n_backend_retries = 0    # per-op retry attempts spent
        self.n_breaker_trips = 0      # circuit breaker closed->open events

    def goodput(self, elapsed_s: Optional[float] = None) -> float:
        """Delivered tokens per second of serving-clock time."""
        if elapsed_s is None:
            elapsed_s = self.t_elapsed
        return self.n_tokens_out / elapsed_s if elapsed_s > 0 else 0.0

    def summary(self, elapsed_s: Optional[float] = None) -> dict:
        if elapsed_s is None:
            elapsed_s = self.t_elapsed
        return {
            "ttft": self.ttft.summary(),
            "per_token": self.per_token.summary(),
            "e2e": self.e2e.summary(),
            "n_arrived": self.n_arrived,
            "n_admitted": self.n_admitted,
            "n_shed_admission": self.n_shed_admission,
            "n_completed": self.n_completed,
            "n_degraded": self.n_degraded,
            "n_tokens_out": self.n_tokens_out,
            "n_tokens_shed": self.n_tokens_shed,
            "n_prefill_fallback": self.n_prefill_fallback,
            "n_backend_faults": self.n_backend_faults,
            "n_backend_retries": self.n_backend_retries,
            "n_breaker_trips": self.n_breaker_trips,
            "elapsed_s": elapsed_s,
            "goodput_tok_s": self.goodput(elapsed_s),
        }

    # ------------------------------------------------- snapshot (DESIGN §2.11)
    _COUNTERS = ("n_arrived", "n_admitted", "n_shed_admission",
                 "n_completed", "n_degraded", "n_tokens_out",
                 "n_tokens_shed", "n_prefill_fallback", "t_elapsed",
                 "n_backend_faults", "n_backend_retries",
                 "n_breaker_trips")

    def state_dict(self) -> dict:
        d = {"ttft": self.ttft.state_dict(),
             "per_token": self.per_token.state_dict(),
             "e2e": self.e2e.state_dict()}
        for k in self._COUNTERS:
            d[k] = getattr(self, k)
        return d

    @classmethod
    def from_state(cls, d: dict) -> "ServeMetrics":
        m = cls()
        m.ttft = LatencyHistogram.from_state(d["ttft"])
        m.per_token = LatencyHistogram.from_state(d["per_token"])
        m.e2e = LatencyHistogram.from_state(d["e2e"])
        for k in cls._COUNTERS:
            setattr(m, k, d[k])
        return m
