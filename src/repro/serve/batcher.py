"""The continuous batcher: interleaved decode + chunked prefill per step.

One engine step (Sarathi-style continuous batching) assembles

    [ one decode token for EVERY running decode stream ]
  + [ one prefill chunk for ONE policy-chosen stream  ]

so decode latency stays bounded while prefills make progress. WHICH
stream prefills and HOW LARGE the chunk is are the dispatch policy's
calls (serve/policies.py); the `ich-adaptive` policy routes them through
the `sched` facade with per-request cost = remaining prompt tokens,
refined each step from the measured step wall-clock.

Two execution backends behind one `step_plan` contract:

* `SimBackend` — no model, a seeded `StepCostModel` prices each step
  (fixed dispatch overhead + per-decode-token + context-dependent
  per-prefill-token + lognormal jitter) and a `SimClock` advances by it.
  Bit-deterministic: CI and benchmarks/bench_serve.py sweep offered load
  on this backend with zero machine noise.
* `EngineBackend` — the real `serve.engine.Engine` under a `WallClock`;
  each request owns its KV cache and the step executes per-request
  (B=1), so interleaving is bit-identical to serial execution
  (tests/test_serve_batch.py).

Faults: a PR 7 `FaultPlan`'s stalls apply to the batcher loop as worker
0 — a pending stall at a step boundary adds its duration to that step's
clock, and deadline handling must DEGRADE the affected requests (shed
remaining decode, keep the prefix) rather than blow their SLOs silently
(tests/test_serve_slo_chaos.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import numpy as np

from ..robust.faults import FaultClock, FaultPlan
from .loadgen import Arrival, OpenPoissonLoadGen
from .metrics import ServeMetrics
from .policies import DispatchPolicy, StepPlan
from .queue import AdmissionQueue, Request, RequestState


# --------------------------------------------------------------------- clocks
class WallClock:
    """Real time (monotonic)."""

    def now(self) -> float:
        return time.monotonic()

    def advance(self, dt: float) -> None:  # wall time advances itself
        pass


class SimClock:
    """Simulated serving clock: starts at 0, advances only when told."""

    def __init__(self, t0: float = 0.0):
        self._t = float(t0)

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> None:
        if dt < 0:
            raise ValueError(f"clock cannot run backwards (dt={dt})")
        self._t += float(dt)


# ----------------------------------------------------------------- cost model
@dataclasses.dataclass(frozen=True)
class StepCostModel:
    """Prices one batched engine step for the simulated backend.

    seconds = overhead
            + n_decode * decode_token_s
            + chunk * prefill_token_s * (1 + ctx / ctx_scale)
            + lognormal jitter (seeded per step)

    The context term makes LATE chunks of a long prompt cost more per
    token than early ones (attention over the growing KV prefix) — the
    nonuniformity the iCh divisor and the cost refiner exist to track.
    """

    overhead_s: float = 2e-3
    decode_token_s: float = 2e-4
    prefill_token_s: float = 5e-5
    ctx_scale: float = 512.0
    jitter_sigma: float = 0.10
    seed: int = 0

    def step_seconds(self, plan: StepPlan, step_idx: int) -> float:
        cost = self.overhead_s + plan.n_decode * self.decode_token_s
        if plan.prefill is not None and plan.prefill_chunk > 0:
            ctx = plan.prefill.prefill_done
            cost += (plan.prefill_chunk * self.prefill_token_s
                     * (1.0 + ctx / self.ctx_scale))
        if self.jitter_sigma > 0:
            rng = np.random.default_rng((self.seed << 24) + step_idx)
            cost *= float(rng.lognormal(0.0, self.jitter_sigma))
        return cost


# ------------------------------------------------------------------- backends
class SimBackend:
    """Advance request state logically; a `StepCostModel` prices the step.

    Generated token ids are a deterministic function of (req_id, position)
    so interleaving order can never change outputs — the simulated twin of
    the real backend's bit-identity property."""

    def __init__(self, cost_model: Optional[StepCostModel] = None):
        self.cost_model = cost_model if cost_model is not None \
            else StepCostModel()
        self.wall_clock = False

    def execute(self, plan: StepPlan, step_idx: int) -> float:
        dt = self.cost_model.step_seconds(plan, step_idx)
        for st in plan.decode:
            st.out_tokens.append(
                int((st.request.req_id * 7919 + len(st.out_tokens)) % 251))
        if plan.prefill is not None and plan.prefill_chunk > 0:
            st = plan.prefill
            st.prefill_done += plan.prefill_chunk
            if st.remaining_prefill == 0:
                # prefill's final logits yield the first generated token
                st.out_tokens.append(int((st.request.req_id * 7919) % 251))
        return dt


class EngineBackend:
    """Execute the plan on the real `serve.engine.Engine`, one request at
    a time (B=1): each `RequestState` owns its KV cache and iCh band, so
    a step's work is a pure function of per-request state and interleaved
    execution is bit-identical to running the requests serially."""

    def __init__(self, engine):
        self.engine = engine
        self.wall_clock = True

    def execute(self, plan: StepPlan, step_idx: int) -> float:
        t0 = time.monotonic()
        for st in plan.decode:
            self.engine.decode_one(st)
        if plan.prefill is not None and plan.prefill_chunk > 0:
            self.engine.prefill_chunk_step(plan.prefill, plan.prefill_chunk)
        return time.monotonic() - t0


# ------------------------------------------------------------------- batcher
class ContinuousBatcher:
    """Open-loop serving driver: admission queue + policy + backend.

    `run(arrivals, ...)` releases requests at their arrival stamps (the
    open loop: arrivals never wait for completions, so overload shows up
    as backlog and tail latency, not reduced offered load), steps the
    engine until drained, and accounts TTFT / per-token / e2e latency
    into `ServeMetrics`.
    """

    def __init__(self, policy: DispatchPolicy, *,
                 queue: Optional[AdmissionQueue] = None,
                 backend=None, clock=None,
                 faults: Optional[FaultPlan] = None,
                 metrics: Optional[ServeMetrics] = None):
        self.policy = policy
        self.queue = queue if queue is not None else AdmissionQueue()
        self.backend = backend if backend is not None else SimBackend()
        if clock is None:
            clock = WallClock() if getattr(self.backend, "wall_clock",
                                           False) else SimClock()
        self.clock = clock
        self.metrics = metrics if metrics is not None else ServeMetrics()
        self.fault_clock = (FaultClock(faults, 1)
                            if faults is not None else None)
        self.step_idx = 0

    # ------------------------------------------------------------ lifecycle
    def submit(self, req: Request) -> Optional[RequestState]:
        self.metrics.n_arrived += 1
        st = self.queue.submit(req)
        if st is None:
            self.metrics.n_shed_admission += 1
            self.metrics.n_tokens_shed += req.n_new
        else:
            self.metrics.n_admitted += 1
        return st

    def _shed_expired(self, now: float) -> None:
        """Deadline enforcement at step boundaries: a running request past
        its SLO budget sheds its remaining decode steps and finalizes
        DEGRADED — the per-request PR 7 contract (prefix kept, n_shed
        counted, never an exception)."""
        for st in list(self.queue.running):
            if not st.past_deadline(now):
                continue
            shed = (st.remaining_decode if st.remaining_prefill == 0
                    else st.request.n_new - len(st.out_tokens))
            if shed > 0:
                st.degraded = True
                st.n_shed = shed
                self.metrics.n_degraded += 1
                self.metrics.n_tokens_shed += shed
            self._finalize(st, now)

    def _finalize(self, st: RequestState, now: float) -> None:
        self.queue.finish(st, now)
        self.metrics.n_completed += 1
        self.metrics.n_tokens_out += len(st.out_tokens)
        if st.t_first_token is not None:
            self.metrics.ttft.record(
                st.t_first_token - st.request.t_arrival)
        self.metrics.e2e.record(now - st.request.t_arrival)

    # ----------------------------------------------------------------- step
    def step(self) -> bool:
        """One engine step; returns False when there was nothing to do."""
        now = self.clock.now()
        self.queue.admit(now)
        self._shed_expired(now)
        plan = self.policy.choose(self.queue, now)
        if plan.prefill is None and not plan.decode:
            return False
        prefill_st = plan.prefill
        n_out_before = {id(st): len(st.out_tokens) for st in plan.decode}
        dt = self.backend.execute(plan, self.step_idx)
        # stalls from a PR 7 FaultPlan hit the batcher loop as worker 0:
        # the stall's duration lands on this step's clock, and the
        # deadline check at the NEXT boundary degrades what it blew
        if self.fault_clock is not None:
            self.fault_clock.chunks_done[0] += 1
            stall = self.fault_clock.pending_stall(0)
            if stall is not None:
                dt += stall.duration
        self.clock.advance(dt)
        self.step_idx += 1
        now = self.clock.now()
        # ---- account decode tokens ----
        for st in plan.decode:
            if len(st.out_tokens) > n_out_before[id(st)]:
                if st.t_last_token is not None:
                    self.metrics.per_token.record(now - st.t_last_token)
                st.t_last_token = now
                if st.t_first_token is None:  # decode-started-first stream
                    st.t_first_token = now
        # ---- account the prefill chunk ----
        if prefill_st is not None and plan.prefill_chunk > 0:
            prefill_st.chunk_log.append(
                {"chunk": plan.prefill_chunk, "dt": dt, "d": prefill_st.d})
            if prefill_st.remaining_prefill == 0 and prefill_st.out_tokens:
                # prefill completed this step: its final logits produced
                # the request's first token
                prefill_st.t_first_token = now
                prefill_st.t_last_token = now
        self.policy.observe(plan, dt)
        # ---- retire finished streams ----
        for st in list(self.queue.running):
            if (st.remaining_prefill == 0
                    and len(st.out_tokens) >= st.request.n_new):
                self._finalize(st, now)
        return True

    # ------------------------------------------------------------------ run
    def run(self, arrivals: list, *,
            make_request: Callable[[Arrival], Request],
            max_steps: int = 100_000) -> ServeMetrics:
        """Drive the full open-loop trace to completion.

        `arrivals` are released when the serving clock reaches their
        stamp; when the queue is idle but arrivals remain, the clock
        jumps to the next stamp (simulated clock) or sleeps (wall clock).
        """
        pending = sorted(arrivals, key=lambda a: (a.t, a.req_id))
        i = 0
        t_start = self.clock.now()
        for _ in range(max_steps):
            now = self.clock.now()
            while i < len(pending) and pending[i].t + t_start <= now:
                # shift the arrival onto the serving clock so latencies
                # and deadlines measure from the actual release stamp
                a = dataclasses.replace(pending[i], t=pending[i].t + t_start)
                self.submit(make_request(a))
                i += 1
            if not self.step():
                if i >= len(pending):
                    if self.queue.n_outstanding == 0:
                        break
                    # outstanding but unsteppable should be impossible:
                    # admit() promotes whenever a slot is free
                    self.queue.admit(now)
                    continue
                gap = pending[i].t + t_start - now
                if isinstance(self.clock, SimClock):
                    self.clock.advance(gap)
                else:  # pragma: no cover - wall-clock idle
                    time.sleep(min(gap, 0.05))
        self.metrics.t_elapsed = self.clock.now() - t_start
        return self.metrics


def make_request_factory(gen: OpenPoissonLoadGen, *,
                         vocab_size: int) -> Callable[[Arrival], Request]:
    """Arrival -> Request using the load generator's seeded prompt
    tokens; the factory bench_serve and the quickstart share."""

    def make(a: Arrival) -> Request:
        return Request(req_id=a.req_id,
                       tokens=gen.prompt_tokens(a, vocab_size),
                       n_new=a.n_new, deadline_s=a.deadline_s,
                       t_arrival=a.t)

    return make
