"""The continuous batcher: interleaved decode + chunked prefill per step.

One engine step (Sarathi-style continuous batching) assembles

    [ one decode token for EVERY running decode stream ]
  + [ one prefill chunk for ONE policy-chosen stream  ]

so decode latency stays bounded while prefills make progress. WHICH
stream prefills and HOW LARGE the chunk is are the dispatch policy's
calls (serve/policies.py); the `ich-adaptive` policy routes them through
the `sched` facade with per-request cost = remaining prompt tokens,
refined each step from the measured step wall-clock.

Two execution backends behind one `step_plan` contract:

* `SimBackend` — no model, a seeded `StepCostModel` prices each step
  (fixed dispatch overhead + per-decode-token + context-dependent
  per-prefill-token + lognormal jitter) and a `SimClock` advances by it.
  Bit-deterministic: CI and benchmarks/bench_serve.py sweep offered load
  on this backend with zero machine noise.
* `EngineBackend` — the real `serve.engine.Engine` under a `WallClock`;
  each request owns its KV cache and the step executes per-request
  (B=1), so interleaving is bit-identical to serial execution
  (tests/test_serve_batch.py).

Faults: a PR 7 `FaultPlan`'s stalls apply to the batcher loop as worker
0 — a pending stall at a step boundary adds its duration to that step's
clock, and deadline handling must DEGRADE the affected requests (shed
remaining decode, keep the prefix) rather than blow their SLOs silently
(tests/test_serve_slo_chaos.py).

Durability (DESIGN.md §2.11): pass ``journal=`` (a
`repro.robust.ServeJournal`) and the batcher appends every admission,
`StepPlan`, stall, and completion as a JSON line; because every policy
decision and simulated cost is a pure function of seeds + recorded
events, replaying the journal through a fresh batcher
(`repro.robust.resume_from_journal`) reconstructs the exact pre-crash
state — queue, per-request iCh bands, policy internals, metrics — and
the resumed run is bit-identical to an uninterrupted one. `snapshot()`
captures the same state directly for cross-checks and for
`ContinuousBatcher.restore`. The `EngineBackend` boundary is hardened:
a per-op retry budget (the executor's `_attempt` contract) plus a
`CircuitBreaker` turn a flaky backend into degraded requests via the
deadline path instead of an exception out of the batcher loop.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Optional

import numpy as np

from ..core import executor as E
from ..robust.faults import FaultClock, FaultError, FaultPlan, InjectedFault
from .loadgen import Arrival, OpenPoissonLoadGen
from .metrics import ServeMetrics
from .policies import DispatchPolicy, StepPlan
from .queue import AdmissionQueue, Request, RequestState


# --------------------------------------------------------------------- clocks
class WallClock:
    """Real time (monotonic)."""

    def now(self) -> float:
        return time.monotonic()

    def advance(self, dt: float) -> None:  # wall time advances itself
        pass


class SimClock:
    """Simulated serving clock: starts at 0, advances only when told."""

    def __init__(self, t0: float = 0.0):
        self._t = float(t0)

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> None:
        if dt < 0:
            raise ValueError(f"clock cannot run backwards (dt={dt})")
        self._t += float(dt)

    def jump(self, t: float) -> None:
        """Set the clock outright — journal replay snaps it to each
        RECORDED step time so a wall-clock run's deadline decisions
        replay exactly (accumulated float drift would otherwise flip a
        borderline shed)."""
        self._t = float(t)


# ----------------------------------------------------------------- cost model
@dataclasses.dataclass(frozen=True)
class StepCostModel:
    """Prices one batched engine step for the simulated backend.

    seconds = overhead
            + n_decode * decode_token_s
            + chunk * prefill_token_s * (1 + ctx / ctx_scale)
            + lognormal jitter (seeded per step)

    The context term makes LATE chunks of a long prompt cost more per
    token than early ones (attention over the growing KV prefix) — the
    nonuniformity the iCh divisor and the cost refiner exist to track.
    """

    overhead_s: float = 2e-3
    decode_token_s: float = 2e-4
    prefill_token_s: float = 5e-5
    ctx_scale: float = 512.0
    jitter_sigma: float = 0.10
    seed: int = 0

    def step_seconds(self, plan: StepPlan, step_idx: int) -> float:
        cost = self.overhead_s + plan.n_decode * self.decode_token_s
        if plan.prefill is not None and plan.prefill_chunk > 0:
            ctx = plan.prefill.prefill_done
            cost += (plan.prefill_chunk * self.prefill_token_s
                     * (1.0 + ctx / self.ctx_scale))
        if self.jitter_sigma > 0:
            rng = np.random.default_rng((self.seed << 24) + step_idx)
            cost *= float(rng.lognormal(0.0, self.jitter_sigma))
        return cost


# ------------------------------------------------------------------- backends
class SimBackend:
    """Advance request state logically; a `StepCostModel` prices the step.

    Generated token ids are a deterministic function of (req_id, position)
    so interleaving order can never change outputs — the simulated twin of
    the real backend's bit-identity property."""

    def __init__(self, cost_model: Optional[StepCostModel] = None):
        self.cost_model = cost_model if cost_model is not None \
            else StepCostModel()
        self.wall_clock = False

    def execute(self, plan: StepPlan, step_idx: int) -> float:
        dt = self.cost_model.step_seconds(plan, step_idx)
        for st in plan.decode:
            st.out_tokens.append(
                int((st.request.req_id * 7919 + len(st.out_tokens)) % 251))
        if plan.prefill is not None and plan.prefill_chunk > 0:
            st = plan.prefill
            st.prefill_done += plan.prefill_chunk
            if st.remaining_prefill == 0:
                # prefill's final logits yield the first generated token
                st.out_tokens.append(int((st.request.req_id * 7919) % 251))
        return dt


class CircuitBreaker:
    """Three-state breaker guarding the engine boundary (DESIGN.md §2.11).

    closed --[threshold consecutive failed steps]--> open
    open   --[cooldown_steps engine steps pass]----> half_open (one probe)
    half_open --success--> closed    half_open --failure--> open

    The cooldown is measured in ENGINE STEPS, not seconds, so breaker
    behaviour is deterministic under the simulated clock and replays
    bit-identically from a journal. While open, `allow()` is False and
    the backend skips the step's ops entirely — requests stop making
    progress and the deadline path degrades them, which is the intended
    failure mode for a down backend (bounded, accounted, no exception).
    """

    CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"

    def __init__(self, *, threshold: int = 3, cooldown_steps: int = 8):
        if threshold < 1 or cooldown_steps < 1:
            raise ValueError("threshold and cooldown_steps must be >= 1")
        self.threshold = int(threshold)
        self.cooldown_steps = int(cooldown_steps)
        self.state = self.CLOSED
        self.failures = 0          # consecutive failed steps while closed
        self.opened_at = -1        # step_idx of the last trip
        self.n_trips = 0

    def allow(self, step_idx: int) -> bool:
        """May this step touch the engine? Transitions open->half_open
        once the cooldown has elapsed (the single probe step)."""
        if self.state == self.OPEN:
            if step_idx - self.opened_at >= self.cooldown_steps:
                self.state = self.HALF_OPEN
                return True
            return False
        return True

    def record_success(self) -> None:
        self.state = self.CLOSED
        self.failures = 0

    def record_failure(self, step_idx: int) -> None:
        self.failures += 1
        if self.state == self.HALF_OPEN or self.failures >= self.threshold:
            self.state = self.OPEN
            self.opened_at = int(step_idx)
            self.failures = 0
            self.n_trips += 1

    # ------------------------------------------------------------- snapshot
    def state_dict(self) -> dict:
        return {"threshold": self.threshold,
                "cooldown_steps": self.cooldown_steps, "state": self.state,
                "failures": self.failures, "opened_at": self.opened_at,
                "n_trips": self.n_trips}

    @classmethod
    def from_state(cls, d: dict) -> "CircuitBreaker":
        b = cls(threshold=d["threshold"], cooldown_steps=d["cooldown_steps"])
        b.state = d["state"]
        b.failures = int(d["failures"])
        b.opened_at = int(d["opened_at"])
        b.n_trips = int(d["n_trips"])
        return b


class EngineBackend:
    """Execute the plan on the real `serve.engine.Engine`, one request at
    a time (B=1): each `RequestState` owns its KV cache and iCh band, so
    a step's work is a pure function of per-request state and interleaved
    execution is bit-identical to running the requests serially.

    The boundary is hardened (DESIGN.md §2.11): each engine op runs under
    the executor's `_attempt` retry contract (`retries` attempts with
    bounded exponential backoff, `sleep_fn=` injectable so retry suites
    cost zero wall-clock), and a terminal `FaultError`/`InjectedFault` is
    ABSORBED — the op's request simply makes no progress this step, and
    the deadline path eventually degrades it. A `CircuitBreaker` stops
    hammering an engine that fails whole steps consecutively. Real bugs
    (any other exception type) still propagate.
    """

    def __init__(self, engine, *, retries: int = 0,
                 retry_backoff_s: float = 0.0,
                 breaker: Optional[CircuitBreaker] = None,
                 open_step_s: float = 0.0,
                 sleep_fn: Optional[Callable[[float], None]] = None):
        self.engine = engine
        self.wall_clock = True
        self.retries = int(retries)
        self.retry_backoff_s = float(retry_backoff_s)
        self.breaker = breaker
        # seconds charged to a breaker-skipped step so a simulated clock
        # still advances toward the deadlines that degrade stuck requests
        self.open_step_s = float(open_step_s)
        self.sleep_fn = sleep_fn
        self.n_faults = 0           # terminal per-op faults absorbed
        self._stats = E.ExecStats()
        self._lock = threading.Lock()

    @property
    def n_retries(self) -> int:
        return self._stats.retries

    def _op(self, fn: Callable[[], None]) -> bool:
        """One engine op under the retry budget; False = fault absorbed."""
        try:
            E._attempt(lambda _i: fn(), 0, self.retries,
                       self.retry_backoff_s, self._stats, self._lock,
                       self.sleep_fn)
            return True
        except (FaultError, InjectedFault):
            self.n_faults += 1
            return False

    def execute(self, plan: StepPlan, step_idx: int) -> float:
        t0 = time.monotonic()
        if self.breaker is not None and not self.breaker.allow(step_idx):
            return (time.monotonic() - t0) + self.open_step_s
        ok = True
        for st in plan.decode:
            if not self._op(lambda st=st: self.engine.decode_one(st)):
                ok = False
        if plan.prefill is not None and plan.prefill_chunk > 0:
            if not self._op(lambda: self.engine.prefill_chunk_step(
                    plan.prefill, plan.prefill_chunk)):
                ok = False
        if self.breaker is not None:
            if ok:
                self.breaker.record_success()
            else:
                self.breaker.record_failure(step_idx)
        return time.monotonic() - t0

    # ---------------------------------------------- restore (DESIGN.md §2.11)
    def rebuild_state(self, st: RequestState) -> None:
        """Re-derive `st.cache`/`st.last_logits` after a snapshot restore.

        KV caches are never serialized; instead the journaled prefill
        chunk SIZES are replayed through `prefill_chunk_step` — identical
        chunking means identical `prefill_extend` calls, so the rebuilt
        cache is bit-identical (§2.10's chunk-invariance) — then the
        already-emitted decode tokens are re-derived with `decode_one`.
        The replayed tokens must match the snapshot or the restore is
        refused.
        """
        if st.prefill_done == 0 and not st.out_tokens:
            st.cache = None
            st.last_logits = None
            return
        tmp = RequestState(request=st.request, status=st.status, d=st.d)
        for rec in st.chunk_log:
            c = min(int(rec["chunk"]), tmp.remaining_prefill)
            if c > 0:
                self.engine.prefill_chunk_step(tmp, c)
        if tmp.prefill_done != st.prefill_done:
            raise ValueError(
                f"chunk log replays to {tmp.prefill_done} prefill tokens "
                f"but the snapshot recorded {st.prefill_done}")
        while len(tmp.out_tokens) < len(st.out_tokens):
            self.engine.decode_one(tmp)
        if tmp.out_tokens != [int(t) for t in st.out_tokens]:
            raise ValueError("replayed tokens diverge from the snapshot; "
                             "refusing to resume on a different engine")
        st.cache = tmp.cache
        st.last_logits = tmp.last_logits


# ------------------------------------------------------------------- batcher
class ContinuousBatcher:
    """Open-loop serving driver: admission queue + policy + backend.

    `run(arrivals, ...)` releases requests at their arrival stamps (the
    open loop: arrivals never wait for completions, so overload shows up
    as backlog and tail latency, not reduced offered load), steps the
    engine until drained, and accounts TTFT / per-token / e2e latency
    into `ServeMetrics`.
    """

    JOURNAL_VERSION = 1

    def __init__(self, policy: DispatchPolicy, *,
                 queue: Optional[AdmissionQueue] = None,
                 backend=None, clock=None,
                 faults: Optional[FaultPlan] = None,
                 metrics: Optional[ServeMetrics] = None,
                 journal=None):
        self.policy = policy
        self.queue = queue if queue is not None else AdmissionQueue()
        self.backend = backend if backend is not None else SimBackend()
        if clock is None:
            clock = WallClock() if getattr(self.backend, "wall_clock",
                                           False) else SimClock()
        self.clock = clock
        self.metrics = metrics if metrics is not None else ServeMetrics()
        self.faults = faults
        self.fault_clock = (FaultClock(faults, 1)
                            if faults is not None else None)
        self.step_idx = 0
        self._t_start: Optional[float] = None
        self._submitted_ids: set = set()
        self.journal = journal
        if journal is not None:
            journal.append(self._header())

    def _header(self) -> dict:
        cm = getattr(self.backend, "cost_model", None)
        return {"ev": "header", "version": self.JOURNAL_VERSION,
                "policy": type(self.policy).__name__,
                "backend": type(self.backend).__name__,
                "cost_model": (dataclasses.asdict(cm)
                               if cm is not None else None),
                "queue": {"max_pending": self.queue.max_pending,
                          "max_running": self.queue.max_running,
                          "init_divisor": self.queue.init_divisor},
                "faults": (self.faults.to_json()
                           if self.faults is not None else None),
                "faults_fp": (self.faults.fingerprint()
                              if self.faults is not None else None)}

    def _j(self, ev: dict) -> None:
        if self.journal is not None:
            self.journal.append(ev)

    # ------------------------------------------------------------ lifecycle
    def submit(self, req: Request) -> Optional[RequestState]:
        self.metrics.n_arrived += 1
        self._submitted_ids.add(req.req_id)
        st = self.queue.submit(req)
        if st is None:
            self.metrics.n_shed_admission += 1
            self.metrics.n_tokens_shed += req.n_new
        else:
            self.metrics.n_admitted += 1
        self._j({"ev": "submit", "req": req.to_dict(),
                 "admitted": st is not None})
        return st

    def _shed_expired(self, now: float) -> None:
        """Deadline enforcement at step boundaries: a running request past
        its SLO budget sheds its remaining decode steps and finalizes
        DEGRADED — the per-request PR 7 contract (prefix kept, n_shed
        counted, never an exception)."""
        for st in list(self.queue.running):
            if not st.past_deadline(now):
                continue
            shed = (st.remaining_decode if st.remaining_prefill == 0
                    else st.request.n_new - len(st.out_tokens))
            if shed > 0:
                st.degraded = True
                st.n_shed = shed
                self.metrics.n_degraded += 1
                self.metrics.n_tokens_shed += shed
            self._finalize(st, now)

    def _finalize(self, st: RequestState, now: float) -> None:
        self.queue.finish(st, now)
        self.metrics.n_completed += 1
        self.metrics.n_tokens_out += len(st.out_tokens)
        if st.t_first_token is not None:
            self.metrics.ttft.record(
                st.t_first_token - st.request.t_arrival)
        self.metrics.e2e.record(now - st.request.t_arrival)
        self._j({"ev": "finish", "req_id": st.request.req_id, "t": now,
                 "degraded": st.degraded, "n_shed": st.n_shed,
                 "n_tok": len(st.out_tokens)})

    # ----------------------------------------------------------------- step
    def step(self, _dt_override: Optional[float] = None) -> bool:
        """One engine step; returns False when there was nothing to do.

        `_dt_override` is the journal-replay hook: `resume_from_journal`
        passes the RECORDED step duration so a wall-clock run's measured
        timings replay exactly (simulated backends never need it — their
        costs are already pure functions of seeds)."""
        now = self.clock.now()
        self.queue.admit(now)
        self._shed_expired(now)
        plan = self.policy.choose(self.queue, now)
        if plan.prefill is None and not plan.decode:
            return False
        idx = self.step_idx
        prefill_st = plan.prefill
        n_out_before = {id(st): len(st.out_tokens) for st in plan.decode}
        dt = self.backend.execute(plan, idx)
        # stalls from a PR 7 FaultPlan hit the batcher loop as worker 0:
        # the stall's duration lands on this step's clock, and the
        # deadline check at the NEXT boundary degrades what it blew
        if self.fault_clock is not None:
            self.fault_clock.chunks_done[0] += 1
            stall = self.fault_clock.pending_stall(0)
            if stall is not None:
                dt += stall.duration
                self._j({"ev": "stall", "i": idx,
                         "duration": stall.duration})
        if _dt_override is not None:
            dt = float(_dt_override)
        self.clock.advance(dt)
        self.step_idx += 1
        now = self.clock.now()
        self._j({"ev": "step", "i": idx,
                 "decode": [st.request.req_id for st in plan.decode],
                 "prefill": (prefill_st.request.req_id
                             if prefill_st is not None else None),
                 "chunk": plan.prefill_chunk, "dt": dt, "t": now})
        # ---- account decode tokens ----
        for st in plan.decode:
            if len(st.out_tokens) > n_out_before[id(st)]:
                if st.t_last_token is not None:
                    self.metrics.per_token.record(now - st.t_last_token)
                st.t_last_token = now
                if st.t_first_token is None:  # decode-started-first stream
                    st.t_first_token = now
        # ---- account the prefill chunk ----
        if prefill_st is not None and plan.prefill_chunk > 0:
            prefill_st.chunk_log.append(
                {"chunk": plan.prefill_chunk, "dt": dt, "d": prefill_st.d})
            if prefill_st.remaining_prefill == 0 and prefill_st.out_tokens:
                # prefill completed this step: its final logits produced
                # the request's first token
                prefill_st.t_first_token = now
                prefill_st.t_last_token = now
        # ---- hardened-boundary counters (EngineBackend only) ----
        if hasattr(self.backend, "n_faults"):
            self.metrics.n_backend_faults = self.backend.n_faults
            self.metrics.n_backend_retries = self.backend.n_retries
            if self.backend.breaker is not None:
                self.metrics.n_breaker_trips = self.backend.breaker.n_trips
        # surface any O(n^2) prefix-rerun prefill chunks the engine took
        eng = getattr(self.backend, "engine", None)
        if eng is not None and hasattr(eng, "n_prefill_fallbacks"):
            self.metrics.n_prefill_fallback = eng.n_prefill_fallbacks
        self.policy.observe(plan, dt)
        # ---- retire finished streams ----
        for st in list(self.queue.running):
            if (st.remaining_prefill == 0
                    and len(st.out_tokens) >= st.request.n_new):
                self._finalize(st, now)
        return True

    # ------------------------------------------------------------------ run
    def run(self, arrivals: list, *,
            make_request: Callable[[Arrival], Request],
            max_steps: int = 100_000) -> ServeMetrics:
        """Drive the full open-loop trace to completion.

        `arrivals` are released when the serving clock reaches their
        stamp; when the queue is idle but arrivals remain, the clock
        jumps to the next stamp (simulated clock) or sleeps (wall clock).
        Resumable: a restored batcher keeps its original `t_start`, and
        arrivals already submitted before the crash are skipped.
        """
        pending = sorted(arrivals, key=lambda a: (a.t, a.req_id))
        i = 0
        if self._t_start is None:
            self._t_start = self.clock.now()
            self._j({"ev": "run", "t_start": self._t_start})
        t_start = self._t_start
        for _ in range(max_steps):
            now = self.clock.now()
            while i < len(pending) and pending[i].t + t_start <= now:
                if pending[i].req_id not in self._submitted_ids:
                    # shift the arrival onto the serving clock so
                    # latencies and deadlines measure from the actual
                    # release stamp
                    a = dataclasses.replace(pending[i],
                                            t=pending[i].t + t_start)
                    self.submit(make_request(a))
                i += 1
            if not self.step():
                if i >= len(pending):
                    if self.queue.n_outstanding == 0:
                        break
                    # outstanding but unsteppable should be impossible:
                    # admit() promotes whenever a slot is free
                    self.queue.admit(now)
                    continue
                gap = pending[i].t + t_start - now
                if isinstance(self.clock, SimClock):
                    self._j({"ev": "gap", "dt": gap})
                    self.clock.advance(gap)
                else:  # pragma: no cover - wall-clock idle
                    time.sleep(min(gap, 0.05))
        self.metrics.t_elapsed = self.clock.now() - t_start
        return self.metrics

    # ------------------------------------------- snapshot (DESIGN.md §2.11)
    def snapshot(self) -> dict:
        """JSON-serializable full driver state at a step boundary.

        Captures everything `restore` needs EXCEPT policy internals and
        KV caches: stateless policies (`fcfs-static`, `round-robin` up to
        its cursor) restore exactly; the iCh-adaptive policy's refiner
        state is replay-derived (use `resume_from_journal` when policy
        internals must survive bit-exactly); KV caches are re-derived by
        `EngineBackend.rebuild_state`.
        """
        return {"version": self.JOURNAL_VERSION,
                "step_idx": self.step_idx,
                "t_now": self.clock.now(), "t_start": self._t_start,
                "queue": self.queue.state_dict(),
                "metrics": self.metrics.state_dict(),
                "fault_clock": (None if self.fault_clock is None else
                                {"chunks_done":
                                     [int(c) for c in
                                      self.fault_clock.chunks_done],
                                 "stall_idx":
                                     [int(s) for s in
                                      self.fault_clock.stall_idx]}),
                "breaker": (self.backend.breaker.state_dict()
                            if getattr(self.backend, "breaker", None)
                            is not None else None)}

    @classmethod
    def restore(cls, snap: dict, *, policy: DispatchPolicy, backend=None,
                clock=None, faults: Optional[FaultPlan] = None,
                journal=None) -> "ContinuousBatcher":
        """Rebuild a batcher from `snapshot()` output.

        The clock defaults to a `SimClock` resumed at the snapshot's
        serving-clock time (pass `clock=` to override). Running requests
        get their KV re-derived via `backend.rebuild_state` when the
        backend provides it.
        """
        if snap.get("version") != cls.JOURNAL_VERSION:
            raise ValueError(
                f"snapshot version {snap.get('version')} != "
                f"{cls.JOURNAL_VERSION}")
        q = AdmissionQueue.from_state(snap["queue"])
        m = ServeMetrics.from_state(snap["metrics"])
        if clock is None:
            clock = SimClock(snap["t_now"])
        b = cls(policy, queue=q, backend=backend, clock=clock,
                faults=faults, metrics=m, journal=journal)
        b.step_idx = int(snap["step_idx"])
        b._t_start = snap["t_start"]
        for group in ("pending", "running", "done"):
            for s in snap["queue"][group]:
                b._submitted_ids.add(int(s["request"]["req_id"]))
        for r in snap["queue"]["shed"]:
            b._submitted_ids.add(int(r["req_id"]))
        fc_state = snap.get("fault_clock")
        if b.fault_clock is not None and fc_state is not None:
            for w, c in enumerate(fc_state["chunks_done"]):
                b.fault_clock.chunks_done[w] = int(c)
            for w, s in enumerate(fc_state["stall_idx"]):
                b.fault_clock.stall_idx[w] = int(s)
        if (snap.get("breaker") is not None
                and getattr(b.backend, "breaker", None) is not None):
            b.backend.breaker = CircuitBreaker.from_state(snap["breaker"])
        if hasattr(b.backend, "rebuild_state"):
            for st in b.queue.running:
                b.backend.rebuild_state(st)
        return b


def make_request_factory(gen: OpenPoissonLoadGen, *,
                         vocab_size: int) -> Callable[[Arrival], Request]:
    """Arrival -> Request using the load generator's seeded prompt
    tokens; the factory bench_serve and the quickstart share."""

    def make(a: Arrival) -> Request:
        return Request(req_id=a.req_id,
                       tokens=gen.prompt_tokens(a, vocab_size),
                       n_new=a.n_new, deadline_s=a.deadline_s,
                       t_arrival=a.t)

    return make
