"""Admission-controlled request queue: pending/running/done lifecycle.

The continuous batcher (serve/batcher.py) owns one `AdmissionQueue`.
Requests flow

    submit() -> PENDING -> admit() -> RUNNING -> DONE
           \\-> shed (bounded queue overflow, deterministic)

and every request carries its own `RequestState`: the per-request iCh
divisor band (``d``, ``ks`` — moved OFF the engine singleton, so two
interleaved requests can no longer pollute each other's band), the prefill
cursor, the KV cache, the generated tokens, and the latency timestamps the
metrics layer reads. `deadline_s` is the per-request SLO budget from PR 7
(DESIGN.md §2.9): when the serving clock overruns it mid-decode the
batcher sheds the remaining steps and finalizes the request `degraded`
with the same ``degraded``/``n_shed`` contract `Engine.generate` exposes.

Numpy-only: no jax import, the queue works identically under the real
engine and the simulated-clock backend.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Optional

import numpy as np

PENDING, RUNNING, DONE, SHED = "pending", "running", "done", "shed"


@dataclasses.dataclass(frozen=True)
class Request:
    """What the client submitted (immutable)."""

    req_id: int
    tokens: np.ndarray           # (1, S) int prompt
    n_new: int                   # decode budget
    deadline_s: Optional[float] = None   # e2e SLO budget from arrival
    t_arrival: float = 0.0

    def __post_init__(self):
        t = np.asarray(self.tokens)
        if t.ndim == 1:
            t = t[None, :]
        if t.ndim != 2 or t.shape[0] != 1 or t.shape[1] < 1:
            raise ValueError(
                f"prompt must be (1, S>=1) or (S>=1,), got {t.shape}")
        object.__setattr__(self, "tokens", t)
        if self.n_new < 1:
            raise ValueError(f"n_new must be >= 1, got {self.n_new}")

    @property
    def prompt_len(self) -> int:
        return int(self.tokens.shape[1])

    def to_dict(self) -> dict:
        """JSON-serializable form (journal admission events, snapshots)."""
        return {"req_id": int(self.req_id),
                "tokens": [int(t) for t in self.tokens[0]],
                "n_new": int(self.n_new), "deadline_s": self.deadline_s,
                "t_arrival": float(self.t_arrival)}

    @classmethod
    def from_dict(cls, d: dict) -> "Request":
        return cls(req_id=int(d["req_id"]),
                   tokens=np.asarray(d["tokens"], np.int32),
                   n_new=int(d["n_new"]), deadline_s=d.get("deadline_s"),
                   t_arrival=float(d.get("t_arrival", 0.0)))


@dataclasses.dataclass
class RequestState:
    """Per-request runtime state (one per admitted request).

    The iCh fields are the paper's per-worker (d_i, k_i) pair scoped to the
    request's prefill stream: `d` divides the remaining prompt into the
    next chunk, `ks` is the measured chunk-throughput history the band
    classifies against. `cache`/`last_logits` are opaque to the queue (jax
    arrays under the real engine, None under the simulated backend).
    """

    request: Request
    status: str = PENDING
    # ---- iCh chunk state (per request, NOT per engine) ----
    d: float = 4.0
    ks: list = dataclasses.field(default_factory=list)
    chunk_log: list = dataclasses.field(default_factory=list)
    # ---- prefill / decode cursors ----
    prefill_done: int = 0
    cache: Any = None
    last_logits: Any = None
    out_tokens: list = dataclasses.field(default_factory=list)
    # ---- SLO outcome (PR 7 generate() contract, per request) ----
    degraded: bool = False
    n_shed: int = 0
    # ---- timestamps (serving-clock seconds) ----
    t_admit: float = 0.0
    t_first_token: Optional[float] = None
    t_last_token: Optional[float] = None
    t_done: Optional[float] = None

    # ------------------------------------------------------------ progress
    @property
    def prompt_len(self) -> int:
        return self.request.prompt_len

    @property
    def remaining_prefill(self) -> int:
        return self.prompt_len - self.prefill_done

    @property
    def needs_prefill(self) -> bool:
        return self.status == RUNNING and self.remaining_prefill > 0

    @property
    def decoding(self) -> bool:
        return (self.status == RUNNING and self.remaining_prefill == 0
                and len(self.out_tokens) < self.request.n_new)

    @property
    def remaining_decode(self) -> int:
        return self.request.n_new - len(self.out_tokens)

    @property
    def deadline_at(self) -> Optional[float]:
        if self.request.deadline_s is None:
            return None
        return self.request.t_arrival + self.request.deadline_s

    def past_deadline(self, now: float) -> bool:
        dl = self.deadline_at
        return dl is not None and now > dl

    def output(self) -> np.ndarray:
        """(1, n_done) generated ids (empty (1, 0) before first token)."""
        if not self.out_tokens:
            return np.zeros((1, 0), dtype=np.int32)
        return np.asarray(self.out_tokens, dtype=np.int32).reshape(1, -1)

    def stats(self) -> dict:
        """The per-request stats contract (`Engine.generate` superset)."""
        return {"chunks": self.chunk_log, "d_final": self.d,
                "degraded": self.degraded, "n_shed": self.n_shed,
                "deadline_s": self.request.deadline_s,
                "ttft": (None if self.t_first_token is None
                         else self.t_first_token - self.request.t_arrival),
                "e2e": (None if self.t_done is None
                        else self.t_done - self.request.t_arrival)}

    # ------------------------------------------- snapshot (DESIGN.md §2.11)
    def state_dict(self) -> dict:
        """Everything durable about the request: cursors, iCh band, output,
        timestamps. `cache`/`last_logits` are deliberately absent — under
        the real engine they are re-derived bit-identically by replaying
        the journaled prefill chunks through `prefill_extend`
        (`EngineBackend.rebuild_state`)."""
        return {"request": self.request.to_dict(), "status": self.status,
                "d": self.d, "ks": list(self.ks),
                "chunk_log": [dict(c) for c in self.chunk_log],
                "prefill_done": int(self.prefill_done),
                "out_tokens": [int(t) for t in self.out_tokens],
                "degraded": self.degraded, "n_shed": int(self.n_shed),
                "t_admit": self.t_admit,
                "t_first_token": self.t_first_token,
                "t_last_token": self.t_last_token, "t_done": self.t_done}

    @classmethod
    def from_state(cls, d: dict) -> "RequestState":
        return cls(request=Request.from_dict(d["request"]),
                   status=d["status"], d=float(d["d"]),
                   ks=list(d["ks"]),
                   chunk_log=[dict(c) for c in d["chunk_log"]],
                   prefill_done=int(d["prefill_done"]),
                   out_tokens=[int(t) for t in d["out_tokens"]],
                   degraded=bool(d["degraded"]), n_shed=int(d["n_shed"]),
                   t_admit=d["t_admit"],
                   t_first_token=d["t_first_token"],
                   t_last_token=d["t_last_token"], t_done=d["t_done"])


class AdmissionQueue:
    """Bounded pending queue + running set with deterministic shed.

    `submit()` accepts a request into PENDING unless the queue already
    holds `max_pending` requests — then the NEW request is shed
    immediately (deterministic drop-tail: the same arrival trace always
    sheds the same request ids, asserted in tests/test_serve_batch.py).
    `admit()` promotes FCFS from PENDING to RUNNING up to `max_running`
    concurrent requests (the continuous batch size).
    """

    def __init__(self, *, max_pending: int = 64, max_running: int = 8,
                 init_divisor: float = 4.0):
        if max_pending < 1 or max_running < 1:
            raise ValueError("max_pending and max_running must be >= 1")
        self.max_pending = int(max_pending)
        self.max_running = int(max_running)
        self.init_divisor = float(init_divisor)
        self.pending: deque[RequestState] = deque()
        self.running: list[RequestState] = []
        self.done: list[RequestState] = []
        self.shed: list[Request] = []

    # ------------------------------------------------------------ lifecycle
    def submit(self, req: Request) -> Optional[RequestState]:
        """Queue a request; returns its state, or None when shed."""
        if len(self.pending) >= self.max_pending:
            self.shed.append(req)
            return None
        st = RequestState(request=req, d=self.init_divisor)
        self.pending.append(st)
        return st

    def admit(self, now: float) -> list[RequestState]:
        """Promote pending -> running (FCFS) up to `max_running`."""
        admitted = []
        while self.pending and len(self.running) < self.max_running:
            st = self.pending.popleft()
            st.status = RUNNING
            st.t_admit = now
            self.running.append(st)
            admitted.append(st)
        return admitted

    def finish(self, st: RequestState, now: float) -> None:
        """Move a running request to DONE (completed or degraded)."""
        st.status = DONE
        st.t_done = now
        self.running.remove(st)
        self.done.append(st)

    # ------------------------------------------------------------- queries
    @property
    def n_outstanding(self) -> int:
        return len(self.pending) + len(self.running)

    @property
    def n_shed(self) -> int:
        return len(self.shed)

    def prefilling(self) -> list[RequestState]:
        return [st for st in self.running if st.needs_prefill]

    def decoding(self) -> list[RequestState]:
        return [st for st in self.running if st.decoding]

    # ------------------------------------------- snapshot (DESIGN.md §2.11)
    def state_dict(self) -> dict:
        return {"max_pending": self.max_pending,
                "max_running": self.max_running,
                "init_divisor": self.init_divisor,
                "pending": [st.state_dict() for st in self.pending],
                "running": [st.state_dict() for st in self.running],
                "done": [st.state_dict() for st in self.done],
                "shed": [r.to_dict() for r in self.shed]}

    @classmethod
    def from_state(cls, d: dict) -> "AdmissionQueue":
        q = cls(max_pending=d["max_pending"], max_running=d["max_running"],
                init_divisor=d["init_divisor"])
        q.pending = deque(RequestState.from_state(s) for s in d["pending"])
        q.running = [RequestState.from_state(s) for s in d["running"]]
        q.done = [RequestState.from_state(s) for s in d["done"]]
        q.shed = [Request.from_dict(r) for r in d["shed"]]
        return q
