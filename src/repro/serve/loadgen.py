"""Seeded open-loop Poisson load generation for the serving engine.

OPEN-loop means arrivals are scheduled up front from the seed — they do not
wait for the system to finish previous requests (the queue_flex exemplar's
`OpenPoissonLoadGen`). That is the property that makes tail-latency curves
honest: a saturated server keeps receiving work and the backlog shows up in
p99/p999 instead of silently throttling the generator.

Prompt-length and output-length distributions mirror the paper-grid
workload families (`tests/_paper_grid.py`): heavy-tailed zipf (the
production prompt mix — many short, few huge) and lognormal, plus fixed /
uniform for controlled tests. Everything is a pure function of the seed.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class LengthDist:
    """A seeded integer length distribution clamped to [lo, hi].

    kinds: ``fixed`` (always lo), ``uniform`` (lo..hi inclusive),
    ``zipf`` (lo + zipf(alpha) - 1, clamped — the heavy-tailed prompt mix),
    ``lognormal`` (lo + round(lognormal(mu, sigma)), clamped).
    """

    kind: str = "fixed"
    lo: int = 32
    hi: int = 32
    alpha: float = 1.8     # zipf exponent
    mu: float = 3.0        # lognormal log-mean
    sigma: float = 0.8     # lognormal log-std

    def __post_init__(self):
        if self.kind not in ("fixed", "uniform", "zipf", "lognormal"):
            raise ValueError(f"unknown length distribution {self.kind!r}")
        if not (1 <= self.lo <= self.hi):
            raise ValueError(
                f"need 1 <= lo <= hi, got lo={self.lo}, hi={self.hi}")
        if self.kind == "zipf" and self.alpha <= 1.0:
            raise ValueError(f"zipf alpha must be > 1, got {self.alpha}")

    def sample(self, rng: np.random.Generator, size: int) -> np.ndarray:
        if self.kind == "fixed":
            return np.full(size, self.lo, dtype=np.int64)
        if self.kind == "uniform":
            return rng.integers(self.lo, self.hi + 1, size).astype(np.int64)
        if self.kind == "zipf":
            raw = self.lo + rng.zipf(self.alpha, size) - 1
        else:  # lognormal
            raw = self.lo + np.round(
                rng.lognormal(self.mu, self.sigma, size)).astype(np.int64)
        return np.minimum(raw, self.hi).astype(np.int64)


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One generated request: when it arrives and how big it is."""

    req_id: int
    t: float               # arrival time (serving-clock seconds)
    prompt_len: int
    n_new: int
    deadline_s: Optional[float] = None  # per-request SLO budget (PR 7)


class OpenPoissonLoadGen:
    """Open-loop Poisson arrival process at `rate` requests/second.

    Inter-arrival gaps are iid Exponential(rate); prompt/output lengths
    draw from their `LengthDist`s. The whole trace is a pure function of
    `seed`, so a sweep point replays bit-identically (the determinism the
    CI smoke asserts)."""

    def __init__(self, rate: float, *,
                 prompt_lens: Optional[LengthDist] = None,
                 output_lens: Optional[LengthDist] = None,
                 deadline_s: Optional[float] = None,
                 seed: int = 0):
        if rate <= 0:
            raise ValueError(f"arrival rate must be > 0, got {rate}")
        self.rate = float(rate)
        self.prompt_lens = prompt_lens if prompt_lens is not None \
            else LengthDist("zipf", lo=16, hi=256, alpha=1.6)
        self.output_lens = output_lens if output_lens is not None \
            else LengthDist("fixed", lo=8, hi=8)
        self.deadline_s = deadline_s
        self.seed = int(seed)

    def arrivals(self, n: int, t0: float = 0.0) -> list[Arrival]:
        """The first `n` arrivals after `t0`, scheduled open-loop."""
        if n <= 0:
            return []
        rng = np.random.default_rng(self.seed)
        gaps = rng.exponential(1.0 / self.rate, n)
        times = t0 + np.cumsum(gaps)
        plens = self.prompt_lens.sample(rng, n)
        nlens = self.output_lens.sample(rng, n)
        return [Arrival(req_id=i, t=float(times[i]),
                        prompt_len=int(plens[i]), n_new=int(nlens[i]),
                        deadline_s=self.deadline_s)
                for i in range(n)]

    def prompt_tokens(self, arrival: Arrival, vocab_size: int) -> np.ndarray:
        """Deterministic (1, S) token ids for an arrival — seeded per
        request id so the same trace yields the same prompts."""
        rng = np.random.default_rng((self.seed << 20) + arrival.req_id)
        return rng.integers(0, vocab_size,
                            (1, arrival.prompt_len)).astype(np.int32)
