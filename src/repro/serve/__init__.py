"""`repro.serve` — continuous-batching serving on the iCh scheduler.

The serving subsystem (DESIGN.md §2.10): an admission-controlled request
queue, an open-loop Poisson load generator, pluggable dispatch policies
(FCFS-static / round-robin / ich-adaptive), and the continuous batcher
that interleaves one chunked-prefill slice with every running decode
stream per engine step, with per-request iCh chunk state and
log-bucketed tail-latency metrics.

Exports are lazy (PEP 562): the queue/loadgen/metrics/policies/batcher
surface is numpy-only and must stay importable without paying for jax;
only `Engine`/`EngineConfig` pull in the model stack.
"""

_LAZY = {
    # real model engine (jax)
    "Engine": "engine",
    "EngineConfig": "engine",
    # open-loop load generation
    "Arrival": "loadgen",
    "LengthDist": "loadgen",
    "OpenPoissonLoadGen": "loadgen",
    # admission queue + per-request state
    "AdmissionQueue": "queue",
    "Request": "queue",
    "RequestState": "queue",
    # latency accounting
    "LatencyHistogram": "metrics",
    "ServeMetrics": "metrics",
    # dispatch policies
    "DispatchPolicy": "policies",
    "FCFSStatic": "policies",
    "IChAdaptive": "policies",
    "RoundRobin": "policies",
    "StepPlan": "policies",
    "default_policies": "policies",
    # the batcher + its backends/clocks + the hardened boundary
    "CircuitBreaker": "batcher",
    "ContinuousBatcher": "batcher",
    "EngineBackend": "batcher",
    "SimBackend": "batcher",
    "SimClock": "batcher",
    "StepCostModel": "batcher",
    "WallClock": "batcher",
    "make_request_factory": "batcher",
}

__all__ = sorted(_LAZY)


def __getattr__(name):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(f".{mod}", __name__), name)


def __dir__():
    return sorted(__all__)
