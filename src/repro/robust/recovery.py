"""Superstep-boundary checkpoint/restart for the sharded kernel layer
(DESIGN.md §2.11).

PR 7 gave the *dynamic* layers a fault story (steal-path reclaim inside a
run); this module gives the static sharded lowering one. The (p, S_B)
kernel grid from `core/tiling.py` executes one B-tile block per worker per
superstep, and the superstep barrier is a **consistent cut**: at barrier s
every worker has either fully executed its block `block_perm[w, s]` or not
touched it at all — there is no in-flight state to capture. A
`CheckpointLog` records exactly those facts, one `(worker, step)` entry per
completed block, and nothing else needs to be durable: the schedule itself
is a pure function of `(costs, policy, p)` and rebuilds from its inputs.

On k worker deaths, `plan_recovery` (surfaced as
`Schedule.reshard_survivors(dead=...)`):

1. collects every block NOT known complete from the checkpoint — the dead
   workers' lost blocks plus whatever anyone had not yet reached;
2. widens that set to whole **item-closed chains** (`block_chains`): a
   chain with any incomplete block is re-executed entirely, because its
   items' partial accumulations cannot be split across an old and a new
   worker without changing the fold order (§2.6 exactness);
3. re-lowers the widened set onto the p-k survivors with the SAME
   `partition_tiles` LPT used for the original lowering, producing a
   standard `WorkerShards` over the original flat payload — recovery runs
   the normal sharded kernels, just over fewer rows.

`RecoveryPlan.combine` then merges the interrupted run's output with the
re-execution's: every item belongs to exactly one chain, so the selector is
a per-item mask — items of re-executed chains take the recovered value,
everything else keeps the checkpointed value. Each item is folded by
exactly one worker in ascending tile order in BOTH pieces, which is the
§2.6 argument verbatim; the combined output is bit-identical to the
fault-free run (tests/test_recovery.py, SpMV/BFS/K-Means at k in {1,2} of
p in {2,4}).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Iterable, Optional, Union

import numpy as np

from repro.core import tiling as T


@dataclasses.dataclass
class CheckpointLog:
    """Append-only record of completed (worker, superstep) blocks.

    `mark(w, s)` means "worker w's grid step s block finished" — written at
    the superstep barrier, so an entry is only ever appended for fully
    executed blocks. The log is JSON-serializable (CI uploads it next to
    the serving journal on recovery-matrix failures) and ignores marks for
    padding steps, so `mark_through(w, n)` can blanket-mark a prefix."""

    entries: list = dataclasses.field(default_factory=list)

    def mark(self, worker: int, step: int) -> None:
        w, s = int(worker), int(step)
        if w < 0 or s < 0:
            raise ValueError(f"invalid checkpoint entry ({worker}, {step})")
        self.entries.append((w, s))

    def mark_through(self, worker: int, n_steps: int) -> None:
        """Worker completed grid steps 0..n_steps-1 (its position at the
        barrier where the run was interrupted)."""
        for s in range(int(n_steps)):
            self.mark(worker, s)

    def completed_blocks(self, shards: T.WorkerShards) -> np.ndarray:
        """Sorted block ids the log proves complete under `shards`."""
        done = set()
        for w, s in self.entries:
            if w < shards.p and s < shards.n_steps:
                b = int(shards.block_perm[w, s])
                if b >= 0:
                    done.add(b)
        return np.array(sorted(done), dtype=np.int64)

    def to_json(self) -> str:
        return json.dumps({"entries": [[int(w), int(s)]
                                       for w, s in self.entries]},
                          separators=(",", ":"))

    @classmethod
    def from_json(cls, blob: Union[str, dict]) -> "CheckpointLog":
        d = json.loads(blob) if isinstance(blob, str) else dict(blob)
        log = cls()
        for w, s in d.get("entries", ()):
            log.mark(w, s)
        return log


@dataclasses.dataclass(frozen=True)
class RecoveryPlan:
    """The re-lowering that finishes an interrupted sharded run.

    `done_shards` is the completed prefix as a partial layout over the
    ORIGINAL p workers (what the interrupted run's output provably
    contains); `shards` is the survivor re-execution layout over p_rec =
    p - k rows. Both index the original flat payload, so the standard
    sharded kernels run both without repacking. `redo_items` masks the
    items owned by re-executed chains — `combine` selects per item."""

    dead: tuple                # original worker ids lost
    survivors: tuple           # original worker ids still alive
    superstep: int             # B, unchanged from the original lowering
    keep_blocks: np.ndarray    # blocks of fully-complete chains (kept)
    redo_blocks: np.ndarray    # blocks re-executed on survivors
    lost_blocks: np.ndarray    # blocks not proven complete (pre-widening)
    shards: T.WorkerShards     # (p_rec, S_rec) survivor re-execution layout
    done_shards: T.WorkerShards  # (p, S_B) completed-prefix partial layout
    redo_items: np.ndarray     # bool (n_items,): owned by a redo chain

    @property
    def p_rec(self) -> int:
        return self.shards.p

    def combine(self, partial, recovered) -> np.ndarray:
        """Merge per-item outputs: re-executed chains' items take the
        recovered value, completed chains' items keep the checkpointed
        one. Works for any per-item-leading-axis output (SpMV y, BFS
        frontier, K-Means assignments)."""
        partial = np.asarray(partial)
        recovered = np.asarray(recovered)
        if partial.shape != recovered.shape:
            raise ValueError(f"cannot combine outputs of shapes "
                             f"{partial.shape} and {recovered.shape}")
        if partial.shape[0] != self.redo_items.size:
            raise ValueError(
                f"output leading axis {partial.shape[0]} does not match "
                f"{self.redo_items.size} items")
        mask = self.redo_items.reshape(
            (-1,) + (1,) * (partial.ndim - 1))
        return np.where(mask, recovered, partial)

    def makespan_model(self, tile_cost: np.ndarray) -> dict:
        """Barrier-time cost model for the recovered run: the completed
        prefix ran concurrently on all p workers (bounded by its slowest
        worker), then survivors execute the re-lowered remainder. Used by
        the bench to compare reshard-on-survivors against PR 7's
        steal-only reclaim inflation."""
        tile_cost = np.asarray(tile_cost, np.float64)
        t_done = float(self.done_shards.worker_cost(tile_cost).max(
            initial=0.0))
        t_redo = float(self.shards.worker_cost(tile_cost).max(initial=0.0))
        return {"t_done": t_done, "t_redo": t_redo,
                "makespan": t_done + t_redo}


def plan_recovery(tiles: T.TileSchedule, tile_cost: np.ndarray,
                  shards: T.WorkerShards, *, dead: Iterable[int],
                  checkpoint: Optional[CheckpointLog] = None) -> RecoveryPlan:
    """Build the survivor re-execution plan for an interrupted sharded run.

    Without a checkpoint the plan is worst-case: nothing is proven
    complete and every chain is re-executed on the survivors (a full
    restart at p-k, still bit-identical). See the module docstring for
    the widening argument."""
    tile_cost = np.asarray(tile_cost, np.float64)
    p, B = shards.p, shards.superstep
    Tn = int(shards.worker.size)
    n_blocks = -(-Tn // B)
    dead = tuple(sorted({int(w) for w in dead}))
    if any(w < 0 or w >= p for w in dead):
        raise ValueError(f"dead workers {dead} out of range for p={p}")
    survivors = tuple(w for w in range(p) if w not in dead)
    if not survivors:
        raise ValueError(f"all {p} workers dead: nothing can recover")

    done = (checkpoint.completed_blocks(shards) if checkpoint is not None
            else np.empty(0, np.int64))
    done_mask = np.zeros(n_blocks, dtype=bool)
    done_mask[done] = True
    lost = np.flatnonzero(~done_mask)

    # widen to item-closed chains: any chain with an incomplete block is
    # re-executed whole (its items' fold order cannot be split)
    chain = T.block_chains(tiles.item_id, B)
    redo_chains = np.unique(chain[lost]) if lost.size else np.empty(
        0, np.int64)
    redo_mask = np.isin(chain, redo_chains)
    redo = np.flatnonzero(redo_mask)
    keep = np.flatnonzero(~redo_mask)

    done_shards = _partial_layout(shards, keep, Tn, B)
    rec_shards = _relower(tiles, tile_cost, redo, len(survivors), Tn, B)

    # items owned by redo chains: the ids appearing in redo blocks' tiles
    redo_items = np.zeros(tiles.n_items, dtype=bool)
    if redo.size:
        idx = (redo[:, None] * B + np.arange(B)).reshape(-1)
        idx = idx[idx < Tn]
        ids = tiles.item_id[idx]
        redo_items[ids[ids >= 0]] = True

    return RecoveryPlan(dead=dead, survivors=survivors, superstep=B,
                        keep_blocks=keep, redo_blocks=redo,
                        lost_blocks=lost, shards=rec_shards,
                        done_shards=done_shards, redo_items=redo_items)


def _partial_layout(shards: T.WorkerShards, blocks: np.ndarray,
                    n_tiles: int, B: int) -> T.WorkerShards:
    """`shards` restricted to `blocks`: same rows, kept blocks at their
    original owner in their original ascending order."""
    keep_mask = np.zeros(-(-n_tiles // B), dtype=bool)
    keep_mask[blocks] = True
    bp = np.where((shards.block_perm >= 0)
                  & keep_mask[np.clip(shards.block_perm, 0, None)],
                  shards.block_perm, -1)
    # compact each row left so S_B shrinks to the longest kept row
    rows = [r[r >= 0] for r in bp]
    s_b = max((len(r) for r in rows), default=0) or 1
    out = np.full((shards.p, s_b), -1, np.int32)
    for w, r in enumerate(rows):
        out[w, :len(r)] = r
    return T.shards_from_block_perm(out, n_tiles, B)


def _relower(tiles: T.TileSchedule, tile_cost: np.ndarray,
             redo: np.ndarray, p_rec: int, n_tiles: int,
             B: int) -> T.WorkerShards:
    """Re-partition the redo blocks' tiles onto the survivors with the
    original `partition_tiles` LPT. The subset is processed in ascending
    block order, so subset block j IS original block redo[j] (only the
    final original block can be partial, and it sorts last); chains inside
    the subset coincide with the original chains because every redo chain
    is included whole."""
    if redo.size == 0:
        return T.shards_from_block_perm(
            np.full((p_rec, 1), -1, np.int32), n_tiles, B)
    idx = (redo[:, None] * B + np.arange(B)).reshape(-1)
    idx = idx[idx < n_tiles]
    sub_worker = T.partition_tiles(tile_cost[idx], tiles.item_id[idx],
                                   p_rec, block=B)
    block_w = sub_worker[::B]                       # per subset block
    counts = np.bincount(block_w, minlength=p_rec)
    s_rec = max(int(counts.max(initial=0)), 1)
    bp = np.full((p_rec, s_rec), -1, np.int32)
    order = np.argsort(block_w, kind="stable")      # ascending per worker
    w_sorted = block_w[order]
    pos = np.arange(order.size) - np.searchsorted(w_sorted, w_sorted)
    bp[w_sorted, pos] = redo[order].astype(np.int32)  # original block ids
    return T.shards_from_block_perm(bp, n_tiles, B)
