"""Append-only serving journal + deterministic crash resume
(DESIGN.md §2.11).

The continuous batcher (serve/batcher.py) is deterministic by
construction: every policy decision is a pure function of queue state,
every simulated step cost is a pure function of (seed, step_idx), and
every generated token is a pure function of (req_id, position) — or, on
the real engine, of the journaled prefill chunk sizes (§2.10's
chunk-invariance). So the journal does not need to checkpoint any
derived state. It records only the DRIVER events — admissions, step
plans, injected stalls, completions, idle gaps — and
`resume_from_journal` replays them through a fresh batcher. The replay
re-derives queue contents, per-request iCh bands, policy internals, and
metrics bit-identically, then verifies itself: the old journal must be
an exact prefix of the new one, event by event, or the resume is
refused with `JournalDivergence`.

Journal lines are JSON (one event per line). Python's repr-based float
serialization round-trips exactly, so event equality — including
recorded step durations — is bit-exact across a save/load cycle. A torn
final line (the crash happened mid-write) is tolerated and dropped.

Module-level imports stay numpy/stdlib-only; `repro.serve` is imported
lazily inside `resume_from_journal` to keep `repro.robust` importable
from the core executor/simulator (same discipline as
`faults.simulate_faulty`).
"""
from __future__ import annotations

import json
from typing import Optional


class JournalDivergence(RuntimeError):
    """Replaying a journal did not reproduce it (or the resume
    configuration does not match the journal's header)."""


def _canonical(ev: dict) -> str:
    """Serialize an event to its journal line, coercing numpy scalars."""
    def default(o):
        item = getattr(o, "item", None)
        if callable(item):
            return item()
        raise TypeError(f"journal events must be JSON-serializable, "
                        f"got {type(o).__name__}")
    return json.dumps(ev, sort_keys=True, separators=(",", ":"),
                      default=default)


class ServeJournal:
    """Append-only event log, optionally mirrored to a JSONL file.

    Events are stored in canonical JSON form (every `append` round-trips
    the dict through `json`), so an in-memory journal compares equal to
    the same journal loaded back from disk. When `path` is given, every
    event is written and flushed immediately — the file is crash-durable
    up to the last completed line.
    """

    def __init__(self, path: Optional[str] = None, events=None):
        self.path = None if path is None else str(path)
        self.events: list = []
        self._fh = None
        if self.path is not None:
            self._fh = open(self.path, "a", encoding="utf-8")
        if events:
            for ev in events:
                self.append(ev)

    def append(self, ev: dict) -> None:
        line = _canonical(ev)
        self.events.append(json.loads(line))
        if self._fh is not None:
            self._fh.write(line + "\n")
            self._fh.flush()

    @property
    def header(self) -> Optional[dict]:
        if self.events and self.events[0].get("ev") == "header":
            return self.events[0]
        return None

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __len__(self) -> int:
        return len(self.events)

    # ------------------------------------------------------------------ io
    def to_jsonl(self) -> str:
        return "".join(_canonical(ev) + "\n" for ev in self.events)

    @classmethod
    def from_jsonl(cls, text: str) -> "ServeJournal":
        """Parse a journal dump; a torn FINAL line is dropped (the crash
        interrupted the write), a malformed line anywhere else raises."""
        j = cls()
        lines = [ln for ln in text.split("\n") if ln.strip()]
        for k, ln in enumerate(lines):
            try:
                j.events.append(json.loads(ln))
            except json.JSONDecodeError:
                if k == len(lines) - 1:
                    break
                raise
        return j

    @classmethod
    def load(cls, path) -> "ServeJournal":
        with open(path, "r", encoding="utf-8") as fh:
            return cls.from_jsonl(fh.read())


def _replayable_prefix(events: list) -> list:
    """Drop torn tail events that belong to a step which never landed.

    A "stall" line is always followed by its "step" line within the same
    `step()` call; a journal ending in a stall means the crash hit
    between the two writes, and that step never completed — replay must
    not include it.
    """
    out = list(events)
    while out and out[-1].get("ev") == "stall":
        out.pop()
    return out


def resume_from_journal(journal, *, policy, backend=None, queue=None,
                        clock=None, faults=None, metrics=None,
                        journal_path: Optional[str] = None,
                        strict: bool = True):
    """Rebuild a `ContinuousBatcher` by replaying a journal.

    Constructs a fresh batcher (journaling into a NEW journal, mirrored
    to `journal_path` if given) with the caller-supplied components —
    which must match the crashed run's configuration; under
    ``strict=True`` the new header must equal the journaled one — and
    drives the recorded driver events through it: submits re-enter the
    admission queue, gaps advance the clock, and each recorded step runs
    through the full `step()` path with the RECORDED duration, so even
    wall-clock-measured timings replay exactly. Afterward the old
    journal must be an exact prefix of the new one or
    `JournalDivergence` is raised.

    Returns the resumed batcher: its queue, policy state, metrics, and
    step counter are bit-identical to the crashed run's at the kill
    point, and calling `run()` with the original arrival trace continues
    it (already-submitted arrivals are skipped).
    """
    from repro.serve.batcher import ContinuousBatcher, SimClock
    from repro.serve.queue import Request

    events = _replayable_prefix(journal.events)
    if not events or events[0].get("ev") != "header":
        raise JournalDivergence("journal has no header; nothing to resume")
    if clock is None:
        # replay always runs on the simulated clock so recorded times
        # land exactly; a resumed wall-clock run keeps advancing it by
        # each step's measured duration
        t0 = next((ev["t_start"] for ev in events
                   if ev.get("ev") == "run"), 0.0)
        clock = SimClock(t0)
    new = ServeJournal(path=journal_path)
    b = ContinuousBatcher(policy, queue=queue, backend=backend,
                          clock=clock, faults=faults, metrics=metrics,
                          journal=new)
    old_hdr, new_hdr = events[0], new.events[0]
    if strict and old_hdr != new_hdr:
        bad = sorted(k for k in set(old_hdr) | set(new_hdr)
                     if old_hdr.get(k) != new_hdr.get(k))
        raise JournalDivergence(
            f"resume configuration differs from the journal header on "
            f"{bad}; pass strict=False to override")
    # a wall-clock journal's step times are MEASUREMENTS, not derived
    # state: replay injects the recorded durations and snaps the clock
    # to each recorded step time (so deadline decisions replay exactly),
    # and the self-check compares events modulo the measured "t" stamps
    wall = bool(getattr(b.backend, "wall_clock", False))
    for ev in events[1:]:
        kind = ev.get("ev")
        if kind == "run":
            b._t_start = ev["t_start"]
            b._j(dict(ev))
        elif kind == "submit":
            st = b.submit(Request.from_dict(ev["req"]))
            if (st is not None) != bool(ev["admitted"]):
                raise JournalDivergence(
                    f"request {ev['req']['req_id']} admission diverged "
                    f"on replay")
        elif kind == "gap":
            b._j(dict(ev))
            b.clock.advance(ev["dt"])
        elif kind == "step":
            if not b.step(_dt_override=ev["dt"]):
                raise JournalDivergence(
                    f"journal step {ev['i']} replayed to an empty plan")
            if wall and isinstance(b.clock, SimClock):
                b.clock.jump(ev["t"])
        elif kind in ("stall", "finish"):
            pass  # re-emitted by the replayed step() itself
        else:
            raise JournalDivergence(f"unknown journal event {kind!r}")
    # ---- self-check: the old journal must be a prefix of the new one ----
    if len(new.events) < len(events):
        raise JournalDivergence(
            f"replay produced {len(new.events)} events for a journal of "
            f"{len(events)}")

    def norm(ev):
        return {k: v for k, v in ev.items() if k != "t"} if wall else ev

    for k, (a, c) in enumerate(zip(events, new.events)):
        if norm(a) != norm(c):
            raise JournalDivergence(
                f"replay diverged at event {k}: recorded {a!r}, "
                f"replayed {c!r}")
    return b
