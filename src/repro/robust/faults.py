"""Deterministic, seeded fault injection for the scheduler stack.

The paper's pitch is that iCh + work-stealing stays near-best *without
tuning* because stealing absorbs surprises; this module makes the surprises
first-class and replayable (DESIGN.md §2.9). A `FaultPlan` is a frozen,
seeded description of everything that will go wrong in one run:

* **worker deaths** — worker `w` retires permanently after completing
  `after_chunks` chunks. Its already-completed work stands; its *queued*
  work is reclaimed by survivors through the existing steal machinery
  (whole-range drain instead of steal-half, because a dead owner will
  never drain its own last item).
* **transient stalls** — worker `w` goes unresponsive for `duration`
  (seconds on the threaded executor, simulated time units in the
  discrete-event simulator) at a chunk boundary, then resumes.
* **flaky / poisoned items** — a seeded fraction of loop bodies raise
  `InjectedFault` on their first `flaky_failures` attempts (recoverable by
  the executor's per-item retry budget); `poison` items raise on EVERY
  attempt (a permanent fault that must propagate to the caller).
* **corrupted cost estimates** — multiplicative lognormal noise on the
  per-item cost array handed to schedule construction (the workload the
  stealing layer must absorb at runtime).

Everything derived from a plan is a pure function of ``(plan, n, p)`` with
its own `numpy` Generator streams, so a chaos run replays bit-identically:
the same plan yields the same flaky-item set, the same corruption, the same
death/stall points — asserted in `tests/test_robust.py`.

This module is numpy-only and imports nothing from `repro.core`, so the
simulator and executor can import it without cycles; `simulate_faulty`
imports the simulator lazily.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import threading
from typing import Callable, Optional, Union

import numpy as np

_NEVER = 1 << 62  # "after more chunks than any run dispatches"


class InjectedFault(RuntimeError):
    """Raised by a ChaosBody-wrapped loop body at a planned fault site."""


class FaultError(RuntimeError):
    """Unrecoverable fault outcome: work remained but no live worker could
    execute it (e.g. every worker died), or a static assignment cannot
    reclaim a dead worker's share."""


@dataclasses.dataclass(frozen=True)
class Death:
    """Worker `worker` retires right before dispatching its
    (`after_chunks`+1)-th chunk; completed chunks stand, queued work is
    reclaimed by survivors."""

    worker: int
    after_chunks: int = 0

    def __post_init__(self):
        if self.worker < 0:
            raise ValueError(f"worker must be >= 0, got {self.worker}")
        if self.after_chunks < 0:
            raise ValueError(
                f"after_chunks must be >= 0, got {self.after_chunks}")


@dataclasses.dataclass(frozen=True)
class Stall:
    """Worker `worker` goes unresponsive for `duration` at the chunk
    boundary after completing `after_chunks` chunks, then resumes (the
    executor's watchdog may declare it dead in the meantime, in which case
    its queue is reclaimed by survivors and the worker retires on wake)."""

    worker: int
    after_chunks: int = 0
    duration: float = 1.0

    def __post_init__(self):
        if self.worker < 0:
            raise ValueError(f"worker must be >= 0, got {self.worker}")
        if self.after_chunks < 0:
            raise ValueError(
                f"after_chunks must be >= 0, got {self.after_chunks}")
        if self.duration < 0:
            raise ValueError(f"duration must be >= 0, got {self.duration}")



@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """One seeded, frozen chaos scenario; every derived stream replays
    bit-identically for the same plan."""

    seed: int = 0
    deaths: tuple = ()       # tuple[Death, ...] (bare (w, k) pairs coerced)
    stalls: tuple = ()       # tuple[Stall, ...] (bare tuples coerced)
    flaky_frac: float = 0.0  # fraction of items that fail transiently
    flaky_failures: int = 1  # failed attempts per flaky item before success
    poison: tuple = ()       # item indices that fail on EVERY attempt
    cost_noise: float = 0.0  # lognormal sigma of estimate corruption

    def __post_init__(self):
        object.__setattr__(self, "deaths", tuple(
            d if isinstance(d, Death) else Death(*d) for d in self.deaths))
        object.__setattr__(self, "stalls", tuple(
            s if isinstance(s, Stall) else Stall(*s) for s in self.stalls))
        object.__setattr__(self, "poison",
                           tuple(int(i) for i in self.poison))
        if not (0.0 <= self.flaky_frac <= 1.0):
            raise ValueError(
                f"flaky_frac must be in [0, 1], got {self.flaky_frac}")
        if self.flaky_failures < 1:
            raise ValueError(
                f"flaky_failures must be >= 1, got {self.flaky_failures}")
        if self.cost_noise < 0:
            raise ValueError(
                f"cost_noise must be >= 0, got {self.cost_noise}")

    # ------------------------------------------------------ derived streams
    def validate_workers(self, p: int) -> None:
        """Reject plans naming workers a p-worker run does not have —
        a silently ignored death would make a chaos test vacuously green."""
        for f in (*self.deaths, *self.stalls):
            if f.worker >= p:
                raise ValueError(
                    f"fault plan names worker {f.worker} but the run has "
                    f"p={p} workers")

    def death_after(self, p: int) -> np.ndarray:
        """(p,) chunk count after which each worker dies (huge = never)."""
        self.validate_workers(p)
        after = np.full(p, _NEVER, dtype=np.int64)
        for d in self.deaths:
            after[d.worker] = min(after[d.worker], d.after_chunks)
        return after

    def stalls_for(self, p: int) -> list:
        """Per-worker stall lists, each sorted by `after_chunks`."""
        self.validate_workers(p)
        per: list[list[Stall]] = [[] for _ in range(p)]
        for s in self.stalls:
            per[s.worker].append(s)
        for lst in per:
            lst.sort(key=lambda s: s.after_chunks)
        return per

    def flaky_items(self, n: int) -> np.ndarray:
        """Sorted item indices chosen to fail transiently (seeded)."""
        k = int(round(self.flaky_frac * n))
        if k == 0:
            return np.empty(0, dtype=np.int64)
        rng = np.random.default_rng(self.seed)
        return np.sort(rng.choice(n, size=k, replace=False)).astype(np.int64)

    def corrupt_costs(self, costs: np.ndarray) -> np.ndarray:
        """Cost estimates under multiplicative lognormal corruption —
        what schedule construction sees when its cost model is wrong.
        Identity (a copy) when `cost_noise` is 0."""
        costs = np.asarray(costs, np.float64)
        if self.cost_noise == 0.0:
            return costs.copy()
        rng = np.random.default_rng(self.seed + 1)
        return costs * np.exp(
            self.cost_noise * rng.standard_normal(costs.shape))

    def wrap_body(self, body: Callable[[int], None], n: int):
        """`body` with this plan's flaky/poison faults injected; returns
        `body` unchanged when the plan injects no body faults."""
        if self.flaky_frac == 0.0 and not self.poison:
            return body
        return ChaosBody(self, n, body)

    @property
    def has_body_faults(self) -> bool:
        return self.flaky_frac > 0.0 or bool(self.poison)

    # ------------------------------------------------------ serialization
    def to_json(self) -> str:
        """Canonical JSON for this plan (sorted keys, no whitespace) —
        the journal/CI artifact form. `from_json(to_json(p)) == p` for
        every valid plan, and equal plans serialize to equal strings, so
        `fingerprint()` is a stable identity."""
        return json.dumps({
            "seed": int(self.seed),
            "deaths": [[d.worker, d.after_chunks] for d in self.deaths],
            "stalls": [[s.worker, s.after_chunks, s.duration]
                       for s in self.stalls],
            "flaky_frac": float(self.flaky_frac),
            "flaky_failures": int(self.flaky_failures),
            "poison": list(self.poison),
            "cost_noise": float(self.cost_noise),
        }, sort_keys=True, separators=(",", ":"))

    @classmethod
    def from_json(cls, blob: Union[str, dict]) -> "FaultPlan":
        """Inverse of `to_json` (also accepts an already-parsed dict).
        Round-trips through `__post_init__`, so invalid serialized plans
        are rejected with the same errors as invalid constructor args."""
        d = json.loads(blob) if isinstance(blob, str) else dict(blob)
        return cls(
            seed=int(d.get("seed", 0)),
            deaths=tuple(Death(int(w), int(a))
                         for w, a in d.get("deaths", ())),
            stalls=tuple(Stall(int(w), int(a), float(dur))
                         for w, a, dur in d.get("stalls", ())),
            flaky_frac=float(d.get("flaky_frac", 0.0)),
            flaky_failures=int(d.get("flaky_failures", 1)),
            poison=tuple(d.get("poison", ())),
            cost_noise=float(d.get("cost_noise", 0.0)),
        )

    def fingerprint(self) -> str:
        """Short stable content hash of the canonical JSON. A journal
        stamps this in its header so resume can refuse to continue under
        a different chaos plan than the one the prefix ran under."""
        return hashlib.sha256(self.to_json().encode()).hexdigest()[:16]


class FaultClock:
    """Per-run fault bookkeeping shared by the simulator and the threaded
    executor: when each worker dies, which stalls it has left, and how many
    chunks it has completed — the layer-independent fault clock (faults
    trigger at chunk boundaries in BOTH layers, which is what makes one
    plan replayable across them)."""

    __slots__ = ("death_after", "stalls", "stall_idx", "chunks_done")

    def __init__(self, plan: FaultPlan, p: int):
        self.death_after = plan.death_after(p)
        self.stalls = plan.stalls_for(p)
        self.stall_idx = [0] * p
        self.chunks_done = np.zeros(p, dtype=np.int64)

    def dies_now(self, w: int) -> bool:
        return bool(self.chunks_done[w] >= self.death_after[w])

    def pending_stall(self, w: int) -> Optional[Stall]:
        """The next unconsumed stall due at (or before) w's current chunk
        count, consumed on read; None when w runs undisturbed."""
        i = self.stall_idx[w]
        lst = self.stalls[w]
        if i < len(lst) and lst[i].after_chunks <= self.chunks_done[w]:
            self.stall_idx[w] = i + 1
            return lst[i]
        return None


class ChaosBody:
    """A loop body wrapped with planned faults: flaky items raise
    `InjectedFault` on their first `flaky_failures` attempts then succeed
    (the executor's retry budget is the recovery path); poisoned items
    raise on every attempt. Thread-safe; `injected` counts faults fired."""

    def __init__(self, plan: FaultPlan, n: int, body: Callable[[int], None]):
        self._body = body
        self._lock = threading.Lock()
        self._left = {int(i): plan.flaky_failures
                      for i in plan.flaky_items(n)}
        self._poison = frozenset(plan.poison)
        self.injected = 0

    def __call__(self, i: int):
        i = int(i)
        if i in self._poison:
            with self._lock:
                self.injected += 1
            raise InjectedFault(f"poisoned item {i}")
        fire = False
        with self._lock:
            left = self._left.get(i, 0)
            if left > 0:
                self._left[i] = left - 1
                self.injected += 1
                fire = True
        if fire:
            raise InjectedFault(f"transient fault at item {i}")
        return self._body(i)


@dataclasses.dataclass
class FaultReport:
    """A chaos run next to its fault-free twin (same costs, policy, p,
    time model, simulator seed — only the plan differs)."""

    faulty: object  # SimResult
    clean: object   # SimResult
    plan: FaultPlan

    @property
    def inflation(self) -> float:
        """Makespan inflation vs the fault-free run (>= ~1.0: losing
        workers can only slow a run down, modulo steal-path luck)."""
        if self.clean.makespan <= 0:
            return 1.0
        return float(self.faulty.makespan / self.clean.makespan)


def simulate_faulty(costs, p: int, policy, plan: FaultPlan, *,
                    params=None, record_chunks: bool = False,
                    record_assignment: bool = False) -> FaultReport:
    """Run the discrete-event simulator twice — fault-free and under
    `plan` — and return both results with the makespan inflation. Both
    runs are deterministic, so the report replays bit-identically."""
    from repro.core import simulator as S  # lazy: avoids an import cycle

    prm = params if params is not None else S.SimParams()
    clean = S.simulate(np.asarray(costs, np.float64), int(p), policy, prm,
                       record_chunks=record_chunks,
                       record_assignment=record_assignment)
    faulty = S.simulate(np.asarray(costs, np.float64), int(p), policy, prm,
                        record_chunks=record_chunks,
                        record_assignment=record_assignment, faults=plan)
    return FaultReport(faulty=faulty, clean=clean, plan=plan)
