"""`repro.robust` — deterministic fault injection and recovery
(DESIGN.md §2.9).

One seeded `FaultPlan` spans all three execution layers: the discrete-event
simulator replays it as fault events (`core/simulator.py`, `faults=`), the
threaded executor survives it with supervised workers (`core/executor.py`:
retry budgets, watchdog, dead-deque reclaim), and `Schedule.replay_faulty`
reports the makespan inflation a chaos scenario costs a constructed
schedule. Everything derived from a plan is a pure function of its seed, so
chaos runs replay bit-identically.
"""
from .faults import (ChaosBody, Death, FaultClock, FaultError, FaultPlan,
                     FaultReport, InjectedFault, Stall, simulate_faulty)
# recovery/journal import AFTER faults: both pull in repro.core/serve
# modules that import repro.robust.faults back (submodule import, safe
# once .faults is bound above)
from .recovery import CheckpointLog, RecoveryPlan, plan_recovery
from .journal import JournalDivergence, ServeJournal, resume_from_journal

__all__ = ["ChaosBody", "CheckpointLog", "Death", "FaultClock",
           "FaultError", "FaultPlan", "FaultReport", "InjectedFault",
           "JournalDivergence", "RecoveryPlan", "ServeJournal", "Stall",
           "plan_recovery", "resume_from_journal", "simulate_faulty"]
