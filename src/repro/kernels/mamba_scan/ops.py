"""Jitted wrapper: pads S to the chunk multiple and dispatches."""
import functools

import jax
import jax.numpy as jnp

from .mamba_scan import mamba_scan


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mamba_scan_op(q, k, v, log_a, *, chunk: int = 128, interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    S = q.shape[1]
    pad = (-S) % chunk
    if pad:
        zf = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q, k, v = zf(q), zf(k), zf(v)
        log_a = jnp.pad(log_a, ((0, 0), (0, pad), (0, 0)))
    y, s = mamba_scan(q, k, v, log_a, chunk=chunk, interpret=interpret)
    return y[:, :S], s
