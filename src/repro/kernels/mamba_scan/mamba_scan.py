"""Pallas TPU chunked SSD scan (Mamba2 / mLSTM shared algebra).

Computes, per (batch, head):   S_t = a_t * S_{t-1} + k_t (x) v_t,
                               y_t = q_t . S_t
in chunked form: grid = (B*H, n_chunks) with chunks innermost; the (N, Pd)
state lives in fp32 VMEM scratch and persists across the sequential chunk
steps (TPU grids execute in row-major order — the TPU-native replacement
for the sequential recurrence, DESIGN.md §2). Per chunk the intra term is
two (Q,Q)/(Q,N) matmuls on the MXU; chunk length Q=128/256 keeps all
operands 128-aligned.

Matches models.ssm.chunked_gated_scan (the oracle in ref.py) bit-for-bit up
to fp32 accumulation order.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(q_ref, k_ref, v_ref, la_ref, y_ref, s_final_ref, state_scr, *,
                n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    q = q_ref[0].astype(jnp.float32)   # (Q, N)
    k = k_ref[0].astype(jnp.float32)   # (Q, N)
    v = v_ref[0].astype(jnp.float32)   # (Q, Pd)
    la = la_ref[0].astype(jnp.float32)  # (Q,)
    l = jnp.cumsum(la)                 # inclusive in-chunk decay
    total = l[-1]

    # intra-chunk: s_ij = (q_i . k_j) exp(l_i - l_j), j <= i
    s_qk = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
    dec = jnp.exp(jnp.clip(l[:, None] - l[None, :], -60.0, 0.0))
    Q = q.shape[0]
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    s_qk = jnp.where(jj <= ii, s_qk * dec, 0.0)
    y = jax.lax.dot(s_qk, v, preferred_element_type=jnp.float32)

    # inter-chunk: y_i += exp(l_i) q_i . S_prev   (state (N, Pd))
    y = y + jax.lax.dot(q, state_scr[...],
                        preferred_element_type=jnp.float32) * jnp.exp(l)[:, None]

    # state update: S = exp(total) S_prev + sum_j exp(total - l_j) k_j (x) v_j
    w = jnp.exp(jnp.clip(total - l, -60.0, 0.0))
    state_scr[...] = state_scr[...] * jnp.exp(total) + jax.lax.dot_general(
        k * w[:, None], v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    y_ref[0, ...] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        s_final_ref[0, ...] = state_scr[...]


def mamba_scan(q, k, v, log_a, *, chunk: int = 128, interpret: bool = False):
    """q,k (B,S,H,N); v (B,S,H,Pd); log_a (B,S,H) <= 0.
    Returns (y (B,S,H,Pd), final_state (B,H,N,Pd) fp32).
    S must be a multiple of `chunk` (callers pad)."""
    B, S, H, N = q.shape
    Pd = v.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    def bh(t):  # (B,S,H,*) -> (B*H, S, *)
        return t.transpose(0, 2, 1, 3).reshape(B * H, S, t.shape[-1])

    qr, kr, vr = bh(q), bh(k), bh(v)
    lar = log_a.transpose(0, 2, 1).reshape(B * H, S)

    kernel = functools.partial(_ssd_kernel, n_chunks=nc)
    y, s_final = pl.pallas_call(
        kernel,
        grid=(B * H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, Pd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk), lambda b, c: (b, c)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, Pd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, N, Pd), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, S, Pd), v.dtype),
            jax.ShapeDtypeStruct((B * H, N, Pd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, Pd), jnp.float32)],
        interpret=interpret,
    )(qr, kr, vr, lar)
    y = y.reshape(B, H, S, Pd).transpose(0, 2, 1, 3)
    return y, s_final.reshape(B, H, N, Pd)
