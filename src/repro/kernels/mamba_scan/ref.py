"""Oracle: the XLA chunked scan from models.ssm (itself validated against a
step-by-step sequential recurrence in tests/test_ssm)."""
from ...models.ssm import chunked_gated_scan


def ssd_ref(q, k, v, log_a, chunk: int = 128):
    y, state = chunked_gated_scan(q, k, v, log_a, chunk=chunk)
    # kernel state layout is (B,H,N,Pd); oracle returns (B,H,Pd,N)
    return y, state.swapaxes(-1, -2)
