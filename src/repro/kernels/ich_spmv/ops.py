"""Deprecated shim: `IChSpmv` is now a thin wrapper over the `repro.sched`
registry ("spmv" workload). Use the facade instead:

    from repro.sched import default_scheduler
    spmv = default_scheduler().build("spmv", indptr, indices, data)

The shim produces bit-identical packing/outputs (same construction path,
same kernel) and shares the facade's schedule cache; it emits a
`DeprecationWarning` and will be removed once downstream callers migrate.
"""
import warnings

from repro.core import policies as P
from repro.sched.api import default_scheduler
from repro.sched.defaults import ICH_EPS
from repro.sched.kernels import SpmvOp


class IChSpmv(SpmvOp):
    """Pack once (iCh schedule construction), apply many times."""

    def __init__(self, indptr, indices, data, *, rows_per_tile: int = 8,
                 eps: float = ICH_EPS, width: int = None):
        warnings.warn(
            "IChSpmv is deprecated; use repro.sched: "
            "default_scheduler().build('spmv', indptr, indices, data)",
            DeprecationWarning, stacklevel=2)
        built = default_scheduler().build(
            "spmv", indptr, indices, data, policy=P.ich(eps),
            rows_per_tile=rows_per_tile, width=width)
        self.__dict__.update(built.__dict__)
