"""Public wrapper: host-side iCh schedule construction + jitted kernel call.

Schedule construction is the vectorized `core.tiling` path (array programs,
no per-row Python loops) and the kernel accumulates through the shared
`core.segmented` windowed epilogue, so both the pack-once and apply-many
sides stay array-speed at production row counts.
"""
import functools

import jax
import numpy as np

from .ich_spmv import ich_spmv, ich_tile_width, pack_tiles


class IChSpmv:
    """Pack once (iCh schedule construction), apply many times."""

    def __init__(self, indptr, indices, data, *, rows_per_tile: int = 8,
                 eps: float = 0.33, width: int = None):
        self.n_rows = len(indptr) - 1
        vals, cols, rowid, W = pack_tiles(
            np.asarray(indptr), np.asarray(indices), np.asarray(data),
            rows_per_tile=rows_per_tile, width=width, eps=eps)
        self.width = W
        self.vals = jax.numpy.asarray(vals)
        self.cols = jax.numpy.asarray(cols)
        self.rowid = jax.numpy.asarray(rowid)
        self._jitted = {}  # interpret mode -> jitted spmv (compile once)

    def __call__(self, x, interpret: bool | None = None):
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        if interpret not in self._jitted:
            self._jitted[interpret] = jax.jit(functools.partial(
                ich_spmv, n_rows=self.n_rows, interpret=interpret))
        return self._jitted[interpret](self.vals, self.cols, self.rowid, x)
