"""iCh-scheduled segmented SpMV — the paper's technique at the kernel level.

TPU adaptation (DESIGN.md §2): a TPU grid is static, so iCh's *runtime*
chunk adaptation becomes *schedule construction*. The host packs CSR rows
into fixed-shape work tiles (R rows x W nnz slots) where the tile width W is
chosen by the paper's band classification over the row-nnz distribution
(`ich_tile_width`), and rows whose nnz exceeds W are SPLIT across several
tiles — the work-stealing analogue: no tile (chunk) can be overloaded, heavy
rows' overflow migrates to later tiles exactly like stolen iterations.

Two kernel realizations share the body:

* `ich_spmv` — the sequential reference grid: grid = (T,), one tile per
  step, read-modify-write accumulation into the single output vector (grid
  steps execute in order on one TPU core, so the RMW is safe).
* `ich_spmv_sharded` — the production 2D grid (DESIGN.md §2.6): the
  schedule's parallelism p is lowered onto the accelerator as a
  worker-major grid (p, S_B). Tiles are cost-partitioned across p workers
  at superstep-block granularity (`core.tiling.partition_tiles`,
  item-closed so no row spans workers) and each grid step processes a
  SUPERSTEP of B tiles — fetched as one aligned (B, R, W) block straight
  out of the FLAT payload via a prefetched data-dependent block index
  (`WorkerShards.kernel_block_ids`; lowering moves no payload bytes) —
  with B in-order windowed RMWs, amortizing per-step dispatch/prefetch
  overhead. The payload fetch is DOUBLE-BUFFERED (`core/pipelining.py`):
  step j+1's blocks DMA into the spare VMEM slot while step j computes,
  restoring the fetch/compute overlap Mosaic cannot derive for a
  data-dependent block index. Every worker accumulates into its own row of a (p, n_rows)
  output block (no cross-worker races; the worker dimension is declared
  "parallel" so Mosaic may split it across TPU cores), and a host-side
  pairwise tree reduce (`core.segmented.worker_reduce`) folds the
  accumulators — bit-identical to the sequential grid because each row is
  owned by exactly one worker and all others contribute exact zeros.

x is kept whole in VMEM (fits for n <= ~1M fp32). The per-tile
accumulation routes through the shared segmented-reduction layer
(`core/segmented.py`): a one-hot matmul folds the R partial sums into one
length-R output window instead of R scalar read-modify-writes.
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.pipelining import (double_buffer_scratch,
                                   fetch_double_buffered)
from repro.core.segmented import (emit_step_cost, segmented_apply,
                                  segmented_apply_batch, worker_reduce)
from repro.core.tiling import build_schedule, ich_tile_width, pack_csr
from repro.sched.defaults import ICH_EPS

__all__ = ["ich_tile_width", "pack_tiles", "ich_spmv", "ich_spmv_sharded"]


def pack_tiles(indptr: np.ndarray, indices: np.ndarray, data: np.ndarray,
               *, rows_per_tile: int = 8, width: int = None,
               eps: float = ICH_EPS):
    """CSR -> (values (T,R,W), cols (T,R,W), rowid (T,R)) with row splitting.

    Thin wrapper over the shared schedule-construction layer
    (`core.tiling`): rows are cut into width-W segments; segments are packed
    greedily into tiles of R row-slots each (a segment of a heavy row may
    land in any tile => tile work is uniform at R*W slots).
    """
    row_nnz = np.diff(indptr)
    sched = build_schedule(row_nnz, rows_per_tile=rows_per_tile,
                           width=width, eps=eps)
    vals, cols = pack_csr(indptr, indices, data, sched)
    return vals, cols, sched.item_id, sched.width


def _spmv_kernel(rowid_ref, vals_ref, cols_ref, x_ref, out_ref):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    vals = vals_ref[0]  # (R, W)
    cols = cols_ref[0]
    x = x_ref[...]  # (n,)
    partial = jnp.sum(vals * x[cols], axis=1)  # (R,)
    rows = rowid_ref[t]  # (R,) SMEM scalars for this tile
    # rows may repeat across tiles (split rows): sum-accumulate through the
    # shared segmented epilogue (one windowed RMW, padding masked inside)
    segmented_apply(out_ref, rows, partial, combine="add")


def ich_spmv(vals, cols, rowid, x, n_rows: int, *, interpret: bool = False):
    """Sequential reference grid. vals/cols (T,R,W); rowid (T,R); x (n,).
    Returns y (n_rows,)."""
    T, R, W = vals.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,  # rowid prefetched to SMEM (the schedule)
        grid=(T,),
        in_specs=[
            pl.BlockSpec((1, R, W), lambda t, rowid: (t, 0, 0)),
            pl.BlockSpec((1, R, W), lambda t, rowid: (t, 0, 0)),
            pl.BlockSpec(x.shape, lambda t, rowid: (0,)),  # x whole in VMEM
        ],
        out_specs=pl.BlockSpec((n_rows,), lambda t, rowid: (0,)),
    )
    return pl.pallas_call(
        _spmv_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_rows,), x.dtype),
        interpret=interpret,
    )(rowid, vals, cols, x)


def _spmv_sharded_body(rowid_ref, blkid_ref, vals_hbm, cols_hbm, slotc_hbm,
                       x_ref, out_ref, cost_ref, bufs, sems, *, S: int,
                       B: int):
    w, j = pl.program_id(0), pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)
        if cost_ref is not None:
            cost_ref[...] = jnp.zeros_like(cost_ref)

    # double-buffered data-dependent fetch: superstep s+1's blocks stream
    # in while s computes (core/pipelining.py); same block bytes in the
    # same order as the single-buffered lowering, so results are
    # bit-identical to the sequential grid
    hbm = (vals_hbm, cols_hbm) if slotc_hbm is None \
        else (vals_hbm, cols_hbm, slotc_hbm)
    blocks = fetch_double_buffered(list(zip(hbm, bufs, sems)),
                                   blkid_ref, w, j, B=B)
    vals = blocks[0]  # (B, R, W): one superstep of this worker's shard
    cols = blocks[1]
    x = x_ref[...]  # (n,)
    partial = jnp.sum(vals * x[cols], axis=2)  # (B, R)
    rows = rowid_ref[pl.ds(w * S + j * B, B)]  # (B, R) SMEM scalars
    # B in-order windowed RMWs into THIS worker's accumulator row — the
    # same fold order the sequential grid uses for these tiles
    segmented_apply_batch(out_ref, rows, partial, combine="add")
    if cost_ref is not None:
        emit_step_cost(cost_ref, rows, blocks[2], j)


def _spmv_kernel_sharded(rowid_ref, blkid_ref, vals_hbm, cols_hbm, x_ref,
                         out_ref, vbuf, cbuf, vsem, csem, *, S: int, B: int):
    _spmv_sharded_body(rowid_ref, blkid_ref, vals_hbm, cols_hbm, None,
                       x_ref, out_ref, None, (vbuf, cbuf), (vsem, csem),
                       S=S, B=B)


def _spmv_kernel_sharded_cost(rowid_ref, blkid_ref, vals_hbm, cols_hbm,
                              slotc_hbm, x_ref, out_ref, cost_ref, vbuf,
                              cbuf, sbuf, vsem, csem, ssem, *, S: int,
                              B: int):
    _spmv_sharded_body(rowid_ref, blkid_ref, vals_hbm, cols_hbm, slotc_hbm,
                       x_ref, out_ref, cost_ref, (vbuf, cbuf, sbuf),
                       (vsem, csem, ssem), S=S, B=B)


def ich_spmv_sharded(vals, cols, rowid, blkid, x, n_rows: int, p: int,
                     superstep: int, *, slot_cost=None,
                     interpret: bool = False):
    """Worker-sharded 2D grid. vals/cols (T_pad, R, W): the FLAT packed
    payload with T padded to whole supersteps (`pack_csr(...,
    pad_tiles_to=B)`); rowid (p*S, R) and blkid (p*S_B,) from
    `core.tiling.WorkerShards` (`shard_item_id` / `kernel_block_ids`);
    x (n,). Returns y (n_rows,).

    With `slot_cost` — the (T_pad, R) per-slot scheduled-cost stream
    (`Schedule.slot_cost` padded to T_pad) — the kernel additionally emits
    a per-worker, per-superstep cost output (p, S_B) and returns
    (y, costs): the measured-cost feedback the refiner folds back into
    per-item estimates (DESIGN.md §2.7). Padding steps emit 0, so per-
    worker sums account exactly the schedule's tile costs."""
    T_pad, R, W = vals.shape
    p, B = int(p), int(superstep)
    n_steps = int(blkid.shape[0]) // p
    S = n_steps * B
    if blkid.shape[0] != p * n_steps or rowid.shape[0] != p * S or T_pad % B:
        raise ValueError(f"shard layout mismatch: blkid {blkid.shape}, "
                         f"rowid {rowid.shape}, T_pad={T_pad}, p={p}, B={B}")
    emit = slot_cost is not None
    # data-dependent superstep payloads stay whole in ANY memory; the
    # kernel double-buffers them through 2-slot VMEM scratch so step j+1's
    # blocks stream in while step j computes (core/pipelining.py)
    in_specs = [
        pl.BlockSpec(memory_space=pltpu.ANY),  # vals (T_pad, R, W)
        pl.BlockSpec(memory_space=pltpu.ANY),  # cols (T_pad, R, W)
    ]
    db_streams = [((R, W), vals.dtype), ((R, W), jnp.int32)]
    out_specs = pl.BlockSpec((1, n_rows), lambda w, j, rowid, blk: (w, 0))
    out_shape = jax.ShapeDtypeStruct((p, n_rows), x.dtype)
    if emit:
        kernel = functools.partial(_spmv_kernel_sharded_cost, S=S, B=B)
        in_specs.append(pl.BlockSpec(memory_space=pltpu.ANY))  # slot costs
        db_streams.append(((R,), jnp.float32))
        out_specs = [out_specs, pl.BlockSpec(
            (1, n_steps), lambda w, j, rowid, blk: (w, 0))]
        out_shape = [out_shape,
                     jax.ShapeDtypeStruct((p, n_steps), jnp.float32)]
    else:
        kernel = functools.partial(_spmv_kernel_sharded, S=S, B=B)
    in_specs.append(pl.BlockSpec(x.shape, lambda w, j, rowid, blk: (0,)))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # sharded rowid + block ids to SMEM
        grid=(p, n_steps),
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=double_buffer_scratch(B, db_streams),
    )
    call = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        # workers are independent (item-closed partition): the shard
        # dimension may run concurrently across TPU cores / megacore
        compiler_params=None if interpret else pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )
    if emit:
        acc, costs = call(rowid, blkid, vals, cols,
                          jnp.asarray(slot_cost, jnp.float32), x)
        return worker_reduce(acc, "add"), costs
    acc = call(rowid, blkid, vals, cols, x)
    return worker_reduce(acc, "add")
