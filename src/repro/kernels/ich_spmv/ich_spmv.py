"""iCh-scheduled segmented SpMV — the paper's technique at the kernel level.

TPU adaptation (DESIGN.md §2): a TPU grid is static, so iCh's *runtime*
chunk adaptation becomes *schedule construction*. The host packs CSR rows
into fixed-shape work tiles (R rows x W nnz slots) where the tile width W is
chosen by the paper's band classification over the row-nnz distribution
(`ich_tile_width`), and rows whose nnz exceeds W are SPLIT across several
tiles — the work-stealing analogue: no tile (chunk) can be overloaded, heavy
rows' overflow migrates to later tiles exactly like stolen iterations.

The kernel is a persistent-grid pallas_call: grid = (n_tiles,); each step
loads its (R, W) value/column tile from HBM into VMEM, gathers x, reduces
over W, and ACCUMULATES into the output rows (grid steps execute
sequentially on a TPU core, so read-modify-write of the output is safe).
x is kept whole in VMEM (fits for n <= ~1M fp32).
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu



def ich_tile_width(row_nnz: np.ndarray, eps: float = 0.33,
                   min_w: int = 8, max_w: int = 512) -> int:
    """Pick the tile width with the paper's band (eqs. 1-3, 8).

    W = the band's UPPER edge mu*(1+eps), rounded up to a power of two:
    every "normal"-classified row (within mu +- eps*mu) fits in one segment;
    only "high" rows split across tiles — the work-stealing analogue (their
    overflow migrates to later tiles). A multiplicative walk (adapt_d per
    chunk) has no equilibrium on a static distribution — measured in
    benchmarks/bench_ich_spmv.py — so schedule construction uses the band
    directly; the runtime walk remains correct where k_i is cumulative
    (simulator/executor/serving).
    """
    mu = float(np.mean(row_nnz))
    upper = mu * (1.0 + eps)
    w = 2 ** int(np.ceil(np.log2(max(upper, 1.0))))
    return int(min(max(w, min_w), max_w))


def pack_tiles(indptr: np.ndarray, indices: np.ndarray, data: np.ndarray,
               *, rows_per_tile: int = 8, width: int = None, eps: float = 0.33):
    """CSR -> (values (T,R,W), cols (T,R,W), rowid (T,R)) with row splitting.

    Rows are cut into width-W segments; segments are packed greedily into
    tiles of R row-slots each (a segment of a heavy row may land in any
    tile => tile work is uniform at R*W slots).
    """
    n = len(indptr) - 1
    row_nnz = np.diff(indptr)
    W = width or ich_tile_width(row_nnz, eps)
    R = rows_per_tile
    segs = []  # (row, start_in_row, length)
    for r in range(n):
        nnz = int(row_nnz[r])
        for s in range(0, max(nnz, 1), W):
            segs.append((r, s, min(W, nnz - s) if nnz else 0))
    T = -(-len(segs) // R)
    vals = np.zeros((T, R, W), data.dtype)
    cols = np.zeros((T, R, W), np.int32)
    rowid = np.full((T, R), -1, np.int32)
    for i, (r, s, ln) in enumerate(segs):
        t, j = divmod(i, R)
        rowid[t, j] = r
        if ln > 0:
            base = indptr[r] + s
            vals[t, j, :ln] = data[base:base + ln]
            cols[t, j, :ln] = indices[base:base + ln]
    return vals, cols, rowid, W


def _spmv_kernel(rowid_ref, vals_ref, cols_ref, x_ref, out_ref, *, n_rows: int):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    vals = vals_ref[0]  # (R, W)
    cols = cols_ref[0]
    x = x_ref[...]  # (n,)
    partial = jnp.sum(vals * x[cols], axis=1)  # (R,)
    rows = rowid_ref[t]  # (R,) SMEM scalars for this tile
    # accumulate per row-slot; rows may repeat across tiles (split rows)
    for j in range(rows.shape[0]):
        r = jnp.clip(rows[j], 0, n_rows - 1)
        inc = jnp.where(rows[j] >= 0, partial[j], 0.0)
        out_ref[r] = out_ref[r] + inc


def ich_spmv(vals, cols, rowid, x, n_rows: int, *, interpret: bool = False):
    """vals/cols (T,R,W); rowid (T,R); x (n,). Returns y (n_rows,)."""
    T, R, W = vals.shape
    kernel = functools.partial(_spmv_kernel, n_rows=n_rows)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,  # rowid prefetched to SMEM (the schedule)
        grid=(T,),
        in_specs=[
            pl.BlockSpec((1, R, W), lambda t, rowid: (t, 0, 0)),
            pl.BlockSpec((1, R, W), lambda t, rowid: (t, 0, 0)),
            pl.BlockSpec(x.shape, lambda t, rowid: (0,)),  # x whole in VMEM
        ],
        out_specs=pl.BlockSpec((n_rows,), lambda t, rowid: (0,)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_rows,), x.dtype),
        interpret=interpret,
    )(rowid, vals, cols, x)
