"""iCh-scheduled segmented SpMV — the paper's technique at the kernel level.

TPU adaptation (DESIGN.md §2): a TPU grid is static, so iCh's *runtime*
chunk adaptation becomes *schedule construction*. The host packs CSR rows
into fixed-shape work tiles (R rows x W nnz slots) where the tile width W is
chosen by the paper's band classification over the row-nnz distribution
(`ich_tile_width`), and rows whose nnz exceeds W are SPLIT across several
tiles — the work-stealing analogue: no tile (chunk) can be overloaded, heavy
rows' overflow migrates to later tiles exactly like stolen iterations.

The kernel is a persistent-grid pallas_call: grid = (n_tiles,); each step
loads its (R, W) value/column tile from HBM into VMEM, gathers x, reduces
over W, and ACCUMULATES into the output rows (grid steps execute
sequentially on a TPU core, so read-modify-write of the output is safe).
x is kept whole in VMEM (fits for n <= ~1M fp32). The per-tile accumulation
routes through the shared segmented-reduction layer (`core/segmented.py`):
a one-hot matmul folds the R partial sums into one length-R output window
instead of R scalar read-modify-writes.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.segmented import segmented_apply
from repro.core.tiling import build_schedule, ich_tile_width, pack_csr
from repro.sched.defaults import ICH_EPS

__all__ = ["ich_tile_width", "pack_tiles", "ich_spmv"]


def pack_tiles(indptr: np.ndarray, indices: np.ndarray, data: np.ndarray,
               *, rows_per_tile: int = 8, width: int = None,
               eps: float = ICH_EPS):
    """CSR -> (values (T,R,W), cols (T,R,W), rowid (T,R)) with row splitting.

    Thin wrapper over the shared schedule-construction layer
    (`core.tiling`): rows are cut into width-W segments; segments are packed
    greedily into tiles of R row-slots each (a segment of a heavy row may
    land in any tile => tile work is uniform at R*W slots).
    """
    row_nnz = np.diff(indptr)
    sched = build_schedule(row_nnz, rows_per_tile=rows_per_tile,
                           width=width, eps=eps)
    vals, cols = pack_csr(indptr, indices, data, sched)
    return vals, cols, sched.item_id, sched.width


def _spmv_kernel(rowid_ref, vals_ref, cols_ref, x_ref, out_ref):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    vals = vals_ref[0]  # (R, W)
    cols = cols_ref[0]
    x = x_ref[...]  # (n,)
    partial = jnp.sum(vals * x[cols], axis=1)  # (R,)
    rows = rowid_ref[t]  # (R,) SMEM scalars for this tile
    # rows may repeat across tiles (split rows): sum-accumulate through the
    # shared segmented epilogue (one windowed RMW, padding masked inside)
    segmented_apply(out_ref, rows, partial, combine="add")


def ich_spmv(vals, cols, rowid, x, n_rows: int, *, interpret: bool = False):
    """vals/cols (T,R,W); rowid (T,R); x (n,). Returns y (n_rows,)."""
    T, R, W = vals.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,  # rowid prefetched to SMEM (the schedule)
        grid=(T,),
        in_specs=[
            pl.BlockSpec((1, R, W), lambda t, rowid: (t, 0, 0)),
            pl.BlockSpec((1, R, W), lambda t, rowid: (t, 0, 0)),
            pl.BlockSpec(x.shape, lambda t, rowid: (0,)),  # x whole in VMEM
        ],
        out_specs=pl.BlockSpec((n_rows,), lambda t, rowid: (0,)),
    )
    return pl.pallas_call(
        _spmv_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_rows,), x.dtype),
        interpret=interpret,
    )(rowid, vals, cols, x)
