"""Pure-jnp oracle for the iCh-scheduled SpMV kernel."""
import jax.numpy as jnp
import numpy as np


def spmv_ref(indptr, indices, data, x):
    """CSR @ x via segment-sum, pure numpy/jnp."""
    n = len(indptr) - 1
    seg = np.repeat(np.arange(n), np.diff(indptr))
    prod = jnp.asarray(data) * jnp.asarray(x)[jnp.asarray(indices)]
    return jnp.zeros(n, prod.dtype).at[jnp.asarray(seg)].add(prod)


def tiles_ref(vals, cols, rowid, x, n_rows):
    """Oracle operating on the packed-tile format itself (isolates packing
    bugs from kernel bugs)."""
    partial = (vals * np.asarray(x)[cols]).sum(axis=2)  # (T,R)
    y = np.zeros(n_rows, vals.dtype)
    valid = rowid >= 0
    np.add.at(y, rowid[valid], partial[valid])
    return jnp.asarray(y)
