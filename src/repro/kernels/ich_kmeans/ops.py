"""Deprecated shim: `IChKMeans` is now a thin wrapper over the `repro.sched`
registry ("kmeans" workload). Use the facade instead:

    from repro.sched import default_scheduler
    km = default_scheduler().build("kmeans", predicted_costs)

The shim produces bit-identical schedules/outputs (same construction path,
same kernel) and shares the facade's schedule cache; it emits a
`DeprecationWarning` and will be removed once downstream callers migrate.
"""
import warnings

from repro.core import policies as P
from repro.sched.api import LoopScheduler
from repro.sched.costs import quantize_costs  # noqa: F401  (legacy re-export)
from repro.sched.defaults import ICH_EPS
from repro.sched.kernels import KMeansOp

# Cache-less on purpose: K-Means re-predicts costs every round, so every
# schedule is one-shot — caching would only retain dead entries in a
# process-global LRU (the legacy class pinned nothing). Matrix/graph
# workloads (spmv/bfs shims) DO share the default scheduler's cache.
_SHIM_SCHED = LoopScheduler(cache_size=0)


class IChKMeans(KMeansOp):
    """Schedule once per round's cost prediction, assign many times."""

    def __init__(self, costs, *, rows_per_tile: int = 8, eps: float = ICH_EPS,
                 width: int = None):
        warnings.warn(
            "IChKMeans is deprecated; use repro.sched: "
            "default_scheduler().build('kmeans', costs)",
            DeprecationWarning, stacklevel=2)
        built = _SHIM_SCHED.build(
            "kmeans", costs, policy=P.ich(eps),
            rows_per_tile=rows_per_tile, width=width)
        self.__dict__.update(built.__dict__)
