"""Public wrapper: iCh schedule construction over a predicted per-point cost
array (workloads.kmeans_rounds), then the assignment kernel many times.

Per-round re-scheduling rides the vectorized `core.tiling` path (the point
of the O(n) construction: a fresh cost prediction every round means a fresh
schedule every round), and the kernel writes assignments through the shared
`core.segmented` "store" epilogue.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tiling import build_schedule

from .ich_kmeans import ich_kmeans_assign


def quantize_costs(costs: np.ndarray) -> np.ndarray:
    """Predicted float costs -> integer work units (>= 1 per point)."""
    return np.maximum(np.ceil(np.asarray(costs, np.float64)), 1.0).astype(
        np.int64)


class IChKMeans:
    """Schedule once per round's cost prediction, assign many times."""

    def __init__(self, costs, *, rows_per_tile: int = 8, eps: float = 0.33,
                 width: int = None):
        self.sizes = quantize_costs(costs)
        self.n = len(self.sizes)
        self.schedule = build_schedule(self.sizes,
                                       rows_per_tile=rows_per_tile,
                                       width=width, eps=eps)
        self.rowid = jnp.asarray(self.schedule.item_id)
        self._jitted = {}  # interpret mode -> jitted assign (compile once)

    def __call__(self, points, centroids, interpret: bool | None = None):
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        if interpret not in self._jitted:
            self._jitted[interpret] = jax.jit(functools.partial(
                ich_kmeans_assign, interpret=interpret))
        return self._jitted[interpret](jnp.asarray(points, jnp.float32),
                                       jnp.asarray(centroids, jnp.float32),
                                       self.rowid)
