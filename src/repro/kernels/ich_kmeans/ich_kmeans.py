"""iCh-scheduled K-Means assignment — the paper's KM application on TPU.

The paper's K-Means loop (§5.1) is near-uniform FLOP-wise but has a
heavy-tailed per-point *cost* (membership flips, cache misses) that is
reshuffled every round. Schedule construction (DESIGN.md §2) consumes that
predicted cost array: each point's cost is quantized to work units, the band
picks the per-slot unit capacity W, and points costlier than W occupy
several slots — possibly in different tiles — so per-tile predicted cost
stays uniform at R*W units, exactly like a split CSR row. A multiply-
scheduled point is recomputed once per slot; the assignment write is
idempotent (same argmin), so correctness is unaffected — redundant compute
is the price a static grid pays where the runtime would have stolen.

Two kernel realizations share the body (see ich_spmv for the pattern):

* `ich_kmeans_assign` — sequential reference grid (T,): each step gathers
  its R scheduled points from the (n, D) point table in VMEM, computes
  squared distances to the (K, D) centroids, and writes per-point argmin
  through the prefetched item-id schedule ("store" mode: uncovered window
  rows keep their previously written assignment).
* `ich_kmeans_assign_sharded` — worker-sharded 2D grid (p, S/B)
  (DESIGN.md §2.6): tiles are cost-partitioned across p workers
  (item-closed — no point spans workers), each grid step computes a
  superstep of B tiles ((B*R, D) point gather), every worker stores into
  its own row of a (p, n) block, and a pairwise tree max
  (`core.segmented.worker_reduce`) folds the accumulators — bit-identical
  to the sequential grid: assignments are >= 0, each point is stored by
  exactly one worker, and every other worker holds the zero-initialized
  identity.

Unlike the SpMV/BFS/MoE sharded kernels, this one needs no manual
double-buffering (`core/pipelining.py`): its block streams are AFFINE in
the grid step (the whole point/centroid tables sit in VMEM; the point
gather indexes through SMEM scalars, not a data-dependent payload block),
so Mosaic's automatic pipeliner already overlaps fetch and compute.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.segmented import (emit_step_cost, segmented_apply,
                                  segmented_apply_batch, worker_reduce)


def _kmeans_kernel(rowid_ref, pts_ref, cent_ref, out_ref, *, n_points: int):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    pts = pts_ref[...]    # (n, D)
    cent = cent_ref[...]  # (K, D)
    ids = rowid_ref[t]    # (R,) SMEM scalars: point per slot, -1 pad
    sel = pts[jnp.clip(ids, 0, n_points - 1)]  # (R, D)
    d2 = jnp.sum((sel[:, None, :] - cent[None, :, :]) ** 2, axis=-1)  # (R, K)
    assign = jnp.argmin(d2, axis=1).astype(jnp.int32)  # (R,)
    # duplicate slots of a split point carry the same argmin, so the
    # segmented "store" (any-wins within the window) is exact
    segmented_apply(out_ref, ids, assign, combine="store")


def ich_kmeans_assign(points, centroids, rowid, *, interpret: bool = False):
    """Sequential reference grid. points (n, D); centroids (K, D);
    rowid (T, R) schedule. Returns assignments (n,) int32."""
    n = points.shape[0]
    T, R = rowid.shape
    kernel = functools.partial(_kmeans_kernel, n_points=n)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,  # rowid prefetched to SMEM (the schedule)
        grid=(T,),
        in_specs=[
            pl.BlockSpec(points.shape, lambda t, rowid: (0, 0)),
            pl.BlockSpec(centroids.shape, lambda t, rowid: (0, 0)),
        ],
        out_specs=pl.BlockSpec((n,), lambda t, rowid: (0,)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=interpret,
    )(rowid, points, centroids)


def _kmeans_sharded_body(rowid_ref, pts_ref, cent_ref, out_ref, slotc_ref,
                         cost_ref, *, n_points: int, S: int, B: int):
    w, j = pl.program_id(0), pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)
        if cost_ref is not None:
            cost_ref[...] = jnp.zeros_like(cost_ref)

    pts = pts_ref[...]    # (n, D)
    cent = cent_ref[...]  # (K, D)
    ids = rowid_ref[pl.ds(w * S + j * B, B)]  # (B, R) SMEM scalars
    flat = ids.reshape(-1)  # (B*R,)
    sel = pts[jnp.clip(flat, 0, n_points - 1)]  # (B*R, D)
    d2 = jnp.sum((sel[:, None, :] - cent[None, :, :]) ** 2, axis=-1)
    assign = jnp.argmin(d2, axis=1).astype(jnp.int32).reshape(ids.shape)
    segmented_apply_batch(out_ref, ids, assign, combine="store")
    if cost_ref is not None:
        emit_step_cost(cost_ref, ids, slotc_ref[...], j)


def _kmeans_kernel_sharded(rowid_ref, pts_ref, cent_ref, out_ref, *,
                           n_points: int, S: int, B: int):
    _kmeans_sharded_body(rowid_ref, pts_ref, cent_ref, out_ref, None, None,
                         n_points=n_points, S=S, B=B)


def _kmeans_kernel_sharded_cost(rowid_ref, pts_ref, cent_ref, slotc_ref,
                                out_ref, cost_ref, *, n_points: int,
                                S: int, B: int):
    _kmeans_sharded_body(rowid_ref, pts_ref, cent_ref, out_ref, slotc_ref,
                         cost_ref, n_points=n_points, S=S, B=B)


def ich_kmeans_assign_sharded(points, centroids, rowid, p: int,
                              superstep: int, *, slot_cost=None,
                              interpret: bool = False):
    """Worker-sharded 2D grid. points (n, D); centroids (K, D); rowid
    (p*S, R) in the shard layout of `core.tiling.WorkerShards`. Returns
    assignments (n,) int32.

    With `slot_cost` — here already in the SHARD layout (p*S, R), matching
    `rowid`, since this kernel has no flat-payload indirection — the
    kernel additionally emits the per-worker, per-superstep cost output
    and returns (assignments, costs) (DESIGN.md §2.7)."""
    n = points.shape[0]
    PS, R = rowid.shape
    p, B = int(p), int(superstep)
    S = PS // p
    if PS != p * S or S % B:
        raise ValueError(f"shard layout mismatch: {PS} rows, p={p}, B={B}")
    n_steps = S // B
    emit = slot_cost is not None
    in_specs = [
        pl.BlockSpec(points.shape, lambda w, j, rowid: (0, 0)),
        pl.BlockSpec(centroids.shape, lambda w, j, rowid: (0, 0)),
    ]
    out_specs = pl.BlockSpec((1, n), lambda w, j, rowid: (w, 0))
    out_shape = jax.ShapeDtypeStruct((p, n), jnp.int32)
    if emit:
        kernel = functools.partial(_kmeans_kernel_sharded_cost, n_points=n,
                                   S=S, B=B)
        in_specs.append(pl.BlockSpec(
            (B, R), lambda w, j, rowid: (w * (S // B) + j, 0)))
        out_specs = [out_specs, pl.BlockSpec(
            (1, n_steps), lambda w, j, rowid: (w, 0))]
        out_shape = [out_shape,
                     jax.ShapeDtypeStruct((p, n_steps), jnp.float32)]
    else:
        kernel = functools.partial(_kmeans_kernel_sharded, n_points=n,
                                   S=S, B=B)
    call = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,  # sharded rowid prefetched to SMEM
            grid=(p, n_steps),
            in_specs=in_specs,
            out_specs=out_specs,
        ),
        out_shape=out_shape,
        # workers are independent (item-closed partition): the shard
        # dimension may run concurrently across TPU cores / megacore
        compiler_params=None if interpret else pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )
    if emit:
        acc, costs = call(rowid, points, centroids,
                          jnp.asarray(slot_cost, jnp.float32))
        return worker_reduce(acc, "store"), costs
    acc = call(rowid, points, centroids)
    return worker_reduce(acc, "store")
