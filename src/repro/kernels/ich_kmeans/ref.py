"""Pure-numpy oracles for the iCh-scheduled K-Means assignment kernel."""
import numpy as np


def kmeans_assign_ref(points, centroids) -> np.ndarray:
    """argmin_k ||x_i - c_k||^2, same fp32 formula as the kernel."""
    pts = np.asarray(points, np.float32)
    cent = np.asarray(centroids, np.float32)
    d2 = ((pts[:, None, :] - cent[None, :, :]) ** 2).sum(-1)
    return np.argmin(d2, axis=1).astype(np.int32)


def kmeans_update_ref(points, assign, k: int) -> np.ndarray:
    """Centroid update for a full reference round (empty clusters keep a
    zero centroid, matching the degenerate-input convention in tests)."""
    pts = np.asarray(points, np.float32)
    out = np.zeros((k, pts.shape[1]), np.float32)
    counts = np.bincount(assign, minlength=k).astype(np.float32)
    np.add.at(out, assign, pts)
    return out / np.maximum(counts, 1.0)[:, None]
