"""Pure-numpy oracles for the iCh-scheduled BFS kernel."""
import numpy as np


def bfs_step_ref(indptr, indices, frontier, visited):
    """Pull-direction expansion: u joins iff some in-neighbor (row u of the
    CSR) is on the frontier and u is unvisited. Indicators are float arrays
    to mirror the kernel's interface."""
    n = len(indptr) - 1
    seg = np.repeat(np.arange(n), np.diff(indptr))
    hit = np.zeros(n)
    np.maximum.at(hit, seg, np.asarray(frontier)[np.asarray(indices)])
    return (hit * (1.0 - np.asarray(visited))).astype(np.float32)


def bfs_levels_ref(indptr, indices, source: int = 0) -> np.ndarray:
    """Level per vertex (-1 = unreached) under pull-direction BFS."""
    n = len(indptr) - 1
    level = np.full(n, -1, np.int32)
    level[source] = 0
    frontier = np.zeros(n, np.float32)
    frontier[source] = 1.0
    visited = frontier.copy()
    depth = 0
    while frontier.any():
        nxt = bfs_step_ref(indptr, indices, frontier, visited)
        depth += 1
        level[nxt > 0] = depth
        visited = np.maximum(visited, nxt)
        frontier = nxt
    return level
