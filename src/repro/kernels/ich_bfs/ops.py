"""Public wrapper: pack the graph once (iCh schedule construction), then run
frontier expansions / full traversals many times.

Packing uses the vectorized `core.tiling` construction and each level's
kernel max-accumulates through the shared `core.segmented` windowed
epilogue — no Python-level per-vertex or per-slot loops on either side.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tiling import build_schedule, pack_csr

from .ich_bfs import ich_bfs_step


class IChBfs:
    """CSR graph (rows = in-neighbor lists) packed into iCh work tiles.

    The degree array is the per-vertex cost the paper's BFS workload
    exposes; the schedule (width, splitting, packing) is built from it once
    and reused for every level of every traversal.
    """

    def __init__(self, indptr, indices, *, rows_per_tile: int = 8,
                 eps: float = 0.33, width: int = None):
        indptr = np.asarray(indptr)
        indices = np.asarray(indices)
        self.n = len(indptr) - 1
        self.schedule = build_schedule(np.diff(indptr),
                                       rows_per_tile=rows_per_tile,
                                       width=width, eps=eps)
        mask, cols = pack_csr(indptr, indices,
                              np.ones(len(indices), np.float32),
                              self.schedule)
        self.mask = jnp.asarray(mask)
        self.cols = jnp.asarray(cols)
        self.rowid = jnp.asarray(self.schedule.item_id)
        self._jitted = {}  # interpret mode -> jitted step (compile once)

    def step(self, frontier, visited, interpret: bool | None = None):
        """One frontier expansion; indicator in, indicator out."""
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        if interpret not in self._jitted:
            self._jitted[interpret] = jax.jit(functools.partial(
                ich_bfs_step, n_vertices=self.n, interpret=interpret))
        return self._jitted[interpret](self.mask, self.cols, self.rowid,
                                       jnp.asarray(frontier, jnp.float32),
                                       jnp.asarray(visited, jnp.float32))

    def levels(self, source: int = 0,
               interpret: bool | None = None) -> np.ndarray:
        """Full traversal: level per vertex (-1 = unreached)."""
        level = np.full(self.n, -1, np.int32)
        level[source] = 0
        frontier = np.zeros(self.n, np.float32)
        frontier[source] = 1.0
        visited = frontier.copy()
        depth = 0
        while frontier.any():
            nxt = np.asarray(self.step(frontier, visited, interpret))
            depth += 1
            level[nxt > 0] = depth
            visited = np.maximum(visited, nxt)
            frontier = nxt
        return level
