"""Deprecated shim: `IChBfs` is now a thin wrapper over the `repro.sched`
registry ("bfs" workload). Use the facade instead:

    from repro.sched import default_scheduler
    bfs = default_scheduler().build("bfs", indptr, indices)

The shim produces bit-identical packing/outputs (same construction path,
same kernel) and shares the facade's schedule cache; it emits a
`DeprecationWarning` and will be removed once downstream callers migrate.
"""
import warnings

from repro.core import policies as P
from repro.sched.api import default_scheduler
from repro.sched.defaults import ICH_EPS
from repro.sched.kernels import BfsOp


class IChBfs(BfsOp):
    """CSR graph (rows = in-neighbor lists) packed into iCh work tiles."""

    def __init__(self, indptr, indices, *, rows_per_tile: int = 8,
                 eps: float = ICH_EPS, width: int = None):
        warnings.warn(
            "IChBfs is deprecated; use repro.sched: "
            "default_scheduler().build('bfs', indptr, indices)",
            DeprecationWarning, stacklevel=2)
        built = default_scheduler().build(
            "bfs", indptr, indices, policy=P.ich(eps),
            rows_per_tile=rows_per_tile, width=width)
        self.__dict__.update(built.__dict__)
