"""iCh-scheduled BFS frontier expansion — the paper's BF application on TPU.

Pull-direction (bottom-up) level step over a CSR graph whose row u lists u's
in-neighbors: vertex u joins the next frontier iff some in-neighbor is on the
current frontier and u is unvisited. Per-vertex cost = degree, the paper's
BFS workload (§5.1): most vertices are trivial, frontier-adjacent ones heavy.

The schedule is constructed once per graph by `core.tiling` (DESIGN.md §2):
band-picked width W over the degree distribution, heavy adjacency lists
split across W-wide segments, segments greedily packed into (T, R) slots.
`mask` is the all-ones CSR payload from `pack_csr` — 1.0 on real edge slots,
0.0 on padding — so a padded slot can never observe frontier[cols==0].

Two kernel realizations share the body (see ich_spmv for the pattern):

* `ich_bfs_step` — sequential reference grid (T,): each step gathers
  frontier[cols] (R, W), reduces with max over W, and max-accumulates into
  the per-vertex output (split rows OR together across tiles), masked by
  `visited`; grid steps run in order on one core, so the RMW is safe.
* `ich_bfs_step_sharded` — worker-sharded 2D grid (p, S_B) (DESIGN.md
  §2.6): tiles are cost-partitioned across p workers at superstep-block
  granularity (item-closed — no vertex spans workers), each grid step
  fetches a superstep of B tiles as one aligned (B, R, W) block straight
  from the FLAT payload via a prefetched data-dependent block index
  (no payload reorder) — DOUBLE-BUFFERED through 2-slot VMEM scratch so
  step j+1's blocks stream in while step j computes (core/pipelining.py)
  — every worker max-accumulates into its own row of
  a (p, n) block, and a pairwise tree max (`core.segmented.worker_reduce`)
  folds the accumulators — bit-identical to the sequential grid: each
  vertex is owned by one worker and all others contribute exact zeros
  (the max identity for the 0/1 frontier indicators).

The max-accumulation routes through the shared segmented-reduction layer
(`core/segmented.py`): one windowed read-modify-write per tile instead of R
scalar ones.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.pipelining import (double_buffer_scratch,
                                   fetch_double_buffered)
from repro.core.segmented import (emit_step_cost, segmented_apply,
                                  segmented_apply_batch, worker_reduce)


def _bfs_kernel(rowid_ref, mask_ref, cols_ref, frontier_ref, visited_ref,
                out_ref, *, n_vertices: int):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    mask = mask_ref[0]      # (R, W) 1.0 on real edge slots
    cols = cols_ref[0]      # (R, W) in-neighbor ids
    frontier = frontier_ref[...]  # (n,) 1.0 = on current frontier
    visited = visited_ref[...]    # (n,) 1.0 = already visited
    hit = jnp.max(mask * frontier[cols], axis=1)  # (R,) any frontier nbr?
    rows = rowid_ref[t]     # (R,) SMEM scalars: vertex per slot, -1 pad
    inc = hit * (1.0 - visited[jnp.clip(rows, 0, n_vertices - 1)])
    # split adjacency lists OR together across tiles: max-accumulate through
    # the shared segmented epilogue (padding slots masked by its one-hot)
    segmented_apply(out_ref, rows, inc, combine="max")


def ich_bfs_step(mask, cols, rowid, frontier, visited, n_vertices: int,
                 *, interpret: bool = False):
    """One frontier expansion on the sequential reference grid. mask/cols
    (T,R,W); rowid (T,R); frontier and visited (n,) float32 indicators.
    Returns the next frontier (n,)."""
    T, R, W = mask.shape
    kernel = functools.partial(_bfs_kernel, n_vertices=n_vertices)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,  # rowid prefetched to SMEM (the schedule)
        grid=(T,),
        in_specs=[
            pl.BlockSpec((1, R, W), lambda t, rowid: (t, 0, 0)),
            pl.BlockSpec((1, R, W), lambda t, rowid: (t, 0, 0)),
            pl.BlockSpec(frontier.shape, lambda t, rowid: (0,)),
            pl.BlockSpec(visited.shape, lambda t, rowid: (0,)),
        ],
        out_specs=pl.BlockSpec((n_vertices,), lambda t, rowid: (0,)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_vertices,), frontier.dtype),
        interpret=interpret,
    )(rowid, mask, cols, frontier, visited)


def _bfs_sharded_body(rowid_ref, blkid_ref, mask_hbm, cols_hbm, slotc_hbm,
                      frontier_ref, visited_ref, out_ref, cost_ref, bufs,
                      sems, *, n_vertices: int, S: int, B: int):
    w, j = pl.program_id(0), pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)
        if cost_ref is not None:
            cost_ref[...] = jnp.zeros_like(cost_ref)

    # double-buffered data-dependent fetch (core/pipelining.py): same
    # block bytes in the same order, so bit-identity to the sequential
    # grid is preserved
    hbm = (mask_hbm, cols_hbm) if slotc_hbm is None \
        else (mask_hbm, cols_hbm, slotc_hbm)
    blocks = fetch_double_buffered(list(zip(hbm, bufs, sems)),
                                   blkid_ref, w, j, B=B)
    mask = blocks[0]  # (B, R, W): one superstep of this worker's shard
    cols = blocks[1]
    frontier = frontier_ref[...]
    visited = visited_ref[...]
    hit = jnp.max(mask * frontier[cols], axis=2)  # (B, R)
    rows = rowid_ref[pl.ds(w * S + j * B, B)]  # (B, R) SMEM scalars
    inc = hit * (1.0 - visited[jnp.clip(rows, 0, n_vertices - 1)])
    segmented_apply_batch(out_ref, rows, inc, combine="max")
    if cost_ref is not None:
        emit_step_cost(cost_ref, rows, blocks[2], j)


def _bfs_kernel_sharded(rowid_ref, blkid_ref, mask_hbm, cols_hbm,
                        frontier_ref, visited_ref, out_ref, mbuf, cbuf,
                        msem, csem, *, n_vertices: int, S: int, B: int):
    _bfs_sharded_body(rowid_ref, blkid_ref, mask_hbm, cols_hbm, None,
                      frontier_ref, visited_ref, out_ref, None,
                      (mbuf, cbuf), (msem, csem),
                      n_vertices=n_vertices, S=S, B=B)


def _bfs_kernel_sharded_cost(rowid_ref, blkid_ref, mask_hbm, cols_hbm,
                             slotc_hbm, frontier_ref, visited_ref, out_ref,
                             cost_ref, mbuf, cbuf, sbuf, msem, csem, ssem,
                             *, n_vertices: int, S: int, B: int):
    _bfs_sharded_body(rowid_ref, blkid_ref, mask_hbm, cols_hbm, slotc_hbm,
                      frontier_ref, visited_ref, out_ref, cost_ref,
                      (mbuf, cbuf, sbuf), (msem, csem, ssem),
                      n_vertices=n_vertices, S=S, B=B)


def ich_bfs_step_sharded(mask, cols, rowid, blkid, frontier, visited,
                         n_vertices: int, p: int, superstep: int,
                         *, slot_cost=None, interpret: bool = False):
    """One frontier expansion on the worker-sharded 2D grid. mask/cols
    (T_pad, R, W): the FLAT packed payload with T padded to whole
    supersteps; rowid (p*S, R) and blkid (p*S_B,) from
    `core.tiling.WorkerShards`; frontier/visited (n,) float32 indicators.
    Returns the next frontier (n,).

    With `slot_cost` ((T_pad, R) per-slot scheduled costs) the kernel
    additionally emits the per-worker, per-superstep cost output and
    returns (next_frontier, costs) — the measured-cost feedback stream
    (DESIGN.md §2.7)."""
    T_pad, R, W = mask.shape
    p, B = int(p), int(superstep)
    n_steps = int(blkid.shape[0]) // p
    S = n_steps * B
    if blkid.shape[0] != p * n_steps or rowid.shape[0] != p * S or T_pad % B:
        raise ValueError(f"shard layout mismatch: blkid {blkid.shape}, "
                         f"rowid {rowid.shape}, T_pad={T_pad}, p={p}, B={B}")
    emit = slot_cost is not None
    # payloads stay whole in ANY memory; the kernel double-buffers the
    # data-dependent superstep blocks through 2-slot VMEM scratch
    # (core/pipelining.py)
    in_specs = [
        pl.BlockSpec(memory_space=pltpu.ANY),  # mask (T_pad, R, W)
        pl.BlockSpec(memory_space=pltpu.ANY),  # cols (T_pad, R, W)
    ]
    db_streams = [((R, W), mask.dtype), ((R, W), jnp.int32)]
    out_specs = pl.BlockSpec((1, n_vertices),
                             lambda w, j, rowid, blk: (w, 0))
    out_shape = jax.ShapeDtypeStruct((p, n_vertices), frontier.dtype)
    if emit:
        kernel = functools.partial(_bfs_kernel_sharded_cost,
                                   n_vertices=n_vertices, S=S, B=B)
        in_specs.append(pl.BlockSpec(memory_space=pltpu.ANY))  # slot costs
        db_streams.append(((R,), jnp.float32))
        out_specs = [out_specs, pl.BlockSpec(
            (1, n_steps), lambda w, j, rowid, blk: (w, 0))]
        out_shape = [out_shape,
                     jax.ShapeDtypeStruct((p, n_steps), jnp.float32)]
    else:
        kernel = functools.partial(_bfs_kernel_sharded,
                                   n_vertices=n_vertices, S=S, B=B)
    in_specs += [
        pl.BlockSpec(frontier.shape, lambda w, j, rowid, blk: (0,)),
        pl.BlockSpec(visited.shape, lambda w, j, rowid, blk: (0,)),
    ]
    call = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,  # sharded rowid + block ids to SMEM
            grid=(p, n_steps),
            in_specs=in_specs,
            out_specs=out_specs,
            scratch_shapes=double_buffer_scratch(B, db_streams),
        ),
        out_shape=out_shape,
        # workers are independent (item-closed partition): the shard
        # dimension may run concurrently across TPU cores / megacore
        compiler_params=None if interpret else pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )
    if emit:
        acc, costs = call(rowid, blkid, mask, cols,
                          jnp.asarray(slot_cost, jnp.float32),
                          frontier, visited)
        return worker_reduce(acc, "max"), costs
    acc = call(rowid, blkid, mask, cols, frontier, visited)
    return worker_reduce(acc, "max")
