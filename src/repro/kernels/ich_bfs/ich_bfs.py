"""iCh-scheduled BFS frontier expansion — the paper's BF application on TPU.

Pull-direction (bottom-up) level step over a CSR graph whose row u lists u's
in-neighbors: vertex u joins the next frontier iff some in-neighbor is on the
current frontier and u is unvisited. Per-vertex cost = degree, the paper's
BFS workload (§5.1): most vertices are trivial, frontier-adjacent ones heavy.

The schedule is constructed once per graph by `core.tiling` (DESIGN.md §2):
band-picked width W over the degree distribution, heavy adjacency lists
split across W-wide segments, segments greedily packed into (T, R) slots.
`mask` is the all-ones CSR payload from `pack_csr` — 1.0 on real edge slots,
0.0 on padding — so a padded slot can never observe frontier[cols==0].

Kernel per level: persistent grid (T,); each step gathers frontier[cols]
(R, W), reduces with max over W, and max-accumulates into the per-vertex
output (split rows OR together across tiles), masked by `visited`. The
max-accumulation routes through the shared segmented-reduction layer
(`core/segmented.py`): one windowed read-modify-write per tile instead of R
scalar ones. Grid steps run sequentially on a TPU core, so the RMW is safe.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.segmented import segmented_apply


def _bfs_kernel(rowid_ref, mask_ref, cols_ref, frontier_ref, visited_ref,
                out_ref, *, n_vertices: int):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    mask = mask_ref[0]      # (R, W) 1.0 on real edge slots
    cols = cols_ref[0]      # (R, W) in-neighbor ids
    frontier = frontier_ref[...]  # (n,) 1.0 = on current frontier
    visited = visited_ref[...]    # (n,) 1.0 = already visited
    hit = jnp.max(mask * frontier[cols], axis=1)  # (R,) any frontier nbr?
    rows = rowid_ref[t]     # (R,) SMEM scalars: vertex per slot, -1 pad
    inc = hit * (1.0 - visited[jnp.clip(rows, 0, n_vertices - 1)])
    # split adjacency lists OR together across tiles: max-accumulate through
    # the shared segmented epilogue (padding slots masked by its one-hot)
    segmented_apply(out_ref, rows, inc, combine="max")


def ich_bfs_step(mask, cols, rowid, frontier, visited, n_vertices: int,
                 *, interpret: bool = False):
    """One frontier expansion. mask/cols (T,R,W); rowid (T,R); frontier and
    visited (n,) float32 indicators. Returns the next frontier (n,)."""
    T, R, W = mask.shape
    kernel = functools.partial(_bfs_kernel, n_vertices=n_vertices)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,  # rowid prefetched to SMEM (the schedule)
        grid=(T,),
        in_specs=[
            pl.BlockSpec((1, R, W), lambda t, rowid: (t, 0, 0)),
            pl.BlockSpec((1, R, W), lambda t, rowid: (t, 0, 0)),
            pl.BlockSpec(frontier.shape, lambda t, rowid: (0,)),
            pl.BlockSpec(visited.shape, lambda t, rowid: (0,)),
        ],
        out_specs=pl.BlockSpec((n_vertices,), lambda t, rowid: (0,)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_vertices,), frontier.dtype),
        interpret=interpret,
    )(rowid, mask, cols, frontier, visited)
