"""Pallas TPU flash attention (causal, GQA) — forward kernel.

Grid layout: (B*Hq, n_q_blocks, n_kv_blocks) with the KV dim innermost; TPU
executes the grid sequentially in row-major order, so the online-softmax
accumulators (m, l, acc) live in VMEM scratch and persist across the KV steps
of one (batch-head, q-block) pair. Fully-masked causal blocks are skipped
with pl.when — this is the term the XLA blockwise path cannot drop (it
computes then masks), worth ~2x on attention FLOPs at long sequence.

GQA is handled by the K/V index_map (q-head -> kv-head), so K/V are never
materialized at Hq width. VMEM budget per step: q/k/v blocks 256x128
(64-192KB) + fp32 scores 256x256 (256KB) — comfortably < 16MB VMEM; MXU dims
are multiples of 128 when dh >= 128 (dh=64 archs pad on sublanes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, q_block: int, kv_block: int, causal: bool,
                  n_kv_blocks: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * q_block
    k_start = ki * kv_block

    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    if causal:
        # skip blocks strictly above the causal diagonal (saved FLOPs)
        pl.when(k_start <= q_start + q_block - 1)(_compute)
    else:
        _compute()

    @pl.when(ki == n_kv_blocks - 1)
    def _finish():
        o_ref[0, ...] = (acc_scr[...] /
                         jnp.maximum(l_scr[...], 1e-20)[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, q_block: int = 256,
                    kv_block: int = 256, interpret: bool = False):
    """q (B,Sq,Hq,dh); k,v (B,Skv,Hkv,dh), Hq % Hkv == 0. Returns (B,Sq,Hq,dh).
    Sq / Skv must be multiples of the block sizes (callers pad)."""
    B, Sq, Hq, dh = q.shape
    _, Skv, Hkv, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    rep = Hq // Hkv
    scale = dh ** -0.5
    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Skv)
    assert Sq % q_block == 0 and Skv % kv_block == 0, (Sq, Skv, q_block, kv_block)
    nq, nk = Sq // q_block, Skv // kv_block

    qr = q.transpose(0, 2, 1, 3).reshape(B * Hq, Sq, dh)
    kr = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv, dh)
    vr = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv, dh)

    kernel = functools.partial(
        _flash_kernel, scale=scale, q_block=q_block, kv_block=kv_block,
        causal=causal, n_kv_blocks=nk)

    out = pl.pallas_call(
        kernel,
        grid=(B * Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, q_block, dh), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, kv_block, dh),
                         lambda bh, qi, ki, rep=rep: (bh // rep, ki, 0)),
            pl.BlockSpec((1, kv_block, dh),
                         lambda bh, qi, ki, rep=rep: (bh // rep, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, dh), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block, dh), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, Hq, Sq, dh).transpose(0, 2, 1, 3)
