"""Jitted public wrapper for the flash attention kernel: pads sequences to
block multiples, dispatches to the Pallas kernel (interpret=True on CPU)."""
import functools

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention


@functools.partial(jax.jit, static_argnames=("causal", "q_block", "kv_block",
                                             "interpret"))
def flash_attention_op(q, k, v, *, causal: bool = True, q_block: int = 256,
                       kv_block: int = 256, interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, Sq, Hq, dh = q.shape
    Skv = k.shape[1]
    qb = min(q_block, max(8, Sq))
    kb = min(kv_block, max(8, Skv))
    pq = (-Sq) % qb
    pk = (-Skv) % kb
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    # padded key positions sit above the causal diagonal of every real query
    # row only if Skv+pk > Sq+pq — guard by masking padded keys via causal
    # structure: real q rows (< Sq) never attend beyond Skv when
    # Skv - Sq == pk offset... keep it simple: causal path pads consistently.
    out = flash_attention(q, k, v, causal=causal, q_block=qb, kv_block=kb,
                          interpret=interpret)
    return out[:, :Sq]
