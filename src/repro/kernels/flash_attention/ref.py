"""Pure-jnp oracle for the flash attention kernel."""
import jax.numpy as jnp
import jax


def attention_ref(q, k, v, *, causal: bool = True):
    """q (B,Sq,Hq,dh); k,v (B,Skv,Hkv,dh). fp32 math, matches kernel output
    up to accumulation order."""
    B, Sq, Hq, dh = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    rep = Hq // Hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (dh ** -0.5)
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Skv), bool), k=Skv - Sq)
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
