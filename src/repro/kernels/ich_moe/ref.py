"""Pure-numpy oracle for the iCh-scheduled MoE expert-dispatch kernel."""
import numpy as np


def _silu(x):
    return x / (1.0 + np.exp(-x))


def moe_dispatch_ref(indptr, tok, w, x, wi, wg, wo):
    """Expert-major CSR apply: y[t] += w_entry * FFN_e(x[t]) over every
    kept dispatch entry of every expert e. The dispatch-plan analogue of
    spmv_ref: the plan's CSR (sched/moe.py DispatchPlan.csr) is the
    matrix, the gated expert FFN the per-entry work."""
    n_tokens, d = x.shape
    y = np.zeros((n_tokens, d), np.float32)
    E = len(indptr) - 1
    for e in range(E):
        lo, hi = int(indptr[e]), int(indptr[e + 1])
        if hi == lo:
            continue
        xs = x[tok[lo:hi]].astype(np.float32)          # (n_e, D)
        h = xs @ wi[e]
        g = xs @ wg[e]
        ye = (_silu(g) * h) @ wo[e]                    # (n_e, D)
        np.add.at(y, tok[lo:hi], ye * w[lo:hi, None])
    return y


def expert_loads_ref(indptr):
    """Per-expert kept token counts straight off the CSR layout."""
    return np.diff(np.asarray(indptr)).astype(np.int64)
