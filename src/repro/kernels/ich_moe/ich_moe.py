"""iCh-scheduled MoE expert dispatch — the model running on the scheduler.

The dispatch plan (`repro.sched.moe.plan_dispatch`) resolves token->expert
routing on the host; its kept entries form an expert-major CSR (expert =
item, token ids = column indices, combine weights = values) that packs
into the SAME fixed-shape (T, R, W) work tiles every other iCh kernel
uses (`core.tiling.pack_csr`): row splitting spreads a hot expert's
tokens across tiles exactly like a heavy SpMV row, so no tile — and
after cost partitioning no WORKER — is overloaded by router skew.

`ich_moe_sharded` is the worker-sharded 2D realization (DESIGN.md §2.6
applied to §2.8): grid (p, S_B), each grid step fetches one superstep of
B tiles straight out of the flat payload via the prefetched block-index
stream — double-buffered through 2-slot VMEM scratch so step j+1's
blocks stream in while step j computes (core/pipelining.py) — applies
the gated expert FFN to every (expert-slot, token-slot)
pair of the block, and scatters the weighted outputs into this worker's
private (1, n_tokens, D) accumulator with a one-hot matmul (tokens are
NOT item-closed across workers — a token's K experts may live on
different shards — so the scatter cannot reuse the windowed segmented
epilogue, which is keyed on item ids; the EXPERT-space reductions below
do reuse it). `core.segmented.worker_reduce` folds the p accumulators on
the host; the fold tree is deterministic, so outputs are reproducible
run-to-run even though tokens shared across workers make the sum order
differ from a sequential evaluation (same allclose tolerance class as
any matmul reassociation).

With `slot_cost`, the kernel emits the measured-cost feedback twice over:

* (p, S_B) per-worker per-superstep totals — `emit_step_cost`, the
  stream `Schedule.observe(shards=...)` folds into the `CostRefiner`;
* (p, E) per-worker PER-EXPERT totals — `segmented_apply_batch` into an
  (1, E) window per worker (expert ids ARE the schedule's item ids, so
  the windowed epilogue applies). Worker-summed, these equal the
  schedule's per-item costs EXACTLY (integer token counts carried in
  float32), the §2.7 routing proof extended to expert granularity — and
  the measured per-expert load that `refine_cap_scale` turns into the
  next step's capacity scale.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.pipelining import (double_buffer_scratch,
                                   fetch_double_buffered)
from repro.core.segmented import (emit_step_cost, segmented_apply_batch,
                                  worker_reduce)

__all__ = ["ich_moe_sharded"]


def _moe_sharded_body(rowid_ref, blkid_ref, vals_hbm, cols_hbm, slotc_hbm,
                      x_ref, wi_ref, wg_ref, wo_ref, out_ref, cost_ref,
                      ecost_ref, bufs, sems, *, S: int, B: int):
    w, j = pl.program_id(0), pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)
        if cost_ref is not None:
            cost_ref[...] = jnp.zeros_like(cost_ref)
            ecost_ref[...] = jnp.zeros_like(ecost_ref)

    # double-buffered data-dependent fetch (core/pipelining.py)
    hbm = (vals_hbm, cols_hbm) if slotc_hbm is None \
        else (vals_hbm, cols_hbm, slotc_hbm)
    blocks = fetch_double_buffered(list(zip(hbm, bufs, sems)),
                                   blkid_ref, w, j, B=B)
    vals = blocks[0]  # (B, R, W): one superstep of combine weights
    cols = blocks[1]  # (B, R, W): token ids (0 on padding, vals 0)
    x = x_ref[...]    # (n_tokens, D)
    rows = rowid_ref[pl.ds(w * S + j * B, B)]  # (B, R) expert ids, -1 pad
    e = jnp.maximum(rows, 0)

    # gated FFN on every slot: tokens enter f32 like the in-graph router
    # path; expert weights are gathered per slot row (whole-E residency)
    xs = x[cols].astype(jnp.float32)                   # (B, R, W, D)
    h = jnp.einsum("brwd,brdf->brwf", xs, wi_ref[...][e],
                   preferred_element_type=jnp.float32)
    g = jnp.einsum("brwd,brdf->brwf", xs, wg_ref[...][e],
                   preferred_element_type=jnp.float32)
    yb = jnp.einsum("brwf,brfd->brwd", jax.nn.silu(g) * h, wo_ref[...][e],
                    preferred_element_type=jnp.float32)
    # combine weight per slot; padding slots carry vals == 0 and padding
    # STEPS fetch a clamped block whose vals are real, so mask on rows too
    contrib = yb * vals[..., None] * (rows >= 0)[..., None, None]

    # token scatter: one-hot matmul over the flattened (B*R*W) slot axis
    # into this worker's private accumulator (tokens are not item-closed
    # across workers, so no windowed RMW — the window is in expert space)
    n_tokens = out_ref.shape[1]
    flat_tok = cols.reshape(-1)                        # (B*R*W,)
    flat_c = contrib.reshape(-1, contrib.shape[-1])    # (B*R*W, D)
    lane = jax.lax.broadcasted_iota(jnp.int32, (n_tokens,
                                                flat_tok.shape[0]), 0)
    onehot = (lane == flat_tok[None, :]).astype(jnp.float32)
    out_ref[...] += jnp.dot(onehot, flat_c,
                            preferred_element_type=jnp.float32)[None]

    if cost_ref is not None:
        slotc = blocks[2]  # (B, R) scheduled per-slot costs
        emit_step_cost(cost_ref, rows, slotc, j)
        # per-expert totals: expert ids are the schedule's item ids, so
        # the windowed segmented epilogue applies directly
        masked = jnp.where(rows >= 0, slotc, 0.0)
        segmented_apply_batch(ecost_ref, rows, masked, combine="add")


def _moe_kernel_sharded(rowid_ref, blkid_ref, vals_hbm, cols_hbm, x_ref,
                        wi_ref, wg_ref, wo_ref, out_ref, vbuf, cbuf, vsem,
                        csem, *, S: int, B: int):
    _moe_sharded_body(rowid_ref, blkid_ref, vals_hbm, cols_hbm, None,
                      x_ref, wi_ref, wg_ref, wo_ref, out_ref, None, None,
                      (vbuf, cbuf), (vsem, csem), S=S, B=B)


def _moe_kernel_sharded_cost(rowid_ref, blkid_ref, vals_hbm, cols_hbm,
                             slotc_hbm, x_ref, wi_ref, wg_ref, wo_ref,
                             out_ref, cost_ref, ecost_ref, vbuf, cbuf,
                             sbuf, vsem, csem, ssem, *, S: int, B: int):
    _moe_sharded_body(rowid_ref, blkid_ref, vals_hbm, cols_hbm, slotc_hbm,
                      x_ref, wi_ref, wg_ref, wo_ref, out_ref, cost_ref,
                      ecost_ref, (vbuf, cbuf, sbuf), (vsem, csem, ssem),
                      S=S, B=B)


def ich_moe_sharded(vals, cols, rowid, blkid, x, wi, wg, wo, p: int,
                    superstep: int, *, slot_cost=None,
                    interpret: bool = False):
    """Worker-sharded MoE expert application over a packed dispatch plan.

    vals/cols (T_pad, R, W): flat packed combine weights + token ids
    (`pack_csr` over the plan's expert-major CSR, padded to whole
    supersteps); rowid (p*S, R) per-slot expert ids and blkid (p*S_B,)
    from `WorkerShards`; x (n_tokens, D) token activations; wi/wg
    (E, D, F) and wo (E, F, D) expert FFN weights. Returns y (n_tokens, D)
    in float32.

    With `slot_cost` ((T_pad, R), the schedule's per-slot cost stream)
    returns (y, step_costs (p, S_B), expert_costs (p, E)); summed over
    workers the expert costs equal the schedule's per-expert totals
    exactly (integer token counts in float32)."""
    T_pad, R, W = vals.shape
    n_tokens, D = x.shape
    E = wi.shape[0]
    p, B = int(p), int(superstep)
    n_steps = int(blkid.shape[0]) // p
    S = n_steps * B
    if blkid.shape[0] != p * n_steps or rowid.shape[0] != p * S or T_pad % B:
        raise ValueError(f"shard layout mismatch: blkid {blkid.shape}, "
                         f"rowid {rowid.shape}, T_pad={T_pad}, p={p}, B={B}")
    emit = slot_cost is not None
    # payloads stay whole in ANY memory; the kernel double-buffers the
    # data-dependent superstep blocks through 2-slot VMEM scratch
    # (core/pipelining.py)
    in_specs = [
        pl.BlockSpec(memory_space=pltpu.ANY),  # vals (T_pad, R, W)
        pl.BlockSpec(memory_space=pltpu.ANY),  # cols (T_pad, R, W)
    ]
    db_streams = [((R, W), vals.dtype), ((R, W), jnp.int32)]
    out_specs = pl.BlockSpec((1, n_tokens, D),
                             lambda w, j, rowid, blk: (w, 0, 0))
    out_shape = jax.ShapeDtypeStruct((p, n_tokens, D), jnp.float32)
    if emit:
        kernel = functools.partial(_moe_kernel_sharded_cost, S=S, B=B)
        in_specs.append(pl.BlockSpec(memory_space=pltpu.ANY))  # slot costs
        db_streams.append(((R,), jnp.float32))
        out_specs = [out_specs,
                     pl.BlockSpec((1, n_steps),
                                  lambda w, j, rowid, blk: (w, 0)),
                     pl.BlockSpec((1, E), lambda w, j, rowid, blk: (w, 0))]
        out_shape = [out_shape,
                     jax.ShapeDtypeStruct((p, n_steps), jnp.float32),
                     jax.ShapeDtypeStruct((p, E), jnp.float32)]
    else:
        kernel = functools.partial(_moe_kernel_sharded, S=S, B=B)
    # token activations + the full expert weight stacks stay whole in VMEM
    in_specs.append(pl.BlockSpec(x.shape, lambda w, j, rowid, blk: (0, 0)))
    in_specs.append(pl.BlockSpec(wi.shape,
                                 lambda w, j, rowid, blk: (0, 0, 0)))
    in_specs.append(pl.BlockSpec(wg.shape,
                                 lambda w, j, rowid, blk: (0, 0, 0)))
    in_specs.append(pl.BlockSpec(wo.shape,
                                 lambda w, j, rowid, blk: (0, 0, 0)))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # sharded expert ids + block ids to SMEM
        grid=(p, n_steps),
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=double_buffer_scratch(B, db_streams),
    )
    call = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        # workers accumulate into private rows; the shard dimension may
        # run concurrently across TPU cores / megacore
        compiler_params=None if interpret else pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )
    if emit:
        acc, costs, ecosts = call(rowid, blkid, vals, cols,
                                  jnp.asarray(slot_cost, jnp.float32),
                                  x, wi, wg, wo)
        return worker_reduce(acc, "add"), costs, ecosts
    acc = call(rowid, blkid, vals, cols, x, wi, wg, wo)
    return worker_reduce(acc, "add")
