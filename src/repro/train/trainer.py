"""Training loop: checkpoint/restart, failure injection, elastic re-shard.

Fault-tolerance contract (DESIGN.md §6):
* auto-resume from the newest fully-published checkpoint;
* `failure_at` injects a crash mid-run (tests restart end-to-end);
* restarts may use a DIFFERENT mesh (elastic): checkpoints are logical,
  `load_state` re-places arrays under the new shardings;
* async checkpoint writer stays off the critical path;
* the data pipeline (iCh dispatcher) prefetches the next batch during step t.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import numpy as np

from ..data.pipeline import Pipeline
from ..models.moe import DistContext
from . import checkpoint as CKPT
from . import train_step as TS


@dataclasses.dataclass
class RunConfig:
    steps: int = 50
    batch: int = 8
    seq: int = 128
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 20
    log_every: int = 10
    seed: int = 0
    failure_at: Optional[int] = None  # inject a crash AFTER this step


class InjectedFailure(RuntimeError):
    pass


def train(cfg, run: RunConfig, tcfg: TS.TrainConfig = None, mesh=None,
          verbose: bool = True):
    """Returns (final_state, losses). Call again after a crash to resume."""
    tcfg = tcfg or TS.TrainConfig(opt=dataclasses.replace(
        TS.TrainConfig().opt, warmup_steps=10, total_steps=run.steps))
    dist = None
    if mesh is not None and np.prod(list(mesh.shape.values())) > 1:
        from ..launch.mesh import batch_axes_of
        dist = DistContext(mesh, batch_axes=batch_axes_of(mesh))

    state = TS.init_train_state(cfg, jax.random.PRNGKey(run.seed),
                                max_seq=run.seq, tcfg=tcfg)
    start_step = 0
    if CKPT.list_steps(run.ckpt_dir):
        state, start_step = CKPT.load_state(state, run.ckpt_dir)
        if verbose:
            print(f"[trainer] resumed from step {start_step}")

    step_fn = jax.jit(TS.make_train_step(cfg, tcfg, dist), donate_argnums=0)
    pipe = Pipeline(cfg, run.batch, run.seq, seed=run.seed)
    ckpt = CKPT.AsyncCheckpointer(run.ckpt_dir)

    losses = []
    t0 = time.time()
    for step in range(start_step, run.steps):
        batch_np, ingest = pipe.get_batch(step)
        batch = {k: jax.numpy.asarray(v) for k, v in batch_np.items()}
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if verbose and (step % run.log_every == 0 or step == run.steps - 1):
            print(f"[trainer] step {step} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"ingest_steals {ingest.steals} "
                  f"({time.time()-t0:.1f}s)")
        if (step + 1) % run.ckpt_every == 0 or step == run.steps - 1:
            ckpt.save(state, step + 1)
        if run.failure_at is not None and step + 1 == run.failure_at:
            ckpt.wait()
            raise InjectedFailure(f"injected failure after step {step + 1}")
    ckpt.wait()
    return state, losses
