"""Fault-tolerant checkpointing.

Design (1000+-node posture, DESIGN.md §6):
* the state pytree is saved as flat npz shards + a JSON manifest;
* writes go to a temp dir and are published with an atomic rename, so a
  node failure mid-write never corrupts the latest checkpoint;
* an async writer thread keeps checkpointing off the training critical path;
* checkpoints are MESH-AGNOSTIC: arrays are saved logically-unsharded, and
  `load_state` reshards onto whatever mesh/process the restart has —
  elastic re-scaling is a load-time concern, not a save-time one.
"""
from __future__ import annotations

import json
import pathlib
import shutil
import threading
import time

import jax
import numpy as np


def _flatten(state):
    leaves, treedef = jax.tree.flatten(state)
    return leaves, treedef


def save_state(state, ckpt_dir: str, step: int) -> str:
    """Synchronous atomic save. Returns the published directory."""
    root = pathlib.Path(ckpt_dir)
    tmp = root / f".tmp_step_{step}"
    final = root / f"step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves, treedef = _flatten(state)
    arrays = {f"a{i}": np.asarray(l) for i, l in enumerate(leaves)}
    np.savez(tmp / "shard_0.npz", **arrays)
    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "time": time.time(),
        "dtypes": [str(np.asarray(l).dtype) for l in leaves],
        "shapes": [list(np.asarray(l).shape) for l in leaves],
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)  # atomic publish
    return str(final)


class AsyncCheckpointer:
    """Fire-and-forget checkpoint writer (one in flight at a time)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None

    def save(self, state, step: int):
        self.wait()
        host_state = jax.tree.map(np.asarray, state)  # snapshot off-device

        def _run():
            save_state(host_state, self.ckpt_dir, step)
            self._gc()

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(list_steps(self.ckpt_dir))
        for s in steps[:-self.keep]:
            shutil.rmtree(pathlib.Path(self.ckpt_dir) / f"step_{s}",
                          ignore_errors=True)


def list_steps(ckpt_dir: str) -> list[int]:
    root = pathlib.Path(ckpt_dir)
    if not root.exists():
        return []
    out = []
    for p in root.glob("step_*"):
        if (p / "manifest.json").exists():  # only fully-published ckpts
            out.append(int(p.name.split("_")[1]))
    return sorted(out)


def load_state(like_state, ckpt_dir: str, step: int | None = None,
               shardings=None):
    """Restore into the structure of `like_state` (resharding as needed).

    `like_state` may come from a DIFFERENT mesh than the save: arrays are
    logically complete on disk, so elastic restarts just re-place them.
    """
    steps = list_steps(ckpt_dir)
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    step = steps[-1] if step is None else step
    d = pathlib.Path(ckpt_dir) / f"step_{step}"
    data = np.load(d / "shard_0.npz")
    leaves, treedef = _flatten(like_state)
    loaded = [data[f"a{i}"] for i in range(len(leaves))]
    if shardings is not None:
        flat_sh = treedef.flatten_up_to(shardings)
        loaded = [jax.device_put(a, s) for a, s in zip(loaded, flat_sh)]
    return treedef.unflatten(loaded), step
