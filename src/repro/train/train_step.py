"""Training step: value_and_grad over the model loss, AdamW update, iCh MoE
capacity-scale adaptation, optional microbatch accumulation and gradient
compression. Built to be `jax.jit`-ed with explicit in/out shardings by
launch/dryrun.py and launch/train.py.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..models import model as M
from ..models import moe as MOE
from ..optim import adamw
from ..optim import grad_compress as GC
from ..sched.defaults import ICH_EPS


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: adamw.AdamWConfig = adamw.AdamWConfig()
    microbatch: int = 0          # 0 = no accumulation
    grad_compress: bool = False  # int8 + error feedback on grads
    ich_eps: float = ICH_EPS     # MoE balancer epsilon (unified default)
    dtype: Any = jnp.bfloat16
    cast_params_once: bool = False  # bf16-cast the param tree BEFORE the
    # FSDP all-gathers (halves weight-gather wire + gathered traffic; §Perf)
    bf16_params: bool = False    # store params bf16 + fp32 master in opt
    # state — guarantees bf16 weight gathers AND bf16 grad reductions
    # (XLA may gather-then-convert under cast_params_once; measured §Perf)


def init_train_state(cfg, key, max_seq: int = 0, tcfg: TrainConfig = TrainConfig()):
    params = M.init_params(cfg, key, max_seq)
    opt = adamw.init_state(params)
    if tcfg.bf16_params:
        opt["master"] = params
        params = jax.tree.map(
            lambda t: t.astype(jnp.bfloat16) if t.dtype == jnp.float32 else t,
            params)
    state = {
        "params": params,
        "opt": opt,
        "cap_scales": jnp.ones((M.n_moe_layers(cfg), max(cfg.n_experts, 1)),
                               jnp.float32),
    }
    if tcfg.grad_compress:
        state["grad_err"] = GC.init_error_state(params)
    return state


def train_state_pspecs(cfg, tp: int = 16, max_seq: int = 0,
                       tcfg: TrainConfig = TrainConfig()):
    pp = M.param_pspecs(cfg, tp, max_seq)
    op = adamw.opt_pspecs(pp)
    if tcfg.bf16_params:
        op["master"] = jax.tree.map(lambda x: x, pp)
    ps = {
        "params": pp,
        "opt": op,
        "cap_scales": P(None, None),
    }
    if tcfg.grad_compress:
        ps["grad_err"] = jax.tree.map(lambda x: x, pp)
    return ps


def batch_pspec(cfg, batch_axes=("data",)):
    b = tuple(batch_axes)
    spec = {"tokens": P(b, None), "labels": P(b, None)}
    if cfg.family == "encdec":
        spec["frames"] = P(b, None, None)
    if cfg.family == "vlm":
        spec["patches"] = P(b, None, None)
    return spec


def make_train_step(cfg, tcfg: TrainConfig = TrainConfig(), dist=None):
    """Returns step(state, batch) -> (state, metrics)."""

    def loss_for_grad(params, batch, cap_scales):
        if tcfg.cast_params_once:
            params = jax.tree.map(
                lambda t: t.astype(tcfg.dtype)
                if t.dtype == jnp.float32 else t, params)
        loss, metrics = M.loss_fn(cfg, params, batch, cap_scales,
                                  dist=dist, dtype=tcfg.dtype)
        return loss, metrics

    def step(state, batch):
        caps = state["cap_scales"] if cfg.moe else None

        if tcfg.microbatch > 1:
            mb = tcfg.microbatch

            def split(x):
                b = x.shape[0]
                return x.reshape(mb, b // mb, *x.shape[1:])

            mbatch = jax.tree.map(split, batch)

            def acc_step(carry, micro):
                g_acc, l_acc = carry
                (loss, metrics), grads = jax.value_and_grad(
                    loss_for_grad, has_aux=True)(state["params"], micro, caps)
                g_acc = jax.tree.map(jnp.add, g_acc, grads)
                return (g_acc, l_acc + loss), metrics

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])
            (grads, loss_sum), metrics = jax.lax.scan(
                acc_step, (zeros, jnp.zeros((), jnp.float32)), mbatch)
            grads = jax.tree.map(lambda g: g / mb, grads)
            metrics = jax.tree.map(lambda m: m[-1], metrics)
            metrics["loss"] = loss_sum / mb
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_for_grad, has_aux=True)(state["params"], batch, caps)

        new_state = dict(state)
        if tcfg.grad_compress:
            grads, new_err = GC.tree_compress(grads, state["grad_err"])
            new_state["grad_err"] = new_err

        if tcfg.bf16_params:
            master = state["opt"]["master"]
            new_master, new_opt, opt_metrics = adamw.apply_updates(
                master, grads, {k: v for k, v in state["opt"].items()
                                if k != "master"}, tcfg.opt)
            new_opt["master"] = new_master
            new_params = jax.tree.map(
                lambda t: t.astype(jnp.bfloat16)
                if t.dtype == jnp.float32 else t, new_master)
        else:
            new_params, new_opt, opt_metrics = adamw.apply_updates(
                state["params"], grads, state["opt"], tcfg.opt)
        new_state["params"] = new_params
        new_state["opt"] = new_opt
        metrics.update(opt_metrics)

        if cfg.moe:
            counts = metrics.pop("counts")  # (n_moe_layers, E)
            new_state["cap_scales"] = jax.vmap(
                partial(MOE.ich_update_cap_scale, eps=tcfg.ich_eps)
            )(counts, state["cap_scales"])
        return new_state, metrics

    return step
