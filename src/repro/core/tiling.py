"""iCh schedule construction: the paper's band heuristic as a tiling layer.

On a TPU the grid of a `pallas_call` is static, so iCh's *runtime* chunk
adaptation becomes *schedule construction* on the host (DESIGN.md §2): given
per-item work sizes (nnz per CSR row, frontier degree per vertex, predicted
cost per K-Means point), we

1. pick a tile width W with the paper's variance band (eqs. 1-3, 8):
   W = pow2-roundup of mu * (1 + eps), so every "normal"-classified item fits
   in one segment (`ich_tile_width`);
2. split items wider than W into W-sized segments (`split_items`) — the
   work-stealing analogue: a heavy item's overflow migrates to later tiles
   exactly like stolen iterations;
3. greedily pack segments, in order, into fixed-shape tiles of R segment
   slots each (`build_schedule`), yielding a `TileSchedule` whose
   `item_id` array is the scalar-prefetch schedule a kernel consumes.

Every kernel under `repro/kernels/ich_*` builds its schedule here; `pack_csr`
additionally gathers CSR payloads into the (T, R, W) layout. The schedule is
cross-checkable against the discrete-event simulator: `slot_ranges()` maps
tiles to contiguous chunks in flattened work-unit space, which can be handed
to `simulate(..., policies.pretiled(ranges), record_chunks=True)` — the
simulator's per-chunk work must equal `tile_cost` (see
benchmarks/bench_ich_kernels.py and tests/test_tiling.py).

Construction is fully vectorized (DESIGN.md §2.5): segment counts come from a
ceil-div, segment/unit coordinates from `cumsum`/`repeat` de-flattening, and
payload packing from one fancy-gather — no Python-level per-segment or
per-nonzero loop anywhere on the construction path, so a schedule over
millions of items builds in milliseconds (benchmarks/bench_schedule_build.py
tracks the trajectory in BENCH_schedule.json). The original loop
formulations are kept as `_reference_*` oracles; tests assert equality.
"""
from __future__ import annotations

import dataclasses
import threading
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro.sched.defaults import ICH_EPS

# ---------------------------------------------------------------------------
# Construction workspace: schedule construction is a per-request operation in
# a serving path, so its temporaries (a few MB per million items) are reused
# across calls instead of being re-allocated (and re-page-faulted) every
# time. Only scratch lives here — every array handed back to a caller is
# freshly allocated. Guarded by a lock: construction is thread-safe, calls
# just serialize over the scratch. The helper pool overlaps the two
# independent gather passes on a second core (NumPy's take/repeat release
# the GIL).
# ---------------------------------------------------------------------------
_WS: dict[str, np.ndarray] = {}
_WS_LOCK = threading.Lock()
_POOL = ThreadPoolExecutor(max_workers=1,
                           thread_name_prefix="tiling-gather")


def _ws(name: str, n: int, dtype) -> np.ndarray:
    """A reusable scratch vector of at least n elements (prefix view)."""
    buf = _WS.get(name)
    if buf is None or buf.size < n or buf.dtype != np.dtype(dtype):
        grow = 0 if buf is None else buf.size * 2
        buf = np.empty(max(n, grow, 1024), dtype)
        _WS[name] = buf
    return buf[:n]


def _ws_iota(n: int, dtype=np.int32) -> np.ndarray:
    """Persistent [0, 1, 2, ...] prefix (never recomputed), one per dtype —
    callers indexing past 2**31 units must ask for the int64 variant (an
    int32 arange would silently wrap)."""
    key = f"iota_{np.dtype(dtype).name}"
    buf = _WS.get(key)
    if buf is None or buf.size < n:
        grow = 0 if buf is None else buf.size * 2
        buf = np.arange(max(n, grow, 1024), dtype=dtype)
        _WS[key] = buf
    return buf[:n]


def ich_tile_width(sizes: np.ndarray, eps: float = ICH_EPS,
                   min_w: int = 8, max_w: int = 512) -> int:
    """Pick the tile width with the paper's band (eqs. 1-3, 8).

    W = the band's UPPER edge mu*(1+eps), rounded up to a power of two:
    every "normal"-classified item (within mu +- eps*mu) fits in one segment;
    only "high" items split across tiles — the work-stealing analogue (their
    overflow migrates to later tiles). A multiplicative walk (adapt_d per
    chunk) has no equilibrium on a static distribution — measured in
    benchmarks/bench_ich_spmv.py — so schedule construction uses the band
    directly; the runtime walk remains correct where k_i is cumulative
    (simulator/executor/serving).
    """
    mu = float(np.mean(sizes))
    upper = mu * (1.0 + eps)
    w = 2 ** int(np.ceil(np.log2(max(upper, 1.0))))
    return int(min(max(w, min_w), max_w))


def split_items(
        sizes: np.ndarray, width: int
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Cut items into width-W segments: (item, start_in_item, length) arrays.

    Segments are emitted in item order; a zero-size item still emits one
    zero-length segment so every item owns at least one slot (kernels rely on
    this to e.g. zero an empty CSR row's output).

    Vectorized: item i emits max(ceil(sizes[i]/W), 1) segments, so the
    segment->item map is one `repeat` of iota; every other per-segment
    stream is a `take` through that map (a segment's rank within its item is
    its global rank minus its item's exclusive-prefix segment count, one
    `cumsum`), and start/length follow with in-place int32 arithmetic.
    Per-item sizes and the total segment count must fit int32 (a single item
    is bounded at 2**31-1 work units). `_reference_split_items` is the loop
    oracle.
    """
    if int(width) <= 0:
        raise ValueError(f"tile width must be positive, got {width}")
    if np.asarray(sizes).size == 0:
        empty = np.empty(0, np.int32)
        return empty, empty.copy(), empty.copy()
    item, start, length, _ = _split_segments(sizes, width, 1)
    return item, start, length


def _split_segments(
        sizes: np.ndarray, width: int, round_to: int
) -> tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """Segment streams padded to a multiple of `round_to` slots.

    Returns (item, start, length, n_segs): the first n_segs entries are real
    segments in item order, the (< round_to) tail is padding with item -1
    and start/length 0 — exactly the slot layout `build_schedule` reshapes
    to (T, R). The returned arrays are caller-owned; only scratch comes from
    the shared workspace (see the module comment on `_WS`).
    """
    sizes_arr = np.asarray(sizes)
    if sizes_arr.size and \
            int(sizes_arr.max()) > np.iinfo(np.int32).max - max(int(width), 1):
        raise ValueError("per-item sizes must fit int32; largest item is "
                         f"{int(sizes_arr.max())} work units")
    s32 = sizes_arr.astype(np.int32, copy=False)
    w = np.int32(width)
    n = s32.size
    with _WS_LOCK:
        n_segs = _ws("n_segs", n, np.int32)
        np.add(s32, np.int32(width - 1), out=n_segs)
        np.floor_divide(n_segs, w, out=n_segs)
        np.maximum(n_segs, np.int32(1), out=n_segs)
        total = int(n_segs.sum(dtype=np.int64))
        if total > np.iinfo(np.int32).max:
            raise ValueError(f"schedule would need {total} segments, which "
                             "exceeds the int32 construction bound")
        cum = _ws("cum", n, np.int32)
        np.cumsum(n_segs, out=cum)
        padded = -(-max(total, 1) // round_to) * round_to
        first = _ws("first", n, np.int32)
        np.subtract(cum, n_segs, out=first)  # exclusive-prefix seg counts
        item = np.repeat(_ws_iota(n), n_segs)
        start = np.empty(padded, np.int32)
        length = np.empty(padded, np.int32)
        # the two gathers through `item` are independent: run one on the
        # helper thread while this thread does the other (below the
        # threshold the pool handoff costs more than it overlaps)
        first_rep = _ws("first_rep", total, np.int32)
        fut = (_POOL.submit(np.take, first, item, out=first_rep, mode="clip")
               if total >= 65_536 else
               np.take(first, item, out=first_rep, mode="clip"))
        np.take(s32, item, out=length[:total], mode="clip")
        if fut is not first_rep:
            fut.result()
        np.subtract(_ws_iota(total), first_rep, out=start[:total])
        np.multiply(start[:total], w, out=start[:total])
        # length = clip(size - start, 0, W)
        np.subtract(length[:total], start[:total], out=length[:total])
        np.clip(length[:total], 0, w, out=length[:total])
    item.resize(padded, refcheck=False)  # zero-fills the (< round_to) tail
    item[total:] = -1
    start[total:] = 0
    length[total:] = 0
    return item, start, length, total


def _reference_split_items(sizes: np.ndarray,
                           width: int) -> list[tuple[int, int, int]]:
    """Loop oracle for `split_items` (one tuple per segment, same order)."""
    if int(width) <= 0:
        raise ValueError(f"tile width must be positive, got {width}")
    segs: list[tuple[int, int, int]] = []
    for i, size in enumerate(np.asarray(sizes)):
        size = int(size)
        for s in range(0, max(size, 1), width):
            segs.append((i, s, min(width, size - s) if size else 0))
    return segs


@dataclasses.dataclass(frozen=True)
class TileSchedule:
    """An iCh-constructed static schedule: T tiles x R segment slots.

    `item_id[t, j]` is the item whose segment occupies slot (t, j), or -1 for
    a padding slot; `seg_start`/`seg_len` locate the segment within the item
    (in work units: nonzeros, edges, cost quanta). `item_id` is what a kernel
    prefetches to SMEM as its scatter/gather schedule.
    """

    item_id: np.ndarray    # (T, R) int32, -1 = padding slot
    seg_start: np.ndarray  # (T, R) int32
    seg_len: np.ndarray    # (T, R) int32
    width: int             # W: work-unit capacity of one slot
    n_items: int

    @property
    def n_tiles(self) -> int:
        return int(self.item_id.shape[0])

    @property
    def rows_per_tile(self) -> int:
        return int(self.item_id.shape[1])

    def tile_work(self) -> np.ndarray:
        """Work units (e.g. nonzeros) packed into each tile, shape (T,)."""
        return self.seg_len.sum(axis=1).astype(np.int64)

    def tile_cost(self, costs: np.ndarray, sizes: np.ndarray) -> np.ndarray:
        """Per-tile cost when item i's cost is spread evenly over its
        `sizes[i]` work units (zero-size items carry no units). This is the
        quantity the discrete-event simulator must reproduce chunk-by-chunk
        for the pretiled schedule — see `slot_ranges`."""
        costs = np.asarray(costs, np.float64)
        sizes = np.asarray(sizes, np.float64)
        unit = np.divide(costs, sizes, out=np.zeros_like(costs),
                         where=sizes > 0)
        per_slot = np.where(self.item_id >= 0,
                            unit[np.clip(self.item_id, 0, self.n_items - 1)],
                            0.0)
        return (per_slot * self.seg_len).sum(axis=1)

    def slot_ranges(self) -> np.ndarray:
        """(T, 2) [begin, end) chunks in flattened work-unit space.

        Greedy packing keeps segments in item order, so each tile covers a
        contiguous run of work units — i.e. the schedule IS a pretiled
        central-queue chunking, directly consumable by
        `simulate(unit_costs, p, policies.pretiled(ranges))`.
        """
        cum = np.concatenate([[0], np.cumsum(self.seg_len.reshape(-1))])
        bounds = cum[::self.rows_per_tile]  # len T*R+1 strided by R -> T+1
        return np.stack([bounds[:-1], bounds[1:]], axis=1).astype(np.int64)

    def unit_costs(self, costs: np.ndarray, sizes: np.ndarray) -> np.ndarray:
        """Expand per-item costs to the flattened work-unit cost array that
        `slot_ranges` indexes into (item i -> sizes[i] units of equal cost)."""
        costs = np.asarray(costs, np.float64)
        sizes = np.asarray(sizes, np.int64)
        unit = np.divide(costs, sizes, out=np.zeros_like(costs),
                         where=sizes > 0)
        return np.repeat(unit, sizes)


def _check_width(width: int | None) -> int | None:
    if width is not None and int(width) <= 0:
        raise ValueError(f"explicit tile width must be positive, got {width}")
    return None if width is None else int(width)


def build_schedule(sizes: np.ndarray, *, rows_per_tile: int = 8,
                   width: int | None = None, eps: float = ICH_EPS,
                   min_w: int = 8, max_w: int = 512) -> TileSchedule:
    """Band -> W -> segments -> greedy packing into (T, R) slots.

    Packing is a reshape: segments are already in pack order, so tile t's
    slots are segments [t*R, (t+1)*R) and the only real work is padding the
    segment axis out to T*R. `_reference_build_schedule` is the loop oracle.
    """
    sizes = np.asarray(sizes)
    if sizes.size == 0:
        raise ValueError("cannot build a schedule from an empty sizes array")
    width = _check_width(width)
    W = width if width else ich_tile_width(sizes, eps, min_w, max_w)
    R = int(rows_per_tile)
    item_id, seg_start, seg_len, _ = _split_segments(sizes, W, R)
    T = item_id.size // R
    return TileSchedule(item_id.reshape(T, R), seg_start.reshape(T, R),
                        seg_len.reshape(T, R), W, len(sizes))


def _reference_build_schedule(sizes: np.ndarray, *, rows_per_tile: int = 8,
                              width: int | None = None, eps: float = ICH_EPS,
                              min_w: int = 8,
                              max_w: int = 512) -> TileSchedule:
    """Loop oracle for `build_schedule` (per-segment placement loop)."""
    sizes = np.asarray(sizes)
    if sizes.size == 0:
        raise ValueError("cannot build a schedule from an empty sizes array")
    width = _check_width(width)
    W = width if width else ich_tile_width(sizes, eps, min_w, max_w)
    R = int(rows_per_tile)
    segs = _reference_split_items(sizes, W)
    T = -(-len(segs) // R)
    item_id = np.full((T, R), -1, np.int32)
    seg_start = np.zeros((T, R), np.int32)
    seg_len = np.zeros((T, R), np.int32)
    for i, (item, s, ln) in enumerate(segs):
        t, j = divmod(i, R)
        item_id[t, j] = item
        seg_start[t, j] = s
        seg_len[t, j] = ln
    return TileSchedule(item_id, seg_start, seg_len, W, len(sizes))


def _unit_coords(schedule: TileSchedule) -> tuple[np.ndarray, np.ndarray]:
    """De-flatten the schedule to work-unit granularity: (slot, pos) where
    `slot` is the flat (t*R + j) slot owning each unit and `pos` the unit's
    rank within its segment. One `repeat` + one `cumsum`. Used by
    `coverage_counts`; `pack_csr` re-derives the same coordinates inline in
    workspace int32 (its hot path fuses them into src/dst index builds)."""
    seg_len = schedule.seg_len.reshape(-1).astype(np.int64)
    slot = np.repeat(np.arange(seg_len.size, dtype=np.int64), seg_len)
    first = np.repeat(np.cumsum(seg_len) - seg_len, seg_len)
    pos = np.arange(int(seg_len.sum()), dtype=np.int64) - first
    return slot, pos


def pack_csr(indptr: np.ndarray, indices: np.ndarray, data: np.ndarray,
             schedule: TileSchedule) -> tuple[np.ndarray, np.ndarray]:
    """Gather CSR payloads into the schedule's (T, R, W) layout.

    Returns (vals, cols); padding slots/tails are zero, so sum-reductions
    over W need no masking (and vals doubles as a validity mask when the
    payload is all-ones, as in BFS).

    Vectorized: every scheduled work unit's CSR source index is
    indptr[item] + seg_start + pos and its destination is slot*W + pos, so
    the whole packing is one gather + one (sorted-index) scatter per payload
    array, with the vals and cols chains overlapped on the helper thread.
    Index arithmetic runs in int32 through the construction workspace when
    nnz and T*R*W fit (the int64 general case takes the same path, just
    wider). `_reference_pack_csr` is the loop oracle.
    """
    indices = np.asarray(indices)
    data = np.asarray(data)
    T, R, W = schedule.n_tiles, schedule.rows_per_tile, schedule.width
    n_slots = T * R
    trw = n_slots * W
    vals = np.zeros(trw, data.dtype)
    cols = np.zeros(trw, np.int32)
    with _WS_LOCK:
        len_f = schedule.seg_len.reshape(-1)
        cum = _ws("pk_cum", n_slots, np.int64)
        np.cumsum(len_f, out=cum)
        total = int(cum[-1])
        dt = np.int32 if max(trw, int(indptr[-1])) < 2 ** 31 else np.int64
        # per-slot CSR base: indptr[item] + seg_start (padding slots have
        # len 0 and contribute no units, so their wrapped base is never read)
        base = _ws("pk_base", n_slots, dt)
        np.take(np.asarray(indptr).astype(dt, copy=False),
                schedule.item_id.reshape(-1), out=base, mode="wrap")
        base += schedule.seg_start.reshape(-1)
        first = _ws("pk_first", n_slots, dt)
        np.subtract(cum, len_f, out=first, casting="unsafe")
        # slot/unit iotas in dt: int32 arange would wrap past 2**31 units,
        # which is exactly when the wide path is selected
        slot = np.repeat(_ws_iota(n_slots, dt), len_f)
        # pos = unit rank within its segment; src = CSR source per unit
        pos = _ws("pk_pos", total, dt)
        np.take(first, slot, out=pos, mode="clip")
        np.subtract(_ws_iota(total, dt), pos, out=pos)
        src = _ws("pk_src", total, dt)
        np.take(base, slot, out=src, mode="clip")
        src += pos
        dst = _ws("pk_dst", total, dt)
        np.multiply(slot, dt(W), out=dst)  # dst = slot*W + pos, all in dt
        dst += pos
        # vals chain on the helper thread, cols chain here
        def _scatter(dst_flat, payload, srcidx, out):
            out[dst_flat] = np.take(payload, srcidx)

        fut = (_POOL.submit(_scatter, dst, data, src, vals)
               if total >= 65_536 else _scatter(dst, data, src, vals))
        cols[dst] = np.take(indices, src)
        if fut is not None:
            fut.result()
    return vals.reshape(T, R, W), cols.reshape(T, R, W)


def _reference_pack_csr(indptr: np.ndarray, indices: np.ndarray,
                        data: np.ndarray,
                        schedule: TileSchedule) -> tuple[np.ndarray,
                                                         np.ndarray]:
    """Loop oracle for `pack_csr` (per-slot copy loop)."""
    T, R, W = schedule.n_tiles, schedule.rows_per_tile, schedule.width
    vals = np.zeros((T, R, W), np.asarray(data).dtype)
    cols = np.zeros((T, R, W), np.int32)
    for t in range(T):
        for j in range(R):
            item, s, ln = (int(schedule.item_id[t, j]),
                           int(schedule.seg_start[t, j]),
                           int(schedule.seg_len[t, j]))
            if item >= 0 and ln > 0:
                base = int(indptr[item]) + s
                vals[t, j, :ln] = data[base:base + ln]
                cols[t, j, :ln] = indices[base:base + ln]
    return vals, cols


def coverage_counts(schedule: TileSchedule, sizes: np.ndarray) -> np.ndarray:
    """How many times each item's work units appear in the schedule; a valid
    schedule covers every unit exactly once (tests/test_tiling.py).

    Vectorized: each scheduled unit's global position is
    offsets[item] + seg_start + pos; the histogram is one `bincount`.
    `_reference_coverage_counts` is the loop oracle."""
    sizes = np.asarray(sizes, np.int64)
    offsets = np.concatenate([[0], np.cumsum(sizes)])
    total = int(offsets[-1])
    item_f = schedule.item_id.reshape(-1).astype(np.int64)
    start_f = schedule.seg_start.reshape(-1).astype(np.int64)
    slot, pos = _unit_coords(schedule)
    where = offsets[item_f[slot]] + start_f[slot] + pos
    return np.bincount(where, minlength=total).astype(np.int64)


def _reference_coverage_counts(schedule: TileSchedule,
                               sizes: np.ndarray) -> np.ndarray:
    """Loop oracle for `coverage_counts` (per-slot increment loop)."""
    sizes = np.asarray(sizes, np.int64)
    offsets = np.concatenate([[0], np.cumsum(sizes)])
    counts = np.zeros(int(offsets[-1]), np.int64)
    for t in range(schedule.n_tiles):
        for j in range(schedule.rows_per_tile):
            item = int(schedule.item_id[t, j])
            ln = int(schedule.seg_len[t, j])
            if item >= 0 and ln > 0:
                b = int(offsets[item]) + int(schedule.seg_start[t, j])
                counts[b:b + ln] += 1
    return counts
