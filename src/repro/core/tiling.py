"""iCh schedule construction: the paper's band heuristic as a tiling layer.

On a TPU the grid of a `pallas_call` is static, so iCh's *runtime* chunk
adaptation becomes *schedule construction* on the host (DESIGN.md §2): given
per-item work sizes (nnz per CSR row, frontier degree per vertex, predicted
cost per K-Means point), we

1. pick a tile width W with the paper's variance band (eqs. 1-3, 8):
   W = pow2-roundup of mu * (1 + eps), so every "normal"-classified item fits
   in one segment (`ich_tile_width`);
2. split items wider than W into W-sized segments (`split_items`) — the
   work-stealing analogue: a heavy item's overflow migrates to later tiles
   exactly like stolen iterations;
3. greedily pack segments, in order, into fixed-shape tiles of R segment
   slots each (`build_schedule`), yielding a `TileSchedule` whose
   `item_id` array is the scalar-prefetch schedule a kernel consumes.

Every kernel under `repro/kernels/ich_*` builds its schedule here; `pack_csr`
additionally gathers CSR payloads into the (T, R, W) layout. The schedule is
cross-checkable against the discrete-event simulator: `slot_ranges()` maps
tiles to contiguous chunks in flattened work-unit space, which can be handed
to `simulate(..., policies.pretiled(ranges), record_chunks=True)` — the
simulator's per-chunk work must equal `tile_cost` (see
benchmarks/bench_ich_kernels.py and tests/test_tiling.py).
"""
from __future__ import annotations

import dataclasses

import numpy as np


def ich_tile_width(sizes: np.ndarray, eps: float = 0.33,
                   min_w: int = 8, max_w: int = 512) -> int:
    """Pick the tile width with the paper's band (eqs. 1-3, 8).

    W = the band's UPPER edge mu*(1+eps), rounded up to a power of two:
    every "normal"-classified item (within mu +- eps*mu) fits in one segment;
    only "high" items split across tiles — the work-stealing analogue (their
    overflow migrates to later tiles). A multiplicative walk (adapt_d per
    chunk) has no equilibrium on a static distribution — measured in
    benchmarks/bench_ich_spmv.py — so schedule construction uses the band
    directly; the runtime walk remains correct where k_i is cumulative
    (simulator/executor/serving).
    """
    mu = float(np.mean(sizes))
    upper = mu * (1.0 + eps)
    w = 2 ** int(np.ceil(np.log2(max(upper, 1.0))))
    return int(min(max(w, min_w), max_w))


def split_items(sizes: np.ndarray, width: int) -> list[tuple[int, int, int]]:
    """Cut items into width-W segments: [(item, start_in_item, length), ...].

    Segments are emitted in item order; a zero-size item still emits one
    zero-length segment so every item owns at least one slot (kernels rely on
    this to e.g. zero an empty CSR row's output).
    """
    segs: list[tuple[int, int, int]] = []
    for i, size in enumerate(np.asarray(sizes)):
        size = int(size)
        for s in range(0, max(size, 1), width):
            segs.append((i, s, min(width, size - s) if size else 0))
    return segs


@dataclasses.dataclass(frozen=True)
class TileSchedule:
    """An iCh-constructed static schedule: T tiles x R segment slots.

    `item_id[t, j]` is the item whose segment occupies slot (t, j), or -1 for
    a padding slot; `seg_start`/`seg_len` locate the segment within the item
    (in work units: nonzeros, edges, cost quanta). `item_id` is what a kernel
    prefetches to SMEM as its scatter/gather schedule.
    """

    item_id: np.ndarray    # (T, R) int32, -1 = padding slot
    seg_start: np.ndarray  # (T, R) int32
    seg_len: np.ndarray    # (T, R) int32
    width: int             # W: work-unit capacity of one slot
    n_items: int

    @property
    def n_tiles(self) -> int:
        return int(self.item_id.shape[0])

    @property
    def rows_per_tile(self) -> int:
        return int(self.item_id.shape[1])

    def tile_work(self) -> np.ndarray:
        """Work units (e.g. nonzeros) packed into each tile, shape (T,)."""
        return self.seg_len.sum(axis=1).astype(np.int64)

    def tile_cost(self, costs: np.ndarray, sizes: np.ndarray) -> np.ndarray:
        """Per-tile cost when item i's cost is spread evenly over its
        `sizes[i]` work units (zero-size items carry no units). This is the
        quantity the discrete-event simulator must reproduce chunk-by-chunk
        for the pretiled schedule — see `slot_ranges`."""
        costs = np.asarray(costs, np.float64)
        sizes = np.asarray(sizes, np.float64)
        unit = np.divide(costs, sizes, out=np.zeros_like(costs),
                         where=sizes > 0)
        per_slot = np.where(self.item_id >= 0,
                            unit[np.clip(self.item_id, 0, self.n_items - 1)],
                            0.0)
        return (per_slot * self.seg_len).sum(axis=1)

    def slot_ranges(self) -> np.ndarray:
        """(T, 2) [begin, end) chunks in flattened work-unit space.

        Greedy packing keeps segments in item order, so each tile covers a
        contiguous run of work units — i.e. the schedule IS a pretiled
        central-queue chunking, directly consumable by
        `simulate(unit_costs, p, policies.pretiled(ranges))`.
        """
        cum = np.concatenate([[0], np.cumsum(self.seg_len.reshape(-1))])
        bounds = cum[::self.rows_per_tile]  # len T*R+1 strided by R -> T+1
        return np.stack([bounds[:-1], bounds[1:]], axis=1).astype(np.int64)

    def unit_costs(self, costs: np.ndarray, sizes: np.ndarray) -> np.ndarray:
        """Expand per-item costs to the flattened work-unit cost array that
        `slot_ranges` indexes into (item i -> sizes[i] units of equal cost)."""
        costs = np.asarray(costs, np.float64)
        sizes = np.asarray(sizes, np.int64)
        unit = np.divide(costs, sizes, out=np.zeros_like(costs),
                         where=sizes > 0)
        return np.repeat(unit, sizes)


def build_schedule(sizes: np.ndarray, *, rows_per_tile: int = 8,
                   width: int | None = None, eps: float = 0.33,
                   min_w: int = 8, max_w: int = 512) -> TileSchedule:
    """Band -> W -> segments -> greedy packing into (T, R) slots."""
    sizes = np.asarray(sizes)
    if sizes.size == 0:
        raise ValueError("cannot build a schedule from an empty sizes array")
    W = int(width) if width else ich_tile_width(sizes, eps, min_w, max_w)
    R = int(rows_per_tile)
    segs = split_items(sizes, W)
    T = -(-len(segs) // R)
    item_id = np.full((T, R), -1, np.int32)
    seg_start = np.zeros((T, R), np.int32)
    seg_len = np.zeros((T, R), np.int32)
    for i, (item, s, ln) in enumerate(segs):
        t, j = divmod(i, R)
        item_id[t, j] = item
        seg_start[t, j] = s
        seg_len[t, j] = ln
    return TileSchedule(item_id, seg_start, seg_len, W, len(sizes))


def pack_csr(indptr: np.ndarray, indices: np.ndarray, data: np.ndarray,
             schedule: TileSchedule) -> tuple[np.ndarray, np.ndarray]:
    """Gather CSR payloads into the schedule's (T, R, W) layout.

    Returns (vals, cols); padding slots/tails are zero, so sum-reductions
    over W need no masking (and vals doubles as a validity mask when the
    payload is all-ones, as in BFS).
    """
    T, R, W = schedule.n_tiles, schedule.rows_per_tile, schedule.width
    vals = np.zeros((T, R, W), data.dtype)
    cols = np.zeros((T, R, W), np.int32)
    for t in range(T):
        for j in range(R):
            item, s, ln = (int(schedule.item_id[t, j]),
                           int(schedule.seg_start[t, j]),
                           int(schedule.seg_len[t, j]))
            if item >= 0 and ln > 0:
                base = int(indptr[item]) + s
                vals[t, j, :ln] = data[base:base + ln]
                cols[t, j, :ln] = indices[base:base + ln]
    return vals, cols


def coverage_counts(schedule: TileSchedule, sizes: np.ndarray) -> np.ndarray:
    """How many times each item's work units appear in the schedule; a valid
    schedule covers every unit exactly once (tests/test_tiling.py)."""
    sizes = np.asarray(sizes, np.int64)
    offsets = np.concatenate([[0], np.cumsum(sizes)])
    counts = np.zeros(int(offsets[-1]), np.int64)
    for t in range(schedule.n_tiles):
        for j in range(schedule.rows_per_tile):
            item = int(schedule.item_id[t, j])
            ln = int(schedule.seg_len[t, j])
            if item >= 0 and ln > 0:
                b = int(offsets[item]) + int(schedule.seg_start[t, j])
                counts[b:b + ln] += 1
    return counts
