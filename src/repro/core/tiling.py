"""iCh schedule construction: the paper's band heuristic as a tiling layer.

On a TPU the grid of a `pallas_call` is static, so iCh's *runtime* chunk
adaptation becomes *schedule construction* on the host (DESIGN.md §2): given
per-item work sizes (nnz per CSR row, frontier degree per vertex, predicted
cost per K-Means point), we

1. pick a tile width W with the paper's variance band (eqs. 1-3, 8):
   W = pow2-roundup of mu * (1 + eps), so every "normal"-classified item fits
   in one segment (`ich_tile_width`);
2. split items wider than W into W-sized segments (`split_items`) — the
   work-stealing analogue: a heavy item's overflow migrates to later tiles
   exactly like stolen iterations;
3. greedily pack segments, in order, into fixed-shape tiles of R segment
   slots each (`build_schedule`), yielding a `TileSchedule` whose
   `item_id` array is the scalar-prefetch schedule a kernel consumes.

Every kernel under `repro/kernels/ich_*` builds its schedule here; `pack_csr`
additionally packs CSR payloads into the (T, R, W) layout (optionally padded
to whole supersteps). The sharding layer (DESIGN.md §2.6) lowers the
schedule's parallelism p onto the accelerator: `partition_tiles`
LPT-assigns item-closed chains of superstep blocks to workers by tile cost
and `make_shards`/`shard_schedule` lay the result out as the (p, S_B)
block permutation whose blocks the 2D kernels fetch straight out of the
flat payload — lowering moves no payload bytes. The schedule is
cross-checkable against the discrete-event simulator: `slot_ranges()` maps
tiles to contiguous chunks in flattened work-unit space, which can be handed
to `simulate(..., policies.pretiled(ranges), record_chunks=True)` — the
simulator's per-chunk work must equal `tile_cost` (see
benchmarks/bench_ich_kernels.py and tests/test_tiling.py) — and the worker
partition replays the same way through `policies.assigned`
(tests/test_sharding.py).

Construction is fully vectorized (DESIGN.md §2.5): segment counts come from a
ceil-div, segment/unit coordinates from `cumsum`/`repeat` de-flattening, and
payload packing from one fancy-gather — no Python-level per-segment or
per-nonzero loop anywhere on the construction path, so a schedule over
millions of items builds in milliseconds (benchmarks/bench_schedule_build.py
tracks the trajectory in BENCH_schedule.json). The original loop
formulations are kept as `_reference_*` oracles; tests assert equality.
"""
from __future__ import annotations

import dataclasses
import heapq
import threading
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro.sched.defaults import ICH_EPS, SUPERSTEP

# ---------------------------------------------------------------------------
# Construction workspace: schedule construction is a per-request operation in
# a serving path, so its temporaries (a few MB per million items) are reused
# across calls instead of being re-allocated (and re-page-faulted) every
# time. Only scratch lives here — every array handed back to a caller is
# freshly allocated. Guarded by a lock: construction is thread-safe, calls
# just serialize over the scratch. The helper pool overlaps the two
# independent gather passes on a second core (NumPy's take/repeat release
# the GIL).
# ---------------------------------------------------------------------------
_WS: dict[str, np.ndarray] = {}
_WS_LOCK = threading.Lock()
_POOL = ThreadPoolExecutor(max_workers=1,
                           thread_name_prefix="tiling-gather")


def _ws(name: str, n: int, dtype) -> np.ndarray:
    """A reusable scratch vector of at least n elements (prefix view)."""
    buf = _WS.get(name)
    if buf is None or buf.size < n or buf.dtype != np.dtype(dtype):
        grow = 0 if buf is None else buf.size * 2
        buf = np.empty(max(n, grow, 1024), dtype)
        _WS[name] = buf
    return buf[:n]


def _ws_iota(n: int, dtype=np.int32) -> np.ndarray:
    """Persistent [0, 1, 2, ...] prefix (never recomputed), one per dtype —
    callers indexing past 2**31 units must ask for the int64 variant (an
    int32 arange would silently wrap)."""
    key = f"iota_{np.dtype(dtype).name}"
    buf = _WS.get(key)
    if buf is None or buf.size < n:
        grow = 0 if buf is None else buf.size * 2
        buf = np.arange(max(n, grow, 1024), dtype=dtype)
        _WS[key] = buf
    return buf[:n]


def ich_tile_width(sizes: np.ndarray, eps: float = ICH_EPS,
                   min_w: int = 8, max_w: int = 512) -> int:
    """Pick the tile width with the paper's band (eqs. 1-3, 8).

    W = the band's UPPER edge mu*(1+eps), rounded up to a power of two:
    every "normal"-classified item (within mu +- eps*mu) fits in one segment;
    only "high" items split across tiles — the work-stealing analogue (their
    overflow migrates to later tiles). A multiplicative walk (adapt_d per
    chunk) has no equilibrium on a static distribution — measured in
    benchmarks/bench_ich_spmv.py — so schedule construction uses the band
    directly; the runtime walk remains correct where k_i is cumulative
    (simulator/executor/serving).
    """
    sizes = np.asarray(sizes)
    mu = float(np.mean(sizes)) if sizes.size else 0.0
    upper = mu * (1.0 + eps)
    w = 2 ** int(np.ceil(np.log2(max(upper, 1.0))))
    return int(min(max(w, min_w), max_w))


def split_items(
        sizes: np.ndarray, width: int
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Cut items into width-W segments: (item, start_in_item, length) arrays.

    Segments are emitted in item order; a zero-size item still emits one
    zero-length segment so every item owns at least one slot (kernels rely on
    this to e.g. zero an empty CSR row's output).

    Vectorized: item i emits max(ceil(sizes[i]/W), 1) segments, so the
    segment->item map is one `repeat` of iota; every other per-segment
    stream is a `take` through that map (a segment's rank within its item is
    its global rank minus its item's exclusive-prefix segment count, one
    `cumsum`), and start/length follow with in-place int32 arithmetic.
    Per-item sizes and the total segment count must fit int32 (a single item
    is bounded at 2**31-1 work units). `_reference_split_items` is the loop
    oracle.
    """
    if int(width) <= 0:
        raise ValueError(f"tile width must be positive, got {width}")
    if np.asarray(sizes).size == 0:
        empty = np.empty(0, np.int32)
        return empty, empty.copy(), empty.copy()
    item, start, length, _ = _split_segments(sizes, width, 1)
    return item, start, length


def _split_segments(
        sizes: np.ndarray, width: int, round_to: int
) -> tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """Segment streams padded to a multiple of `round_to` slots.

    Returns (item, start, length, n_segs): the first n_segs entries are real
    segments in item order, the (< round_to) tail is padding with item -1
    and start/length 0 — exactly the slot layout `build_schedule` reshapes
    to (T, R). The returned arrays are caller-owned; only scratch comes from
    the shared workspace (see the module comment on `_WS`).
    """
    sizes_arr = np.asarray(sizes)
    if sizes_arr.size and \
            int(sizes_arr.max()) > np.iinfo(np.int32).max - max(int(width), 1):
        raise ValueError("per-item sizes must fit int32; largest item is "
                         f"{int(sizes_arr.max())} work units")
    s32 = sizes_arr.astype(np.int32, copy=False)
    w = np.int32(width)
    n = s32.size
    with _WS_LOCK:
        n_segs = _ws("n_segs", n, np.int32)
        np.add(s32, np.int32(width - 1), out=n_segs)
        np.floor_divide(n_segs, w, out=n_segs)
        np.maximum(n_segs, np.int32(1), out=n_segs)
        total = int(n_segs.sum(dtype=np.int64))
        if total > np.iinfo(np.int32).max:
            raise ValueError(f"schedule would need {total} segments, which "
                             "exceeds the int32 construction bound")
        cum = _ws("cum", n, np.int32)
        np.cumsum(n_segs, out=cum)
        padded = -(-max(total, 1) // round_to) * round_to
        first = _ws("first", n, np.int32)
        np.subtract(cum, n_segs, out=first)  # exclusive-prefix seg counts
        item = np.repeat(_ws_iota(n), n_segs)
        start = np.empty(padded, np.int32)
        length = np.empty(padded, np.int32)
        # the two gathers through `item` are independent: run one on the
        # helper thread while this thread does the other (below the
        # threshold the pool handoff costs more than it overlaps)
        first_rep = _ws("first_rep", total, np.int32)
        fut = (_POOL.submit(np.take, first, item, out=first_rep, mode="clip")
               if total >= 65_536 else
               np.take(first, item, out=first_rep, mode="clip"))
        np.take(s32, item, out=length[:total], mode="clip")
        if fut is not first_rep:
            fut.result()
        np.subtract(_ws_iota(total), first_rep, out=start[:total])
        np.multiply(start[:total], w, out=start[:total])
        # length = clip(size - start, 0, W)
        np.subtract(length[:total], start[:total], out=length[:total])
        np.clip(length[:total], 0, w, out=length[:total])
    item.resize(padded, refcheck=False)  # zero-fills the (< round_to) tail
    item[total:] = -1
    start[total:] = 0
    length[total:] = 0
    return item, start, length, total


def _reference_split_items(sizes: np.ndarray,
                           width: int) -> list[tuple[int, int, int]]:
    """Loop oracle for `split_items` (one tuple per segment, same order)."""
    if int(width) <= 0:
        raise ValueError(f"tile width must be positive, got {width}")
    segs: list[tuple[int, int, int]] = []
    for i, size in enumerate(np.asarray(sizes)):
        size = int(size)
        for s in range(0, max(size, 1), width):
            segs.append((i, s, min(width, size - s) if size else 0))
    return segs


@dataclasses.dataclass(frozen=True)
class TileSchedule:
    """An iCh-constructed static schedule: T tiles x R segment slots.

    `item_id[t, j]` is the item whose segment occupies slot (t, j), or -1 for
    a padding slot; `seg_start`/`seg_len` locate the segment within the item
    (in work units: nonzeros, edges, cost quanta). `item_id` is what a kernel
    prefetches to SMEM as its scatter/gather schedule.
    """

    item_id: np.ndarray    # (T, R) int32, -1 = padding slot
    seg_start: np.ndarray  # (T, R) int32
    seg_len: np.ndarray    # (T, R) int32
    width: int             # W: work-unit capacity of one slot
    n_items: int

    @property
    def n_tiles(self) -> int:
        return int(self.item_id.shape[0])

    @property
    def rows_per_tile(self) -> int:
        return int(self.item_id.shape[1])

    def tile_work(self) -> np.ndarray:
        """Work units (e.g. nonzeros) packed into each tile, shape (T,)."""
        return self.seg_len.sum(axis=1).astype(np.int64)

    def slot_cost(self, costs: np.ndarray, sizes: np.ndarray) -> np.ndarray:
        """Per-SLOT cost decomposition, shape (T, R): item i's cost spread
        evenly over its `sizes[i]` work units, times the units each slot
        holds (padding slots and zero-size items are 0). Rows sum to
        `tile_cost`; this is the granularity the sharded kernels' cost
        output accounts at and the measured-cost refiner distributes
        tile-level observations with (`sched/adaptive.py`)."""
        costs = np.asarray(costs, np.float64)
        sizes = np.asarray(sizes, np.float64)
        unit = np.divide(costs, sizes, out=np.zeros_like(costs),
                         where=sizes > 0)
        per_slot = np.where(self.item_id >= 0,
                            unit[np.clip(self.item_id, 0, self.n_items - 1)],
                            0.0)
        return per_slot * self.seg_len

    def tile_cost(self, costs: np.ndarray, sizes: np.ndarray) -> np.ndarray:
        """Per-tile cost when item i's cost is spread evenly over its
        `sizes[i]` work units (zero-size items carry no units). This is the
        quantity the discrete-event simulator must reproduce chunk-by-chunk
        for the pretiled schedule — see `slot_ranges`."""
        return self.slot_cost(costs, sizes).sum(axis=1)

    def slot_ranges(self) -> np.ndarray:
        """(T, 2) [begin, end) chunks in flattened work-unit space.

        Greedy packing keeps segments in item order, so each tile covers a
        contiguous run of work units — i.e. the schedule IS a pretiled
        central-queue chunking, directly consumable by
        `simulate(unit_costs, p, policies.pretiled(ranges))`.
        """
        cum = np.concatenate([[0], np.cumsum(self.seg_len.reshape(-1))])
        bounds = cum[::self.rows_per_tile]  # len T*R+1 strided by R -> T+1
        return np.stack([bounds[:-1], bounds[1:]], axis=1).astype(np.int64)

    def unit_costs(self, costs: np.ndarray, sizes: np.ndarray) -> np.ndarray:
        """Expand per-item costs to the flattened work-unit cost array that
        `slot_ranges` indexes into (item i -> sizes[i] units of equal cost)."""
        costs = np.asarray(costs, np.float64)
        sizes = np.asarray(sizes, np.int64)
        unit = np.divide(costs, sizes, out=np.zeros_like(costs),
                         where=sizes > 0)
        return np.repeat(unit, sizes)


# ---------------------------------------------------------------------------
# Worker sharding: lower the schedule's parallelism p onto the accelerator
# (DESIGN.md §2.6). Tiles are partitioned across p workers by tile cost and
# each worker's shard becomes one slice of a 2D kernel grid, so tiles run
# concurrently across TPU cores instead of serially on one grid.
# ---------------------------------------------------------------------------

def tile_spans(item_id: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """(first_item, last_item) per tile, -1 for all-padding tiles.

    Greedy packing emits segments in item order, so within a tile the item
    ids are nondecreasing with any -1 padding confined to the tail — the
    first real item is slot 0 and the last is the row max.
    """
    first = item_id[:, 0].astype(np.int32)
    last = item_id.max(axis=1).astype(np.int32)
    return first, last


def block_chains(item_id: np.ndarray, block: int = 1) -> np.ndarray:
    """(n_blocks,) chain id per `block`-tile superstep block: consecutive
    blocks share a chain exactly when an item has segments on both sides of
    their boundary (the cut is not item-closed). This is the merge step of
    `partition_tiles`, exposed so recovery can reason at the same
    granularity — a chain is the smallest unit that can move between
    workers without breaking the one-worker-per-item fold order."""
    T = int(item_id.shape[0])
    blk = int(block)
    if blk < 1:
        raise ValueError(f"block must be positive, got {block}")
    if T == 0:
        return np.empty(0, np.int64)
    first, last = tile_spans(item_id)
    # cut between tiles t-1 and t is item-closed unless an item spans it
    spans = (last[:-1] == first[1:]) & (first[1:] >= 0) & (last[:-1] >= 0)
    if blk == 1:
        merge = spans
    else:
        # block boundaries sit at tiles blk, 2*blk, ...: blocks b-1 and b
        # merge when the tile-level cut there is not item-closed
        merge = spans[blk - 1:T - 1:blk]
    return np.concatenate([[0], np.cumsum(~merge)]).astype(np.int64)


def partition_tiles(tile_cost: np.ndarray, item_id: np.ndarray,
                    p: int, block: int = 1) -> np.ndarray:
    """Cost-balanced (LPT) tile -> worker map, shape (T,) int32.

    Tiles are grouped at `block` granularity (`block` = the kernel
    superstep B, so a worker's shard is a list of whole B-tile blocks the
    2D kernels can fetch straight out of the FLAT payload — no payload
    reorder). Blocks are further merged into *item-closed chains*: a chain
    boundary is only allowed where no item has segments on both sides
    (split items span contiguous tile runs, so the check is last-item !=
    first-item across the cut). Chains are then assigned to workers by LPT
    (heaviest chain to the least-loaded worker), which is BinLPT's
    placement rule (PAPERS.md) applied to iCh-constructed tiles.

    Keeping every item's tiles on ONE worker is what makes the sharded
    kernels bit-identical to the sequential grid: each output row is
    accumulated by exactly one worker, in ascending tile order (the same
    fold order the single grid uses), and every other worker contributes an
    exact identity element to the cross-worker reduction.
    """
    tile_cost = np.asarray(tile_cost, np.float64)
    T = int(tile_cost.size)
    p, blk = int(p), int(block)
    if p < 1:
        raise ValueError(f"worker count must be positive, got {p}")
    if blk < 1:
        raise ValueError(f"block must be positive, got {block}")
    if T == 0:
        return np.empty(0, np.int32)
    if p == 1:
        return np.zeros(T, np.int32)
    n_blocks = -(-T // blk)
    chain = block_chains(item_id, blk)
    n_chains = int(chain[-1]) + 1
    bcost = tile_cost
    if blk > 1:
        bcost = np.bincount(np.arange(T) // blk, weights=tile_cost,
                            minlength=n_blocks)
    ccost = np.bincount(chain, weights=bcost, minlength=n_chains)
    order = np.argsort(-ccost, kind="stable")
    heap = [(0.0, w) for w in range(p)]
    chain_worker = np.empty(n_chains, np.int32)
    for c in order:
        load, w = heapq.heappop(heap)
        chain_worker[c] = w
        heapq.heappush(heap, (load + float(ccost[c]), w))
    block_worker = chain_worker[chain]
    return np.repeat(block_worker, blk)[:T]


@dataclasses.dataclass(frozen=True)
class WorkerShards:
    """A tile -> worker partition lowered to a padded (p, S_B) BLOCK layout.

    `worker[t]` is tile t's worker (constant within each superstep block);
    `block_perm[w, s]` is the B-tile block worker w executes at grid step
    s (-1 = padding step), each worker's blocks in ascending order — block
    b covers tiles [b*B, (b+1)*B). Because blocks are contiguous runs of
    the FLAT tile sequence, the 2D kernels fetch them directly from the
    flat (T_pad, R, W) payload via a prefetched data-dependent block index
    (`kernel_block_ids`) — lowering to the shard layout moves NO payload
    bytes. `perm` is the tile-granular expansion (p, S_B*B) used for the
    prefetched item-id schedule and for tests.
    """

    worker: np.ndarray      # (T,) int32 tile -> worker
    block_perm: np.ndarray  # (p, S_B) int32 block index, -1 = padding
    superstep: int          # tiles per block / kernel grid step (B)

    @property
    def p(self) -> int:
        return int(self.block_perm.shape[0])

    @property
    def n_steps(self) -> int:
        """S_B: kernel grid steps per worker (blocks, incl. padding)."""
        return int(self.block_perm.shape[1])

    @property
    def tiles_per_worker(self) -> int:
        """S = S_B * B: tile slots per worker's shard (incl. padding)."""
        return self.n_steps * self.superstep

    @property
    def n_tiles_padded(self) -> int:
        """Flat tile count rounded up to whole blocks — the first axis the
        kernels' payload must have (`pack_csr(..., pad_tiles_to=B)`)."""
        T = int(self.worker.size)
        return -(-T // self.superstep) * self.superstep

    @property
    def perm(self) -> np.ndarray:
        """Tile-granular shard layout (p, S): tile at worker w's slot s,
        -1 padding (block_perm expanded; the last real block's tail past T
        is padding)."""
        B = self.superstep
        T = int(self.worker.size)
        tiles = (self.block_perm[:, :, None] * B
                 + np.arange(B, dtype=np.int32)[None, None, :])
        tiles = np.where((self.block_perm[:, :, None] >= 0) & (tiles < T),
                         tiles, -1)
        return tiles.reshape(self.p, -1).astype(np.int32)

    def kernel_block_ids(self) -> np.ndarray:
        """(p*S_B,) int32 block-index prefetch stream for the kernels'
        data-dependent BlockSpec index maps, padding steps clamped to
        block 0 (their prefetched item ids are -1, so the fetched payload
        is never applied)."""
        return np.maximum(self.block_perm, 0).reshape(-1)

    def worker_cost(self, tile_cost: np.ndarray) -> np.ndarray:
        """Per-worker assigned cost, shape (p,) — the quantity the
        simulator's static-assignment replay must reproduce
        (`Schedule.replay_sharded`). Tiles with worker -1 (present only in
        partial layouts from `shards_from_block_perm`) carry no cost."""
        tile_cost = np.asarray(tile_cost, np.float64)
        live = self.worker >= 0
        return np.bincount(self.worker[live], weights=tile_cost[live],
                           minlength=self.p)

    def shard_item_id(self, schedule: TileSchedule) -> np.ndarray:
        """The (p*S, R) scalar-prefetch schedule for the sharded kernels:
        tile perm[w, s]'s item ids at row w*S + s, -1 rows on padding."""
        flat = self.perm.reshape(-1)
        if schedule.n_tiles == 0:  # 0-tile schedule: every row is padding
            return np.full((flat.size, schedule.rows_per_tile), -1, np.int32)
        out = np.where((flat >= 0)[:, None],
                       schedule.item_id[np.clip(flat, 0, None)],
                       np.int32(-1))
        return np.ascontiguousarray(out, np.int32)


def make_shards(worker: np.ndarray, p: int,
                superstep: int = SUPERSTEP) -> WorkerShards:
    """Lay a (block-aligned) tile -> worker map out as the shard layout."""
    worker = np.asarray(worker, np.int32)
    p, B = int(p), int(superstep)
    if B < 1:
        raise ValueError(f"superstep must be positive, got {superstep}")
    if worker.size and not (0 <= int(worker.min())
                            and int(worker.max()) < p):
        raise ValueError(f"worker ids must lie in [0, {p}), got "
                         f"[{int(worker.min())}, {int(worker.max())}]")
    T = worker.size
    n_blocks = -(-T // B)
    block_worker = worker[::B]
    if not np.array_equal(np.repeat(block_worker, B)[:T], worker):
        raise ValueError("worker map is not constant within superstep "
                         f"blocks of {B} tiles; partition with "
                         f"partition_tiles(..., block={B})")
    counts = np.bincount(block_worker, minlength=p)
    S_B = max(int(counts.max(initial=0)), 1)
    block_perm = np.full((p, S_B), -1, np.int32)
    order = np.argsort(block_worker, kind="stable")  # ascending per worker
    w_sorted = block_worker[order]
    pos = np.arange(order.size) - np.searchsorted(w_sorted, w_sorted)
    block_perm[w_sorted, pos] = order.astype(np.int32)
    return WorkerShards(worker=worker, block_perm=block_perm, superstep=B)


def shards_from_block_perm(block_perm: np.ndarray, n_tiles: int,
                           superstep: int = SUPERSTEP) -> WorkerShards:
    """A `WorkerShards` over an EXPLICIT (p, S_B) block layout that may
    cover only a subset of the blocks — how recovery runs the standard
    sharded kernels over partial block sets (the completed prefix of an
    interrupted run, or the survivor re-execution layout). Tiles of
    unlisted blocks get worker -1 ("not executed in this layout"); padding
    steps stay -1 as usual. Listed block ids must be in range and
    pairwise distinct."""
    bp = np.ascontiguousarray(block_perm, np.int32)
    if bp.ndim != 2:
        raise ValueError(f"block_perm must be 2-D (p, S_B), got {bp.shape}")
    T, B = int(n_tiles), int(superstep)
    if B < 1:
        raise ValueError(f"superstep must be positive, got {superstep}")
    n_blocks = -(-T // B)
    flat = bp.reshape(-1)
    sel = flat >= 0
    ids = flat[sel]
    if ids.size and (int(ids.max()) >= n_blocks):
        raise ValueError(f"block id {int(ids.max())} out of range for "
                         f"{n_blocks} blocks of {B} tiles")
    if np.unique(ids).size != ids.size:
        raise ValueError("block_perm lists a block more than once")
    w_of_block = np.full(n_blocks, -1, np.int32)
    rows = np.repeat(np.arange(bp.shape[0], dtype=np.int32), bp.shape[1])
    w_of_block[ids] = rows[sel]
    worker = np.repeat(w_of_block, B)[:T]
    return WorkerShards(worker=worker, block_perm=bp, superstep=B)


def shard_schedule(schedule: TileSchedule, tile_cost: np.ndarray, p: int,
                   superstep: int = SUPERSTEP) -> WorkerShards:
    """Partition tiles by cost (at superstep-block granularity) and lower
    to the zero-copy shard layout."""
    worker = partition_tiles(tile_cost, schedule.item_id, p,
                             block=superstep)
    return make_shards(worker, p, superstep)


def _check_width(width: int | None) -> int | None:
    if width is not None and int(width) <= 0:
        raise ValueError(f"explicit tile width must be positive, got {width}")
    return None if width is None else int(width)


def build_schedule(sizes: np.ndarray, *, rows_per_tile: int = 8,
                   width: int | None = None, eps: float = ICH_EPS,
                   min_w: int = 8, max_w: int = 512) -> TileSchedule:
    """Band -> W -> segments -> greedy packing into (T, R) slots.

    Packing is a reshape: segments are already in pack order, so tile t's
    slots are segments [t*R, (t+1)*R) and the only real work is padding the
    segment axis out to T*R. `_reference_build_schedule` is the loop oracle.

    An EMPTY sizes array yields a valid 0-tile schedule (width from the
    band's floor): zero-item workloads (an exhausted BFS frontier, zero
    admitted moe-dispatch tokens) must schedule as a no-op — replay,
    executor dispatch, sharding, and kernel lowering all degenerate
    cleanly — rather than crash the serving path.
    """
    sizes = np.asarray(sizes)
    width = _check_width(width)
    W = width if width else ich_tile_width(sizes, eps, min_w, max_w)
    R = int(rows_per_tile)
    if sizes.size == 0:
        empty = np.zeros((0, R), np.int32)
        return TileSchedule(empty, empty.copy(), empty.copy(), W, 0)
    item_id, seg_start, seg_len, _ = _split_segments(sizes, W, R)
    T = item_id.size // R
    return TileSchedule(item_id.reshape(T, R), seg_start.reshape(T, R),
                        seg_len.reshape(T, R), W, len(sizes))


def _reference_build_schedule(sizes: np.ndarray, *, rows_per_tile: int = 8,
                              width: int | None = None, eps: float = ICH_EPS,
                              min_w: int = 8,
                              max_w: int = 512) -> TileSchedule:
    """Loop oracle for `build_schedule` (per-segment placement loop)."""
    sizes = np.asarray(sizes)
    width = _check_width(width)
    W = width if width else ich_tile_width(sizes, eps, min_w, max_w)
    R = int(rows_per_tile)
    segs = _reference_split_items(sizes, W)
    T = -(-len(segs) // R)
    item_id = np.full((T, R), -1, np.int32)
    seg_start = np.zeros((T, R), np.int32)
    seg_len = np.zeros((T, R), np.int32)
    for i, (item, s, ln) in enumerate(segs):
        t, j = divmod(i, R)
        item_id[t, j] = item
        seg_start[t, j] = s
        seg_len[t, j] = ln
    return TileSchedule(item_id, seg_start, seg_len, W, len(sizes))


def _unit_coords(schedule: TileSchedule) -> tuple[np.ndarray, np.ndarray]:
    """De-flatten the schedule to work-unit granularity: (slot, pos) where
    `slot` is the flat (t*R + j) slot owning each unit and `pos` the unit's
    rank within its segment. One `repeat` + one `cumsum`. Used by
    `coverage_counts`; `pack_csr` re-derives the same coordinates inline in
    workspace int32 (its hot path fuses them into src/dst index builds)."""
    seg_len = schedule.seg_len.reshape(-1).astype(np.int64)
    slot = np.repeat(np.arange(seg_len.size, dtype=np.int64), seg_len)
    first = np.repeat(np.cumsum(seg_len) - seg_len, seg_len)
    pos = np.arange(int(seg_len.sum()), dtype=np.int64) - first
    return slot, pos


def pack_csr(indptr: np.ndarray, indices: np.ndarray, data: np.ndarray,
             schedule: TileSchedule, *,
             pad_tiles_to: int = 1) -> tuple[np.ndarray, np.ndarray]:
    """Gather CSR payloads into the schedule's (T, R, W) layout.

    Returns (vals, cols); padding slots/tails are zero, so sum-reductions
    over W need no masking (and vals doubles as a validity mask when the
    payload is all-ones, as in BFS). `pad_tiles_to` rounds the tile axis
    up to a multiple (all-zero pad tiles) — the worker-sharded kernels
    fetch whole supersteps of B tiles straight out of this FLAT array
    (`WorkerShards.kernel_block_ids`), so they need T padded to B; the
    pad tiles cost nothing beyond their zero pages.

    Fast path (canonical CSR, schedule built from its row lengths): slots
    in flat tile order name the work units in exactly CSR order (items
    ascending, seg_start ascending within an item, coverage exactly once),
    so the whole packing is a ragged-to-padded reshape of the SEQUENTIAL
    payload stream — `out[lane < seg_len] = payload` — with no index
    streams at all. Inputs that break the sequential-stream precondition
    (indptr not starting at 0, schedule total != nnz) fall back to a
    rectangular per-slot gather (indptr[item] + seg_start + [0, W) per
    slot, masked past seg_len). Either way the two payload chains (vals,
    cols) overlap on the helper thread and index/mask scratch is reused
    across calls through the construction workspace.
    `_reference_pack_csr` is the loop oracle.
    """
    indices = np.asarray(indices)
    data = np.asarray(data)
    R, W = schedule.rows_per_tile, schedule.width
    T = schedule.n_tiles
    if int(pad_tiles_to) < 1:
        raise ValueError(f"pad_tiles_to must be positive, got {pad_tiles_to}")
    T_pad = -(-T // int(pad_tiles_to)) * int(pad_tiles_to)
    length = schedule.seg_len.reshape(-1)
    if data.size == 0:  # no payload at all: every slot is padding
        return (np.zeros((T_pad, R, W), data.dtype),
                np.zeros((T_pad, R, W), np.int32))
    if indices.dtype != np.int32:
        indices = indices.astype(np.int32)
    with _WS_LOCK:
        sequential = (int(indptr[0]) == 0
                      and int(length.sum(dtype=np.int64)) == data.size)
        lane = _ws_iota(W)
        if sequential:
            # mask[k, l] = lane l of slot k is a real unit; True positions
            # in C-order are exactly the CSR payload stream, in order
            # (pad tiles' rows stay all-False -> calloc zeros untouched)
            mask = _ws("pk_mask", T * R * W, np.bool_).reshape(T * R, W)
            np.less(lane[None, :], length[:, None], out=mask)

            def _chain(payload):
                out = np.zeros((T_pad * R, W), payload.dtype)  # calloc
                out[:T * R][mask] = payload
                return out
        else:
            n_slots = T * R
            dt = (np.int32 if max(n_slots * W, int(indptr[-1]) + W) < 2 ** 31
                  else np.int64)
            # per-slot CSR base: indptr[item] + seg_start (padding slots
            # have len 0, so their wrapped base is never kept)
            base = _ws("pk_base", n_slots, dt)
            np.take(np.asarray(indptr).astype(dt, copy=False),
                    schedule.item_id.reshape(-1), out=base, mode="wrap")
            base += schedule.seg_start.reshape(-1)
            src = _ws("pk_src", n_slots * W, dt).reshape(n_slots, W)
            np.add(base[:, None], _ws_iota(W, dt)[None, :], out=src)
            pad = _ws("pk_pad", n_slots * W, np.bool_).reshape(n_slots, W)
            np.greater_equal(lane[None, :], length[:, None], out=pad)

            def _chain(payload):
                out = np.zeros((T_pad * R, W), payload.dtype)
                np.take(payload, src, out=out[:n_slots], mode="clip")
                np.copyto(out[:n_slots], 0, where=pad)
                return out

        fut = (_POOL.submit(_chain, data)
               if T_pad * R * W >= 65_536 else None)
        vals = _chain(data) if fut is None else None
        cols = _chain(indices)
        if fut is not None:
            vals = fut.result()
    return (vals.reshape(T_pad, R, W), cols.reshape(T_pad, R, W))


def _reference_pack_csr(indptr: np.ndarray, indices: np.ndarray,
                        data: np.ndarray,
                        schedule: TileSchedule) -> tuple[np.ndarray,
                                                         np.ndarray]:
    """Loop oracle for `pack_csr` (per-slot copy loop)."""
    T, R, W = schedule.n_tiles, schedule.rows_per_tile, schedule.width
    vals = np.zeros((T, R, W), np.asarray(data).dtype)
    cols = np.zeros((T, R, W), np.int32)
    for t in range(T):
        for j in range(R):
            item, s, ln = (int(schedule.item_id[t, j]),
                           int(schedule.seg_start[t, j]),
                           int(schedule.seg_len[t, j]))
            if item >= 0 and ln > 0:
                base = int(indptr[item]) + s
                vals[t, j, :ln] = data[base:base + ln]
                cols[t, j, :ln] = indices[base:base + ln]
    return vals, cols


def coverage_counts(schedule: TileSchedule, sizes: np.ndarray) -> np.ndarray:
    """How many times each item's work units appear in the schedule; a valid
    schedule covers every unit exactly once (tests/test_tiling.py).

    Vectorized: each scheduled unit's global position is
    offsets[item] + seg_start + pos; the histogram is one `bincount`.
    `_reference_coverage_counts` is the loop oracle."""
    sizes = np.asarray(sizes, np.int64)
    offsets = np.concatenate([[0], np.cumsum(sizes)])
    total = int(offsets[-1])
    item_f = schedule.item_id.reshape(-1).astype(np.int64)
    start_f = schedule.seg_start.reshape(-1).astype(np.int64)
    slot, pos = _unit_coords(schedule)
    where = offsets[item_f[slot]] + start_f[slot] + pos
    return np.bincount(where, minlength=total).astype(np.int64)


def _reference_coverage_counts(schedule: TileSchedule,
                               sizes: np.ndarray) -> np.ndarray:
    """Loop oracle for `coverage_counts` (per-slot increment loop)."""
    sizes = np.asarray(sizes, np.int64)
    offsets = np.concatenate([[0], np.cumsum(sizes)])
    counts = np.zeros(int(offsets[-1]), np.int64)
    for t in range(schedule.n_tiles):
        for j in range(schedule.rows_per_tile):
            item = int(schedule.item_id[t, j])
            ln = int(schedule.seg_len[t, j])
            if item >= 0 and ln > 0:
                b = int(offsets[item]) + int(schedule.seg_start[t, j])
                counts[b:b + ln] += 1
    return counts
