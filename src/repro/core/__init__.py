"""Core of the reproduction: the iCh adaptive self-scheduling loop scheduler
(Booth & Lane, 2020) plus the baseline scheduler family, a discrete-event
simulator for scheduler-quality evaluation, a real threaded executor, and the
paper's workload generators.
"""
from .policies import (
    Policy,
    assigned,
    binlpt,
    dynamic,
    guided,
    ich,
    ich_chunk,
    ich_initial_d,
    paper_policy_grid,
    pretiled,
    static,
    stealing,
    taskloop,
)
from .tiling import (
    TileSchedule,
    WorkerShards,
    build_schedule,
    coverage_counts,
    ich_tile_width,
    make_shards,
    pack_csr,
    partition_tiles,
    shard_schedule,
    split_items,
)
from .simulator import (
    SimParams,
    SimResult,
    best_time_over_grid,
    eps_sensitivity,
    replay_refined,
    simulate,
    speedup,
    worst_stealing,
)
from .welford import (Welford, WelfordVec, adapt_d, classify, ich_band,
                      steal_merge, LOW, NORMAL, HIGH)
from .executor import parallel_for, ExecStats

# The segmented kernel epilogue (core/segmented.py) is the one core module
# that needs jax/pallas; it is re-exported lazily (PEP 562) so the
# numpy-only core — simulator sweeps, host-side schedule construction —
# keeps importing without paying the jax import.
_SEGMENTED_EXPORTS = frozenset(
    {"segment_max", "segment_sum", "segmented_apply", "slot_window"})


def __getattr__(name):
    if name in _SEGMENTED_EXPORTS:
        from . import segmented
        return getattr(segmented, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "Policy", "assigned", "binlpt", "dynamic", "guided", "ich", "ich_chunk",
    "ich_initial_d", "paper_policy_grid", "pretiled", "static", "stealing",
    "taskloop",
    "TileSchedule", "WorkerShards", "build_schedule", "coverage_counts",
    "ich_tile_width", "make_shards", "pack_csr", "partition_tiles",
    "shard_schedule", "split_items",
    "segment_max", "segment_sum", "segmented_apply", "slot_window",
    "SimParams", "SimResult", "best_time_over_grid", "eps_sensitivity",
    "replay_refined", "simulate", "speedup", "worst_stealing",
    "Welford", "WelfordVec", "adapt_d", "classify", "ich_band",
    "steal_merge",
    "LOW", "NORMAL", "HIGH", "parallel_for", "ExecStats",
]
