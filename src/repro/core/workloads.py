"""Workload generators mirroring the paper's test applications (§5.1).

Every generator returns per-iteration *costs* (abstract time units) consumed
by the simulator; nested-loop applications (BFS levels, K-Means rounds)
return one cost array per parallel-for invocation (barrier between loops).

* synth    — BinLPT's synthetic benchmark: linear and exponential
             (increasing / decreasing) workloads; Exp(beta), sorted (§5.1).
* BFS      — Rodinia BFS over generated graphs: uniform-degree and
             scale-free (P(k) ~ k^-2.3); per-level loop cost = vertex degree.
* K-Means  — per-round point loop; near-uniform base cost with a heavy tail
             that is reshuffled every round ("workload ... changes per
             outermost loop iteration", §5.1) and small per-iteration work
             (memory-bound), which is what makes central queues saturate.
* LavaMD   — 8x8x8 box domain; cost[i] = particles_i * sum of particles in
             the 27-neighborhood (boundary boxes have fewer neighbors).
* spmv     — Table 1 stat-matched synthetic row-cost arrays (15 inputs):
             cost = row_overhead + nnz(row).
"""
from __future__ import annotations

import dataclasses
import math
import zlib

import numpy as np


# ----------------------------------------------------------------------------
# Synth (paper §5.1, BinLPT's benchmark)
# ----------------------------------------------------------------------------

def synth_linear(n: int = 100_000, seed: int = 0) -> np.ndarray:
    """Linearly increasing workload (BinLPT's 'linear')."""
    return np.linspace(1.0, 1000.0, n)


def synth_exp(n: int = 100_000, increasing: bool = True, beta: float = None, seed: int = 0) -> np.ndarray:
    """1e6 samples from Exp(beta=1e6), sorted (paper uses n=beta=1e6).

    We keep beta = n so the workload *range* (max/min ~ 1e6 -> 1) matches the
    paper at any simulation scale.
    """
    rng = np.random.default_rng(seed)
    beta = float(n) if beta is None else beta
    w = rng.exponential(scale=beta, size=n)
    w = np.maximum(np.sort(w), 1.0)
    return w if increasing else w[::-1].copy()


# ----------------------------------------------------------------------------
# Breadth-first search (Rodinia BFS; uniform + scale-free inputs)
# ----------------------------------------------------------------------------

def _random_graph_csr(degrees: np.ndarray, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """Configuration-model-ish directed graph: random targets per out-edge."""
    rng = np.random.default_rng(seed)
    n = len(degrees)
    indptr = np.concatenate([[0], np.cumsum(degrees)]).astype(np.int64)
    indices = rng.integers(0, n, size=int(indptr[-1]), dtype=np.int64)
    return indptr, indices


def bfs_levels(kind: str = "uniform", n: int = 100_000, seed: int = 0,
               mask_cost: float = 0.5) -> list[np.ndarray]:
    """Rodinia-BFS loops: each level is a parallel-for over ALL n vertices;
    cost = mask check (~mask_cost) everywhere + edge scans for vertices on
    the current frontier. This sparse-dense irregularity (most iterations
    trivial, frontier clusters heavy) is the paper's BF workload."""
    rng = np.random.default_rng(seed)
    if kind == "uniform":
        degrees = rng.integers(1, 21, size=n)  # uniform #neighbors (Rodinia gen)
    elif kind == "scale_free":
        # P(k) ~ k^-2.3 (paper: gamma = 2.3), clipped to keep |E| manageable.
        degrees = np.minimum(rng.zipf(2.3, size=n), n // 10)
    else:
        raise ValueError(kind)
    indptr, indices = _random_graph_csr(degrees.astype(np.int64), seed + 1)

    visited = np.zeros(n, dtype=bool)
    frontier = np.array([0], dtype=np.int64)
    visited[0] = True
    levels: list[np.ndarray] = []
    deg = (indptr[1:] - indptr[:-1]).astype(np.float64)
    while len(frontier) > 0:
        # Rodinia: loop over ALL vertices; frontier vertices add edge work
        costs = np.full(n, mask_cost)
        costs[frontier] += 1.0 + deg[frontier]
        levels.append(costs)
        # expand
        nbr = np.concatenate([indices[indptr[v]:indptr[v + 1]] for v in frontier]) \
            if len(frontier) < 4096 else indices[_ranges_mask(indptr, frontier)]
        nbr = np.unique(nbr)
        nbr = nbr[~visited[nbr]]
        visited[nbr] = True
        frontier = nbr
    # static workload estimate a user could hand to workload-aware methods:
    # degree-based, frontier-oblivious (the mask is unknowable a priori)
    static_est = mask_cost + 1.0 + deg
    return levels, static_est


def _ranges_mask(indptr: np.ndarray, frontier: np.ndarray) -> np.ndarray:
    """Gather concatenated index ranges for a large frontier, vectorized."""
    starts = indptr[frontier]
    lens = (indptr[frontier + 1] - indptr[frontier]).astype(np.int64)
    total = int(lens.sum())
    # standard trick: offsets within each concatenated range
    rep = np.repeat(np.arange(len(frontier)), lens)
    within = np.arange(total) - np.repeat(np.concatenate([[0], np.cumsum(lens)[:-1]]), lens)
    return (starts[rep] + within).astype(np.int64)


# ----------------------------------------------------------------------------
# K-Means (Rodinia; KDD-cup-like shape)
# ----------------------------------------------------------------------------

def kmeans_rounds(
    n: int = 100_000, rounds: int = 10, seed: int = 0
) -> tuple[list[np.ndarray], np.ndarray]:
    """Per-round cost arrays + the round-0 estimate handed to binlpt.

    Small mean cost (memory-bound distance computations) with a reshuffled
    heavy tail each round (points whose membership flips / cache misses).
    """
    rng = np.random.default_rng(seed)
    out: list[np.ndarray] = []
    for r in range(rounds):
        base = rng.uniform(6.0, 10.0, size=n)
        tail_idx = rng.choice(n, size=n // 50, replace=False)  # 2% expensive
        base[tail_idx] += rng.exponential(120.0, size=len(tail_idx))
        out.append(base)
    return out, out[0].copy()


# ----------------------------------------------------------------------------
# LavaMD (Rodinia; 8x8x8 boxes, N-body inside 27-neighborhoods)
# ----------------------------------------------------------------------------

def lavamd_costs(nx: int = 8, particles_mean: float = 100.0, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    shape = (nx, nx, nx)
    particles = rng.poisson(particles_mean, size=shape).astype(np.float64)
    cost = np.zeros(shape)
    for dx in (-1, 0, 1):
        for dy in (-1, 0, 1):
            for dz in (-1, 0, 1):
                shifted = np.zeros(shape)
                xs = slice(max(0, dx), nx + min(0, dx))
                xd = slice(max(0, -dx), nx + min(0, -dx))
                ys = slice(max(0, dy), nx + min(0, dy))
                yd = slice(max(0, -dy), nx + min(0, -dy))
                zs = slice(max(0, dz), nx + min(0, dz))
                zd = slice(max(0, -dz), nx + min(0, -dz))
                shifted[xd, yd, zd] = particles[xs, ys, zs]
                cost += particles * shifted  # pairwise interactions
    return cost.reshape(-1) / 10.0  # heavy iterations (~1e3 units each)


# ----------------------------------------------------------------------------
# SpMV (Table 1 stat-matched synthetic inputs)
# ----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MatrixSpec:
    name: str
    area: str
    mean: float     # x-bar: avg nnz/row
    ratio: float    # max/min nnz per row
    sigma2: float   # variance of nnz/row


# Paper Table 1 (vertex/edge counts in the paper are millions; we simulate a
# row-count-scaled version with the same distributional stats).
TABLE1: list[MatrixSpec] = [
    MatrixSpec("FullChip", "Freescale", 8.9, 1.1e6, 3.2e6),
    MatrixSpec("circuit5M_dc", "Freescale", 4.2, 12, 1.0),
    MatrixSpec("wikipedia", "Gleich", 12.6, 1.8e5, 6.2e4),
    MatrixSpec("patents", "Pajek", 3.9, 762, 31.5),
    MatrixSpec("AS365", "DIMACS", 5.9, 4.6, 0.7),
    MatrixSpec("delaunay_n23", "DIMACS", 5.9, 7, 1.7),
    MatrixSpec("wb-edu", "Gleich", 5.8, 2.5e4, 2.0e3),
    MatrixSpec("hugebubbles-10", "DIMACS", 2.9, 1, 0.0),
    MatrixSpec("arabic-2005", "LAW", 28.1, 5.7e5, 3.0e5),
    MatrixSpec("road_usa", "DIMACS", 2.4, 4.5, 0.8),
    MatrixSpec("nlpkkt240", "Schenk", 27.1, 4.6, 4.8),
    MatrixSpec("uk-2005", "LAW", 23.7, 1.7e6, 2.7e6),
    MatrixSpec("kmer_P1a", "GenBank", 2.1, 20, 0.4),
    MatrixSpec("kmer_A2a", "GenBank", 2.1, 20, 0.3),
    MatrixSpec("kmer_V1r", "GenBank", 2.1, 4, 0.3),
]


# Per-item share cap for synthesized hub rows, as a multiple of the mean
# row. Naively transplanting `ratio` into a 1e4-row simulation planted
# single rows worth ~1% of ALL work — at reduced n an item's share of
# total work explodes far past anything in the real 5M-row matrices.
# The binding granularity condition is chunk-shaped: a self-scheduler's
# largest dispatch window is ~n/p^2 iterations (iCh's initial chunk), so
# any such window must stay well under one thread's fair share
# (mean*n/p). Both sides scale with n, so the cap is n-free:
# deg <= HUB_DEG_CAP * mean keeps an initial-chunk window at most
# ~HUB_DEG_CAP/p of a thread share (~0.3 at the paper's p=28). Over-cap
# hubs are split k ways (k rows of degree/k), preserving total hub mass
# and hence the nnz distribution's mean and skew at this scale.
HUB_DEG_CAP = 8.0

# Per-RUN share cap for hub placement. Heavy rows stay clustered in
# contiguous runs (natural host/domain orderings — paper Fig. 1a/1b),
# but a single run must not exceed this fraction of one thread's fair
# share at the paper's machine width: an even initial split drops a
# whole run into ONE worker's queue region, and a run worth multiple
# thread-shares turns into an atomic multi-share dispatch the instant
# any self-scheduler takes a queue-sized chunk. Real web/circuit
# matrices cluster heavy rows in MANY per-domain runs, never one block
# holding tens of percent of all nonzeros.
HUB_RUN_SHARE = 0.25
_P_REF = 28  # the paper's thread count (Table 2 evaluation width)


def matrix_row_nnz(spec: MatrixSpec, n: int = 150_000, seed: int = 0) -> np.ndarray:
    """Sample a row-nnz sequence approximately matching (mean, ratio, sigma2).

    Strategy: a low-variance body (lognormal, moment-matched to the residual
    variance) plus a small set of hub rows of degree ~ ratio (power-law webs/
    circuits have few enormous rows — Fig. 1c), placed contiguously to mimic
    natural orderings that cluster heavy rows (paper Fig. 1a/1b). Hub degrees
    and per-run masses are capped (HUB_DEG_CAP / HUB_RUN_SHARE, splitting
    hubs across extra rows and runs, total-nnz-preserving), so reduced-n
    sampling cannot plant paper-impossible indivisible items.
    """
    # crc32, not hash(): str hashing is randomized per process
    # (PYTHONHASHSEED), which made every matrix's sampled rows — and the
    # paper-conformance rankings over them — irreproducible across runs
    rng = np.random.default_rng(seed + zlib.crc32(spec.name.encode()))
    mean, sigma2, ratio = spec.mean, spec.sigma2, max(spec.ratio, 1.0)
    hub_deg = max(1.0, min(ratio, n / 10.0))  # at simulation scale
    # hubs explain the variance beyond what a tame body can carry, but may
    # consume at most half the mean mass (keeps x-bar on target; variance is
    # then as large as achievable at this row count -- reported honestly).
    body_var = min(sigma2, max(1.0, mean) ** 2)
    hub_var = max(0.0, sigma2 - body_var)
    n_hubs = 0
    if hub_var > 0 and hub_deg > mean:
        by_var = math.ceil(hub_var * n / (hub_deg**2))
        by_mass = math.floor(0.5 * mean * n / hub_deg)
        n_hubs = int(max(1, min(by_var, by_mass, n // 50)))
        # per-item share cap: an over-cap hub row splits into k rows of
        # degree/k (mass-preserving; see HUB_DEG_CAP above)
        max_deg = max(mean + 1.0, HUB_DEG_CAP * mean)
        if hub_deg > max_deg:
            k = math.ceil(hub_deg / max_deg)
            n_hubs = min(n_hubs * k, n // 2)
            hub_deg = max(1.0, round(hub_deg / k))
    hub_mass = n_hubs * hub_deg / n
    body_mean = max(1.0, mean - hub_mass)
    if body_var > 0.05 * body_mean**2:
        s2 = math.log(1.0 + body_var / body_mean**2)
        mu = math.log(body_mean) - s2 / 2.0
        body = rng.lognormal(mu, math.sqrt(s2), size=n)
    else:
        body = rng.normal(body_mean, math.sqrt(max(body_var, 1e-12)), size=n)
    nnz = np.maximum(np.round(body), 1.0)
    if n_hubs > 0:
        # contiguous heavy runs, one per segment of the index space, each
        # holding at most HUB_RUN_SHARE of a _P_REF-thread fair share
        run_mass = HUB_RUN_SHARE * mean * n / _P_REF
        per_run = max(1, int(run_mass / hub_deg))
        m = math.ceil(n_hubs / per_run)
        seg = np.linspace(0, n, m + 1).astype(np.int64)
        left = n_hubs
        for i in range(m):
            take = min(per_run, left)
            start = int(rng.integers(seg[i], max(seg[i + 1] - take, seg[i] + 1)))
            nnz[start:start + take] = hub_deg
            left -= take
    return nnz


def spmv_costs(spec: MatrixSpec, n: int = 150_000, seed: int = 0) -> np.ndarray:
    """Row cost = row overhead (1) + 1 per nonzero (multiply-add + gather)."""
    return 1.0 + matrix_row_nnz(spec, n, seed)


def achieved_stats(nnz: np.ndarray) -> tuple[float, float, float]:
    return float(nnz.mean()), float(nnz.max() / max(nnz.min(), 1.0)), float(nnz.var())
