"""Double-buffered fetch of data-dependent payload blocks (DESIGN.md §2.12).

The worker-sharded iCh kernels read their payload supersteps through a
DATA-DEPENDENT block index (`WorkerShards.kernel_block_ids`): worker w's
j-th grid step needs tiles `[blk*B, blk*B + B)` of the flat packed payload,
where `blk = blkid[w*S_B + j]` is only known from the prefetched schedule.
Mosaic auto-pipelines AFFINE block streams (it can see step s+1's index
while s computes), but an index read out of SMEM defeats that analysis, so
the naive lowering serializes fetch -> compute every step.

This module restores the overlap by hand: each payload stream gets a
two-slot VMEM scratch buffer and a matching two-slot DMA semaphore, and
every grid step

1. (j == 0 only) kicks off the DMA for its OWN first block into slot 0;
2. kicks off the DMA for step j+1's block — readable from the prefetched
   `blkid` stream — into slot (j+1) % 2;
3. waits on slot j % 2 and computes from it.

Step j's compute therefore always overlaps step j+1's fetch, exactly the
schedule Mosaic builds for affine streams. Slot parity guarantees safety:
the slot being written holds step j-1's block, which was fully consumed
before step j began (grid steps on a core run in order). Bit-identity to
the single-buffered kernels is structural — the same block bytes reach the
same jnp compute in the same order; only the copy timing changes.

The K-Means kernel is NOT rewritten onto this path: its block streams
(points, assignment windows) are affine in the grid step, so Mosaic's
automatic pipeliner already double-buffers them.
"""
from __future__ import annotations

from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["double_buffer_scratch", "fetch_double_buffered"]


def double_buffer_scratch(B: int, streams) -> list:
    """`scratch_shapes` entries for `fetch_double_buffered`.

    `streams` is a list of `(block_shape, dtype)` pairs, one per payload
    input, where `block_shape` is the per-tile shape — e.g. ``(R, W)`` for
    a (T_pad, R, W) payload. Returns the 2-slot ``(2, B, *block_shape)``
    VMEM buffers for all streams followed by their 2-slot DMA semaphores;
    the kernel receives them as scratch refs in that order.
    """
    bufs = [pltpu.VMEM((2, int(B)) + tuple(shape), dtype)
            for shape, dtype in streams]
    sems = [pltpu.SemaphoreType.DMA((2,)) for _ in streams]
    return bufs + sems


def _block_copy(hbm_ref, buf_ref, sem_ref, slot, blk, B: int):
    return pltpu.make_async_copy(hbm_ref.at[pl.ds(blk * B, B)],
                                 buf_ref.at[slot], sem_ref.at[slot])


def fetch_double_buffered(streams, blkid_ref, w, j, *, B: int) -> list:
    """Return grid step (w, j)'s payload blocks, next step's DMA in flight.

    `streams` is a list of `(hbm_ref, buf_ref, sem_ref)` triples: the
    whole payload left in `pltpu.ANY` memory space, its ``(2, B, ...)``
    VMEM scratch, and its ``(2,)`` DMA semaphore (`double_buffer_scratch`).
    `blkid_ref` is the prefetched ``(p * S_B,)`` block-id stream; padding
    steps carry a clamped id (block 0) exactly as the single-buffered
    index maps did, and their fetched block is masked out downstream by
    the -1 row ids. Returns one ``(B, ...)`` array per stream.
    """
    n_steps = pl.num_programs(1)
    idx = w * n_steps + j
    blk = blkid_ref[idx]

    @pl.when(j == 0)
    def _warmup():  # this worker's first block has no previous step to
        for hbm, buf, sem in streams:  # have prefetched it
            _block_copy(hbm, buf, sem, 0, blk, B).start()

    @pl.when(j + 1 < n_steps)
    def _prefetch():
        nxt = blkid_ref[idx + 1]
        for hbm, buf, sem in streams:
            _block_copy(hbm, buf, sem, (j + 1) % 2, nxt, B).start()

    cur = j % 2
    out = []
    for hbm, buf, sem in streams:
        _block_copy(hbm, buf, sem, cur, blk, B).wait()
        out.append(buf[cur])
    return out
