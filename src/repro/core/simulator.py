"""Discrete-event simulator for self-scheduling policies (paper §5-6).

This container has a single CPU core while the paper evaluates on a 28-thread
Xeon, so scheduler *quality* (makespan / speedup) is evaluated with a
discrete-event simulator whose policy logic is bit-faithful to the paper
(chunk laws, iCh classification/adaptation, THE-protocol steal-half with
rollback) and whose time model captures the costs the paper discusses:

* per-chunk dispatch under a queue lock (central queue => serialization,
  which is what kills ``dynamic(1)`` at high thread counts),
* local dispatch cost on distributed deques,
* steal cost, failed-steal cost, and a remote (cross-socket NUMA -> in our
  TPU adaptation cross-pod ICI) penalty multiplier,
* per-worker speed heterogeneity (DVFS / memory-bandwidth jitter, §3.2),
* iCh adaptation bookkeeping cost.

Events are processed at chunk granularity: O(#chunks + #steals) heap ops.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Optional

import numpy as np

from . import policies as P
from . import welford as W
# numpy-only, imports nothing back from repro.core (see robust/faults.py)
from repro.robust.faults import FaultClock, FaultError


@dataclasses.dataclass(frozen=True)
class SimParams:
    dispatch_overhead: float = 1.0      # central-queue grab (lock held)
    local_dispatch_overhead: float = 0.25
    steal_overhead: float = 4.0         # successful steal (lock held)
    failed_steal_overhead: float = 1.0  # empty-victim probe / rollback
    adapt_overhead: float = 0.15        # iCh classification + d update
    task_overhead: float = 3.0          # taskloop task creation/scheduling
    remote_penalty: float = 3.0         # cross-socket steal multiplier
    socket_size: int = 14               # threads per socket (2x14 Haswell)
    speed_jitter: float = 0.06          # stddev of per-worker speed factor
    seed: int = 0


@dataclasses.dataclass
class SimResult:
    makespan: float
    n: int
    p: int
    policy: str
    chunks: int = 0
    steals: int = 0
    failed_steals: int = 0
    busy: float = 0.0
    overhead: float = 0.0
    ks: Optional[np.ndarray] = None
    ds: Optional[np.ndarray] = None
    assignment: Optional[np.ndarray] = None  # per-iteration worker id
    # per-dispatched-chunk records (begin, end, worker, work), in dispatch
    # order; filled when simulate(..., record_chunks=True)
    chunk_log: Optional[list] = None
    # per-worker busy time (sum of work/speed dispatched to each worker) —
    # the imbalance diagnostic the measured-cost refiner reports on
    worker_busy: Optional[np.ndarray] = None
    # ---- fault injection (repro.robust, DESIGN.md §2.9) ----
    deaths: int = 0      # workers that retired under an injected death
    stall_events: int = 0
    reclaims: int = 0    # whole-range steals from dead workers' queues
    # ("death", t, w) / ("stall", t, w, duration) /
    # ("reclaim", t, thief, victim, begin, end), in simulated-time order;
    # filled when simulate(..., faults=...) is given a plan
    fault_log: Optional[list] = None

    @property
    def efficiency(self) -> float:
        return self.busy / (self.makespan * self.p) if self.makespan > 0 else 0.0


_SPEEDS_CACHE: dict[tuple[int, float, int], np.ndarray] = {}


def _speeds(p: int, params: SimParams) -> np.ndarray:
    # One stable speed stream per seed: worker w has the same speed at every
    # thread count, so speedups are measured against a consistent baseline.
    # Memoized per (p, jitter, seed): policy-grid sweeps call simulate()
    # hundreds of times with identical params, and re-seeding a default_rng
    # per call was measurable overhead. Cached arrays are frozen read-only.
    key = (p, params.speed_jitter, params.seed)
    s = _SPEEDS_CACHE.get(key)
    if s is None:
        rng = np.random.default_rng(params.seed)
        s = 1.0 + params.speed_jitter * rng.standard_normal(max(p, 64))
        s = np.clip(s[:p], 0.5, None)
        s.setflags(write=False)
        _SPEEDS_CACHE[key] = s
    return s


def simulate(
    costs: np.ndarray,
    p: int,
    policy: P.Policy,
    params: SimParams = SimParams(),
    record_assignment: bool = False,
    estimate: np.ndarray = None,
    record_chunks: bool = False,
    faults=None,
) -> SimResult:
    """`estimate` is the workload estimate HANDED to workload-aware policies
    (binlpt); defaults to the true costs. Passing a stale estimate models
    K-Means-style per-round workload drift (paper §6.1).

    `faults` is an optional `repro.robust.FaultPlan` (DESIGN.md §2.9):
    worker deaths and stalls become discrete events. A dead worker's
    remaining queue is reclaimed by survivors through the steal path
    (whole-range drain — a dead owner never frees its own last item), so
    every iteration is still dispatched exactly once; if every worker dies
    with work outstanding, `FaultError` is raised. Fault replay is
    deterministic: the same plan + params yields an identical trace."""
    costs = np.asarray(costs, dtype=np.float64)
    n = len(costs)
    csum = np.concatenate([[0.0], np.cumsum(costs)])
    res = SimResult(0.0, n, p, policy.label())
    res.worker_busy = np.zeros(p)
    if record_chunks:
        res.chunk_log = []
    if faults is not None:
        faults.validate_workers(p)
        res.fault_log = []
    if n == 0:
        return res
    speeds = _speeds(p, params)
    assignment = np.full(n, -1, dtype=np.int32) if record_assignment else None

    if policy.kind == P.CENTRAL:
        est = costs if estimate is None else np.asarray(estimate, np.float64)
        _simulate_central(costs, csum, p, policy, params, speeds, res,
                          assignment, est, faults)
    else:
        _simulate_distributed(costs, csum, p, policy, params, speeds, res,
                              assignment, faults)
    res.assignment = assignment
    return res


class _FaultState(FaultClock):
    """The shared fault clock plus the simulator's per-worker dead flags."""

    __slots__ = ("dead",)

    def __init__(self, plan, p: int):
        super().__init__(plan, p)
        self.dead = np.zeros(p, dtype=bool)


# ----------------------------------------------------------------------------
# Central-queue family: dynamic / guided / taskloop / binlpt / static
# ----------------------------------------------------------------------------

def _simulate_central(costs, csum, p, policy, params, speeds, res, assignment,
                      estimate=None, faults=None):
    n = len(costs)
    pretiled: Optional[list[tuple[int, int]]] = None
    if policy.law == "pretiled":
        pretiled = P.pretile(policy, costs if estimate is None else estimate, p)
    grab_cost = params.task_overhead if policy.name == "taskloop" else params.dispatch_overhead

    if faults is not None and policy.name in ("assigned", "binlpt"):
        # both bind chunks to workers statically before the run: a dead
        # worker's share has no queue anyone can reclaim it from
        raise ValueError(
            f"policy {policy.name!r} assigns work statically; fault "
            "injection needs a queue survivors can reclaim from")
    fs = _FaultState(faults, p) if faults is not None else None

    if policy.name == "assigned":
        # Static per-chunk worker assignment (policies.assigned): worker w
        # runs its chunks in list order, no queue and no stealing — the
        # simulator twin of the worker-sharded kernel grids. Makespan is
        # the max per-worker finish time; with zero dispatch overhead and
        # jitter it reduces to the partition's max per-worker cost
        # (Schedule.replay_sharded / tests/test_sharding.py).
        if policy.workers and not (0 <= min(policy.workers)
                                   and max(policy.workers) < p):
            raise ValueError(f"assignment names workers outside [0, {p}): "
                             f"[{min(policy.workers)}, "
                             f"{max(policy.workers)}]")
        tw = np.zeros(p)
        for (b, e), w in zip(pretiled, policy.workers or ()):
            work = csum[e] - csum[b]
            tw[w] += grab_cost + work / speeds[w]
            if assignment is not None:
                assignment[b:e] = w
            if res.chunk_log is not None:
                res.chunk_log.append((b, e, w, work))
            res.chunks += 1
            res.busy += work / speeds[w]
            res.worker_busy[w] += work / speeds[w]
            res.overhead += grab_cost
        res.makespan = float(tw.max()) if p else 0.0
        return

    if policy.name == "binlpt":
        # BinLPT (paper ref. 9): equal-work chunks are STATICALLY assigned to
        # threads by LPT on the workload ESTIMATE; threads then run their own
        # bins (no stealing). Imbalance comes from estimate staleness and
        # worker-speed jitter — which is why the paper's binlpt falls behind
        # on-demand methods on skewed workloads.
        est = costs if estimate is None else estimate
        ecsum = np.concatenate([[0.0], np.cumsum(np.asarray(est, np.float64))])
        loads = np.zeros(p)
        bins: list[list[tuple[int, int]]] = [[] for _ in range(p)]
        for (b, e) in pretiled:  # already in descending-work order
            w = int(np.argmin(loads))
            bins[w].append((b, e))
            loads[w] += ecsum[e] - ecsum[b]
        makespan = 0.0
        for w in range(p):
            tw = 0.0
            for (b, e) in bins[w]:
                work = csum[e] - csum[b]
                tw += grab_cost + work / speeds[w]
                if assignment is not None:
                    assignment[b:e] = w
                if res.chunk_log is not None:
                    res.chunk_log.append((b, e, w, work))
                res.chunks += 1
                res.busy += work / speeds[w]
                res.worker_busy[w] += work / speeds[w]
                res.overhead += grab_cost
            makespan = max(makespan, tw)
        res.makespan = makespan
        return

    next_idx = 0          # next unscheduled iteration (law policies)
    next_chunk = 0        # next chunk index (pretiled policies)
    queue_free = 0.0      # central-queue lock availability
    heap: list[tuple[float, int, int]] = [(0.0, w, w) for w in range(p)]
    heapq.heapify(heap)
    seq = p
    makespan = 0.0

    while heap:
        t, _, w = heapq.heappop(heap)
        makespan = max(makespan, t)
        if fs is not None and not fs.dead[w]:
            # fault clock ticks at chunk boundaries: death first (a worker
            # both due to die and due to stall is simply dead), then stalls
            if fs.dies_now(w):
                fs.dead[w] = True
                res.deaths += 1
                res.fault_log.append(("death", t, w))
                continue  # retires: never requeued; queue stays shared
            st = fs.pending_stall(w)
            if st is not None:
                res.stall_events += 1
                res.fault_log.append(("stall", t, w, st.duration))
                seq += 1
                heapq.heappush(heap, (t + st.duration, seq, w))
                continue
        # request work from the central queue
        if pretiled is not None:
            if next_chunk >= len(pretiled):
                continue
            start = max(t, queue_free)
            queue_free = start + grab_cost
            b, e = pretiled[next_chunk]
            next_chunk += 1
        else:
            if next_idx >= n:
                continue
            start = max(t, queue_free)
            queue_free = start + grab_cost
            remaining = n - next_idx
            if policy.law == "guided":
                chunk = P.guided_next_chunk(remaining, p, policy.chunk)
            else:
                chunk = min(policy.chunk, remaining)
            b, e = next_idx, next_idx + chunk
            next_idx = e
        work = csum[e] - csum[b]
        if assignment is not None:
            assignment[b:e] = w
        if res.chunk_log is not None:
            res.chunk_log.append((b, e, w, work))
        done = start + grab_cost + work / speeds[w]
        res.chunks += 1
        if fs is not None:
            fs.chunks_done[w] += 1
        res.busy += work / speeds[w]
        res.worker_busy[w] += work / speeds[w]
        res.overhead += (start - t) + grab_cost
        seq += 1
        heapq.heappush(heap, (done, seq, w))
    if fs is not None:
        stranded = (len(pretiled) - next_chunk if pretiled is not None
                    else n - next_idx)
        if stranded > 0:
            raise FaultError(
                f"every worker died with {stranded} central-queue "
                f"chunk(s)/iteration(s) outstanding")
    res.makespan = makespan


# ----------------------------------------------------------------------------
# Distributed-queue family: stealing / iCh (THE protocol)
# ----------------------------------------------------------------------------

def _simulate_distributed(costs, csum, p, policy, params, speeds, res,
                          assignment, faults=None):
    fs = _FaultState(faults, p) if faults is not None else None
    n = len(costs)
    # Even contiguous initial split (paper §3.1): |q_i| = n/p.
    bounds = np.linspace(0, n, p + 1).astype(np.int64)
    qbegin = bounds[:-1].astype(np.int64).copy()
    qend = bounds[1:].astype(np.int64).copy()
    lock_free = np.zeros(p)
    ks = np.zeros(p)                      # completed-iteration counters k_i
    ds = np.full(p, P.ich_initial_d(p))   # chunk divisors d_i (iCh)
    fails = np.zeros(p, dtype=np.int64)   # consecutive failed steal attempts
    rng = np.random.default_rng(params.seed + 104729 * p)

    # events: (time, seq, worker, kind, payload) kind: 0=idle, 1=chunk-done
    heap: list[tuple[float, int, int, int, int]] = []
    for w in range(p):
        heap.append((0.0, w, w, 0, 0))
    heapq.heapify(heap)
    seq = p
    makespan = 0.0
    remaining_total = n

    def qlen(v: int) -> int:
        return int(qend[v] - qbegin[v])

    def push(t: float, w: int, kind: int, payload: int = 0):
        nonlocal seq
        seq += 1
        heapq.heappush(heap, (t, seq, w, kind, payload))

    while heap:
        t, _, w, kind, payload = heapq.heappop(heap)
        makespan = max(makespan, t)

        if kind == 1:  # chunk completed: update bookkeeping, then go idle
            ks[w] += payload
            if fs is not None:
                fs.chunks_done[w] += 1
            if policy.adaptive:
                mu, delta = W.ich_band(ks, policy.eps)
                ds[w] = W.adapt_d(ds[w], W.classify(ks[w], mu, delta))
                res.overhead += params.adapt_overhead
                push(t + params.adapt_overhead, w, 0)
            else:
                push(t, w, 0)
            continue

        # kind == 0: idle -> dispatch from own queue or steal
        if fs is not None and not fs.dead[w]:
            # fault clock ticks at chunk boundaries (death wins over a
            # stall due at the same boundary); a dead worker's deque keeps
            # its [begin, end) range for survivors to reclaim
            if fs.dies_now(w):
                fs.dead[w] = True
                res.deaths += 1
                res.fault_log.append(("death", t, w))
                continue  # retires; never requeued
            st = fs.pending_stall(w)
            if st is not None:
                res.stall_events += 1
                res.fault_log.append(("stall", t, w, st.duration))
                push(t + st.duration, w, 0)
                continue
        if qlen(w) > 0:
            fails[w] = 0
            start = max(t, lock_free[w])
            lock_free[w] = start + params.local_dispatch_overhead
            ql = qlen(w)
            if policy.adaptive:
                chunk = min(ql, P.ich_chunk(ql, ds[w]))
            else:
                chunk = min(ql, max(1, policy.chunk))
            b = int(qbegin[w])
            e = b + chunk
            qbegin[w] = e
            remaining_total -= chunk
            work = csum[e] - csum[b]
            if assignment is not None:
                assignment[b:e] = w
            if res.chunk_log is not None:
                res.chunk_log.append((b, e, w, work))
            done = start + params.local_dispatch_overhead + work / speeds[w]
            res.chunks += 1
            res.busy += work / speeds[w]
            res.worker_busy[w] += work / speeds[w]
            res.overhead += (start - t) + params.local_dispatch_overhead
            push(done, w, 1, chunk)
            continue

        # Steal path (paper Listing 1, THE protocol). Victim selection is
        # BLIND random probing (a thief cannot see queue sizes without
        # touching the victim's cache line) — the paper's "randomly selecting
        # from nonoptimal choices". An empty probe costs a (remote-penalized)
        # round trip; consecutive failures back off exponentially.
        if remaining_total <= 0:
            continue  # nothing left anywhere: worker retires
        v = int((w + 1 + rng.integers(p - 1)) % p) if p > 1 else w
        remote = (w // params.socket_size) != (v // params.socket_size)
        rmul = params.remote_penalty if remote else 1.0
        # a DEAD victim's queue is reclaimed whole: steal-half would strand
        # its last iteration forever (the owner never drains it), so the
        # thief takes the entire remaining range through the same lock
        dead_v = fs is not None and fs.dead[v]
        if p == 1 or (qlen(v) if dead_v else qlen(v) // 2) <= 0:
            # empty probe: victim has <2 stealable iterations (or a dead
            # victim's queue is already empty)
            res.failed_steals += 1
            probe = params.failed_steal_overhead * rmul
            back = params.failed_steal_overhead * float(2 ** min(fails[w], 10))
            fails[w] += 1
            res.overhead += probe + back
            push(t + probe + back, w, 0)
            continue
        cost = params.steal_overhead * rmul
        start = max(t, lock_free[v])
        lock_free[v] = start + cost
        # re-read under the lock (may have drained)
        take = qlen(v) if dead_v else qlen(v) // 2
        if take <= 0:
            # rollback (paper Listing 1 lines 12-16)
            res.failed_steals += 1
            back = params.failed_steal_overhead * float(2 ** min(fails[w], 10))
            fails[w] += 1
            res.overhead += (start - t) + cost + back
            push(start + cost + back, w, 0)
            continue
        if dead_v:
            b, e = int(qbegin[v]), int(qend[v])
            qbegin[v] = e
            qbegin[w], qend[w] = b, e
            res.reclaims += 1
            res.fault_log.append(("reclaim", start + cost, w, v, b, e))
        else:
            new_end = int(qend[v]) - take
            qend[v] = new_end
            qbegin[w] = new_end
            qend[w] = new_end + take
        res.steals += 1
        fails[w] = 0
        res.overhead += (start - t) + cost
        if policy.adaptive:
            ks[w], ds[w] = W.steal_merge(ks[w], ds[w], ks[v], ds[v])
        push(start + cost, w, 0)

    if fs is not None and remaining_total > 0:
        raise FaultError(
            f"every worker died with {remaining_total} iteration(s) "
            f"stranded in dead workers' queues")
    res.makespan = makespan
    res.ks = ks
    res.ds = ds


# ----------------------------------------------------------------------------
# Schedule replay on refreshed costs (measured-cost feedback, DESIGN.md §2.7)
# ----------------------------------------------------------------------------

def replay_refined(
    unit_costs: np.ndarray,
    ranges,
    p: int,
    workers: Optional[np.ndarray] = None,
    params: SimParams = SimParams(),
    record_chunks: bool = False,
) -> SimResult:
    """Replay an already-constructed schedule's chunk list on a REFRESHED
    per-unit cost array — the deterministic check of refinement quality.

    A constructed schedule fixes `ranges` ([begin, end) chunks in flattened
    work-unit space, e.g. `TileSchedule.slot_ranges()`); `unit_costs` is
    what those units are NOW believed (or measured) to cost, which need not
    be the estimates the schedule was built from. With `workers=None` the
    chunks go through the central pretiled queue (`policies.pretiled`);
    with a per-chunk worker array they replay as the static sharded
    assignment (`policies.assigned`). The makespan answers "what would this
    schedule cost on the true workload" — `Schedule.replay_refined` feeds
    it per-item costs, and the observe/refine loop must drive it down
    (benchmarks/bench_schedule_build.py's refine-loop section,
    tests/test_adaptive_properties.py).
    """
    pol = (P.pretiled(ranges) if workers is None
           else P.assigned(ranges, workers))
    return simulate(np.asarray(unit_costs, np.float64), int(p), pol, params,
                    record_chunks=record_chunks)


# ----------------------------------------------------------------------------
# Paper metrics (§6.1 eq. 9, §6.2 eqs. 10-11)
# ----------------------------------------------------------------------------

def best_time_over_grid(
    costs: np.ndarray, p: int, name: str, params: SimParams = SimParams()
) -> float:
    """T(app, schedule, p): best makespan across the Table 2 parameter grid."""
    times = [
        simulate(costs, p, pol, params).makespan
        for pol in P.paper_policy_grid(p)
        if pol.name == name
    ]
    return float(min(times))


def speedup(costs: np.ndarray, p: int, name: str, params: SimParams = SimParams()) -> float:
    """Paper eq. 9: speedup vs. guided on one thread."""
    t1 = best_time_over_grid(costs, 1, "guided", params)
    tp = best_time_over_grid(costs, p, name, params)
    return t1 / tp


def eps_sensitivity(costs: np.ndarray, p: int, params: SimParams = SimParams()) -> float:
    """Paper eq. 10: worst/best iCh makespan over eps in {25%, 33%, 50%}."""
    times = [simulate(costs, p, P.ich(e), params).makespan for e in (0.25, 0.33, 0.50)]
    return float(max(times) / min(times))


def worst_stealing(costs: np.ndarray, p: int, params: SimParams = SimParams()) -> float:
    """Paper eq. 11: worst-eps iCh over best-chunk stealing."""
    ich_t = max(simulate(costs, p, P.ich(e), params).makespan for e in (0.25, 0.33, 0.50))
    st_t = min(simulate(costs, p, P.stealing(c), params).makespan for c in (1, 2, 3, 64))
    return float(ich_t / st_t)
