"""Jitted schedule construction: `core/tiling.py` as an XLA array program.

The numpy construction path (band width -> item splitting -> greedy
packing -> payload pack -> LPT sharding) is already loop-free —
cumsum/repeat/take programs — so it ports to jax nearly term-for-term.
This module is that port: build -> pack -> shard runs as a jitted
pipeline on the accelerator, so per-request scheduling (the serving
path's ich-adaptive policy, every `Schedule.refine()` round) stops
round-tripping arrays through host numpy.

Conformance bar: ELEMENT-IDENTICAL outputs to `core/tiling.py`, not
"close" (tests/test_tiling_jax.py asserts it over the paper-grid
families). The integer streams (item_id/seg_start/seg_len, block
permutations, prefetch streams) are exact by construction — same
index arithmetic, same gathers. The one subtlety is float cost
arithmetic: LPT partitioning compares f64 partial sums, so a one-ulp
difference in `tile_cost` can flip a worker assignment. Two rules keep
it exact:

* all cost arithmetic runs in float64 (`jax.experimental.enable_x64`
  scopes the flip to this module's traces — nothing else in the repo
  sees x64);
* reductions replicate numpy's exact association order:
  `_pairwise_rowsum` mirrors numpy's pairwise_sum (8-accumulator
  unrolled block reduction) for the slot-cost row sums, and
  `segment_sum` matches `np.bincount(weights=...)` addition order for
  block/chain folds (both asserted in the test suite).

Shapes must be static under jit, so a tiny host-side `SchedulePlan`
(one numpy pass over sizes: total segment count, tile count, width)
parameterizes the traced program; jax caches one executable per plan
shape. The only device->host sync in the whole pipeline is the
per-worker block count that sizes the (p, S_B) shard layout — and
callers that know S_B (a refine round re-lowering at the same shape,
the serving path's steady state) can pass `n_steps=` and skip even
that. Input buffers are donated to the pipeline where the platform
supports it (no-op on CPU), so a refine loop reuses the previous
generation's device pages instead of growing the live set.

Zero-tile schedules (empty sizes) mirror `build_schedule`'s 0-tile
semantics host-side — there is nothing to launch.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.sched.defaults import ICH_EPS, SUPERSTEP

from .tiling import TileSchedule, WorkerShards, _check_width, ich_tile_width

# jax import is deliberately eager here: this module IS the jax path.
import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import enable_x64


def _i32(x):
    return jnp.asarray(x, jnp.int32)


# ---------------------------------------------------------------------------
# Host-side shape plan: everything jit needs to be static, from one cheap
# numpy pass over sizes.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SchedulePlan:
    """Static shapes of one schedule construction (the jit cache key)."""

    n_items: int        # len(sizes)
    width: int          # W (band width, host-resolved like the numpy path)
    total_segs: int     # real segments before padding
    n_tiles: int        # T = ceil(max(total, 1) / R)
    rows_per_tile: int  # R

    @property
    def capacity(self) -> int:
        return self.n_tiles * self.rows_per_tile


def plan_schedule(sizes: np.ndarray, *, rows_per_tile: int = 8,
                  width: int | None = None, eps: float = ICH_EPS,
                  min_w: int = 8, max_w: int = 512) -> SchedulePlan:
    """Resolve the static shapes `build_schedule` would produce."""
    sizes = np.asarray(sizes)
    width = _check_width(width)
    W = width if width else ich_tile_width(sizes, eps, min_w, max_w)
    R = int(rows_per_tile)
    if sizes.size == 0:
        return SchedulePlan(0, W, 0, 0, R)
    if int(sizes.max()) > np.iinfo(np.int32).max - W:
        raise ValueError("per-item sizes must fit int32; largest item is "
                         f"{int(sizes.max())} work units")
    total = int(np.maximum(-(-sizes.astype(np.int64) // W), 1).sum())
    if total > np.iinfo(np.int32).max:
        raise ValueError(f"schedule would need {total} segments, which "
                         "exceeds the int32 construction bound")
    T = -(-max(total, 1) // R)
    return SchedulePlan(int(sizes.size), W, total, T, R)


# ---------------------------------------------------------------------------
# Device-side containers (jax.Array twins of TileSchedule / WorkerShards)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DeviceSchedule:
    """`TileSchedule` with device-resident arrays."""

    item_id: jax.Array    # (T, R) int32, -1 = padding slot
    seg_start: jax.Array  # (T, R) int32
    seg_len: jax.Array    # (T, R) int32
    width: int
    n_items: int

    @property
    def n_tiles(self) -> int:
        return int(self.item_id.shape[0])

    @property
    def rows_per_tile(self) -> int:
        return int(self.item_id.shape[1])

    def to_host(self) -> TileSchedule:
        return TileSchedule(np.asarray(self.item_id),
                            np.asarray(self.seg_start),
                            np.asarray(self.seg_len),
                            self.width, self.n_items)


@dataclasses.dataclass(frozen=True)
class DeviceLowering:
    """One schedule fully lowered on device: tiles + costs + the (p, S_B)
    shard layout + the exact streams the sharded kernels prefetch.
    What a `backend="jax"` Schedule memoizes per (p, superstep)."""

    schedule: DeviceSchedule
    tile_cost: jax.Array   # (T,) float64, numpy-identical association order
    worker: jax.Array      # (T,) int32
    block_perm: jax.Array  # (p, S_B) int32, -1 = padding step
    rowid: jax.Array       # (p*S, R) int32 shard item-id stream
    blkid: jax.Array       # (p*S_B,) int32 kernel block-id prefetch stream
    slot_cost: jax.Array   # (T_pad, R) float32 flat kernel cost stream
    superstep: int

    @property
    def p(self) -> int:
        return int(self.block_perm.shape[0])

    @property
    def n_steps(self) -> int:
        return int(self.block_perm.shape[1])

    def to_host_shards(self) -> WorkerShards:
        return WorkerShards(worker=np.asarray(self.worker),
                            block_perm=np.asarray(self.block_perm),
                            superstep=self.superstep)


# ---------------------------------------------------------------------------
# Bit-exact float reductions
# ---------------------------------------------------------------------------

def _pairwise_rowsum(x: jax.Array) -> jax.Array:
    """Sum (T, R) over axis 1 in EXACTLY numpy's pairwise_sum association
    order (sequential under 8 columns; 8 accumulators then a fixed
    4-2-1 combine tree up to 128; halved recursion above), so LPT sees
    bit-identical tile costs to the numpy path. R is static, so the
    "loop" unrolls at trace time."""
    R = int(x.shape[1])
    if R == 0:
        return jnp.zeros(x.shape[0], x.dtype)
    if R < 8:
        res = x[:, 0]
        for i in range(1, R):
            res = res + x[:, i]
        return res
    if R <= 128:
        r = [x[:, j] for j in range(8)]
        i = 8
        while i + 8 <= R:
            for j in range(8):
                r[j] = r[j] + x[:, i + j]
            i += 8
        res = ((r[0] + r[1]) + (r[2] + r[3])) + ((r[4] + r[5]) + (r[6] + r[7]))
        while i < R:
            res = res + x[:, i]
            i += 1
        return res
    half = (R // 2) - ((R // 2) % 8)
    return _pairwise_rowsum(x[:, :half]) + _pairwise_rowsum(x[:, half:])


def _segment_sum(values: jax.Array, segment_ids: jax.Array,
                 num_segments: int) -> jax.Array:
    """`np.bincount(segment_ids, weights=values)` twin (sequential
    scatter-add matches bincount's addition order bit-exactly on CPU/TPU
    for the contiguous id streams used here)."""
    return jax.ops.segment_sum(values, segment_ids,
                               num_segments=num_segments)


# ---------------------------------------------------------------------------
# Traced mirrors of the construction stages
# ---------------------------------------------------------------------------

def ich_tile_width_jax(sizes: jax.Array, eps: float = ICH_EPS,
                       min_w: int = 8, max_w: int = 512) -> jax.Array:
    """Traceable twin of `ich_tile_width` (device scalar; the pipeline
    itself resolves W host-side because tile shapes must be static)."""
    with enable_x64():
        sizes = jnp.asarray(sizes)
        mu = (jnp.mean(sizes.astype(jnp.float64)) if sizes.size
              else jnp.float64(0.0))
        upper = mu * (1.0 + eps)
        # integer shift, not exp2: XLA CPU lowers exp2 via exp(x*ln2),
        # which returns 15.999... for exp2(4.0)
        e = jnp.ceil(jnp.log2(jnp.maximum(upper, 1.0))).astype(jnp.int32)
        w = jnp.left_shift(1, jnp.clip(e, 0, 30))
        return jnp.clip(w, min_w, max_w).astype(jnp.int32)


def _split_build(sizes: jax.Array, *, width: int, total: int, n_tiles: int,
                 rows_per_tile: int) -> tuple[jax.Array, jax.Array,
                                              jax.Array]:
    """`_split_segments` + the (T, R) reshape of `build_schedule`."""
    n = sizes.shape[0]
    R, cap = rows_per_tile, n_tiles * rows_per_tile
    s32 = sizes.astype(jnp.int32)
    n_segs = jnp.maximum(lax.div(s32 + jnp.int32(width - 1),
                                 jnp.int32(width)), 1)
    first = jnp.cumsum(n_segs) - n_segs  # exclusive-prefix seg counts
    item = jnp.repeat(jnp.arange(n, dtype=jnp.int32), n_segs,
                      total_repeat_length=cap)
    pos = jnp.arange(cap, dtype=jnp.int32)
    valid = pos < total  # total is static: the tail mask is a constant
    safe = jnp.clip(item, 0, n - 1)
    start = (pos - first[safe]) * jnp.int32(width)
    length = jnp.clip(s32[safe] - start, 0, width)
    item = jnp.where(valid, item, -1)
    start = jnp.where(valid, start, 0)
    length = jnp.where(valid, length, 0)
    return (item.reshape(n_tiles, R), start.reshape(n_tiles, R),
            length.reshape(n_tiles, R))


def _slot_tile_cost(costs: jax.Array, sizes: jax.Array, item_id: jax.Array,
                    seg_len: jax.Array) -> tuple[jax.Array, jax.Array]:
    """`TileSchedule.slot_cost` / `tile_cost` twins (f64, numpy order)."""
    n = costs.shape[0]
    costs = costs.astype(jnp.float64)
    sizes_f = sizes.astype(jnp.float64)
    unit = jnp.where(sizes_f > 0, costs / jnp.where(sizes_f > 0, sizes_f, 1.0),
                     0.0)
    safe = jnp.clip(item_id, 0, max(n - 1, 0))
    per_slot = jnp.where(item_id >= 0, unit[safe], 0.0)
    slot_cost = per_slot * seg_len
    return slot_cost, _pairwise_rowsum(slot_cost)


def _partition(tile_cost: jax.Array, item_id: jax.Array, *, p: int,
               block: int) -> jax.Array:
    """`partition_tiles` twin: item-closed chain merge + LPT assignment.

    `jnp.argmin(loads)` breaks load ties on the smallest worker id —
    exactly the heapq (load, w) tuple order of the numpy original — and
    f64 loads accumulate in the same chain order, so assignments match
    bit-for-bit. Phantom chain slots (the chain count is data-dependent;
    the loop runs over the static n_blocks bound) carry zero cost and
    sort AFTER every real chain (stable argsort, higher ids), so they
    cannot perturb any real assignment."""
    T = int(item_id.shape[0])
    blk = int(block)
    n_blocks = -(-T // blk)
    first = item_id[:, 0]
    last = jnp.max(item_id, axis=1)
    spans = (last[:-1] == first[1:]) & (first[1:] >= 0) & (last[:-1] >= 0)
    merge = spans if blk == 1 else spans[blk - 1:T - 1:blk]
    chain = jnp.concatenate([jnp.zeros(1, jnp.int32),
                             jnp.cumsum((~merge).astype(jnp.int32))])
    bcost = tile_cost
    if blk > 1:
        bcost = _segment_sum(tile_cost,
                             jnp.arange(T, dtype=jnp.int32) // blk, n_blocks)
    ccost = _segment_sum(bcost, chain, n_blocks)
    order = jnp.argsort(-ccost, stable=True)

    def assign(i, carry):
        loads, cw = carry
        c = order[i]
        w = jnp.argmin(loads).astype(jnp.int32)
        return loads.at[w].add(ccost[c]), cw.at[c].set(w)

    loads, chain_worker = lax.fori_loop(
        0, n_blocks, assign,
        (jnp.zeros(p, jnp.float64), jnp.zeros(n_blocks, jnp.int32)))
    block_worker = chain_worker[chain]
    return jnp.repeat(block_worker, blk,
                      total_repeat_length=n_blocks * blk)[:T]


def _shard_layout(worker: jax.Array, item_id: jax.Array, slot_cost: jax.Array,
                  *, p: int, superstep: int,
                  n_steps: int) -> tuple[jax.Array, jax.Array, jax.Array,
                                         jax.Array]:
    """`make_shards` + the kernels' prefetch streams, at static S_B."""
    T = int(worker.shape[0])
    B, S_B = int(superstep), int(n_steps)
    n_blocks = -(-T // B)
    R = int(item_id.shape[1])
    block_worker = worker[::B]
    order = jnp.argsort(block_worker, stable=True)
    w_sorted = block_worker[order]
    pos = jnp.arange(n_blocks) - jnp.searchsorted(w_sorted, w_sorted)
    block_perm = jnp.full((p, S_B), -1, jnp.int32)
    block_perm = block_perm.at[w_sorted, pos].set(order.astype(jnp.int32))
    # tile-granular perm -> shard item-id stream (WorkerShards.shard_item_id)
    tiles = (block_perm[:, :, None] * B
             + jnp.arange(B, dtype=jnp.int32)[None, None, :])
    tiles = jnp.where((block_perm[:, :, None] >= 0) & (tiles < T), tiles, -1)
    flat = tiles.reshape(-1)
    rowid = jnp.where((flat >= 0)[:, None],
                      item_id[jnp.clip(flat, 0, None)], jnp.int32(-1))
    blkid = jnp.maximum(block_perm, 0).reshape(-1)
    # flat (T_pad, R) float32 cost stream (sched/kernels._flat_slot_cost)
    T_pad = n_blocks * B
    flat_cost = jnp.zeros((T_pad, R), jnp.float32)
    flat_cost = flat_cost.at[:T].set(slot_cost.astype(jnp.float32))
    return block_perm, rowid, blkid, flat_cost


def _pack_gather(indptr: jax.Array, indices: jax.Array, data: jax.Array,
                 item_id: jax.Array, seg_start: jax.Array,
                 seg_len: jax.Array, *, width: int,
                 pad_tiles_to: int) -> tuple[jax.Array, jax.Array]:
    """`pack_csr` twin as the rectangular gather (the numpy fast path is a
    masked sequential reshape of the same element stream; tests assert the
    two agree bit-for-bit, as they already do for the numpy fallback)."""
    T, R = item_id.shape
    W = int(width)
    T_pad = -(-T // int(pad_tiles_to)) * int(pad_tiles_to)
    item = item_id.reshape(-1)
    base = (indptr[jnp.clip(item, 0, None)].astype(jnp.int64)
            + seg_start.reshape(-1).astype(jnp.int64))
    lane = jnp.arange(W, dtype=jnp.int64)
    src = jnp.clip(base[:, None] + lane[None, :], 0, data.shape[0] - 1)
    keep = lane[None, :] < seg_len.reshape(-1)[:, None]
    vals = jnp.where(keep, data[src], 0).reshape(T, R, W)
    cols = jnp.where(keep, indices[src], 0).reshape(T, R, W).astype(jnp.int32)
    if T_pad > T:
        vals = jnp.pad(vals, ((0, T_pad - T), (0, 0), (0, 0)))
        cols = jnp.pad(cols, ((0, T_pad - T), (0, 0), (0, 0)))
    return vals, cols


# ---------------------------------------------------------------------------
# Jitted entry points (donation where the platform supports it)
# ---------------------------------------------------------------------------

def _donate(*argnums):
    """Donate argnums on backends with buffer donation; CPU jax donates
    silently or warns depending on version — keep it off there."""
    return argnums if jax.default_backend() != "cpu" else ()


@functools.cache
def _jit_build(width: int, total: int, n_tiles: int, rows_per_tile: int):
    fn = functools.partial(_split_build, width=width, total=total,
                           n_tiles=n_tiles, rows_per_tile=rows_per_tile)
    return jax.jit(fn, donate_argnums=_donate(0))


@functools.cache
def _jit_construct(width: int, total: int, n_tiles: int, rows_per_tile: int,
                   p: int, block: int):
    """build + cost + partition fused into one executable."""

    def construct(sizes, costs):
        item_id, seg_start, seg_len = _split_build(
            sizes, width=width, total=total, n_tiles=n_tiles,
            rows_per_tile=rows_per_tile)
        slot_cost, tile_cost = _slot_tile_cost(costs, sizes, item_id,
                                               seg_len)
        if p == 1:
            worker = jnp.zeros(n_tiles, jnp.int32)
        else:
            worker = _partition(tile_cost, item_id, p=p, block=block)
        n_blocks = -(-n_tiles // block)
        counts = _segment_sum(jnp.ones(n_blocks, jnp.int32), worker[::block],
                              p)
        return (item_id, seg_start, seg_len, slot_cost, tile_cost, worker,
                counts)

    return jax.jit(construct, donate_argnums=_donate(0, 1))


@functools.cache
def _jit_layout(p: int, superstep: int, n_steps: int):
    fn = functools.partial(_shard_layout, p=p, superstep=superstep,
                           n_steps=n_steps)
    return jax.jit(fn)


@functools.cache
def _jit_pack(width: int, pad_tiles_to: int):
    fn = functools.partial(_pack_gather, width=width,
                           pad_tiles_to=pad_tiles_to)
    return jax.jit(fn, donate_argnums=_donate(2))


@functools.cache
def _jit_partition(p: int, block: int):
    return jax.jit(functools.partial(_partition, p=p, block=block))


# ---------------------------------------------------------------------------
# Public mirrors
# ---------------------------------------------------------------------------

def split_items_jax(sizes: np.ndarray,
                    width: int) -> tuple[jax.Array, jax.Array, jax.Array]:
    """`split_items` twin: device (item, start, length), real segments only."""
    if int(width) <= 0:
        raise ValueError(f"tile width must be positive, got {width}")
    plan = plan_schedule(sizes, rows_per_tile=1, width=int(width))
    if plan.n_items == 0:
        z = jnp.zeros(0, jnp.int32)
        return z, z, z
    with enable_x64():
        item, start, length = _jit_build(plan.width, plan.total_segs,
                                         plan.n_tiles, 1)(jnp.asarray(sizes))
    t = plan.total_segs
    return item.reshape(-1)[:t], start.reshape(-1)[:t], length.reshape(-1)[:t]


def build_schedule_jax(sizes: np.ndarray, *, rows_per_tile: int = 8,
                       width: int | None = None, eps: float = ICH_EPS,
                       min_w: int = 8, max_w: int = 512) -> DeviceSchedule:
    """`build_schedule` twin with device-resident tiles."""
    plan = plan_schedule(sizes, rows_per_tile=rows_per_tile, width=width,
                         eps=eps, min_w=min_w, max_w=max_w)
    R = plan.rows_per_tile
    if plan.n_items == 0:
        z = jnp.zeros((0, R), jnp.int32)
        return DeviceSchedule(z, z, z, plan.width, 0)
    with enable_x64():
        item_id, seg_start, seg_len = _jit_build(
            plan.width, plan.total_segs, plan.n_tiles, R)(jnp.asarray(sizes))
    return DeviceSchedule(item_id, seg_start, seg_len, plan.width,
                          plan.n_items)


def pack_csr_jax(indptr, indices, data, schedule, *,
                 pad_tiles_to: int = 1) -> tuple[jax.Array, jax.Array]:
    """`pack_csr` twin over a `DeviceSchedule` (or host `TileSchedule`)."""
    if int(pad_tiles_to) < 1:
        raise ValueError(f"pad_tiles_to must be positive, got {pad_tiles_to}")
    T, R, W = schedule.n_tiles, schedule.rows_per_tile, schedule.width
    T_pad = -(-T // int(pad_tiles_to)) * int(pad_tiles_to)
    data = jnp.asarray(data)
    if data.shape[0] == 0:  # no payload: every slot is padding
        return (jnp.zeros((T_pad, R, W), data.dtype),
                jnp.zeros((T_pad, R, W), jnp.int32))
    with enable_x64():
        return _jit_pack(W, int(pad_tiles_to))(
            jnp.asarray(np.asarray(indptr)), jnp.asarray(np.asarray(indices)),
            data, jnp.asarray(schedule.item_id),
            jnp.asarray(schedule.seg_start), jnp.asarray(schedule.seg_len))


def partition_tiles_jax(tile_cost, item_id, p: int,
                        block: int = 1) -> jax.Array:
    """`partition_tiles` twin (device (T,) worker map)."""
    p, blk = int(p), int(block)
    if p < 1:
        raise ValueError(f"worker count must be positive, got {p}")
    if blk < 1:
        raise ValueError(f"block must be positive, got {block}")
    T = int(np.asarray(item_id).shape[0] if isinstance(item_id, np.ndarray)
            else item_id.shape[0])
    if T == 0:
        return jnp.zeros(0, jnp.int32)
    if p == 1:
        return jnp.zeros(T, jnp.int32)
    with enable_x64():
        return _jit_partition(p, blk)(
            jnp.asarray(np.asarray(tile_cost, np.float64)),
            jnp.asarray(item_id))


def lower_schedule_jax(sizes: np.ndarray, costs: np.ndarray, *, p: int,
                       superstep: int = SUPERSTEP, rows_per_tile: int = 8,
                       width: int | None = None, eps: float = ICH_EPS,
                       min_w: int = 8, max_w: int = 512,
                       n_steps: int | None = None) -> DeviceLowering:
    """The pipeline: build -> cost -> partition (one executable) -> shard
    layout + prefetch streams (a second, layout-shaped executable).

    `n_steps` (S_B) sizes the (p, S_B) layout; when omitted it is read
    back from the device block counts — the pipeline's single scalar
    sync. Pass the previous generation's `lowering.n_steps` in a refine
    loop to stay fully on device.
    """
    p = int(p)
    if p < 1:
        raise ValueError(f"worker count must be positive, got {p}")
    B = int(superstep)
    if B < 1:
        raise ValueError(f"superstep must be positive, got {superstep}")
    plan = plan_schedule(sizes, rows_per_tile=rows_per_tile, width=width,
                         eps=eps, min_w=min_w, max_w=max_w)
    R = plan.rows_per_tile
    if plan.n_items == 0:
        z2 = jnp.zeros((0, R), jnp.int32)
        dev = DeviceSchedule(z2, z2, z2, plan.width, 0)
        S_B = max(int(n_steps or 0), 1)
        with enable_x64():
            empty_cost = jnp.zeros(0, jnp.float64)
        return DeviceLowering(
            schedule=dev, tile_cost=empty_cost,
            worker=jnp.zeros(0, jnp.int32),
            block_perm=jnp.full((p, S_B), -1, jnp.int32),
            rowid=jnp.full((p * S_B * B, R), -1, jnp.int32),
            blkid=jnp.zeros(p * S_B, jnp.int32),
            slot_cost=jnp.zeros((0, R), jnp.float32), superstep=B)
    with enable_x64():
        (item_id, seg_start, seg_len, slot_cost, tile_cost, worker,
         counts) = _jit_construct(plan.width, plan.total_segs, plan.n_tiles,
                                  R, p, B)(
            jnp.asarray(np.asarray(sizes)),
            jnp.asarray(np.asarray(costs, np.float64)))
        if n_steps is None:
            n_steps = max(int(jnp.max(counts)), 1)  # the one scalar sync
        block_perm, rowid, blkid, flat_cost = _jit_layout(p, B, int(n_steps))(
            worker, item_id, slot_cost)
    dev = DeviceSchedule(item_id, seg_start, seg_len, plan.width,
                         plan.n_items)
    return DeviceLowering(schedule=dev, tile_cost=tile_cost, worker=worker,
                          block_perm=block_perm, rowid=rowid, blkid=blkid,
                          slot_cost=flat_cost, superstep=B)
