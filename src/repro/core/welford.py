"""Running statistics used by iCh (paper §3.2, eqs. 4-8).

The paper considers the classical Welford running mean/variance (eqs. 6-7,
ref. [26]) but rejects keeping full running variance as too expensive for a
lightweight loop scheduler; iCh instead estimates the deviation band as a
fractional multiplier of the running mean (eq. 8):

    delta = eps * mean(k_j)

Both estimators are implemented here: `Welford` (the exact running moments,
used by the beyond-paper MoE balancer where we can afford vectorized math and
by tests as an oracle) and `ich_band` (the paper's cheap band).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable

import numpy as np

LOW, NORMAL, HIGH = -1, 0, 1


@dataclasses.dataclass
class Welford:
    """Welford running mean/variance (paper eq. 6-7)."""

    count: int = 0
    mean: float = 0.0
    m2: float = 0.0

    def update(self, x: float) -> None:
        self.count += 1
        d = x - self.mean
        self.mean += d / self.count
        self.m2 += d * (x - self.mean)

    def update_many(self, xs: Iterable[float]) -> None:
        for x in xs:
            self.update(x)

    @property
    def variance(self) -> float:
        return self.m2 / self.count if self.count > 0 else 0.0

    @property
    def std(self) -> float:
        return float(np.sqrt(self.variance))


@dataclasses.dataclass
class WelfordVec:
    """Vectorized Welford: one running (count, mean, M2) triple PER ITEM.

    The measured-cost feedback loop (`sched/adaptive.py`) folds one
    observed cost sample per item per execution round; a Python-object
    `Welford` per item would cost O(n) attribute churn per round, so the
    same recurrence runs as three aligned arrays. `update(x, mask)` is the
    scalar `Welford.update` applied at every `mask`-selected lane —
    `tests/test_adaptive_properties.py` asserts lane-for-lane agreement
    with the scalar oracle.
    """

    count: np.ndarray  # (n,) int64 samples folded per item
    mean: np.ndarray   # (n,) float64 running mean
    m2: np.ndarray     # (n,) float64 running sum of squared deviations

    @classmethod
    def zeros(cls, n: int) -> "WelfordVec":
        return cls(np.zeros(n, np.int64), np.zeros(n), np.zeros(n))

    @property
    def n(self) -> int:
        return int(self.count.size)

    def update(self, xs: np.ndarray, mask: np.ndarray | None = None) -> None:
        """Fold one sample per item; items where `mask` is False keep their
        stats untouched (an execution round that never observed them)."""
        xs = np.asarray(xs, np.float64)
        if mask is None:
            mask = np.ones(self.n, dtype=bool)
        cnt = self.count + mask
        safe = np.maximum(cnt, 1)
        d = xs - self.mean
        mean = self.mean + np.where(mask, d / safe, 0.0)
        self.m2 += np.where(mask, d * (xs - mean), 0.0)
        self.mean = mean
        self.count = cnt

    @property
    def variance(self) -> np.ndarray:
        return np.divide(self.m2, self.count,
                         out=np.zeros_like(self.m2),
                         where=self.count > 0)


def ich_band(ks: np.ndarray, eps: float) -> tuple[float, float]:
    """Paper eq. 8: the (mu, delta) band from per-worker completed counts.

    mu    = sum_j k_j / p   (mean iteration throughput)
    delta = eps * mu
    """
    mu = float(np.sum(ks)) / len(ks)
    return mu, eps * mu


def classify(k_i: float, mu: float, delta: float) -> int:
    """Paper eqs. 1-3: classify a worker's throughput against mu +- delta."""
    if k_i < mu - delta:
        return LOW
    if k_i > mu + delta:
        return HIGH
    return NORMAL


def adapt_d(d_i: float, cls: int, d_min: float = 1.0, d_max: float = 4096.0) -> float:
    """Paper §3.2 adaptation of the chunk divisor d_i.

    chunk = ceil(|q_i| / d_i); the *direction* is deliberately inverted vs.
    load-balance tuning:
      low  (slow worker)  -> d/2  -> chunk DOUBLES  (fewer interruptions)
      high (fast worker)  -> 2d   -> chunk HALVES   (more stealable work)
    """
    if cls == LOW:
        d_i = d_i / 2.0
    elif cls == HIGH:
        d_i = d_i * 2.0
    return float(min(max(d_i, d_min), d_max))


def steal_merge(k_thief: float, d_thief: float, k_victim: float, d_victim: float) -> tuple[float, float]:
    """Paper Listing 1 lines 6-7: average thief/victim bookkeeping on steal."""
    return (k_thief + k_victim) / 2.0, (d_thief + d_victim) / 2.0
