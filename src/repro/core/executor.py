"""Real threaded fork-join executor for the scheduler family.

This is the host-side realization of the paper's runtime (the analogue of the
libgomp implementation): actual ``threading.Thread`` workers, per-worker
deques with locks, THE-protocol steal-half with rollback, and iCh's adaptive
chunk bookkeeping. On this container's single CPU core it cannot demonstrate
wall-clock speedup (the simulator covers scheduler quality); its job is to
prove the *policy implementations* are operational under real concurrency:
every iteration executes exactly once, steals happen, counters stay sane.

It is also the engine behind ``repro/sched/data_sched.py`` (per-host
input-shard dispatch with stealing, wrapped by ``data/pipeline.py``), where
it runs for real in production; the `repro.sched.LoopScheduler` facade
reaches it through `Schedule.parallel_for` / `parallel_for_units`.

Measured-cost feedback (DESIGN.md §2.7): with ``record_chunks=True`` the
executor records one ``(begin, end, worker, elapsed_seconds)`` entry per
dispatched chunk — on BOTH the central-queue and distributed-deque paths —
and, on the distributed path, one ``(thief, victim, begin, end)`` entry per
committed steal. These are the wall-clock observations
``Schedule.observe`` folds back into the cost refiner. Because thread
interleaving is nondeterministic, ``deterministic=True`` additionally runs
the SAME per-worker dispatch/steal logic cooperatively (round-robin, one
dispatch-or-steal attempt per turn, single thread): with a fixed seed the
chunk and steal logs are bit-reproducible run to run, which is what pins
the instrumentation's accounting in tests (`tests/test_adaptive_properties
.py::test_deterministic_replay_identical_steal_trace`).

Supervision & fault recovery (DESIGN.md §2.9): workers are supervised —
the first exception a worker thread raises is captured, aborts the run,
and re-raises in the caller (a raising ``body`` can never silently return
partial results). Each item gets a retry budget with bounded exponential
backoff (``retries`` / ``retry_backoff_s``); a seeded
`repro.robust.FaultPlan` (``faults=``) injects worker deaths, stalls, and
flaky/poisoned bodies at deterministic points; a watchdog (``watchdog_s``)
declares workers that stop heartbeating dead so survivors reclaim their
deque range through the steal path (whole-range drain — a dead owner never
frees its own last item). Recovery preserves the exactly-once invariant:
completed chunks stand, queued ranges move atomically under the deque
locks, and a run that cannot complete (every worker dead with work
outstanding) raises `FaultError` instead of hanging.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Optional

import numpy as np

from . import policies as P
from . import welford as W
from repro.robust.faults import FaultClock, FaultError, FaultPlan

# bound on the per-retry exponential backoff sleep
RETRY_BACKOFF_CAP_S = 0.1


@dataclasses.dataclass
class ExecStats:
    chunks: int = 0
    steals: int = 0
    failed_steals: int = 0
    ks: Optional[np.ndarray] = None
    ds: Optional[np.ndarray] = None
    # per-dispatched-chunk records (begin, end, worker, elapsed_seconds),
    # appended at chunk completion; filled when record_chunks=True
    chunk_log: Optional[list] = None
    # per-committed-steal records (thief, victim, begin, end), in commit
    # order; filled when record_chunks=True on the distributed path
    steal_log: Optional[list] = None
    # ---- supervision / fault recovery (DESIGN.md §2.9) ----
    retries: int = 0          # re-attempts after a body exception
    deaths: int = 0           # workers retired (injected death or watchdog)
    stall_events: int = 0     # injected stalls taken
    reclaims: int = 0         # whole-range drains of dead workers' deques
    faults_observed: int = 0  # body exceptions + deaths + stalls seen
    faults_recovered: int = 0  # retried-to-success items + reclaims
    # ("death", worker, chunks_done) / ("stall", worker, chunks_done, dur) /
    # ("watchdog_kill", worker) / ("reclaim", thief, victim, begin, end);
    # filled when faults= or watchdog_s= is active. Under deterministic=True
    # the log is bit-reproducible for a fixed plan/seed.
    fault_log: Optional[list] = None


class _Deque:
    """[begin, end) index deque guarded by a lock (THE-protocol shaped)."""

    __slots__ = ("begin", "end", "lock")

    def __init__(self, begin: int, end: int):
        self.begin = begin
        self.end = end
        self.lock = threading.Lock()

    def pop_front(self, chunk: int) -> tuple[int, int]:
        """Owner-side dispatch: take up to `chunk` iterations from the front."""
        with self.lock:
            take = min(chunk, self.end - self.begin)
            if take <= 0:
                return 0, 0
            b = self.begin
            self.begin = b + take
            return b, b + take

    def steal_back_half(self) -> tuple[int, int]:
        """Thief-side: steal half the remaining range from the back
        (paper Listing 1; rollback == returning an empty range)."""
        with self.lock:
            half = (self.end - self.begin) // 2
            if half <= 0:
                return 0, 0
            new_end = self.end - half
            self.end = new_end
            return new_end, new_end + half

    def drain(self) -> tuple[int, int]:
        """Thief-side reclaim of a DEAD owner's queue: take the ENTIRE
        remaining range. Steal-half would strand the last iteration forever
        (the owner will never dispatch it), so recovery drains whole."""
        with self.lock:
            b, e = self.begin, self.end
            self.begin = e
            return b, e

    def size(self) -> int:
        return self.end - self.begin


def _attempt(body, i: int, retries: int, backoff_s: float,
             stats: ExecStats, stats_lock, sleep_fn=None) -> None:
    """Run `body(i)` under the per-item retry budget: transient failures
    are re-attempted up to `retries` times with bounded exponential
    backoff; a still-failing item re-raises (and the supervisor aborts the
    run). Retrying per ITEM — not per chunk — is what keeps the
    exactly-once invariant: items before the failing one are never
    re-executed. `sleep_fn` replaces `time.sleep` for the backoff wait
    (tests and simulated clocks pass a no-op / virtual sleep so retry
    suites cost zero wall-clock)."""
    if sleep_fn is None:
        sleep_fn = time.sleep
    attempt = 0
    while True:
        try:
            body(i)
            if attempt:
                with stats_lock:
                    stats.faults_recovered += 1
            return
        except Exception:
            with stats_lock:
                stats.faults_observed += 1
            if attempt >= retries:
                raise
            attempt += 1
            with stats_lock:
                stats.retries += 1
            delay = min(backoff_s * (2 ** (attempt - 1)),
                        RETRY_BACKOFF_CAP_S)
            if delay > 0:
                sleep_fn(delay)


def parallel_for(
    n: int,
    body: Callable[[int], None],
    p: int,
    policy: P.Policy,
    seed: int = 0,
    record_chunks: bool = False,
    deterministic: bool = False,
    faults: Optional[FaultPlan] = None,
    retries: int = 0,
    retry_backoff_s: float = 0.0,
    watchdog_s: Optional[float] = None,
    sleep_fn: Optional[Callable[[float], None]] = None,
) -> ExecStats:
    """Run `body(i)` for i in [0, n) on `p` threads under `policy`.

    `record_chunks` fills `ExecStats.chunk_log` (and `steal_log` on
    distributed policies); `deterministic` replaces the threads with a
    cooperative round-robin driver over the same per-worker logic, so the
    recorded logs are bit-reproducible for a fixed seed.

    Supervision: worker exceptions abort the run and re-raise here;
    `retries`/`retry_backoff_s` give each item a transient-failure budget;
    `faults` injects a seeded `repro.robust.FaultPlan` (deaths, stalls,
    flaky/poisoned bodies — deaths trigger at chunk boundaries, queued
    work is reclaimed by survivors); `watchdog_s` (threaded distributed
    path only) declares a worker dead after that many seconds without a
    heartbeat and re-enqueues its deque range for stealing. Under a plan
    every iteration still executes exactly once unless NO live worker
    remains, which raises `FaultError`. Injected stalls sleep for their
    duration on threads; the deterministic driver logs them and charges
    one round-robin turn instead (turns, not wall time, are its clock).
    `sleep_fn` replaces `time.sleep` for retry backoff AND injected stall
    waits (pass a no-op to run chaos/retry suites at zero wall-clock
    without changing the recorded fault logs).
    """
    stats = ExecStats()
    stats_lock = threading.Lock()
    if record_chunks:
        stats.chunk_log = []
    if faults is not None or watchdog_s is not None:
        stats.fault_log = []
    fc = None
    if faults is not None:
        faults.validate_workers(p)
        fc = FaultClock(faults, p)
        body = faults.wrap_body(body, n)

    if policy.kind == P.CENTRAL:
        _run_central(n, body, p, policy, stats, stats_lock, deterministic,
                     fc=fc, retries=retries, backoff_s=retry_backoff_s,
                     sleep_fn=sleep_fn)
    else:
        if record_chunks:
            stats.steal_log = []
        _run_distributed(n, body, p, policy, stats, stats_lock, seed,
                         deterministic, fc=fc, retries=retries,
                         backoff_s=retry_backoff_s, watchdog_s=watchdog_s,
                         sleep_fn=sleep_fn)
    return stats


# step outcomes shared by both families' per-worker logic
_RAN, _STOLE, _FAILED, _EMPTY, _DEAD, _STALLED = range(6)


def _fault_gate(w, fc, dead, stats, stats_lock, deterministic,
                sleep_fn=None) -> Optional[int]:
    """The per-step fault clock check both families run at chunk
    boundaries: returns a step outcome when worker w dies/stalls/was
    already declared dead, else None (proceed to dispatch)."""
    if fc is not None and not dead[w]:
        if fc.dies_now(w):
            dead[w] = True
            with stats_lock:
                stats.deaths += 1
                stats.faults_observed += 1
                stats.fault_log.append(
                    ("death", w, int(fc.chunks_done[w])))
            return _DEAD
        st = fc.pending_stall(w)
        if st is not None:
            with stats_lock:
                stats.stall_events += 1
                stats.faults_observed += 1
                stats.fault_log.append(
                    ("stall", w, int(fc.chunks_done[w]), st.duration))
            if not deterministic:
                (sleep_fn or time.sleep)(st.duration)
            return _STALLED
    if dead[w]:  # planned death or watchdog declaration
        return _DEAD
    return None


def _run_central(n, body, p, policy, stats, stats_lock, deterministic=False,
                 fc=None, retries=0, backoff_s=0.0, sleep_fn=None):
    pos = [0]
    tiles: Optional[list[tuple[int, int]]] = None
    if policy.law == "pretiled":
        # pretiled central policies need a workload estimate; with none
        # available at execution time we fall back to equal-count tiles.
        uniform = np.ones(n)
        tiles = P.pretile(policy if policy.name != "binlpt" else P.taskloop(p), uniform, p)
    qlock = threading.Lock()
    dead = np.zeros(p, dtype=bool)

    def grab() -> tuple[int, int]:
        with qlock:
            if tiles is not None:
                if pos[0] >= len(tiles):
                    return 0, 0
                t = tiles[pos[0]]
                pos[0] += 1
                return t
            if pos[0] >= n:
                return 0, 0
            remaining = n - pos[0]
            if policy.law == "guided":
                c = P.guided_next_chunk(remaining, p, policy.chunk)
            else:
                c = min(policy.chunk, remaining)
            b = pos[0]
            pos[0] = b + c
            return b, b + c

    def step(w: int) -> int:
        """One chunk grab + execution for (virtual) worker w."""
        gate = _fault_gate(w, fc, dead, stats, stats_lock, deterministic,
                           sleep_fn)
        if gate is not None:
            return gate
        b, e = grab()
        if e <= b:
            return _EMPTY
        record = stats.chunk_log is not None  # clock reads only when asked
        t0 = time.perf_counter() if record else 0.0
        for i in range(b, e):
            _attempt(body, i, retries, backoff_s, stats, stats_lock,
                     sleep_fn)
        if record:
            dt = time.perf_counter() - t0
        if fc is not None:
            fc.chunks_done[w] += 1
        with stats_lock:
            stats.chunks += 1
            if record:
                stats.chunk_log.append((b, e, w, dt))
        return _RAN

    if deterministic:
        live = list(range(p))
        while live:
            live = [w for w in live if step(w) in (_RAN, _STALLED)]
    else:
        abort = threading.Event()

        def worker(w: int):
            while not abort.is_set():
                r = step(w)
                if r in (_DEAD, _EMPTY):
                    return

        _run_threads(worker, p, abort)

    if fc is not None:
        stranded = ((len(tiles) - pos[0]) if tiles is not None
                    else (n - pos[0]))
        if stranded > 0:
            raise FaultError(
                f"every worker died with {stranded} central-queue "
                f"chunk(s)/iteration(s) outstanding")


def _run_distributed(n, body, p, policy, stats, stats_lock, seed,
                     deterministic=False, fc=None, retries=0, backoff_s=0.0,
                     watchdog_s=None, sleep_fn=None):
    bounds = np.linspace(0, n, p + 1).astype(np.int64)
    deques = [_Deque(int(bounds[i]), int(bounds[i + 1])) for i in range(p)]
    ks = np.zeros(p)
    ds = np.full(p, P.ich_initial_d(p))
    dead = np.zeros(p, dtype=bool)
    heartbeat = [time.perf_counter()] * p
    rngs = [np.random.default_rng(seed + w) for w in range(p)]

    def step(w: int) -> int:
        """One dispatch-or-steal attempt for worker w — the unit the
        threaded loop AND the deterministic round-robin driver share."""
        gate = _fault_gate(w, fc, dead, stats, stats_lock, deterministic,
                           sleep_fn)
        if gate is not None:
            return gate
        heartbeat[w] = time.perf_counter()
        q = deques[w]
        if policy.adaptive:
            chunk = P.ich_chunk(q.size(), ds[w])
        else:
            chunk = max(1, policy.chunk)
        b, e = q.pop_front(chunk)
        if e > b:
            record = stats.chunk_log is not None
            t0 = time.perf_counter() if record else 0.0
            for i in range(b, e):
                _attempt(body, i, retries, backoff_s, stats, stats_lock,
                         sleep_fn)
            if record:
                dt = time.perf_counter() - t0
            ks[w] += e - b
            if policy.adaptive:
                mu, delta = W.ich_band(ks, policy.eps)
                ds[w] = W.adapt_d(ds[w], W.classify(ks[w], mu, delta))
            if fc is not None:
                fc.chunks_done[w] += 1
            with stats_lock:
                stats.chunks += 1
                if record:
                    stats.chunk_log.append((b, e, w, dt))
            return _RAN
        # steal phase
        victims = [v for v in range(p) if v != w and deques[v].size() > 0]
        if not victims:
            return _EMPTY
        v = int(victims[rngs[w].integers(len(victims))])
        if dead[v]:
            # reclaim: the owner is dead, take its whole remaining range
            sb, se = deques[v].drain()
        else:
            sb, se = deques[v].steal_back_half()
        if se <= sb:
            with stats_lock:
                stats.failed_steals += 1
            return _FAILED
        if policy.adaptive:
            ks[w], ds[w] = W.steal_merge(ks[w], ds[w], ks[v], ds[v])
        dq = deques[w]
        with dq.lock:
            dq.begin, dq.end = sb, se
        with stats_lock:
            stats.steals += 1
            if dead[v]:
                stats.reclaims += 1
                stats.faults_recovered += 1
                stats.fault_log.append(("reclaim", w, v, sb, se))
            if stats.steal_log is not None:
                stats.steal_log.append((w, v, sb, se))
        return _STOLE

    if deterministic:
        # Cooperative round-robin: worker 0..p-1 each take one step per
        # sweep. A worker retires when it dies or when its step found no
        # work anywhere (steals within the sweep re-activate nobody: once
        # every deque is empty it stays empty — steals only move work
        # between deques; a DEAD worker's nonempty deque keeps survivors
        # in rotation until they reclaim it).
        live = list(range(p))
        while live:
            nxt = []
            for w in live:
                r = step(w)
                if r == _DEAD:
                    continue
                if r == _EMPTY and all(d.size() == 0 for d in deques):
                    continue
                nxt.append(w)
            live = nxt
    else:
        abort = threading.Event()
        stop_watchdog = threading.Event()
        monitor = None
        if watchdog_s is not None:
            def watchdog():
                # Declares a worker dead when its heartbeat goes stale
                # while its deque still holds work: survivors then reclaim
                # the range via drain(). The declared worker retires at
                # its next step (a Python thread cannot be killed; if it
                # was merely slow, its current chunk still completes —
                # exactly-once is preserved either way).
                while not stop_watchdog.wait(watchdog_s / 4.0):
                    now = time.perf_counter()
                    for v in range(p):
                        if (not dead[v] and deques[v].size() > 0
                                and now - heartbeat[v] > watchdog_s):
                            dead[v] = True
                            with stats_lock:
                                stats.deaths += 1
                                stats.faults_observed += 1
                                stats.fault_log.append(("watchdog_kill", v))

            monitor = threading.Thread(target=watchdog, daemon=True)
            monitor.start()

        def worker(w: int):
            while not abort.is_set():
                r = step(w)
                if r == _DEAD:
                    return
                if r != _EMPTY:
                    continue
                if all(deques[v].size() == 0 for v in range(p)):
                    return
                # other workers may still publish stolen work; loop on

        try:
            _run_threads(worker, p, abort)
        finally:
            stop_watchdog.set()
            if monitor is not None:
                monitor.join()

    stats.ks = ks
    stats.ds = ds
    if fc is not None or watchdog_s is not None:
        stranded = sum(d.size() for d in deques)
        if stranded > 0:
            raise FaultError(
                f"every worker died with {stranded} iteration(s) stranded "
                f"in dead workers' deques")


def _run_threads(fn, p, abort: Optional[threading.Event] = None):
    """Run fn(0..p-1) on real threads, supervised: the first exception any
    worker raises is captured and RE-RAISED here in the caller — a raising
    `body` must never silently return partial results (the pre-robustness
    behavior lost worker exceptions entirely). On failure `abort` is set so
    sibling workers drain out at their next step instead of spinning
    against a dead worker's nonempty deque."""
    errors: list[tuple[int, BaseException]] = []
    elock = threading.Lock()

    def run(w: int):
        try:
            fn(w)
        except BaseException as e:  # noqa: BLE001 - supervisor re-raises
            with elock:
                errors.append((w, e))
            if abort is not None:
                abort.set()

    threads = [threading.Thread(target=run, args=(w,)) for w in range(p)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if errors:
        # deterministic choice among racing failures: lowest worker id
        errors.sort(key=lambda we: we[0])
        raise errors[0][1]
