"""Real threaded fork-join executor for the scheduler family.

This is the host-side realization of the paper's runtime (the analogue of the
libgomp implementation): actual ``threading.Thread`` workers, per-worker
deques with locks, THE-protocol steal-half with rollback, and iCh's adaptive
chunk bookkeeping. On this container's single CPU core it cannot demonstrate
wall-clock speedup (the simulator covers scheduler quality); its job is to
prove the *policy implementations* are operational under real concurrency:
every iteration executes exactly once, steals happen, counters stay sane.

It is also the engine behind ``repro/sched/data_sched.py`` (per-host
input-shard dispatch with stealing, wrapped by ``data/pipeline.py``), where
it runs for real in production; the `repro.sched.LoopScheduler` facade
reaches it through `Schedule.parallel_for` / `parallel_for_units`.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Optional

import numpy as np

from . import policies as P
from . import welford as W


@dataclasses.dataclass
class ExecStats:
    chunks: int = 0
    steals: int = 0
    failed_steals: int = 0
    ks: Optional[np.ndarray] = None
    ds: Optional[np.ndarray] = None


class _Deque:
    """[begin, end) index deque guarded by a lock (THE-protocol shaped)."""

    __slots__ = ("begin", "end", "lock")

    def __init__(self, begin: int, end: int):
        self.begin = begin
        self.end = end
        self.lock = threading.Lock()

    def pop_front(self, chunk: int) -> tuple[int, int]:
        """Owner-side dispatch: take up to `chunk` iterations from the front."""
        with self.lock:
            take = min(chunk, self.end - self.begin)
            if take <= 0:
                return 0, 0
            b = self.begin
            self.begin = b + take
            return b, b + take

    def steal_back_half(self) -> tuple[int, int]:
        """Thief-side: steal half the remaining range from the back
        (paper Listing 1; rollback == returning an empty range)."""
        with self.lock:
            half = (self.end - self.begin) // 2
            if half <= 0:
                return 0, 0
            new_end = self.end - half
            self.end = new_end
            return new_end, new_end + half

    def size(self) -> int:
        return self.end - self.begin


def parallel_for(
    n: int,
    body: Callable[[int], None],
    p: int,
    policy: P.Policy,
    seed: int = 0,
) -> ExecStats:
    """Run `body(i)` for i in [0, n) on `p` threads under `policy`."""
    stats = ExecStats()
    stats_lock = threading.Lock()

    if policy.kind == P.CENTRAL:
        _run_central(n, body, p, policy, stats, stats_lock)
    else:
        _run_distributed(n, body, p, policy, stats, stats_lock, seed)
    return stats


def _run_central(n, body, p, policy, stats, stats_lock):
    pos = [0]
    tiles: Optional[list[tuple[int, int]]] = None
    if policy.law == "pretiled":
        # pretiled central policies need a workload estimate; with none
        # available at execution time we fall back to equal-count tiles.
        uniform = np.ones(n)
        tiles = P.pretile(policy if policy.name != "binlpt" else P.taskloop(p), uniform, p)
    qlock = threading.Lock()

    def grab() -> tuple[int, int]:
        with qlock:
            if tiles is not None:
                if pos[0] >= len(tiles):
                    return 0, 0
                t = tiles[pos[0]]
                pos[0] += 1
                return t
            if pos[0] >= n:
                return 0, 0
            remaining = n - pos[0]
            if policy.law == "guided":
                c = P.guided_next_chunk(remaining, p, policy.chunk)
            else:
                c = min(policy.chunk, remaining)
            b = pos[0]
            pos[0] = b + c
            return b, b + c

    def worker():
        while True:
            b, e = grab()
            if e <= b:
                return
            for i in range(b, e):
                body(i)
            with stats_lock:
                stats.chunks += 1

    _run_threads(worker, p)


def _run_distributed(n, body, p, policy, stats, stats_lock, seed):
    bounds = np.linspace(0, n, p + 1).astype(np.int64)
    deques = [_Deque(int(bounds[i]), int(bounds[i + 1])) for i in range(p)]
    ks = np.zeros(p)
    ds = np.full(p, P.ich_initial_d(p))
    done = np.zeros(p, dtype=bool)

    def worker(w: int):
        rng = np.random.default_rng(seed + w)
        while True:
            q = deques[w]
            if policy.adaptive:
                chunk = P.ich_chunk(q.size(), ds[w])
            else:
                chunk = max(1, policy.chunk)
            b, e = q.pop_front(chunk)
            if e > b:
                for i in range(b, e):
                    body(i)
                ks[w] += e - b
                if policy.adaptive:
                    mu, delta = W.ich_band(ks, policy.eps)
                    ds[w] = W.adapt_d(ds[w], W.classify(ks[w], mu, delta))
                with stats_lock:
                    stats.chunks += 1
                continue
            # steal phase
            victims = [v for v in range(p) if v != w and deques[v].size() > 0]
            if not victims:
                if all(deques[v].size() == 0 for v in range(p)):
                    done[w] = True
                    if done.all():
                        return
                    # other workers may still publish stolen work; one retry
                    # round then exit (termination: all queues empty is stable
                    # here because steals only move work between queues).
                    return
                continue
            v = int(victims[rng.integers(len(victims))])
            sb, se = deques[v].steal_back_half()
            if se <= sb:
                with stats_lock:
                    stats.failed_steals += 1
                continue
            if policy.adaptive:
                ks[w], ds[w] = W.steal_merge(ks[w], ds[w], ks[v], ds[v])
            dq = deques[w]
            with dq.lock:
                dq.begin, dq.end = sb, se
            with stats_lock:
                stats.steals += 1

    _run_threads(worker, p, pass_index=True)
    stats.ks = ks
    stats.ds = ds


def _run_threads(fn, p, pass_index=False):
    threads = [
        threading.Thread(target=(lambda w=w: fn(w)) if pass_index else fn)
        for w in range(p)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
