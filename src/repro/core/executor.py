"""Real threaded fork-join executor for the scheduler family.

This is the host-side realization of the paper's runtime (the analogue of the
libgomp implementation): actual ``threading.Thread`` workers, per-worker
deques with locks, THE-protocol steal-half with rollback, and iCh's adaptive
chunk bookkeeping. On this container's single CPU core it cannot demonstrate
wall-clock speedup (the simulator covers scheduler quality); its job is to
prove the *policy implementations* are operational under real concurrency:
every iteration executes exactly once, steals happen, counters stay sane.

It is also the engine behind ``repro/sched/data_sched.py`` (per-host
input-shard dispatch with stealing, wrapped by ``data/pipeline.py``), where
it runs for real in production; the `repro.sched.LoopScheduler` facade
reaches it through `Schedule.parallel_for` / `parallel_for_units`.

Measured-cost feedback (DESIGN.md §2.7): with ``record_chunks=True`` the
executor records one ``(begin, end, worker, elapsed_seconds)`` entry per
dispatched chunk — on BOTH the central-queue and distributed-deque paths —
and, on the distributed path, one ``(thief, victim, begin, end)`` entry per
committed steal. These are the wall-clock observations
``Schedule.observe`` folds back into the cost refiner. Because thread
interleaving is nondeterministic, ``deterministic=True`` additionally runs
the SAME per-worker dispatch/steal logic cooperatively (round-robin, one
dispatch-or-steal attempt per turn, single thread): with a fixed seed the
chunk and steal logs are bit-reproducible run to run, which is what pins
the instrumentation's accounting in tests (`tests/test_adaptive_properties
.py::test_deterministic_replay_identical_steal_trace`).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Optional

import numpy as np

from . import policies as P
from . import welford as W


@dataclasses.dataclass
class ExecStats:
    chunks: int = 0
    steals: int = 0
    failed_steals: int = 0
    ks: Optional[np.ndarray] = None
    ds: Optional[np.ndarray] = None
    # per-dispatched-chunk records (begin, end, worker, elapsed_seconds),
    # appended at chunk completion; filled when record_chunks=True
    chunk_log: Optional[list] = None
    # per-committed-steal records (thief, victim, begin, end), in commit
    # order; filled when record_chunks=True on the distributed path
    steal_log: Optional[list] = None


class _Deque:
    """[begin, end) index deque guarded by a lock (THE-protocol shaped)."""

    __slots__ = ("begin", "end", "lock")

    def __init__(self, begin: int, end: int):
        self.begin = begin
        self.end = end
        self.lock = threading.Lock()

    def pop_front(self, chunk: int) -> tuple[int, int]:
        """Owner-side dispatch: take up to `chunk` iterations from the front."""
        with self.lock:
            take = min(chunk, self.end - self.begin)
            if take <= 0:
                return 0, 0
            b = self.begin
            self.begin = b + take
            return b, b + take

    def steal_back_half(self) -> tuple[int, int]:
        """Thief-side: steal half the remaining range from the back
        (paper Listing 1; rollback == returning an empty range)."""
        with self.lock:
            half = (self.end - self.begin) // 2
            if half <= 0:
                return 0, 0
            new_end = self.end - half
            self.end = new_end
            return new_end, new_end + half

    def size(self) -> int:
        return self.end - self.begin


def parallel_for(
    n: int,
    body: Callable[[int], None],
    p: int,
    policy: P.Policy,
    seed: int = 0,
    record_chunks: bool = False,
    deterministic: bool = False,
) -> ExecStats:
    """Run `body(i)` for i in [0, n) on `p` threads under `policy`.

    `record_chunks` fills `ExecStats.chunk_log` (and `steal_log` on
    distributed policies); `deterministic` replaces the threads with a
    cooperative round-robin driver over the same per-worker logic, so the
    recorded logs are bit-reproducible for a fixed seed.
    """
    stats = ExecStats()
    stats_lock = threading.Lock()
    if record_chunks:
        stats.chunk_log = []

    if policy.kind == P.CENTRAL:
        _run_central(n, body, p, policy, stats, stats_lock, deterministic)
    else:
        if record_chunks:
            stats.steal_log = []
        _run_distributed(n, body, p, policy, stats, stats_lock, seed,
                         deterministic)
    return stats


def _run_central(n, body, p, policy, stats, stats_lock, deterministic=False):
    pos = [0]
    tiles: Optional[list[tuple[int, int]]] = None
    if policy.law == "pretiled":
        # pretiled central policies need a workload estimate; with none
        # available at execution time we fall back to equal-count tiles.
        uniform = np.ones(n)
        tiles = P.pretile(policy if policy.name != "binlpt" else P.taskloop(p), uniform, p)
    qlock = threading.Lock()

    def grab() -> tuple[int, int]:
        with qlock:
            if tiles is not None:
                if pos[0] >= len(tiles):
                    return 0, 0
                t = tiles[pos[0]]
                pos[0] += 1
                return t
            if pos[0] >= n:
                return 0, 0
            remaining = n - pos[0]
            if policy.law == "guided":
                c = P.guided_next_chunk(remaining, p, policy.chunk)
            else:
                c = min(policy.chunk, remaining)
            b = pos[0]
            pos[0] = b + c
            return b, b + c

    def step(w: int) -> bool:
        """One chunk grab + execution for (virtual) worker w; False when
        the queue is drained."""
        b, e = grab()
        if e <= b:
            return False
        record = stats.chunk_log is not None  # clock reads only when asked
        t0 = time.perf_counter() if record else 0.0
        for i in range(b, e):
            body(i)
        if record:
            dt = time.perf_counter() - t0
        with stats_lock:
            stats.chunks += 1
            if record:
                stats.chunk_log.append((b, e, w, dt))
        return True

    if deterministic:
        live = list(range(p))
        while live:
            live = [w for w in live if step(w)]
        return

    def worker(w: int):
        while step(w):
            pass

    _run_threads(worker, p)


def _run_distributed(n, body, p, policy, stats, stats_lock, seed,
                     deterministic=False):
    bounds = np.linspace(0, n, p + 1).astype(np.int64)
    deques = [_Deque(int(bounds[i]), int(bounds[i + 1])) for i in range(p)]
    ks = np.zeros(p)
    ds = np.full(p, P.ich_initial_d(p))
    done = np.zeros(p, dtype=bool)
    rngs = [np.random.default_rng(seed + w) for w in range(p)]

    # step outcomes
    RAN, STOLE, FAILED, EMPTY = 0, 1, 2, 3

    def step(w: int) -> int:
        """One dispatch-or-steal attempt for worker w — the unit the
        threaded loop AND the deterministic round-robin driver share."""
        q = deques[w]
        if policy.adaptive:
            chunk = P.ich_chunk(q.size(), ds[w])
        else:
            chunk = max(1, policy.chunk)
        b, e = q.pop_front(chunk)
        if e > b:
            record = stats.chunk_log is not None
            t0 = time.perf_counter() if record else 0.0
            for i in range(b, e):
                body(i)
            if record:
                dt = time.perf_counter() - t0
            ks[w] += e - b
            if policy.adaptive:
                mu, delta = W.ich_band(ks, policy.eps)
                ds[w] = W.adapt_d(ds[w], W.classify(ks[w], mu, delta))
            with stats_lock:
                stats.chunks += 1
                if record:
                    stats.chunk_log.append((b, e, w, dt))
            return RAN
        # steal phase
        victims = [v for v in range(p) if v != w and deques[v].size() > 0]
        if not victims:
            return EMPTY
        v = int(victims[rngs[w].integers(len(victims))])
        sb, se = deques[v].steal_back_half()
        if se <= sb:
            with stats_lock:
                stats.failed_steals += 1
            return FAILED
        if policy.adaptive:
            ks[w], ds[w] = W.steal_merge(ks[w], ds[w], ks[v], ds[v])
        dq = deques[w]
        with dq.lock:
            dq.begin, dq.end = sb, se
        with stats_lock:
            stats.steals += 1
            if stats.steal_log is not None:
                stats.steal_log.append((w, v, sb, se))
        return STOLE

    if deterministic:
        # Cooperative round-robin: worker 0..p-1 each take one step per
        # sweep. A worker retires when its step found no work anywhere
        # (steals within the sweep re-activate nobody: once every deque is
        # empty it stays empty — steals only move work between deques).
        live = list(range(p))
        while live:
            nxt = []
            for w in live:
                r = step(w)
                if r == EMPTY and all(d.size() == 0 for d in deques):
                    continue
                nxt.append(w)
            live = nxt
        stats.ks = ks
        stats.ds = ds
        return

    def worker(w: int):
        while True:
            r = step(w)
            if r != EMPTY:
                continue
            if all(deques[v].size() == 0 for v in range(p)):
                done[w] = True
                if done.all():
                    return
                # other workers may still publish stolen work; one retry
                # round then exit (termination: all queues empty is stable
                # here because steals only move work between queues).
                return
            continue

    _run_threads(worker, p)
    stats.ks = ks
    stats.ds = ds


def _run_threads(fn, p):
    threads = [threading.Thread(target=lambda w=w: fn(w)) for w in range(p)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
