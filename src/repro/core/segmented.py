"""Shared segmented-reduction epilogue for the iCh Pallas kernels.

Every `ich_*` kernel ends the same way: a tile computed one value per
segment slot and must fold those R values into the output array at the rows
named by the prefetched `item_id` schedule, where several slots may name the
same row (a split item contributes multiple segments, possibly within one
tile). The original kernels did this with an unrolled per-slot scalar
read-modify-write — R sequential scalar ops per grid step that neither the
MXU nor the VPU can help with.

This module replaces that epilogue with one windowed vector op, exploiting a
structural guarantee of `core.tiling.build_schedule`: greedy packing keeps
segments in item order and every item owns at least one segment, so the
items appearing in any tile of R slots form a CONTIGUOUS id range spanning
at most R rows (consecutive slots step the item id by 0 or +1). A tile's
whole scatter therefore lands inside one length-R window of the output:

1. `slot_window` finds the window base and builds the (R, R) masked one-hot
   matrix P with P[j, i] = 1 iff slot j's row is base + i (padding slots,
   id -1, give all-zero rows);
2. the slot values are combined per output row — `segment_sum` is a one-hot
   matmul (values @ P, an MXU op), `segment_max` a masked VPU reduction;
3. `segmented_apply` folds the combined window into `out_ref[base:base+R]`
   with a single dynamic-slice read-modify-write (grid steps run
   sequentially on a TPU core, so the RMW is race-free), under one of three
   combine modes: "add" (SpMV partial sums), "max" (BFS frontier OR),
   "store" (K-Means idempotent assignment; uncovered window rows keep their
   previous value).

The window invariant only needs segments emitted in item order with >= 1
segment per item — exactly what `build_schedule` guarantees for any sizes,
width, or rows_per_tile.

Two extensions serve the worker-sharded 2D kernels (DESIGN.md §2.6):
`segmented_apply_batch` folds a whole superstep (B tiles) through B
windowed RMWs in tile order (static unroll, so the fold order — and hence
the floating-point result — matches the sequential grid exactly), accepting
either a 1D output ref or a worker's (1, n) accumulator block; and
`worker_reduce` is the host-side epilogue that folds the (p, n) per-worker
accumulators into the final output with a pairwise tree. The tree order is
free because the shard partition is item-closed
(`core.tiling.partition_tiles`): every output row is accumulated by exactly
one worker and all others hold an exact identity element (0 for add — a
worker's accumulated row is never -0.0, since 0.0 + x only produces -0.0
when x is -0.0, and the accumulate chain starts at +0.0 — 0 for max over
nonnegative values, 0/-1 for store-as-max), so combining identities in any
order is bit-exact.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

COMBINES = ("add", "max", "store")


def slot_window(rows: jax.Array, n_out: int) -> tuple[jax.Array, jax.Array]:
    """Window base + masked one-hot for a tile's R slot rows.

    `rows` is the (R,) int32 slot->row schedule for one tile (-1 = padding).
    Returns `(base, onehot)` where `base` is a scalar window origin clamped
    to [0, n_out - Wn] and `onehot` is (R, Wn) bool with
    `onehot[j, i] = (rows[j] == base + i)`; Wn = min(R, n_out). Padding
    slots produce all-zero one-hot rows, and an all-padding tile produces an
    all-zero matrix (the apply becomes a no-op).
    """
    R = rows.shape[0]
    wn = min(R, int(n_out))
    valid = rows >= 0
    r0 = jnp.min(jnp.where(valid, rows, n_out - 1))
    base = jnp.clip(r0, 0, n_out - wn)
    offs = jnp.where(valid, rows - base, -1)
    lane = jax.lax.broadcasted_iota(jnp.int32, (R, wn), 1)
    return base, offs[:, None] == lane


def segment_sum(values: jax.Array, onehot: jax.Array) -> jax.Array:
    """Per-window-row sums of slot values: a (1,R)x(R,Wn) one-hot matmul.

    Accumulates in float32 or wider — float64 inputs keep float64 accuracy
    (matching the scalar-loop epilogue this layer replaced) while float32
    stays a plain MXU matmul."""
    acc = jnp.promote_types(values.dtype, jnp.float32)
    return jnp.dot(values[None, :].astype(acc), onehot.astype(acc),
                   preferred_element_type=acc)[0]


def segment_max(values: jax.Array, onehot: jax.Array,
                neutral) -> jax.Array:
    """Per-window-row max of slot values (masked VPU reduction)."""
    return jnp.max(jnp.where(onehot, values[:, None], neutral), axis=0)


def _window_read(out_ref, base, wn):
    """Window slice of a 1D (n,) output ref or a (1, n) accumulator block."""
    if len(out_ref.shape) == 2:
        return out_ref[0, pl.ds(base, wn)]
    return out_ref[pl.ds(base, wn)]


def _window_write(out_ref, base, wn, upd) -> None:
    if len(out_ref.shape) == 2:
        out_ref[0, pl.ds(base, wn)] = upd
    else:
        out_ref[pl.ds(base, wn)] = upd


def segmented_apply(out_ref, rows: jax.Array, values: jax.Array, *,
                    combine: str) -> None:
    """Fold a tile's (R,) slot values into `out_ref` through its schedule.

    One windowed read-modify-write replaces R scalar ones. Rows inside the
    window that no slot covers are always left unchanged. `out_ref` is the
    (n,) output of a sequential-grid kernel or one worker's (1, n)
    accumulator block of a sharded kernel. `combine`:
      * "add"   — out[r] += sum of the slots scheduled on row r (SpMV);
      * "max"   — out[r] = max(out[r], max of r's slots) (BFS);
      * "store" — out[r] = r's slot value where r is scheduled this tile
                  (K-Means; duplicate slots of a split item carry identical
                  values, so any-wins is exact).
    """
    if combine not in COMBINES:
        raise ValueError(f"combine must be one of {COMBINES}, got {combine!r}")
    n_out = out_ref.shape[-1]
    base, onehot = slot_window(rows, n_out)
    wn = onehot.shape[1]
    cur = _window_read(out_ref, base, wn)
    if combine == "add":
        upd = cur + segment_sum(values, onehot).astype(cur.dtype)
    else:
        neutral = (-jnp.inf if jnp.issubdtype(values.dtype, jnp.floating)
                   else jnp.iinfo(values.dtype).min)
        covered = jnp.any(onehot, axis=0)
        val = segment_max(values, onehot, neutral).astype(cur.dtype)
        if combine == "max":
            upd = jnp.where(covered, jnp.maximum(cur, val), cur)
        else:  # store
            upd = jnp.where(covered, val, cur)
    _window_write(out_ref, base, wn, upd)


def segmented_apply_batch(out_ref, rows: jax.Array, values: jax.Array, *,
                          combine: str) -> None:
    """Fold one superstep — B tiles of (R,) slot values — into `out_ref`.

    `rows`/`values` are (B, R); the B windowed RMWs unroll statically in
    tile order, so a worker's fold order over its tiles is exactly the
    sequential grid's (bit-identical accumulation), while the caller's
    gather/compute amortizes over the whole (B*R, W) block.
    """
    B = rows.shape[0]
    for b in range(B):
        segmented_apply(out_ref, rows[b], values[b], combine=combine)


def emit_step_cost(cost_ref, rows: jax.Array, slot_cost: jax.Array,
                   j) -> None:
    """Accumulate one superstep's executed cost into this worker's
    (1, n_steps) cost-output row at step j (measured-cost feedback,
    DESIGN.md §2.7).

    `slot_cost` is the fetched (B, R) per-slot scheduled-cost block and
    `rows` the matching prefetched item ids; slots whose id is -1
    contribute nothing — padding steps fetch a CLAMPED block (block 0),
    so without the mask they would double-count it. The emitted stream
    therefore accounts exactly the tiles this worker really executed, and
    summing it recovers the schedule's per-worker tile-cost totals
    (tests/test_adaptive_properties.py). The per-step scalar lands as a
    masked one-hot row add — vector-friendly on the TPU, identical in
    interpret mode. Callers zero `cost_ref` at step 0 alongside their
    accumulator."""
    step_cost = jnp.sum(jnp.where(rows >= 0, slot_cost, 0.0))
    n_steps = cost_ref.shape[-1]
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, n_steps), 1)
    cost_ref[...] += jnp.where(lane == j,
                               step_cost.astype(cost_ref.dtype), 0)


def worker_reduce(acc: jax.Array, combine: str) -> jax.Array:
    """Fold (p, n) per-worker accumulators into the final (n,) output.

    Pairwise tree over the worker axis. Exact for any order because the
    shard partition is item-closed: each row was accumulated by exactly one
    worker and every other worker holds the combine's identity there ("add"
    folds +0.0s, "max" folds 0s under nonnegative values, "store" is
    lowered to max over init values; see module docstring).
    """
    if combine not in COMBINES:
        raise ValueError(f"combine must be one of {COMBINES}, got {combine!r}")
    op = jnp.add if combine == "add" else jnp.maximum
    parts = [acc[i] for i in range(acc.shape[0])]
    while len(parts) > 1:
        folded = [op(parts[i], parts[i + 1])
                  for i in range(0, len(parts) - 1, 2)]
        if len(parts) % 2:
            folded.append(parts[-1])
        parts = folded
    return parts[0]
