"""Self-scheduling policies (paper §2.1, §5.2, Table 2).

Each policy is a small descriptor consumed by the simulator
(`core.simulator`) and by the real threaded executor (`core.executor`).
Two families exist:

* central-queue policies — ``dynamic``, ``guided``, ``taskloop``, ``binlpt``,
  ``static``: a single shared queue of (precomputed or law-generated) chunks;
* distributed-queue policies — ``stealing``, ``ich``: per-worker THE deques,
  even initial split, random-victim steal-half on empty.

Parameters default to the paper's Table 2 values.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

# The unified iCh epsilon (paper Table 2; tuned default shared with the
# tiling layer and kernel wrappers). Lives in the dependency-free
# `repro.sched.defaults` so both sides of the facade import one constant.
from repro.sched.defaults import ICH_EPS

CENTRAL = "central"
DISTRIBUTED = "distributed"


@dataclasses.dataclass(frozen=True)
class Policy:
    name: str
    kind: str
    # central-queue chunk law: one of "fixed", "guided", "pretiled"
    law: str = "fixed"
    chunk: int = 1
    # distributed-queue parameters
    adaptive: bool = False  # True only for iCh
    eps: float = ICH_EPS  # iCh epsilon (paper grid: 25%, 33%, 50%)
    # pretiled chunk policies (taskloop / binlpt / static / pretiled)
    num_tasks: Optional[int] = None  # taskloop: num_tasks = p
    binlpt_chunks: Optional[int] = None  # binlpt: max number of chunks
    explicit: Optional[tuple] = None  # pretiled: ((begin, end), ...)
    # assigned: static per-chunk worker ids (parallel to `explicit`)
    workers: Optional[tuple] = None

    def label(self) -> str:
        if self.name == "ich":
            return f"ich(eps={self.eps:g})"
        if self.name == "taskloop":
            return "taskloop"
        if self.name == "binlpt":
            return f"binlpt({self.binlpt_chunks})"
        if self.name == "pretiled":
            return f"pretiled({len(self.explicit or ())})"
        if self.name == "assigned":
            return f"assigned({len(self.explicit or ())})"
        return f"{self.name}({self.chunk})"


def dynamic(chunk: int = 1) -> Policy:
    """OpenMP ``schedule(dynamic, chunk)``: central queue, fixed chunk."""
    return Policy("dynamic", CENTRAL, law="fixed", chunk=chunk)


def guided(chunk: int = 1) -> Policy:
    """OpenMP ``schedule(guided, chunk)``: chunk = max(remaining/p, chunk)."""
    return Policy("guided", CENTRAL, law="guided", chunk=chunk)


def taskloop(num_tasks: Optional[int] = None) -> Policy:
    """OpenMP ``taskloop num_tasks(p)``: p contiguous equal-count tasks."""
    return Policy("taskloop", CENTRAL, law="pretiled", num_tasks=num_tasks)


def binlpt(nchunks: int = 384) -> Policy:
    """BinLPT (paper ref. 9): workload-aware equal-work chunking + LPT order.

    Requires the true per-iteration workload estimate (workload-AWARE); the
    simulator provides it from the cost array, mirroring how BinLPT is given
    the user-supplied loop-work estimate.
    """
    return Policy("binlpt", CENTRAL, law="pretiled", binlpt_chunks=nchunks)


def static() -> Policy:
    """OpenMP ``schedule(static)``: p contiguous equal-count blocks, no queue."""
    return Policy("static", CENTRAL, law="pretiled", num_tasks=-1)


def pretiled(chunks) -> Policy:
    """Explicit central-queue chunk list, e.g. an iCh-constructed tile
    schedule's `slot_ranges()` — lets the simulator replay a schedule built
    by `core.tiling` chunk-for-chunk (the kernel/simulator cross-check in
    benchmarks/bench_ich_kernels.py)."""
    return Policy("pretiled", CENTRAL, law="pretiled",
                  explicit=tuple((int(b), int(e)) for b, e in chunks))


def assigned(chunks, workers) -> Policy:
    """Explicit chunk list with a STATIC per-chunk worker assignment: chunk
    i runs on workers[i], chunks of one worker in list order — no queue, no
    stealing. This is the simulator twin of the worker-sharded kernel
    execution layer (`core.tiling.partition_tiles` + the 2D `ich_*`
    grids): `Schedule.replay_sharded` replays a constructed schedule's
    tile -> worker partition through it, and under zero overhead/jitter
    the makespan equals the partition's max per-worker cost."""
    chunks = tuple((int(b), int(e)) for b, e in chunks)
    workers = tuple(int(w) for w in workers)
    if len(workers) != len(chunks):
        raise ValueError(f"{len(chunks)} chunks but {len(workers)} worker "
                         "assignments")
    if workers and min(workers) < 0:
        raise ValueError(f"worker ids must be >= 0, got {min(workers)}")
    return Policy("assigned", CENTRAL, law="pretiled", explicit=chunks,
                  workers=workers)


def stealing(chunk: int = 1) -> Policy:
    """Generic work-stealing with fixed chunk (paper's base algorithm)."""
    return Policy("stealing", DISTRIBUTED, chunk=chunk, adaptive=False)


def ich(eps: float = ICH_EPS) -> Policy:
    """iCh: adaptive chunk work-stealing (the paper's contribution)."""
    return Policy("ich", DISTRIBUTED, adaptive=True, eps=eps)


# ----------------------------------------------------------------------------
# Chunk laws / pretiling helpers (shared by simulator and executor)
# ----------------------------------------------------------------------------

def guided_next_chunk(remaining: int, p: int, min_chunk: int) -> int:
    """Guided self-scheduling law (paper §2.1): ~remaining/p, floored."""
    return max(min(remaining, min_chunk), int(math.ceil(remaining / p)))


def pretile(policy: Policy, costs: np.ndarray, p: int) -> list[tuple[int, int]]:
    """Build the chunk list for pretiled central policies.

    Returns [(begin, end), ...] in the order workers will be offered them.
    """
    n = len(costs)
    if policy.explicit is not None:
        return [(int(b), int(e)) for b, e in policy.explicit]
    if policy.name in ("taskloop", "static"):
        k = p if (policy.num_tasks is None or policy.num_tasks < 0) else policy.num_tasks
        k = max(1, min(k, n))
        bounds = np.linspace(0, n, k + 1).astype(np.int64)
        return [(int(bounds[i]), int(bounds[i + 1])) for i in range(k) if bounds[i] < bounds[i + 1]]
    if policy.name == "binlpt":
        k = max(p, min(policy.binlpt_chunks or p, n))
        # Equal-WORK contiguous chunking from the (known) workload estimate.
        csum = np.concatenate([[0.0], np.cumsum(costs, dtype=np.float64)])
        total = csum[-1]
        targets = np.linspace(0, total, k + 1)
        bounds = np.searchsorted(csum, targets, side="left")
        bounds[0], bounds[-1] = 0, n
        bounds = np.unique(bounds)
        chunks = [(int(bounds[i]), int(bounds[i + 1])) for i in range(len(bounds) - 1)]
        # LPT order: heaviest chunks are handed out first.
        work = [float(csum[e] - csum[b]) for b, e in chunks]
        order = np.argsort(work)[::-1]
        return [chunks[i] for i in order]
    raise ValueError(f"not a pretiled policy: {policy.name}")


def ich_initial_d(p: int) -> float:
    """Paper §3.1: d_i = p so that the initial chunk is |q_i|/p = n/p^2."""
    return float(p)


def ich_chunk(queue_len: int, d_i: float) -> int:
    """chunk = ceil(|q_i| / d_i), at least 1 (consistent w/ paper Fig. 2)."""
    if queue_len <= 0:
        return 0
    return max(1, int(math.ceil(queue_len / d_i)))


def paper_policy_grid(p: int) -> list[Policy]:
    """The full Table 2 parameter grid used in the paper's evaluation."""
    grid: list[Policy] = []
    grid += [guided(c) for c in (1, 2, 3)]
    grid += [dynamic(c) for c in (1, 2, 3)]
    grid += [taskloop(p)]
    grid += [binlpt(c) for c in (128, 384, 576)]
    grid += [stealing(c) for c in (1, 2, 3, 64)]
    grid += [ich(e) for e in (0.25, 0.33, 0.50)]
    return grid
