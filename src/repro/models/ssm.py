"""Recurrent sequence mixers: Mamba2 (SSD) and xLSTM (mLSTM / sLSTM).

Both Mamba2 and mLSTM share the same algebra,

    S_t = a_t * S_{t-1} + k_t (x) v_t        (state (P, N) per head)
    y_t = q_t . S_t                          (contract over N)

so one chunked scan (`chunked_gated_scan`) serves both: intra-chunk terms are
computed in matmul (MXU) form, inter-chunk state is carried by lax.scan —
the TPU-native replacement for the sequential recurrence (DESIGN.md §2).
mLSTM's normalizer n_t = a_t n + k_t is folded in as an extra ones-channel of
v. Numerical simplifications vs. the xLSTM paper (sigmoid gates instead of
stabilized exponential gating) are deliberate and documented in DESIGN.md;
the ref.py oracle for kernels/mamba_scan implements the same equations.

sLSTM is inherently sequential (recurrent weights R h_{t-1}); it lowers as a
length-S lax.scan (a While loop in HLO).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import layers as L


# ----------------------------------------------------------------------------
# Generic chunked gated scan
# ----------------------------------------------------------------------------

def chunked_gated_scan(q, k, v, log_a, state=None, chunk: int = 256, *,
                       exact_chunk: bool = False):
    """q,k: (B,S,H,N); v: (B,S,H,Pd); log_a: (B,S,H) (<= 0).

    Returns y (B,S,H,Pd), final state (B,H,Pd,N). fp32 state math.

    With `exact_chunk` the scan-block length Q is `chunk` EXACTLY (padding
    S up to it when shorter) instead of min(chunk, S): an incremental
    prefill feeding Q-aligned slices through `state` then replays the same
    scan steps as one call over the whole sequence, bit for bit
    (models/model.py `prefill_extend`). Chunking is NOT reassociation-free
    in general — two calls only agree bitwise when their Q and chunk
    boundaries coincide.
    """
    B, S, H, N = q.shape
    Pd = v.shape[-1]
    Q = int(chunk) if exact_chunk else min(chunk, S)
    pad = (-S) % Q
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        log_a = jnp.pad(log_a, ((0, 0), (0, pad), (0, 0)))
    nc = (S + pad) // Q

    def resh(t):
        return t.reshape(B, nc, Q, *t.shape[2:]).swapaxes(0, 1)

    qc, kc, vc, lc = resh(q), resh(k), resh(v), resh(log_a.astype(jnp.float32))
    if state is None:
        state = jnp.zeros((B, H, Pd, N), jnp.float32)

    causal = jnp.tril(jnp.ones((Q, Q), bool))

    def step(S_prev, inp):
        qb, kb, vb, lb = inp  # (B,Q,H,*)
        l = jnp.cumsum(lb, axis=1)  # inclusive within chunk
        total = l[:, -1]  # (B,H)
        # intra-chunk: scores_ij = (q_i . k_j) exp(l_i - l_j), j <= i
        s_qk = jnp.einsum("bihn,bjhn->bhij", qb.astype(jnp.float32),
                          kb.astype(jnp.float32))
        decay = jnp.exp(jnp.clip(l[:, :, None] - l[:, None, :], -60.0, 0.0))
        decay = decay.transpose(0, 3, 1, 2)  # (B,H,i,j)
        s_qk = jnp.where(causal[None, None], s_qk * decay, 0.0)
        y_intra = jnp.einsum("bhij,bjhp->bihp", s_qk, vb.astype(jnp.float32))
        # inter-chunk: y_i += exp(l_i) q_i . S_prev
        y_inter = jnp.einsum("bihn,bhpn->bihp", qb.astype(jnp.float32), S_prev)
        y_inter = y_inter * jnp.exp(l)[..., None]
        # state update: S = exp(total) S_prev + sum_j exp(total - l_j) k_j (x) v_j
        w = jnp.exp(jnp.clip(total[:, None] - l, -60.0, 0.0))  # (B,Q,H)
        S_new = S_prev * jnp.exp(total)[:, :, None, None] + jnp.einsum(
            "bjhn,bjhp,bjh->bhpn", kb.astype(jnp.float32),
            vb.astype(jnp.float32), w)
        return S_new, y_intra + y_inter

    state, ys = jax.lax.scan(step, state, (qc, kc, vc, lc))
    y = ys.swapaxes(0, 1).reshape(B, nc * Q, H, Pd)[:, :S]
    return y.astype(v.dtype), state


def gated_scan_step(q, k, v, log_a, state):
    """Single-token recurrence (decode). q,k (B,H,N); v (B,H,Pd);
    log_a (B,H); state (B,H,Pd,N)."""
    a = jnp.exp(log_a.astype(jnp.float32))[..., None, None]
    state = state * a + jnp.einsum("bhn,bhp->bhpn", k.astype(jnp.float32),
                                   v.astype(jnp.float32))
    y = jnp.einsum("bhn,bhpn->bhp", q.astype(jnp.float32), state)
    return y.astype(v.dtype), state


# ----------------------------------------------------------------------------
# Causal depthwise conv (Mamba front conv, kernel K)
# ----------------------------------------------------------------------------

def causal_conv(x, w, conv_state=None):
    """x (B,S,C), w (K,C) depthwise. Returns (y, new_state (B,K-1,C))."""
    K = w.shape[0]
    if conv_state is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    return y, xp[:, -(K - 1):] if K > 1 else None


# ----------------------------------------------------------------------------
# Mamba2 block (zamba2)
# ----------------------------------------------------------------------------

def init_mamba2(key, cfg):
    d = cfg.d_model
    d_in = cfg.mamba_expand * d
    N = cfg.ssm_state
    H = d_in // cfg.ssm_head_dim
    ks = jax.random.split(key, 9)
    return {
        "in_z": L.dense_init(ks[0], d, d_in),
        "in_x": L.dense_init(ks[1], d, d_in),
        "in_B": L.dense_init(ks[2], d, N),
        "in_C": L.dense_init(ks[3], d, N),
        "in_dt": L.dense_init(ks[4], d, H),
        "conv_x": jax.random.normal(ks[5], (cfg.conv_kernel, d_in)) * 0.2,
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": jnp.ones((d_in,), jnp.float32),
        "out": L.dense_init(ks[6], d_in, d),
    }


def mamba2_pspec(cfg, tp: int = 16):
    d_in = cfg.mamba_expand * cfg.d_model
    H = d_in // cfg.ssm_head_dim
    m = "model" if (d_in % tp == 0 and H % tp == 0) else None
    return {
        "in_z": P("data", m), "in_x": P("data", m),
        "in_B": P("data", None), "in_C": P("data", None),
        "in_dt": P("data", m),
        "conv_x": P(None, m),
        "A_log": P(m), "D": P(m), "dt_bias": P(m),
        "norm": P(m),
        "out": P(m, "data"),
    }


def apply_mamba2(cfg, p, x, state=None, *, chunk: int = None,
                 exact_chunk: bool = False):
    """x (B,S,D). state: None (train/prefill from scratch) or dict with
    'conv' (B,K-1,d_in) and 'ssm' (B,H,hd,N) for streaming/decode.

    `exact_chunk` forces the chunked scan with scan-block length exactly
    `chunk` (bypassing the single-token recurrence, whose op order
    differs): the incremental-prefill mode (see `chunked_gated_scan`)."""
    B, S, D = x.shape
    d_in = cfg.mamba_expand * D
    N, hd = cfg.ssm_state, cfg.ssm_head_dim
    H = d_in // hd
    chunk = chunk or getattr(cfg, "ssm_chunk", 256)
    z = x @ p["in_z"].astype(x.dtype)
    xs = x @ p["in_x"].astype(x.dtype)
    Bm = x @ p["in_B"].astype(x.dtype)
    Cm = x @ p["in_C"].astype(x.dtype)
    dt = jax.nn.softplus((x @ p["in_dt"].astype(x.dtype)).astype(jnp.float32)
                         + p["dt_bias"])  # (B,S,H)
    xs, conv_state = causal_conv(xs, p["conv_x"].astype(x.dtype),
                                 None if state is None else state["conv"])
    xs = jax.nn.silu(xs)
    xh = xs.reshape(B, S, H, hd)
    log_a = -jnp.exp(p["A_log"])[None, None] * dt  # (B,S,H), <= 0
    # shared B/C across heads (MQA-style); dt folded into v
    k = jnp.broadcast_to(Bm[:, :, None, :], (B, S, H, N))
    q = jnp.broadcast_to(Cm[:, :, None, :], (B, S, H, N))
    v = xh * dt.astype(xh.dtype)[..., None]
    ssm_prev = None if state is None else state["ssm"]
    if S == 1 and ssm_prev is not None and not exact_chunk:
        y, ssm = gated_scan_step(q[:, 0], k[:, 0], v[:, 0], log_a[:, 0], ssm_prev)
        y = y[:, None]
    else:
        y, ssm = chunked_gated_scan(q, k, v, log_a, state=ssm_prev,
                                    chunk=chunk, exact_chunk=exact_chunk)
    y = y + xh * p["D"][None, None, :, None]
    y = y.reshape(B, S, d_in) * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6)
         * p["norm"]).astype(x.dtype)
    out = y @ p["out"].astype(x.dtype)
    new_state = {"conv": conv_state, "ssm": ssm}
    return out, new_state


def mamba2_state_spec(cfg, batch: int, dtype=jnp.float32):
    d_in = cfg.mamba_expand * cfg.d_model
    H = d_in // cfg.ssm_head_dim
    return {
        "conv": jax.ShapeDtypeStruct((batch, cfg.conv_kernel - 1, d_in), dtype),
        "ssm": jax.ShapeDtypeStruct((batch, H, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
    }


# ----------------------------------------------------------------------------
# mLSTM block (xlstm)
# ----------------------------------------------------------------------------

def init_mlstm(key, cfg):
    d = cfg.d_model
    d_in = cfg.mamba_expand * d
    H = cfg.n_heads
    dh = d_in // H
    ks = jax.random.split(key, 8)
    return {
        "up_z": L.dense_init(ks[0], d, d_in),
        "up_x": L.dense_init(ks[1], d, d_in),
        "wq": L.dense_init(ks[2], d_in, d_in),
        "wk": L.dense_init(ks[3], d_in, d_in),
        "wv": L.dense_init(ks[4], d_in, d_in),
        "w_i": L.dense_init(ks[5], d_in, H),
        "w_f": L.dense_init(ks[6], d_in, H),
        "down": L.dense_init(ks[7], d_in, d),
    }


def mlstm_pspec(cfg, tp: int = 16):
    d_in = cfg.mamba_expand * cfg.d_model
    m = "model" if (cfg.n_heads % tp == 0) else None
    return {
        "up_z": P("data", m), "up_x": P("data", m),
        "wq": P(m, None), "wk": P(m, None), "wv": P(m, None),
        "w_i": P(m, None), "w_f": P(m, None),
        "down": P(m, "data"),
    }


def apply_mlstm(cfg, p, x, state=None, *, chunk: int = None,
                exact_chunk: bool = False):
    """x (B,S,D) -> (y, state). state: (B,H,dh+1,dh) fp32 (normalizer folded
    as the extra v channel). `exact_chunk` as in `apply_mamba2`."""
    B, S, D = x.shape
    d_in = cfg.mamba_expand * D
    H = cfg.n_heads
    dh = d_in // H
    chunk = chunk or getattr(cfg, "ssm_chunk", 256)
    z = x @ p["up_z"].astype(x.dtype)
    xm = x @ p["up_x"].astype(x.dtype)
    q = (xm @ p["wq"].astype(x.dtype)).reshape(B, S, H, dh) * (dh ** -0.5)
    k = (xm @ p["wk"].astype(x.dtype)).reshape(B, S, H, dh) * (dh ** -0.5)
    v = (xm @ p["wv"].astype(x.dtype)).reshape(B, S, H, dh)
    ig = jax.nn.sigmoid((xm @ p["w_i"].astype(x.dtype)).astype(jnp.float32))
    fg = jax.nn.sigmoid((xm @ p["w_f"].astype(x.dtype)).astype(jnp.float32) + 1.0)
    log_a = jnp.log(fg + 1e-9)
    kk = k * ig.astype(k.dtype)[..., None]
    v1 = jnp.concatenate([v, jnp.ones((B, S, H, 1), v.dtype)], axis=-1)
    if S == 1 and state is not None and not exact_chunk:
        y1, st = gated_scan_step(q[:, 0], kk[:, 0], v1[:, 0], log_a[:, 0], state)
        y1 = y1[:, None]
    else:
        y1, st = chunked_gated_scan(q, kk, v1, log_a, state=state,
                                    chunk=chunk, exact_chunk=exact_chunk)
    num, den = y1[..., :dh], y1[..., dh:]
    y = num / jnp.maximum(jnp.abs(den), 1.0)
    y = y.reshape(B, S, d_in) * jax.nn.silu(z)
    return y @ p["down"].astype(x.dtype), st


def mlstm_state_spec(cfg, batch: int):
    d_in = cfg.mamba_expand * cfg.d_model
    dh = d_in // cfg.n_heads
    return jax.ShapeDtypeStruct((batch, cfg.n_heads, dh + 1, dh), jnp.float32)


# ----------------------------------------------------------------------------
# sLSTM block (xlstm) — inherently sequential
# ----------------------------------------------------------------------------

def init_slstm(key, cfg):
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    ks = jax.random.split(key, 6)
    return {
        "wz": L.dense_init(ks[0], d, d), "wi": L.dense_init(ks[1], d, H),
        "wf": L.dense_init(ks[2], d, H), "wo": L.dense_init(ks[3], d, d),
        "r": jax.random.normal(ks[4], (H, dh, dh)) * (dh ** -0.5),
        "down": L.dense_init(ks[5], d, d),
    }


def slstm_pspec(cfg, tp: int = 16):
    return {"wz": P("data", None), "wi": P("data", None),
            "wf": P("data", None), "wo": P("data", None),
            "r": P(None, None, None), "down": P("data", None)}


def apply_slstm(cfg, p, x, state=None):
    """x (B,S,D). state: dict h,c (B,H,dh) fp32. Sequential lax.scan over S."""
    B, S, D = x.shape
    H = cfg.n_heads
    dh = D // H
    zs = (x @ p["wz"].astype(x.dtype)).reshape(B, S, H, dh).astype(jnp.float32)
    os_ = (x @ p["wo"].astype(x.dtype)).reshape(B, S, H, dh).astype(jnp.float32)
    is_ = (x @ p["wi"].astype(x.dtype)).astype(jnp.float32)
    fs = (x @ p["wf"].astype(x.dtype)).astype(jnp.float32)
    if state is None:
        state = {"h": jnp.zeros((B, H, dh), jnp.float32),
                 "c": jnp.zeros((B, H, dh), jnp.float32)}

    r = p["r"]

    def step(carry, inp):
        h, c = carry
        z_t, o_t, i_t, f_t = inp
        zr = jnp.tanh(z_t + jnp.einsum("bhd,hde->bhe", h, r))
        i = jax.nn.sigmoid(i_t)[..., None]
        f = jax.nn.sigmoid(f_t + 1.0)[..., None]
        c = f * c + i * zr
        h = jax.nn.sigmoid(o_t) * jnp.tanh(c)
        return (h, c), h

    (h, c), ys = jax.lax.scan(
        step, (state["h"], state["c"]),
        (zs.swapaxes(0, 1), os_.swapaxes(0, 1),
         is_.swapaxes(0, 1), fs.swapaxes(0, 1)))
    y = ys.swapaxes(0, 1).reshape(B, S, D).astype(x.dtype)
    return y @ p["down"].astype(x.dtype), {"h": h, "c": c}


def slstm_state_spec(cfg, batch: int):
    dh = cfg.d_model // cfg.n_heads
    return {"h": jax.ShapeDtypeStruct((batch, cfg.n_heads, dh), jnp.float32),
            "c": jax.ShapeDtypeStruct((batch, cfg.n_heads, dh), jnp.float32)}
