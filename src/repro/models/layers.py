"""Shared building blocks: norms, RoPE, MLPs, embeddings.

Pure-functional style: ``init_*`` builds a params dict; ``*_pspec`` builds a
PartitionSpec tree with the SAME structure (tested); apply functions are free
functions. Sharding axis convention (launch/mesh.py):

  "data"  — DP/FSDP axis (params: FSDP-sharded; activations: batch)
  "model" — TP axis (params: heads / ffn / vocab / experts)
  "pod"   — multi-pod DP axis (params replicated, activations batch-sharded)
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------- helpers

def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32):
    scale = 1.0 / math.sqrt(d_in)
    return jax.random.normal(key, (d_in, d_out), dtype) * scale


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return jax.random.normal(key, (vocab, d), dtype) * 0.02


# ------------------------------------------------------------------ norms

def init_norm(cfg, key=None):
    d = cfg.d_model
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}
    return {}  # nonparametric_ln (olmo)


def norm_pspec(cfg):
    if cfg.norm == "rmsnorm":
        return {"scale": P(None)}
    if cfg.norm == "layernorm":
        return {"scale": P(None), "bias": P(None)}
    return {}


def apply_norm(cfg, p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        return (xf * p["scale"]).astype(x.dtype)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    xf = (xf - mean) * jax.lax.rsqrt(var + eps)
    if cfg.norm == "layernorm":
        xf = xf * p["scale"] + p["bias"]
    return xf.astype(x.dtype)  # nonparametric_ln: no affine


# ------------------------------------------------------------------- RoPE

def rope_freqs(positions: jnp.ndarray, dh: int, theta: float) -> tuple[jnp.ndarray, jnp.ndarray]:
    """positions (...,S) -> cos/sin (...,S, dh//2), fp32."""
    inv = 1.0 / (theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x (B,S,H,dh); cos/sin (B,S,dh//2) or (S,dh//2)."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    if cos.ndim == 2:
        cos, sin = cos[None, :, None, :], sin[None, :, None, :]
    else:
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


# ------------------------------------------------------------------- MLPs

def init_mlp(key, cfg, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.act == "swiglu":
        return {"wi": dense_init(k1, d, f), "wg": dense_init(k2, d, f), "wo": dense_init(k3, f, d)}
    return {"wi": dense_init(k1, d, f), "wo": dense_init(k3, f, d)}


def mlp_pspec(cfg):
    if cfg.act == "swiglu":
        return {"wi": P("data", "model"), "wg": P("data", "model"), "wo": P("model", "data")}
    return {"wi": P("data", "model"), "wo": P("model", "data")}


def apply_mlp(cfg, p, x):
    h = x @ p["wi"].astype(x.dtype)
    if cfg.act == "swiglu":
        h = jax.nn.silu(x @ p["wg"].astype(x.dtype)) * h
    else:
        h = jax.nn.gelu(h)
    return h @ p["wo"].astype(x.dtype)


# -------------------------------------------------------------- embeddings

def init_embeddings(key, cfg, max_seq: int = 0):
    keys = jax.random.split(key, 3)
    V = cfg.padded_vocab
    p = {"tok": embed_init(keys[0], V, cfg.d_model)}
    if not cfg.tie_embeddings:
        p["head"] = dense_init(keys[1], cfg.d_model, V)
    if cfg.rope_theta == 0.0 and max_seq > 0:  # learned positions (whisper)
        p["pos"] = embed_init(keys[2], max_seq, cfg.d_model)
    return p


def embeddings_pspec(cfg, max_seq: int = 0):
    p = {"tok": P("model", "data")}
    if not cfg.tie_embeddings:
        p["head"] = P("data", "model")
    if cfg.rope_theta == 0.0 and max_seq > 0:
        p["pos"] = P(None, "data")
    return p


def embed_tokens(cfg, p, tokens):
    return jnp.take(p["tok"], tokens, axis=0)


def lm_logits(cfg, p, x):
    """Logits stay in compute dtype (bf16): at (B,S,V) they are the largest
    activation; the CE upcasts inside its (fused) reductions instead."""
    w = p["head"] if not cfg.tie_embeddings else p["tok"].T
    return x @ w.astype(x.dtype)
