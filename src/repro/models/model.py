"""Model assembly for all 10 assigned architectures.

Homogeneous layer stacks are STACKED (leading L dim) and driven by
``lax.scan`` — the production pattern (MaxText-style) that keeps HLO size and
compile time O(1) in depth and makes remat policies uniform. Heterogeneous
families (zamba2's Mamba/shared-attention interleave, xlstm's mLSTM/sLSTM
mix) use explicit per-layer parameter lists instead (cfg.scan_layers=False).

Entry points:
  init_params / param_pspecs          — parameters + PartitionSpec tree
  loss_fn                             — training loss (+ MoE aux, counts)
  prefill / decode_step               — serving paths with KV/SSM caches
  cache_specs                         — ShapeDtypeStructs for the dry-run
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import attention as A
from . import layers as L
from . import moe as MOE
from . import ssm as SS
from .moe import DistContext


# ----------------------------------------------------------------------------
# Layer-stack segmentation
# ----------------------------------------------------------------------------

def segments_of(cfg) -> list[tuple[str, int]]:
    """Homogeneous (kind, count) segments of the decoder stack."""
    if cfg.family in ("dense", "vlm"):
        return [("dense", cfg.n_layers)]
    if cfg.family == "moe":
        segs = []
        if cfg.moe_layer_start > 0:
            segs.append(("densffn", cfg.moe_layer_start))
        segs.append(("moe", cfg.n_layers - cfg.moe_layer_start))
        return segs
    if cfg.family == "encdec":
        return [("dec", cfg.n_layers)]
    raise ValueError(cfg.family)


def n_moe_layers(cfg) -> int:
    return (cfg.n_layers - cfg.moe_layer_start) if cfg.moe else 0


# ----------------------------------------------------------------------------
# Block init / pspec
# ----------------------------------------------------------------------------

def _init_block(key, cfg, kind: str):
    ks = jax.random.split(key, 4)
    if kind in ("dense", "densffn", "moe"):
        p = {"ln1": L.init_norm(cfg), "attn": A.init_attention(ks[0], cfg),
             "ln2": L.init_norm(cfg)}
        if kind == "dense":
            p["mlp"] = L.init_mlp(ks[1], cfg)
        elif kind == "densffn":
            p["mlp"] = L.init_mlp(ks[1], cfg, d_ff=cfg.dense_d_ff)
        else:
            p["moe"] = MOE.init_moe(ks[1], cfg)
        return p
    if kind == "enc":
        return {"ln1": L.init_norm(cfg), "attn": A.init_attention(ks[0], cfg),
                "ln2": L.init_norm(cfg), "mlp": L.init_mlp(ks[1], cfg)}
    if kind == "dec":
        return {"ln1": L.init_norm(cfg), "attn": A.init_attention(ks[0], cfg),
                "lnx": L.init_norm(cfg), "xattn": A.init_attention(ks[2], cfg, cross=True),
                "ln2": L.init_norm(cfg), "mlp": L.init_mlp(ks[1], cfg)}
    if kind == "A":  # zamba2 shared attention block
        return {"ln1": L.init_norm(cfg), "attn": A.init_attention(ks[0], cfg),
                "ln2": L.init_norm(cfg), "mlp": L.init_mlp(ks[1], cfg)}
    if kind == "M":
        return {"ln1": L.init_norm(cfg), "mamba": SS.init_mamba2(ks[0], cfg)}
    if kind == "X":
        return {"ln1": L.init_norm(cfg), "mlstm": SS.init_mlstm(ks[0], cfg)}
    if kind == "S":
        return {"ln1": L.init_norm(cfg), "slstm": SS.init_slstm(ks[0], cfg)}
    raise ValueError(kind)


def _block_pspec(cfg, kind: str, tp: int):
    n = L.norm_pspec(cfg)
    if kind in ("dense", "densffn", "moe"):
        p = {"ln1": n, "attn": A.attention_pspec(cfg, tp), "ln2": dict(n)}
        if kind == "moe":
            p["moe"] = MOE.moe_pspec(cfg)
        else:
            p["mlp"] = L.mlp_pspec(cfg)
        return p
    if kind in ("enc", "A"):
        return {"ln1": n, "attn": A.attention_pspec(cfg, tp), "ln2": dict(n),
                "mlp": L.mlp_pspec(cfg)}
    if kind == "dec":
        return {"ln1": n, "attn": A.attention_pspec(cfg, tp), "lnx": dict(n),
                "xattn": A.attention_pspec(cfg, tp), "ln2": dict(n),
                "mlp": L.mlp_pspec(cfg)}
    if kind == "M":
        return {"ln1": n, "mamba": SS.mamba2_pspec(cfg, tp)}
    if kind == "X":
        return {"ln1": n, "mlstm": SS.mlstm_pspec(cfg, tp)}
    if kind == "S":
        return {"ln1": n, "slstm": SS.slstm_pspec(cfg, tp)}
    raise ValueError(kind)


def _stack_init(key, cfg, kind: str, count: int):
    keys = jax.random.split(key, count)
    return jax.vmap(lambda k: _init_block(k, cfg, kind))(keys)


def _stack_pspec(cfg, kind: str, tp: int):
    """Prepend the stacked-layer dim (unsharded) to every leaf pspec."""
    return jax.tree.map(lambda s: P(None, *s), _block_pspec(cfg, kind, tp),
                        is_leaf=lambda x: isinstance(x, P))


# ----------------------------------------------------------------------------
# init / pspecs
# ----------------------------------------------------------------------------

def init_params(cfg, key, max_seq: int = 0):
    k_emb, k_body, k_enc = jax.random.split(key, 3)
    params: dict[str, Any] = {"embed": L.init_embeddings(k_emb, cfg, max_seq)}
    if cfg.family in ("hybrid", "ssm"):
        keys = jax.random.split(k_body, len(cfg.block_pattern))
        blocks = []
        shared_attn = None
        for i, kind in enumerate(cfg.block_pattern):
            if kind == "A" and cfg.shared_attention:
                if shared_attn is None:
                    shared_attn = _init_block(keys[i], cfg, "A")
                blocks.append({})  # weights live in params["shared_attn"]
            else:
                blocks.append(_init_block(keys[i], cfg, kind))
        params["blocks"] = blocks
        if shared_attn is not None:
            params["shared_attn"] = shared_attn
    elif cfg.family == "encdec":
        params["enc"] = _stack_init(k_enc, cfg, "enc", cfg.encoder_layers)
        params["enc_norm"] = L.init_norm(cfg)
        params["segments"] = [_stack_init(k_body, cfg, "dec", cfg.n_layers)]
    else:
        segs = segments_of(cfg)
        keys = jax.random.split(k_body, len(segs))
        params["segments"] = [
            _stack_init(k, cfg, kind, cnt) for k, (kind, cnt) in zip(keys, segs)
        ]
    params["final_norm"] = L.init_norm(cfg)
    return params


def param_pspecs(cfg, tp: int = 16, max_seq: int = 0):
    ps: dict[str, Any] = {"embed": L.embeddings_pspec(cfg, max_seq)}
    if cfg.family in ("hybrid", "ssm"):
        blocks = []
        shared_done = False
        for kind in cfg.block_pattern:
            if kind == "A" and cfg.shared_attention:
                blocks.append({})
                shared_done = True
            else:
                blocks.append(_block_pspec(cfg, kind, tp))
        ps["blocks"] = blocks
        if shared_done:
            ps["shared_attn"] = _block_pspec(cfg, "A", tp)
    elif cfg.family == "encdec":
        ps["enc"] = _stack_pspec(cfg, "enc", tp)
        ps["enc_norm"] = L.norm_pspec(cfg)
        ps["segments"] = [_stack_pspec(cfg, "dec", tp)]
    else:
        ps["segments"] = [_stack_pspec(cfg, kind, tp) for kind, _ in segments_of(cfg)]
    ps["final_norm"] = L.norm_pspec(cfg)
    return ps


# ----------------------------------------------------------------------------
# Transformer block application
# ----------------------------------------------------------------------------

def _apply_block_full(cfg, kind, p, x, *, cap_scale=None, dist=None,
                      window=0, cross_kv=None, causal=True,
                      moe_dropless=False):
    """Full-sequence block (train / prefill). Returns (x, kv, aux)."""
    aux = None
    kv = None
    if kind in ("dense", "densffn", "moe", "enc", "dec", "A"):
        h, kv = A.attention(cfg, p["attn"], A_norm(cfg, p["ln1"], x),
                            causal=causal, window=window)
        x = x + h
        if kind == "dec" and cross_kv is not None:
            h, _ = A.attention(cfg, p["xattn"], A_norm(cfg, p["lnx"], x),
                               causal=False, cross_kv=cross_kv)
            x = x + h
        if kind == "moe":
            h, aux = MOE.apply_moe(cfg, p["moe"], A_norm(cfg, p["ln2"], x),
                                   cap_scale, dist=dist,
                                   dropless=moe_dropless)
        else:
            h = L.apply_mlp(cfg, p["mlp"], A_norm(cfg, p["ln2"], x))
        x = x + h
    elif kind == "M":
        h, kv = SS.apply_mamba2(cfg, p["mamba"], A_norm(cfg, p["ln1"], x))
        x = x + h
    elif kind == "X":
        h, kv = SS.apply_mlstm(cfg, p["mlstm"], A_norm(cfg, p["ln1"], x))
        x = x + h
    elif kind == "S":
        h, kv = SS.apply_slstm(cfg, p["slstm"], A_norm(cfg, p["ln1"], x))
        x = x + h
    else:
        raise ValueError(kind)
    return x, kv, aux


def A_norm(cfg, p, x):
    return L.apply_norm(cfg, p, x)


REMAT_POLICIES = {
    # nothing: recompute the whole layer in bwd — lowest memory (baseline)
    "nothing": lambda: jax.checkpoint_policies.nothing_saveable,
    # dots: save matmul outputs — fastest bwd, highest memory
    "dots": lambda: jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
}


def remat_policy(cfg):
    return REMAT_POLICIES.get(getattr(cfg, "remat_policy", "nothing"),
                              REMAT_POLICIES["nothing"])()


def _constrain(x, dist: Optional[DistContext]):
    if dist is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(dist.mesh, P((*dist.batch_axes,), None, None)))


# ----------------------------------------------------------------------------
# Forward (training) + loss
# ----------------------------------------------------------------------------

def _run_segments(cfg, params, x, *, cap_scales=None, dist=None,
                  cross_kv=None, causal=True, collect_kv=False,
                  moe_dropless=False):
    """Run the decoder stack. Returns (x, aux_summary, kvs per segment)."""
    aux_sum = {"aux_loss": jnp.zeros((), jnp.float32),
               "dropped": jnp.zeros((), jnp.float32),
               "stolen": jnp.zeros((), jnp.float32),
               "entries": jnp.zeros((), jnp.float32)}
    counts = []
    kvs = []

    if cfg.family in ("hybrid", "ssm"):
        for i, kind in enumerate(cfg.block_pattern):
            p = params["blocks"][i]
            if kind == "A" and cfg.shared_attention:
                p = params["shared_attn"]
            window = cfg.attn_window if kind == "A" else 0

            def blk(p_, x_, kind=kind, window=window):
                return _apply_block_full(cfg, kind, p_, x_, dist=dist,
                                         window=window)

            if cfg.remat and not collect_kv:
                blk = jax.checkpoint(blk, policy=remat_policy(cfg))
            x, kv, _ = blk(p, x)
            x = _constrain(x, dist)
            if collect_kv:
                kvs.append(kv)
        return x, aux_sum, counts, kvs

    moe_i = 0
    for seg_idx, (kind, cnt) in enumerate(segments_of(cfg)):
        stacked = params["segments"][seg_idx]
        cap_seg = None
        if kind == "moe":
            cap_seg = cap_scales[moe_i:moe_i + cnt]
            moe_i += cnt

        def body(carry, xs):
            x, acc = carry
            p_layer = xs["p"]
            cap = xs.get("cap")
            x, kv, aux = _apply_block_full(cfg, kind, p_layer, x,
                                           cap_scale=cap, dist=dist,
                                           causal=causal,
                                           moe_dropless=moe_dropless)
            x = _constrain(x, dist)
            out = {}
            if collect_kv and kv is not None:
                out["k"], out["v"] = kv
            if aux is not None:
                acc = {key: acc[key] + aux[key] for key in acc}
                out["counts"] = aux["counts"]
            return (x, acc), out

        if cfg.remat:
            body = jax.checkpoint(body, policy=remat_policy(cfg))

        xs_in = {"p": stacked}
        if cap_seg is not None:
            xs_in["cap"] = cap_seg
        (x, aux_sum), ys = jax.lax.scan(body, (x, aux_sum), xs_in)
        if "counts" in ys:
            counts.append(ys["counts"])
        if collect_kv and "k" in ys:
            kvs.append((ys["k"], ys["v"]))
    return x, aux_sum, counts, kvs


def _embed_inputs(cfg, params, batch, dtype):
    """Token (+ frontend stub) embedding. Returns (x, n_prefix)."""
    x = L.embed_tokens(cfg, params["embed"], batch["tokens"]).astype(dtype)
    n_prefix = 0
    if cfg.family == "vlm" and "patches" in batch:
        x = jnp.concatenate([batch["patches"].astype(dtype), x], axis=1)
        n_prefix = batch["patches"].shape[1]
    if cfg.rope_theta == 0.0 and "pos" in params["embed"]:
        S = x.shape[1]
        x = x + params["embed"]["pos"][:S][None].astype(dtype)
    return x, n_prefix


def _encode(cfg, params, frames, dtype, dist=None):
    """Whisper encoder over stub frame embeddings (B, S_enc, D)."""
    x = frames.astype(dtype)
    if "pos" in params["embed"]:
        x = x + params["embed"]["pos"][:x.shape[1]][None].astype(dtype)

    def body(carry, p_layer):
        h, _, _ = _apply_block_full(cfg, "enc", p_layer, carry, causal=False,
                                    dist=dist)
        return _constrain(h, dist), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["enc"])
    return L.apply_norm(cfg, params["enc_norm"], x)


def loss_fn(cfg, params, batch, cap_scales=None, *, dist=None,
            dtype=jnp.bfloat16, aux_weight: float = 0.01):
    """batch: tokens (B,S), labels (B,S) [-1 = masked]; encdec: + frames;
    vlm: + patches. Returns (loss, metrics)."""
    x, n_prefix = _embed_inputs(cfg, params, batch, dtype)
    cross_kv = None
    if cfg.family == "encdec":
        enc_out = _encode(cfg, params, batch["frames"], dtype, dist)
        # cross K/V computed once from encoder output with the first dec
        # layer's projections applied per-layer inside the stack; here we
        # precompute per-layer K/V lazily by passing enc_out and projecting
        # inside each layer -- for scan simplicity we share one projection
        # input (enc_out) and let each layer build its own K/V.
        cross_kv = enc_out

    if cross_kv is not None:
        x, aux, counts, _ = _run_segments_encdec(cfg, params, x, cross_kv, dist)
    else:
        x, aux, counts, _ = _run_segments(cfg, params, x,
                                          cap_scales=cap_scales, dist=dist)
    x = L.apply_norm(cfg, params["final_norm"], x)
    if n_prefix:
        x = x[:, n_prefix:]
    logits = L.lm_logits(cfg, params["embed"], x)
    labels = batch["labels"]
    valid = labels >= 0
    lab = jnp.where(valid, labels, 0)
    # Sharding-friendly CE: no take_along_axis across the model-sharded vocab
    # dim (which would force an all-gather of the full logits). The one-hot
    # mask and the exp fuse into the reductions, so nothing of size V is
    # materialized beyond the (already sharded, bf16) logits; accumulation
    # happens in fp32.
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = (logits - m).astype(jnp.float32)
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0].astype(jnp.float32)
    onehot = (lab[..., None] == jnp.arange(logits.shape[-1])[None, None])
    true_logit = jnp.sum(jnp.where(onehot, logits, 0).astype(jnp.float32), axis=-1)
    nll = lse - true_logit
    loss = jnp.sum(nll * valid) / jnp.maximum(valid.sum(), 1)
    metrics = {"loss": loss, "n_tokens": valid.sum()}
    if cfg.moe:
        loss = loss + aux_weight * aux["aux_loss"]
        metrics.update({k: aux[k] for k in ("aux_loss", "dropped", "stolen", "entries")})
        metrics["counts"] = (jnp.concatenate(counts, axis=0)
                             if counts else jnp.zeros((0, cfg.n_experts)))
    return loss, metrics


def _run_segments_encdec(cfg, params, x, enc_out, dist):
    """Decoder stack with per-layer cross-attention against enc_out."""
    stacked = params["segments"][0]

    def body(carry, p_layer):
        h = carry
        a, _ = A.attention(cfg, p_layer["attn"], A_norm(cfg, p_layer["ln1"], h),
                           causal=True)
        h = h + a
        # per-layer cross K/V from encoder output
        ek = (enc_out @ p_layer["xattn"]["wk"].astype(h.dtype)).reshape(
            enc_out.shape[0], enc_out.shape[1], cfg.n_kv_heads, cfg.dh)
        ev = (enc_out @ p_layer["xattn"]["wv"].astype(h.dtype)).reshape(
            enc_out.shape[0], enc_out.shape[1], cfg.n_kv_heads, cfg.dh)
        a, _ = A.attention(cfg, p_layer["xattn"], A_norm(cfg, p_layer["lnx"], h),
                           causal=False, cross_kv=(ek, ev))
        h = h + a
        h = h + L.apply_mlp(cfg, p_layer["mlp"], A_norm(cfg, p_layer["ln2"], h))
        return _constrain(h, dist), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, stacked)
    zero = jnp.zeros((), jnp.float32)
    return x, {"aux_loss": zero, "dropped": zero, "stolen": zero,
               "entries": zero}, [], []


# ----------------------------------------------------------------------------
# Serving: prefill + decode
# ----------------------------------------------------------------------------

def prefill(cfg, params, batch, cap_scales=None, *, dist=None,
            dtype=jnp.bfloat16):
    """Process the full prompt; return (last-token logits, cache).

    Cache layout matches decode_step: per-segment stacked (L,B,S,Hkv,dh) K/V
    for attention stacks; per-layer state list for hybrid/ssm; whisper adds
    per-layer cross K/V computed once from the encoder output.

    MoE layers dispatch DROPLESS here (per-request capacity — see
    models/moe.py): a served token's output must not depend on which other
    tokens share the batch, and decode must continue a prefill exactly.
    """
    x, n_prefix = _embed_inputs(cfg, params, batch, dtype)
    if cfg.family == "encdec":
        enc_out = _encode(cfg, params, batch["frames"], dtype, dist)
        x, cache = _prefill_encdec(cfg, params, x, enc_out, dist)
    elif cfg.family in ("hybrid", "ssm"):
        states = []
        for i, kind in enumerate(cfg.block_pattern):
            p = params["blocks"][i]
            if kind == "A" and cfg.shared_attention:
                p = params["shared_attn"]
            window = cfg.attn_window if kind == "A" else 0
            x, st, _ = _apply_block_full(cfg, kind, p, x, dist=dist, window=window)
            x = _constrain(x, dist)
            states.append({"k": st[0], "v": st[1]} if kind == "A" else st)
        cache = states
    else:
        x, _, _, kvs = _run_segments(cfg, params, x, cap_scales=cap_scales,
                                     dist=dist, collect_kv=True,
                                     moe_dropless=True)
        cache = [{"k": k, "v": v} for (k, v) in kvs]
    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = L.lm_logits(cfg, params["embed"], x[:, -1])
    return logits, cache


def _prefill_encdec(cfg, params, x, enc_out, dist):
    stacked = params["segments"][0]
    B, Se = enc_out.shape[:2]

    def body(carry, p_layer):
        h = carry
        a, kv = A.attention(cfg, p_layer["attn"], A_norm(cfg, p_layer["ln1"], h),
                            causal=True)
        h = h + a
        ek = (enc_out @ p_layer["xattn"]["wk"].astype(h.dtype)).reshape(
            B, Se, cfg.n_kv_heads, cfg.dh)
        ev = (enc_out @ p_layer["xattn"]["wv"].astype(h.dtype)).reshape(
            B, Se, cfg.n_kv_heads, cfg.dh)
        a, _ = A.attention(cfg, p_layer["xattn"], A_norm(cfg, p_layer["lnx"], h),
                           causal=False, cross_kv=(ek, ev))
        h = h + a
        h = h + L.apply_mlp(cfg, p_layer["mlp"], A_norm(cfg, p_layer["ln2"], h))
        return _constrain(h, dist), {"k": kv[0], "v": kv[1], "ck": ek, "cv": ev}

    x, ys = jax.lax.scan(body, x, stacked)
    return x, {"self": [{"k": ys["k"], "v": ys["v"]}],
               "cross": {"k": ys["ck"], "v": ys["cv"]}}


def extend_cache_specs_ok(cfg) -> bool:
    """True when `prefill_extend` supports this family: stacked attention
    segments whose cache is per-segment (L,B,S,Hkv,dh) K/V, or pure
    recurrent stacks (ssm) whose O(1) block states thread chunk to
    chunk."""
    if cfg.family == "ssm":
        # an attention block in the pattern would need windowed-KV
        # extension — the hybrid family stays on the prefix-rerun path
        return all(k in ("M", "X", "S") for k in cfg.block_pattern)
    return cfg.family in ("dense", "vlm", "moe")


def empty_extend_cache(cfg, batch: int, seq: int, dtype=jnp.bfloat16):
    """Zeroed per-segment K/V caches sized for an incremental prefill of
    exactly `seq` tokens. Sizing the cache to the PROMPT length (not
    max_seq) is what makes chunked extension bit-identical to a one-shot
    prefill: the final chunk's attention reduces over the same Skv, with
    the not-yet-written tail excluded by the causal mask (scores at
    NEG_INF underflow to exact 0.0 weight).

    ssm family: recurrent blocks carry O(1) state, not a (seq,) cache —
    the zero state IS what a from-scratch scan starts from, so the first
    chunk already matches a one-shot prefill's opening scan steps."""
    if cfg.family == "ssm":
        def zeros(spec):
            return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)
        states = []
        for kind in cfg.block_pattern:
            if kind == "M":
                states.append(zeros(SS.mamba2_state_spec(cfg, batch, dtype)))
            elif kind == "X":
                states.append(zeros(SS.mlstm_state_spec(cfg, batch)))
            else:  # "S"
                states.append(zeros(SS.slstm_state_spec(cfg, batch)))
        return states
    hkv, dh = cfg.n_kv_heads, cfg.dh
    return [{"k": jnp.zeros((cnt, batch, seq, hkv, dh), dtype),
             "v": jnp.zeros((cnt, batch, seq, hkv, dh), dtype)}
            for _, cnt in segments_of(cfg)]


def prefill_extend(cfg, params, tokens, cache, done, cap_scales=None, *,
                   dist=None, dtype=jnp.bfloat16, ssm_chunk=None):
    """Incremental chunked prefill: run ONLY the new chunk against the
    growing cache — O(chunk * context) work per chunk instead of the
    O(prefix^2) of re-running the whole prefix every chunk.

    `tokens` is the chunk (B, C) starting at absolute position `done`
    (scalar, may be traced); `cache` holds the previous chunks' K/V in
    positions [0, done) of per-segment stacked (L, B, S, Hkv, dh) buffers
    (see `empty_extend_cache`). Returns (last-token logits, new cache).

    Bit-identity with `prefill` of the full prompt: every per-position
    computation (embed, norms, q/k/v projections, the attention einsum,
    MLP/MoE rows) is a row-wise function of that position's values, so a
    chunk's rows match the full run's rows exactly; the attention softmax
    reduces over the same cache-length Skv with identical masked entries.
    MoE layers dispatch dropless (per-token, no cross-token capacity
    competition) exactly like `prefill`. Supported families are listed by
    `extend_cache_specs_ok`: stacked attention segments, plus the ssm
    family, whose recurrent block states (mamba2 conv+ssm, mLSTM matrix,
    sLSTM h/c) thread from chunk to chunk. For ssm the bit-identity
    condition is scan-block alignment: `ssm_chunk` must be the one-shot
    run's Q = min(cfg.ssm_chunk, prompt_len) and every chunk boundary a
    multiple of it (the serving engine enforces both) — then each call
    replays exactly the scan steps the one-shot `chunked_gated_scan`
    would run, final partial chunk padded identically. Hybrid recurrent
    state (attention blocks in the pattern) and encoder caches still
    don't extend this way.
    """
    if not extend_cache_specs_ok(cfg):
        raise NotImplementedError(
            f"prefill_extend supports stacked attention families, "
            f"not {cfg.family!r}")
    B, C = tokens.shape
    x = L.embed_tokens(cfg, params["embed"], tokens).astype(dtype)
    if cfg.rope_theta == 0.0 and "pos" in params["embed"]:
        x = x + jax.lax.dynamic_slice_in_dim(
            params["embed"]["pos"], done, C, 0)[None].astype(dtype)

    if cfg.family == "ssm":
        Q = int(ssm_chunk) if ssm_chunk else getattr(cfg, "ssm_chunk", 256)
        new_states = []
        for i, kind in enumerate(cfg.block_pattern):
            p = params["blocks"][i]
            xin = A_norm(cfg, p["ln1"], x)
            if kind == "M":
                h, ns = SS.apply_mamba2(cfg, p["mamba"], xin,
                                        state=cache[i], chunk=Q,
                                        exact_chunk=True)
            elif kind == "X":
                h, ns = SS.apply_mlstm(cfg, p["mlstm"], xin,
                                       state=cache[i], chunk=Q,
                                       exact_chunk=True)
            else:  # "S": plain lax.scan, exact at any boundary
                h, ns = SS.apply_slstm(cfg, p["slstm"], xin, state=cache[i])
            x = x + h
            x = _constrain(x, dist)
            new_states.append(ns)
        x = L.apply_norm(cfg, params["final_norm"], x)
        logits = L.lm_logits(cfg, params["embed"], x[:, -1])
        return logits, new_states

    positions = done + jnp.arange(C)

    new_cache = []
    moe_i = 0
    for seg_idx, (kind, cnt) in enumerate(segments_of(cfg)):
        stacked = params["segments"][seg_idx]
        cap_seg = None
        if kind == "moe":
            cap_seg = cap_scales[moe_i:moe_i + cnt]
            moe_i += cnt

        def body(x, xs, kind=kind):
            p_layer = xs["p"]
            q, k1, v1 = A._qkv(cfg, p_layer["attn"],
                               A_norm(cfg, p_layer["ln1"], x))
            if cfg.rope_theta > 0:
                cos, sin = L.rope_freqs(positions, cfg.dh, cfg.rope_theta)
                q = L.apply_rope(q, cos, sin)
                k1 = L.apply_rope(k1, cos, sin)
            ck = jax.lax.dynamic_update_slice(
                xs["k"], k1.astype(xs["k"].dtype), (0, done, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                xs["v"], v1.astype(xs["v"].dtype), (0, done, 0, 0))
            h = A.full_attention(q, ck, cv, causal=True, q_offset=done)
            h = h.reshape(B, C, cfg.n_heads * cfg.dh) \
                @ p_layer["attn"]["wo"].astype(x.dtype)
            x = x + h
            xin = A_norm(cfg, p_layer["ln2"], x)
            if kind == "moe":
                h, _ = MOE.apply_moe(cfg, p_layer["moe"], xin, xs["cap"],
                                     dist=dist, dropless=True)
            else:
                h = L.apply_mlp(cfg, p_layer["mlp"], xin)
            x = x + h
            return _constrain(x, dist), {"k": ck, "v": cv}

        xs_in = {"p": stacked, "k": cache[seg_idx]["k"],
                 "v": cache[seg_idx]["v"]}
        if cap_seg is not None:
            xs_in["cap"] = cap_seg
        x, ys = jax.lax.scan(body, x, xs_in)
        new_cache.append(ys)

    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = L.lm_logits(cfg, params["embed"], x[:, -1])
    return logits, new_cache


def decode_step(cfg, params, tokens, cache, pos, cap_scales=None, *,
                dist=None, dtype=jnp.bfloat16):
    """One decode step. tokens (B,1) int32; pos: scalar int32 (current write
    position; same across the batch — serve_step semantics). Returns
    (logits (B,V), new cache)."""
    x = L.embed_tokens(cfg, params["embed"], tokens).astype(dtype)
    if cfg.rope_theta == 0.0 and "pos" in params["embed"]:
        x = x + params["embed"]["pos"][pos][None, None].astype(dtype)

    if cfg.family in ("hybrid", "ssm"):
        new_states = []
        for i, kind in enumerate(cfg.block_pattern):
            p = params["blocks"][i]
            if kind == "A" and cfg.shared_attention:
                p = params["shared_attn"]
            st = cache[i]
            if kind == "A":
                h, ck, cv = A.decode_attention(
                    cfg, p["attn"], A_norm(cfg, p["ln1"], x), st["k"], st["v"],
                    pos, window=cfg.attn_window)
                x = x + h
                x = x + L.apply_mlp(cfg, p["mlp"], A_norm(cfg, p["ln2"], x))
                new_states.append({"k": ck, "v": cv})
            elif kind == "M":
                h, ns = SS.apply_mamba2(cfg, p["mamba"], A_norm(cfg, p["ln1"], x), state=st)
                x = x + h
                new_states.append(ns)
            elif kind == "X":
                h, ns = SS.apply_mlstm(cfg, p["mlstm"], A_norm(cfg, p["ln1"], x), state=st)
                x = x + h
                new_states.append(ns)
            else:  # "S"
                h, ns = SS.apply_slstm(cfg, p["slstm"], A_norm(cfg, p["ln1"], x), state=st)
                x = x + h
                new_states.append(ns)
        new_cache = new_states
    elif cfg.family == "encdec":
        x, new_cache = _decode_encdec(cfg, params, x, cache, pos, dist)
    else:
        seg_caches = cache
        new_cache = []
        moe_i = 0
        for seg_idx, (kind, cnt) in enumerate(segments_of(cfg)):
            stacked = params["segments"][seg_idx]
            cap_seg = None
            if kind == "moe":
                cap_seg = cap_scales[moe_i:moe_i + cnt]
                moe_i += cnt

            def body(x, xs):
                p_layer = xs["p"]
                h, ck, cv = A.decode_attention(
                    cfg, p_layer["attn"], A_norm(cfg, p_layer["ln1"], x),
                    xs["k"], xs["v"], pos)
                x = x + h
                xin = A_norm(cfg, p_layer["ln2"], x)
                if kind == "moe":
                    # dropless like prefill: a single decode token must see
                    # the same experts it would in a fresh longer prefill
                    h, _ = MOE.apply_moe(cfg, p_layer["moe"], xin, xs["cap"],
                                         dist=dist, dropless=True)
                else:
                    h = L.apply_mlp(cfg, p_layer["mlp"], xin)
                x = x + h
                return x, {"k": ck, "v": cv}

            xs_in = {"p": stacked, "k": seg_caches[seg_idx]["k"],
                     "v": seg_caches[seg_idx]["v"]}
            if cap_seg is not None:
                xs_in["cap"] = cap_seg
            x, ys = jax.lax.scan(body, x, xs_in)
            new_cache.append(ys)

    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = L.lm_logits(cfg, params["embed"], x[:, -1])
    return logits, new_cache


def _decode_encdec(cfg, params, x, cache, pos, dist):
    stacked = params["segments"][0]

    def body(x, xs):
        p_layer = xs["p"]
        h, ck, cv = A.decode_attention(
            cfg, p_layer["attn"], A_norm(cfg, p_layer["ln1"], x),
            xs["k"], xs["v"], pos)
        x = x + h
        h, _, _ = A.decode_attention(
            cfg, p_layer["xattn"], A_norm(cfg, p_layer["lnx"], x),
            xs["ck"], xs["cv"], pos, cross=True)
        x = x + h
        x = x + L.apply_mlp(cfg, p_layer["mlp"], A_norm(cfg, p_layer["ln2"], x))
        return x, {"k": ck, "v": cv}

    xs_in = {"p": stacked, "k": cache["self"][0]["k"], "v": cache["self"][0]["v"],
             "ck": cache["cross"]["k"], "cv": cache["cross"]["v"]}
    x, ys = jax.lax.scan(body, x, xs_in)
    return x, {"self": [ys], "cross": cache["cross"]}


# ----------------------------------------------------------------------------
# Cache specs (dry-run ShapeDtypeStructs) + sharding
# ----------------------------------------------------------------------------

def cache_specs(cfg, batch: int, cache_len: int, dtype=jnp.bfloat16):
    """ShapeDtypeStruct tree matching decode_step's cache argument."""
    hkv, dh = cfg.n_kv_heads, cfg.dh
    if cfg.family in ("hybrid", "ssm"):
        states = []
        for kind in cfg.block_pattern:
            if kind == "A":
                w = min(cache_len, cfg.attn_window) if cfg.attn_window else cache_len
                states.append({
                    "k": jax.ShapeDtypeStruct((batch, w, hkv, dh), dtype),
                    "v": jax.ShapeDtypeStruct((batch, w, hkv, dh), dtype)})
            elif kind == "M":
                states.append(SS.mamba2_state_spec(cfg, batch))
            elif kind == "X":
                states.append(SS.mlstm_state_spec(cfg, batch))
            else:
                states.append(SS.slstm_state_spec(cfg, batch))
        return states
    if cfg.family == "encdec":
        Lx = cfg.n_layers
        return {
            "self": [{
                "k": jax.ShapeDtypeStruct((Lx, batch, cache_len, hkv, dh), dtype),
                "v": jax.ShapeDtypeStruct((Lx, batch, cache_len, hkv, dh), dtype)}],
            "cross": {
                "k": jax.ShapeDtypeStruct((Lx, batch, cfg.encoder_seq, hkv, dh), dtype),
                "v": jax.ShapeDtypeStruct((Lx, batch, cfg.encoder_seq, hkv, dh), dtype)},
        }
    out = []
    for kind, cnt in segments_of(cfg):
        out.append({
            "k": jax.ShapeDtypeStruct((cnt, batch, cache_len, hkv, dh), dtype),
            "v": jax.ShapeDtypeStruct((cnt, batch, cache_len, hkv, dh), dtype)})
    return out


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def cache_pspecs(cfg, batch: int, mesh, batch_axes=("data",)):
    """PartitionSpec tree for the cache: batch over data axes when divisible,
    kv-heads / ssm-heads over "model" when divisible, else replicated."""
    dp = 1
    for a in batch_axes:
        dp *= mesh.shape[a]
    tp = mesh.shape["model"]
    b_ax = tuple(batch_axes) if _div(batch, dp) else None

    def kv_spec(stacked: bool):
        lead = (None,) if stacked else ()
        if _div(cfg.n_kv_heads, tp):
            return P(*lead, b_ax, None, "model", None)
        # kv heads don't divide TP: shard the cache SEQ dim over "model"
        # instead (sequence-sharded KV). The baseline decode all-gathers one
        # layer's shard at a time (fits HBM; collective-heavy — the
        # flash-decoding shard_map path in §Perf removes that traffic).
        return P(*lead, b_ax, "model", None, None)

    if cfg.family in ("hybrid", "ssm"):
        d_in = cfg.mamba_expand * cfg.d_model
        hm = d_in // cfg.ssm_head_dim
        m_ax = "model" if _div(hm, tp) else None
        x_ax = "model" if _div(cfg.n_heads, tp) else None
        states = []
        for kind in cfg.block_pattern:
            if kind == "A":
                states.append({"k": kv_spec(False), "v": kv_spec(False)})
            elif kind == "M":
                states.append({"conv": P(b_ax, None, m_ax if _div(d_in, tp) else None),
                               "ssm": P(b_ax, m_ax, None, None)})
            elif kind == "X":
                states.append(P(b_ax, x_ax, None, None))
            else:
                states.append({"h": P(b_ax, x_ax, None), "c": P(b_ax, x_ax, None)})
        return states
    if cfg.family == "encdec":
        # cross K/V covers encoder_seq (1500): small, not evenly divisible —
        # keep it replicated over "model"
        cross = P(None, b_ax, None, None, None)
        return {"self": [{"k": kv_spec(True), "v": kv_spec(True)}],
                "cross": {"k": cross, "v": cross}}
    return [{"k": kv_spec(True), "v": kv_spec(True)} for _ in segments_of(cfg)]
