"""Mixture-of-Experts layer with iCh-adaptive capacity + token stealing.

The paper's loop-scheduling problem reappears verbatim in MoE: tokens are
loop iterations, experts are workers, and router imbalance is the irregular
workload. This layer integrates iCh (DESIGN.md §2) as:

* per-expert *capacity* = the chunk size analogue, adapted by the paper's
  classification (eqs. 1-3, 8) on router load counts (the throughput signal
  that is exact and free in-graph, replacing wall-clock k_i);
* *work stealing* = a schedule-time reroute: overflow entries are rerouted
  to their token's max-slack alternative expert and ranked AFTER the
  target's first-round keeps, all before any FFN work runs — there is no
  runtime steal protocol to speak of on a TPU, the whole "steal" is one
  extra position pass over the dispatch decisions (DESIGN.md §2.8);
* `cap_scale` (E,) carried in the train state = the d_i array.

Dispatch is sort-based (argsort by expert + in-segment positions), never the
O(T*E*C) GShard one-hot einsum, so it scales to 1M-token global batches.
The decision pass (`dispatch_decisions`) is mirrored bit-for-bit by the
host-side planner `repro.sched.moe.plan_dispatch`, which feeds the same
decisions through `LoopScheduler.schedule` into the worker-sharded expert
kernel (`sched/kernels.py:MoeDispatchOp`) — the model and the scheduler
agree on every routing decision at equal capacity
(tests/test_moe_sched.py).

Serving (prefill/decode) dispatches DROPLESS (`dropless=True`): capacity
is per-request (cap = the whole local pool), so no token is ever dropped
or rerouted and a token's expert outputs cannot depend on which other
tokens share the serving batch — decode at position S is exactly a fresh
prefill of S+1 tokens (tests/test_arch_smoke.py). Training keeps the
capacity + steal semantics.

Distribution: expert-parallel over the "model" axis via shard_map — tokens
stay data-sharded and replicated across model ranks, each model rank runs its
E/tp local experts, partial token outputs are psum'ed over "model" (same
collective cost as a Megatron TP FFN all-reduce). Expert weights are
additionally FSDP-sharded over "data" and all-gathered on entry (ZeRO-3).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import layers as L
from ..sched.defaults import (ICH_EPS, MOE_CAP_SCALE_MAX, MOE_CAP_SCALE_MIN,
                              MOE_CAPACITY_FACTOR, MOE_CMAX_FACTOR,
                              MOE_MIN_CAPACITY)
from ..sched.moe import expert_capacity


@dataclasses.dataclass(frozen=True)
class DistContext:
    """How a model step is laid out on the mesh (launch/mesh.py)."""
    mesh: jax.sharding.Mesh
    batch_axes: tuple = ("data",)
    tp_axis: str = "model"
    fsdp_axis: Optional[str] = "data"

    @property
    def tp(self) -> int:
        return self.mesh.shape[self.tp_axis]


def init_moe(key, cfg):
    d, fe, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": L.dense_init(ks[0], d, e),
        "wi": jax.random.normal(ks[1], (e, d, fe)) * (d ** -0.5),
        "wg": jax.random.normal(ks[2], (e, d, fe)) * (d ** -0.5),
        "wo": jax.random.normal(ks[3], (e, fe, d)) * (fe ** -0.5),
    }
    if cfg.n_shared_experts:
        fs = cfg.n_shared_experts * fe
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "wi": L.dense_init(kk[0], d, fs),
            "wg": L.dense_init(kk[1], d, fs),
            "wo": L.dense_init(kk[2], fs, d),
        }
    return p


def moe_pspec(cfg):
    p = {
        "router": P(None, None),
        "wi": P("model", "data", None),
        "wg": P("model", "data", None),
        "wo": P("model", None, "data"),
    }
    if cfg.n_shared_experts:
        p["shared"] = {"wi": P("data", "model"), "wg": P("data", "model"),
                       "wo": P("model", "data")}
    return p


def capacity(cfg, t_local: int, factor: float = MOE_CAPACITY_FACTOR) -> int:
    """Base per-expert capacity for a local token pool of size t_local."""
    return expert_capacity(t_local, cfg.n_experts, cfg.experts_per_token,
                           factor)


# ----------------------------------------------------------------------------
# iCh balancer (paper §3.2 applied to expert load)
# ----------------------------------------------------------------------------

def ich_update_cap_scale(counts: jnp.ndarray, cap_scale: jnp.ndarray,
                         eps: float = ICH_EPS, step: float = 1.5) -> jnp.ndarray:
    """Adapt per-expert capacity scale with the paper's classification.

    counts: router load per expert (the k_i signal). Overloaded ("high")
    experts grow their capacity share, underloaded ("low") shrink it — the
    *chunk-size* direction here follows load because capacity is a buffer
    bound, not an interruption interval; the paper's inverted rule lives in
    the steal direction (overflow moves low-ward).

    The multiplicative step is damped (1.5x, not 2x — undamped doubling
    oscillates against drifting routers) and the scale is clipped to the
    materializable range [0.25, 2.0] (C_max = 2*C_base is the compiled
    buffer). Total scale is renormalized only when it EXCEEDS the budget
    (sum == E), i.e. capacity is taken from cold experts only when hot ones
    actually need it.
    """
    mu = jnp.mean(counts)
    delta = eps * mu
    up = counts > mu + delta
    down = counts < mu - delta
    new = jnp.where(up, cap_scale * step, jnp.where(down, cap_scale / step,
                                                    cap_scale))
    new = jnp.clip(new, MOE_CAP_SCALE_MIN, MOE_CAP_SCALE_MAX)
    budget = jnp.float32(cap_scale.shape[0])
    over = new.sum() / budget
    return jnp.where(over > 1.0, new / over, new)


# ----------------------------------------------------------------------------
# Sort-based dispatch with capacity + one steal round
# ----------------------------------------------------------------------------

def _dispatch_positions(experts_flat: jnp.ndarray, n_experts: int):
    """positions of each (token,choice) entry within its expert segment."""
    order = jnp.argsort(experts_flat, stable=True)
    es = experts_flat[order]
    seg_start = jnp.searchsorted(es, jnp.arange(n_experts))
    pos_sorted = jnp.arange(es.shape[0]) - seg_start[es]
    # scatter positions back to entry order
    pos = jnp.zeros_like(pos_sorted).at[order].set(pos_sorted)
    return pos


def dispatch_decisions(e_topk, cap_e, *, steal: bool = True,
                       counts: Optional[jnp.ndarray] = None):
    """Resolve the capacity cut + steal round over the flat (token, choice)
    entries. The in-graph half of the dispatch decision pass; the host-side
    planner `repro.sched.moe.plan_dispatch` mirrors it bit-for-bit.

    e_topk (T, K) router choices; cap_e (E,) per-expert capacities; counts
    optionally the precomputed (E,) router demand (recomputed if absent).
    Returns (expert, token, pos, keep, stolen): final per-entry expert ids
    (a stolen entry points at its steal target), token ids, in-segment
    dispatch slots, the survival mask, and the stolen-entry count.
    """
    T, K = e_topk.shape
    E = cap_e.shape[0]
    ef = e_topk.reshape(-1)            # (T*K,)
    tf = jnp.repeat(jnp.arange(T), K)  # token id per entry
    pos = _dispatch_positions(ef, E)
    keep = pos < cap_e[ef]

    # ---- steal round: dropped entries go to the token's best LOW expert ----
    if steal:
        if counts is None:
            counts = jnp.zeros((E,), jnp.float32).at[ef].add(1.0)
        slack = jnp.maximum(cap_e.astype(jnp.float32) - counts, 0.0)  # (E,)
        # per entry: token's alternative choices' slack (prefer max slack)
        alt_slack = slack[e_topk]                       # (T,K)
        fallback = e_topk[jnp.arange(T), jnp.argmax(alt_slack, axis=-1)]  # (T,)
        ef2 = jnp.where(keep, ef, fallback[tf])
        used = jnp.zeros((E,), jnp.int32).at[ef].add(keep.astype(jnp.int32))
        pos2 = _dispatch_positions(jnp.where(keep, E + 1, ef2), E + 2)  # rank among stolen only
        pos2 = pos2 + used[ef2]
        keep2 = (~keep) & (pos2 < cap_e[ef2])
        ef = jnp.where(keep2, ef2, ef)
        pos = jnp.where(keep2, pos2, pos)
        stolen = keep2.sum()
        keep = keep | keep2
    else:
        stolen = jnp.zeros((), jnp.int32)
    return ef, tf, pos, keep, stolen


def moe_local(cfg, p, x, cap_scale, *, eps: float = ICH_EPS,
              n_local_experts: Optional[int] = None,
              local_expert_offset: int = 0,
              capacity_factor: float = MOE_CAPACITY_FACTOR,
              steal: bool = True, dropless: bool = False):
    """MoE forward on a local token pool x (T, D).

    Router runs over ALL experts; only entries whose expert falls in
    [offset, offset + n_local) are dispatched here (EP under shard_map).
    `dropless` gives every expert capacity for the whole pool (serving:
    per-request capacity, no competition, no steal, no drops — the
    dispatch buffer grows to (E_loc, T, D)).
    Returns (y (T,D) partial output, aux dict).
    """
    T, D = x.shape
    E, K = cfg.n_experts, cfg.experts_per_token
    e_loc = n_local_experts or E
    logits = (x @ p["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    w_topk, e_topk = jax.lax.top_k(probs, K)  # (T,K)
    w_topk = w_topk / jnp.maximum(w_topk.sum(-1, keepdims=True), 1e-9)

    # aux load-balance loss (Switch): E * sum_e f_e * p_e  (global via psum
    # by the shard_map caller)
    counts_all = jnp.zeros((E,), jnp.float32).at[e_topk.reshape(-1)].add(1.0)
    me = probs.mean(axis=0)
    aux_loss = E * jnp.sum((counts_all / (T * K)) * me)

    if dropless:
        # per-request capacity: an expert can hold the whole pool, so the
        # capacity cut keeps everything and the steal round has no work
        C_max = T
        cap_e = jnp.full((E,), T, jnp.int32)
        steal = False
    else:
        C_base = capacity(cfg, T, capacity_factor)
        C_max = max(C_base, int(round(getattr(
            cfg, "moe_cmax_factor", MOE_CMAX_FACTOR) * C_base)))
        cap_e = jnp.clip(jnp.round(C_base * cap_scale), MOE_MIN_CAPACITY,
                         C_max).astype(jnp.int32)  # (E,)

    wf = w_topk.reshape(-1)
    ef, tf, pos, keep, stolen = dispatch_decisions(e_topk, cap_e,
                                                   steal=steal,
                                                   counts=counts_all)
    dropped = (~keep).sum()

    # ---- local dispatch: only entries on [offset, offset+e_loc) ----
    # Slot-indexed dispatch: build an (E_loc, C_max) slot->token map and
    # gather/scatter through it, so intermediate buffers scale with the
    # expert buffer size (E_loc*C_max*D), NOT with T*K*D (6-8x larger at
    # 1M-token global batches; the difference between fitting HBM or not).
    e_rel = ef - local_expert_offset
    local = keep & (e_rel >= 0) & (e_rel < e_loc)
    e_idx = jnp.where(local, e_rel, 0)
    c_idx = jnp.where(local, jnp.minimum(pos, C_max - 1), 0)
    slot_tok = jnp.full((e_loc, C_max), -1, jnp.int32).at[e_idx, c_idx].max(
        jnp.where(local, tf, -1).astype(jnp.int32))
    slot_w = jnp.zeros((e_loc, C_max), jnp.float32).at[e_idx, c_idx].max(
        jnp.where(local, wf, 0.0))
    slot_valid = slot_tok >= 0
    buf = jnp.where(slot_valid[..., None],
                    x[jnp.maximum(slot_tok, 0)], 0.0).astype(x.dtype)

    h = jnp.einsum("ecd,edf->ecf", buf, p["wi"].astype(x.dtype))
    g = jnp.einsum("ecd,edf->ecf", buf, p["wg"].astype(x.dtype))
    hb = jax.nn.silu(g) * h
    yb = jnp.einsum("ecf,efd->ecd", hb, p["wo"].astype(x.dtype))

    contrib = yb * (slot_w * slot_valid)[..., None].astype(yb.dtype)
    y = jnp.zeros_like(x).at[jnp.maximum(slot_tok, 0).reshape(-1)].add(
        contrib.reshape(e_loc * C_max, D))

    aux = {"aux_loss": aux_loss, "dropped": dropped.astype(jnp.float32),
           "stolen": stolen.astype(jnp.float32), "counts": counts_all,
           "entries": jnp.float32(T * K)}
    return y, aux


def apply_moe(cfg, p, x, cap_scale, *, dist: Optional[DistContext] = None,
              eps: float = ICH_EPS, steal: bool = True,
              capacity_factor: float = MOE_CAPACITY_FACTOR,
              dropless: bool = False):
    """MoE block on x (B,S,D) (or (B,1,D) decode). Returns (y, aux).

    `dropless` is the serving dispatch mode (models/model.py prefill and
    decode_step): per-request capacity, no drops, no steal."""
    B, S, D = x.shape
    x2 = x.reshape(B * S, D)

    if dist is None:
        y2, aux = moe_local(cfg, p, x2, cap_scale, eps=eps, steal=steal,
                            capacity_factor=capacity_factor,
                            dropless=dropless)
    else:
        tp = dist.tp
        e_loc = cfg.n_experts // tp
        bspec = P((*dist.batch_axes,), None)
        wspec_i = P(dist.tp_axis, dist.fsdp_axis, None)
        wspec_o = P(dist.tp_axis, None, dist.fsdp_axis)

        def block(x_l, router, wi, wg, wo, cap_l):
            if dist.fsdp_axis:
                wi = jax.lax.all_gather(wi, dist.fsdp_axis, axis=1, tiled=True)
                wg = jax.lax.all_gather(wg, dist.fsdp_axis, axis=1, tiled=True)
                wo = jax.lax.all_gather(wo, dist.fsdp_axis, axis=2, tiled=True)
            idx = jax.lax.axis_index(dist.tp_axis)
            p_l = {"router": router, "wi": wi, "wg": wg, "wo": wo}
            y_l, aux_l = moe_local(
                cfg, p_l, x_l, cap_l, eps=eps,
                n_local_experts=e_loc, local_expert_offset=idx * e_loc,
                steal=steal, capacity_factor=capacity_factor,
                dropless=dropless)
            y_l = jax.lax.psum(y_l, dist.tp_axis)
            # make aux outputs fully replicated: scalars pmean'ed over every
            # mesh axis; counts summed over data shards (global expert load)
            all_axes = (*dist.batch_axes, dist.tp_axis)
            aux_l = {
                k: (jax.lax.psum(v, dist.batch_axes)  # global expert load
                    if k == "counts" else jax.lax.pmean(v, all_axes))
                for k, v in aux_l.items()
            }
            return y_l, aux_l

        y2, aux = jax.shard_map(
            block, mesh=dist.mesh,
            in_specs=(bspec, P(None, None), wspec_i, wspec_i, wspec_o, P(None)),
            out_specs=(bspec, {"aux_loss": P(), "dropped": P(), "stolen": P(),
                               "counts": P(), "entries": P()}),
            check_vma=False,
        )(x2, p["router"], p["wi"], p["wg"], p["wo"], cap_scale)

    y = y2.reshape(B, S, D)
    if cfg.n_shared_experts:
        sp = p["shared"]
        h = jax.nn.silu(x @ sp["wg"].astype(x.dtype)) * (x @ sp["wi"].astype(x.dtype))
        y = y + h @ sp["wo"].astype(x.dtype)
    return y, aux
