"""GQA attention: blockwise (flash-style, memory-bounded) XLA path, KV cache
decode path, sliding windows, and cross-attention (whisper).

GQA is computed with grouped einsums — K/V are NEVER materialized at Hq
width (a (B,S,Hq,dh) repeat of a 32k cache is GiBs per layer). q is viewed
as (B, S, Hkv, rep, dh) and contracted against (B, S, Hkv, dh).

The blockwise path is the XLA mirror of kernels/flash_attention (same
online-softmax algorithm) so memory stays O(S*block) at 32k prefill and the
Pallas kernel has a shape-identical oracle. The Pallas kernel additionally
skips fully-masked causal blocks — an optimization recorded in §Perf.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import layers as L

NEG_INF = -1e30


def init_attention(key, cfg, cross: bool = False):
    d, dh = cfg.d_model, cfg.dh
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": L.dense_init(ks[0], d, hq * dh),
        "wk": L.dense_init(ks[1], d, hkv * dh),
        "wv": L.dense_init(ks[2], d, hkv * dh),
        "wo": L.dense_init(ks[3], hq * dh, d),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * dh,), jnp.float32)
        p["bk"] = jnp.zeros((hkv * dh,), jnp.float32)
        p["bv"] = jnp.zeros((hkv * dh,), jnp.float32)
    return p


def attention_pspec(cfg, tp: int = 16):
    """Heads over "model" when divisible; else FSDP-only (DESIGN.md §6)."""
    q_tp = "model" if (cfg.n_heads * cfg.dh) % tp == 0 and cfg.n_heads % tp == 0 else None
    kv_tp = "model" if q_tp == "model" and cfg.n_kv_heads % tp == 0 else None
    p = {
        "wq": P("data", q_tp),
        "wk": P("data", kv_tp),
        "wv": P("data", kv_tp),
        "wo": P(q_tp, "data"),
    }
    if cfg.qkv_bias:
        p["bq"] = P(q_tp)
        p["bk"] = P(kv_tp)
        p["bv"] = P(kv_tp)
    return p


def _qkv(cfg, p, x, xkv=None):
    xkv = x if xkv is None else xkv
    B, S = x.shape[:2]
    Skv = xkv.shape[1]
    q = x @ p["wq"].astype(x.dtype)
    k = xkv @ p["wk"].astype(x.dtype)
    v = xkv @ p["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(B, S, cfg.n_heads, cfg.dh)
    k = k.reshape(B, Skv, cfg.n_kv_heads, cfg.dh)
    v = v.reshape(B, Skv, cfg.n_kv_heads, cfg.dh)
    return q, k, v


def _group_q(q, hkv):
    """(B,S,Hq,dh) -> (B,S,Hkv,rep,dh)."""
    B, S, Hq, dh = q.shape
    return q.reshape(B, S, hkv, Hq // hkv, dh)


def blockwise_attention(
    q, k, v, *,
    causal: bool,
    q_offset: int = 0,
    window: int = 0,
    q_block: int = 512,
    kv_block: int = 1024,
):
    """Online-softmax attention, O(S*block) memory. q (B,Sq,Hq,dh),
    k/v (B,Skv,Hkv,dh) un-repeated. fp32 accumulation."""
    B, Sq, Hq, dh = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    rep = Hq // Hkv
    scale = dh ** -0.5
    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Skv)
    nq = -(-Sq // q_block)
    nk = -(-Skv // kv_block)
    pad_q = nq * q_block - Sq
    pad_k = nk * kv_block - Skv
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else q
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else k
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else v
    # (B,Hkv,rep,nq,qb,dh) / (B,Hkv,nk,kb,dh)
    qp = qp.reshape(B, nq, q_block, Hkv, rep, dh).transpose(0, 3, 4, 1, 2, 5)
    kp = kp.reshape(B, nk, kv_block, Hkv, dh).transpose(0, 3, 1, 2, 4)
    vp = vp.reshape(B, nk, kv_block, Hkv, dh).transpose(0, 3, 1, 2, 4)

    q_pos = q_offset + jnp.arange(nq * q_block).reshape(nq, q_block)
    k_pos = jnp.arange(nk * kv_block).reshape(nk, kv_block)
    k_valid = (jnp.arange(nk * kv_block) < Skv).reshape(nk, kv_block)

    def per_q_block(qi):
        qb = qp[:, :, :, qi]  # (B,Hkv,rep,qb,dh)

        @partial(jax.checkpoint, policy=jax.checkpoint_policies.nothing_saveable)
        def kv_step(carry, ki):
            m, l, acc = carry
            s = jnp.einsum("bgrqd,bgkd->bgrqk", qb, kp[:, :, ki],
                           preferred_element_type=jnp.float32) * scale
            mask = k_valid[ki][None, :]
            if causal:
                mask = mask & (k_pos[ki][None, :] <= q_pos[qi][:, None])
            if window > 0:
                mask = mask & (k_pos[ki][None, :] > q_pos[qi][:, None] - window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bgrqk,bgkd->bgrqd", p, vp[:, :, ki].astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((B, Hkv, rep, q_block), NEG_INF, jnp.float32),
            jnp.zeros((B, Hkv, rep, q_block), jnp.float32),
            jnp.zeros((B, Hkv, rep, q_block, dh), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(kv_step, init, jnp.arange(nk))
        return acc / jnp.maximum(l, 1e-20)[..., None]

    out = jax.lax.map(per_q_block, jnp.arange(nq))  # (nq,B,Hkv,rep,qb,dh)
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(B, nq * q_block, Hq, dh)
    return out[:, :Sq].astype(q.dtype)


def full_attention(q, k, v, *, causal: bool, q_offset=0, window: int = 0):
    """Materialized-scores attention (decode + small shapes), grouped GQA.
    fp32 softmax. q_offset may be a traced scalar (decode position)."""
    B, Sq, Hq, dh = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    qg = _group_q(q, Hkv)
    s = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k,
                   preferred_element_type=jnp.float32) * (dh ** -0.5)
    k_pos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        q_pos = q_offset + jnp.arange(Sq)
        mask = k_pos[None, :] <= q_pos[:, None]
        if window > 0:
            mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, Hq, dh).astype(v.dtype)


def attention(
    cfg, p, x, *,
    positions=None,
    causal: bool = True,
    window: int = 0,
    impl: str = "blockwise",
    cross_kv=None,
):
    """Full-sequence attention (train / prefill). Returns (out, (k, v))."""
    if cross_kv is not None:
        q = (x @ p["wq"].astype(x.dtype)).reshape(x.shape[0], x.shape[1], cfg.n_heads, cfg.dh)
        if cfg.qkv_bias:
            q = q + p["bq"].astype(x.dtype).reshape(cfg.n_heads, cfg.dh)
        k, v = cross_kv
        kv = None
    else:
        q, k, v = _qkv(cfg, p, x)
        if cfg.rope_theta > 0:
            pos = positions if positions is not None else jnp.arange(x.shape[1])
            cos, sin = L.rope_freqs(pos, cfg.dh, cfg.rope_theta)
            q = L.apply_rope(q, cos, sin)
            k = L.apply_rope(k, cos, sin)
        kv = (k, v)
    if impl == "blockwise" and x.shape[1] >= 1024:
        out = blockwise_attention(q, k, v, causal=causal, window=window)
    else:
        out = full_attention(q, k, v, causal=causal, window=window)
    out = out.reshape(x.shape[0], x.shape[1], cfg.n_heads * cfg.dh)
    return out @ p["wo"].astype(x.dtype), kv


def decode_attention(cfg, p, x, cache_k, cache_v, pos, *, window: int = 0,
                     cross: bool = False):
    """Single-token decode. cache_k/v: (B, S_max, Hkv, dh); pos: scalar int —
    current position (same for every row of the batch, serve_step semantics).
    Returns (out, new_cache_k, new_cache_v)."""
    B = x.shape[0]
    if cross:
        q = (x @ p["wq"].astype(x.dtype)).reshape(B, 1, cfg.n_heads, cfg.dh)
        if cfg.qkv_bias:
            q = q + p["bq"].astype(x.dtype).reshape(cfg.n_heads, cfg.dh)
        k, v = cache_k, cache_v
    else:
        q, k1, v1 = _qkv(cfg, p, x)
        if cfg.rope_theta > 0:
            cos, sin = L.rope_freqs(jnp.asarray(pos)[None], cfg.dh, cfg.rope_theta)
            q = L.apply_rope(q, cos, sin)
            k1 = L.apply_rope(k1, cos, sin)
        write = pos % cache_k.shape[1] if window > 0 else pos  # ring buffer
        cache_k = jax.lax.dynamic_update_slice(
            cache_k, k1.astype(cache_k.dtype), (0, write, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(
            cache_v, v1.astype(cache_v.dtype), (0, write, 0, 0))
        k, v = cache_k, cache_v
    # windowed ring cache: every live slot is within the window by
    # construction, and `k_pos <= pos` masks slots not yet written, so the
    # causal mask is correct for both the ring and the linear cache.
    out = full_attention(q, k, v, causal=not cross, q_offset=pos)
    out = out.reshape(B, 1, cfg.n_heads * cfg.dh) @ p["wo"].astype(x.dtype)
    return out, cache_k, cache_v


def decode_attention_seqsharded(cfg, p, x, cache_k, cache_v, pos, dist, *,
                                window: int = 0):
    """Distributed flash-decoding for sequence-sharded KV caches (§Perf).

    When kv-heads don't divide TP, the cache shards its SEQ dim over
    "model". The BASELINE decode lets XLA all-gather each layer's cache
    (O(cache/layer) wire per step). Here instead every model rank computes
    partial attention (m_i, l_i, acc_i) over its local 1/tp of the context
    and ranks merge the online-softmax stats — wire per layer drops from
    O(B*S*Hkv*dh) to O(tp * B*Hq*(dh+2)): ~5000x less for phi3-medium
    decode_32k. The cache write lands only on the owner rank's shard.
    """
    B = x.shape[0]
    q, k1, v1 = _qkv(cfg, p, x)
    if cfg.rope_theta > 0:
        cos, sin = L.rope_freqs(jnp.asarray(pos)[None], cfg.dh, cfg.rope_theta)
        q = L.apply_rope(q, cos, sin)
        k1 = L.apply_rope(k1, cos, sin)

    tp_axis = dist.tp_axis
    bspec = P((*dist.batch_axes,), None, None, None)

    def block(q_l, k_new, v_new, ck, cv):
        tp = jax.lax.axis_size(tp_axis)
        r = jax.lax.axis_index(tp_axis)
        s_loc = ck.shape[1]
        # owner-rank cache write (masked dynamic update)
        local_pos = pos - r * s_loc
        in_range = (local_pos >= 0) & (local_pos < s_loc)
        lp = jnp.clip(local_pos, 0, s_loc - 1)
        ck_new = jax.lax.dynamic_update_slice(ck, k_new.astype(ck.dtype),
                                              (0, lp, 0, 0))
        cv_new = jax.lax.dynamic_update_slice(cv, v_new.astype(cv.dtype),
                                              (0, lp, 0, 0))
        ck = jnp.where(in_range, ck_new, ck)
        cv = jnp.where(in_range, cv_new, cv)
        # local partial attention over this rank's context shard
        qg = _group_q(q_l, cfg.n_kv_heads)  # (B,1,G,rep,dh)
        s = jnp.einsum("bqgrd,bkgd->bgrqk", qg, ck,
                       preferred_element_type=jnp.float32) * (cfg.dh ** -0.5)
        k_pos = r * s_loc + jnp.arange(s_loc)
        mask = k_pos[None, None, None, None, :] <= pos
        if window > 0:
            mask = mask & (k_pos[None, None, None, None, :] > pos - window)
        s = jnp.where(mask, s, NEG_INF)
        m = s.max(axis=-1)                        # (B,G,rep,1)
        pexp = jnp.exp(s - m[..., None])
        l = pexp.sum(axis=-1)
        acc = jnp.einsum("bgrqk,bkgd->bgrqd", pexp, cv.astype(jnp.float32))
        # merge partial softmax stats across ranks (tiny collectives)
        m_all = jax.lax.all_gather(m, tp_axis)    # (tp,B,G,rep,1)
        l_all = jax.lax.all_gather(l, tp_axis)
        acc_all = jax.lax.all_gather(acc, tp_axis)
        m_g = m_all.max(axis=0)
        corr = jnp.exp(m_all - m_g[None])
        l_g = (l_all * corr).sum(axis=0)
        acc_g = (acc_all * corr[..., None]).sum(axis=0)
        out = acc_g / jnp.maximum(l_g, 1e-20)[..., None]
        out = out.transpose(0, 3, 1, 2, 4).reshape(B, 1, cfg.n_heads * cfg.dh)
        return out.astype(x.dtype), ck, cv

    out, ck, cv = jax.shard_map(
        block, mesh=dist.mesh,
        in_specs=(P((*dist.batch_axes,), None, None, None),
                  bspec, bspec,
                  P((*dist.batch_axes,), dist.tp_axis, None, None),
                  P((*dist.batch_axes,), dist.tp_axis, None, None)),
        out_specs=(P((*dist.batch_axes,), None, None),
                   P((*dist.batch_axes,), dist.tp_axis, None, None),
                   P((*dist.batch_axes,), dist.tp_axis, None, None)),
        check_vma=False,
    )(q, k1, v1, cache_k, cache_v)
    return out @ p["wo"].astype(x.dtype), ck, cv
