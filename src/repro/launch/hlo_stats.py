"""Parse collective statistics out of post-SPMD optimized HLO text.

``compiled.cost_analysis()`` has FLOPs and HBM bytes but NOT collective
traffic, so we parse ``compiled.as_text()``: for every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute instruction we
record result bytes, derive operand bytes from the replica-group size, and
compute ring-algorithm wire bytes per participating device (the number that
actually divides by link bandwidth).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_SRC_TGT_RE = re.compile(r"source_target_pairs=\{\{")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    # per-kind: [count, result_bytes, operand_bytes, wire_bytes_per_device]
    by_kind: dict
    total_operand_bytes: float
    total_wire_bytes: float

    def summary(self) -> str:
        lines = []
        for k, (c, rb, ob, wb) in sorted(self.by_kind.items()):
            lines.append(f"{k:20s} n={c:4d} result={rb/1e6:10.1f}MB "
                         f"operand={ob/1e6:10.1f}MB wire/dev={wb/1e6:10.1f}MB")
        return "\n".join(lines)


def parse_collectives(hlo_text: str) -> CollectiveStats:
    by_kind = defaultdict(lambda: [0, 0.0, 0.0, 0.0])
    seen_starts = set()
    for line in hlo_text.splitlines():
        m = _COLL_RE.match(line)
        if not m:
            continue
        type_str, kind = m.group(1), m.group(2)
        # avoid double counting async start/done pairs: count starts, skip done
        if "-done(" in line:
            continue
        rb = _type_bytes(type_str)
        g = 1
        mg = _GROUPS_RE.search(line)
        if mg:
            g = len(mg.group(1).split(","))
        else:
            mi = _GROUPS_IOTA_RE.search(line)
            if mi:
                g = int(mi.group(2))
        if kind == "all-gather":
            ob = rb / max(g, 1)
            wire = rb * (g - 1) / max(g, 1)
        elif kind == "reduce-scatter":
            ob = rb * g
            wire = rb * (g - 1)
        elif kind == "all-reduce":
            ob = rb
            wire = 2.0 * rb * (g - 1) / max(g, 1)
        elif kind == "all-to-all":
            ob = rb
            wire = rb * (g - 1) / max(g, 1)
        else:  # collective-permute
            ob = rb
            wire = rb
        ent = by_kind[kind]
        ent[0] += 1
        ent[1] += rb
        ent[2] += ob
        ent[3] += wire
    total_ob = sum(v[2] for v in by_kind.values())
    total_wb = sum(v[3] for v in by_kind.values())
    return CollectiveStats(dict(by_kind), total_ob, total_wb)


def count_op(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}\(", hlo_text))
