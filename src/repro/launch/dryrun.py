import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable (e)).

For every (architecture x input shape) cell, lower + compile the step the
shape dictates (train_step / prefill / decode) against the production mesh
(single-pod 16x16 and multi-pod 2x16x16), print memory_analysis (proves it
fits) and cost_analysis (FLOPs/bytes for the roofline), parse collective
traffic from the optimized HLO, and dump a JSON record consumed by
launch/roofline.py and EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]
"""
import argparse
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCHS, SHAPES, get_arch
from ..models import model as M
from ..models.moe import DistContext
from ..train import train_step as TS
from . import hlo_stats, specs
from .mesh import batch_axes_of, make_production_mesh


def _ns_tree(mesh, pspec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def build_step(cfg, shape, mesh, *, attn_impl: str = "blockwise",
               decode_params_fsdp: bool = True, serve_bf16: bool = False,
               train_opt: bool = False, ssm_chunk: int = 0):
    """Returns (fn, arg_specs, in_shardings, out_shardings, donate)."""
    if ssm_chunk:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, ssm_chunk=ssm_chunk)
    baxes = batch_axes_of(mesh)
    tp = mesh.shape["model"]
    dist = DistContext(mesh, batch_axes=baxes)
    sp = specs.input_specs(cfg, shape)

    if shape.kind == "train":
        if train_opt:
            import dataclasses as _dc
            cfg = _dc.replace(cfg, moe_cmax_factor=1.25, remat_policy="dots")
        mb = max(1, cfg.train_microbatch) * (2 if train_opt else 1)
        tcfg = TS.TrainConfig(microbatch=mb, bf16_params=train_opt)
        sp["state"] = specs.state_specs(cfg, shape.seq_len, tcfg)
        step = TS.make_train_step(cfg, tcfg, dist)
        state_ns = _ns_tree(mesh, TS.train_state_pspecs(cfg, tp, shape.seq_len, tcfg))
        batch_ns = _ns_tree(mesh, TS.batch_pspec(cfg, baxes))
        return (step, (sp["state"], sp["batch"]), (state_ns, batch_ns),
                (state_ns, None), (0,))

    pp = M.param_pspecs(cfg, tp, shape.seq_len)
    if not decode_params_fsdp:
        # TP-only serving weights: drop the FSDP axis; weights that relied on
        # FSDP for sharding (head-count not divisible by tp) get "model" on
        # their largest tp-divisible dim instead — every rank then runs full
        # heads over its seq shard (the flash-decode layout), with only a
        # tiny activation regather.
        def _serve_spec(spec, leaf):
            names = tuple(a if a != "data" else None for a in spec)
            if "model" in names or not hasattr(leaf, "shape") or leaf.ndim == 0:
                return P(*names)
            # replicate small weights: sharding them buys KBs of HBM but
            # costs a per-layer activation psum (measured on xlstm: 554 MiB
            # of wire for a 350M model — worse than replication)
            if leaf.size * 4 < 32 * 2**20:
                return P(*names)
            names = list(names) + [None] * (leaf.ndim - len(names))
            dims = sorted(range(leaf.ndim), key=lambda d: -leaf.shape[d])
            for d in dims:
                if leaf.shape[d] % tp == 0:
                    names[d] = "model"
                    break
            return P(*names)
        pp = jax.tree.map(_serve_spec, pp, sp["params"],
                          is_leaf=lambda x: isinstance(x, P))
    params_ns = _ns_tree(mesh, pp)
    if serve_bf16:
        sp["params"] = jax.tree.map(
            lambda t: jax.ShapeDtypeStruct(t.shape, jnp.bfloat16)
            if t.dtype == jnp.float32 else t, sp["params"])
    caps = jnp.ones((M.n_moe_layers(cfg), max(cfg.n_experts, 1)), jnp.float32) \
        if cfg.moe else None

    if shape.kind == "prefill":
        def fn(params, batch):
            return M.prefill(cfg, params, batch, caps, dist=dist)
        batch_ns = _ns_tree(mesh, {k: P(baxes, *([None] * (len(v.shape) - 1)))
                                   for k, v in sp["batch"].items()})
        return (fn, (sp["params"], sp["batch"]), (params_ns, batch_ns), None, ())

    # decode
    def fn(params, tokens, cache, pos):
        return M.decode_step(cfg, params, tokens, cache, pos, caps, dist=dist)

    cache_ns = _ns_tree(mesh, M.cache_pspecs(cfg, shape.global_batch, mesh, baxes))
    tok_b = baxes if shape.global_batch % _prod(mesh, baxes) == 0 else None
    tok_ns = NamedSharding(mesh, P(tok_b, None))
    pos_ns = NamedSharding(mesh, P())
    logits_ns = NamedSharding(mesh, P(tok_b, "model"))
    return (fn, (sp["params"], sp["tokens"], sp["cache"], sp["pos"]),
            (params_ns, tok_ns, cache_ns, pos_ns),
            (logits_ns, cache_ns), (2,))


def _prod(mesh, axes):
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def run_cell(arch_name: str, shape_name: str, multi_pod: bool = False,
             out_dir: str = "results/dryrun", save_hlo: bool = False,
             **step_kwargs) -> dict:
    cfg = get_arch(arch_name)
    shape = SHAPES[shape_name]
    rec = {"arch": arch_name, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "params": cfg.param_count(),
           "active_params": cfg.active_param_count()}
    if not cfg.supports(shape):
        rec["status"] = "SKIP"
        rec["reason"] = "full-attention arch: long_500k needs sub-quadratic attention (DESIGN.md §5)"
        return _save(rec, out_dir)
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        fn, args, in_sh, out_sh, donate = build_step(cfg, shape, mesh, **step_kwargs)
        t0 = time.time()
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        rec["lower_s"] = round(time.time() - t0, 1)
        t0 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0, 1)
        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
        }
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):  # older jaxlib: one dict per device
            cost = cost[0] if cost else {}
        rec["cost"] = {k: float(v) for k, v in cost.items()
                       if isinstance(v, (int, float)) and k in
                       ("flops", "bytes accessed", "transcendentals",
                        "optimal_seconds", "utilization")}
        hlo = compiled.as_text()
        st = hlo_stats.parse_collectives(hlo)
        rec["collectives"] = {k: {"n": v[0], "result_bytes": v[1],
                                  "operand_bytes": v[2], "wire_bytes": v[3]}
                              for k, v in st.by_kind.items()}
        rec["collective_operand_bytes"] = st.total_operand_bytes
        rec["collective_wire_bytes"] = st.total_wire_bytes
        rec["status"] = "OK"
        if save_hlo:
            p = pathlib.Path(out_dir) / f"{arch_name}_{shape_name}_{rec['mesh']}.hlo"
            p.parent.mkdir(parents=True, exist_ok=True)
            p.write_text(hlo)
        print(f"[dryrun] {arch_name} x {shape_name} ({rec['mesh']}): OK "
              f"flops/dev={rec['cost'].get('flops', 0):.3e} "
              f"args={rec['memory']['argument_bytes']/2**30:.2f}GiB "
              f"temp={rec['memory']['temp_bytes']/2**30:.2f}GiB "
              f"coll={rec['collective_wire_bytes']/2**20:.1f}MiB/dev "
              f"(lower {rec['lower_s']}s compile {rec['compile_s']}s)")
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec["status"] = "FAIL"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        print(f"[dryrun] {arch_name} x {shape_name}: FAIL {rec['error'][:200]}")
    return _save(rec, out_dir)


def _save(rec: dict, out_dir: str) -> dict:
    p = pathlib.Path(out_dir)
    p.mkdir(parents=True, exist_ok=True)
    slim = {k: v for k, v in rec.items() if k != "traceback"}
    name = f"{rec['arch']}_{rec['shape']}_{rec['mesh']}.json"
    (p / name).write_text(json.dumps(slim, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--serve-opt", action="store_true",
                    help="optimized serving: TP-only bf16 weights (§Perf)")
    ap.add_argument("--train-opt", action="store_true",
                    help="optimized training: bf16-cast-once + MoE C_max 1.25 (§Perf)")
    ap.add_argument("--ssm-chunk", type=int, default=0)
    args = ap.parse_args()

    cells = []
    archs = list(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))
    n_ok = n_fail = n_skip = 0
    for a, s, mp in cells:
        kw = dict(decode_params_fsdp=False, serve_bf16=True) if args.serve_opt else {}
        if args.train_opt:
            kw["train_opt"] = True
        if args.ssm_chunk:
            kw["ssm_chunk"] = args.ssm_chunk
        rec = run_cell(a, s, multi_pod=mp, out_dir=args.out,
                       save_hlo=args.save_hlo, **kw)
        n_ok += rec["status"] == "OK"
        n_fail += rec["status"] == "FAIL"
        n_skip += rec["status"] == "SKIP"
    print(f"[dryrun] done: {n_ok} OK, {n_skip} SKIP, {n_fail} FAIL")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
