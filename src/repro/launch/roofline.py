"""Roofline analysis (deliverable (g)).

Reads the dry-run JSON records and derives, per (arch x shape) on the
single-pod mesh:

  compute term    = HLO_FLOPs_per_device / PEAK_FLOPS          [s]
  memory term     = HLO_bytes_per_device / HBM_BW              [s]
  collective term = wire_bytes_per_device / ICI_BW             [s]

(cost_analysis is the per-device SPMD program, so dividing by per-chip peaks
is the brief's "HLO/(chips x peak)" computed shard-wise.) Also reports
MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) against HLO FLOPs — the
useful-compute ratio that exposes remat/recompute and masked-block waste —
the dominant term, and the roofline fraction = compute_term / max(terms).

Usage: PYTHONPATH=src python -m repro.launch.roofline [--dir results/dryrun]
       [--mesh 16x16] [--csv out.csv] [--markdown]
"""
from __future__ import annotations

import argparse
import json
import pathlib

from ..configs import ARCHS, SHAPES
from .costmodel import MeshShape, cell_cost
from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS

HBM_BYTES = 16 * 2**30  # v5e per chip


def tokens_of(shape) -> int:
    if shape.kind == "train":
        return shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return shape.global_batch * shape.seq_len
    return shape.global_batch  # decode: one token per row


def model_flops(arch, shape) -> float:
    """6*N*D for train, 2*N*D for inference (fwd only); MoE uses active N."""
    n = arch.active_param_count()
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * tokens_of(shape)


def analyze(rec: dict, cmax: float = None, **knobs) -> dict:
    """Roofline terms from the ANALYTIC cost model (launch/costmodel.py —
    XLA cost_analysis under-counts While bodies, see module docstring);
    the dry-run record supplies memory fit + the collective inventory."""
    import dataclasses as _dc
    arch = ARCHS[rec["arch"]]
    if cmax is not None and arch.moe:
        arch = _dc.replace(arch, moe_cmax_factor=cmax)
    shape = SHAPES[rec["shape"]]
    multi = rec["mesh"] == "2x16x16"
    mesh = MeshShape(pods=2 if multi else 1)
    cost = cell_cost(arch, shape, mesh, **knobs)
    terms = cost.terms()
    dom = max(terms, key=terms.get)
    t_bound = max(terms.values())
    m = rec["memory"]
    # donated outputs alias inputs: live bytes = args + temps + unaliased out
    mem_total = m["argument_bytes"] + m["temp_bytes"] + max(
        0, m["output_bytes"] - m["alias_bytes"])
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "status": rec["status"],
        "t_compute_s": terms["compute"], "t_memory_s": terms["memory"],
        "t_collective_s": terms["collective"],
        "dominant": dom,
        "roofline_fraction": (terms["compute"] / t_bound) if t_bound > 0 else 0.0,
        "model_flops": cost.useful_flops * mesh.chips,
        "hlo_flops_measured": rec["cost"].get("flops", 0.0),
        "useful_flops_ratio": cost.useful_flops / cost.flops if cost.flops else 0.0,
        "mem_per_dev_bytes": mem_total,
        "fits_hbm": mem_total <= HBM_BYTES,
        "step_time_bound_s": t_bound,
        "mfu_bound": (cost.useful_flops / PEAK_FLOPS) / t_bound if t_bound > 0 else 0.0,
    }


def bottleneck_note(row: dict) -> str:
    d = row["dominant"]
    if d == "collective":
        return ("overlap/shrink collectives: reduce-scatter grads, bf16-cast "
                "before FSDP gather, shard_map flash-decode for seq-sharded KV")
    if d == "memory":
        return ("raise arithmetic intensity: fuse attention (Pallas flash), "
                "larger per-step tile reuse, quantized KV")
    return ("compute-bound: cut non-useful FLOPs (remat policy, causal block "
            "skipping, masked-expert waste) to close useful-ratio gap")


def _opt_knobs(rec):
    """The §Perf lever set, per shape kind (serve-opt / train-opt / kernels)."""
    shape = SHAPES[rec["shape"]]
    if shape.kind == "train":
        return dict(bf16_gather=True, causal_skip=True, ssm_kernel=True,
                    remat_factor=3.2, cmax=1.25)
    if shape.kind == "prefill":
        return dict(causal_skip=True, ssm_kernel=True, decode_fsdp=False)
    return dict(decode_fsdp=False, ssm_kernel=True)


def load(dir_: str, mesh: str, opt: bool = False):
    rows = []
    for f in sorted(pathlib.Path(dir_).glob("*.json")):
        rec = json.loads(f.read_text())
        if rec["mesh"] != mesh:
            continue
        if rec["status"] != "OK":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": rec["mesh"], "status": rec["status"]})
            continue
        knobs = _opt_knobs(rec) if opt else {}
        rows.append(analyze(rec, **knobs))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--csv", default="results/roofline.csv")
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--opt", action="store_true",
                    help="apply the §Perf lever set (serve-opt/train-opt/kernels)")
    args = ap.parse_args()
    rows = load(args.dir, args.mesh, opt=args.opt)

    hdr = ("arch,shape,status,t_compute_ms,t_memory_ms,t_collective_ms,"
           "dominant,roofline_fraction,useful_flops_ratio,mfu_bound,"
           "mem_per_dev_GiB,fits_hbm")
    lines = [hdr]
    for r in rows:
        if r["status"] != "OK":
            lines.append(f"{r['arch']},{r['shape']},{r['status']},,,,,,,,,")
            continue
        lines.append(
            f"{r['arch']},{r['shape']},OK,"
            f"{1e3*r['t_compute_s']:.3f},{1e3*r['t_memory_s']:.3f},"
            f"{1e3*r['t_collective_s']:.3f},{r['dominant']},"
            f"{r['roofline_fraction']:.3f},{r['useful_flops_ratio']:.3f},"
            f"{r['mfu_bound']:.3f},{r['mem_per_dev_bytes']/2**30:.2f},"
            f"{r['fits_hbm']}")
    out = "\n".join(lines)
    print(out)
    if args.csv:
        p = pathlib.Path(args.csv)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(out + "\n")
    if args.markdown:
        print()
        print("| arch | shape | compute | memory | collective | dominant | "
              "roofline frac | useful FLOPs | note |")
        print("|---|---|---|---|---|---|---|---|---|")
        for r in rows:
            if r["status"] != "OK":
                print(f"| {r['arch']} | {r['shape']} | — | — | — | "
                      f"{r['status']} | — | — | sub-quadratic only |")
                continue
            print(f"| {r['arch']} | {r['shape']} | {1e3*r['t_compute_s']:.2f}ms"
                  f" | {1e3*r['t_memory_s']:.2f}ms | {1e3*r['t_collective_s']:.2f}ms"
                  f" | {r['dominant']} | {r['roofline_fraction']:.2f} | "
                  f"{r['useful_flops_ratio']:.2f} | {bottleneck_note(r)[:60]} |")


if __name__ == "__main__":
    main()
