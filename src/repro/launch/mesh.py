"""Production mesh construction (deliverable (e), MULTI-POD DRY-RUN §1).

Defined as FUNCTIONS so importing this module never touches jax device
state. Single pod = 16x16 = 256 chips (v5e pod); multi-pod = 2x16x16 = 512.
"""
from __future__ import annotations

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(devices)} — dryrun.py must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before any "
            "jax import")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_smoke_mesh():
    """1x1 mesh over the single real device (smoke tests / examples)."""
    import jax
    return jax.make_mesh((1, 1), ("data", "model"), devices=jax.devices()[:1])


def batch_axes_of(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


# Hardware constants for the roofline (TPU v5e per chip).
PEAK_FLOPS = 197e12      # bf16 FLOP/s
HBM_BW = 819e9           # bytes/s
ICI_BW = 50e9            # bytes/s per link
