"""Analytic roofline cost model per (arch x shape x mesh).

WHY ANALYTIC: XLA's HloCostAnalysis counts a While body ONCE, independent of
the trip count, so ``compiled.cost_analysis()`` under-counts any scanned
program (layer stacks, microbatch accumulation, blockwise attention, SSD
chunk scans) by large, structure-dependent factors. The dry-run remains the
proof of compile/fit and the inventory of which collectives exist with their
per-instance sizes; the roofline TERMS below are computed from the model
structure and the sharding actually used — the standard production approach
(cf. MFU calculators) — with every formula explicit and unit-tested.

Conventions (documented in EXPERIMENTS.md §Roofline):
  * matmul FLOPs = 2*M*N*K; training multiplies by (fwd=1, bwd=2, remat=1) = 4
    (remat policy "nothing" recomputes the fwd in the bwd pass);
    attention adds one extra fwd (inner kv-scan checkpointing) = 5x fwd.
  * the XLA blockwise attention path computes the FULL S^2 score matrix
    (causal masking, no block skipping) — that waste is charged here and is
    exactly what the Pallas flash kernel removes (see §Perf).
  * MoE expert FLOPs are charged at the padded capacity buffer size
    (E_local * C_max slots per rank), not at the useful token count.
  * HBM bytes: parameter traffic (each local shard read once per fwd/bwd/
    remat pass + optimizer read/write), activation traffic approximated as
    12 bytes/elem per block boundary tensor (write + 2 reads, bf16+fp32 mix),
    KV-cache read/write for decode, gathered-weight traffic for FSDP.
  * collective bytes (wire, per device): FSDP all-gathers of bf16 weights
    (fwd + remat + bwd), grad reduce (reduce-scatter model: (g-1)/g), TP
    psums of block outputs, MoE psum per layer, embedding/logits gathers.
"""
from __future__ import annotations

import dataclasses

from ..configs.base import ArchConfig, ShapeSpec
from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS


@dataclasses.dataclass(frozen=True)
class MeshShape:
    pods: int = 1
    dp: int = 16
    tp: int = 16

    @property
    def chips(self) -> int:
        return self.pods * self.dp * self.tp


@dataclasses.dataclass
class CellCost:
    flops: float           # per device, per step
    hbm_bytes: float       # per device, per step
    wire_bytes: float      # per device, per step (ICI)
    useful_flops: float    # MODEL_FLOPS share per device

    def terms(self):
        return {
            "compute": self.flops / PEAK_FLOPS,
            "memory": self.hbm_bytes / HBM_BW,
            "collective": self.wire_bytes / ICI_BW,
        }


# --------------------------------------------------------------------------
# per-block FLOPs for one token (fwd only, unsharded "global" counts)
# --------------------------------------------------------------------------

def _attn_proj_flops(cfg) -> float:
    d, dh = cfg.d_model, cfg.dh
    return 2.0 * d * (cfg.n_heads * dh + 2 * cfg.n_kv_heads * dh) + \
        2.0 * (cfg.n_heads * dh) * d


def _attn_score_flops(cfg, s_ctx: float) -> float:
    """per-token score+pv FLOPs against context length s_ctx."""
    return 4.0 * s_ctx * cfg.n_heads * cfg.dh


def _mlp_flops(cfg, f: int) -> float:
    mult = 3 if cfg.act == "swiglu" else 2
    return 2.0 * mult * cfg.d_model * f


def _mamba_flops(cfg) -> float:
    d = cfg.d_model
    d_in = cfg.mamba_expand * d
    N = cfg.ssm_state
    H = d_in // cfg.ssm_head_dim
    P = cfg.ssm_head_dim
    Q = cfg.ssm_chunk
    proj = 2.0 * d * (2 * d_in + 2 * N + H) + 2.0 * d_in * d
    # SSD per token: intra scores Q*(N+P) per head + state update N*P per head
    ssd = 2.0 * H * (Q * (N + P) + N * P)
    return proj + ssd


def _mlstm_flops(cfg) -> float:
    d = cfg.d_model
    d_in = cfg.mamba_expand * d
    H = cfg.n_heads
    dh = d_in // H
    Q = cfg.ssm_chunk
    proj = 2.0 * d * 2 * d_in + 3 * 2.0 * d_in * d_in + 2.0 * d_in * d
    intra = 2.0 * H * (Q * (dh + dh) + dh * dh)
    return proj + intra


def _slstm_flops(cfg) -> float:
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    return 2.0 * d * (2 * d + 2 * H) + 2.0 * H * dh * dh + 2.0 * d * d


def _layer_flops_per_token(cfg, kind: str, s_ctx: float) -> float:
    if kind in ("dense", "densffn", "moe", "enc", "dec", "A"):
        f = _attn_proj_flops(cfg) + _attn_score_flops(cfg, s_ctx)
        if kind == "dec":
            f += _attn_proj_flops(cfg) + _attn_score_flops(cfg, cfg.encoder_seq)
        if kind == "dense" or kind in ("enc", "dec", "A"):
            f += _mlp_flops(cfg, cfg.d_ff)
        elif kind == "densffn":
            f += _mlp_flops(cfg, cfg.dense_d_ff or cfg.d_ff)
        else:  # moe: capacity-padded expert compute + shared experts
            waste = getattr(cfg, "moe_cmax_factor", 2.0) * 1.25  # C_max x cf
            f += waste * cfg.experts_per_token * _mlp_flops(cfg, cfg.moe_d_ff)
            f += cfg.n_shared_experts * _mlp_flops(cfg, cfg.moe_d_ff)
            f += 2.0 * cfg.d_model * cfg.n_experts  # router
        return f
    if kind == "M":
        return _mamba_flops(cfg)
    if kind == "X":
        return _mlstm_flops(cfg)
    if kind == "S":
        return _slstm_flops(cfg)
    raise ValueError(kind)


def _layers(cfg) -> list[str]:
    if cfg.family in ("hybrid", "ssm"):
        return list(cfg.block_pattern)
    if cfg.family == "encdec":
        return ["dec"] * cfg.n_layers  # encoder handled separately
    from ..models.model import segments_of
    out = []
    for kind, cnt in segments_of(cfg):
        out += [kind] * cnt
    return out


def _param_bytes(cfg, dtype_bytes: float = 4.0) -> float:
    return cfg.param_count() * dtype_bytes


def _ssm_state_traffic(cfg, tokens_dev: float, chunk: int = None) -> float:
    """HBM roundtrips of the inter-chunk state in the XLA chunked scan:
    2 (read+write) * (tokens/Q) * H*P*N * 4B per recurrent layer. The Pallas
    mamba_scan kernel keeps the state in VMEM scratch => this term ~ 0."""
    if cfg.family not in ("hybrid", "ssm"):
        return 0.0
    Q = chunk or cfg.ssm_chunk
    d_in = cfg.mamba_expand * cfg.d_model
    total = 0.0
    for kind in cfg.block_pattern:
        if kind == "M":
            H = d_in // cfg.ssm_head_dim
            state = H * cfg.ssm_head_dim * cfg.ssm_state
        elif kind == "X":
            dh = d_in // cfg.n_heads
            state = cfg.n_heads * (dh + 1) * dh
        else:
            continue
        total += 2.0 * (tokens_dev / Q) * state * 4.0
    return total


def cell_cost(cfg: ArchConfig, shape: ShapeSpec, mesh: MeshShape = MeshShape(),
              *, causal_skip: bool = False, remat_factor: float = None,
              decode_fsdp: bool = True, bf16_gather: bool = False,
              ssm_kernel: bool = False) -> CellCost:
    """Analytic per-device cost for this cell.

    Knobs mirror §Perf levers: causal_skip (Pallas flash), remat_factor
    (override the recompute multiplier), decode_fsdp (FSDP-sharded serving
    weights => per-step gathers), bf16_gather (cast before FSDP all-gather).
    """
    B, S = shape.global_batch, shape.seq_len
    chips = mesh.chips
    dp_all = mesh.pods * mesh.dp
    layers = _layers(cfg)
    V, D = cfg.padded_vocab, cfg.d_model

    if shape.kind == "train":
        T = B * S
        s_ctx = (S / 2.0) if causal_skip else float(S)
        fwd = sum(_layer_flops_per_token(cfg, k, s_ctx) for k in layers) * T
        if cfg.family == "encdec":
            fwd += cfg.encoder_layers * (
                _attn_proj_flops(cfg) + _attn_score_flops(cfg, cfg.encoder_seq)
                + _mlp_flops(cfg, cfg.d_ff)) * B * cfg.encoder_seq
        fwd += 2.0 * V * D * T  # logits
        # fwd(1) + bwd(2) + layer remat(1); attention inner checkpoint ~ +0.2
        rf = remat_factor if remat_factor is not None else 4.2
        flops_global = fwd * rf
        useful = 6.0 * cfg.active_param_count() * T
        # HBM: params fp32 read x3 (fwd/remat/bwd) + opt m,v read+write +
        # grads write+read; activations ~12B per elem per layer boundary
        pb = _param_bytes(cfg) / (mesh.tp * mesh.dp)  # local shard
        param_traffic = pb * (3 + 4 + 2)
        act = 12.0 * (T / dp_all) * D * (len(layers) + 2) * (1 + 1.0)  # +bwd
        gathered = (_param_bytes(cfg, 2.0 if bf16_gather else 4.0) / mesh.tp) * 3
        hbm = param_traffic + act + gathered
        if not ssm_kernel:
            hbm += _ssm_state_traffic(cfg, T / dp_all) * 2.0  # fwd + remat/bwd
        # wire: FSDP gathers x3 passes + grad reduce-scatter+allgather + TP
        # psums (2 per layer fwd, x2 bwd) + pod all-reduce
        wb = _param_bytes(cfg, 2.0 if bf16_gather else 4.0) / mesh.tp
        fsdp_gather = 3.0 * wb * (mesh.dp - 1) / mesh.dp
        # bf16 params => grads are bf16 at the reduce boundary too
        grad_reduce = 2.0 * (_param_bytes(cfg, 2.0 if bf16_gather else 4.0)
                             / mesh.tp) * (mesh.dp - 1) / mesh.dp
        tp_psum = 4.0 * 2.0 * (T / dp_all) * D * 2.0 * len(layers) * \
            (mesh.tp - 1) / mesh.tp / mesh.tp
        pod = 0.0
        if mesh.pods > 1:
            pod = 2.0 * _param_bytes(cfg) / (mesh.tp * mesh.dp) * \
                (mesh.pods - 1) / mesh.pods
        wire = fsdp_gather + grad_reduce + tp_psum + pod
        return CellCost(flops_global / chips, hbm, wire, useful / chips)

    if shape.kind == "prefill":
        T = B * S
        s_ctx = (S / 2.0) if causal_skip else float(S)
        fwd = sum(_layer_flops_per_token(cfg, k, s_ctx) for k in layers) * T
        fwd += 2.0 * V * D * B  # last-token logits
        pb2 = _param_bytes(cfg, 2.0)
        hbm = pb2 / (mesh.tp * (mesh.dp if decode_fsdp else 1)) + \
            pb2 / mesh.tp + 12.0 * (T / dp_all) * D * len(layers) + \
            _kv_bytes(cfg, B, S) / chips
        if not ssm_kernel:
            hbm += _ssm_state_traffic(cfg, T / dp_all)
        fsdp_gather = (pb2 / mesh.tp) * (mesh.dp - 1) / mesh.dp if decode_fsdp else 0.0
        tp_psum = 2.0 * (T / dp_all) * D * 2.0 * len(layers) * \
            (mesh.tp - 1) / mesh.tp / mesh.tp
        return CellCost(fwd / chips, hbm, fsdp_gather + tp_psum,
                        2.0 * cfg.active_param_count() * T / chips)

    # decode: one token per row, context S
    s_ctx = float(min(S, cfg.attn_window) if cfg.attn_window else S)
    fwd = sum(_layer_flops_per_token(cfg, k, s_ctx) for k in layers) * B
    fwd += 2.0 * V * D * B
    wbytes = 4.0 if decode_fsdp else 2.0  # fp32 baseline vs bf16 serve-opt
    pb2 = _param_bytes(cfg, wbytes)
    kv = _kv_bytes(cfg, B, S)
    hbm = pb2 / mesh.tp + kv / chips + pb2 / (mesh.tp * (mesh.dp if decode_fsdp else 1))
    # decode weights: fp32 FSDP-sharded (baseline) or bf16 TP-only (serve-opt)
    if decode_fsdp:
        fsdp_gather = (_param_bytes(cfg, 4.0) / mesh.tp) * (mesh.dp - 1) / mesh.dp
    else:
        fsdp_gather = 0.0
    # NOTE (measured, §Perf iteration 1.1): XLA SPMD already computes
    # seq-sharded decode attention as sharded-softmax + tiny stat psums —
    # there is NO per-layer cache all-gather; the explicit flash-decode
    # shard_map (models.attention.decode_attention_seqsharded) pins that
    # behavior rather than trusting the partitioner.
    tp_psum = 2.0 * B / dp_all * D * 2.0 * len(layers) * (mesh.tp - 1) / mesh.tp / mesh.tp
    return CellCost(fwd / chips, hbm, fsdp_gather + tp_psum,
                    2.0 * cfg.active_param_count() * B / chips)


def _kv_bytes(cfg, B: int, S: int) -> float:
    """global KV/state cache bytes (bf16 kv, fp32 ssm states)."""
    if cfg.family in ("hybrid", "ssm"):
        total = 0.0
        d_in = cfg.mamba_expand * cfg.d_model
        for kind in cfg.block_pattern:
            if kind == "A":
                w = min(S, cfg.attn_window) if cfg.attn_window else S
                total += 2.0 * B * w * cfg.n_kv_heads * cfg.dh * 2
            elif kind == "M":
                H = d_in // cfg.ssm_head_dim
                total += 4.0 * B * H * cfg.ssm_head_dim * cfg.ssm_state
            elif kind == "X":
                dh = d_in // cfg.n_heads
                total += 4.0 * B * cfg.n_heads * (dh + 1) * dh
            else:
                total += 4.0 * B * cfg.d_model * 2
        return total
    n_attn = cfg.n_layers
    kv = 2.0 * B * S * cfg.n_kv_heads * cfg.dh * 2 * n_attn
    if cfg.family == "encdec":
        kv += 2.0 * B * cfg.encoder_seq * cfg.n_kv_heads * cfg.dh * 2 * cfg.n_layers
    return kv
