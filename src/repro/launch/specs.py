"""ShapeDtypeStruct stand-ins for every model input (dry-run §2): weak-type
correct, shardable, no device allocation."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig, ShapeSpec
from ..models import model as M
from ..train import train_step as TS


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ArchConfig, shape: ShapeSpec):
    B, S = shape.global_batch, shape.seq_len
    batch = {"tokens": sds((B, S), jnp.int32), "labels": sds((B, S), jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = sds((B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["patches"] = sds((B, cfg.num_patches, cfg.d_model), jnp.bfloat16)
    return batch


def prefill_batch_specs(cfg: ArchConfig, shape: ShapeSpec):
    b = train_batch_specs(cfg, shape)
    del b["labels"]
    return b


def decode_input_specs(cfg: ArchConfig, shape: ShapeSpec, dtype=jnp.bfloat16):
    """(tokens, cache, pos) for decode_step."""
    B, S = shape.global_batch, shape.seq_len
    tokens = sds((B, 1), jnp.int32)
    cache = M.cache_specs(cfg, B, S, dtype)
    pos = sds((), jnp.int32)
    return tokens, cache, pos


def state_specs(cfg: ArchConfig, max_seq: int, tcfg=None):
    tcfg = tcfg or TS.TrainConfig()
    return jax.eval_shape(
        lambda: TS.init_train_state(cfg, jax.random.PRNGKey(0), max_seq, tcfg))


def input_specs(cfg: ArchConfig, shape: ShapeSpec):
    """All inputs for the step this shape lowers (brief: dry-run §2)."""
    if shape.kind == "train":
        return {"state": state_specs(cfg, shape.seq_len),
                "batch": train_batch_specs(cfg, shape)}
    if shape.kind == "prefill":
        return {"params": jax.eval_shape(
                    lambda: M.init_params(cfg, jax.random.PRNGKey(0), shape.seq_len)),
                "batch": prefill_batch_specs(cfg, shape)}
    tokens, cache, pos = decode_input_specs(cfg, shape)
    return {"params": jax.eval_shape(
                lambda: M.init_params(cfg, jax.random.PRNGKey(0), shape.seq_len)),
            "tokens": tokens, "cache": cache, "pos": pos}
